"""Kernel correctness: Pallas (interpret) vs pure-jnp ref vs a scalar
transliteration of the paper's rule. Hypothesis sweeps shapes and value
regimes (overlap-heavy, ordered-heavy, ε-uncertain)."""

import os
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model
from compile.kernels import hvc, ref


def make_intervals(rng, n, d, spread, gap):
    """Random intervals; `spread` controls concurrency density, `gap`
    shifts consecutive intervals apart (ordered-heavy when large)."""
    base = (rng.integers(0, spread, size=(n, 1)) + np.arange(n)[:, None] * gap).astype(np.int64)
    start = (base + rng.integers(0, 20, size=(n, d))).astype(np.int32)
    end = start + rng.integers(0, 30, size=(n, d)).astype(np.int32)
    owners = rng.integers(0, d, size=n)
    idx = np.arange(n)
    # owner component must be the max (it's the process's own physical time)
    start[idx, owners] = start.max(axis=1)
    end[idx, owners] = end.max(axis=1)
    return start, end, owners


def owner_vals(arr, owners):
    return arr[np.arange(arr.shape[0]), owners].astype(np.int32)


@given(
    n=st.integers(min_value=1, max_value=48),
    d=st.integers(min_value=1, max_value=8),
    spread=st.sampled_from([5, 50, 500]),
    gap=st.sampled_from([0, 10, 100]),
    eps=st.sampled_from([0, 3, 25, 1 << 30]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=60, deadline=None)
def test_pair_verdict_matches_ref_and_paper_rule(n, d, spread, gap, eps, seed):
    rng = np.random.default_rng(seed)
    a_s, a_e, own_a = make_intervals(rng, n, d, spread, gap)
    b_s, b_e, own_b = make_intervals(rng, n, d, spread, gap)
    a_so, a_eo = owner_vals(a_s, own_a), owner_vals(a_e, own_a)
    b_so, b_eo = owner_vals(b_s, own_b), owner_vals(b_e, own_b)
    eps_arr = np.array([eps], dtype=np.int32)

    got = np.asarray(
        hvc.pair_verdict(a_s, a_e, b_s, b_e, a_so, a_eo, b_so, b_eo, eps_arr)
    )
    want = np.asarray(
        ref.pair_verdict_ref(a_s, a_e, b_s, b_e, a_so, a_eo, b_so, b_eo, eps)
    )
    np.testing.assert_array_equal(got, want)

    # independent scalar oracle (the paper's rule, line by line)
    for i in range(n):
        scalar = ref.paper_rule_scalar(
            a_s[i].tolist(), a_e[i].tolist(), b_s[i].tolist(), b_e[i].tolist(),
            int(own_a[i]), int(own_b[i]), eps,
        )
        assert got[i] == scalar, f"pair {i}: kernel={got[i]} scalar={scalar}"


@given(
    tiles=st.integers(min_value=1, max_value=3),
    d=st.integers(min_value=1, max_value=8),
    eps=st.sampled_from([0, 5, 1 << 30]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_cut_matrix_matches_ref(tiles, d, eps, seed):
    tile = 8
    n = tiles * tile
    rng = np.random.default_rng(seed)
    s, e, owners = make_intervals(rng, n, d, 100, 5)
    so, eo = owner_vals(s, owners), owner_vals(e, owners)
    eps_arr = np.array([eps], dtype=np.int32)
    got = np.asarray(hvc.cut_matrix(s, e, so, eo, eps_arr, tile=tile))
    want = np.asarray(ref.cut_matrix_ref(s, e, so, eo, eps))
    np.testing.assert_array_equal(got, want)


def test_verdict_antisymmetry_property():
    rng = np.random.default_rng(7)
    n, d = 128, 4
    a_s, a_e, own_a = make_intervals(rng, n, d, 50, 20)
    b_s, b_e, own_b = make_intervals(rng, n, d, 50, 20)
    a_so, a_eo = owner_vals(a_s, own_a), owner_vals(a_e, own_a)
    b_so, b_eo = owner_vals(b_s, own_b), owner_vals(b_e, own_b)
    eps = np.array([5], dtype=np.int32)
    ab = np.asarray(ref.pair_verdict_ref(a_s, a_e, b_s, b_e, a_so, a_eo, b_so, b_eo, 5))
    ba = np.asarray(ref.pair_verdict_ref(b_s, b_e, a_s, a_e, b_so, b_eo, a_so, a_eo, 5))
    swap = {0: 0, 1: 2, 2: 1}
    assert all(ba[i] == swap[int(ab[i])] for i in range(n))
    del eps


def test_eps_infinity_means_never_ordered():
    # ε = ∞ (the paper's experimental setting) ⇒ case 2 never fires:
    # physically disjoint but causally incomparable intervals stay concurrent
    d = 4
    a_s = np.full((1, d), 10, dtype=np.int32)
    a_e = np.full((1, d), 20, dtype=np.int32)
    b_s = np.full((1, d), 500, dtype=np.int32)
    b_e = np.full((1, d), 600, dtype=np.int32)
    own = np.array([0])
    args = (a_s, a_e, b_s, b_e,
            owner_vals(a_s, own), owner_vals(a_e, own),
            owner_vals(b_s, own), owner_vals(b_e, own))
    small = np.asarray(ref.pair_verdict_ref(*args, 5))
    inf = np.asarray(ref.pair_verdict_ref(*args, 1 << 30))
    assert small[0] == 1, "clearly ordered with small eps"
    assert inf[0] == 0, "eps=inf keeps them concurrent"


def test_overlapping_intervals_concurrent_any_eps():
    d = 3
    a_s = np.array([[10, 10, 10]], dtype=np.int32)
    a_e = np.array([[50, 50, 50]], dtype=np.int32)
    b_s = np.array([[30, 30, 30]], dtype=np.int32)
    b_e = np.array([[70, 70, 70]], dtype=np.int32)
    own = np.array([1])
    for eps in (0, 100, 1 << 30):
        v = np.asarray(ref.pair_verdict_ref(
            a_s, a_e, b_s, b_e,
            owner_vals(a_s, own), owner_vals(a_e, own),
            owner_vals(b_s, own), owner_vals(b_e, own), eps))
        assert v[0] == 0


def test_model_cut_counts():
    # three mutually overlapping + one far-later interval (small eps)
    d = 2
    s = np.array([[0, 0], [5, 5], [8, 8], [1000, 1000]], dtype=np.int32)
    e = np.array([[20, 20], [25, 25], [30, 30], [1100, 1100]], dtype=np.int32)
    # pad to one tile
    pad = 32 - 4
    s = np.vstack([s, np.full((pad, d), 10_000, dtype=np.int32)])
    e = np.vstack([e, np.full((pad, d), 10_001, dtype=np.int32)])
    so = s[:, 0].copy()
    eo = e[:, 0].copy()
    eps = np.array([2], dtype=np.int32)
    m, counts = model.cut_matrix_fn(s, e, so, eo, eps)
    m = np.asarray(m)
    counts = np.asarray(counts)
    assert m[0, 1] == 0 and m[1, 2] == 0 and m[0, 2] == 0
    assert m[0, 3] == 1 and m[3, 0] == 2
    assert counts[0] >= 2 and counts[1] >= 2 and counts[2] >= 2


@pytest.mark.parametrize("b", [1, 7, 256])
def test_pair_verdict_shapes(b):
    d = 8
    rng = np.random.default_rng(b)
    a_s, a_e, own_a = make_intervals(rng, b, d, 50, 5)
    b_s, b_e, own_b = make_intervals(rng, b, d, 50, 5)
    out = hvc.pair_verdict(
        a_s, a_e, b_s, b_e,
        owner_vals(a_s, own_a), owner_vals(a_e, own_a),
        owner_vals(b_s, own_b), owner_vals(b_e, own_b),
        np.array([3], dtype=np.int32),
    )
    assert out.shape == (b,)
    assert out.dtype == np.int32
