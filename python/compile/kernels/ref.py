"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: `hvc.py`'s Pallas kernels must
match them bit-for-bit (pytest + hypothesis sweep), and the Rust scalar
backend (`runtime::accel::NativeAccel`) matches the same semantics via the
differential test in rust/tests/xla_accel.rs.

Semantics — the paper's 3-case HVC-interval causality rule (§V, Fig. 6),
oriented so that ¬(start_a > start_b):

  1. ¬(end_x < start_y)                          → 0 (concurrent)
  2. end_x < start_y ∧ end_x[Sx] ≤ start_y[Sy]−ε → x before y
  3. end_x < start_y, within ε                   → 0 (uncertain ⇒ concurrent)

Verdict encoding: 0 = concurrent, 1 = a before b, 2 = b before a.
"""

import jax.numpy as jnp


def vec_less(x, y):
    """Strict vector less-than over the trailing (HVC) axis:
    all(x <= y) and any(x < y)."""
    le = jnp.all(x <= y, axis=-1)
    lt = jnp.any(x < y, axis=-1)
    return jnp.logical_and(le, lt)


def pair_verdict_ref(a_start, a_end, b_start, b_end,
                     a_start_own, a_end_own, b_start_own, b_end_own, eps):
    """Batched pair verdicts.

    Args:
      a_start, a_end, b_start, b_end: i32[B, D] HVC vectors (ms).
      *_own: i32[B] owner-component values of the respective endpoints.
      eps: i32[] (scalar) clock-synchronization bound, ms.

    Returns:
      i32[B] verdicts (0 concurrent / 1 a→b / 2 b→a).
    """
    # orientation: swap when start_a > start_b (i.e. start_b < start_a)
    swapped = vec_less(b_start, a_start)  # [B] bool
    sw = swapped[:, None]
    x_end = jnp.where(sw, b_end, a_end)
    y_start = jnp.where(sw, a_start, b_start)
    x_end_own = jnp.where(swapped, b_end_own, a_end_own)
    y_start_own = jnp.where(swapped, a_start_own, b_start_own)

    ordered = vec_less(x_end, y_start)
    separated = x_end_own <= y_start_own - eps
    before = jnp.logical_and(ordered, separated)
    verdict = jnp.where(before, jnp.where(swapped, 2, 1), 0)
    return verdict.astype(jnp.int32)


def cut_matrix_ref(starts, ends, owns_start, owns_end, eps):
    """Pairwise verdict matrix for N candidate intervals.

    Args:
      starts, ends: i32[N, D]; owns_*: i32[N]; eps: i32[].

    Returns:
      i32[N, N]: verdict of (interval i, interval j); diagonal is 0
      (an interval is concurrent with itself — overlap case).
    """
    n = starts.shape[0]
    a_start = jnp.repeat(starts, n, axis=0)  # [N*N, D] (i varies slowly)
    a_end = jnp.repeat(ends, n, axis=0)
    b_start = jnp.tile(starts, (n, 1))
    b_end = jnp.tile(ends, (n, 1))
    a_so = jnp.repeat(owns_start, n)
    a_eo = jnp.repeat(owns_end, n)
    b_so = jnp.tile(owns_start, n)
    b_eo = jnp.tile(owns_end, n)
    v = pair_verdict_ref(a_start, a_end, b_start, b_end, a_so, a_eo, b_so, b_eo, eps)
    return v.reshape(n, n)


def paper_rule_scalar(a_start, a_end, b_start, b_end, owner_a, owner_b, eps):
    """Direct (unvectorized) transliteration of the paper's rule, used by
    the tests as an independent oracle for the oracles."""
    def less(x, y):
        return all(p <= q for p, q in zip(x, y)) and any(p < q for p, q in zip(x, y))

    if less(b_start, a_start):
        v = paper_rule_scalar(b_start, b_end, a_start, a_end, owner_b, owner_a, eps)
        return {0: 0, 1: 2, 2: 1}[v]
    if less(a_end, b_start):
        if a_end[owner_a] <= b_start[owner_b] - eps:
            return 1
        return 0
    return 0
