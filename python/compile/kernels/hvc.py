"""L1 — Pallas kernels for batched HVC-interval causality verdicts.

The monitors' compute hot-spot: given batches of candidate HVC intervals,
decide concurrent / before / after under the paper's 3-case rule. Two
kernels:

* `pair_verdict(...)` — B independent pairs → i32[B] verdicts. One VMEM
  block (B×D i32 is tiny), pure VPU comparisons, no data-dependent control
  flow.
* `cut_matrix(...)` — N intervals → i32[N, N] pairwise verdict matrix,
  tiled over (TI, TJ) output blocks with BlockSpec so the candidate tiles
  stream HBM→VMEM; this is the shape a real-TPU deployment would run when
  joining monitor windows in bulk.

Kernels are lowered with `interpret=True`: the CPU PJRT client cannot run
Mosaic custom-calls, and correctness (vs `ref.py`) is the build-time
signal. The TPU performance story (VMEM footprint, lane mapping) is
estimated in DESIGN.md §Hardware-Adaptation.

Clock encoding: i32 milliseconds; ε=∞ floor entries are pre-shifted by the
Rust caller (see rust/src/runtime/pjrt.rs `encode_ms`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# shared verdict math (traced inside the kernels)
# ---------------------------------------------------------------------------

def _vec_less(x, y):
    le = jnp.all(x <= y, axis=-1)
    lt = jnp.any(x < y, axis=-1)
    return jnp.logical_and(le, lt)


def _verdict(a_start, a_end, b_start, b_end,
             a_start_own, a_end_own, b_start_own, b_end_own, eps):
    swapped = _vec_less(b_start, a_start)
    sw = swapped[..., None]
    x_end = jnp.where(sw, b_end, a_end)
    y_start = jnp.where(sw, a_start, b_start)
    x_end_own = jnp.where(swapped, b_end_own, a_end_own)
    y_start_own = jnp.where(swapped, a_start_own, b_start_own)
    ordered = _vec_less(x_end, y_start)
    separated = x_end_own <= y_start_own - eps
    before = jnp.logical_and(ordered, separated)
    return jnp.where(before, jnp.where(swapped, 2, 1), 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# pair_verdict: B independent pairs, single block
# ---------------------------------------------------------------------------

def _pair_verdict_kernel(a_start_ref, a_end_ref, b_start_ref, b_end_ref,
                         a_so_ref, a_eo_ref, b_so_ref, b_eo_ref, eps_ref,
                         out_ref):
    eps = eps_ref[0]
    out_ref[...] = _verdict(
        a_start_ref[...], a_end_ref[...], b_start_ref[...], b_end_ref[...],
        a_so_ref[...], a_eo_ref[...], b_so_ref[...], b_eo_ref[...], eps,
    )


@functools.partial(jax.jit, static_argnames=())
def pair_verdict(a_start, a_end, b_start, b_end,
                 a_start_own, a_end_own, b_start_own, b_end_own, eps):
    """i32[B,D] ×4, i32[B] ×4, i32[1]  →  i32[B] verdicts."""
    b = a_start.shape[0]
    return pl.pallas_call(
        _pair_verdict_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(a_start, a_end, b_start, b_end,
      a_start_own, a_end_own, b_start_own, b_end_own, eps)


# ---------------------------------------------------------------------------
# cut_matrix: N×N pairwise verdicts, tiled output grid
# ---------------------------------------------------------------------------

def _cut_matrix_kernel(starts_i_ref, ends_i_ref, so_i_ref, eo_i_ref,
                       starts_j_ref, ends_j_ref, so_j_ref, eo_j_ref,
                       eps_ref, out_ref):
    # tile shapes: [TI, D] for the i-side, [TJ, D] for the j-side
    eps = eps_ref[0]
    si = starts_i_ref[...]          # [TI, D]
    ei = ends_i_ref[...]
    sj = starts_j_ref[...]          # [TJ, D]
    ej = ends_j_ref[...]
    # broadcast to [TI, TJ, D]
    a_start = si[:, None, :]
    a_end = ei[:, None, :]
    b_start = sj[None, :, :]
    b_end = ej[None, :, :]
    a_so = so_i_ref[...][:, None]
    a_eo = eo_i_ref[...][:, None]
    b_so = so_j_ref[...][None, :]
    b_eo = eo_j_ref[...][None, :]
    out_ref[...] = _verdict(a_start, a_end, b_start, b_end,
                            a_so, a_eo, b_so, b_eo, eps)


def cut_matrix(starts, ends, owns_start, owns_end, eps, tile=32):
    """i32[N,D] ×2, i32[N] ×2, i32[1] → i32[N,N] pairwise verdicts.

    The output is produced in (tile × tile) blocks; each grid step loads
    one i-tile and one j-tile of candidates into VMEM.
    """
    n, d = starts.shape
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        _cut_matrix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((tile, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile,), lambda i, j: (j,)),
            pl.BlockSpec((tile,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.int32),
        interpret=True,
    )(starts, ends, owns_start, owns_end, starts, ends, owns_start, owns_end, eps)
