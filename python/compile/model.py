"""L2 — the JAX detector graph composed from the L1 Pallas kernels.

Two exports, AOT-lowered by `aot.py` into `artifacts/` and executed from
the Rust monitor hot path through PJRT:

* `pair_verdict_fn`  — B pair verdicts (the monitor's candidate-vs-window
  join when a new candidate arrives);
* `cut_matrix_fn`    — N×N pairwise verdicts plus, fused on top, the
  per-row count of concurrent partners (a cheap reduction the monitor
  uses to prune rows with no partner before the exact tuple search).

Everything here is shape-static (PJRT executables are compiled per
shape); the Rust side pads batches to the compiled size.
"""

import jax.numpy as jnp

from compile.kernels import hvc


def pair_verdict_fn(a_start, a_end, b_start, b_end,
                    a_start_own, a_end_own, b_start_own, b_end_own, eps):
    """i32[B] verdicts for B interval pairs."""
    return (hvc.pair_verdict(a_start, a_end, b_start, b_end,
                             a_start_own, a_end_own, b_start_own, b_end_own,
                             eps),)


def cut_matrix_fn(starts, ends, owns_start, owns_end, eps):
    """(i32[N,N] verdict matrix, i32[N] concurrent-partner counts).

    The count excludes the diagonal (an interval trivially "overlaps"
    itself under the rule).
    """
    m = hvc.cut_matrix(starts, ends, owns_start, owns_end, eps)
    n = m.shape[0]
    concurrent = (m == 0).astype(jnp.int32)
    off_diag = concurrent - jnp.eye(n, dtype=jnp.int32)
    counts = jnp.sum(off_diag, axis=1)
    return m, counts
