"""AOT lowering: JAX/Pallas detector kernels → HLO *text* artifacts.

HLO text (NOT `lowered.compile()` serialization) is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids which
xla_extension 0.5.1 (behind the rust `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Each export writes:
  artifacts/<name>.hlo.txt   — HLO text, loaded by rust runtime/pjrt.rs
  artifacts/<name>.meta      — whitespace-separated static shape params

Run `make artifacts` (idempotent: skips when inputs are older than
outputs). A self-check executes each lowered function against ref.py on
random inputs before anything is written.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402

# compiled batch size of the pair-verdict executable (rust pads to this)
PAIR_B = 256
# compiled candidate count / tile of the cut-matrix executable
CUT_N = 64
CUT_TILE = 32
# padded HVC dimension (max servers; the paper's N is 3 or 5)
DIM = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _rand_clocks(rng, n, d):
    base = rng.integers(0, 1000, size=(n, 1), dtype=np.int32)
    start = base + rng.integers(0, 50, size=(n, d), dtype=np.int32)
    end = start + rng.integers(0, 50, size=(n, d), dtype=np.int32)
    return start.astype(np.int32), end.astype(np.int32)


def selfcheck_pair():
    rng = np.random.default_rng(0)
    a_s, a_e = _rand_clocks(rng, PAIR_B, DIM)
    b_s, b_e = _rand_clocks(rng, PAIR_B, DIM)
    owners_a = rng.integers(0, DIM, size=PAIR_B)
    owners_b = rng.integers(0, DIM, size=PAIR_B)
    a_so = a_s[np.arange(PAIR_B), owners_a]
    a_eo = a_e[np.arange(PAIR_B), owners_a]
    b_so = b_s[np.arange(PAIR_B), owners_b]
    b_eo = b_e[np.arange(PAIR_B), owners_b]
    eps = np.array([7], dtype=np.int32)
    got = model.pair_verdict_fn(a_s, a_e, b_s, b_e, a_so, a_eo, b_so, b_eo, eps)[0]
    want = ref.pair_verdict_ref(a_s, a_e, b_s, b_e, a_so, a_eo, b_so, b_eo, eps[0])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def selfcheck_cut():
    rng = np.random.default_rng(1)
    s, e = _rand_clocks(rng, CUT_N, DIM)
    owners = rng.integers(0, DIM, size=CUT_N)
    so = s[np.arange(CUT_N), owners]
    eo = e[np.arange(CUT_N), owners]
    eps = np.array([7], dtype=np.int32)
    m, counts = model.cut_matrix_fn(s, e, so, eo, eps)
    want = ref.cut_matrix_ref(s, e, so, eo, eps[0])
    np.testing.assert_array_equal(np.asarray(m), np.asarray(want))
    assert counts.shape == (CUT_N,)


def export_pair(outdir: str):
    args = (
        _i32((PAIR_B, DIM)), _i32((PAIR_B, DIM)),
        _i32((PAIR_B, DIM)), _i32((PAIR_B, DIM)),
        _i32((PAIR_B,)), _i32((PAIR_B,)), _i32((PAIR_B,)), _i32((PAIR_B,)),
        _i32((1,)),
    )
    lowered = jax.jit(model.pair_verdict_fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(os.path.join(outdir, "pair_verdict.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(outdir, "pair_verdict.meta"), "w") as f:
        f.write(f"{PAIR_B} {DIM}\n")
    return len(text)


def export_cut(outdir: str):
    args = (
        _i32((CUT_N, DIM)), _i32((CUT_N, DIM)),
        _i32((CUT_N,)), _i32((CUT_N,)),
        _i32((1,)),
    )
    lowered = jax.jit(model.cut_matrix_fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(os.path.join(outdir, "cut_matrix.hlo.txt"), "w") as f:
        f.write(text)
    with open(os.path.join(outdir, "cut_matrix.meta"), "w") as f:
        f.write(f"{CUT_N} {DIM} {CUT_TILE}\n")
    return len(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="output dir (default ../artifacts)")
    args = ap.parse_args()
    outdir = args.out or os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts",
    )
    os.makedirs(outdir, exist_ok=True)
    print("self-check: pair_verdict vs ref ...", flush=True)
    selfcheck_pair()
    print("self-check: cut_matrix vs ref ...", flush=True)
    selfcheck_cut()
    n1 = export_pair(outdir)
    print(f"wrote pair_verdict.hlo.txt ({n1} chars, B={PAIR_B}, D={DIM})")
    n2 = export_cut(outdir)
    print(f"wrote cut_matrix.hlo.txt ({n2} chars, N={CUT_N}, D={DIM})")


if __name__ == "__main__":
    main()
