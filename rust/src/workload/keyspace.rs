//! Key-popularity samplers: which key does the next operation touch?
//!
//! Production KV traffic is skewed — a few keys absorb most operations
//! (PCAP, 1509.02464 §V measures consistency–latency under exactly this
//! knob). We model popularity over a rank space `0..n` with three
//! distributions: uniform (the pre-workload default), Zipf with
//! parameter θ (rank r gets weight `(r+1)^-θ`), and a hot-set split
//! (a fixed fraction of traffic lands on the first `hot` ranks).
//!
//! Sampling must be O(1) **and** bit-reproducible across engines: the
//! sharded runner replays the same per-client RNG streams on every
//! worker, so a draw may not cost a data-dependent number of RNG calls
//! beyond what rejection sampling already pins. We therefore build a
//! Walker/Vose **alias table** once at setup (pure `f64` arithmetic,
//! no RNG) and sample with exactly two draws: one `below(n)` column
//! pick and one `f64()` coin. Uniform stays a single `below(n)` so the
//! inert default consumes precisely the draws today's apps make.

use crate::util::rng::Rng;

/// Key-popularity distribution over ranks `0..n_keys`.
#[derive(Debug, Clone, PartialEq)]
pub enum KeyDist {
    /// Every rank equally likely — the inert default.
    Uniform,
    /// Zipf: rank `r` has weight `(r+1)^-theta`. `theta = 0` degenerates
    /// to uniform weights (but still samples through the alias table);
    /// production traces sit around `theta ∈ [0.99, 1.2]`.
    Zipf { theta: f64 },
    /// The first `hot` ranks share `hot_frac` of the mass uniformly;
    /// the remaining ranks share the rest uniformly.
    HotSet { hot: usize, hot_frac: f64 },
}

impl KeyDist {
    /// Closed-form probability of each rank under this distribution —
    /// the reference the alias table is pinned against in tests.
    pub fn closed_form(&self, n: usize) -> Vec<f64> {
        assert!(n > 0);
        match self {
            KeyDist::Uniform => vec![1.0 / n as f64; n],
            KeyDist::Zipf { theta } => {
                let w: Vec<f64> = (0..n).map(|r| ((r + 1) as f64).powf(-theta)).collect();
                let h: f64 = w.iter().sum();
                w.into_iter().map(|x| x / h).collect()
            }
            KeyDist::HotSet { hot, hot_frac } => {
                let hot = (*hot).min(n);
                if hot == 0 || hot == n {
                    return vec![1.0 / n as f64; n];
                }
                let cold = n - hot;
                (0..n)
                    .map(|r| {
                        if r < hot {
                            hot_frac / hot as f64
                        } else {
                            (1.0 - hot_frac) / cold as f64
                        }
                    })
                    .collect()
            }
        }
    }

    /// Validation shared by [`crate::workload::WorkloadCfg::validate`].
    pub fn validate(&self) -> Result<(), String> {
        match self {
            KeyDist::Uniform => Ok(()),
            KeyDist::Zipf { theta } => {
                if !theta.is_finite() || *theta <= 0.0 {
                    Err(format!("zipf theta must be finite and > 0, got {theta}"))
                } else {
                    Ok(())
                }
            }
            KeyDist::HotSet { hot, hot_frac } => {
                if *hot == 0 {
                    Err("hot-set needs at least one hot key".into())
                } else if !(0.0..=1.0).contains(hot_frac) {
                    Err(format!("hot_frac must be in [0, 1], got {hot_frac}"))
                } else {
                    Ok(())
                }
            }
        }
    }
}

/// O(1) rank sampler: uniform fast path or a prebuilt alias table.
#[derive(Debug, Clone)]
pub struct KeySampler {
    n: usize,
    table: Option<AliasTable>,
}

/// Vose alias table: column `i` returns `i` with probability `prob[i]`,
/// otherwise `alias[i]`.
#[derive(Debug, Clone)]
struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build from (unnormalized) weights. Deterministic: the small/large
    /// worklists are plain index-ordered stacks, no RNG, no float
    /// comparison beyond the canonical `< 1.0` split.
    fn build(weights: &[f64]) -> Self {
        let n = weights.len();
        let sum: f64 = weights.iter().sum();
        assert!(sum > 0.0, "alias table needs positive total weight");
        // scale so the mean column holds exactly 1.0
        let mut scaled: Vec<f64> = weights.iter().map(|w| w * n as f64 / sum).collect();
        let mut alias = vec![0u32; n];
        let mut prob = vec![1.0f64; n];
        let mut small: Vec<usize> = Vec::new();
        let mut large: Vec<usize> = Vec::new();
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l as u32;
            scaled[l] -= 1.0 - scaled[s];
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // float-drift leftovers on either list are full columns
        for i in small.into_iter().chain(large) {
            prob[i] = 1.0;
            alias[i] = i as u32;
        }
        Self { prob, alias }
    }

    /// Reconstruct P(rank) from the table itself: column `i` contributes
    /// `prob[i]/n` to rank `i` and `(1-prob[i])/n` to rank `alias[i]`.
    /// Pure bookkeeping — no sampling noise — so tests can pin it
    /// against the closed form at 1e-12.
    fn mass(&self) -> Vec<f64> {
        let n = self.prob.len();
        let mut p = vec![0.0f64; n];
        for i in 0..n {
            p[i] += self.prob[i] / n as f64;
            p[self.alias[i] as usize] += (1.0 - self.prob[i]) / n as f64;
        }
        p
    }
}

impl KeySampler {
    /// Build a sampler for `n` ranks. Uniform takes the no-table path
    /// (one RNG draw per sample — identical to pre-workload apps).
    pub fn new(dist: &KeyDist, n: usize) -> Self {
        assert!(n > 0, "keyspace must be non-empty");
        dist.validate().unwrap_or_else(|e| panic!("bad key distribution: {e}"));
        let table = match dist {
            KeyDist::Uniform => None,
            _ => Some(AliasTable::build(&dist.closed_form(n))),
        };
        Self { n, table }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Draw one rank. Uniform: exactly one `below(n)`. Skewed: exactly
    /// one `below(n)` plus one `f64()` — the draw count is input-
    /// independent, which is what keeps sharded replays bit-identical.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let col = rng.below(self.n as u64) as usize;
        match &self.table {
            None => col,
            Some(t) => {
                if rng.f64() < t.prob[col] {
                    col
                } else {
                    t.alias[col] as usize
                }
            }
        }
    }

    /// Exact per-rank mass this sampler realizes (closed form for
    /// uniform, alias-table reconstruction otherwise).
    pub fn mass(&self) -> Vec<f64> {
        match &self.table {
            None => vec![1.0 / self.n as f64; self.n],
            Some(t) => t.mass(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_mass_matches(dist: KeyDist, n: usize) {
        let sampler = KeySampler::new(&dist, n);
        let got = sampler.mass();
        let want = dist.closed_form(n);
        for (r, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (g - w).abs() < 1e-12,
                "{dist:?} n={n} rank {r}: table mass {g} vs closed form {w}"
            );
        }
        let total: f64 = got.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "mass sums to {total}");
    }

    #[test]
    fn alias_table_reproduces_zipf_closed_form_exactly() {
        // no sampling noise: the alias table's reconstructed mass must
        // equal the closed-form Zipf pmf to float precision
        for theta in [0.5, 0.8, 0.99, 1.2, 2.0] {
            assert_mass_matches(KeyDist::Zipf { theta }, 128);
        }
        assert_mass_matches(KeyDist::Zipf { theta: 0.99 }, 1);
        assert_mass_matches(KeyDist::Zipf { theta: 1.2 }, 1000);
    }

    #[test]
    fn alias_table_reproduces_hot_set_exactly() {
        assert_mass_matches(KeyDist::HotSet { hot: 4, hot_frac: 0.9 }, 128);
        assert_mass_matches(KeyDist::HotSet { hot: 1, hot_frac: 0.5 }, 16);
        // degenerate all-hot collapses to uniform
        assert_mass_matches(KeyDist::HotSet { hot: 16, hot_frac: 0.9 }, 16);
    }

    #[test]
    fn zipf_mass_is_monotone_in_rank_and_theta() {
        let low = KeyDist::Zipf { theta: 0.8 }.closed_form(64);
        let high = KeyDist::Zipf { theta: 1.2 }.closed_form(64);
        for r in 1..64 {
            assert!(low[r] <= low[r - 1], "zipf decreasing in rank");
        }
        assert!(high[0] > low[0], "higher theta concentrates rank 0");
        assert!(high[63] < low[63], "higher theta starves the tail");
    }

    #[test]
    fn sampling_is_deterministic_and_in_range() {
        let s = KeySampler::new(&KeyDist::Zipf { theta: 0.99 }, 100);
        let mut a = Rng::stream(42, 7);
        let mut b = Rng::stream(42, 7);
        for _ in 0..1000 {
            let x = s.sample(&mut a);
            assert_eq!(x, s.sample(&mut b), "same stream, same draws");
            assert!(x < 100);
        }
    }

    #[test]
    fn uniform_sampler_draw_count_matches_raw_below() {
        // the inert path must consume exactly the draws a bare
        // `rng.below(n)` would — pin by comparing the stream positions
        let s = KeySampler::new(&KeyDist::Uniform, 37);
        let mut a = Rng::stream(9, 1);
        let mut b = Rng::stream(9, 1);
        for _ in 0..500 {
            assert_eq!(s.sample(&mut a) as u64, b.below(37));
        }
        assert_eq!(a.next_u64(), b.next_u64(), "streams stayed in lockstep");
    }

    #[test]
    fn skewed_sampling_lands_near_closed_form() {
        // coarse empirical check that the table is wired the right way
        // around (the exact pin is the mass test above)
        let dist = KeyDist::Zipf { theta: 1.2 };
        let s = KeySampler::new(&dist, 32);
        let mut rng = Rng::stream(5, 3);
        let mut counts = vec![0u64; 32];
        let n = 200_000;
        for _ in 0..n {
            counts[s.sample(&mut rng)] += 1;
        }
        let want = dist.closed_form(32);
        for r in [0usize, 1, 2, 31] {
            let got = counts[r] as f64 / n as f64;
            assert!(
                (got - want[r]).abs() < 0.01,
                "rank {r}: sampled {got} vs closed {}",
                want[r]
            );
        }
        assert!(counts[0] > counts[31] * 10, "head dominates tail at theta=1.2");
    }

    #[test]
    fn validation_rejects_bad_parameters() {
        assert!(KeyDist::Zipf { theta: 0.0 }.validate().is_err());
        assert!(KeyDist::Zipf { theta: -1.0 }.validate().is_err());
        assert!(KeyDist::Zipf { theta: f64::NAN }.validate().is_err());
        assert!(KeyDist::HotSet { hot: 0, hot_frac: 0.5 }.validate().is_err());
        assert!(KeyDist::HotSet { hot: 2, hot_frac: 1.5 }.validate().is_err());
        assert!(KeyDist::Zipf { theta: 0.99 }.validate().is_ok());
        assert!(KeyDist::Uniform.validate().is_ok());
    }
}
