//! Client churn: join/leave schedules lowered onto the fault timeline.
//!
//! Production clients come and go; the simulator already has exactly the
//! machinery to express that — [`crate::faults::state::Change::Crash`] /
//! [`Change::Restart`] target *any* proc, the network drops traffic to
//! and from a crashed proc, and the owning shard dispatches the
//! lifecycle hook to the actor. A [`ChurnPlan`] is therefore lowered to
//! `Crash`/`Restart` changes on **client** procs and merged into the one
//! fault [`crate::faults::state::Timeline`] the engines replay — churn
//! composes with partitions and server crashes for free, on all three
//! engines, because it is the same timeline.
//!
//! The client actor interprets the hooks as *leave* (drop in-flight
//! calls, go quiet) and *rejoin* (resume the closed loop), mirroring how
//! servers interpret them as crash/re-sync.

use crate::faults::state::Change;
use crate::sim::Time;

/// One client's leave (and optional rejoin) window.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnEvent {
    /// client index `0..n_clients` (not a proc id — the runner maps it)
    pub client: usize,
    /// when the client leaves
    pub at: Time,
    /// how long it stays gone; `0` = never rejoins
    pub rejoin_after: Time,
}

/// A seed-independent churn schedule (all times are virtual).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ChurnPlan {
    pub events: Vec<ChurnEvent>,
}

impl ChurnPlan {
    /// No churn — contributes nothing to the fault timeline.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_none(&self) -> bool {
        self.events.is_empty()
    }

    /// Builder sugar mirroring [`crate::faults::plan::FaultPlan::with`].
    pub fn with(mut self, ev: ChurnEvent) -> Self {
        self.events.push(ev);
        self
    }

    /// Every `stride`-th client leaves at `at` and rejoins after `gone`.
    pub fn periodic(n_clients: usize, stride: usize, at: Time, gone: Time) -> Self {
        assert!(stride > 0);
        Self {
            events: (0..n_clients)
                .step_by(stride)
                .map(|client| ChurnEvent { client, at, rejoin_after: gone })
                .collect(),
        }
    }

    /// Reject schedules the run cannot honor: unknown client indices or
    /// windows outside `[0, duration)`.
    pub fn validate(&self, n_clients: usize, duration: Time) -> Result<(), String> {
        for (i, ev) in self.events.iter().enumerate() {
            if ev.client >= n_clients {
                return Err(format!(
                    "churn event {i}: client {} out of range (n_clients = {n_clients})",
                    ev.client
                ));
            }
            if ev.at >= duration {
                return Err(format!(
                    "churn event {i}: leave at {} is past the run duration {duration}",
                    ev.at
                ));
            }
            if ev.rejoin_after > 0 && ev.at + ev.rejoin_after >= duration {
                return Err(format!(
                    "churn event {i}: rejoin at {} is past the run duration {duration}",
                    ev.at + ev.rejoin_after
                ));
            }
        }
        Ok(())
    }

    /// Lower to fault-timeline changes. `client_proc_base` is the proc
    /// id of client 0 in the runner's layout (clients occupy
    /// `base .. base + n_clients`). Scale is applied by the caller via
    /// the times already stored in the plan.
    pub fn lower(&self, client_proc_base: u32) -> Vec<(Time, Change)> {
        let mut changes = Vec::with_capacity(self.events.len() * 2);
        for ev in &self.events {
            let proc = client_proc_base + ev.client as u32;
            changes.push((ev.at, Change::Crash { proc }));
            if ev.rejoin_after > 0 {
                changes.push((ev.at + ev.rejoin_after, Change::Restart { proc }));
            }
        }
        changes
    }

    /// Scale every event time by `scale` (experiment scaling).
    pub fn scaled(&self, scale: f64) -> Self {
        Self {
            events: self
                .events
                .iter()
                .map(|ev| ChurnEvent {
                    client: ev.client,
                    at: (ev.at as f64 * scale) as Time,
                    rejoin_after: ((ev.rejoin_after as f64 * scale) as Time)
                        .max(if ev.rejoin_after > 0 { 1 } else { 0 }),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SEC;

    #[test]
    fn none_is_inert() {
        let p = ChurnPlan::none();
        assert!(p.is_none());
        assert!(p.validate(0, SEC).is_ok());
        assert!(p.lower(10).is_empty());
    }

    #[test]
    fn lower_maps_clients_onto_procs() {
        let p = ChurnPlan::none()
            .with(ChurnEvent { client: 0, at: 5 * SEC, rejoin_after: 10 * SEC })
            .with(ChurnEvent { client: 3, at: 8 * SEC, rejoin_after: 0 });
        assert!(p.validate(4, 60 * SEC).is_ok());
        let ch = p.lower(6); // e.g. 3 servers + 3 monitors → clients at proc 6
        assert_eq!(
            ch,
            vec![
                (5 * SEC, Change::Crash { proc: 6 }),
                (15 * SEC, Change::Restart { proc: 6 }),
                (8 * SEC, Change::Crash { proc: 9 }), // no rejoin: stays gone
            ]
        );
    }

    #[test]
    fn periodic_strides_the_client_set() {
        let p = ChurnPlan::periodic(6, 2, 10 * SEC, 5 * SEC);
        assert_eq!(p.events.len(), 3);
        assert_eq!(
            p.events.iter().map(|e| e.client).collect::<Vec<_>>(),
            vec![0, 2, 4]
        );
        assert!(p.validate(6, 60 * SEC).is_ok());
    }

    #[test]
    fn validate_rejects_out_of_range_events() {
        let bad_client = ChurnPlan::none().with(ChurnEvent {
            client: 9,
            at: SEC,
            rejoin_after: 0,
        });
        assert!(bad_client.validate(4, 60 * SEC).is_err());
        let late_leave = ChurnPlan::none().with(ChurnEvent {
            client: 0,
            at: 60 * SEC,
            rejoin_after: 0,
        });
        assert!(late_leave.validate(4, 60 * SEC).is_err());
        let late_rejoin = ChurnPlan::none().with(ChurnEvent {
            client: 0,
            at: 50 * SEC,
            rejoin_after: 20 * SEC,
        });
        assert!(late_rejoin.validate(4, 60 * SEC).is_err());
    }

    #[test]
    fn scaled_compresses_the_schedule() {
        let p = ChurnPlan::none()
            .with(ChurnEvent { client: 1, at: 10 * SEC, rejoin_after: 20 * SEC })
            .scaled(0.1);
        assert_eq!(p.events[0].at, SEC);
        assert_eq!(p.events[0].rejoin_after, 2 * SEC);
        // a tiny scale never turns a rejoin into "gone forever"
        let tiny = ChurnPlan::none()
            .with(ChurnEvent { client: 0, at: SEC, rejoin_after: SEC })
            .scaled(1e-12);
        assert_eq!(tiny.events[0].rejoin_after, 1);
    }
}
