//! Production-traffic workload engine: key skew, load shapes, client
//! churn — the knobs the paper's fixed-graph experiments never vary.
//!
//! The paper measures optimistic execution under small static graphs
//! with polite closed-loop clients (§VI). PCAP (1509.02464) shows the
//! consistency–latency tradeoff is governed by key popularity, arrival
//! rate and operation mix, and the journal version (1909.01980) warns
//! that rollback cost can erase the optimistic benefit when contention
//! concentrates violations on a few keys. This module generates that
//! traffic deterministically:
//!
//! * [`keyspace`] — O(1) bit-reproducible rank samplers (uniform, Zipf
//!   via a Walker/Vose alias table, hot-set).
//! * [`shape`] — piecewise per-client load curves (flat, ramps, diurnal
//!   sine, flash crowds) evaluated from the virtual clock.
//! * [`churn`] — client join/leave schedules lowered to `Crash`/
//!   `Restart` changes on client procs and merged into the fault
//!   timeline, so "Black Friday during a regional partition with 20%
//!   of clients flapping" is one scenario expression.
//!
//! The consumer is [`crate::apps::kvmix`], a YCSB-style read/write-mix
//! app whose guarded hot keys generate real mutual-exclusion violations
//! under skew. [`WorkloadCfg::uniform_default`] is **inert**: no churn,
//! no shape, uniform keys — pinned bit-identical to pre-workload runs
//! on all three engines by `tests/sharded_determinism.rs`.

pub mod churn;
pub mod keyspace;
pub mod shape;

use crate::sim::Time;
use churn::ChurnPlan;
use keyspace::KeyDist;
use shape::LoadShape;

/// Workload knobs carried by [`crate::exp::config::ExpConfig`]. The key/
/// mix/shape fields are consumed only by the kvmix app; `churn` applies
/// to any app (it lowers onto the fault timeline in the runner).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadCfg {
    /// keyspace size (ranks `0..n_keys`, interned as `kv_{r}`)
    pub n_keys: usize,
    /// key-popularity distribution over ranks
    pub dist: KeyDist,
    /// fraction of kvmix cycles that write (YCSB-style mix knob)
    pub put_pct: f64,
    /// the first `guarded` ranks are contention-guarded hot keys:
    /// writes to them take an occupancy flag that the monitor watches
    /// for mutual-exclusion violations (how skew becomes violations)
    pub guarded: usize,
    /// target per-client op-rate curve; `None` = closed-loop pacing via
    /// [`crate::client::actor::ClientTiming`] only (the inert path)
    pub shape: Option<LoadShape>,
    /// client leave/rejoin schedule; `ChurnPlan::none()` = inert
    pub churn: ChurnPlan,
}

impl WorkloadCfg {
    /// The inert default: uniform keys, balanced mix, a small guarded
    /// hot set (only observable if the app is kvmix), no shape, no
    /// churn. Every pre-workload scenario carries this and must stay
    /// bit-identical to its pre-workload schedule.
    pub fn uniform_default() -> Self {
        Self {
            n_keys: 64,
            dist: KeyDist::Uniform,
            put_pct: 0.5,
            guarded: 4,
            shape: None,
            churn: ChurnPlan::none(),
        }
    }

    /// True when nothing here can perturb a non-kvmix run.
    pub fn is_inert(&self) -> bool {
        self.shape.is_none() && self.churn.is_none()
    }

    pub fn with_keys(mut self, n_keys: usize, guarded: usize) -> Self {
        self.n_keys = n_keys;
        self.guarded = guarded;
        self
    }

    pub fn with_dist(mut self, dist: KeyDist) -> Self {
        self.dist = dist;
        self
    }

    pub fn with_mix(mut self, put_pct: f64) -> Self {
        self.put_pct = put_pct;
        self
    }

    pub fn with_shape(mut self, shape: LoadShape) -> Self {
        self.shape = Some(shape);
        self
    }

    pub fn with_churn(mut self, churn: ChurnPlan) -> Self {
        self.churn = churn;
        self
    }

    /// Validate against the deployment it will run in. Called by
    /// [`crate::exp::config::ExpConfig::with_workload`], which panics on
    /// `Err` — experiment construction is the right time to find out.
    pub fn validate(&self, n_clients: usize, duration: Time) -> Result<(), String> {
        if self.n_keys == 0 {
            return Err("n_keys must be positive".into());
        }
        if self.guarded > self.n_keys {
            return Err(format!(
                "guarded hot set ({}) larger than the keyspace ({})",
                self.guarded, self.n_keys
            ));
        }
        if !(0.0..=1.0).contains(&self.put_pct) {
            return Err(format!("put_pct must be in [0, 1], got {}", self.put_pct));
        }
        self.dist.validate()?;
        if let Some(shape) = &self.shape {
            shape.validate()?;
        }
        self.churn.validate(n_clients, duration)
    }

    /// Scale shape and churn timelines by the experiment scale factor.
    pub fn scaled(&self, scale: f64) -> Self {
        Self {
            shape: self.shape.as_ref().map(|s| s.scaled(scale)),
            churn: self.churn.scaled(scale),
            ..self.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SEC;
    use churn::ChurnEvent;

    #[test]
    fn uniform_default_is_inert_and_valid() {
        let w = WorkloadCfg::uniform_default();
        assert!(w.is_inert());
        assert_eq!(w.dist, KeyDist::Uniform);
        assert!(w.validate(15, 120 * SEC).is_ok());
    }

    #[test]
    fn builders_compose() {
        let w = WorkloadCfg::uniform_default()
            .with_keys(128, 8)
            .with_dist(KeyDist::Zipf { theta: 0.99 })
            .with_mix(0.3)
            .with_shape(LoadShape::constant(20.0, 60 * SEC))
            .with_churn(ChurnPlan::periodic(8, 2, 10 * SEC, 5 * SEC));
        assert!(!w.is_inert());
        assert!(w.validate(8, 60 * SEC).is_ok());
        assert_eq!(w.n_keys, 128);
        assert_eq!(w.guarded, 8);
    }

    #[test]
    fn validate_rejects_bad_workloads() {
        let d = 60 * SEC;
        assert!(WorkloadCfg::uniform_default().with_keys(0, 0).validate(4, d).is_err());
        assert!(WorkloadCfg::uniform_default().with_keys(8, 9).validate(4, d).is_err());
        assert!(WorkloadCfg::uniform_default().with_mix(1.5).validate(4, d).is_err());
        assert!(WorkloadCfg::uniform_default()
            .with_dist(KeyDist::Zipf { theta: -0.5 })
            .validate(4, d)
            .is_err());
        assert!(WorkloadCfg::uniform_default()
            .with_shape(LoadShape::default())
            .validate(4, d)
            .is_err());
        assert!(WorkloadCfg::uniform_default()
            .with_churn(ChurnPlan::none().with(ChurnEvent {
                client: 99,
                at: SEC,
                rejoin_after: 0
            }))
            .validate(4, d)
            .is_err());
    }

    #[test]
    fn scaled_touches_only_timelines() {
        let w = WorkloadCfg::uniform_default()
            .with_dist(KeyDist::Zipf { theta: 1.2 })
            .with_shape(LoadShape::constant(10.0, 100 * SEC))
            .with_churn(ChurnPlan::none().with(ChurnEvent {
                client: 0,
                at: 50 * SEC,
                rejoin_after: 10 * SEC,
            }))
            .scaled(0.1);
        assert_eq!(w.dist, KeyDist::Zipf { theta: 1.2 });
        assert_eq!(w.shape.as_ref().unwrap().total_dur(), 10 * SEC);
        assert_eq!(w.churn.events[0].at, 5 * SEC);
    }
}
