//! Piecewise load curves: how hard do clients push over the run?
//!
//! A [`LoadShape`] is a sequence of segments, each with a duration and a
//! rate law (flat, linear ramp, diurnal sine). [`LoadShape::rate_at`]
//! evaluates the target per-client op rate (ops/sec) at a virtual time —
//! pure arithmetic on `(t, segments)`, no RNG, no state — so every
//! engine and every shard computes the same pacing from the same clock.
//!
//! The kvmix app lowers the rate to think time: after each cycle it
//! sleeps `1/rate` seconds. `shape = None` in
//! [`crate::workload::WorkloadCfg`] skips pacing entirely and leaves the
//! client's [`crate::client::actor::ClientTiming`] think-time draws as
//! the only pacing — the inert default path.

use crate::sim::{Time, SEC};

/// Rate law of one segment. Rates are ops/sec per client.
#[derive(Debug, Clone, PartialEq)]
pub enum ShapeKind {
    /// Constant rate.
    Flat { rate: f64 },
    /// Linear ramp from `from` to `to` across the segment.
    Ramp { from: f64, to: f64 },
    /// `base + amp * sin(2π · elapsed/period)` — a compressed diurnal
    /// cycle. `amp < base` keeps the rate positive.
    Diurnal { base: f64, amp: f64, period: Time },
}

impl ShapeKind {
    /// Short tag for per-phase labels ("flat"/"ramp"/"diurnal").
    pub fn label(&self) -> &'static str {
        match self {
            ShapeKind::Flat { .. } => "flat",
            ShapeKind::Ramp { .. } => "ramp",
            ShapeKind::Diurnal { .. } => "diurnal",
        }
    }
}

/// One segment: a rate law held for `dur` of virtual time.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeSeg {
    pub dur: Time,
    pub kind: ShapeKind,
}

/// Piecewise load curve. Past the final segment the last instantaneous
/// rate holds (so a run longer than the shape degrades gracefully).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct LoadShape {
    pub segs: Vec<ShapeSeg>,
}

impl LoadShape {
    /// A single flat segment covering `dur`.
    pub fn constant(rate: f64, dur: Time) -> Self {
        Self { segs: vec![ShapeSeg { dur, kind: ShapeKind::Flat { rate } }] }
    }

    /// Flash crowd: `base` until `spike_from`, `peak` for `spike_dur`,
    /// then `base` again for the rest of `total`. "Black Friday" in
    /// three segments.
    pub fn flash_crowd(
        base: f64,
        peak: f64,
        spike_from: Time,
        spike_dur: Time,
        total: Time,
    ) -> Self {
        assert!(spike_from + spike_dur <= total, "spike must fit inside the run");
        Self {
            segs: vec![
                ShapeSeg { dur: spike_from, kind: ShapeKind::Flat { rate: base } },
                ShapeSeg { dur: spike_dur, kind: ShapeKind::Flat { rate: peak } },
                ShapeSeg {
                    dur: total - spike_from - spike_dur,
                    kind: ShapeKind::Flat { rate: base },
                },
            ],
        }
    }

    /// One compressed day: a sine around `base` with amplitude `amp`.
    pub fn diurnal(base: f64, amp: f64, period: Time, total: Time) -> Self {
        Self { segs: vec![ShapeSeg { dur: total, kind: ShapeKind::Diurnal { base, amp, period } }] }
    }

    /// Target per-client rate (ops/sec) at virtual time `t`.
    pub fn rate_at(&self, t: Time) -> f64 {
        let mut start: Time = 0;
        for (i, seg) in self.segs.iter().enumerate() {
            let last = i + 1 == self.segs.len();
            if t < start + seg.dur || last {
                let elapsed = (t - start).min(seg.dur);
                return Self::eval(&seg.kind, elapsed, seg.dur);
            }
            start += seg.dur;
        }
        0.0 // unreachable for validated (non-empty) shapes
    }

    fn eval(kind: &ShapeKind, elapsed: Time, dur: Time) -> f64 {
        match kind {
            ShapeKind::Flat { rate } => *rate,
            ShapeKind::Ramp { from, to } => {
                let frac = if dur == 0 { 1.0 } else { elapsed as f64 / dur as f64 };
                from + (to - from) * frac
            }
            ShapeKind::Diurnal { base, amp, period } => {
                let phase = 2.0 * std::f64::consts::PI * (elapsed as f64 / *period as f64);
                base + amp * phase.sin()
            }
        }
    }

    /// Total duration covered by the segments.
    pub fn total_dur(&self) -> Time {
        self.segs.iter().map(|s| s.dur).sum()
    }

    /// Scale every segment duration by `scale` (experiment scaling) —
    /// rates are per-client and stay put; only the timeline compresses.
    pub fn scaled(&self, scale: f64) -> Self {
        Self {
            segs: self
                .segs
                .iter()
                .map(|s| {
                    let kind = match &s.kind {
                        ShapeKind::Diurnal { base, amp, period } => ShapeKind::Diurnal {
                            base: *base,
                            amp: *amp,
                            period: ((*period as f64 * scale) as Time).max(1),
                        },
                        k => k.clone(),
                    };
                    ShapeSeg { dur: ((s.dur as f64 * scale) as Time).max(1), kind }
                })
                .collect(),
        }
    }

    /// Reject shapes the runner cannot pace by: no segments, zero-length
    /// segments, or non-positive rates anywhere on the curve.
    pub fn validate(&self) -> Result<(), String> {
        if self.segs.is_empty() {
            return Err("load shape has no segments".into());
        }
        for (i, seg) in self.segs.iter().enumerate() {
            if seg.dur == 0 {
                return Err(format!("segment {i} has zero duration"));
            }
            let (lo, hi) = match &seg.kind {
                ShapeKind::Flat { rate } => (*rate, *rate),
                ShapeKind::Ramp { from, to } => (from.min(*to), from.max(*to)),
                ShapeKind::Diurnal { base, amp, period } => {
                    if *period == 0 {
                        return Err(format!("segment {i}: diurnal period is zero"));
                    }
                    if *amp < 0.0 {
                        return Err(format!("segment {i}: negative amplitude"));
                    }
                    (base - amp, base + amp)
                }
            };
            if !(lo.is_finite() && hi.is_finite()) || lo <= 0.0 {
                return Err(format!(
                    "segment {i}: rate range [{lo}, {hi}] must be finite and positive"
                ));
            }
        }
        Ok(())
    }

    /// Pacing gap for one cycle at rate `rate_at(t)`: `1/rate` seconds
    /// in sim time, floored at 1 tick so a huge rate still advances.
    pub fn gap_at(&self, t: Time) -> Time {
        let rate = self.rate_at(t);
        ((SEC as f64 / rate) as Time).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_and_flash_crowd_evaluate_piecewise() {
        let s = LoadShape::flash_crowd(10.0, 80.0, 20 * SEC, 10 * SEC, 60 * SEC);
        assert!(s.validate().is_ok());
        assert_eq!(s.total_dur(), 60 * SEC);
        assert_eq!(s.rate_at(0), 10.0);
        assert_eq!(s.rate_at(19 * SEC), 10.0);
        assert_eq!(s.rate_at(20 * SEC), 80.0, "spike starts");
        assert_eq!(s.rate_at(29 * SEC), 80.0);
        assert_eq!(s.rate_at(30 * SEC), 10.0, "spike ends");
        assert_eq!(s.rate_at(10_000 * SEC), 10.0, "past the end: last rate holds");
    }

    #[test]
    fn ramp_interpolates_linearly() {
        let s = LoadShape {
            segs: vec![ShapeSeg { dur: 10 * SEC, kind: ShapeKind::Ramp { from: 10.0, to: 30.0 } }],
        };
        assert!(s.validate().is_ok());
        assert_eq!(s.rate_at(0), 10.0);
        assert!((s.rate_at(5 * SEC) - 20.0).abs() < 1e-9);
        assert!((s.rate_at(10 * SEC) - 30.0).abs() < 1e-9, "clamped at segment end");
    }

    #[test]
    fn diurnal_oscillates_around_base() {
        let s = LoadShape::diurnal(20.0, 10.0, 40 * SEC, 80 * SEC);
        assert!(s.validate().is_ok());
        assert!((s.rate_at(0) - 20.0).abs() < 1e-9);
        assert!((s.rate_at(10 * SEC) - 30.0).abs() < 1e-9, "quarter period: peak");
        assert!((s.rate_at(30 * SEC) - 10.0).abs() < 1e-9, "three quarters: trough");
    }

    #[test]
    fn gap_is_inverse_rate() {
        let s = LoadShape::constant(10.0, 60 * SEC);
        assert_eq!(s.gap_at(0), SEC / 10);
        let fast = LoadShape::constant(1e18, SEC);
        assert_eq!(fast.gap_at(0), 1, "floored at one tick");
    }

    #[test]
    fn scaled_compresses_durations_not_rates() {
        let s = LoadShape::flash_crowd(10.0, 80.0, 20 * SEC, 10 * SEC, 60 * SEC).scaled(0.1);
        assert_eq!(s.total_dur(), 6 * SEC);
        assert_eq!(s.rate_at(0), 10.0, "rates untouched");
        assert_eq!(s.rate_at(2 * SEC), 80.0, "spike scaled into place");
    }

    #[test]
    fn validation_rejects_degenerate_shapes() {
        assert!(LoadShape::default().validate().is_err(), "no segments");
        let zero_dur =
            LoadShape { segs: vec![ShapeSeg { dur: 0, kind: ShapeKind::Flat { rate: 1.0 } }] };
        assert!(zero_dur.validate().is_err());
        assert!(LoadShape::constant(0.0, SEC).validate().is_err(), "zero rate");
        assert!(LoadShape::constant(-5.0, SEC).validate().is_err());
        let sag = LoadShape::diurnal(10.0, 10.0, 20 * SEC, 40 * SEC);
        assert!(sag.validate().is_err(), "amplitude touches zero");
        let ramp_to_zero = LoadShape {
            segs: vec![ShapeSeg { dur: SEC, kind: ShapeKind::Ramp { from: 5.0, to: 0.0 } }],
        };
        assert!(ramp_to_zero.validate().is_err());
    }
}
