//! # optikv — Optimistic Execution in a Key-Value Store
//!
//! A reproduction of *"Technical Report: Optimistic Execution in
//! Key-Value Store"* (Nguyen, Charapko, Kulkarni, Demirbas; 2018):
//! run algorithms designed for sequential consistency on an eventually-
//! consistent Dynamo/Voldemort-style store, monitor the correctness
//! predicate P with HVC-based predicate detection, and roll back (or
//! abort/restart tasks) when P is violated.
//!
//! Layers:
//! * **L3 (this crate)** — the store, the Voldemort-style quorum client,
//!   the monitoring module (local detectors + monitors), rollback, the
//!   paper's three applications, and the deterministic discrete-event
//!   simulator substituting for the paper's AWS/local-lab testbeds.
//! * **L2/L1 (python/, build-time only)** — JAX + Pallas kernels for the
//!   batched HVC-interval verdicts, AOT-lowered to HLO text and executed
//!   from `runtime::pjrt` via the PJRT CPU client.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured numbers.

pub mod apps;
pub mod client;
pub mod clock;
pub mod detect;
pub mod exp;
pub mod metrics;
pub mod predicate;
pub mod rollback;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod util;
