//! # optikv — Optimistic Execution in a Key-Value Store
//!
//! A reproduction of *"Technical Report: Optimistic Execution in
//! Key-Value Store"* (Nguyen, Charapko, Kulkarni, Demirbas; 2018):
//! run algorithms designed for sequential consistency on an eventually-
//! consistent Dynamo/Voldemort-style store, monitor the correctness
//! predicate P with HVC-based predicate detection, and roll back (or
//! abort/restart tasks) when P is violated.
//!
//! Layers:
//! * **L3 (this crate)** — the partitioned store (consistent-hash ring
//!   with virtual nodes, per-key N-server preference lists; cluster size
//!   is independent of the replication factor), the Voldemort-style
//!   quorum client, the monitoring module (partition-aware local
//!   detectors + monitors), rollback, the paper's three applications,
//!   and the deterministic discrete-event simulator substituting for the
//!   paper's AWS/local-lab testbeds.
//! * **L2/L1 (python/, build-time only, cargo feature `accel`)** — JAX +
//!   Pallas kernels for the batched HVC-interval verdicts, AOT-lowered to
//!   HLO text and executed from `runtime::pjrt` via the PJRT CPU client.
//!
//! The client stack is pipelined: a transport-agnostic N/R/W quorum
//! engine ([`client::quorum`], pure transition functions) under a thin
//! multiplexing actor ([`client::actor`]) that keeps up to
//! `pipeline_depth` calls in flight and lets applications scatter-gather
//! independent operations ([`client::app::AppAction::Batch`]). Depth 1 —
//! the default — reproduces the paper's serial closed-loop client
//! event-for-event; quorum broadcasts share one `Rc<ServerOp>` payload
//! across all N replicas.
//!
//! Data placement: every key routes to a position on the cluster ring
//! ([`store::ring`]) and replicates to the N distinct servers walking
//! clockwise from there. Servers store, window-log, snapshot and monitor
//! only the partitions they own; clients resolve the preference list per
//! operation and run the N/R/W quorum protocol against it. With
//! `cluster_servers == N` (the default) every preference list is the
//! whole cluster and the original full-replication deployment of the
//! paper is reproduced exactly; with `cluster_servers > N` the store
//! scales horizontally (`exp::scenarios::scaleout_conjunctive`,
//! `benches/scaleout_throughput.rs`).
//!
//! Fault injection: experiments carry a declarative, seed-deterministic
//! [`faults::FaultPlan`] — network partitions by region group, server
//! crash/restart cycles with peer re-sync, slow nodes, drop bursts —
//! lowered to a transition timeline the simulator applies between
//! events. The detect-rollback machinery is thereby exercised under the
//! CAP conditions that justify it (§VI), and the violation detection-
//! latency CDF becomes a reproducible artifact
//! ([`exp::runner::ExpResult::detection_cdf`]).
//!
//! Workload engine: experiments can swap the polite closed-loop traffic
//! for production-shaped load via [`workload::WorkloadCfg`] — Zipf /
//! hot-set key popularity (O(1) alias-table sampling), piecewise load
//! curves (flash crowds, diurnal cycles), and client churn lowered onto
//! the same fault timeline, consumed by the YCSB-style [`apps::kvmix`]
//! read/write-mix app whose guarded hot keys turn skew into real
//! mutual-exclusion violations. The `uniform_default()` workload is
//! inert and reproduces every pre-workload run bit-identically.
//!
//! Adaptive consistency: a runtime [`adapt::AdaptController`] watches
//! the live signals the system already produces (violation reports,
//! rollback stall time, quorum timeouts, op-latency percentiles) over
//! sliding windows and, through a pluggable [`adapt::Policy`], drives an
//! epoch-based reconfiguration protocol that switches the whole cluster
//! between eventual and sequential quorum configurations mid-run —
//! answering the paper's deployment question of *when* to run
//! optimistically. The default static policy deploys no controller and
//! reproduces every pre-adapt run bit-identically.
//!
//! Observability: a deterministic flight recorder ([`trace`]) threads
//! per-actor bounded rings of typed events — quorum calls, applies with
//! HVC snapshots, candidates, verdicts, violations, recovery phases,
//! mode switches, faults — through the whole stack, stamped with the
//! engine-invariant `(at, seq)` dispatch key so merged traces are
//! bit-identical across the serial/sharded/threaded engines. On each
//! violation, [`trace::forensics`] walks the recording back to the
//! guilty writes; [`trace::chrome`] exports a Perfetto-loadable Chrome
//! trace plus the adapt-signal time series. The [`trace::TraceCfg::off`]
//! default is inert and reproduces every pre-trace run bit-identically.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured numbers.

pub mod adapt;
pub mod apps;
pub mod client;
pub mod clock;
pub mod detect;
pub mod exp;
pub mod faults;
pub mod metrics;
pub mod predicate;
pub mod rollback;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod trace;
pub mod util;
pub mod workload;
