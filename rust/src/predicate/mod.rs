//! Predicate specifications (DNF over key-value literals), the XML format
//! of Fig. 3, the shared registry, and naming-convention inference.

pub mod infer;
pub mod spec;
