//! Automatic inference of predicates from variable naming conventions
//! (§V "Automatic inference of predicate from variable names").
//!
//! Graph applications create one mutual-exclusion predicate per edge whose
//! endpoints belong to different clients. Manually specifying hundreds of
//! thousands of predicates is impossible, so when a server first sees a
//! request for a Peterson lock variable it synthesizes the predicate for
//! that edge on the fly.
//!
//! Naming convention (nodes are integers `a < b`):
//!   flag_{a}_{b}_{a}  — node-a side flag of edge (a,b)
//!   flag_{a}_{b}_{b}  — node-b side flag
//!   turn_{a}_{b}      — Peterson turn variable, value `a` or `b`
//!
//! The inferred predicate for edge (a,b), per the paper:
//!   ¬P_ab ≡ (flag_a_b_a = true ∧ turn_a_b = a)
//!         ∧ (flag_a_b_b = true ∧ turn_a_b = b)
//! — one clause, two conjuncts (each conjunct must co-hold on one replica
//! view; the two conjuncts may be witnessed on different replicas).

use crate::predicate::spec::{Clause, Conjunct, Literal, PredKind, PredicateSpec, PredId};
use crate::store::value::{Interner, Value};

/// A recognized Peterson lock variable for edge (a, b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeLockVar {
    pub a: u64,
    pub b: u64,
}

/// Lock variable names for an edge.
pub fn flag_name(a: u64, b: u64, side: u64) -> String {
    debug_assert!(a < b);
    debug_assert!(side == a || side == b);
    format!("flag_{a}_{b}_{side}")
}

pub fn turn_name(a: u64, b: u64) -> String {
    debug_assert!(a < b);
    format!("turn_{a}_{b}")
}

pub fn pred_name(a: u64, b: u64) -> String {
    format!("me_{a}_{b}")
}

/// Recognize a lock variable name. Returns the edge if `name` matches the
/// convention (the trigger for on-demand predicate generation).
pub fn recognize(name: &str) -> Option<EdgeLockVar> {
    let rest = name.strip_prefix("flag_").or_else(|| name.strip_prefix("turn_"))?;
    let is_flag = name.starts_with("flag_");
    let parts: Vec<&str> = rest.split('_').collect();
    let expected = if is_flag { 3 } else { 2 };
    if parts.len() != expected {
        return None;
    }
    let a: u64 = parts[0].parse().ok()?;
    let b: u64 = parts[1].parse().ok()?;
    if a >= b {
        return None;
    }
    if is_flag {
        let side: u64 = parts[2].parse().ok()?;
        if side != a && side != b {
            return None;
        }
    }
    Some(EdgeLockVar { a, b })
}

/// Build the mutual-exclusion predicate for edge (a, b).
pub fn edge_predicate(a: u64, b: u64, interner: &mut Interner) -> PredicateSpec {
    debug_assert!(a < b);
    let fa = interner.intern(&flag_name(a, b, a));
    let fb = interner.intern(&flag_name(a, b, b));
    let t = interner.intern(&turn_name(a, b));
    let clause = Clause {
        conjuncts: vec![
            Conjunct {
                literals: vec![
                    Literal { var: fa, value: Value::Bool(true) },
                    Literal { var: t, value: Value::Int(a as i64) },
                ],
            },
            Conjunct {
                literals: vec![
                    Literal { var: fb, value: Value::Bool(true) },
                    Literal { var: t, value: Value::Int(b as i64) },
                ],
            },
        ],
    };
    PredicateSpec {
        id: PredId(u32::MAX), // assigned by the registry
        name: pred_name(a, b),
        kind: PredKind::Semilinear,
        clauses: vec![clause],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recognizes_convention() {
        assert_eq!(recognize("flag_3_17_3"), Some(EdgeLockVar { a: 3, b: 17 }));
        assert_eq!(recognize("flag_3_17_17"), Some(EdgeLockVar { a: 3, b: 17 }));
        assert_eq!(recognize("turn_3_17"), Some(EdgeLockVar { a: 3, b: 17 }));
    }

    #[test]
    fn rejects_non_lock_names() {
        assert_eq!(recognize("color_5"), None);
        assert_eq!(recognize("flag_17_3_3"), None, "a must be < b");
        assert_eq!(recognize("flag_3_17_9"), None, "side must be an endpoint");
        assert_eq!(recognize("turn_3_17_3"), None, "turn has two parts");
        assert_eq!(recognize("flag_a_b_a"), None, "non-numeric");
        assert_eq!(recognize("turn_5_5"), None, "self-loop");
    }

    #[test]
    fn edge_predicate_shape() {
        let interner = Interner::new();
        let spec = edge_predicate(3, 17, &mut interner.borrow_mut());
        assert_eq!(spec.name, "me_3_17");
        assert_eq!(spec.kind, PredKind::Semilinear);
        assert_eq!(spec.clauses.len(), 1);
        let cjs = &spec.clauses[0].conjuncts;
        assert_eq!(cjs.len(), 2);
        assert_eq!(cjs[0].literals.len(), 2);
        // conjunct 0: flag_3_17_3=true ∧ turn_3_17=3
        let i = interner.borrow();
        assert_eq!(i.name(cjs[0].literals[0].var), "flag_3_17_3");
        assert_eq!(cjs[0].literals[1].value, Value::Int(3));
        assert_eq!(i.name(cjs[1].literals[0].var), "flag_3_17_17");
        assert_eq!(cjs[1].literals[1].value, Value::Int(17));
    }

    #[test]
    fn name_helpers_round_trip() {
        let n = flag_name(1, 2, 2);
        assert_eq!(n, "flag_1_2_2");
        assert_eq!(recognize(&n), Some(EdgeLockVar { a: 1, b: 2 }));
        let t = turn_name(1, 2);
        assert_eq!(recognize(&t), Some(EdgeLockVar { a: 1, b: 2 }));
    }
}
