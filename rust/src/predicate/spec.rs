//! Predicate specifications.
//!
//! The monitors detect `¬P` given in disjunctive normal form (§V): `¬P ≡
//! C_1 ∨ C_2 ∨ …` where each *clause* `C_k` is a conjunction of
//! *conjuncts*, and each conjunct is a set of `(variable = value)`
//! literals that must hold **within a single server's local view
//! simultaneously**. Different conjuncts of a clause may be satisfied on
//! different servers at pairwise-concurrent HVC intervals — that is
//! exactly the cross-replica inconsistency the paper detects.
//!
//! The XML format of Fig. 3 is supported: each `<conjClause>` is a clause
//! and, per the paper's conjunctive-predicate semantics, every `<var>`
//! becomes its own conjunct. An extended `<conjunct>` grouping element is
//! accepted for predicates (like the Peterson mutual-exclusion ones) whose
//! literals must co-hold on one replica view.

use std::collections::HashMap;

use crate::store::value::{Interner, KeyId, Value};
use crate::util::xmlmini::{self, Element};

/// Predicate identifier (index into the registry).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PredId(pub u32);

/// Predicate class — selects the detection algorithm (§III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredKind {
    /// e.g. conjunctive predicates; Algorithm 1 (forbidden states)
    Linear,
    /// e.g. the mutual-exclusion predicates; Algorithm 2 (semi-forbidden)
    Semilinear,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Literal {
    pub var: KeyId,
    pub value: Value,
}

/// Literals that must hold together on one server view.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Conjunct {
    pub literals: Vec<Literal>,
}

impl Conjunct {
    /// Does an assignment (var → values seen) satisfy every literal?
    /// A literal is satisfied if *any* sibling value equals the expected
    /// value (safe direction: never miss a violation).
    pub fn satisfied_by(&self, lookup: impl Fn(KeyId) -> Option<Vec<Value>>) -> bool {
        self.literals.iter().all(|lit| {
            lookup(lit.var)
                .map(|vals| vals.iter().any(|v| *v == lit.value))
                .unwrap_or(false)
        })
    }
}

/// Conjunction of conjuncts: true iff all conjuncts hold on pairwise
/// concurrent intervals.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Clause {
    pub conjuncts: Vec<Conjunct>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PredicateSpec {
    pub id: PredId,
    pub name: String,
    pub kind: PredKind,
    /// DNF of ¬P
    pub clauses: Vec<Clause>,
}

impl PredicateSpec {
    /// All variables the predicate mentions.
    pub fn vars(&self) -> Vec<KeyId> {
        let mut out: Vec<KeyId> = self
            .clauses
            .iter()
            .flat_map(|c| c.conjuncts.iter())
            .flat_map(|cj| cj.literals.iter().map(|l| l.var))
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Parse the paper's XML format (Fig. 3). `interner` resolves variable
    /// names to key ids.
    pub fn from_xml(id: PredId, name: &str, src: &str, interner: &mut Interner) -> Result<Self, String> {
        let root = xmlmini::parse(src).map_err(|e| e.to_string())?;
        if root.name != "predicate" {
            return Err(format!("expected <predicate>, got <{}>", root.name));
        }
        let kind = match root.child_text("type") {
            Some("linear") | Some("conjunctive") => PredKind::Linear,
            Some("semilinear") => PredKind::Semilinear,
            other => return Err(format!("unknown predicate type {other:?}")),
        };
        let mut clauses = Vec::new();
        for cl in root.children_named("conjClause") {
            let mut clause = Clause::default();
            // extended grouping: explicit <conjunct> children
            let grouped: Vec<&Element> = cl.children_named("conjunct").collect();
            if !grouped.is_empty() {
                for g in grouped {
                    clause.conjuncts.push(parse_conjunct_vars(g, interner)?);
                }
            } else {
                // paper semantics: each <var> is its own conjunct
                for v in cl.children_named("var") {
                    let lit = parse_literal(v, interner)?;
                    clause.conjuncts.push(Conjunct { literals: vec![lit] });
                }
            }
            if clause.conjuncts.is_empty() {
                return Err("empty conjClause".into());
            }
            clauses.push(clause);
        }
        if clauses.is_empty() {
            return Err("predicate has no clauses".into());
        }
        Ok(Self { id, name: name.to_string(), kind, clauses })
    }

    /// Serialize to the XML format (round-trip / tooling).
    pub fn to_xml(&self, interner: &Interner) -> String {
        let mut root = Element::new("predicate");
        let mut ty = Element::new("type");
        ty.text = match self.kind {
            PredKind::Linear => "linear".into(),
            PredKind::Semilinear => "semilinear".into(),
        };
        root.children.push(ty);
        for (ci, clause) in self.clauses.iter().enumerate() {
            let mut cl = Element::new("conjClause");
            let mut id_el = Element::new("id");
            id_el.text = ci.to_string();
            cl.children.push(id_el);
            for conjunct in &clause.conjuncts {
                let mut cj = Element::new("conjunct");
                for lit in &conjunct.literals {
                    let mut v = Element::new("var");
                    let mut n = Element::new("name");
                    n.text = interner.name(lit.var).to_string();
                    let mut val = Element::new("value");
                    val.text = lit.value.to_string();
                    v.children.push(n);
                    v.children.push(val);
                    cj.children.push(v);
                }
                cl.children.push(cj);
            }
            root.children.push(cl);
        }
        root.to_xml()
    }
}

fn parse_literal(v: &Element, interner: &mut Interner) -> Result<Literal, String> {
    let name = v.child_text("name").ok_or("var without <name>")?;
    let value = v.child_text("value").ok_or("var without <value>")?;
    Ok(Literal { var: interner.intern(name), value: Value::parse(value) })
}

fn parse_conjunct_vars(g: &Element, interner: &mut Interner) -> Result<Conjunct, String> {
    let mut out = Conjunct::default();
    for v in g.children_named("var") {
        out.literals.push(parse_literal(v, interner)?);
    }
    if out.literals.is_empty() {
        return Err("empty conjunct".into());
    }
    Ok(out)
}

/// The shared predicate registry: all registered predicates plus the
/// relevant-variable index the local detectors use for their fast path.
#[derive(Debug, Default)]
pub struct Registry {
    preds: Vec<PredicateSpec>,
    by_name: HashMap<String, PredId>,
    /// var → (pred, clause idx, conjunct idx) that mention it
    var_index: HashMap<KeyId, Vec<(PredId, u16, u16)>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, mut spec: PredicateSpec) -> PredId {
        if let Some(&id) = self.by_name.get(&spec.name) {
            return id; // idempotent registration
        }
        let id = PredId(self.preds.len() as u32);
        spec.id = id;
        for (ci, clause) in spec.clauses.iter().enumerate() {
            for (ji, conjunct) in clause.conjuncts.iter().enumerate() {
                for lit in &conjunct.literals {
                    self.var_index.entry(lit.var).or_default().push((id, ci as u16, ji as u16));
                }
            }
        }
        self.by_name.insert(spec.name.clone(), id);
        self.preds.push(spec);
        id
    }

    pub fn get(&self, id: PredId) -> &PredicateSpec {
        &self.preds[id.0 as usize]
    }

    pub fn by_name(&self, name: &str) -> Option<PredId> {
        self.by_name.get(name).copied()
    }

    pub fn len(&self) -> usize {
        self.preds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.preds.is_empty()
    }

    /// The local detector's fast-path lookup: which (pred, clause,
    /// conjunct) does a PUT of `var` affect? None ⇒ zero extra work.
    pub fn affected(&self, var: KeyId) -> Option<&[(PredId, u16, u16)]> {
        self.var_index.get(&var).map(|v| v.as_slice())
    }

    pub fn iter(&self) -> impl Iterator<Item = &PredicateSpec> {
        self.preds.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIG3: &str = r#"
<predicate>
 <type>semilinear</type>
 <conjClause>
  <id>0</id>
  <var> <name>x1</name> <value>1</value> </var>
  <var> <name>y1</name> <value>1</value> </var>
 </conjClause>
 <conjClause>
  <id>1</id>
  <var> <name>z2</name> <value>1</value> </var>
 </conjClause>
</predicate>"#;

    #[test]
    fn parses_fig3() {
        let interner = Interner::new();
        let spec =
            PredicateSpec::from_xml(PredId(0), "fig3", FIG3, &mut interner.borrow_mut()).unwrap();
        assert_eq!(spec.kind, PredKind::Semilinear);
        assert_eq!(spec.clauses.len(), 2);
        // paper semantics: each var its own conjunct
        assert_eq!(spec.clauses[0].conjuncts.len(), 2);
        assert_eq!(spec.clauses[1].conjuncts.len(), 1);
        let x1 = interner.borrow().lookup("x1").unwrap();
        assert_eq!(spec.clauses[0].conjuncts[0].literals[0].var, x1);
        assert_eq!(spec.clauses[0].conjuncts[0].literals[0].value, Value::Int(1));
    }

    #[test]
    fn xml_round_trip() {
        let interner = Interner::new();
        let spec =
            PredicateSpec::from_xml(PredId(0), "rt", FIG3, &mut interner.borrow_mut()).unwrap();
        let xml = spec.to_xml(&interner.borrow());
        let spec2 =
            PredicateSpec::from_xml(PredId(0), "rt", &xml, &mut interner.borrow_mut()).unwrap();
        // grouping becomes explicit on re-parse; clause/conjunct structure equal
        assert_eq!(spec.clauses, spec2.clauses);
        assert_eq!(spec.kind, spec2.kind);
    }

    #[test]
    fn grouped_conjuncts_extension() {
        let src = r#"
<predicate>
 <type>semilinear</type>
 <conjClause>
  <conjunct>
   <var><name>flag_1_2_1</name><value>true</value></var>
   <var><name>turn_1_2</name><value>1</value></var>
  </conjunct>
  <conjunct>
   <var><name>flag_1_2_2</name><value>true</value></var>
   <var><name>turn_1_2</name><value>2</value></var>
  </conjunct>
 </conjClause>
</predicate>"#;
        let interner = Interner::new();
        let spec =
            PredicateSpec::from_xml(PredId(0), "me", src, &mut interner.borrow_mut()).unwrap();
        assert_eq!(spec.clauses[0].conjuncts.len(), 2);
        assert_eq!(spec.clauses[0].conjuncts[0].literals.len(), 2);
    }

    #[test]
    fn conjunct_satisfaction_with_siblings() {
        let interner = Interner::new();
        let x = interner.borrow_mut().intern("x");
        let y = interner.borrow_mut().intern("y");
        let cj = Conjunct {
            literals: vec![
                Literal { var: x, value: Value::Int(1) },
                Literal { var: y, value: Value::Bool(true) },
            ],
        };
        // sibling versions: any matching sibling satisfies the literal
        let ok = cj.satisfied_by(|k| {
            if k == x {
                Some(vec![Value::Int(0), Value::Int(1)])
            } else {
                Some(vec![Value::Bool(true)])
            }
        });
        assert!(ok);
        let missing = cj.satisfied_by(|k| if k == x { Some(vec![Value::Int(1)]) } else { None });
        assert!(!missing, "absent variable cannot satisfy a literal");
    }

    #[test]
    fn registry_index_and_idempotence() {
        let interner = Interner::new();
        let mut reg = Registry::new();
        let spec =
            PredicateSpec::from_xml(PredId(0), "p0", FIG3, &mut interner.borrow_mut()).unwrap();
        let id = reg.add(spec.clone());
        let id2 = reg.add(spec);
        assert_eq!(id, id2, "re-registration is idempotent");
        assert_eq!(reg.len(), 1);
        let x1 = interner.borrow().lookup("x1").unwrap();
        let hits = reg.affected(x1).unwrap();
        assert_eq!(hits, &[(id, 0u16, 0u16)]);
        let z2 = interner.borrow().lookup("z2").unwrap();
        assert_eq!(reg.affected(z2).unwrap()[0].1, 1, "z2 is in clause 1");
        let none = interner.borrow_mut().intern("unrelated");
        assert!(reg.affected(none).is_none());
    }

    #[test]
    fn rejects_malformed() {
        let interner = Interner::new();
        let mut i = interner.borrow_mut();
        assert!(PredicateSpec::from_xml(PredId(0), "x", "<predicate><type>bogus</type></predicate>", &mut i).is_err());
        assert!(PredicateSpec::from_xml(PredId(0), "x", "<predicate><type>linear</type></predicate>", &mut i).is_err());
        assert!(PredicateSpec::from_xml(PredId(0), "x", "<nope/>", &mut i).is_err());
    }
}
