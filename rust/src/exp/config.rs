//! Experiment configuration: one struct describes a full run (topology,
//! consistency, app, monitoring, recovery), mirroring the parameters the
//! paper varies in §VI.

use crate::adapt::AdaptCfg;
use crate::client::consistency::{ClientTiming, ConsistencyCfg};
use crate::clock::hvc::{Millis, EPS_INF};
use crate::detect::monitor::MonitorCfg;
use crate::faults::plan::FaultPlan;
use crate::rollback::recovery::RecoveryPolicy;
use crate::sim::des::SchedKind;
use crate::sim::{Time, SEC};
use crate::store::server::ServerCfg;
use crate::trace::TraceCfg;
use crate::workload::WorkloadCfg;

/// Which testbed to simulate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopoKind {
    /// Ohio / Oregon / Frankfurt (RTT 76/103/163 ms) — §VI-B
    AwsGlobal,
    /// one region, `zones` availability zones, <2 ms — §VI-B workload study
    AwsRegional { zones: usize },
    /// the paper's proxy lab (Fig. 8): 3 regions, tunable one-way delay
    LocalLab { inter_ms: f64 },
    /// single flat region (tests/micro)
    Flat { one_way_ms: f64 },
}

/// Which case study to run.
#[derive(Debug, Clone, PartialEq)]
pub enum AppKind {
    /// Social Media Analysis: power-law graph coloring (§VI-A)
    Coloring {
        nodes: usize,
        /// Holme–Kim m (edges per node; paper ratio 150k/50k ⇒ 3)
        edges_per_node: usize,
        task_size: usize,
        loop_forever: bool,
    },
    /// Weather Monitoring: planar grid, tunable PUT%
    Weather { grid_w: usize, grid_h: usize, put_pct: f64, use_locks: bool },
    /// Conjunctive stress / latency test
    Conjunctive { n_preds: usize, n_conjuncts: usize, beta: f64, put_pct: f64 },
    /// Production-traffic read/write mix driven by the workload engine
    /// ([`crate::apps::kvmix`]): key skew, guarded hot keys, load shapes
    /// — the knobs live in [`ExpConfig::workload`]
    KvMix,
}

/// Verdict backend for the monitors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccelKind {
    /// scalar Rust reference
    Native,
    /// AOT-compiled Pallas/JAX kernels through PJRT (requires artifacts/)
    Xla,
}

#[derive(Debug, Clone)]
pub struct ExpConfig {
    pub name: String,
    pub consistency: ConsistencyCfg,
    /// total servers in the cluster. Independent of the replication
    /// factor N: the keyspace is partitioned over a consistent-hash ring
    /// and each key replicates to its N-server preference list. Defaults
    /// to N (the paper's deployments), where every server holds the full
    /// keyspace and the historical behavior is reproduced exactly.
    pub cluster_servers: usize,
    /// virtual nodes per server on the partitioning ring
    pub ring_vnodes: usize,
    /// ring token-placement seed (independent of the workload seed so
    /// varying `seed` re-randomizes the workload, not the partitioning)
    pub ring_seed: u64,
    pub n_clients: usize,
    /// max quorum calls a client keeps in flight. 1 (the default)
    /// reproduces the paper's serial closed-loop client bit-identically;
    /// larger depths let the apps scatter-gather independent operations
    /// ([`crate::client::app::AppAction::Batch`]).
    pub pipeline_depth: usize,
    /// monitoring module enabled?
    pub monitors: bool,
    pub recovery: RecoveryPolicy,
    pub topo: TopoKind,
    pub app: AppKind,
    pub seed: u64,
    /// virtual run length
    pub duration: Time,
    /// HVC ε; the paper's experiments treat ε as ∞ (§III-A) — pure
    /// vector clocks — and finite values are exercised in ablations.
    /// Under a *skewed* workload ([`Self::workload`]) ε also bounds how
    /// long two hot-key occupancy intervals can appear concurrent purely
    /// due to clock uncertainty: a finite ε trims spurious overlap on
    /// contended keys (fewer false candidates) at the cost of missing
    /// genuinely concurrent windows shorter than ε, so skew sweeps pin
    /// ε = ∞ to keep the violation-rate-vs-θ curve a property of the
    /// traffic, not of the clock model.
    pub eps_ms: Millis,
    /// physical clock skew bound of the simulated cluster. Hot-key
    /// contention windows (kvmix guarded writes) are O(one op RTT), so
    /// `skew_ms` must stay well below the op latency for detected
    /// violation counts under skewed workloads to track true contention;
    /// the defaults (0.5 ms vs ≥ ms-scale RTTs) satisfy this.
    pub skew_ms: f64,
    /// Voldemort server threads per machine (paper: M5 instances run 2)
    pub server_threads: usize,
    pub server_cfg: ServerCfg,
    pub monitor_cfg: MonitorCfg,
    pub timing: ClientTiming,
    pub drop_prob: f64,
    pub accel: AccelKind,
    /// declarative fault schedule (partitions, crash/restart, slow nodes,
    /// drop bursts — [`crate::faults`]). [`FaultPlan::none()`], the
    /// default, reproduces fault-free runs event-for-event.
    pub fault_plan: FaultPlan,
    /// adaptive-consistency controller ([`crate::adapt`]). The default
    /// ([`AdaptCfg::static_default`]) deploys no controller and
    /// reproduces pre-adapt runs bit-identically; `consistency` is then
    /// the (only) mode of the whole run.
    pub adapt: AdaptCfg,
    /// event-loop shards for the merged-order sharded engine
    /// ([`crate::sim::des::Sim::new_sharded`]). 0 (the default) keeps
    /// the legacy single event queue; any `k ≥ 1` partitions the event
    /// set into `min(k, servers)` ring-block shards and runs the
    /// window/barrier protocol — results are bit-identical to 0 at
    /// every value by construction.
    pub shards: usize,
    /// run the shard plan on worker *threads*
    /// ([`crate::sim::shard::run_threaded`]) instead of the merged-order
    /// single-threaded engine. Requires `shards >= 1`; results are
    /// bit-identical to both other engines at every shard count.
    pub threaded: bool,
    /// production-traffic workload ([`crate::workload`]): key skew and
    /// mix (consumed by [`AppKind::KvMix`]), load shape, client churn.
    /// The default ([`WorkloadCfg::uniform_default`]) is inert and
    /// reproduces pre-workload runs bit-identically.
    pub workload: WorkloadCfg,
    /// pending-event scheduler backing each shard's queue
    pub sched: SchedKind,
    /// self-stabilizing application variant: coloring clients ignore
    /// rollback notifications and repair conflicting state by
    /// re-coloring. Pair with [`RecoveryPolicy::Stabilize`]; `false`
    /// (the default) leaves every app's abort path unchanged.
    pub stabilize: bool,
    /// deterministic flight recorder ([`crate::trace`]). The default
    /// ([`TraceCfg::off`]) builds no recorder and reproduces pre-trace
    /// runs bit-identically; `ring`/`full` capture per-actor bounded
    /// event rings merged in `(at, seq)` dispatch order.
    pub trace: TraceCfg,
}

impl ExpConfig {
    /// Baseline config: fill in the paper's defaults, then tweak fields.
    pub fn new(name: &str, consistency: ConsistencyCfg, app: AppKind) -> Self {
        Self {
            name: name.to_string(),
            consistency,
            cluster_servers: consistency.n,
            ring_vnodes: crate::store::ring::DEFAULT_VNODES,
            ring_seed: crate::store::ring::DEFAULT_RING_SEED,
            n_clients: 15,
            pipeline_depth: 1,
            monitors: true,
            recovery: RecoveryPolicy::NotifyClients,
            topo: TopoKind::AwsGlobal,
            app,
            seed: 42,
            duration: 120 * SEC,
            eps_ms: EPS_INF,
            skew_ms: 0.5,
            server_threads: 2,
            server_cfg: ServerCfg::default(),
            monitor_cfg: MonitorCfg::default(),
            timing: ClientTiming::default(),
            drop_prob: 0.0,
            accel: AccelKind::Native,
            fault_plan: FaultPlan::none(),
            adapt: AdaptCfg::static_default(),
            shards: 0,
            threaded: false,
            sched: SchedKind::Heap,
            workload: WorkloadCfg::uniform_default(),
            stabilize: false,
            trace: TraceCfg::off(),
        }
    }

    /// Attach the flight recorder ([`crate::trace`]). The default
    /// ([`TraceCfg::off`]) records nothing and reproduces pre-trace
    /// runs bit-identically.
    pub fn with_trace(mut self, trace: TraceCfg) -> Self {
        trace.validate();
        self.trace = trace;
        self
    }

    /// Run on the merged-order sharded engine with `k` shards.
    pub fn with_shards(mut self, k: usize) -> Self {
        self.shards = k;
        self
    }

    /// Run the shard plan on worker threads (one per shard). Implies a
    /// sharded run: set the shard count with [`Self::with_shards`] first
    /// (a threaded run with `shards = 0` is rejected by the runner).
    pub fn with_threaded(mut self) -> Self {
        self.threaded = true;
        self
    }

    /// Pick the pending-event scheduler (heap or calendar queue).
    pub fn with_sched(mut self, sched: SchedKind) -> Self {
        self.sched = sched;
        self
    }

    /// Attach a fault schedule to the run.
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = plan;
        self
    }

    /// Deploy an adaptive-consistency controller. `consistency` stays the
    /// starting mode and must be one of the two configs the controller
    /// switches between.
    pub fn with_adapt(mut self, adapt: AdaptCfg) -> Self {
        if let Err(e) = adapt.validate(self.consistency) {
            panic!("bad adapt config: {e}");
        }
        self.adapt = adapt;
        self
    }

    /// Attach a production-traffic workload. Validated against the
    /// run's client count and duration — experiment construction is the
    /// right time to find out about a bad theta or an out-of-window
    /// churn event.
    pub fn with_workload(mut self, workload: WorkloadCfg) -> Self {
        if let Err(e) = workload.validate(self.n_clients, self.duration) {
            panic!("bad workload config: {e}");
        }
        self.workload = workload;
        self
    }

    /// Let every client keep up to `depth` quorum calls in flight.
    pub fn with_pipeline_depth(mut self, depth: usize) -> Self {
        assert!(depth >= 1, "pipeline depth must be at least 1");
        self.pipeline_depth = depth;
        self
    }

    /// Scale the cluster out to `servers` total servers (N unchanged).
    pub fn with_cluster_servers(mut self, servers: usize) -> Self {
        assert!(
            servers >= self.consistency.n,
            "cluster of {servers} servers cannot host N = {} replicas",
            self.consistency.n
        );
        self.cluster_servers = servers;
        self
    }

    pub fn n_servers(&self) -> usize {
        self.cluster_servers
    }

    /// The partitioning ring this configuration describes.
    pub fn build_ring(&self) -> crate::store::ring::Ring {
        crate::store::ring::Ring::new(
            self.cluster_servers,
            self.consistency.n,
            self.ring_vnodes,
            self.ring_seed,
        )
    }

    pub fn n_regions(&self) -> usize {
        match self.topo {
            TopoKind::AwsGlobal => 3,
            TopoKind::AwsRegional { zones } => zones,
            TopoKind::LocalLab { .. } => 3,
            TopoKind::Flat { .. } => 1,
        }
    }

    pub fn base_ms(&self) -> Vec<Vec<f64>> {
        use crate::sim::net::Topology;
        match self.topo {
            TopoKind::AwsGlobal => Topology::aws_global(),
            TopoKind::AwsRegional { zones } => Topology::aws_regional(zones),
            TopoKind::LocalLab { inter_ms } => Topology::local_lab(inter_ms),
            TopoKind::Flat { one_way_ms } => vec![vec![one_way_ms]],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let cfg = ExpConfig::new(
            "t",
            ConsistencyCfg::n3r1w1(),
            AppKind::Conjunctive { n_preds: 10, n_conjuncts: 10, beta: 0.01, put_pct: 0.5 },
        );
        assert_eq!(cfg.n_servers(), 3, "cluster size defaults to N");
        assert_eq!(cfg.cluster_servers, cfg.consistency.n);
        assert_eq!(cfg.pipeline_depth, 1, "the paper's client is serial");
        assert_eq!(cfg.server_threads, 2);
        assert_eq!(cfg.eps_ms, EPS_INF, "paper treats eps as infinity");
        assert_eq!(cfg.n_regions(), 3);
        assert_eq!(cfg.base_ms()[0][1], 38.0);
        assert!(cfg.fault_plan.is_none(), "fault-free by default");
        assert!(!cfg.adapt.enabled(), "static consistency by default");
        assert_eq!(cfg.shards, 0, "legacy single event queue by default");
        assert_eq!(cfg.sched, SchedKind::Heap);
        assert_eq!(cfg.workload, WorkloadCfg::uniform_default());
        assert!(cfg.workload.is_inert(), "default workload perturbs nothing");
        assert_eq!(cfg.trace, TraceCfg::off());
        assert!(!cfg.trace.enabled(), "no recorder by default");
    }

    #[test]
    fn workload_builder_validates_against_the_run() {
        use crate::workload::keyspace::KeyDist;
        let cfg = ExpConfig::new(
            "t",
            ConsistencyCfg::n3r1w1(),
            AppKind::KvMix,
        )
        .with_workload(
            WorkloadCfg::uniform_default()
                .with_keys(128, 8)
                .with_dist(KeyDist::Zipf { theta: 0.99 }),
        );
        assert_eq!(cfg.workload.n_keys, 128);
        assert!(!matches!(cfg.workload.dist, KeyDist::Uniform));
    }

    #[test]
    #[should_panic(expected = "bad workload config")]
    fn workload_builder_rejects_nonpositive_theta() {
        use crate::workload::keyspace::KeyDist;
        let _ = ExpConfig::new("t", ConsistencyCfg::n3r1w1(), AppKind::KvMix)
            .with_workload(
                WorkloadCfg::uniform_default().with_dist(KeyDist::Zipf { theta: 0.0 }),
            );
    }

    #[test]
    #[should_panic(expected = "bad workload config")]
    fn workload_builder_rejects_churn_outside_duration() {
        use crate::workload::churn::{ChurnEvent, ChurnPlan};
        // default duration is 120 s: a leave at 200 s can never happen
        let _ = ExpConfig::new("t", ConsistencyCfg::n3r1w1(), AppKind::KvMix)
            .with_workload(WorkloadCfg::uniform_default().with_churn(
                ChurnPlan::none().with(ChurnEvent {
                    client: 0,
                    at: 200 * SEC,
                    rejoin_after: 0,
                }),
            ));
    }

    #[test]
    #[should_panic(expected = "bad workload config")]
    fn workload_builder_rejects_empty_shape() {
        let _ = ExpConfig::new("t", ConsistencyCfg::n3r1w1(), AppKind::KvMix)
            .with_workload(
                WorkloadCfg::uniform_default()
                    .with_shape(crate::workload::shape::LoadShape::default()),
            );
    }

    #[test]
    fn shard_builders() {
        let cfg = ExpConfig::new(
            "t",
            ConsistencyCfg::n3r1w1(),
            AppKind::Conjunctive { n_preds: 1, n_conjuncts: 1, beta: 0.0, put_pct: 0.5 },
        )
        .with_shards(4)
        .with_sched(SchedKind::Calendar);
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.sched, SchedKind::Calendar);
    }

    #[test]
    fn adapt_builder_validates_modes() {
        use crate::adapt::{AdaptCfg, HysteresisCfg};
        let cfg = ExpConfig::new(
            "t",
            ConsistencyCfg::new(3, 1, 2),
            AppKind::Conjunctive { n_preds: 1, n_conjuncts: 1, beta: 0.0, put_pct: 0.5 },
        )
        .with_adapt(AdaptCfg::hysteresis(
            HysteresisCfg::default(),
            ConsistencyCfg::new(3, 1, 2),
            ConsistencyCfg::n3r2w2(),
        ));
        assert!(cfg.adapt.enabled());
    }

    #[test]
    #[should_panic(expected = "bad adapt config")]
    fn adapt_builder_rejects_foreign_starting_mode() {
        use crate::adapt::{AdaptCfg, HysteresisCfg};
        let _ = ExpConfig::new(
            "t",
            ConsistencyCfg::n3r1w1(),
            AppKind::Conjunctive { n_preds: 1, n_conjuncts: 1, beta: 0.0, put_pct: 0.5 },
        )
        .with_adapt(AdaptCfg::hysteresis(
            HysteresisCfg::default(),
            ConsistencyCfg::new(3, 1, 2),
            ConsistencyCfg::n3r2w2(),
        ));
    }

    #[test]
    fn fault_plan_builder() {
        use crate::faults::plan::FaultEvent;
        let plan = FaultPlan::none().with(FaultEvent::Crash {
            server: 1,
            at: 10 * SEC,
            restart_after: 5 * SEC,
        });
        let cfg = ExpConfig::new(
            "t",
            ConsistencyCfg::n3r1w1(),
            AppKind::Conjunctive { n_preds: 1, n_conjuncts: 1, beta: 0.0, put_pct: 0.5 },
        )
        .with_fault_plan(plan.clone());
        assert_eq!(cfg.fault_plan, plan);
    }

    #[test]
    fn cluster_servers_decoupled_from_n() {
        let cfg = ExpConfig::new(
            "t",
            ConsistencyCfg::n3r1w1(),
            AppKind::Conjunctive { n_preds: 4, n_conjuncts: 4, beta: 0.01, put_pct: 0.5 },
        )
        .with_cluster_servers(12);
        assert_eq!(cfg.n_servers(), 12);
        assert_eq!(cfg.consistency.n, 3, "replication factor untouched");
        let ring = cfg.build_ring();
        assert_eq!(ring.n_servers(), 12);
        assert_eq!(ring.n_replicas(), 3);
    }

    #[test]
    fn pipeline_depth_builder() {
        let cfg = ExpConfig::new(
            "t",
            ConsistencyCfg::n3r1w1(),
            AppKind::Conjunctive { n_preds: 1, n_conjuncts: 1, beta: 0.0, put_pct: 0.5 },
        )
        .with_pipeline_depth(8);
        assert_eq!(cfg.pipeline_depth, 8);
    }

    #[test]
    #[should_panic(expected = "pipeline depth")]
    fn zero_pipeline_depth_rejected() {
        let _ = ExpConfig::new(
            "t",
            ConsistencyCfg::n3r1w1(),
            AppKind::Conjunctive { n_preds: 1, n_conjuncts: 1, beta: 0.0, put_pct: 0.5 },
        )
        .with_pipeline_depth(0);
    }

    #[test]
    #[should_panic(expected = "cannot host")]
    fn cluster_smaller_than_n_rejected() {
        let _ = ExpConfig::new(
            "t",
            ConsistencyCfg::n5r1w1(),
            AppKind::Conjunctive { n_preds: 1, n_conjuncts: 1, beta: 0.0, put_pct: 0.5 },
        )
        .with_cluster_servers(3);
    }

    #[test]
    fn topo_matrices() {
        let mut cfg = ExpConfig::new(
            "t",
            ConsistencyCfg::n5r1w1(),
            AppKind::Weather { grid_w: 10, grid_h: 10, put_pct: 0.5, use_locks: true },
        );
        cfg.topo = TopoKind::AwsRegional { zones: 5 };
        assert_eq!(cfg.n_regions(), 5);
        assert!(cfg.base_ms()[0][1] < 2.0);
        cfg.topo = TopoKind::LocalLab { inter_ms: 100.0 };
        assert_eq!(cfg.base_ms()[0][1], 100.0);
    }
}
