//! The experiment runner: wires an [`ExpConfig`] into a full simulated
//! deployment — servers (with co-located monitors sharing the machine's
//! CPU threads, as deployed in the paper), clients, and the rollback
//! controller — runs it, and extracts the measurements.
//!
//! Three engines, one world. The same [`build_world`] constructor
//! assembles the deployment for the single-queue engine, the
//! merged-order sharded engine, and the threaded engine
//! ([`crate::sim::shard::run_threaded`]). On a worker shard the
//! constructor builds the *entire* shared state (interner, registry,
//! ring, graphs — all deterministic from the config) and then registers
//! only the actors the shard hosts; per-shard telemetry is pulled out as
//! a [`Harvest`] and merged in shard order, which reproduces the
//! single-queue extraction bit-for-bit (every metric cell is written by
//! exactly one shard; logs carry `(at, seq)` stamps and merge by stable
//! sort on that engine-invariant dispatch key).

use std::cell::RefCell;
use std::rc::Rc;

use crate::adapt::{AdaptController, ModeSpan};
use crate::apps::coloring::{ColoringApp, ColoringShared};
use crate::apps::conjunctive::{ConjunctiveApp, ConjunctiveShared};
use crate::apps::graph::Graph;
use crate::apps::kvmix::{KvMixApp, KvMixShared};
use crate::apps::peterson::{MeOracle, MeOracleRef};
use crate::apps::weather::{WeatherApp, WeatherShared};
use crate::client::actor::ClientActor;
use crate::client::app::AppLogic;
use crate::detect::local::LocalDetector;
use crate::detect::monitor::MonitorActor;
use crate::exp::config::{AccelKind, AppKind, ExpConfig};
use crate::metrics::throughput::{stable_mean, Metrics, MetricsHub};
use crate::predicate::spec::Registry;
use crate::rollback::recovery::ControllerActor;
use crate::runtime::accel::{Accel, NativeAccel};
use crate::sim::des::{Sim, SimStats};
use crate::sim::net::{Topology, TopologyBuilder};
use crate::sim::shard::{run_threaded, ShardPlan, ThreadCfg};
use crate::sim::{ProcId, Time};
use crate::store::ring::Router;
use crate::store::server::ServerActor;
use crate::trace::{ActorKind, TraceHub, TraceRef};
use crate::store::value::Interner;
use crate::util::rng::Rng;
use crate::util::stats::Cdf;
use crate::workload::shape::LoadShape;

/// Everything a bench/example needs after a run.
pub struct ExpResult {
    pub name: String,
    pub metrics: Metrics,
    pub sim_stats: SimStats,
    pub oracle: MeOracleRef,
    /// stable-phase aggregated throughput, application perspective (ops/s)
    pub app_tps: f64,
    /// stable-phase aggregated throughput, server perspective (ops/s)
    pub server_tps: f64,
    /// client-perspective op latency percentiles (ms) — the axis a
    /// pipeline-depth sweep trades against throughput
    pub lat_p50_ms: f64,
    pub lat_p99_ms: f64,
    pub violations_detected: usize,
    pub actual_me_violations: usize,
    /// detection latencies (ms) of every reported violation
    pub detection_latencies_ms: Vec<f64>,
    /// the same latencies as a queryable CDF (time from the violating
    /// write existing to the monitor flagging it) — the §VI headline
    /// artifact: regional p99.9 < 50 ms, global p99.9 < 5 s
    pub detection_cdf: Cdf,
    /// aggregate monitor stats. `pairs_checked` counts interval verdicts
    /// actually computed by the indexed search; `pairs_charged` counts
    /// the modeled linear-scan pairs that drive the virtual CPU cost
    /// (identical to the pre-index `pairs_checked`, so schedules and
    /// costs are comparable PR-over-PR).
    pub candidates_seen: u64,
    pub pairs_checked: u64,
    pub pairs_charged: u64,
    /// largest per-conjunct search window observed on any monitor
    pub window_peak: usize,
    pub active_preds_peak: usize,
    pub gc_evicted: u64,
    /// aggregate client stats
    pub ops_ok: u64,
    pub ops_failed: u64,
    pub restarts: u64,
    /// churn leave/rejoin cycles completed by clients
    pub rejoins: u64,
    /// quorum rounds that expired client-side (serial-round fallbacks +
    /// timeout failures) — the liveness signal the adapt controller
    /// consumes via client reports
    pub quorum_timeouts: u64,
    /// controller stats
    pub recoveries: u64,
    /// recovery phases that hit their ack deadline (a crashed owner
    /// never answered; the controller decided on the live quorum)
    pub recovery_ack_timeouts: u64,
    /// recoveries abandoned for lack of even a live majority
    pub recovery_aborts: u64,
    /// recoveries that ran to completion
    pub completed_recoveries: u64,
    /// mean time-to-recover (ms) over completed recoveries (0 when none)
    pub mean_recovery_ms: f64,
    /// fault-injection stats (aggregated over servers)
    pub crashes: u64,
    pub resyncs: u64,
    pub resync_keys: u64,
    /// servers that dropped and re-derived their partitions on a
    /// controller `Reset` (the `ResetToClean` strategy)
    pub resets: u64,
    /// adaptive-consistency artifacts ([`crate::adapt`]): the announced
    /// mode timeline (a single span covering the whole run when no
    /// controller is deployed), the number of epoch switches, and the
    /// stable throughput of each mode over the windows it fully covered
    pub mode_timeline: Vec<ModeSpan>,
    pub mode_switches: u64,
    pub per_mode_tps: Vec<(String, f64)>,
    /// sharded-engine telemetry ([`crate::sim::des::Sim::new_sharded`],
    /// [`crate::sim::shard::run_threaded`]): window barriers executed and
    /// events dispatched per shard (0 / empty on the single-queue engine)
    pub barriers: u64,
    pub shard_events: Vec<u64>,
    /// conservative lookahead window `W` chosen by [`ShardPlan::build`]
    /// (0 on the single-queue engine)
    pub lookahead: Time,
    /// actors hosted per shard under the plan (empty on the single-queue
    /// engine)
    pub shard_actors: Vec<usize>,
    /// workload/contention stats ([`crate::workload`]): detected
    /// violations per 1000 successful ops — the violation *rate* a skew
    /// sweep plots against zipf θ
    pub violations_per_kop: f64,
    /// share of keyed ops on the hottest single rank (0 unless kvmix ran)
    pub hot_key_share: f64,
    /// smallest number of key ranks absorbing 90% of keyed traffic
    pub keys_p90: usize,
    /// stable throughput per load-shape phase (empty without a shape):
    /// every full metrics window attributed to the segment covering it
    pub phase_tps: Vec<(String, f64)>,
    /// the merged flight recording ([`crate::trace`]) — `None` unless
    /// the config enabled a recorder; engine-invariant when present
    pub trace: Option<TraceHub>,
}

/// Ring-block shard placement for the runner's actor layout
/// (servers | monitors | clients | controller [| adapt]): server `i`
/// and its co-located monitor land on shard `i·k/s`, clients stripe the
/// same way, and the control plane rides shard 0. `k` clamps to the
/// server count so every shard owns at least one server block.
fn shard_plan(topo: &Topology, s: usize, c: usize, shards: usize) -> ShardPlan {
    let k = shards.clamp(1, s);
    let mut shard_of = vec![0u32; topo.n_procs()];
    for i in 0..s {
        shard_of[i] = (i * k / s) as u32;
        shard_of[s + i] = shard_of[i]; // monitor shares the machine
    }
    for j in 0..c {
        shard_of[2 * s + j] = (j * k / c) as u32;
    }
    // controller (and adapt controller, when present) stay on shard 0
    ShardPlan::build(topo, shard_of).expect("runner layout always yields a valid plan")
}

/// Actors hosted per shard — every topology process carries exactly one
/// actor in the runner's layout, so this is a straight census of the
/// plan's `shard_of` table.
fn actor_counts(plan: &ShardPlan) -> Vec<usize> {
    let mut counts = vec![0usize; plan.n_shards];
    for &sh in &plan.shard_of {
        counts[sh as usize] += 1;
    }
    counts
}

/// The actor id layout: servers | monitors | clients | controller
/// [| adapt controller — only when an active policy deploys one, so
/// static-policy runs keep the exact pre-adapt layout].
struct Layout {
    s: usize,
    c: usize,
    server_ids: Vec<ProcId>,
    monitor_ids: Vec<ProcId>,
    client_ids: Vec<ProcId>,
    controller_id: ProcId,
    adapt_id: Option<ProcId>,
}

impl Layout {
    fn new(cfg: &ExpConfig) -> Self {
        let s = cfg.n_servers();
        let c = cfg.n_clients;
        Self {
            s,
            c,
            server_ids: (0..s as u32).map(ProcId).collect(),
            monitor_ids: (s as u32..2 * s as u32).map(ProcId).collect(),
            client_ids: (2 * s as u32..(2 * s + c) as u32).map(ProcId).collect(),
            controller_id: ProcId((2 * s + c) as u32),
            adapt_id: cfg.adapt.enabled().then(|| ProcId((2 * s + c + 1) as u32)),
        }
    }
}

/// Build the topology the layout maps onto (one machine per server with
/// a co-located monitor process, one per client, control plane in
/// region 0).
fn build_topology(cfg: &ExpConfig, lay: &Layout) -> (Topology, Vec<usize>) {
    let n_regions = cfg.n_regions() as u8;
    let mut tb = TopologyBuilder::new();
    let mut server_machines = Vec::new();
    for i in 0..lay.s {
        let (_, m) = tb.add_machine_proc(i as u8 % n_regions, cfg.server_threads);
        server_machines.push(m);
    }
    for i in 0..lay.s {
        // monitor co-located with server i (shares CPU threads)
        tb.add_colocated_proc(server_machines[i]);
    }
    for i in 0..lay.c {
        tb.add_machine_proc(i as u8 % n_regions, 2);
    }
    tb.add_machine_proc(0, 2); // controller
    if lay.adapt_id.is_some() {
        tb.add_machine_proc(0, 2); // adapt controller, beside the control plane
    }
    tb.build(cfg.base_ms(), cfg.drop_prob)
}

/// Does this run (or this worker shard of it) host process `id`?
fn hosts(filter: Option<(&ShardPlan, u32)>, id: ProcId) -> bool {
    filter.map_or(true, |(plan, shard)| plan.shard_of[id.idx()] == shard)
}

/// The world handles a run needs back after the event loop: the hub the
/// hosted actors record into and the mutual-exclusion oracle log. On the
/// threaded engine these are per-shard and merged afterwards.
struct WorldHandles {
    metrics: Metrics,
    oracle: MeOracleRef,
    /// the shard's flight recorder (`None` when tracing is off)
    trace: Option<TraceRef>,
}

/// Construct the deployment inside `sim`, registering only the actors
/// `filter` hosts (all of them when `None`). Everything that must agree
/// across shards — interned key ids, registered predicate ids, the ring,
/// the graphs, per-client app state — is derived deterministically from
/// `cfg` alone, and the app RNG stream is consumed identically whether
/// or not a given client's actor is ultimately registered.
fn build_world(
    cfg: &ExpConfig,
    lay: &Layout,
    sim: &mut Sim,
    filter: Option<(&ShardPlan, u32)>,
) -> WorldHandles {
    let (s, c) = (lay.s, lay.c);

    // ---- shared state ----
    let interner = Interner::new();
    let router = Router::new(cfg.build_ring(), interner.clone());
    let registry = Rc::new(RefCell::new(Registry::new()));
    let metrics = MetricsHub::new(s, c);
    let oracle = MeOracle::new();
    // the flight recorder: one hub per run (per shard on the threaded
    // engine); hosted actors register below so per-shard registries and
    // rings stay key-disjoint and union cleanly at merge
    let trace: Option<TraceRef> = cfg.trace.enabled().then(|| TraceHub::new(cfg.trace));
    let accel: Rc<RefCell<dyn Accel>> = match cfg.accel {
        AccelKind::Native => Rc::new(RefCell::new(NativeAccel::new())),
        AccelKind::Xla => crate::runtime::pjrt::shared_xla_accel(),
    };

    // ---- application construction (freezes the key space and pre-seeds
    //      the registry in canonical order — see the Shared constructors) ----
    let mut app_rng = Rng::stream(cfg.seed, 0xA99);
    let mut apps: Vec<Box<dyn AppLogic>> = Vec::with_capacity(c);
    match &cfg.app {
        AppKind::Coloring { nodes, edges_per_node, task_size, loop_forever } => {
            let graph =
                Rc::new(Graph::powerlaw_cluster(*nodes, *edges_per_node, 0.3, &mut app_rng));
            let sh = ColoringShared::new(
                graph,
                c,
                interner.clone(),
                &registry,
                oracle.clone(),
                metrics.clone(),
                *task_size,
                *loop_forever,
                cfg.stabilize,
            );
            for i in 0..c {
                apps.push(Box::new(ColoringApp::new(sh.clone(), i as u32)));
            }
        }
        AppKind::Weather { grid_w, grid_h, put_pct, use_locks } => {
            let graph = Rc::new(Graph::grid(*grid_w, *grid_h));
            let sh = WeatherShared::new(
                graph,
                c,
                interner.clone(),
                &registry,
                oracle.clone(),
                *put_pct,
                *use_locks,
            );
            for i in 0..c {
                apps.push(Box::new(WeatherApp::new(sh.clone(), i as u32, 0)));
            }
        }
        AppKind::Conjunctive { n_preds, n_conjuncts, beta, put_pct } => {
            let sh = ConjunctiveShared::setup(
                &registry,
                interner.clone(),
                *n_preds,
                *n_conjuncts,
                *beta,
                *put_pct,
            );
            for i in 0..c {
                apps.push(Box::new(ConjunctiveApp::new(sh.clone(), i as u32, 0)));
            }
        }
        AppKind::KvMix => {
            // the workload engine's app: key space, samplers and hot-key
            // predicates all derive from `cfg.workload` with no RNG draws,
            // so every shard freezes the identical interner/registry state
            let sh =
                KvMixShared::setup(&registry, interner.clone(), &cfg.workload, c, metrics.clone());
            for i in 0..c {
                apps.push(Box::new(KvMixApp::new(sh.clone(), i as u32, 0)));
            }
        }
    }

    // ---- actor registration (sparse on worker shards) ----
    for i in 0..s {
        let id = lay.server_ids[i];
        if !hosts(filter, id) {
            continue;
        }
        let detector = cfg.monitors.then(|| {
            LocalDetector::new(
                i as u16,
                registry.clone(),
                interner.clone(),
                router.clone(),
                lay.monitor_ids.clone(),
                true, // naming-convention inference on
            )
        });
        let mut server = ServerActor::new(
            i as u16,
            router.clone(),
            detector,
            cfg.server_cfg.clone(),
            metrics.clone(),
            Some(lay.controller_id),
            lay.server_ids.clone(),
        );
        if let Some(tr) = &trace {
            tr.borrow_mut().register(id, ActorKind::Server, i as u32);
            server = server.with_trace(tr.clone());
        }
        sim.add_actor_at(id, Box::new(server));
    }
    for i in 0..s {
        let id = lay.monitor_ids[i];
        if !hosts(filter, id) {
            continue;
        }
        let mut monitor = MonitorActor::new(
            i as u16,
            registry.clone(),
            accel.clone(),
            Some(lay.controller_id),
            cfg.monitor_cfg.clone(),
            metrics.clone(),
        );
        if let Some(tr) = &trace {
            tr.borrow_mut().register(id, ActorKind::Monitor, i as u32);
            monitor = monitor.with_trace(tr.clone());
        }
        sim.add_actor_at(id, Box::new(monitor));
    }
    for (i, app) in apps.into_iter().enumerate() {
        let id = lay.client_ids[i];
        if !hosts(filter, id) {
            continue;
        }
        let mut client = ClientActor::new(
            i as u32,
            lay.server_ids.clone(),
            router.clone(),
            cfg.consistency,
            cfg.timing,
            cfg.pipeline_depth,
            app,
            metrics.clone(),
        );
        if let Some(adapt) = lay.adapt_id {
            client = client.with_adapt_reports(adapt, cfg.adapt.window);
        }
        if let Some(tr) = &trace {
            tr.borrow_mut().register(id, ActorKind::Client, i as u32);
            client = client.with_trace(tr.clone());
        }
        sim.add_actor_at(id, Box::new(client));
    }
    if hosts(filter, lay.controller_id) {
        let mut controller = ControllerActor::new(
            lay.server_ids.clone(),
            lay.client_ids.clone(),
            cfg.recovery,
            metrics.clone(),
        )
        .with_adapt(lay.adapt_id);
        if let Some(tr) = &trace {
            tr.borrow_mut().register(lay.controller_id, ActorKind::Controller, 0);
            controller = controller.with_trace(tr.clone());
        }
        sim.add_actor_at(lay.controller_id, Box::new(controller));
    }
    if let Some(adapt) = lay.adapt_id {
        if hosts(filter, adapt) {
            let mut ad = AdaptController::new(lay.client_ids.clone(), &cfg.adapt, cfg.consistency)
                .with_rollback(Some(lay.controller_id));
            if let Some(tr) = &trace {
                tr.borrow_mut().register(adapt, ActorKind::Adapt, 0);
                ad = ad.with_trace(tr.clone());
            }
            sim.add_actor_at(adapt, Box::new(ad));
        }
    }

    WorldHandles { metrics, oracle, trace }
}

/// Everything a run (or one worker shard of it) yields, as plain `Send`
/// data. Harvests merge in shard order; the merged harvest of a threaded
/// run is bit-identical to the single harvest of a merged-order run.
struct Harvest {
    metrics: MetricsHub,
    oracle: MeOracle,
    candidates_seen: u64,
    pairs_checked: u64,
    pairs_charged: u64,
    window_peak: usize,
    gc_evicted: u64,
    ops_ok: u64,
    ops_failed: u64,
    restarts: u64,
    rejoins: u64,
    crashes: u64,
    resyncs: u64,
    resync_keys: u64,
    resets: u64,
    recoveries: u64,
    recovery_ack_timeouts: u64,
    recovery_aborts: u64,
    completed_recoveries: u64,
    recovery_ms_total: f64,
    /// mode timeline + switch count, from whichever shard hosts the
    /// adapt controller (at most one does)
    adapt: Option<(Vec<ModeSpan>, u64)>,
    /// the shard's flight recording (rings of the hosted actors only)
    trace: Option<TraceHub>,
}

/// Pull the per-actor counters out of the hosted actors plus copies of
/// the shared-state artifacts.
fn harvest(
    lay: &Layout,
    sim: &mut Sim,
    handles: &WorldHandles,
    filter: Option<(&ShardPlan, u32)>,
) -> Harvest {
    let mut h = Harvest {
        metrics: handles.metrics.borrow().clone(),
        oracle: handles.oracle.borrow().clone(),
        candidates_seen: 0,
        pairs_checked: 0,
        pairs_charged: 0,
        window_peak: 0,
        gc_evicted: 0,
        ops_ok: 0,
        ops_failed: 0,
        restarts: 0,
        rejoins: 0,
        crashes: 0,
        resyncs: 0,
        resync_keys: 0,
        resets: 0,
        recoveries: 0,
        recovery_ack_timeouts: 0,
        recovery_aborts: 0,
        completed_recoveries: 0,
        recovery_ms_total: 0.0,
        adapt: None,
        trace: handles.trace.as_ref().map(|t| t.borrow().clone()),
    };
    for &id in lay.monitor_ids.iter().filter(|&&id| hosts(filter, id)) {
        if let Some(any) = sim.actor_mut(id).as_any() {
            if let Some(mon) = any.downcast_mut::<MonitorActor>() {
                h.candidates_seen += mon.candidates_seen;
                h.pairs_checked += mon.pairs_checked;
                h.pairs_charged += mon.pairs_charged;
                h.window_peak = h.window_peak.max(mon.window_peak);
                h.gc_evicted += mon.gc_evicted;
            }
        }
    }
    for &id in lay.client_ids.iter().filter(|&&id| hosts(filter, id)) {
        if let Some(any) = sim.actor_mut(id).as_any() {
            if let Some(cl) = any.downcast_mut::<ClientActor>() {
                h.ops_ok += cl.ops_ok;
                h.ops_failed += cl.ops_failed;
                h.restarts += cl.restarts;
                h.rejoins += cl.rejoins;
            }
        }
    }
    for &id in lay.server_ids.iter().filter(|&&id| hosts(filter, id)) {
        if let Some(any) = sim.actor_mut(id).as_any() {
            if let Some(sv) = any.downcast_mut::<ServerActor>() {
                h.crashes += sv.crashes;
                h.resyncs += sv.resyncs;
                h.resync_keys += sv.resync_keys;
                h.resets += sv.resets;
            }
        }
    }
    if hosts(filter, lay.controller_id) {
        if let Some(ctl) = sim
            .actor_mut(lay.controller_id)
            .as_any()
            .and_then(|a| a.downcast_mut::<ControllerActor>())
        {
            h.recoveries = ctl.recoveries;
            h.recovery_ack_timeouts = ctl.ack_timeouts;
            h.recovery_aborts = ctl.aborted_recoveries;
            h.completed_recoveries = ctl.completed_recoveries;
            h.recovery_ms_total = ctl.recovery_ms_total;
        }
    }
    if let Some(id) = lay.adapt_id.filter(|&id| hosts(filter, id)) {
        h.adapt = sim
            .actor_mut(id)
            .as_any()
            .and_then(|a| a.downcast_mut::<AdaptController>())
            .map(|ad| (ad.timeline.clone(), ad.switches));
    }
    h
}

/// Fold per-shard harvests (in shard order) into one. Counter merges are
/// sums; the metrics hub and oracle merge by their own engine-invariant
/// rules ([`MetricsHub::merge`], [`MeOracle::merge`]).
fn merge_harvests(mut hs: Vec<Harvest>) -> Harvest {
    let mut acc = hs.remove(0);
    for h in hs {
        acc.metrics.merge(&h.metrics);
        acc.oracle.merge(&h.oracle);
        acc.candidates_seen += h.candidates_seen;
        acc.pairs_checked += h.pairs_checked;
        acc.pairs_charged += h.pairs_charged;
        acc.window_peak = acc.window_peak.max(h.window_peak);
        acc.gc_evicted += h.gc_evicted;
        acc.ops_ok += h.ops_ok;
        acc.ops_failed += h.ops_failed;
        acc.restarts += h.restarts;
        acc.rejoins += h.rejoins;
        acc.crashes += h.crashes;
        acc.resyncs += h.resyncs;
        acc.resync_keys += h.resync_keys;
        acc.resets += h.resets;
        acc.recoveries += h.recoveries;
        acc.recovery_ack_timeouts += h.recovery_ack_timeouts;
        acc.recovery_aborts += h.recovery_aborts;
        acc.completed_recoveries += h.completed_recoveries;
        acc.recovery_ms_total += h.recovery_ms_total;
        if acc.adapt.is_none() {
            acc.adapt = h.adapt;
        }
        match (&mut acc.trace, h.trace) {
            (Some(a), Some(b)) => a.merge(&b),
            (None, Some(b)) => acc.trace = Some(b),
            _ => {}
        }
    }
    acc
}

/// Telemetry the engine (not the world) produced.
struct EngineRun {
    sim_stats: SimStats,
    barriers: u64,
    shard_events: Vec<u64>,
    lookahead: Time,
    shard_actors: Vec<usize>,
}

/// Derive the [`ExpResult`] from a merged harvest — the single
/// extraction path every engine funnels through.
fn finalize(cfg: &ExpConfig, h: Harvest, engine: EngineRun) -> ExpResult {
    let metrics: Metrics = Rc::new(RefCell::new(h.metrics));
    let oracle: MeOracleRef = Rc::new(RefCell::new(h.oracle));
    let (app_tps, server_tps, violations_detected, detection_latencies_ms) = {
        let m = metrics.borrow();
        (
            stable_mean(&m.app_series(), 0.25),
            stable_mean(&m.server_series(), 0.25),
            m.violations.len(),
            m.violations.iter().map(|v| v.detection_latency_ms()).collect::<Vec<f64>>(),
        )
    };
    let (lat_p50_ms, lat_p99_ms) = {
        let ps = metrics.borrow().op_latency_percentiles_ms(&[50.0, 99.0]);
        (ps[0], ps[1])
    };
    let (mode_timeline, mode_switches) = h.adapt.unwrap_or_else(|| {
        // no controller deployed: the whole run is one static span
        (vec![ModeSpan { from: 0, epoch: 0, cfg: cfg.consistency }], 0)
    });
    let per_mode_tps = {
        let m = metrics.borrow();
        per_mode_throughput(&mode_timeline, &m.app_series(), m.window)
    };
    let quorum_timeouts = metrics.borrow().quorum_timeouts;
    let active_preds_peak = metrics.borrow().active_preds_peak;
    let actual_me_violations = oracle.borrow().violations().len();
    let detection_cdf = Cdf::new(detection_latencies_ms.clone());
    let violations_per_kop = if h.ops_ok == 0 {
        0.0
    } else {
        violations_detected as f64 * 1000.0 / h.ops_ok as f64
    };
    let (hot_key_share, keys_p90) = {
        let m = metrics.borrow();
        (m.hot_key_share(), m.keys_covering(0.9))
    };
    let phase_tps = match &cfg.workload.shape {
        None => Vec::new(),
        Some(shape) => {
            let m = metrics.borrow();
            per_phase_throughput(shape, &m.app_series(), m.window)
        }
    };
    ExpResult {
        name: cfg.name.clone(),
        sim_stats: engine.sim_stats,
        barriers: engine.barriers,
        shard_events: engine.shard_events,
        lookahead: engine.lookahead,
        shard_actors: engine.shard_actors,
        metrics,
        oracle,
        app_tps,
        server_tps,
        lat_p50_ms,
        lat_p99_ms,
        violations_detected,
        actual_me_violations,
        detection_latencies_ms,
        detection_cdf,
        candidates_seen: h.candidates_seen,
        pairs_checked: h.pairs_checked,
        pairs_charged: h.pairs_charged,
        window_peak: h.window_peak,
        active_preds_peak,
        gc_evicted: h.gc_evicted,
        ops_ok: h.ops_ok,
        ops_failed: h.ops_failed,
        restarts: h.restarts,
        quorum_timeouts,
        recoveries: h.recoveries,
        recovery_ack_timeouts: h.recovery_ack_timeouts,
        recovery_aborts: h.recovery_aborts,
        completed_recoveries: h.completed_recoveries,
        mean_recovery_ms: if h.completed_recoveries == 0 {
            0.0
        } else {
            h.recovery_ms_total / h.completed_recoveries as f64
        },
        crashes: h.crashes,
        resyncs: h.resyncs,
        resync_keys: h.resync_keys,
        resets: h.resets,
        mode_timeline,
        mode_switches,
        per_mode_tps,
        rejoins: h.rejoins,
        violations_per_kop,
        hot_key_share,
        keys_p90,
        phase_tps,
        trace: h.trace,
    }
}

/// Run one experiment to completion on the engine the config selects:
/// the single-queue engine (`shards == 0`), the merged-order sharded
/// engine (`shards > 0`), or the threaded engine (`threaded` — worker
/// threads under the conservative window protocol). All three produce
/// bit-identical results.
pub fn run(cfg: &ExpConfig) -> ExpResult {
    let lay = Layout::new(cfg);
    let (topo, threads) = build_topology(cfg, &lay);

    // ---- fault schedule: lower the role-level plan onto this layout ----
    // (servers are procs 0..s — the id layout above — and partitions
    // group whole regions, so the topology's region table is the map)
    let fault_timeline =
        crate::faults::lower(&cfg.fault_plan, &topo.region_of, lay.s, cfg.n_regions());
    // client churn rides the same timeline: leave/rejoin lowers onto
    // crash/restart changes against the client procs (2s..2s+c), so every
    // engine replays it through the one fault path it already has
    let fault_timeline = if cfg.workload.churn.is_none() {
        fault_timeline
    } else {
        fault_timeline.merged_with(cfg.workload.churn.lower(2 * lay.s as u32))
    };

    if cfg.threaded {
        assert!(cfg.shards > 0, "threaded runs need with_shards(k >= 1) before with_threaded()");
        let plan = shard_plan(&topo, lay.s, lay.c, cfg.shards);
        let shard_actors = actor_counts(&plan);
        let tcfg = ThreadCfg {
            topo,
            threads,
            seed: cfg.seed,
            skew_ms: cfg.skew_ms,
            eps_ms: cfg.eps_ms,
            sched: cfg.sched,
            timeline: fault_timeline,
        };
        let build = |shard: u32, sim: &mut Sim| {
            let handles = build_world(cfg, &lay, sim, Some((&plan, shard)));
            sim.set_blackboard(Box::new(handles));
        };
        let extract = |shard: u32, sim: &mut Sim| -> Harvest {
            let handles = sim
                .take_blackboard()
                .expect("build stashed the world handles")
                .downcast::<WorldHandles>()
                .expect("blackboard holds this run's world handles");
            harvest(&lay, sim, &handles, Some((&plan, shard)))
        };
        let tr = run_threaded(&tcfg, &plan, cfg.duration, &build, &extract);
        let h = merge_harvests(tr.results);
        return finalize(
            cfg,
            h,
            EngineRun {
                sim_stats: tr.stats,
                barriers: tr.barriers,
                shard_events: tr.per_shard_events,
                lookahead: tr.lookahead,
                shard_actors,
            },
        );
    }

    let (mut sim, plan_info) = if cfg.shards == 0 {
        (Sim::new(topo, &threads, cfg.seed, cfg.skew_ms, cfg.eps_ms), None)
    } else {
        let plan = shard_plan(&topo, lay.s, lay.c, cfg.shards);
        let info = (plan.lookahead, actor_counts(&plan));
        let sim =
            Sim::new_sharded(topo, &threads, cfg.seed, cfg.skew_ms, cfg.eps_ms, &plan, cfg.sched);
        (sim, Some(info))
    };
    let handles = build_world(cfg, &lay, &mut sim, None);
    sim.install_faults(fault_timeline);
    sim.run_until(cfg.duration);
    let h = harvest(&lay, &mut sim, &handles, None);
    let (lookahead, shard_actors) = plan_info.unwrap_or((0, Vec::new()));
    finalize(
        cfg,
        h,
        EngineRun {
            sim_stats: sim.stats().clone(),
            barriers: sim.barriers(),
            shard_events: sim.shard_events(),
            lookahead,
            shard_actors,
        },
    )
}

/// Mean app throughput per consistency mode: every full metrics window
/// is attributed to the mode span that covers it entirely (windows that
/// straddle a switch are skipped, as are the warm-up window and the
/// final, possibly partial one). Returned in first-seen order.
fn per_mode_throughput(
    timeline: &[ModeSpan],
    series: &[f64],
    window: crate::sim::Time,
) -> Vec<(String, f64)> {
    let mut acc: Vec<(String, f64, u64)> = Vec::new();
    if timeline.is_empty() || series.len() < 3 {
        return Vec::new();
    }
    for (i, &v) in series.iter().enumerate().take(series.len() - 1).skip(1) {
        let (ws, we) = (i as crate::sim::Time * window, (i + 1) as crate::sim::Time * window);
        let Some(k) = timeline.iter().rposition(|sp| sp.from <= ws) else { continue };
        if let Some(next) = timeline.get(k + 1) {
            if next.from < we {
                continue; // the mode changed inside this window
            }
        }
        let label = timeline[k].label();
        match acc.iter_mut().find(|(l, _, _)| l.as_str() == label) {
            Some((_, sum, n)) => {
                *sum += v;
                *n += 1;
            }
            None => acc.push((label.to_string(), v, 1)),
        }
    }
    acc.into_iter().map(|(l, sum, n)| (l, sum / n.max(1) as f64)).collect()
}

/// Mean app throughput per load-shape phase, the same full-window
/// attribution as [`per_mode_throughput`]: a window counts toward the
/// segment that covers it entirely (straddlers, the warm-up window and
/// the final partial window are skipped). Labels are `{index}:{kind}` so
/// repeated kinds — the flat/spike/flat of a flash crowd — stay distinct.
fn per_phase_throughput(shape: &LoadShape, series: &[f64], window: Time) -> Vec<(String, f64)> {
    let mut acc: Vec<(String, f64, u64)> = Vec::new();
    if shape.segs.is_empty() || series.len() < 3 {
        return Vec::new();
    }
    // segment start times, from cumulative durations
    let mut starts = Vec::with_capacity(shape.segs.len());
    let mut t: Time = 0;
    for seg in &shape.segs {
        starts.push(t);
        t += seg.dur;
    }
    for (i, &v) in series.iter().enumerate().take(series.len() - 1).skip(1) {
        let (ws, we) = (i as Time * window, (i + 1) as Time * window);
        let Some(k) = starts.iter().rposition(|&from| from <= ws) else { continue };
        // the shape's final rate holds past its end, so the last segment
        // extends to the run's horizon; earlier segments end at the next
        let seg_end = starts.get(k + 1).copied().unwrap_or(Time::MAX);
        if seg_end < we {
            continue; // the phase changed inside this window
        }
        let label = format!("{k}:{}", shape.segs[k].kind.label());
        match acc.iter_mut().find(|(l, _, _)| *l == label) {
            Some((_, sum, n)) => {
                *sum += v;
                *n += 1;
            }
            None => acc.push((label, v, 1)),
        }
    }
    acc.into_iter().map(|(l, sum, n)| (l, sum / n.max(1) as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::consistency::ConsistencyCfg;
    use crate::sim::SEC;

    fn small_conj(consistency: ConsistencyCfg, monitors: bool) -> ExpConfig {
        let mut cfg = ExpConfig::new(
            "test",
            consistency,
            AppKind::Conjunctive { n_preds: 4, n_conjuncts: 3, beta: 0.2, put_pct: 0.5 },
        );
        cfg.n_clients = 6;
        cfg.monitors = monitors;
        cfg.duration = 20 * SEC;
        cfg.topo = crate::exp::config::TopoKind::AwsRegional { zones: 3 };
        cfg
    }

    #[test]
    fn per_mode_throughput_attributes_full_windows() {
        use crate::sim::SEC;
        let tl = vec![
            ModeSpan { from: 0, epoch: 0, cfg: ConsistencyCfg::n3r1w1() },
            ModeSpan { from: 3 * SEC + SEC / 2, epoch: 1, cfg: ConsistencyCfg::n3r2w2() },
            ModeSpan { from: 6 * SEC, epoch: 2, cfg: ConsistencyCfg::n3r1w1() },
        ];
        // windows:   0    1    2    3*   4    5    6    7    8(last)
        // * = straddles the 3.5 s switch; 0 is warm-up; 8 is partial
        let series = vec![10.0, 100.0, 100.0, 55.0, 40.0, 40.0, 100.0, 100.0, 12.0];
        let tps = per_mode_throughput(&tl, &series, SEC);
        assert_eq!(tps.len(), 2);
        assert_eq!(tps[0].0, "eventual");
        assert_eq!(tps[0].1, 100.0, "windows 1, 2, 6, 7");
        assert_eq!(tps[1].0, "sequential");
        assert_eq!(tps[1].1, 40.0, "windows 4, 5");

        // static single-span timeline: everything but the trim is one mode
        let single = vec![ModeSpan { from: 0, epoch: 0, cfg: ConsistencyCfg::n3r1w1() }];
        let tps = per_mode_throughput(&single, &[7.0; 5], SEC);
        assert_eq!(tps, vec![("eventual".to_string(), 7.0)]);

        assert!(per_mode_throughput(&single, &[1.0, 2.0], SEC).is_empty(), "too short");
        assert!(per_mode_throughput(&[], &[7.0; 5], SEC).is_empty());
    }

    #[test]
    fn conjunctive_run_detects_violations() {
        let res = run(&small_conj(ConsistencyCfg::n3r1w1(), true));
        assert!(res.ops_ok > 100, "clients made progress: {}", res.ops_ok);
        assert!(res.app_tps > 0.0);
        assert!(res.server_tps > res.app_tps, "servers see replication fan-out");
        assert!(res.candidates_seen > 0, "candidates flowed to monitors");
        assert!(
            res.violations_detected > 0,
            "beta=0.2 with 3 conjuncts must produce detectable violations"
        );
        for l in &res.detection_latencies_ms {
            assert!(*l > -6.0, "latency cannot be (very) negative: {l}");
        }
    }

    #[test]
    fn indexed_monitor_does_less_verdict_work() {
        // the acceptance bar for the window index: on the conjunctive
        // scenarios the verdicts actually computed fall strictly below
        // the modeled linear scan (which is what the CPU cost charges)
        let res = run(&small_conj(ConsistencyCfg::n3r1w1(), true));
        assert!(res.pairs_charged > 0, "conjunctive run must search");
        assert!(
            res.pairs_checked < res.pairs_charged,
            "index must cut verdict work: checked {} vs charged {}",
            res.pairs_checked,
            res.pairs_charged
        );
        // the default ε = ∞ physically entangles every pair: the
        // certificate covers the whole window and no verdict runs
        assert_eq!(res.pairs_checked, 0, "ε = ∞ certifies every pair");
        assert!(res.window_peak > 0, "windows filled during the run");
    }

    #[test]
    fn monitors_off_means_no_candidates() {
        let res = run(&small_conj(ConsistencyCfg::n3r1w1(), false));
        assert_eq!(res.candidates_seen, 0);
        assert_eq!(res.violations_detected, 0);
        assert!(res.ops_ok > 100);
    }

    #[test]
    fn deterministic_under_seed() {
        let a = run(&small_conj(ConsistencyCfg::n3r1w1(), true));
        let b = run(&small_conj(ConsistencyCfg::n3r1w1(), true));
        assert_eq!(a.ops_ok, b.ops_ok);
        assert_eq!(a.violations_detected, b.violations_detected);
        assert_eq!(a.app_tps, b.app_tps);
    }

    #[test]
    fn depth_one_reproduces_the_serial_client_run_for_run() {
        // `pipeline_depth = 1` is the paper's closed-loop client: setting
        // the knob explicitly must change nothing about the default run —
        // same ops, same violations, same throughput, same event schedule
        let a = run(&small_conj(ConsistencyCfg::n3r1w1(), true));
        let b = run(&small_conj(ConsistencyCfg::n3r1w1(), true).with_pipeline_depth(1));
        assert_eq!(a.ops_ok, b.ops_ok);
        assert_eq!(a.ops_failed, b.ops_failed);
        assert_eq!(a.violations_detected, b.violations_detected);
        assert_eq!(a.app_tps, b.app_tps);
        assert_eq!(a.sim_stats.events, b.sim_stats.events, "identical event schedules");
    }

    #[test]
    fn pipelined_run_overlaps_ops_and_still_detects() {
        let res = run(&small_conj(ConsistencyCfg::n3r1w1(), true).with_pipeline_depth(4));
        assert!(res.ops_ok > 100, "clients made progress: {}", res.ops_ok);
        assert!(res.candidates_seen > 0, "candidates still flow when pipelined");
        assert!(res.violations_detected > 0, "detection survives op overlap");
    }

    #[test]
    fn pipelined_deterministic_under_seed() {
        let mk = || small_conj(ConsistencyCfg::n3r1w1(), true).with_pipeline_depth(4);
        let a = run(&mk());
        let b = run(&mk());
        assert_eq!(a.ops_ok, b.ops_ok);
        assert_eq!(a.violations_detected, b.violations_detected);
        assert_eq!(a.app_tps, b.app_tps);
        assert_eq!(a.sim_stats.events, b.sim_stats.events);
    }

    #[test]
    fn sharded_engine_reproduces_serial_run() {
        // the merged-order sharded engine is bit-identical to the legacy
        // single-queue engine — same ops, same detection, same schedule —
        // while actually exercising the window/barrier/outbox protocol
        let a = run(&small_conj(ConsistencyCfg::n3r1w1(), true));
        let b = run(&small_conj(ConsistencyCfg::n3r1w1(), true).with_shards(2));
        assert_eq!(a.ops_ok, b.ops_ok);
        assert_eq!(a.violations_detected, b.violations_detected);
        assert_eq!(a.app_tps, b.app_tps);
        assert_eq!(a.sim_stats.events, b.sim_stats.events, "identical event schedules");
        assert_eq!(a.barriers, 0, "legacy engine runs no windows");
        assert!(b.barriers > 0, "sharded engine ran the window protocol");
        assert_eq!(b.shard_events.len(), 2);
        assert_eq!(b.shard_events.iter().sum::<u64>(), b.sim_stats.events);
        // the plan's choices are reported ([`ExpResult::lookahead`])
        assert_eq!(a.lookahead, 0);
        assert!(a.shard_actors.is_empty());
        assert!(b.lookahead > 0, "cross-shard latency floors the window");
        assert_eq!(b.shard_actors.len(), 2);
        assert_eq!(b.shard_actors.iter().sum::<usize>(), 13, "2s + c + controller");
    }

    #[test]
    fn threaded_engine_reproduces_serial_run() {
        // the full-stack threaded engine: same world, worker threads under
        // the conservative window protocol — bit-identical results
        let a = run(&small_conj(ConsistencyCfg::n3r1w1(), true));
        let b = run(&small_conj(ConsistencyCfg::n3r1w1(), true).with_shards(2).with_threaded());
        assert_eq!(a.ops_ok, b.ops_ok);
        assert_eq!(a.ops_failed, b.ops_failed);
        assert_eq!(a.violations_detected, b.violations_detected);
        assert_eq!(a.actual_me_violations, b.actual_me_violations);
        assert_eq!(a.app_tps, b.app_tps);
        assert_eq!(a.candidates_seen, b.candidates_seen);
        assert_eq!(a.sim_stats.events, b.sim_stats.events, "identical event schedules");
        assert!(b.barriers > 0, "coordinator ran window barriers");
        assert_eq!(b.shard_events.len(), 2);
        assert_eq!(b.shard_events.iter().sum::<u64>(), b.sim_stats.events);
        assert!(b.lookahead > 0);
        assert_eq!(b.shard_actors.iter().sum::<usize>(), 13);
    }

    #[test]
    fn threaded_matches_merged_order_at_every_shard_count() {
        for k in [1usize, 2, 3] {
            let m = run(&small_conj(ConsistencyCfg::n3r1w1(), true).with_shards(k));
            let t = run(&small_conj(ConsistencyCfg::n3r1w1(), true).with_shards(k).with_threaded());
            assert_eq!(m.ops_ok, t.ops_ok, "shards={k}");
            assert_eq!(m.violations_detected, t.violations_detected, "shards={k}");
            assert_eq!(m.app_tps, t.app_tps, "shards={k}");
            assert_eq!(m.sim_stats.events, t.sim_stats.events, "shards={k}");
            assert_eq!(m.lookahead, t.lookahead, "shards={k}");
            assert_eq!(m.shard_actors, t.shard_actors, "shards={k}");
        }
    }

    #[test]
    #[should_panic(expected = "threaded runs need with_shards")]
    fn threaded_without_shards_is_rejected() {
        run(&small_conj(ConsistencyCfg::n3r1w1(), true).with_threaded());
    }

    #[test]
    fn eventual_beats_sequential_throughput() {
        // the paper's core benefit claim, on the conjunctive workload
        let ev = run(&small_conj(ConsistencyCfg::n3r1w1(), true));
        let seq = run(&small_conj(ConsistencyCfg::n3r1w3(), false));
        assert!(
            ev.app_tps > seq.app_tps,
            "eventual ({}) must out-run sequential ({})",
            ev.app_tps,
            seq.app_tps
        );
    }

    #[test]
    fn coloring_small_end_to_end() {
        let mut cfg = ExpConfig::new(
            "coloring-e2e",
            ConsistencyCfg::n3r1w1(),
            AppKind::Coloring { nodes: 120, edges_per_node: 3, task_size: 5, loop_forever: false },
        );
        cfg.n_clients = 4;
        cfg.duration = 400 * SEC;
        let res = run(&cfg);
        assert!(res.metrics.borrow().tasks_completed > 0, "tasks completed");
        assert!(res.ops_ok > 200);
        // predicates were pre-registered from lock variable names
        assert!(res.active_preds_peak > 0, "inferred predicates monitored");
    }

    #[test]
    fn scaleout_cluster_runs_end_to_end() {
        // 12 servers at N = 3: partitioned routing, detection AND rollback
        // all work on a cluster larger than the replication factor
        let mut cfg = small_conj(ConsistencyCfg::n3r1w1(), true);
        cfg = cfg.with_cluster_servers(12);
        cfg.n_clients = 12;
        cfg.recovery = crate::rollback::recovery::RecoveryPolicy::FullRestore;
        let res = run(&cfg);
        assert!(res.ops_ok > 100, "clients made progress: {}", res.ops_ok);
        assert!(res.candidates_seen > 0, "partition owners emit candidates");
        assert!(res.violations_detected > 0, "detection works across partitions");
        assert!(res.recoveries > 0, "rollback ran on the partitioned cluster");
    }

    #[test]
    fn scaleout_deterministic_under_seed() {
        let mk = || small_conj(ConsistencyCfg::n3r1w1(), true).with_cluster_servers(6);
        let a = run(&mk());
        let b = run(&mk());
        assert_eq!(a.ops_ok, b.ops_ok);
        assert_eq!(a.violations_detected, b.violations_detected);
        assert_eq!(a.app_tps, b.app_tps);
    }

    fn small_kvmix(theta: f64) -> ExpConfig {
        use crate::workload::keyspace::KeyDist;
        use crate::workload::WorkloadCfg;
        let mut cfg = ExpConfig::new("kvmix-test", ConsistencyCfg::n3r1w1(), AppKind::KvMix);
        cfg.n_clients = 8;
        cfg.duration = 20 * SEC;
        cfg.topo = crate::exp::config::TopoKind::AwsRegional { zones: 3 };
        let mut wl = WorkloadCfg::uniform_default().with_keys(32, 4).with_mix(0.5);
        if theta > 0.0 {
            wl = wl.with_dist(KeyDist::Zipf { theta });
        }
        cfg.with_workload(wl)
    }

    #[test]
    fn kvmix_skewed_run_detects_hot_key_contention() {
        let res = run(&small_kvmix(1.2));
        assert!(res.ops_ok > 100, "clients made progress: {}", res.ops_ok);
        assert!(res.hot_key_share > 2.0 / 32.0, "rank 0 dominates: {}", res.hot_key_share);
        assert!(res.keys_p90 < 32, "90% of skewed traffic on a strict subset of ranks");
        assert!(res.candidates_seen > 0, "occupancy flags feed the monitors");
        assert!(res.violations_detected > 0, "hot-key write overlap must be detected");
        assert!(res.violations_per_kop > 0.0);
    }

    #[test]
    fn kvmix_uniform_spreads_traffic() {
        let res = run(&small_kvmix(0.0));
        assert!(res.ops_ok > 100);
        assert!(res.hot_key_share < 0.15, "no dominant rank under uniform: {}", res.hot_key_share);
        assert!(res.keys_p90 > 16, "90% of uniform traffic needs most ranks: {}", res.keys_p90);
    }

    #[test]
    fn kvmix_identical_on_all_three_engines() {
        let a = run(&small_kvmix(1.2));
        let b = run(&small_kvmix(1.2).with_shards(2));
        let c = run(&small_kvmix(1.2).with_shards(2).with_threaded());
        for r in [&b, &c] {
            assert_eq!(a.ops_ok, r.ops_ok);
            assert_eq!(a.violations_detected, r.violations_detected);
            assert_eq!(a.app_tps, r.app_tps);
            assert_eq!(a.sim_stats.events, r.sim_stats.events, "identical event schedules");
            assert_eq!(a.hot_key_share, r.hot_key_share);
            assert_eq!(a.keys_p90, r.keys_p90);
        }
    }

    #[test]
    fn kvmix_churn_lowers_to_leave_and_rejoin() {
        use crate::workload::churn::{ChurnEvent, ChurnPlan};
        let cfg = small_kvmix(1.2);
        let wl = cfg.workload.clone().with_churn(
            ChurnPlan::none().with(ChurnEvent { client: 2, at: 5 * SEC, rejoin_after: 5 * SEC }),
        );
        let cfg = cfg.with_workload(wl);
        let res = run(&cfg);
        assert_eq!(res.rejoins, 1, "the departed client came back");
        assert!(res.ops_ok > 100, "the rest of the fleet kept going");
        // determinism across engines survives the merged timeline
        let t = run(&cfg.clone().with_shards(2).with_threaded());
        assert_eq!(res.ops_ok, t.ops_ok);
        assert_eq!(res.rejoins, t.rejoins);
        assert_eq!(res.sim_stats.events, t.sim_stats.events);
    }

    #[test]
    fn kvmix_flash_crowd_reports_per_phase_throughput() {
        let cfg = small_kvmix(1.2);
        let wl = cfg
            .workload
            .clone()
            .with_shape(LoadShape::flash_crowd(5.0, 50.0, 5 * SEC, 5 * SEC, 20 * SEC));
        let cfg = cfg.with_workload(wl);
        let res = run(&cfg);
        assert_eq!(res.phase_tps.len(), 3, "base / spike / base all covered: {:?}", res.phase_tps);
        let tps_of = |label: &str| {
            res.phase_tps.iter().find(|(l, _)| l.as_str() == label).map(|(_, v)| *v).unwrap()
        };
        assert!(
            tps_of("1:flat") > 2.0 * tps_of("0:flat"),
            "the spike phase carries the load: {:?}",
            res.phase_tps
        );
    }

    #[test]
    fn weather_runs_with_locks() {
        let mut cfg = ExpConfig::new(
            "weather-e2e",
            ConsistencyCfg::n3r1w1(),
            AppKind::Weather { grid_w: 10, grid_h: 10, put_pct: 0.5, use_locks: true },
        );
        cfg.n_clients = 4;
        cfg.duration = 30 * SEC;
        cfg.topo = crate::exp::config::TopoKind::AwsRegional { zones: 3 };
        let res = run(&cfg);
        assert!(res.ops_ok > 100);
        assert!(res.candidates_seen > 0, "boundary locks feed the monitors");
    }
}
