//! The perf harness behind `BENCH_hotpath.json`: a fixed scenario matrix
//! run wall-clock, with the hot-path counters every future perf PR is
//! judged against.
//!
//! The matrix pins the shapes that stress different hot paths:
//!
//! | row                    | stresses                                          |
//! |------------------------|---------------------------------------------------|
//! | `serial`               | the paper's closed-loop client (clock layer)      |
//! | `pipelined-d8`         | depth-8 scatter-gather (request fan-out, Rc share)|
//! | `scaleout-s24`         | 24-server ring, spilled HVCs (dim > inline cap)   |
//! | `scaleout-s24-shards{2,4,8}` | the **full stack on the threaded engine** ([`crate::sim::shard::run_threaded`]) |
//! | `faulted`              | crash/restart + re-sync (fault view on every send)|
//! | `kvmix-zipf{0.99,1.2}-s24` | the workload engine: alias-table draws + hot-key predicates on a 24-server ring |
//! | `flashcrowd-s24`       | load-shape pacing + partition + adapt round trip  |
//! | `recovery-matrix-s24-{mode}-{strat}` | the recovery-strategy matrix: crash churn on a 24-server ring under {eventual, causal, sequential} × {full, reset, stab} — per cell `violations_per_kop`, `recover_ms` (mean time-to-recover) and `net_tps` |
//! | `trace-overhead-s24-{off,ring,full}` | the flight recorder's cost on the scale-out row: `off` pins the inert default (digest-identical to `scaleout-s24`), `ring` the identity-only ring, `full` the forensics payloads (HVC snapshots + candidate keys) |
//! | `monitor-overhead-s24-{on,off}`      | the paper's "<4 %" monitoring-overhead claim as a first-class pair: the same scale-out deployment with and without monitors — compare `net_tps` (virtual-time, what the paper reports) and `events_per_sec` (wall-clock) |
//!
//! The `shards{k}` rows run the *same* `scaleout-s24` deployment —
//! servers, co-located monitors, closed-loop clients, rollback
//! controller — on `k` worker threads under the conservative window
//! protocol, bit-identical to the serial row by the engine's
//! determinism contract. The sweep `serial → shards8` is therefore a
//! true scaling curve of one workload, not an engine-only proxy. They
//! add `shards`, `barriers` and `imbalance` (max/mean − 1 of the
//! per-shard event counts) columns; serial rows carry zeros there.
//!
//! Per row the JSON records `events_per_sec` (DES wall-clock throughput
//! — the headline trajectory number), `sent_bytes_proxy` (nominal bytes
//! over all messages, [`crate::sim::des::MSG_CLASS_BYTES`] — the
//! allocation/traffic proxy), `pairs_checked` vs `pairs_charged` (real
//! vs modeled monitor verdict work) and `window_peak`. Virtual-time
//! results (ops, violations) ride along so a perf regression that
//! *changes behavior* is immediately visible in the same file.
//!
//! Entry point: `cargo bench --bench micro_hotpath -- perf`
//! (`--rows serial,faulted` to subset, `--out PATH` / `$PERF_OUT` to
//! redirect; `$BENCH_SCALE` / `$BENCH_SEED` as everywhere else). CI's
//! `perf-smoke` job runs the smallest row on every push and uploads the
//! artifact, so the emitter can never silently rot.

use std::time::Instant;

use crate::client::consistency::ConsistencyCfg;
use crate::exp::config::ExpConfig;
use crate::exp::{runner, scenarios};

/// The fixed matrix, smallest row first (CI smoke runs `MATRIX[0]`).
pub const MATRIX: [&str; 24] = [
    "serial",
    "pipelined-d8",
    "scaleout-s24",
    "scaleout-s24-shards2",
    "scaleout-s24-shards4",
    "scaleout-s24-shards8",
    "faulted",
    "kvmix-zipf0.99-s24",
    "kvmix-zipf1.2-s24",
    "flashcrowd-s24",
    "recovery-matrix-s24-eventual-full",
    "recovery-matrix-s24-eventual-reset",
    "recovery-matrix-s24-eventual-stab",
    "recovery-matrix-s24-causal-full",
    "recovery-matrix-s24-causal-reset",
    "recovery-matrix-s24-causal-stab",
    "recovery-matrix-s24-sequential-full",
    "recovery-matrix-s24-sequential-reset",
    "recovery-matrix-s24-sequential-stab",
    "trace-overhead-s24-off",
    "trace-overhead-s24-ring",
    "trace-overhead-s24-full",
    "monitor-overhead-s24-on",
    "monitor-overhead-s24-off",
];

/// One measured matrix row.
#[derive(Debug, Clone)]
pub struct PerfRow {
    pub name: String,
    /// DES events dispatched
    pub events: u64,
    /// wall-clock seconds for the whole run
    pub wall_s: f64,
    /// events / wall_s — the headline trajectory number
    pub events_per_sec: f64,
    pub sent_total: u64,
    /// nominal bytes over all sent messages (allocation proxy)
    pub sent_bytes_proxy: u64,
    /// interval verdicts actually computed by the indexed monitor
    pub pairs_checked: u64,
    /// modeled linear-scan pairs (the virtual CPU charge)
    pub pairs_charged: u64,
    /// largest per-conjunct search window observed
    pub window_peak: usize,
    pub candidates_seen: u64,
    pub ops_ok: u64,
    pub violations: usize,
    /// worker threads (0 = serial single-queue engine)
    pub shards: usize,
    /// window barriers executed by the sharded engine
    pub barriers: u64,
    /// per-shard event imbalance, max/mean − 1 (0 when not sharded)
    pub imbalance: f64,
    /// detected violations per 1000 successful ops (the recovery-matrix
    /// rows' first per-cell metric; meaningful on every violating row)
    pub violations_per_kop: f64,
    /// mean time-to-recover (ms) over completed recoveries — 0 when no
    /// recovery ran (or the strategy recovers instantly, e.g. Stabilize)
    pub recover_ms: f64,
    /// net application throughput (virtual-time ops/s) — what the cell's
    /// strategy leaves after its recovery stalls
    pub net_tps: f64,
}

/// Parse the shard count out of a `scaleout-s24-shards{k}` row name.
pub fn sharded_row_shards(row: &str) -> Option<usize> {
    row.strip_prefix("scaleout-s24-shards").and_then(|k| k.parse().ok())
}

/// Parse the two axes out of a `recovery-matrix-s24-{mode}-{strat}` row
/// name (mode and strategy labels as in
/// [`scenarios::RecoveryMode::label`] / [`scenarios::RECOVERY_STRATEGIES`]).
pub fn recovery_row_axes(
    row: &str,
) -> Option<(scenarios::RecoveryMode, crate::rollback::recovery::RecoveryPolicy)> {
    let rest = row.strip_prefix("recovery-matrix-s24-")?;
    let mode = scenarios::RecoveryMode::ALL.into_iter().find(|m| {
        rest.strip_prefix(m.label()).is_some_and(|r| r.starts_with('-'))
    })?;
    let strat = rest.strip_prefix(mode.label())?.strip_prefix('-')?;
    let (policy, _) =
        scenarios::RECOVERY_STRATEGIES.into_iter().find(|(_, label)| *label == strat)?;
    Some((mode, policy))
}

/// The cost of row `with` relative to `baseline`, in percent of the
/// baseline (positive = `with` is slower). Compare `net_tps` for the
/// paper's virtual-time monitoring-overhead claim, `events_per_sec` for
/// the recorder's wall-clock cost.
pub fn overhead_pct(baseline: f64, with: f64) -> f64 {
    if baseline <= 0.0 {
        return 0.0;
    }
    (1.0 - with / baseline) * 100.0
}

/// max/mean − 1 over per-shard event counts: 0 = perfectly balanced.
pub fn imbalance(per_shard: &[u64]) -> f64 {
    if per_shard.is_empty() {
        return 0.0;
    }
    let total: u64 = per_shard.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let mean = total as f64 / per_shard.len() as f64;
    let max = *per_shard.iter().max().unwrap() as f64;
    max / mean - 1.0
}

/// The configuration behind a matrix row. Panics on an unknown name so a
/// typo in `--rows` fails loudly instead of silently measuring nothing.
pub fn matrix_cfg(row: &str, scale: f64, seed: u64) -> ExpConfig {
    match row {
        // the paper's serial closed-loop client on the conjunctive
        // stress workload — the pure clock-layer hot path
        "serial" => scenarios::conjunctive_regional(ConsistencyCfg::n3r1w1(), true, scale, seed),
        // depth-8 scatter-gather coloring: request fan-out dominates
        "pipelined-d8" => scenarios::pipeline_coloring(8, 4, scale, seed),
        // 24-server ring: HVC dimension 24 > HVC_INLINE_CAP, the heap
        // spill path, plus partitioned routing
        "scaleout-s24" => scenarios::scaleout_conjunctive(24, scale, seed),
        // crash/restart churn: the fault view sits on every send
        "faulted" => scenarios::crash_churn_conjunctive(scale, seed),
        // the workload engine wall-clock: Zipf alias-table draws and
        // guarded hot-key traffic on the 24-server partitioned ring
        "kvmix-zipf0.99-s24" => {
            scenarios::kvmix_skew(0.99, scenarios::AdaptRun::StaticEventual, scale, seed)
                .with_cluster_servers(24)
        }
        "kvmix-zipf1.2-s24" => {
            scenarios::kvmix_skew(1.2, scenarios::AdaptRun::StaticEventual, scale, seed)
                .with_cluster_servers(24)
        }
        // shape pacing + mid-run partition + hysteresis round trip: the
        // whole new-subsystem stack in one wall-clock row
        "flashcrowd-s24" => {
            scenarios::kvmix_flash_crowd(scenarios::AdaptRun::Adaptive, true, scale, seed)
                .with_cluster_servers(24)
        }
        // the flight recorder's three modes on the scale-out deployment:
        // `off` must stay digest-identical to `scaleout-s24`, `ring`
        // prices the identity-only ring, `full` the forensics payloads
        "trace-overhead-s24-off" => {
            scenarios::scaleout_conjunctive(24, scale, seed)
                .with_trace(crate::trace::TraceCfg::off())
        }
        "trace-overhead-s24-ring" => {
            scenarios::scaleout_conjunctive(24, scale, seed)
                .with_trace(crate::trace::TraceCfg::ring(1 << 14))
        }
        "trace-overhead-s24-full" => {
            scenarios::scaleout_conjunctive(24, scale, seed)
                .with_trace(crate::trace::TraceCfg::full(1 << 14))
        }
        // the paper's monitoring-overhead claim (§VI: "typically less
        // than 4 %"): the same deployment with the monitors on and off
        "monitor-overhead-s24-on" => scenarios::scaleout_conjunctive(24, scale, seed),
        "monitor-overhead-s24-off" => {
            let mut cfg = scenarios::scaleout_conjunctive(24, scale, seed);
            cfg.monitors = false;
            cfg
        }
        other => {
            if let Some(k) = sharded_row_shards(other) {
                // the scale-out deployment on the threaded engine
                scenarios::scaleout_conjunctive(24, scale, seed).with_shards(k).with_threaded()
            } else if let Some((mode, strategy)) = recovery_row_axes(other) {
                // one cell of the recovery-strategy matrix, on the same
                // 24-server ring the other -s24 rows stress
                scenarios::recovery_matrix_cell(mode, strategy, scale, seed)
                    .with_cluster_servers(24)
            } else {
                panic!("unknown perf matrix row {other:?} (rows: {MATRIX:?})")
            }
        }
    }
}

/// Run one row wall-clock.
pub fn run_row(row: &str, scale: f64, seed: u64) -> PerfRow {
    let shards = sharded_row_shards(row).unwrap_or(0);
    let cfg = matrix_cfg(row, scale, seed);
    let t0 = Instant::now();
    let res = runner::run(&cfg);
    let wall_s = t0.elapsed().as_secs_f64();
    let events = res.sim_stats.events;
    PerfRow {
        name: row.to_string(),
        events,
        wall_s,
        events_per_sec: if wall_s > 0.0 { events as f64 / wall_s } else { 0.0 },
        sent_total: res.sim_stats.sent_total(),
        sent_bytes_proxy: res.sim_stats.sent_bytes_proxy(),
        pairs_checked: res.pairs_checked,
        pairs_charged: res.pairs_charged,
        window_peak: res.window_peak,
        candidates_seen: res.candidates_seen,
        ops_ok: res.ops_ok,
        violations: res.violations_detected,
        shards,
        barriers: res.barriers,
        imbalance: imbalance(&res.shard_events),
        violations_per_kop: res.violations_per_kop,
        recover_ms: res.mean_recovery_ms,
        net_tps: res.app_tps,
    }
}

/// Run the given rows (subset of [`MATRIX`]) in order.
pub fn run_matrix(rows: &[&str], scale: f64, seed: u64) -> Vec<PerfRow> {
    rows.iter().map(|r| run_row(r, scale, seed)).collect()
}

fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Serialize rows to the `BENCH_hotpath.json` schema (no JSON crate —
/// offline builds; the schema is flat enough for a hand-rolled writer).
pub fn to_json(rows: &[PerfRow], scale: f64, seed: u64, measured: bool, provenance: &str) -> String {
    let mut o = String::new();
    o.push_str("{\n");
    o.push_str("  \"schema\": 6,\n");
    o.push_str("  \"bench\": \"hotpath\",\n");
    o.push_str(&format!("  \"scale\": {scale},\n"));
    o.push_str(&format!("  \"seed\": {seed},\n"));
    o.push_str(&format!("  \"measured\": {measured},\n"));
    o.push_str("  \"provenance\": ");
    push_json_str(&mut o, provenance);
    o.push_str(",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        o.push_str("    {\"name\": ");
        push_json_str(&mut o, &r.name);
        o.push_str(&format!(
            ", \"events\": {}, \"wall_s\": {:.4}, \"events_per_sec\": {:.1}, \
             \"sent_total\": {}, \"sent_bytes_proxy\": {}, \"pairs_checked\": {}, \
             \"pairs_charged\": {}, \"window_peak\": {}, \"candidates_seen\": {}, \
             \"ops_ok\": {}, \"violations\": {}, \"shards\": {}, \"barriers\": {}, \
             \"imbalance\": {:.4}, \"violations_per_kop\": {:.3}, \"recover_ms\": {:.3}, \
             \"net_tps\": {:.2}}}",
            r.events,
            r.wall_s,
            r.events_per_sec,
            r.sent_total,
            r.sent_bytes_proxy,
            r.pairs_checked,
            r.pairs_charged,
            r.window_peak,
            r.candidates_seen,
            r.ops_ok,
            r.violations,
            r.shards,
            r.barriers,
            r.imbalance,
            r.violations_per_kop,
            r.recover_ms,
            r.net_tps,
        ));
        o.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    o.push_str("  ]\n}\n");
    o
}

/// Write the JSON next to wherever the harness runs (repo root under
/// `cargo bench`).
pub fn write_json(path: &std::path::Path, json: &str) -> std::io::Result<()> {
    std::fs::write(path, json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_rows_resolve_to_their_scenarios() {
        let serial = matrix_cfg("serial", 0.05, 7);
        assert_eq!(serial.pipeline_depth, 1);
        assert_eq!(serial.n_servers(), 3);
        let piped = matrix_cfg("pipelined-d8", 0.05, 7);
        assert_eq!(piped.pipeline_depth, 8);
        let scaled = matrix_cfg("scaleout-s24", 0.05, 7);
        assert_eq!(scaled.n_servers(), 24, "spills past HVC_INLINE_CAP");
        let faulted = matrix_cfg("faulted", 0.05, 7);
        assert!(!faulted.fault_plan.is_none());
        let sharded = matrix_cfg("scaleout-s24-shards4", 0.05, 7);
        assert_eq!(sharded.n_servers(), 24, "same deployment as the serial scale-out row");
        assert_eq!(sharded.shards, 4);
        assert!(sharded.threaded, "shards rows run the threaded engine");
        assert!(sharded.monitors, "the full stack, not an engine-only mill");
        let kvmix = matrix_cfg("kvmix-zipf1.2-s24", 0.05, 7);
        assert_eq!(kvmix.n_servers(), 24);
        assert_eq!(kvmix.app, crate::exp::config::AppKind::KvMix);
        assert!(!kvmix.workload.is_inert(), "the skew rows exercise the sampler");
        let fc = matrix_cfg("flashcrowd-s24", 0.05, 7);
        assert_eq!(fc.n_servers(), 24);
        assert!(fc.workload.shape.is_some(), "shape pacing is the point of the row");
        assert!(fc.adapt.enabled() && !fc.fault_plan.is_none(), "full round-trip stack");
        let cell = matrix_cfg("recovery-matrix-s24-causal-reset", 0.05, 7);
        assert_eq!(cell.n_servers(), 24, "the matrix rows ride the 24-server ring");
        assert!(cell.consistency.causal);
        assert_eq!(cell.recovery, crate::rollback::recovery::RecoveryPolicy::ResetToClean);
        assert!(!cell.fault_plan.is_none(), "every strategy must terminate through crashes");
    }

    #[test]
    fn overhead_rows_vary_only_the_measured_knob() {
        use crate::trace::TraceMode;
        let base = matrix_cfg("scaleout-s24", 0.05, 7);
        let off = matrix_cfg("trace-overhead-s24-off", 0.05, 7);
        let ring = matrix_cfg("trace-overhead-s24-ring", 0.05, 7);
        let full = matrix_cfg("trace-overhead-s24-full", 0.05, 7);
        assert_eq!(off.trace.mode, TraceMode::Off);
        assert_eq!(ring.trace.mode, TraceMode::Ring);
        assert_eq!(full.trace.mode, TraceMode::Full);
        assert!(!off.trace.enabled() && ring.trace.enabled() && full.trace.enabled());
        for cfg in [&off, &ring, &full] {
            assert_eq!(cfg.app, base.app, "same workload as the scale-out row");
            assert_eq!(cfg.seed, base.seed);
            assert_eq!(cfg.n_clients, base.n_clients);
            assert!(cfg.monitors);
        }

        let on = matrix_cfg("monitor-overhead-s24-on", 0.05, 7);
        let moff = matrix_cfg("monitor-overhead-s24-off", 0.05, 7);
        assert!(on.monitors && !moff.monitors, "the pair varies only the monitors");
        assert_eq!(on.app, moff.app);
        assert_eq!(on.seed, moff.seed);
        assert_eq!(on.consistency, moff.consistency);
        assert!(!on.trace.enabled() && !moff.trace.enabled());
    }

    #[test]
    fn overhead_pct_is_relative_slowdown() {
        assert!((overhead_pct(100.0, 96.0) - 4.0).abs() < 1e-12);
        assert_eq!(overhead_pct(100.0, 100.0), 0.0);
        assert!(overhead_pct(100.0, 110.0) < 0.0, "a speedup reads negative");
        assert_eq!(overhead_pct(0.0, 50.0), 0.0, "degenerate baseline");
    }

    #[test]
    fn recovery_row_names_parse() {
        use crate::rollback::recovery::RecoveryPolicy;
        use scenarios::RecoveryMode;
        assert_eq!(
            recovery_row_axes("recovery-matrix-s24-eventual-full"),
            Some((RecoveryMode::Eventual, RecoveryPolicy::FullRestore))
        );
        assert_eq!(
            recovery_row_axes("recovery-matrix-s24-sequential-stab"),
            Some((RecoveryMode::Sequential, RecoveryPolicy::Stabilize))
        );
        assert_eq!(recovery_row_axes("recovery-matrix-s24-causal-melt"), None);
        assert_eq!(recovery_row_axes("recovery-matrix-s24-eventual"), None);
        assert_eq!(recovery_row_axes("scaleout-s24"), None);
        // every matrix row of the family must parse, and the family is
        // the full 3 × 3 grid
        let cells: Vec<_> =
            MATRIX.iter().filter_map(|r| recovery_row_axes(r)).collect();
        assert_eq!(cells.len(), 9, "3 modes x 3 strategies");
        for mode in RecoveryMode::ALL {
            for (strategy, _) in scenarios::RECOVERY_STRATEGIES {
                assert!(cells.contains(&(mode, strategy)), "{mode:?} x {strategy:?}");
            }
        }
    }

    #[test]
    fn sharded_row_names_parse() {
        assert_eq!(sharded_row_shards("scaleout-s24-shards2"), Some(2));
        assert_eq!(sharded_row_shards("scaleout-s24-shards8"), Some(8));
        assert_eq!(sharded_row_shards("scaleout-s24"), None);
        assert_eq!(sharded_row_shards("serial"), None);
        for row in MATRIX {
            // every sharded matrix row must parse (a rename here must
            // update the parser, and vice versa)
            if row.contains("shards") {
                assert!(sharded_row_shards(row).is_some(), "{row}");
            }
        }
    }

    #[test]
    fn imbalance_is_max_over_mean() {
        assert_eq!(imbalance(&[]), 0.0);
        assert_eq!(imbalance(&[0, 0]), 0.0);
        assert_eq!(imbalance(&[100, 100, 100, 100]), 0.0, "balanced");
        assert!((imbalance(&[150, 50]) - 0.5).abs() < 1e-12, "max 150 / mean 100");
    }

    #[test]
    fn sharded_row_runs_the_full_stack_threaded() {
        let row = run_row("scaleout-s24-shards2", 0.01, 7);
        assert_eq!(row.shards, 2);
        assert!(row.events > 0);
        assert!(row.barriers > 0, "the window protocol ran");
        assert!(row.ops_ok > 0, "clients made progress");
        assert!(row.imbalance >= 0.0);
        assert!(row.pairs_charged > 0, "monitors run on the threaded engine too");
        assert!(row.candidates_seen > 0, "detection is part of the measured stack");
    }

    #[test]
    fn sharded_row_matches_its_serial_twin() {
        // the virtual-time behavior of a shards row must equal the
        // serial scale-out row — the sweep varies only the engine
        let serial = run_row("scaleout-s24", 0.01, 7);
        let sharded = run_row("scaleout-s24-shards2", 0.01, 7);
        assert_eq!(serial.events, sharded.events);
        assert_eq!(serial.ops_ok, sharded.ops_ok);
        assert_eq!(serial.violations, sharded.violations);
        assert_eq!(serial.sent_total, sharded.sent_total);
    }

    #[test]
    #[should_panic(expected = "unknown perf matrix row")]
    fn unknown_row_fails_loudly() {
        let _ = matrix_cfg("seriall", 0.05, 7);
    }

    #[test]
    fn serial_row_runs_and_serializes() {
        // smallest row at the test scale: end-to-end emitter check
        let row = run_row("serial", 0.01, 7);
        assert!(row.events > 0, "the run dispatched events");
        assert!(row.events_per_sec > 0.0);
        assert!(row.sent_bytes_proxy > row.sent_total, "proxy weighs bytes, not messages");
        assert!(row.pairs_checked <= row.pairs_charged);
        let json = to_json(&[row], 0.01, 7, true, "unit-test");
        for key in [
            "\"schema\": 6",
            "\"measured\": true",
            "\"name\": \"serial\"",
            "\"events_per_sec\"",
            "\"sent_bytes_proxy\"",
            "\"pairs_charged\"",
            "\"window_peak\"",
            "\"shards\": 0",
            "\"barriers\": 0",
            "\"imbalance\": 0.0000",
            "\"violations_per_kop\"",
            "\"recover_ms\"",
            "\"net_tps\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        // trailing-comma hygiene for single-row output
        assert!(!json.contains(",\n  ]"));
    }

    #[test]
    fn json_escapes_strings() {
        let mut s = String::new();
        push_json_str(&mut s, "a\"b\\c\nd");
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }
}
