//! Scenario presets — one builder per paper experiment (see DESIGN.md §4).
//!
//! Every builder takes a `scale` knob: 1.0 reproduces the paper's setup
//! parameters (50 000-node graph, long runs); smaller values shrink the
//! workload and duration proportionally so benches finish in CI time while
//! preserving the *shape* of the results (who wins, by what factor).

use crate::adapt::{AdaptCfg, HysteresisCfg};
use crate::client::consistency::{ClientTiming, ConsistencyCfg};
use crate::exp::config::{AppKind, ExpConfig, TopoKind};
use crate::faults::plan::{FaultEvent, FaultPlan};
use crate::rollback::recovery::RecoveryPolicy;
use crate::sim::{Time, SEC};
use crate::trace::TraceCfg;

fn dur(scale: f64, full_secs: u64) -> Time {
    ((full_secs as f64 * scale).max(20.0) as u64) * SEC
}

fn graph_nodes(scale: f64) -> usize {
    ((50_000.0 * scale) as usize).max(200)
}

/// Fig. 9 / Fig. 10 / Fig. 11 base: Social Media Analysis on the AWS
/// global topology, N = 3 servers, 15 clients (C/N = 5).
pub fn social_media_aws(
    consistency: ConsistencyCfg,
    monitors: bool,
    scale: f64,
    seed: u64,
) -> ExpConfig {
    let mut cfg = ExpConfig::new(
        &format!(
            "social-media-{}-{}",
            consistency.label(),
            if monitors { "mon" } else { "nomon" }
        ),
        consistency,
        AppKind::Coloring {
            nodes: graph_nodes(scale),
            edges_per_node: 3,
            task_size: 10,
            loop_forever: true,
        },
    );
    cfg.n_clients = 15;
    cfg.monitors = monitors;
    cfg.topo = TopoKind::AwsGlobal;
    cfg.duration = dur(scale, 600);
    cfg.seed = seed;
    // the paper's coloring clients spend ~115 ms of client-side processing
    // per op (15 clients ≈ 128 ops/s aggregated, §VI-A)
    cfg.timing = ClientTiming::with_think(115.0);
    cfg
}

/// Fig. 12: Weather Monitoring, one AWS region / 5 AZs, N = 5, 10 clients,
/// PUT% ∈ {25, 50}.
pub fn weather_regional(
    consistency: ConsistencyCfg,
    monitors: bool,
    put_pct: f64,
    scale: f64,
    seed: u64,
) -> ExpConfig {
    let side = ((80.0 * scale.sqrt()) as usize).max(20);
    let mut cfg = ExpConfig::new(
        &format!(
            "weather-{}-put{}-{}",
            consistency.label(),
            (put_pct * 100.0) as u32,
            if monitors { "mon" } else { "nomon" }
        ),
        consistency,
        AppKind::Weather { grid_w: side, grid_h: side, put_pct, use_locks: true },
    );
    cfg.n_clients = 10;
    cfg.monitors = monitors;
    cfg.topo = TopoKind::AwsRegional { zones: 5 };
    cfg.duration = dur(scale, 300);
    cfg.seed = seed;
    // light clients (§VI-B stresses the servers relative to the global
    // setup, but the reported throughputs keep them below saturation)
    cfg.timing = ClientTiming::with_think(2.5);
    cfg
}

/// Table III: Conjunctive detection-latency stress, same regional setup as
/// Fig. 12, β = 1 %, PUT% = 50, predicates of 10 conjuncts.
pub fn conjunctive_regional(
    consistency: ConsistencyCfg,
    monitors: bool,
    scale: f64,
    seed: u64,
) -> ExpConfig {
    let mut cfg = ExpConfig::new(
        &format!(
            "conjunctive-{}-{}",
            consistency.label(),
            if monitors { "mon" } else { "nomon" }
        ),
        consistency,
        AppKind::Conjunctive { n_preds: 10, n_conjuncts: 10, beta: 0.01, put_pct: 0.5 },
    );
    cfg.n_clients = 10;
    cfg.monitors = monitors;
    cfg.topo = TopoKind::AwsRegional { zones: 5 };
    cfg.duration = dur(scale, 600);
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(2.5);
    cfg
}

/// Table IV rows: the local-lab proxy network (Fig. 8) with a tunable
/// inter-region one-way latency (50 / 100 ms); N = 3 servers.
pub fn local_lab(
    app: LocalLabApp,
    consistency: ConsistencyCfg,
    monitors: bool,
    inter_ms: f64,
    scale: f64,
    seed: u64,
) -> ExpConfig {
    let (app_kind, n_clients, app_label) = match app {
        LocalLabApp::Conjunctive => (
            AppKind::Conjunctive { n_preds: 10, n_conjuncts: 10, beta: 0.01, put_pct: 0.5 },
            20,
            "conjunctive",
        ),
        LocalLabApp::Weather => {
            let side = ((60.0 * scale.sqrt()) as usize).max(16);
            (
                AppKind::Weather { grid_w: side, grid_h: side, put_pct: 0.5, use_locks: true },
                20,
                "weather",
            )
        }
        LocalLabApp::SocialMedia => (
            AppKind::Coloring {
                nodes: graph_nodes(scale * 0.4),
                edges_per_node: 3,
                task_size: 10,
                loop_forever: true,
            },
            10,
            "social-media",
        ),
    };
    let mut cfg = ExpConfig::new(
        &format!(
            "lab{}ms-{}-{}-{}",
            inter_ms as u32,
            app_label,
            consistency.label(),
            if monitors { "mon" } else { "nomon" }
        ),
        consistency,
        app_kind,
    );
    cfg.n_clients = n_clients;
    cfg.monitors = monitors;
    cfg.topo = TopoKind::LocalLab { inter_ms };
    cfg.duration = dur(scale, 300);
    cfg.seed = seed;
    // Table IV's app throughputs (e.g. 470 ops/s over C/N=20) imply heavy
    // clients here as well
    cfg.timing = ClientTiming::with_think(115.0);
    cfg
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalLabApp {
    Conjunctive,
    Weather,
    SocialMedia,
}

/// The cluster sizes of the scale-out study (fixed N = 3).
pub const SCALEOUT_SIZES: [usize; 4] = [3, 6, 12, 24];

/// Scale-out preset: the conjunctive stress workload on a partitioned
/// cluster of `cluster_servers` servers at fixed N3R1W1 (the journal
/// version's Voldemort deployment shape: cluster size ≫ N). The offered
/// load and the monitored keyspace both grow with the cluster — clients
/// per server and predicates per server are held constant — so aggregate
/// throughput measures how the store scales, not how a fixed workload is
/// diluted.
pub fn scaleout_conjunctive(cluster_servers: usize, scale: f64, seed: u64) -> ExpConfig {
    assert!(cluster_servers >= 3, "the family fixes N = 3");
    let mut cfg = ExpConfig::new(
        &format!("scaleout-s{cluster_servers}-N3R1W1"),
        ConsistencyCfg::n3r1w1(),
        AppKind::Conjunctive {
            n_preds: 2 * cluster_servers,
            n_conjuncts: 6,
            beta: 0.01,
            put_pct: 0.5,
        },
    )
    .with_cluster_servers(cluster_servers);
    // keep servers the bottleneck: thin clients, 5 per server
    cfg.n_clients = 5 * cluster_servers;
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.duration = dur(scale, 300);
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(1.0);
    cfg
}

/// The depths the pipeline sweep exercises (1 = the paper's serial
/// closed-loop client).
pub const PIPELINE_DEPTHS: [usize; 4] = [1, 2, 4, 8];

/// Pipeline depth sweep: Social Media Analysis coloring with *thin*
/// clients (no think time) on the AWS global topology, N3R1W1, so the
/// round-trip latency of the `deg(v)` neighbor reads — not client-side
/// compute — bounds throughput. At depth 1 this is the serial client;
/// deeper clients scatter-gather each node's reads (and each task's
/// deferred commits) in one wave. Few clients, so the sweep measures the
/// *per-client* pipeline win rather than aggregate server scaling (that
/// axis is `scaleout_conjunctive`).
pub fn pipeline_coloring(depth: usize, n_clients: usize, scale: f64, seed: u64) -> ExpConfig {
    assert!(n_clients >= 1);
    let mut cfg = ExpConfig::new(
        &format!("pipeline-d{depth}-c{n_clients}-coloring"),
        ConsistencyCfg::n3r1w1(),
        AppKind::Coloring {
            nodes: ((8_000.0 * scale) as usize).max(240),
            edges_per_node: 3,
            task_size: 10,
            loop_forever: true,
        },
    )
    .with_pipeline_depth(depth);
    cfg.n_clients = n_clients;
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsGlobal;
    cfg.duration = dur(scale, 120);
    cfg.seed = seed;
    cfg.timing = ClientTiming::default(); // thin clients: latency-bound
    cfg
}

/// Partition study: the coloring workload on the AWS global topology
/// with one region (Frankfurt, region 2) cut off for the middle third of
/// the run. N3R1W2 keeps reads optimistic (R = 1: every group still
/// reads) while W = 2 makes writes from the isolated region time out
/// their quorum — so the run exhibits the full §VI story: quorum
/// timeouts during the cut, continued optimistic progress in the
/// majority group, violations from cross-partition divergence, and
/// post-heal recovery.
pub fn partition_coloring(scale: f64, seed: u64) -> ExpConfig {
    let d = dur(scale, 300);
    let mut cfg = ExpConfig::new(
        "partition-coloring-N3R1W2",
        ConsistencyCfg::new(3, 1, 2),
        AppKind::Coloring {
            nodes: ((10_000.0 * scale) as usize).max(240),
            edges_per_node: 3,
            task_size: 10,
            loop_forever: true,
        },
    )
    .with_fault_plan(FaultPlan::none().with(FaultEvent::Partition {
        groups: vec![vec![0, 1], vec![2]],
        from: d / 3,
        until: 2 * d / 3,
    }));
    cfg.n_clients = 9; // 3 per region: every group keeps clients
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsGlobal;
    cfg.duration = d;
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(15.0);
    cfg
}

/// Crash-churn study: the conjunctive stress workload while servers
/// crash, lose their volatile state, restart and re-sync from their
/// preference-list peers. Recovery is `FullRestore` — the controller's
/// per-phase ack deadline decides on the live majority when a crashed
/// server cannot ack the stop-the-world freeze, so the restore runs
/// through the crash windows instead of wedging (the PR-3
/// `NotifyClients` workaround, retired; see DESIGN.md §13).
pub fn crash_churn_conjunctive(scale: f64, seed: u64) -> ExpConfig {
    let d = dur(scale, 300);
    let mut cfg = ExpConfig::new(
        "crash-churn-conjunctive-N3R1W1",
        ConsistencyCfg::n3r1w1(),
        AppKind::Conjunctive { n_preds: 10, n_conjuncts: 6, beta: 0.05, put_pct: 0.5 },
    )
    .with_fault_plan(
        FaultPlan::none()
            .with(FaultEvent::Crash { server: 1, at: d / 4, restart_after: d / 10 })
            .with(FaultEvent::Crash { server: 2, at: 3 * d / 5, restart_after: d / 10 }),
    );
    cfg.n_clients = 9;
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.duration = d;
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(2.5);
    cfg.recovery = crate::rollback::recovery::RecoveryPolicy::FullRestore;
    cfg
}

/// Detection-latency CDF sweep (§VI / Table III): the conjunctive
/// workload under a degraded-but-connected plan — a slow node and a
/// drop burst on the machine link between servers 0 and 1 (which thins
/// the server-0 ↔ monitor-1 / server-1 ↔ monitor-0 candidate paths as
/// well as any re-sync chunks). Detection stays robust because every
/// onset is emitted by all N replica servers, so each violation has
/// candidate copies on un-bursted paths — exactly the redundancy the
/// paper's monitors rely on. `regional = true` is the one-region /
/// 5-AZ deployment (paper: 99.9 % of violations detected < 50 ms);
/// `false` is the Ohio/Oregon/Frankfurt global one (< 5 s).
///
/// The CDF's *shape* is set by the topology (candidate hop + batching),
/// not by the predicate parameters — m and β only set the statistical
/// weight. The paper's 600 s runs use m = 10, β = 1 %; here m = 3,
/// β = 10 % keeps the violation population dense enough that short
/// CI-scale runs still have a meaningful p99.9.
pub fn detection_cdf_faulted(regional: bool, scale: f64, seed: u64) -> ExpConfig {
    let d = dur(scale, 300);
    let mut cfg = ExpConfig::new(
        if regional { "detect-cdf-regional" } else { "detect-cdf-global" },
        ConsistencyCfg::n3r1w1(),
        AppKind::Conjunctive { n_preds: 10, n_conjuncts: 3, beta: 0.1, put_pct: 0.5 },
    )
    .with_fault_plan(
        FaultPlan::none()
            .with(FaultEvent::SlowNode { proc: 2, factor: 3.0, from: d / 4, until: d / 2 })
            .with(FaultEvent::DropBurst {
                link: (0, 1),
                prob: 0.2,
                from: d / 2,
                until: 3 * d / 4,
            }),
    );
    cfg.n_clients = 9;
    cfg.monitors = true;
    cfg.topo = if regional {
        TopoKind::AwsRegional { zones: 5 }
    } else {
        TopoKind::AwsGlobal
    };
    cfg.duration = d;
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(2.5);
    cfg
}

/// How to pin (or not pin) the consistency mode of the adaptive-benefit
/// scenario: the hysteresis controller, or one of the two static
/// baselines it must beat phase-by-phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptRun {
    Adaptive,
    StaticEventual,
    StaticSequential,
}

impl AdaptRun {
    pub fn label(self) -> &'static str {
        match self {
            AdaptRun::Adaptive => "adaptive",
            AdaptRun::StaticEventual => "static-eventual",
            AdaptRun::StaticSequential => "static-sequential",
        }
    }
}

/// The eventual-mode quorum config of [`adaptive_conjunctive`]: R1 keeps
/// reads optimistic, W2 makes a partitioned region's writes surface as
/// quorum timeouts — the signal the hysteresis controller watches.
pub fn adaptive_eventual_mode() -> ConsistencyCfg {
    ConsistencyCfg::new(3, 1, 2)
}

/// Adaptive-consistency study: the conjunctive stress workload on a
/// 3-zone regional cluster whose middle phase is *bad* by fault plan —
/// region 2 (one server, three clients) is cut off for the middle fifth
/// of the run. Under the eventual mode (N3R1W2) the cut region's writes
/// miss their W = 2 quorum and expire, so the controller's
/// timeouts-per-second signal spikes deterministically; the hysteresis
/// policy drops the cluster to sequential (N3R2W2) and — after the heal
/// quiets the signal for `hold_windows` consecutive windows — returns it
/// to eventual. Only the timeout pair is armed: the conjunctive
/// workload's β-driven violation rate is mode-independent statistical
/// background here (the violation and stall pairs carry the paper's
/// premise in scenarios where rollback is the cost driver, and are
/// exercised at policy level).
///
/// The regional topology keeps the sequential mode's quorum penalty in
/// the ~10 % band, so the adaptive run's excursion costs well under the
/// 5 % acceptance envelope vs the best static pin while still
/// demonstrating a full eventual → sequential → eventual round trip.
pub fn adaptive_conjunctive(run: AdaptRun, scale: f64, seed: u64) -> ExpConfig {
    let d = dur(scale, 300);
    let eventual = adaptive_eventual_mode();
    let sequential = ConsistencyCfg::n3r2w2();
    let consistency = match run {
        AdaptRun::StaticSequential => sequential,
        _ => eventual,
    };
    let mut cfg = ExpConfig::new(
        &format!("adaptive-conjunctive-{}", run.label()),
        consistency,
        AppKind::Conjunctive { n_preds: 8, n_conjuncts: 3, beta: 0.01, put_pct: 0.5 },
    )
    .with_fault_plan(FaultPlan::none().with(FaultEvent::Partition {
        groups: vec![vec![0, 1], vec![2]],
        from: 2 * d / 5,
        until: 3 * d / 5,
    }));
    if run == AdaptRun::Adaptive {
        let hysteresis = HysteresisCfg {
            timeouts_per_sec_hi: 0.5,
            timeouts_per_sec_lo: 0.05,
            // the cut keeps the signal hot continuously, so a short hold
            // cannot flap; it lets CI-scale runs finish the round trip
            // with seconds to spare (the default, 5, suits long runs)
            hold_windows: 2,
            ..HysteresisCfg::disarmed()
        };
        cfg = cfg.with_adapt(AdaptCfg::hysteresis(hysteresis, eventual, sequential));
    }
    cfg.n_clients = 9; // 3 per zone: the cut group keeps offering load
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.duration = d;
    cfg.seed = seed;
    // think-time-dominated clients: the sequential mode's extra quorum
    // round trips stay a sub-10 % per-op penalty, so the adaptive run's
    // excursion costs a low single-digit percent of overall throughput —
    // the acceptance envelope is adaptive >= best static - 5 %
    cfg.timing = ClientTiming::with_think(15.0);
    cfg
}

/// [`adaptive_conjunctive`]'s partition scenario on the **three-level
/// escalation ladder**: the same deterministic timeout signal now walks
/// the cluster eventual → causal → sequential one rung per window while
/// the cut is open, and back down two held calm streaks after the heal.
/// Each rung carries its own recovery strategy — the matrix is pushed to
/// the rollback controller on every switch: optimistic mode restores in
/// full, the causal rung re-derives from peers without a freeze, and the
/// sequential rung (where mutual-exclusion violations cannot occur)
/// records without rolling back.
pub fn adaptive_ladder(scale: f64, seed: u64) -> ExpConfig {
    let mut cfg = adaptive_conjunctive(AdaptRun::Adaptive, scale, seed);
    cfg.name = "adaptive-ladder".into();
    cfg.recovery = RecoveryPolicy::FullRestore;
    let eventual = adaptive_eventual_mode();
    let hysteresis = HysteresisCfg {
        timeouts_per_sec_hi: 0.5,
        timeouts_per_sec_lo: 0.05,
        hold_windows: 2,
        ..HysteresisCfg::disarmed()
    };
    cfg.with_adapt(
        AdaptCfg::hysteresis3(
            hysteresis,
            eventual,
            eventual.with_causal(),
            ConsistencyCfg::n3r2w2(),
        )
        .with_recovery_matrix([
            RecoveryPolicy::FullRestore,
            RecoveryPolicy::ResetToClean,
            RecoveryPolicy::Stabilize,
        ]),
    )
}

/// The flight-recorder scenario (`optikv trace`): the three-level
/// adaptive ladder with the deterministic recorder in `Full` mode. One
/// faulted run exercises every event class the recorder knows —
/// β-seeded conjunctive violations (with HVC/key payloads for the
/// forensics walk), the partition's quorum timeouts, the controller's
/// window samples and mode switches, and the per-rung recovery phases.
/// The ring capacity is sized so CI-scale runs (`scale ≤ 0.25`) never
/// evict: every seeded violation must resolve to a non-empty causal
/// chain, which requires its guilty `ServerApply`s to still be in the
/// ring when the walk runs.
pub fn traced_ladder(scale: f64, seed: u64) -> ExpConfig {
    let mut cfg = adaptive_ladder(scale, seed);
    cfg.name = "traced-ladder".into();
    cfg.with_trace(TraceCfg::full(1 << 17))
}

/// The zipf exponents of the skew sweep (0 = uniform).
pub const SKEW_THETAS: [f64; 4] = [0.0, 0.8, 0.99, 1.2];

/// The workload-engine key space every kvmix scenario shares: 64 ranks,
/// the first 8 guarded (occupancy-bracketed writes feeding the hot-key
/// predicates), a 50/50 read/write mix. `theta = 0` is uniform.
fn kvmix_workload(theta: f64) -> crate::workload::WorkloadCfg {
    let wl = crate::workload::WorkloadCfg::uniform_default().with_keys(64, 8).with_mix(0.5);
    if theta > 0.0 {
        wl.with_dist(crate::workload::keyspace::KeyDist::Zipf { theta })
    } else {
        wl
    }
}

fn theta_label(theta: f64) -> String {
    if theta > 0.0 {
        format!("zipf{theta}")
    } else {
        "uniform".to_string()
    }
}

/// Skew sweep: the kvmix production-traffic workload on a 3-zone
/// regional cluster, popularity skew as the independent variable. The
/// violation *rate* (per kop) is monotone in θ — heavier skew
/// concentrates guarded writes onto fewer hot keys, so ring-adjacent
/// occupancy windows overlap more often per op. The adaptive variant
/// arms the violation pair at the paper's "violations are rare" premise
/// (escalate past 5/kop), so heavy skew drives the cluster sequential
/// while light skew leaves it optimistic.
pub fn kvmix_skew(theta: f64, run: AdaptRun, scale: f64, seed: u64) -> ExpConfig {
    let eventual = ConsistencyCfg::n3r1w1();
    let sequential = ConsistencyCfg::n3r2w2();
    let consistency = match run {
        AdaptRun::StaticSequential => sequential,
        _ => eventual,
    };
    let mut cfg = ExpConfig::new(
        &format!("kvmix-{}-{}", theta_label(theta), run.label()),
        consistency,
        AppKind::KvMix,
    );
    cfg.n_clients = 12;
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.duration = dur(scale, 300);
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(2.5);
    if run == AdaptRun::Adaptive {
        let hysteresis = HysteresisCfg {
            viol_per_kop_hi: 5.0,
            viol_per_kop_lo: 1.0,
            hold_windows: 2,
            ..HysteresisCfg::disarmed()
        };
        cfg = cfg.with_adapt(AdaptCfg::hysteresis(hysteresis, eventual, sequential));
    }
    cfg.with_workload(kvmix_workload(theta))
}

/// Flash crowd: kvmix under a load shape that multiplies the per-client
/// arrival rate tenfold for the middle fifth of the run. With
/// `partitioned = true` the spike coincides with a region cut — the
/// composition the workload engine exists for — and the eventual mode is
/// N3R1W2 so the cut region's writes surface as quorum timeouts, the
/// deterministic signal the adaptive variant's hysteresis watches
/// (violation background stays disarmed, as in [`adaptive_conjunctive`]):
/// the controller escalates during the crisis and releases after the
/// heal, a full round trip under flash-crowd traffic.
pub fn kvmix_flash_crowd(run: AdaptRun, partitioned: bool, scale: f64, seed: u64) -> ExpConfig {
    let d = dur(scale, 300);
    let eventual = adaptive_eventual_mode();
    let sequential = ConsistencyCfg::n3r2w2();
    let consistency = match run {
        AdaptRun::StaticSequential => sequential,
        _ => eventual,
    };
    let mut cfg = ExpConfig::new(
        &format!(
            "kvmix-flashcrowd{}-{}",
            if partitioned { "-part" } else { "" },
            run.label()
        ),
        consistency,
        AppKind::KvMix,
    );
    cfg.n_clients = 9; // 3 per zone: a cut group keeps offering load
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.duration = d;
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(2.5);
    if partitioned {
        cfg = cfg.with_fault_plan(FaultPlan::none().with(FaultEvent::Partition {
            groups: vec![vec![0, 1], vec![2]],
            from: 2 * d / 5,
            until: 3 * d / 5,
        }));
    }
    if run == AdaptRun::Adaptive {
        let hysteresis = HysteresisCfg {
            timeouts_per_sec_hi: 0.5,
            timeouts_per_sec_lo: 0.05,
            hold_windows: 2,
            ..HysteresisCfg::disarmed()
        };
        cfg = cfg.with_adapt(AdaptCfg::hysteresis(hysteresis, eventual, sequential));
    }
    let wl = kvmix_workload(0.99)
        .with_shape(crate::workload::shape::LoadShape::flash_crowd(5.0, 50.0, 2 * d / 5, d / 5, d));
    cfg.with_workload(wl)
}

/// Client churn under skewed traffic: every 4th client leaves a third of
/// the way in and rejoins a quarter-run later, lowered onto the same
/// fault timeline the engines already replay. The adaptive variant keeps
/// the timeout pair armed to demonstrate churn does *not* flap the
/// controller — departed clients stop reporting, they don't time out.
pub fn kvmix_churn(run: AdaptRun, scale: f64, seed: u64) -> ExpConfig {
    use crate::workload::churn::ChurnPlan;
    let d = dur(scale, 300);
    let eventual = ConsistencyCfg::n3r1w1();
    let sequential = ConsistencyCfg::n3r2w2();
    let consistency = match run {
        AdaptRun::StaticSequential => sequential,
        _ => eventual,
    };
    let mut cfg = ExpConfig::new(
        &format!("kvmix-churn-{}", run.label()),
        consistency,
        AppKind::KvMix,
    );
    cfg.n_clients = 12;
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.duration = d;
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(2.5);
    if run == AdaptRun::Adaptive {
        let hysteresis = HysteresisCfg {
            timeouts_per_sec_hi: 0.5,
            timeouts_per_sec_lo: 0.05,
            hold_windows: 2,
            ..HysteresisCfg::disarmed()
        };
        cfg = cfg.with_adapt(AdaptCfg::hysteresis(hysteresis, eventual, sequential));
    }
    cfg.with_workload(
        kvmix_workload(0.99).with_churn(ChurnPlan::periodic(12, 4, d / 3, d / 4)),
    )
}

/// The consistency axis of the recovery-strategy matrix: the three
/// escalation levels the adaptive controller moves between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryMode {
    Eventual,
    Causal,
    Sequential,
}

impl RecoveryMode {
    pub const ALL: [RecoveryMode; 3] =
        [RecoveryMode::Eventual, RecoveryMode::Causal, RecoveryMode::Sequential];

    pub fn label(self) -> &'static str {
        match self {
            RecoveryMode::Eventual => "eventual",
            RecoveryMode::Causal => "causal",
            RecoveryMode::Sequential => "sequential",
        }
    }

    pub fn consistency(self) -> ConsistencyCfg {
        match self {
            RecoveryMode::Eventual => ConsistencyCfg::n3r1w1(),
            RecoveryMode::Causal => ConsistencyCfg::n3r1w1().with_causal(),
            RecoveryMode::Sequential => ConsistencyCfg::n3r2w2(),
        }
    }
}

/// The strategy axis of the matrix, with the short labels the perf rows
/// and the `optikv recover` table use.
pub const RECOVERY_STRATEGIES: [(RecoveryPolicy, &str); 3] = [
    (RecoveryPolicy::FullRestore, "full"),
    (RecoveryPolicy::ResetToClean, "reset"),
    (RecoveryPolicy::Stabilize, "stab"),
];

/// One cell of the recovery-strategy matrix: the crash-churn conjunctive
/// workload (two crash/restart cycles — every strategy must terminate
/// through them) under consistency mode × recovery strategy. Everything
/// except the two axes is held fixed so per-cell
/// {violations/kop, time-to-recover, net throughput} differences are
/// attributable to the cell coordinates.
pub fn recovery_matrix_cell(
    mode: RecoveryMode,
    strategy: RecoveryPolicy,
    scale: f64,
    seed: u64,
) -> ExpConfig {
    let strat_label = RECOVERY_STRATEGIES
        .iter()
        .find(|(p, _)| *p == strategy)
        .map(|(_, l)| *l)
        .unwrap_or("custom");
    let mut cfg = crash_churn_conjunctive(scale, seed);
    cfg.name = format!("recmatrix-{}-{}", mode.label(), strat_label);
    cfg.consistency = mode.consistency();
    cfg.cluster_servers = cfg.consistency.n;
    cfg.recovery = strategy;
    cfg
}

/// The `Stabilize` strategy's demonstration workload: the
/// self-stabilizing coloring variant under the crash-churn fault plan.
/// Violations are recorded but nothing rolls back and no task aborts —
/// the continuous re-coloring pass repairs conflicting colors, so the
/// run must keep completing tasks with zero aborts.
pub fn stabilize_coloring(scale: f64, seed: u64) -> ExpConfig {
    let d = dur(scale, 300);
    let mut cfg = ExpConfig::new(
        "stabilize-coloring-N3R1W1",
        ConsistencyCfg::n3r1w1(),
        AppKind::Coloring {
            nodes: ((10_000.0 * scale) as usize).max(240),
            edges_per_node: 3,
            task_size: 10,
            loop_forever: true,
        },
    )
    .with_fault_plan(
        FaultPlan::none()
            .with(FaultEvent::Crash { server: 1, at: d / 4, restart_after: d / 10 }),
    );
    cfg.stabilize = true;
    cfg.recovery = RecoveryPolicy::Stabilize;
    cfg.n_clients = 9;
    cfg.monitors = true;
    cfg.topo = TopoKind::AwsRegional { zones: 3 };
    cfg.duration = d;
    cfg.seed = seed;
    cfg.timing = ClientTiming::with_think(2.5);
    cfg
}

/// The paper's Table II consistency presets for N = 3 and N = 5.
pub fn table2_n3() -> [ConsistencyCfg; 3] {
    [ConsistencyCfg::n3r1w3(), ConsistencyCfg::n3r2w2(), ConsistencyCfg::n3r1w1()]
}

pub fn table2_n5() -> [ConsistencyCfg; 3] {
    [ConsistencyCfg::n5r1w5(), ConsistencyCfg::n5r3w3(), ConsistencyCfg::n5r1w1()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_produce_paper_parameters() {
        let f10 = social_media_aws(ConsistencyCfg::n3r1w1(), true, 1.0, 1);
        assert_eq!(f10.n_clients, 15);
        assert_eq!(f10.n_servers(), 3);
        match f10.app {
            AppKind::Coloring { nodes, task_size, .. } => {
                assert_eq!(nodes, 50_000);
                assert_eq!(task_size, 10);
            }
            _ => panic!("wrong app"),
        }

        let f12 = weather_regional(ConsistencyCfg::n5r1w5(), true, 0.25, 1.0, 1);
        assert_eq!(f12.n_clients, 10);
        assert_eq!(f12.n_servers(), 5);
        assert_eq!(f12.topo, TopoKind::AwsRegional { zones: 5 });

        let t3 = conjunctive_regional(ConsistencyCfg::n5r1w1(), true, 1.0, 1);
        match t3.app {
            AppKind::Conjunctive { n_conjuncts, beta, put_pct, .. } => {
                assert_eq!(n_conjuncts, 10);
                assert_eq!(beta, 0.01);
                assert_eq!(put_pct, 0.5);
            }
            _ => panic!("wrong app"),
        }

        let t4 = local_lab(LocalLabApp::Weather, ConsistencyCfg::n3r2w2(), true, 50.0, 1.0, 1);
        assert_eq!(t4.topo, TopoKind::LocalLab { inter_ms: 50.0 });
        assert_eq!(t4.n_clients, 20);
    }

    #[test]
    fn scaleout_family_fixes_n_and_grows_cluster() {
        for s in SCALEOUT_SIZES {
            let cfg = scaleout_conjunctive(s, 0.1, 1);
            assert_eq!(cfg.n_servers(), s);
            assert_eq!(cfg.consistency, ConsistencyCfg::n3r1w1(), "N fixed at 3");
            assert_eq!(cfg.n_clients, 5 * s, "offered load scales with the cluster");
            match cfg.app {
                AppKind::Conjunctive { n_preds, .. } => assert_eq!(n_preds, 2 * s),
                _ => panic!("wrong app"),
            }
        }
    }

    #[test]
    fn pipeline_family_varies_only_the_depth() {
        let base = pipeline_coloring(1, 1, 0.05, 7);
        assert_eq!(base.pipeline_depth, 1);
        for &d in &PIPELINE_DEPTHS {
            let cfg = pipeline_coloring(d, 1, 0.05, 7);
            assert_eq!(cfg.pipeline_depth, d);
            assert_eq!(cfg.seed, base.seed, "same workload across the sweep");
            assert_eq!(cfg.app, base.app);
            assert_eq!(cfg.n_clients, base.n_clients);
            assert_eq!(cfg.timing.think, 0, "thin clients: latency-bound");
        }
    }

    #[test]
    fn fault_families_carry_valid_plans() {
        let p = partition_coloring(0.1, 1);
        assert!(!p.fault_plan.is_none());
        assert!(p.fault_plan.validate(p.n_servers(), p.n_regions()).is_ok());
        match &p.fault_plan.events[0] {
            FaultEvent::Partition { groups, from, until } => {
                assert_eq!(groups.len(), 2);
                assert!(from < until);
                assert!(*until <= p.duration, "heal happens inside the run");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(p.consistency, ConsistencyCfg::new(3, 1, 2), "W=2 makes cuts visible");

        let c = crash_churn_conjunctive(0.1, 1);
        assert!(c.fault_plan.validate(c.n_servers(), c.n_regions()).is_ok());
        assert_eq!(c.fault_plan.events.len(), 2, "two crash/restart cycles");
        assert_eq!(
            c.recovery,
            crate::rollback::recovery::RecoveryPolicy::FullRestore,
            "the deadline-hardened controller restores through crashes"
        );

        for regional in [true, false] {
            let dcfg = detection_cdf_faulted(regional, 0.1, 1);
            assert!(dcfg.fault_plan.validate(dcfg.n_servers(), dcfg.n_regions()).is_ok());
            assert!(dcfg.monitors);
        }
        assert_eq!(detection_cdf_faulted(true, 0.1, 1).n_regions(), 5);
        assert_eq!(detection_cdf_faulted(false, 0.1, 1).n_regions(), 3);
    }

    #[test]
    fn adaptive_family_varies_only_the_policy_and_start_mode() {
        let ad = adaptive_conjunctive(AdaptRun::Adaptive, 0.1, 7);
        let ev = adaptive_conjunctive(AdaptRun::StaticEventual, 0.1, 7);
        let seq = adaptive_conjunctive(AdaptRun::StaticSequential, 0.1, 7);

        assert!(ad.adapt.enabled());
        assert!(!ev.adapt.enabled() && !seq.adapt.enabled());
        assert_eq!(ad.consistency, adaptive_eventual_mode());
        assert_eq!(ev.consistency, adaptive_eventual_mode());
        assert_eq!(seq.consistency, ConsistencyCfg::n3r2w2());
        assert!(ad.consistency.is_eventual() && seq.consistency.is_sequential());

        // same workload, topology, faults and seed across the family
        for other in [&ev, &seq] {
            assert_eq!(ad.app, other.app);
            assert_eq!(ad.fault_plan, other.fault_plan);
            assert_eq!(ad.seed, other.seed);
            assert_eq!(ad.n_clients, other.n_clients);
            assert_eq!(ad.duration, other.duration);
        }

        // the bad phase sits strictly inside the run and heals before it ends
        assert!(ad.fault_plan.validate(ad.n_servers(), ad.n_regions()).is_ok());
        match &ad.fault_plan.events[0] {
            FaultEvent::Partition { from, until, .. } => {
                assert!(0 < *from && from < until && *until < ad.duration);
            }
            other => panic!("unexpected {other:?}"),
        }

        // the armed signal pair matches the scenario's fault mechanism
        match &ad.adapt.policy {
            crate::adapt::PolicyKind::Hysteresis(h) => {
                assert!(h.timeouts_per_sec_hi.is_finite());
                assert!(h.timeouts_per_sec_lo < h.timeouts_per_sec_hi);
                assert!(h.viol_per_kop_hi.is_infinite(), "β background stays disarmed");
            }
            other => panic!("unexpected policy {other:?}"),
        }
    }

    #[test]
    fn kvmix_families_compose_workload_faults_and_policy() {
        for &theta in &SKEW_THETAS {
            let cfg = kvmix_skew(theta, AdaptRun::StaticEventual, 0.1, 3);
            assert_eq!(cfg.app, AppKind::KvMix);
            assert_eq!(cfg.workload.n_keys, 64);
            assert_eq!(cfg.workload.guarded, 8);
            assert!(cfg.workload.validate(cfg.n_clients, cfg.duration).is_ok());
            assert!(!cfg.adapt.enabled());
        }
        assert!(kvmix_skew(1.2, AdaptRun::Adaptive, 0.1, 3).adapt.enabled());
        assert_eq!(
            kvmix_skew(0.0, AdaptRun::StaticEventual, 0.1, 3).name,
            "kvmix-uniform-static-eventual"
        );
        assert_eq!(
            kvmix_skew(0.99, AdaptRun::StaticEventual, 0.1, 3).name,
            "kvmix-zipf0.99-static-eventual"
        );

        let fc = kvmix_flash_crowd(AdaptRun::Adaptive, true, 0.1, 3);
        assert!(fc.adapt.enabled());
        assert!(!fc.fault_plan.is_none());
        assert!(fc.fault_plan.validate(fc.n_servers(), fc.n_regions()).is_ok());
        let shape = fc.workload.shape.as_ref().unwrap();
        assert_eq!(shape.total_dur(), fc.duration, "the shape covers the run");
        assert!(shape.rate_at(fc.duration / 2) > shape.rate_at(0), "spike in the middle");
        match &fc.fault_plan.events[0] {
            FaultEvent::Partition { from, until, .. } => {
                assert_eq!(*from, 2 * fc.duration / 5, "the cut coincides with the spike");
                assert_eq!(*until, 3 * fc.duration / 5);
            }
            other => panic!("unexpected {other:?}"),
        }
        // the unpartitioned variant is fault-free traffic shaping
        assert!(kvmix_flash_crowd(AdaptRun::StaticEventual, false, 0.1, 3).fault_plan.is_none());

        let ch = kvmix_churn(AdaptRun::StaticEventual, 0.1, 3);
        assert_eq!(ch.workload.churn.events.len(), 3, "every 4th of 12 clients");
        assert!(ch.workload.validate(ch.n_clients, ch.duration).is_ok());
        assert!(kvmix_churn(AdaptRun::Adaptive, 0.1, 3).adapt.enabled());
    }

    #[test]
    fn recovery_matrix_varies_only_its_two_axes() {
        let base = recovery_matrix_cell(RecoveryMode::Eventual, RecoveryPolicy::FullRestore, 0.1, 7);
        assert_eq!(base.name, "recmatrix-eventual-full");
        for mode in RecoveryMode::ALL {
            for (strategy, label) in RECOVERY_STRATEGIES {
                let cell = recovery_matrix_cell(mode, strategy, 0.1, 7);
                assert_eq!(cell.name, format!("recmatrix-{}-{label}", mode.label()));
                assert_eq!(cell.consistency, mode.consistency());
                assert_eq!(cell.recovery, strategy);
                // everything off-axis is held fixed
                assert_eq!(cell.app, base.app);
                assert_eq!(cell.fault_plan, base.fault_plan);
                assert_eq!(cell.seed, base.seed);
                assert_eq!(cell.n_clients, base.n_clients);
                assert_eq!(cell.duration, base.duration);
                assert!(cell.fault_plan.validate(cell.n_servers(), cell.n_regions()).is_ok());
            }
        }
        assert!(RecoveryMode::Causal.consistency().causal);
        assert!(RecoveryMode::Sequential.consistency().is_sequential());

        let st = stabilize_coloring(0.1, 7);
        assert!(st.stabilize, "the app must ignore rollback notifications");
        assert_eq!(st.recovery, RecoveryPolicy::Stabilize);
        assert!(st.fault_plan.validate(st.n_servers(), st.n_regions()).is_ok());
    }

    #[test]
    fn ladder_scenario_is_three_level_with_a_recovery_matrix() {
        use crate::adapt::{Mode, PolicyKind};
        let cfg = adaptive_ladder(0.1, 7);
        assert!(cfg.adapt.enabled());
        assert!(matches!(cfg.adapt.policy, PolicyKind::Hysteresis3(_)));
        assert_eq!(cfg.adapt.causal, Some(adaptive_eventual_mode().with_causal()));
        assert_eq!(cfg.consistency, adaptive_eventual_mode(), "starts on the bottom rung");
        let matrix = cfg.adapt.recovery_by_mode.expect("per-mode strategies configured");
        assert_eq!(matrix[Mode::Eventual.rung()], RecoveryPolicy::FullRestore);
        assert_eq!(matrix[Mode::Causal.rung()], RecoveryPolicy::ResetToClean);
        assert_eq!(matrix[Mode::Sequential.rung()], RecoveryPolicy::Stabilize);
        // off the adapt axis it is the adaptive_conjunctive scenario
        let base = adaptive_conjunctive(AdaptRun::Adaptive, 0.1, 7);
        assert_eq!(cfg.app, base.app);
        assert_eq!(cfg.fault_plan, base.fault_plan);
        assert_eq!(cfg.n_clients, base.n_clients);
        assert_eq!(cfg.duration, base.duration);
    }

    #[test]
    fn traced_ladder_is_the_ladder_plus_a_full_recorder() {
        let cfg = traced_ladder(0.1, 7);
        assert_eq!(cfg.name, "traced-ladder");
        assert!(cfg.trace.enabled());
        assert!(cfg.trace.full_payloads(), "forensics needs HVC/key payloads");
        let base = adaptive_ladder(0.1, 7);
        assert!(!base.trace.enabled(), "the recorder is opt-in");
        assert_eq!(cfg.app, base.app);
        assert_eq!(cfg.fault_plan, base.fault_plan);
        assert_eq!(cfg.seed, base.seed);
        assert_eq!(cfg.duration, base.duration);
    }

    #[test]
    fn scale_shrinks_but_keeps_minimums() {
        let small = social_media_aws(ConsistencyCfg::n3r1w1(), true, 0.01, 1);
        match small.app {
            AppKind::Coloring { nodes, .. } => assert!(nodes >= 200),
            _ => unreachable!(),
        }
        assert!(small.duration >= 20 * SEC);
    }
}
