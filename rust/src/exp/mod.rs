//! Experiment assembly: configuration, the runner that wires topology +
//! actors + shared state into a `Sim`, and the per-figure/table scenario
//! presets.

pub mod config;
pub mod runner;
pub mod scenarios;
