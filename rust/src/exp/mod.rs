//! Experiment assembly: configuration, the runner that wires topology +
//! actors + shared state into a `Sim`, the per-figure/table scenario
//! presets, and the perf harness behind `BENCH_hotpath.json`.

pub mod config;
pub mod perfjson;
pub mod runner;
pub mod scenarios;
