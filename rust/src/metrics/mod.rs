//! Measurement: throughput at the server and application perspectives,
//! violation records, stabilization trimming.

pub mod report;
pub mod throughput;
