//! Reporting helpers shared by the examples and the per-figure benches:
//! percentage math, scale/seed knobs from the environment, and standard
//! summary blocks.

use crate::exp::runner::ExpResult;
use crate::util::stats::{self, Cdf, Histogram, Table};

/// Benefit of `ours` over `baseline` in percent ((ours - base) / base).
pub fn benefit_pct(ours: f64, baseline: f64) -> f64 {
    if baseline == 0.0 {
        0.0
    } else {
        (ours - baseline) / baseline * 100.0
    }
}

/// Monitoring overhead in percent ((off - on) / off), server perspective.
pub fn overhead_pct(tps_with_monitors: f64, tps_without: f64) -> f64 {
    if tps_without == 0.0 {
        0.0
    } else {
        (tps_without - tps_with_monitors) / tps_without * 100.0
    }
}

/// Workload scale factor: `BENCH_SCALE` env (default keeps bench runtimes
/// in CI budgets; 1.0 = the paper's full parameters).
pub fn bench_scale(default: f64) -> f64 {
    std::env::var("BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

pub fn bench_seed() -> u64 {
    std::env::var("BENCH_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42)
}

/// Run summary: the headline counters, then — when the run produced
/// them — the detection-latency quantile ladder and the per-mode
/// throughput split. Both live in [`ExpResult`] on every run; surfacing
/// them here means every CLI path that prints a summary shows them
/// without opting in.
pub fn summarize(r: &ExpResult) -> String {
    let mut out = format!(
        "{:<38} app {:>8.1} ops/s | server {:>9.1} ops/s | viol {:>5} | cand {:>8} | ok {:>8}",
        r.name, r.app_tps, r.server_tps, r.violations_detected, r.candidates_seen, r.ops_ok
    );
    if !r.detection_cdf.is_empty() {
        out.push_str(&format!("\n  detect: {}", r.detection_cdf.summary().render(" ms")));
    }
    for (label, tps) in &r.per_mode_tps {
        out.push_str(&format!("\n  mode {label:<12} {tps:>8.1} ops/s (full windows)"));
    }
    out
}

/// Render Table III from detection latencies.
pub fn latency_table(lat_ms: &[f64]) -> String {
    let mut h = Histogram::table3_buckets();
    for &l in lat_ms {
        h.add(l.max(0.0));
    }
    let mut t = Table::new(&["Response time (ms)", "Count", "Percentage"]);
    for (label, count, pct) in h.rows() {
        t.row(&[label, count.to_string(), format!("{pct:.3}%")]);
    }
    let mut out = t.render();
    if !lat_ms.is_empty() {
        out.push_str(&format!(
            "avg={:.1} ms {}\n",
            stats::mean(lat_ms),
            Cdf::new(lat_ms.to_vec()).summary().render(" ms"),
        ));
    }
    out
}

/// Render the violation detection-latency CDF: the quantile ladder plus
/// the two §VI headline fractions (under 50 ms — the regional claim —
/// and under 5 s — the global one).
pub fn detection_cdf_summary(cdf: &Cdf) -> String {
    if cdf.is_empty() {
        return "detection-latency CDF: no violations detected\n".to_string();
    }
    let s = cdf.summary();
    let mut t = Table::new(&["Quantile", "Detection latency (ms)"]);
    for (label, v) in
        [("p50", s.p50), ("p90", s.p90), ("p99", s.p99), ("p99.9", s.p999), ("max", s.max)]
    {
        t.row(&[label.to_string(), format!("{v:.2}")]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "n={}  P[≤ 50 ms]={:.3}  P[≤ 5 s]={:.3}\n",
        cdf.len(),
        cdf.fraction_le(50.0),
        cdf.fraction_le(5_000.0),
    ));
    out
}

/// Render the adaptive-consistency artifacts: the announced mode
/// timeline (seconds, epoch, config, model) and each mode's stable
/// throughput over the windows it fully covered.
pub fn mode_timeline_summary(r: &ExpResult) -> String {
    if r.mode_switches == 0 && r.mode_timeline.len() <= 1 {
        return "mode timeline: static (no switches)\n".to_string();
    }
    let mut t = Table::new(&["From (s)", "Epoch", "Config", "Model"]);
    for sp in &r.mode_timeline {
        t.row(&[
            format!("{:.1}", sp.from as f64 / crate::sim::SEC as f64),
            sp.epoch.to_string(),
            sp.cfg.label(),
            sp.label().to_string(),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "switches={}  round-trips={}\n",
        r.mode_switches,
        crate::adapt::round_trips(&r.mode_timeline),
    ));
    for (label, tps) in &r.per_mode_tps {
        out.push_str(&format!("  {label:<12} {tps:>8.1} ops/s (full windows)\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percent_math() {
        assert!((benefit_pct(157.0, 100.0) - 57.0).abs() < 1e-9);
        assert!((overhead_pct(96.0, 100.0) - 4.0).abs() < 1e-9);
        assert_eq!(benefit_pct(1.0, 0.0), 0.0);
        assert_eq!(overhead_pct(1.0, 0.0), 0.0);
    }

    #[test]
    fn latency_table_renders() {
        let s = latency_table(&[3.0, 10.0, 60.0, 12_000.0]);
        assert!(s.contains("Response time"));
        assert!(s.contains("avg="));
        // bucket boundaries of the paper's Table III
        assert!(s.contains("0 - 50"));
        assert!(s.contains("10,000 - 17,000"));
    }

    #[test]
    fn cdf_summary_renders() {
        let cdf = Cdf::new((1..=100).map(|i| i as f64).collect());
        let s = detection_cdf_summary(&cdf);
        assert!(s.contains("p99.9"));
        assert!(s.contains("n=100"));
        assert!(s.contains("P[≤ 50 ms]=0.500"));
        let empty = detection_cdf_summary(&Cdf::default());
        assert!(empty.contains("no violations"));
    }
}
