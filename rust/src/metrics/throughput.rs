//! Throughput and violation metrics, recorded at the paper's two
//! perspectives (§VI-A "Performance Metric and Measurement"):
//!
//! * **server perspective** — requests served, aggregated over servers;
//!   used for *overhead* evaluation (monitors interfere with servers);
//! * **application perspective** — successful app-level operations;
//!   used for *benefit* evaluation (what users see).
//!
//! Time is bucketed into fixed windows; "result stabilization" (Fig. 9)
//! trims the initialization phase before averaging.

use std::cell::RefCell;
use std::rc::Rc;

use crate::clock::hvc::Millis;
use crate::predicate::spec::PredId;
use crate::sim::{Time, SEC};

#[derive(Debug, Clone)]
pub struct ViolationRecord {
    pub pred: PredId,
    pub name: String,
    /// safe estimate of the violation start (min witness start, ms)
    pub t_violate_ms: Millis,
    /// when the violating global state came into existence (max witness
    /// start, ms) — detection latency = detected_at − this
    pub t_occurred_ms: Millis,
    pub detected_at: Time,
    pub monitor: u16,
    /// `(at, seq)` dispatch key of the monitor flush that recorded this
    /// violation ([`crate::sim::des::Ctx::event_seq`]) — globally unique
    /// and engine-invariant, so per-shard record lists of a threaded run
    /// merge back into the exact global recording order
    pub at: Time,
    pub seq: u64,
}

impl ViolationRecord {
    /// Detection latency in ms (virtual): time from the violation existing
    /// to the monitor reporting it.
    pub fn detection_latency_ms(&self) -> f64 {
        (self.detected_at / crate::sim::MS) as f64 - self.t_occurred_ms as f64
    }
}

#[derive(Debug, Clone)]
pub struct MetricsHub {
    pub window: Time,
    /// requests served per server per window
    server_ops: Vec<Vec<u64>>,
    /// successful app ops per client per window
    app_ops: Vec<Vec<u64>>,
    /// running total of the buckets above — kept so per-tick pollers
    /// (the adapt controller) read it O(1) instead of re-summing every
    /// window bucket of every client each signal tick
    app_ops_recorded: u64,
    /// failed app ops per client
    pub app_failures: Vec<u64>,
    /// quorum rounds that expired client-side (serial-round fallbacks and
    /// timeout failures) — a liveness signal the adaptive-consistency
    /// controller polls ([`crate::adapt::signals`])
    pub quorum_timeouts: u64,
    pub violations: Vec<ViolationRecord>,
    /// candidates received across monitors
    pub candidates_received: u64,
    /// peak number of simultaneously-active predicates across monitors
    pub active_preds_peak: usize,
    /// app task accounting (coloring tasks, §VI-B recovery discussion)
    pub tasks_completed: u64,
    pub tasks_aborted: u64,
    /// per-task durations (ns), coloring app (§VI-B Discussion)
    pub task_durations: Vec<u64>,
    /// per-op latency samples (ns), app perspective (sampled)
    pub op_latencies: Vec<u64>,
    /// ops per key *rank* (kvmix workloads; empty otherwise) — grows on
    /// demand, powering the contention stats in
    /// [`crate::exp::runner::ExpResult`]
    key_ops: Vec<u64>,
}

pub type Metrics = Rc<RefCell<MetricsHub>>;

/// Cap on retained per-op latency samples. Consumers polling
/// [`MetricsHub::op_latencies`] incrementally (the adapt controller)
/// check against this to distinguish "no ops completed" from "the
/// buffer saturated and sampling stopped".
pub const OP_LATENCY_SAMPLE_CAP: usize = 1_000_000;

impl MetricsHub {
    pub fn new(n_servers: usize, n_clients: usize) -> Metrics {
        Rc::new(RefCell::new(Self {
            window: SEC,
            server_ops: vec![Vec::new(); n_servers],
            app_ops: vec![Vec::new(); n_clients],
            app_ops_recorded: 0,
            app_failures: vec![0; n_clients],
            quorum_timeouts: 0,
            violations: Vec::new(),
            candidates_received: 0,
            active_preds_peak: 0,
            tasks_completed: 0,
            tasks_aborted: 0,
            task_durations: Vec::new(),
            op_latencies: Vec::new(),
            key_ops: Vec::new(),
        }))
    }

    fn bump(series: &mut Vec<u64>, window: Time, t: Time) {
        let idx = (t / window) as usize;
        if series.len() <= idx {
            series.resize(idx + 1, 0);
        }
        series[idx] += 1;
    }

    pub fn record_server(&mut self, server_idx: usize, t: Time) {
        Self::bump(&mut self.server_ops[server_idx], self.window, t);
    }

    pub fn record_app(&mut self, client_idx: usize, t: Time, latency: Time) {
        Self::bump(&mut self.app_ops[client_idx], self.window, t);
        self.app_ops_recorded += 1;
        if self.op_latencies.len() < OP_LATENCY_SAMPLE_CAP {
            self.op_latencies.push(latency);
        }
    }

    pub fn record_app_failure(&mut self, client_idx: usize) {
        self.app_failures[client_idx] += 1;
    }

    /// Count one op against key rank `rank` (kvmix cycles).
    pub fn bump_key(&mut self, rank: usize) {
        if self.key_ops.len() <= rank {
            self.key_ops.resize(rank + 1, 0);
        }
        self.key_ops[rank] += 1;
    }

    /// Ops per key rank (empty unless a keyed workload ran).
    pub fn key_ops(&self) -> &[u64] {
        &self.key_ops
    }

    /// Share of keyed ops landing on the hottest single rank — the
    /// headline contention number (1/n_keys under uniform, → p(rank 0)
    /// under Zipf). 0.0 when no keyed workload ran.
    pub fn hot_key_share(&self) -> f64 {
        let total: u64 = self.key_ops.iter().sum();
        if total == 0 {
            return 0.0;
        }
        *self.key_ops.iter().max().unwrap() as f64 / total as f64
    }

    /// Smallest number of ranks covering fraction `q` of keyed traffic —
    /// a per-key-percentile contention stat ("how few keys absorb 90%
    /// of the load"). 0 when no keyed workload ran.
    pub fn keys_covering(&self, q: f64) -> usize {
        let total: u64 = self.key_ops.iter().sum();
        if total == 0 {
            return 0;
        }
        let mut sorted = self.key_ops.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let target = total as f64 * q;
        let mut acc = 0u64;
        for (i, c) in sorted.iter().enumerate() {
            acc += c;
            if acc as f64 >= target {
                return i + 1;
            }
        }
        sorted.len()
    }

    /// One client's raw per-window op counts — the churn e2e asserts a
    /// departed client's windows are empty while it is gone.
    pub fn client_window_ops(&self, client_idx: usize) -> &[u64] {
        &self.app_ops[client_idx]
    }

    pub fn record_violation(&mut self, rec: ViolationRecord) {
        self.violations.push(rec);
    }

    fn aggregate(series: &[Vec<u64>], window: Time) -> Vec<f64> {
        let len = series.iter().map(|s| s.len()).max().unwrap_or(0);
        let per_sec = SEC as f64 / window as f64;
        (0..len)
            .map(|w| {
                series.iter().map(|s| *s.get(w).unwrap_or(&0)).sum::<u64>() as f64 * per_sec
            })
            .collect()
    }

    /// Aggregated server throughput per window (ops/s) — overhead metric.
    pub fn server_series(&self) -> Vec<f64> {
        Self::aggregate(&self.server_ops, self.window)
    }

    /// Aggregated application throughput per window (ops/s) — benefit metric.
    pub fn app_series(&self) -> Vec<f64> {
        Self::aggregate(&self.app_ops, self.window)
    }

    pub fn total_app_ops(&self) -> u64 {
        self.app_ops_recorded
    }

    pub fn total_server_ops(&self) -> u64 {
        self.server_ops.iter().flat_map(|s| s.iter()).sum()
    }

    /// Client-perspective op latency percentiles in ms (each p in
    /// 0..=100), sharing one sorted copy of the samples. 0.0 entries when
    /// no sample was recorded. This is what makes a pipeline depth sweep
    /// interpretable: deeper pipelines trade per-op latency (queueing in
    /// the client) for wave throughput.
    pub fn op_latency_percentiles_ms(&self, ps: &[f64]) -> Vec<f64> {
        let ms: Vec<f64> = self
            .op_latencies
            .iter()
            .map(|&l| l as f64 / crate::sim::MS as f64)
            .collect();
        // one shared rank convention for every percentile in the crate
        let cdf = crate::util::stats::Cdf::new(ms);
        ps.iter().map(|&p| cdf.quantile(p / 100.0)).collect()
    }

    /// Single-percentile convenience over [`Self::op_latency_percentiles_ms`].
    pub fn op_latency_percentile_ms(&self, p: f64) -> f64 {
        self.op_latency_percentiles_ms(&[p])[0]
    }

    /// Deterministic barrier-time merge of a per-shard hub into this one
    /// (the threaded engine, shards merged in shard order):
    ///
    /// * per-process window series add element-wise — every process is
    ///   hosted by exactly one shard, so for each row only one operand is
    ///   non-zero and the merged counters are bit-exact;
    /// * scalar counters add; `active_preds_peak` takes the max (each
    ///   monitor's peak already lives on one shard, and the global peak
    ///   of disjoint monitor populations is their max);
    /// * sample vectors (`op_latencies`, `task_durations`) concatenate —
    ///   every derived statistic is a multiset function (one shared
    ///   [`crate::util::stats::Cdf`] rank convention), so sample order
    ///   does not matter. `OP_LATENCY_SAMPLE_CAP` becomes per-shard
    ///   under the merge; no workload approaches it;
    /// * violation records concatenate and stable-sort by their
    ///   `(at, seq)` dispatch key, reproducing the exact order a
    ///   merged-order run records them in.
    pub fn merge(&mut self, other: &MetricsHub) {
        assert_eq!(self.window, other.window, "hubs must share a window size");
        assert_eq!(self.server_ops.len(), other.server_ops.len());
        assert_eq!(self.app_ops.len(), other.app_ops.len());
        fn add_rows(dst: &mut [Vec<u64>], src: &[Vec<u64>]) {
            for (d, s) in dst.iter_mut().zip(src) {
                if d.len() < s.len() {
                    d.resize(s.len(), 0);
                }
                for (x, y) in d.iter_mut().zip(s) {
                    *x += y;
                }
            }
        }
        add_rows(&mut self.server_ops, &other.server_ops);
        add_rows(&mut self.app_ops, &other.app_ops);
        self.app_ops_recorded += other.app_ops_recorded;
        for (d, s) in self.app_failures.iter_mut().zip(&other.app_failures) {
            *d += s;
        }
        self.quorum_timeouts += other.quorum_timeouts;
        self.candidates_received += other.candidates_received;
        self.active_preds_peak = self.active_preds_peak.max(other.active_preds_peak);
        self.tasks_completed += other.tasks_completed;
        self.tasks_aborted += other.tasks_aborted;
        self.task_durations.extend_from_slice(&other.task_durations);
        self.op_latencies.extend_from_slice(&other.op_latencies);
        // per-rank counters add element-wise, like the window rows
        if self.key_ops.len() < other.key_ops.len() {
            self.key_ops.resize(other.key_ops.len(), 0);
        }
        for (d, s) in self.key_ops.iter_mut().zip(&other.key_ops) {
            *d += s;
        }
        self.violations.extend_from_slice(&other.violations);
        // stable: entries recorded in one dispatch share a key and must
        // keep their within-shard order
        self.violations.sort_by_key(|v| (v.at, v.seq));
    }
}

/// Mean of the stable phase of a throughput series: drop the first
/// `warmup_frac` of windows (initialization, per Fig. 9) and the final
/// window (partial).
pub fn stable_mean(series: &[f64], warmup_frac: f64) -> f64 {
    if series.len() < 3 {
        return crate::util::stats::mean(series);
    }
    let skip = ((series.len() as f64 * warmup_frac).ceil() as usize).max(1);
    let end = series.len() - 1; // final window may be partial
    if skip >= end {
        return crate::util::stats::mean(series);
    }
    crate::util::stats::mean(&series[skip..end])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    #[test]
    fn windows_aggregate_across_processes() {
        let m = MetricsHub::new(2, 2);
        {
            let mut m = m.borrow_mut();
            m.record_server(0, 100 * MS);
            m.record_server(1, 200 * MS);
            m.record_server(0, 1_500 * MS);
            m.record_app(0, 100 * MS, MS);
            m.record_app(1, 2_500 * MS, 2 * MS);
        }
        let m = m.borrow();
        assert_eq!(m.server_series(), vec![2.0, 1.0]);
        assert_eq!(m.app_series(), vec![1.0, 0.0, 1.0]);
        assert_eq!(m.total_app_ops(), 2);
        assert_eq!(m.total_server_ops(), 3);
    }

    #[test]
    fn latency_percentiles_from_samples() {
        let m = MetricsHub::new(1, 1);
        {
            let mut m = m.borrow_mut();
            for i in 1..=100u64 {
                m.record_app(0, i * MS, i * MS);
            }
        }
        let m = m.borrow();
        let p50 = m.op_latency_percentile_ms(50.0);
        assert!((49.0..=51.0).contains(&p50), "p50={p50}");
        let p99 = m.op_latency_percentile_ms(99.0);
        assert!((98.0..=100.0).contains(&p99), "p99={p99}");
        assert_eq!(MetricsHub::new(1, 1).borrow().op_latency_percentile_ms(50.0), 0.0);
    }

    #[test]
    fn stable_mean_trims_warmup() {
        // warmup ramp then steady 100, then partial last window
        let series = vec![10.0, 50.0, 100.0, 100.0, 100.0, 100.0, 40.0];
        let sm = stable_mean(&series, 0.25);
        assert_eq!(sm, 100.0);
    }

    #[test]
    fn stable_mean_small_series() {
        assert_eq!(stable_mean(&[5.0, 7.0], 0.25), 6.0);
        assert_eq!(stable_mean(&[], 0.25), 0.0);
    }

    fn rec(name: &str, at: Time, seq: u64) -> ViolationRecord {
        ViolationRecord {
            pred: PredId(0),
            name: name.into(),
            t_violate_ms: 123,
            t_occurred_ms: 130,
            detected_at: 456 * MS,
            monitor: 0,
            at,
            seq,
        }
    }

    #[test]
    fn violation_records() {
        let m = MetricsHub::new(1, 1);
        m.borrow_mut().record_violation(rec("me_1_2", 456 * MS, 9));
        assert_eq!(m.borrow().violations.len(), 1);
        let lat = m.borrow().violations[0].detection_latency_ms();
        assert!((lat - 326.0).abs() < 1e-9);
    }

    #[test]
    fn merge_is_elementwise_and_orders_violations_by_dispatch_key() {
        let a = MetricsHub::new(2, 2);
        let b = MetricsHub::new(2, 2);
        {
            // shard a hosts server 0 / client 0; shard b the others
            let mut a = a.borrow_mut();
            a.record_server(0, 100 * MS);
            a.record_app(0, 100 * MS, MS);
            a.record_app_failure(0);
            a.quorum_timeouts = 2;
            a.active_preds_peak = 3;
            a.record_violation(rec("late", 2_000 * MS, 5));
        }
        {
            let mut b = b.borrow_mut();
            b.record_server(1, 2_500 * MS);
            b.record_app(1, 2_500 * MS, 2 * MS);
            b.active_preds_peak = 5;
            b.record_violation(rec("early", 1_000 * MS, 7));
        }
        let mut m = a.borrow().clone();
        m.merge(&b.borrow());
        assert_eq!(m.server_series(), vec![1.0, 0.0, 1.0]);
        assert_eq!(m.app_series(), vec![1.0, 0.0, 1.0]);
        assert_eq!(m.total_app_ops(), 2);
        assert_eq!(m.app_failures, vec![1, 0]);
        assert_eq!(m.quorum_timeouts, 2);
        assert_eq!(m.active_preds_peak, 5, "max of disjoint monitor peaks");
        assert_eq!(m.op_latencies.len(), 2);
        let names: Vec<&str> = m.violations.iter().map(|v| v.name.as_str()).collect();
        assert_eq!(names, vec!["early", "late"], "dispatch-key order, not shard order");
    }

    #[test]
    fn key_ops_count_merge_and_summarize() {
        let a = MetricsHub::new(1, 1);
        {
            let mut a = a.borrow_mut();
            for _ in 0..8 {
                a.bump_key(0);
            }
            a.bump_key(2);
        }
        let b = MetricsHub::new(1, 1);
        {
            let mut b = b.borrow_mut();
            b.bump_key(0);
            // ragged: shard b saw a higher rank than shard a
            b.bump_key(3);
        }
        let mut m = a.borrow().clone();
        m.merge(&b.borrow());
        assert_eq!(m.key_ops(), &[9, 0, 1, 1]);
        assert!((m.hot_key_share() - 9.0 / 11.0).abs() < 1e-12);
        assert_eq!(m.keys_covering(0.8), 1, "rank 0 alone covers 80%");
        assert_eq!(m.keys_covering(1.0), 3, "three ranks carry all traffic");
        assert_eq!(MetricsHub::new(1, 1).borrow().hot_key_share(), 0.0);
        assert_eq!(MetricsHub::new(1, 1).borrow().keys_covering(0.9), 0);
    }
}
