//! The rollback/recovery controller (§IV, Fig. 1): receives violation
//! reports from the monitors and drives one of the paper's recovery
//! strategies:
//!
//! * `NotifyClients` — the cheap path for task-structured apps (coloring):
//!   clients abort and restart their current task; no server state rolls
//!   back because updates were deferred (§VI-B "Discussion").
//! * `FullRestore` — stop-the-world: freeze all servers, restore each to a
//!   cut before `T_violate` (window-log if it reaches back far enough,
//!   periodic snapshot otherwise), resume, and notify clients.
//! * `ResetToClean` — checkpoint-free: one server at a time drops its
//!   owned partitions and re-derives them from preference-list peers
//!   over the `Msg::Sync` path, no freeze (journal version, 1909.01980).
//! * `Stabilize` — no rollback: the violation is recorded and a
//!   self-stabilizing application converges on its own (1808.00822).
//! * `None` — record only (the monitors-as-debugger deployment).
//!
//! The multi-phase strategies are pure state machines behind
//! [`RecoveryStrategy`](crate::rollback::strategy::RecoveryStrategy);
//! this actor owns the transport: it translates emitted
//! [`Action`](crate::rollback::strategy::Action)s into epoch-tagged
//! `RollbackMsg` traffic, filters acks by epoch, and arms one
//! deterministic deadline per ack-collecting phase. `None` and
//! `NotifyClients` stay inline fast paths that schedule no timers, so
//! default configs reproduce pre-strategy schedules bit-for-bit.
//!
//! **Liveness invariant** (the PR-3 wedge, fixed): an ack round must
//! never require a reply from a crashed server. The controller cannot
//! observe crashes directly — fault hooks are delivered only to the
//! affected actor — so each ack-collecting phase arms a deterministic
//! deadline timer. When the deadline fires, the strategy decides on the
//! live quorum: FullRestore proceeds on a majority of owners (the
//! missing servers re-derive their partitions from peers on restart)
//! and aborts below one; ResetToClean simply skips the unresponsive
//! server. Either way the controller can never sit in a recovery phase
//! forever. Stale deadlines are discarded by a per-phase sequence
//! number, so a phase that completed on full acks ignores its own
//! leftover timer.

use crate::metrics::throughput::Metrics;
use crate::rollback::strategy::{
    Ack, Action, FullRestoreStrategy, RecoveryStrategy, ResetToCleanStrategy, StabilizeStrategy,
};
use crate::sim::des::{Actor, Ctx};
use crate::sim::msg::{AdaptMsg, Msg, RollbackMsg};
use crate::sim::{ms, ProcId, Time, MS};
use crate::trace::{TraceEv, TraceRef};

/// High bit tagging controller deadline timers (the low bits carry the
/// phase sequence number, so stale deadlines self-identify).
const DEADLINE_FLAG: u64 = 1 << 62;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryPolicy {
    None,
    NotifyClients,
    FullRestore,
    ResetToClean,
    Stabilize,
}

impl RecoveryPolicy {
    /// Build the strategy state machine for a multi-phase policy.
    /// `None`/`NotifyClients` return no machine — they stay inline
    /// fast paths in the controller.
    fn build(self) -> Option<Box<dyn RecoveryStrategy>> {
        match self {
            RecoveryPolicy::None | RecoveryPolicy::NotifyClients => None,
            RecoveryPolicy::FullRestore => Some(Box::new(FullRestoreStrategy::new())),
            RecoveryPolicy::ResetToClean => Some(Box::new(ResetToCleanStrategy::new())),
            RecoveryPolicy::Stabilize => Some(Box::new(StabilizeStrategy)),
        }
    }
}

pub struct ControllerActor {
    servers: Vec<ProcId>,
    clients: Vec<ProcId>,
    policy: RecoveryPolicy,
    /// the in-flight recovery's strategy machine; `None` means idle
    active: Option<Box<dyn RecoveryStrategy>>,
    epoch: u64,
    /// suppress recoveries closer together than this
    min_gap: Time,
    last_recovery: Time,
    pending_t_violate: i64,
    /// when the current recovery began (stall accounting)
    freeze_started: Time,
    /// how long an ack-collecting phase may wait before the deadline
    /// decides on the live quorum
    ack_deadline: Time,
    /// bumped on every phase entry; deadline timers carry it so a timer
    /// armed for an already-finished phase is discarded as stale
    phase_seq: u64,
    /// a recovery-policy switch requested mid-recovery (by the adapt
    /// controller); applied once the current recovery settles so a
    /// strategy swap can never orphan an in-flight phase
    pending_policy: Option<RecoveryPolicy>,
    /// the adaptive-consistency controller, if one is deployed
    /// ([`crate::adapt`]): every violation report and every finished
    /// recovery is forwarded as a signal sample. `None` (the default)
    /// emits nothing and reproduces the pre-adapt controller exactly.
    adapt: Option<ProcId>,
    metrics: Metrics,
    /// flight recorder handle (`None` = recording off, zero overhead)
    trace: Option<TraceRef>,
    /// stats
    pub violations_received: u64,
    pub recoveries: u64,
    pub window_log_restores: u64,
    pub snapshot_restores: u64,
    /// phases that hit their ack deadline (quorum-advance or abort)
    pub ack_timeouts: u64,
    /// recoveries abandoned because a phase lacked even a live majority
    pub aborted_recoveries: u64,
    /// recoveries that ran to completion (notify-only ones count too)
    pub completed_recoveries: u64,
    /// summed stall time over completed recoveries (ms) — time-to-recover
    pub recovery_ms_total: f64,
}

impl ControllerActor {
    pub fn new(
        servers: Vec<ProcId>,
        clients: Vec<ProcId>,
        policy: RecoveryPolicy,
        metrics: Metrics,
    ) -> Self {
        Self {
            servers,
            clients,
            policy,
            active: None,
            epoch: 0,
            min_gap: ms(1_000.0),
            last_recovery: 0,
            pending_t_violate: 0,
            freeze_started: 0,
            ack_deadline: ms(1_000.0),
            phase_seq: 0,
            pending_policy: None,
            adapt: None,
            metrics,
            trace: None,
            violations_received: 0,
            recoveries: 0,
            window_log_restores: 0,
            snapshot_restores: 0,
            ack_timeouts: 0,
            aborted_recoveries: 0,
            completed_recoveries: 0,
            recovery_ms_total: 0.0,
        }
    }

    /// Wire the adaptive-consistency controller as a signal sink.
    pub fn with_adapt(mut self, adapt: Option<ProcId>) -> Self {
        self.adapt = adapt;
        self
    }

    /// Attach the flight recorder ([`crate::trace`]).
    pub fn with_trace(mut self, trace: TraceRef) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Record one recovery-phase transition on this actor's ring.
    fn trace_phase(&self, ctx: &mut Ctx, phase: &'static str) {
        if let Some(tr) = &self.trace {
            tr.borrow_mut().record(
                ctx.self_id,
                ctx.now(),
                ctx.event_seq(),
                TraceEv::RecoveryPhase { epoch: self.epoch, phase },
            );
        }
    }

    fn notify_clients(&mut self, ctx: &mut Ctx, t_violate_ms: i64) {
        for &c in &self.clients {
            ctx.send(c, Msg::Rollback(RollbackMsg::Notify { epoch: self.epoch, t_violate_ms }));
        }
    }

    fn begin_recovery(&mut self, ctx: &mut Ctx, t_violate_ms: i64) {
        self.epoch += 1;
        self.recoveries += 1;
        self.last_recovery = ctx.now();
        if self.policy != RecoveryPolicy::None {
            self.trace_phase(ctx, "begin");
        }
        match self.policy {
            RecoveryPolicy::None => {}
            RecoveryPolicy::NotifyClients => {
                self.trace_phase(ctx, "notify");
                self.notify_clients(ctx, t_violate_ms);
                // notify-only recovery never freezes the servers: the
                // stall sample is 0, but the adapt controller still sees
                // that a recovery happened
                self.completed_recoveries += 1;
                self.trace_phase(ctx, "done");
                if let Some(a) = self.adapt {
                    ctx.send(a, Msg::Adapt(AdaptMsg::RecoveryDone { stall_ms: 0.0 }));
                }
            }
            policy => {
                let mut strat = policy.build().expect("multi-phase policy");
                self.pending_t_violate = t_violate_ms;
                self.freeze_started = ctx.now();
                let actions = strat.begin(self.servers.len());
                self.active = Some(strat);
                self.apply(ctx, actions);
            }
        }
    }

    /// Arm the deadline for the phase just entered. Only ack-collecting
    /// phases call this, so `None`/`NotifyClients` runs schedule no
    /// timer events at all (they reproduce the pre-deadline schedules
    /// bit-for-bit).
    fn arm_deadline(&mut self, ctx: &mut Ctx) {
        self.phase_seq += 1;
        ctx.schedule(self.ack_deadline, DEADLINE_FLAG | self.phase_seq);
    }

    /// Forward an epoch-valid server ack to the active strategy and
    /// carry out whatever it decides.
    fn ack(&mut self, ctx: &mut Ctx, ack: Ack) {
        if let Some(strat) = self.active.as_mut() {
            let actions = strat.on_server_ack(ack);
            self.apply(ctx, actions);
        }
    }

    /// Execute a strategy's emitted actions in order. This is the only
    /// place strategy decisions turn into wire traffic, so every
    /// strategy inherits the same epoch tagging and deadline handling.
    fn apply(&mut self, ctx: &mut Ctx, actions: Vec<Action>) {
        for a in actions {
            match a {
                Action::Freeze => {
                    self.trace_phase(ctx, "freeze");
                    for &s in &self.servers {
                        ctx.send(s, Msg::Rollback(RollbackMsg::Freeze { epoch: self.epoch }));
                    }
                    self.arm_deadline(ctx);
                }
                Action::Restore => {
                    self.trace_phase(ctx, "restore");
                    // restore to just before the violation started
                    let to_ms = self.pending_t_violate - 1;
                    for &s in &self.servers {
                        ctx.send(
                            s,
                            Msg::Rollback(RollbackMsg::Restore { epoch: self.epoch, to_ms }),
                        );
                    }
                    self.arm_deadline(ctx);
                }
                Action::Resume => {
                    self.trace_phase(ctx, "resume");
                    for &s in &self.servers {
                        ctx.send(s, Msg::Rollback(RollbackMsg::Resume { epoch: self.epoch }));
                    }
                }
                Action::Reset { server } => {
                    self.trace_phase(ctx, "reset");
                    let s = self.servers[server];
                    ctx.send(s, Msg::Rollback(RollbackMsg::Reset { epoch: self.epoch }));
                    self.arm_deadline(ctx);
                }
                Action::NotifyClients => {
                    self.trace_phase(ctx, "notify");
                    let t = self.pending_t_violate;
                    self.notify_clients(ctx, t);
                }
                Action::Done => {
                    self.trace_phase(ctx, "done");
                    self.active = None;
                    self.phase_seq += 1; // invalidate any in-flight deadline
                    let stall_ms = (ctx.now() - self.freeze_started) as f64 / MS as f64;
                    self.completed_recoveries += 1;
                    self.recovery_ms_total += stall_ms;
                    self.apply_pending_policy();
                    if let Some(adapt) = self.adapt {
                        // how long the cluster sat degraded for this
                        // recovery — the rollback-cost signal
                        ctx.send(adapt, Msg::Adapt(AdaptMsg::RecoveryDone { stall_ms }));
                    }
                }
                Action::Abort => {
                    self.trace_phase(ctx, "abort");
                    self.active = None;
                    self.phase_seq += 1;
                    self.aborted_recoveries += 1;
                    self.apply_pending_policy();
                    let stall_ms = (ctx.now() - self.freeze_started) as f64 / MS as f64;
                    if let Some(adapt) = self.adapt {
                        ctx.send(adapt, Msg::Adapt(AdaptMsg::RecoveryDone { stall_ms }));
                    }
                }
            }
        }
    }

    /// A deferred policy switch lands only between recoveries.
    fn apply_pending_policy(&mut self) {
        if let Some(p) = self.pending_policy.take() {
            self.policy = p;
        }
    }

    /// Switch the recovery policy. Takes effect immediately when idle;
    /// mid-recovery it is deferred until the current attempt settles so
    /// a swap can never orphan an in-flight ack phase.
    pub fn set_policy(&mut self, policy: RecoveryPolicy) {
        if self.active.is_some() {
            self.pending_policy = Some(policy);
        } else {
            self.policy = policy;
        }
    }
}

impl Actor for ControllerActor {
    fn on_msg(&mut self, ctx: &mut Ctx, _from: ProcId, msg: Msg) {
        match msg {
            Msg::Violation(rep) => {
                self.violations_received += 1;
                let _ = &self.metrics; // violation metrics recorded by monitors
                if let Some(a) = self.adapt {
                    // forward every report (even ones suppressed below) —
                    // the violation *rate* is the adapt signal, not the
                    // recovery rate. The latency sample uses the monitor's
                    // detection instant, matching
                    // `ViolationRecord::detection_latency_ms` — not this
                    // actor's receipt time, which would add the Violation
                    // message's transit delay
                    let detection_ms =
                        (rep.detected_at / MS) as f64 - rep.t_occurred_ms as f64;
                    ctx.send(a, Msg::Adapt(AdaptMsg::ViolationSeen { detection_ms }));
                }
                let busy = self.active.is_some();
                let too_soon = ctx.now() < self.last_recovery + self.min_gap && self.recoveries > 0;
                if self.policy != RecoveryPolicy::None && !busy && !too_soon {
                    self.begin_recovery(ctx, rep.t_violate_ms);
                }
            }
            Msg::Rollback(RollbackMsg::FrozenAck { epoch }) if epoch == self.epoch => {
                self.ack(ctx, Ack::Frozen);
            }
            Msg::Rollback(RollbackMsg::RestoredAck { epoch, from_window_log })
                if epoch == self.epoch =>
            {
                if from_window_log {
                    self.window_log_restores += 1;
                } else {
                    self.snapshot_restores += 1;
                }
                self.ack(ctx, Ack::Restored);
            }
            Msg::Rollback(RollbackMsg::ResetAck { epoch }) if epoch == self.epoch => {
                self.ack(ctx, Ack::Reset);
            }
            Msg::Adapt(AdaptMsg::SetRecovery { policy }) => {
                self.set_policy(policy);
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, tag: u64) {
        if tag & DEADLINE_FLAG == 0 || (tag & !DEADLINE_FLAG) != self.phase_seq {
            return; // not ours, or a stale deadline of a finished phase
        }
        if let Some(strat) = self.active.as_mut() {
            // a deadline in an ack phase means at least one owner never
            // answered — count it, then let the strategy decide on the
            // quorum it did collect
            self.ack_timeouts += 1;
            let actions = strat.on_deadline();
            self.apply(ctx, actions);
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}
