//! The rollback/recovery controller (§IV, Fig. 1): receives violation
//! reports from the monitors and drives one of the paper's recovery
//! strategies:
//!
//! * `NotifyClients` — the cheap path for task-structured apps (coloring):
//!   clients abort and restart their current task; no server state rolls
//!   back because updates were deferred (§VI-B "Discussion").
//! * `FullRestore` — stop-the-world: freeze all servers, restore each to a
//!   cut before `T_violate` (window-log if it reaches back far enough,
//!   periodic snapshot otherwise), resume, and notify clients.
//! * `None` — record only (the monitors-as-debugger deployment).
//!
//! **Liveness invariant** (the PR-3 wedge, fixed): a freeze/restore ack
//! round must never require a reply from a crashed server. The
//! controller cannot observe crashes directly — fault hooks are
//! delivered only to the affected actor — so each ack-collecting phase
//! arms a deterministic deadline timer. When the deadline fires with a
//! *majority* of owners acked, the phase proceeds on that live quorum
//! (the missing servers re-derive their partitions from peers on
//! restart via the `Msg::Sync` path); below a majority the recovery
//! aborts — servers are resumed, the state machine returns to `Idle`,
//! and the next violation report re-queues a fresh attempt. Either way
//! the controller can never sit in `Freezing`/`Restoring` forever.
//! Stale deadlines are discarded by a per-phase sequence number, so a
//! phase that completed on full acks ignores its own leftover timer.

use crate::metrics::throughput::Metrics;
use crate::sim::des::{Actor, Ctx};
use crate::sim::msg::{AdaptMsg, Msg, RollbackMsg};
use crate::sim::{ms, ProcId, Time, MS};

/// High bit tagging controller deadline timers (the low bits carry the
/// phase sequence number, so stale deadlines self-identify).
const DEADLINE_FLAG: u64 = 1 << 62;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryPolicy {
    None,
    NotifyClients,
    FullRestore,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Idle,
    Freezing { acks: usize },
    Restoring { acks: usize },
}

pub struct ControllerActor {
    servers: Vec<ProcId>,
    clients: Vec<ProcId>,
    policy: RecoveryPolicy,
    state: State,
    epoch: u64,
    /// suppress recoveries closer together than this
    min_gap: Time,
    last_recovery: Time,
    pending_t_violate: i64,
    /// when the current FullRestore freeze began (stall accounting)
    freeze_started: Time,
    /// how long an ack-collecting phase may wait before the deadline
    /// decides on the live quorum
    ack_deadline: Time,
    /// bumped on every phase entry; deadline timers carry it so a timer
    /// armed for an already-finished phase is discarded as stale
    phase_seq: u64,
    /// the adaptive-consistency controller, if one is deployed
    /// ([`crate::adapt`]): every violation report and every finished
    /// recovery is forwarded as a signal sample. `None` (the default)
    /// emits nothing and reproduces the pre-adapt controller exactly.
    adapt: Option<ProcId>,
    metrics: Metrics,
    /// stats
    pub violations_received: u64,
    pub recoveries: u64,
    pub window_log_restores: u64,
    pub snapshot_restores: u64,
    /// phases that hit their ack deadline (quorum-advance or abort)
    pub ack_timeouts: u64,
    /// recoveries abandoned because a phase lacked even a live majority
    pub aborted_recoveries: u64,
    /// recoveries that ran to completion (notify-only ones count too)
    pub completed_recoveries: u64,
    /// summed stall time over completed recoveries (ms) — time-to-recover
    pub recovery_ms_total: f64,
}

impl ControllerActor {
    pub fn new(
        servers: Vec<ProcId>,
        clients: Vec<ProcId>,
        policy: RecoveryPolicy,
        metrics: Metrics,
    ) -> Self {
        Self {
            servers,
            clients,
            policy,
            state: State::Idle,
            epoch: 0,
            min_gap: ms(1_000.0),
            last_recovery: 0,
            pending_t_violate: 0,
            freeze_started: 0,
            ack_deadline: ms(1_000.0),
            phase_seq: 0,
            adapt: None,
            metrics,
            violations_received: 0,
            recoveries: 0,
            window_log_restores: 0,
            snapshot_restores: 0,
            ack_timeouts: 0,
            aborted_recoveries: 0,
            completed_recoveries: 0,
            recovery_ms_total: 0.0,
        }
    }

    /// Wire the adaptive-consistency controller as a signal sink.
    pub fn with_adapt(mut self, adapt: Option<ProcId>) -> Self {
        self.adapt = adapt;
        self
    }

    fn notify_clients(&mut self, ctx: &mut Ctx, t_violate_ms: i64) {
        for &c in &self.clients {
            ctx.send(c, Msg::Rollback(RollbackMsg::Notify { epoch: self.epoch, t_violate_ms }));
        }
    }

    fn begin_recovery(&mut self, ctx: &mut Ctx, t_violate_ms: i64) {
        self.epoch += 1;
        self.recoveries += 1;
        self.last_recovery = ctx.now();
        match self.policy {
            RecoveryPolicy::None => {}
            RecoveryPolicy::NotifyClients => {
                self.notify_clients(ctx, t_violate_ms);
                // notify-only recovery never freezes the servers: the
                // stall sample is 0, but the adapt controller still sees
                // that a recovery happened
                self.completed_recoveries += 1;
                if let Some(a) = self.adapt {
                    ctx.send(a, Msg::Adapt(AdaptMsg::RecoveryDone { stall_ms: 0.0 }));
                }
            }
            RecoveryPolicy::FullRestore => {
                self.state = State::Freezing { acks: 0 };
                self.pending_t_violate = t_violate_ms;
                self.freeze_started = ctx.now();
                for &s in &self.servers {
                    ctx.send(s, Msg::Rollback(RollbackMsg::Freeze { epoch: self.epoch }));
                }
                self.arm_deadline(ctx);
            }
        }
    }

    /// The smallest ack count an ack-collecting phase may proceed on
    /// when its deadline fires.
    fn majority(&self) -> usize {
        self.servers.len() / 2 + 1
    }

    /// Arm the deadline for the phase just entered. Only ack-collecting
    /// phases call this, so `None`/`NotifyClients` runs schedule no
    /// timer events at all (they reproduce the pre-deadline schedules
    /// bit-for-bit).
    fn arm_deadline(&mut self, ctx: &mut Ctx) {
        self.phase_seq += 1;
        ctx.schedule(self.ack_deadline, DEADLINE_FLAG | self.phase_seq);
    }

    /// Freeze phase settled (full acks or live quorum at the deadline):
    /// broadcast the restore cut and start collecting restore acks.
    fn enter_restoring(&mut self, ctx: &mut Ctx) {
        self.state = State::Restoring { acks: 0 };
        // restore to just before the violation started
        let to_ms = self.pending_t_violate - 1;
        for &s in &self.servers {
            ctx.send(s, Msg::Rollback(RollbackMsg::Restore { epoch: self.epoch, to_ms }));
        }
        self.arm_deadline(ctx);
    }

    /// Restore phase settled: resume the cluster, notify clients, and
    /// report the stall to the adapt controller.
    fn finish_restore(&mut self, ctx: &mut Ctx) {
        self.state = State::Idle;
        self.phase_seq += 1; // invalidate any in-flight deadline
        for &s in &self.servers {
            ctx.send(s, Msg::Rollback(RollbackMsg::Resume { epoch: self.epoch }));
        }
        let t = self.pending_t_violate;
        self.notify_clients(ctx, t);
        let stall_ms = (ctx.now() - self.freeze_started) as f64 / MS as f64;
        self.completed_recoveries += 1;
        self.recovery_ms_total += stall_ms;
        if let Some(a) = self.adapt {
            // how long the cluster sat frozen for this restore — the
            // rollback-cost signal
            ctx.send(a, Msg::Adapt(AdaptMsg::RecoveryDone { stall_ms }));
        }
    }

    /// A phase deadline fired without even a live majority: unwedge by
    /// resuming whoever did freeze and returning to `Idle`. The next
    /// violation report re-queues a fresh recovery attempt.
    fn abort_recovery(&mut self, ctx: &mut Ctx) {
        self.state = State::Idle;
        self.phase_seq += 1;
        self.aborted_recoveries += 1;
        for &s in &self.servers {
            ctx.send(s, Msg::Rollback(RollbackMsg::Resume { epoch: self.epoch }));
        }
        let stall_ms = (ctx.now() - self.freeze_started) as f64 / MS as f64;
        if let Some(a) = self.adapt {
            ctx.send(a, Msg::Adapt(AdaptMsg::RecoveryDone { stall_ms }));
        }
    }
}

impl Actor for ControllerActor {
    fn on_msg(&mut self, ctx: &mut Ctx, _from: ProcId, msg: Msg) {
        match msg {
            Msg::Violation(rep) => {
                self.violations_received += 1;
                let _ = &self.metrics; // violation metrics recorded by monitors
                if let Some(a) = self.adapt {
                    // forward every report (even ones suppressed below) —
                    // the violation *rate* is the adapt signal, not the
                    // recovery rate. The latency sample uses the monitor's
                    // detection instant, matching
                    // `ViolationRecord::detection_latency_ms` — not this
                    // actor's receipt time, which would add the Violation
                    // message's transit delay
                    let detection_ms =
                        (rep.detected_at / MS) as f64 - rep.t_occurred_ms as f64;
                    ctx.send(a, Msg::Adapt(AdaptMsg::ViolationSeen { detection_ms }));
                }
                let busy = self.state != State::Idle;
                let too_soon = ctx.now() < self.last_recovery + self.min_gap && self.recoveries > 0;
                if self.policy != RecoveryPolicy::None && !busy && !too_soon {
                    self.begin_recovery(ctx, rep.t_violate_ms);
                }
            }
            Msg::Rollback(RollbackMsg::FrozenAck { epoch }) if epoch == self.epoch => {
                if let State::Freezing { acks } = self.state {
                    let acks = acks + 1;
                    if acks == self.servers.len() {
                        self.enter_restoring(ctx);
                    } else {
                        self.state = State::Freezing { acks };
                    }
                }
            }
            Msg::Rollback(RollbackMsg::RestoredAck { epoch, from_window_log }) if epoch == self.epoch => {
                if from_window_log {
                    self.window_log_restores += 1;
                } else {
                    self.snapshot_restores += 1;
                }
                if let State::Restoring { acks } = self.state {
                    let acks = acks + 1;
                    if acks == self.servers.len() {
                        self.finish_restore(ctx);
                    } else {
                        self.state = State::Restoring { acks };
                    }
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, tag: u64) {
        if tag & DEADLINE_FLAG == 0 || (tag & !DEADLINE_FLAG) != self.phase_seq {
            return; // not ours, or a stale deadline of a finished phase
        }
        match self.state {
            State::Idle => {}
            State::Freezing { acks } => {
                // a deadline in an ack phase means at least one owner
                // never answered — count it, then decide on the quorum
                self.ack_timeouts += 1;
                if acks >= self.majority() {
                    self.enter_restoring(ctx);
                } else {
                    self.abort_recovery(ctx);
                }
            }
            State::Restoring { acks } => {
                self.ack_timeouts += 1;
                if acks >= self.majority() {
                    self.finish_restore(ctx);
                } else {
                    self.abort_recovery(ctx);
                }
            }
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}
