//! Retroscope-style window log (§IV, [11]): each server keeps a bounded
//! ring of recent state changes tagged with physical (HVC-self) time so it
//! can reconstruct its state at any cut within the window on demand —
//! without stopping the world to take a snapshot first.
//!
//! Rolling back to `T` = undoing, newest-first, every logged change whose
//! timestamp is `> T` by restoring the pre-change sibling list.

use std::collections::VecDeque;

use crate::clock::hvc::Millis;
use crate::store::table::Table;
use crate::store::value::{KeyId, Versioned};

#[derive(Debug, Clone)]
pub struct LogEntry {
    /// server physical time of the change (ms)
    pub at_ms: Millis,
    pub key: KeyId,
    /// sibling list *before* the change
    pub prev: Vec<Versioned>,
}

#[derive(Debug, Clone)]
pub struct WindowLog {
    entries: VecDeque<LogEntry>,
    /// retention window (ms); Retroscope demonstrates ~10 minutes
    window_ms: Millis,
    /// hard cap on entries (memory bound)
    max_entries: usize,
    appended: u64,
    /// newest timestamp ever evicted by trimming: cuts at/after this are
    /// still reconstructible, older cuts are not
    trim_high: Option<Millis>,
}

impl WindowLog {
    pub fn new(window_ms: Millis, max_entries: usize) -> Self {
        Self { entries: VecDeque::new(), window_ms, max_entries, appended: 0, trim_high: None }
    }

    /// Record a change that just happened at `at_ms`.
    pub fn append(&mut self, at_ms: Millis, key: KeyId, prev: Vec<Versioned>) {
        self.entries.push_back(LogEntry { at_ms, key, prev });
        self.appended += 1;
        self.trim(at_ms);
    }

    fn trim(&mut self, now_ms: Millis) {
        let horizon = now_ms - self.window_ms;
        while let Some(front) = self.entries.front() {
            if front.at_ms < horizon || self.entries.len() > self.max_entries {
                let e = self.entries.pop_front().unwrap();
                self.trim_high = Some(self.trim_high.map_or(e.at_ms, |h| h.max(e.at_ms)));
            } else {
                break;
            }
        }
    }

    /// Does the log reach back to `to_ms`, i.e. can undoing logged changes
    /// reconstruct the state at that cut? False once changes newer than
    /// `to_ms` have been evicted.
    pub fn covers(&self, to_ms: Millis) -> bool {
        self.trim_high.map_or(true, |h| to_ms >= h)
    }

    /// Roll `table` back to its state at time `to_ms` by undoing newer
    /// changes, newest first. Returns the number of changes undone.
    ///
    /// Note: entries for the same key must be undone newest→oldest so the
    /// oldest `prev` (the state at the cut) wins.
    pub fn rollback(&mut self, table: &mut Table, to_ms: Millis) -> usize {
        let mut undone = 0;
        while let Some(back) = self.entries.back() {
            if back.at_ms <= to_ms {
                break;
            }
            let e = self.entries.pop_back().unwrap();
            table.restore_key(e.key, e.prev);
            undone += 1;
        }
        undone
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn appended(&self) -> u64 {
        self.appended
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::vc::VectorClock;
    use crate::store::value::Value;

    fn vc(n: u64) -> VectorClock {
        let mut v = VectorClock::new();
        for _ in 0..n {
            v.increment(1);
        }
        v
    }

    fn put_logged(t: &mut Table, log: &mut WindowLog, at: Millis, key: KeyId, n: u64, val: i64) {
        let (prev, changed) = t.put(key, vc(n), Value::Int(val));
        if changed {
            log.append(at, key, prev);
        }
    }

    #[test]
    fn rollback_restores_cut_state() {
        let mut t = Table::new();
        let mut log = WindowLog::new(600_000, 100_000);
        let k = KeyId(1);
        put_logged(&mut t, &mut log, 100, k, 1, 10);
        put_logged(&mut t, &mut log, 200, k, 2, 20);
        put_logged(&mut t, &mut log, 300, k, 3, 30);
        assert_eq!(t.get(k)[0].value, Value::Int(30));
        let undone = log.rollback(&mut t, 250);
        assert_eq!(undone, 1);
        assert_eq!(t.get(k)[0].value, Value::Int(20));
        let undone = log.rollback(&mut t, 50);
        assert_eq!(undone, 2);
        assert!(t.get(k).is_empty(), "rolled back before the first write");
    }

    #[test]
    fn multi_key_rollback_order() {
        let mut t = Table::new();
        let mut log = WindowLog::new(600_000, 100_000);
        put_logged(&mut t, &mut log, 100, KeyId(1), 1, 1);
        put_logged(&mut t, &mut log, 150, KeyId(2), 1, 2);
        put_logged(&mut t, &mut log, 200, KeyId(1), 2, 11);
        log.rollback(&mut t, 120);
        assert_eq!(t.get(KeyId(1))[0].value, Value::Int(1));
        assert!(t.get(KeyId(2)).is_empty());
    }

    #[test]
    fn window_trimming_bounds_memory() {
        let mut log = WindowLog::new(1_000, 10);
        for i in 0..100 {
            log.append(i * 10, KeyId(0), vec![]);
        }
        assert!(log.len() <= 10, "max_entries respected, len={}", log.len());
        assert_eq!(log.appended(), 100);
        // time-based trim: everything older than now-1000ms evicted
        log.append(10_000, KeyId(0), vec![]);
        assert_eq!(log.len(), 1);
    }

    #[test]
    fn covers_reports_reachability() {
        let mut log = WindowLog::new(1_000, 1000);
        assert!(log.covers(0), "empty log covers trivially");
        log.append(500, KeyId(0), vec![]);
        log.append(900, KeyId(0), vec![]);
        assert!(log.covers(500));
        assert!(log.covers(400) || !log.covers(400)); // well-defined either way
        log.append(5_000, KeyId(0), vec![]); // trims old entries
        assert!(!log.covers(400), "cut older than the window is not covered");
    }

    #[test]
    fn rollback_idempotent_at_cut() {
        let mut t = Table::new();
        let mut log = WindowLog::new(600_000, 1000);
        put_logged(&mut t, &mut log, 100, KeyId(1), 1, 5);
        log.rollback(&mut t, 200);
        let before = t.get(KeyId(1)).to_vec();
        log.rollback(&mut t, 200);
        assert_eq!(t.get(KeyId(1)), &before[..], "second rollback is a no-op");
    }
}
