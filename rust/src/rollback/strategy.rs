//! Pluggable recovery strategies (§IV + the journal version's recovery
//! matrix, 1909.01980): what the controller *does* about a confirmed
//! violation is a small, pure state machine behind [`RecoveryStrategy`].
//! The [`ControllerActor`](crate::rollback::recovery::ControllerActor)
//! owns the transport — it broadcasts the messages an [`Action`] names,
//! tallies acks per recovery epoch, and arms one deterministic deadline
//! per ack-collecting phase — while the strategy decides how phases
//! chain and what a quorum means. Three strategies ship:
//!
//! * [`FullRestoreStrategy`] — stop-the-world: freeze every owner,
//!   restore each to a cut before `T_violate`, resume. A phase deadline
//!   proceeds on a live majority (crashed owners re-derive state from
//!   peers on restart) or aborts below one, so a crash mid-freeze can
//!   never wedge the controller.
//! * [`ResetToCleanStrategy`] — checkpoint-free: one server at a time
//!   drops its owned partitions and re-derives them from its
//!   preference-list peers over the crash-recovery `Msg::Sync` path.
//!   No freeze — the cluster keeps serving around the resetting
//!   replica; an unresponsive server is skipped at the deadline.
//! * [`StabilizeStrategy`] — no rollback at all (Nguyen et al.,
//!   1808.00822): the violation is recorded and the recovery completes
//!   immediately; a self-stabilizing application (the `stabilize`
//!   coloring variant) converges on its own.
//!
//! Strategies are deliberately sans-IO: every transition is a plain
//! function from an event to a list of [`Action`]s, unit-tested below
//! without a simulator.

/// A server acknowledgement, already epoch-filtered by the controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ack {
    Frozen,
    Restored,
    Reset,
}

/// What the controller should do next. Emitted in order; `Freeze`,
/// `Restore` and `Reset` open a new ack-collecting phase (the
/// controller arms a fresh deadline), `Done`/`Abort` close the recovery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// broadcast `Freeze` to every server
    Freeze,
    /// broadcast `Restore { to_ms: T_violate − 1 }` to every server
    Restore,
    /// broadcast `Resume` to every server
    Resume,
    /// send `Reset` to server `server` (index into the owner list)
    Reset { server: usize },
    /// send the rollback `Notify` to every client
    NotifyClients,
    /// the recovery ran to completion
    Done,
    /// the recovery could not proceed (no live quorum); requeue on the
    /// next violation report
    Abort,
}

/// A recovery's decision logic: which phases run, in what order, and
/// what happens when acks arrive or a phase deadline fires. One
/// instance lives per recovery attempt and is dropped on `Done`/`Abort`.
pub trait RecoveryStrategy {
    fn name(&self) -> &'static str;
    /// Start the recovery over `n_servers` owners.
    fn begin(&mut self, n_servers: usize) -> Vec<Action>;
    /// A server acked the current phase (epoch-filtered upstream).
    fn on_server_ack(&mut self, ack: Ack) -> Vec<Action>;
    /// The current phase's deadline fired with acks still missing.
    fn on_deadline(&mut self) -> Vec<Action>;
}

/// Stop-the-world freeze → restore → resume, proceeding on a live
/// majority at each phase deadline.
pub struct FullRestoreStrategy {
    n: usize,
    phase: FrPhase,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FrPhase {
    Freezing { acks: usize },
    Restoring { acks: usize },
    Closed,
}

impl FullRestoreStrategy {
    pub fn new() -> Self {
        Self { n: 0, phase: FrPhase::Closed }
    }

    fn majority(&self) -> usize {
        self.n / 2 + 1
    }

    fn finish(&mut self) -> Vec<Action> {
        self.phase = FrPhase::Closed;
        vec![Action::Resume, Action::NotifyClients, Action::Done]
    }
}

impl RecoveryStrategy for FullRestoreStrategy {
    fn name(&self) -> &'static str {
        "full-restore"
    }

    fn begin(&mut self, n_servers: usize) -> Vec<Action> {
        self.n = n_servers;
        self.phase = FrPhase::Freezing { acks: 0 };
        vec![Action::Freeze]
    }

    fn on_server_ack(&mut self, ack: Ack) -> Vec<Action> {
        match (self.phase, ack) {
            (FrPhase::Freezing { acks }, Ack::Frozen) => {
                let acks = acks + 1;
                if acks == self.n {
                    self.phase = FrPhase::Restoring { acks: 0 };
                    vec![Action::Restore]
                } else {
                    self.phase = FrPhase::Freezing { acks };
                    Vec::new()
                }
            }
            (FrPhase::Restoring { acks }, Ack::Restored) => {
                let acks = acks + 1;
                if acks == self.n {
                    self.finish()
                } else {
                    self.phase = FrPhase::Restoring { acks };
                    Vec::new()
                }
            }
            // a late ack for a phase already left behind
            _ => Vec::new(),
        }
    }

    fn on_deadline(&mut self) -> Vec<Action> {
        match self.phase {
            FrPhase::Freezing { acks } => {
                if acks >= self.majority() {
                    // proceed on the live quorum; the silent owners
                    // re-derive their partitions from peers on restart
                    self.phase = FrPhase::Restoring { acks: 0 };
                    vec![Action::Restore]
                } else {
                    self.phase = FrPhase::Closed;
                    vec![Action::Resume, Action::Abort]
                }
            }
            FrPhase::Restoring { acks } => {
                if acks >= self.majority() {
                    self.finish()
                } else {
                    self.phase = FrPhase::Closed;
                    vec![Action::Resume, Action::Abort]
                }
            }
            FrPhase::Closed => Vec::new(),
        }
    }
}

/// Checkpoint-free rolling reset: servers re-derive their owned
/// partitions from preference-list peers, one at a time so the quorum
/// keeps serving throughout. An owner that never acks (crashed) is
/// skipped at its deadline — its restart path runs the same
/// re-derivation anyway.
pub struct ResetToCleanStrategy {
    n: usize,
    next: usize,
    done: bool,
}

impl ResetToCleanStrategy {
    pub fn new() -> Self {
        Self { n: 0, next: 0, done: true }
    }

    fn advance(&mut self) -> Vec<Action> {
        self.next += 1;
        if self.next >= self.n {
            self.done = true;
            vec![Action::NotifyClients, Action::Done]
        } else {
            vec![Action::Reset { server: self.next }]
        }
    }
}

impl RecoveryStrategy for ResetToCleanStrategy {
    fn name(&self) -> &'static str {
        "reset-to-clean"
    }

    fn begin(&mut self, n_servers: usize) -> Vec<Action> {
        self.n = n_servers;
        self.next = 0;
        self.done = false;
        vec![Action::Reset { server: 0 }]
    }

    fn on_server_ack(&mut self, ack: Ack) -> Vec<Action> {
        if self.done || ack != Ack::Reset {
            return Vec::new();
        }
        self.advance()
    }

    fn on_deadline(&mut self) -> Vec<Action> {
        if self.done {
            return Vec::new();
        }
        // the server under reset never answered: skip it and move on
        self.advance()
    }
}

/// No rollback: record the violation, complete immediately, and let the
/// self-stabilizing application converge on its own.
pub struct StabilizeStrategy;

impl RecoveryStrategy for StabilizeStrategy {
    fn name(&self) -> &'static str {
        "stabilize"
    }

    fn begin(&mut self, _n_servers: usize) -> Vec<Action> {
        vec![Action::Done]
    }

    fn on_server_ack(&mut self, _ack: Ack) -> Vec<Action> {
        Vec::new()
    }

    fn on_deadline(&mut self) -> Vec<Action> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_restore_happy_path_chains_phases() {
        let mut s = FullRestoreStrategy::new();
        assert_eq!(s.begin(3), vec![Action::Freeze]);
        assert!(s.on_server_ack(Ack::Frozen).is_empty());
        assert!(s.on_server_ack(Ack::Frozen).is_empty());
        assert_eq!(s.on_server_ack(Ack::Frozen), vec![Action::Restore]);
        assert!(s.on_server_ack(Ack::Restored).is_empty());
        assert!(s.on_server_ack(Ack::Restored).is_empty());
        assert_eq!(
            s.on_server_ack(Ack::Restored),
            vec![Action::Resume, Action::NotifyClients, Action::Done]
        );
        // anything after Done is inert
        assert!(s.on_server_ack(Ack::Restored).is_empty());
        assert!(s.on_deadline().is_empty());
    }

    #[test]
    fn full_restore_deadline_proceeds_on_live_majority() {
        // 3 owners, one crashed: 2 freeze acks ≥ majority(2) → restore
        let mut s = FullRestoreStrategy::new();
        s.begin(3);
        s.on_server_ack(Ack::Frozen);
        s.on_server_ack(Ack::Frozen);
        assert_eq!(s.on_deadline(), vec![Action::Restore]);
        // restore acks from the two live owners, deadline again
        s.on_server_ack(Ack::Restored);
        s.on_server_ack(Ack::Restored);
        assert_eq!(
            s.on_deadline(),
            vec![Action::Resume, Action::NotifyClients, Action::Done]
        );
    }

    #[test]
    fn full_restore_aborts_below_majority() {
        let mut s = FullRestoreStrategy::new();
        s.begin(3);
        s.on_server_ack(Ack::Frozen); // 1 < majority(2)
        assert_eq!(s.on_deadline(), vec![Action::Resume, Action::Abort]);
        assert!(s.on_deadline().is_empty(), "closed after abort");
    }

    #[test]
    fn full_restore_ignores_mismatched_acks() {
        let mut s = FullRestoreStrategy::new();
        s.begin(2);
        // a stray Restored ack while still freezing changes nothing
        assert!(s.on_server_ack(Ack::Restored).is_empty());
        s.on_server_ack(Ack::Frozen);
        assert_eq!(s.on_server_ack(Ack::Frozen), vec![Action::Restore]);
    }

    #[test]
    fn reset_to_clean_rolls_through_every_server() {
        let mut s = ResetToCleanStrategy::new();
        assert_eq!(s.begin(3), vec![Action::Reset { server: 0 }]);
        assert_eq!(s.on_server_ack(Ack::Reset), vec![Action::Reset { server: 1 }]);
        assert_eq!(s.on_server_ack(Ack::Reset), vec![Action::Reset { server: 2 }]);
        assert_eq!(s.on_server_ack(Ack::Reset), vec![Action::NotifyClients, Action::Done]);
        assert!(s.on_server_ack(Ack::Reset).is_empty());
    }

    #[test]
    fn reset_to_clean_skips_silent_servers_at_the_deadline() {
        let mut s = ResetToCleanStrategy::new();
        s.begin(3);
        // server 0 never acks (crashed): the deadline moves on
        assert_eq!(s.on_deadline(), vec![Action::Reset { server: 1 }]);
        assert_eq!(s.on_server_ack(Ack::Reset), vec![Action::Reset { server: 2 }]);
        // last one silent too — the recovery still terminates
        assert_eq!(s.on_deadline(), vec![Action::NotifyClients, Action::Done]);
        assert!(s.on_deadline().is_empty());
    }

    #[test]
    fn reset_to_clean_single_server_cluster_terminates() {
        let mut s = ResetToCleanStrategy::new();
        assert_eq!(s.begin(1), vec![Action::Reset { server: 0 }]);
        assert_eq!(s.on_server_ack(Ack::Reset), vec![Action::NotifyClients, Action::Done]);
    }

    #[test]
    fn stabilize_completes_immediately() {
        let mut s = StabilizeStrategy;
        assert_eq!(s.begin(5), vec![Action::Done]);
        assert!(s.on_server_ack(Ack::Frozen).is_empty());
        assert!(s.on_deadline().is_empty());
    }
}
