//! Rollback substrate (§IV): Retroscope-style window logs, periodic
//! snapshots, and the recovery controller.

pub mod recovery;
pub mod snapshot;
pub mod windowlog;
