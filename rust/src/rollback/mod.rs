//! Rollback substrate (§IV): Retroscope-style window logs, periodic
//! snapshots, the recovery controller, and the pluggable strategy
//! state machines it drives.

pub mod recovery;
pub mod snapshot;
pub mod strategy;
pub mod windowlog;
