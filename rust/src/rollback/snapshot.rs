//! Periodic full snapshots (§IV): the fallback when a violation is older
//! than the window-log horizon. The controller picks the most recent
//! snapshot strictly before `T_violate`.

use std::collections::HashMap;

use crate::clock::hvc::Millis;
use crate::store::table::Table;
use crate::store::value::{KeyId, Versioned};

#[derive(Debug, Clone)]
pub struct Snapshot {
    pub at_ms: Millis,
    pub data: HashMap<KeyId, Vec<Versioned>>,
}

#[derive(Debug, Clone)]
pub struct SnapshotStore {
    snaps: Vec<Snapshot>,
    /// retain at most this many snapshots (ring)
    keep: usize,
    taken: u64,
}

impl SnapshotStore {
    pub fn new(keep: usize) -> Self {
        Self { snaps: Vec::new(), keep: keep.max(1), taken: 0 }
    }

    pub fn take(&mut self, at_ms: Millis, table: &Table) {
        self.snaps.push(Snapshot { at_ms, data: table.snapshot() });
        self.taken += 1;
        if self.snaps.len() > self.keep {
            self.snaps.remove(0);
        }
    }

    /// Most recent snapshot taken at or before `to_ms`.
    pub fn latest_before(&self, to_ms: Millis) -> Option<&Snapshot> {
        self.snaps.iter().rev().find(|s| s.at_ms <= to_ms)
    }

    /// Restore `table` from the latest snapshot before `to_ms`; falls back
    /// to the empty initial state if none exists. Returns the snapshot
    /// time used (0 for initial state).
    pub fn restore_before(&self, table: &mut Table, to_ms: Millis) -> Millis {
        match self.latest_before(to_ms) {
            Some(s) => {
                table.restore_snapshot(s.data.clone());
                s.at_ms
            }
            None => {
                table.restore_snapshot(HashMap::new());
                0
            }
        }
    }

    pub fn len(&self) -> usize {
        self.snaps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.snaps.is_empty()
    }

    pub fn taken(&self) -> u64 {
        self.taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::vc::VectorClock;
    use crate::store::value::Value;

    fn vc(n: u64) -> VectorClock {
        let mut v = VectorClock::new();
        for _ in 0..n {
            v.increment(1);
        }
        v
    }

    #[test]
    fn restore_picks_latest_before_cut() {
        let mut t = Table::new();
        let mut ss = SnapshotStore::new(10);
        t.put(KeyId(1), vc(1), Value::Int(1));
        ss.take(100, &t);
        t.put(KeyId(1), vc(2), Value::Int(2));
        ss.take(200, &t);
        t.put(KeyId(1), vc(3), Value::Int(3));

        let used = ss.restore_before(&mut t, 150);
        assert_eq!(used, 100);
        assert_eq!(t.get(KeyId(1))[0].value, Value::Int(1));
    }

    #[test]
    fn restore_before_everything_resets_to_initial() {
        let mut t = Table::new();
        let mut ss = SnapshotStore::new(10);
        t.put(KeyId(1), vc(1), Value::Int(1));
        ss.take(100, &t);
        let used = ss.restore_before(&mut t, 50);
        assert_eq!(used, 0);
        assert!(t.is_empty());
    }

    #[test]
    fn ring_keeps_bounded() {
        let t = Table::new();
        let mut ss = SnapshotStore::new(3);
        for i in 0..10 {
            ss.take(i * 100, &t);
        }
        assert_eq!(ss.len(), 3);
        assert_eq!(ss.taken(), 10);
        assert!(ss.latest_before(100).is_none(), "old snapshots evicted");
        assert!(ss.latest_before(900).is_some());
    }
}
