//! optikv CLI — launch optimistic-execution experiments from the command
//! line.
//!
//! ```text
//! optikv run  --app <coloring|weather|conjunctive> --consistency N3R1W1
//!             [--cluster-servers S] [--clients 15] [--duration-s 120]
//!             [--monitors true] [--pipeline-depth 1]
//!             [--topo aws-global|aws-regional|lab50|lab100]
//!             [--recovery none|notify|restore|reset|stabilize]
//!             [--accel native|xla]
//!             [--put-pct 50] [--scale 0.05] [--seed 42] [--eps-ms inf]
//!             [--fault-plan "partition:0,1|2@10-40;crash:1@20+15"]
//! optikv table2        — print the consistency presets
//! optikv latency-demo  — quick Table-III style latency histogram
//! optikv scaleout      — throughput vs cluster size at fixed N=3
//! optikv pipeline      — throughput/latency vs client pipeline depth
//! optikv faults        — partition / crash-churn / detection-CDF demos
//! optikv adapt         — adaptive consistency vs the static pins on the
//!                        fault-phased scenario (mode timeline + per-mode
//!                        throughput)
//! optikv shards        — sharded-engine smoke: merged-order runs must be
//!                        bit-identical to serial at every shard count
//!                        (exit 1 otherwise), plus a threaded scaling sweep
//! optikv workload      — production-traffic engine smoke: skew sweep
//!                        (violation rate must be monotone in zipf θ, exit 1
//!                        otherwise), flash crowd under partition (adaptive
//!                        round trip required), client churn (rejoins
//!                        required)
//! optikv recover       — recovery-strategy matrix smoke: every
//!                        {eventual, causal, sequential} × {full, reset,
//!                        stab} cell must complete its recoveries through
//!                        crash churn (exit 1 if any cell wedges), plus the
//!                        self-stabilizing coloring demonstration (zero
//!                        aborts required)
//! optikv trace         — flight-recorder demo: run the faulted adaptive
//!                        ladder with the recorder in Full mode, write a
//!                        Perfetto-loadable Chrome trace (--out trace.json),
//!                        the per-window adapt-signal CSV (--csv) and the
//!                        violation-forensics report (--forensics); exit 1
//!                        if any seeded violation resolves to an empty
//!                        causal chain
//! ```
//!
//! Fault-plan DSL (windows in virtual seconds): `partition:0,1|2@10-40`
//! cuts region group {0,1} from {2}; `crash:1@20+15` crashes server 1 at
//! 20 s and restarts it 15 s later; `slow:2x4@10-30` makes server 2's
//! links 4× slower; `burst:0-1:0.3@5-25` adds 30 % loss on link 0↔1.

use optikv::client::consistency::ConsistencyCfg;
use optikv::exp::config::{AccelKind, AppKind, ExpConfig, TopoKind};
use optikv::exp::runner::run;
use optikv::exp::scenarios;
use optikv::faults::FaultPlan;
use optikv::metrics::report;
use optikv::rollback::recovery::RecoveryPolicy;
use optikv::sim::SEC;
use optikv::util::cli::Args;
use optikv::util::stats::{self, Table};

fn main() {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args),
        Some("table2") => cmd_table2(),
        Some("latency-demo") => cmd_latency_demo(&args),
        Some("scaleout") => cmd_scaleout(&args),
        Some("pipeline") => cmd_pipeline(&args),
        Some("faults") => cmd_faults(&args),
        Some("adapt") => cmd_adapt(&args),
        Some("shards") => cmd_shards(&args),
        Some("workload") => cmd_workload(&args),
        Some("recover") => cmd_recover(&args),
        Some("trace") => cmd_trace(&args),
        _ => {
            eprintln!(
                "usage: optikv <run|table2|latency-demo|scaleout|pipeline|faults|adapt|shards|workload|recover|trace> [flags]  (see module docs)"
            );
            std::process::exit(2);
        }
    }
}

fn cmd_run(args: &Args) {
    let consistency = ConsistencyCfg::parse(args.get_or("consistency", "N3R1W1"))
        .expect("bad --consistency (e.g. N3R1W1)");
    let scale = args.get_f64("scale", 0.05);
    let seed = args.get_u64("seed", 42);
    let put_pct = args.get_f64("put-pct", 50.0) / 100.0;
    let app = match args.get_or("app", "conjunctive") {
        "coloring" | "social-media" => AppKind::Coloring {
            nodes: ((50_000.0 * scale) as usize).max(200),
            edges_per_node: 3,
            task_size: args.get_usize("task-size", 10),
            loop_forever: true,
        },
        "weather" => {
            let side = ((80.0 * scale.sqrt()) as usize).max(16);
            AppKind::Weather { grid_w: side, grid_h: side, put_pct, use_locks: true }
        }
        "conjunctive" => AppKind::Conjunctive {
            n_preds: args.get_usize("preds", 10),
            n_conjuncts: args.get_usize("conjuncts", 10),
            beta: args.get_f64("beta", 0.01),
            put_pct,
        },
        other => {
            eprintln!("unknown --app {other}");
            std::process::exit(2);
        }
    };
    let mut cfg = ExpConfig::new("cli-run", consistency, app)
        .with_cluster_servers(args.get_usize("cluster-servers", consistency.n))
        .with_pipeline_depth(args.get_usize("pipeline-depth", 1));
    cfg.n_clients = args.get_usize("clients", 15);
    cfg.monitors = args.get_bool("monitors", true);
    cfg.duration = args.get_u64("duration-s", 120) * SEC;
    cfg.seed = seed;
    cfg.topo = match args.get_or("topo", "aws-global") {
        "aws-global" => TopoKind::AwsGlobal,
        "aws-regional" => TopoKind::AwsRegional { zones: 5 },
        "lab50" => TopoKind::LocalLab { inter_ms: 50.0 },
        "lab100" => TopoKind::LocalLab { inter_ms: 100.0 },
        other => {
            eprintln!("unknown --topo {other}");
            std::process::exit(2);
        }
    };
    cfg.recovery = match args.get_or("recovery", "notify") {
        "none" => RecoveryPolicy::None,
        "notify" => RecoveryPolicy::NotifyClients,
        "restore" => RecoveryPolicy::FullRestore,
        "reset" => RecoveryPolicy::ResetToClean,
        "stabilize" => RecoveryPolicy::Stabilize,
        other => {
            eprintln!("unknown --recovery {other}");
            std::process::exit(2);
        }
    };
    cfg.accel = match args.get_or("accel", "native") {
        "native" => AccelKind::Native,
        "xla" => AccelKind::Xla,
        other => {
            eprintln!("unknown --accel {other}");
            std::process::exit(2);
        }
    };
    if let Some(e) = args.get("eps-ms") {
        if e != "inf" {
            cfg.eps_ms = e.parse().expect("bad --eps-ms");
        }
    }
    if let Some(spec) = args.get("fault-plan") {
        let plan = match FaultPlan::parse(spec) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("bad --fault-plan: {e}");
                std::process::exit(2);
            }
        };
        if let Err(e) = plan.validate(cfg.n_servers(), cfg.n_regions()) {
            eprintln!("bad --fault-plan: {e}");
            std::process::exit(2);
        }
        cfg.fault_plan = plan;
    }

    eprintln!(
        "running `{}` on {} ({} clients, {:?}, monitors={}) ...",
        args.get_or("app", "conjunctive"),
        consistency.label(),
        cfg.n_clients,
        cfg.topo,
        cfg.monitors
    );
    let res = run(&cfg);
    println!("{}", report::summarize(&res));
    let m = res.metrics.borrow();
    println!(
        "violations={} recoveries={} tasks done/aborted={}/{} failures={} peak-preds={}",
        res.violations_detected,
        res.recoveries,
        m.tasks_completed,
        m.tasks_aborted,
        res.ops_failed,
        res.active_preds_peak,
    );
    if !res.detection_latencies_ms.is_empty() {
        println!(
            "detection latency: avg {:.1} ms, p99 {:.1} ms, max {:.1} ms",
            stats::mean(&res.detection_latencies_ms),
            stats::percentile(&res.detection_latencies_ms, 99.0),
            stats::max(&res.detection_latencies_ms)
        );
    }
    if res.sim_stats.fault_transitions > 0 {
        println!(
            "faults: {} transitions, {} msgs cut, {} crashes, {} re-syncs ({} versions merged)",
            res.sim_stats.fault_transitions,
            res.sim_stats.fault_dropped,
            res.crashes,
            res.resyncs,
            res.resync_keys,
        );
        print!("{}", report::detection_cdf_summary(&res.detection_cdf));
    }
}

fn cmd_table2() {
    let mut t = Table::new(&["N", "R", "W", "Abbreviation", "Consistency model"]);
    for c in scenarios::table2_n3().iter().chain(scenarios::table2_n5().iter()) {
        t.row(&[
            c.n.to_string(),
            c.r.to_string(),
            c.w.to_string(),
            c.label(),
            c.model_name().to_string(),
        ]);
    }
    t.print();
}

fn cmd_latency_demo(args: &Args) {
    let scale = args.get_f64("scale", 0.05);
    let res = run(&scenarios::conjunctive_regional(
        ConsistencyCfg::n5r1w1(),
        true,
        scale,
        args.get_u64("seed", 42),
    ));
    println!("{}", report::latency_table(&res.detection_latencies_ms));
}

fn cmd_scaleout(args: &Args) {
    let scale = args.get_f64("scale", 0.05);
    let seed = args.get_u64("seed", 42);
    let mut t = Table::new(&["servers", "clients", "app ops/s", "server ops/s", "violations"]);
    for &s in &scenarios::SCALEOUT_SIZES {
        let cfg = scenarios::scaleout_conjunctive(s, scale, seed);
        let res = run(&cfg);
        t.row(&[
            s.to_string(),
            cfg.n_clients.to_string(),
            format!("{:.0}", res.app_tps),
            format!("{:.0}", res.server_tps),
            res.violations_detected.to_string(),
        ]);
    }
    t.print();
}

fn cmd_faults(args: &Args) {
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);

    println!("== partition (AWS global, region 2 cut for the middle third) ==");
    let res = run(&scenarios::partition_coloring(scale, seed));
    println!("{}", report::summarize(&res));
    println!(
        "failed ops {} | restarts {} | msgs cut by faults {}",
        res.ops_failed, res.restarts, res.sim_stats.fault_dropped
    );
    print!("{}", report::detection_cdf_summary(&res.detection_cdf));

    println!("\n== crash churn (two crash/restart + peer re-sync cycles) ==");
    let res = run(&scenarios::crash_churn_conjunctive(scale, seed));
    println!("{}", report::summarize(&res));
    println!(
        "crashes {} | re-syncs {} | versions merged back {}",
        res.crashes, res.resyncs, res.resync_keys
    );

    for regional in [true, false] {
        let label = if regional { "regional (5 AZ)" } else { "global (3 regions)" };
        println!("\n== detection-latency CDF, {label}, degraded network ==");
        let res = run(&scenarios::detection_cdf_faulted(regional, scale, seed));
        println!("{}", report::summarize(&res));
        print!("{}", report::detection_cdf_summary(&res.detection_cdf));
    }
}

fn cmd_adapt(args: &Args) {
    use optikv::exp::scenarios::AdaptRun;
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);

    let mut t = Table::new(&["run", "app ops/s", "ok", "failed", "timeouts", "switches"]);
    let mut adaptive_tps = 0.0;
    let mut best_static: f64 = 0.0;
    let mut round_trips = 0;
    for run_kind in [AdaptRun::StaticEventual, AdaptRun::StaticSequential, AdaptRun::Adaptive] {
        let res = run(&scenarios::adaptive_conjunctive(run_kind, scale, seed));
        t.row(&[
            run_kind.label().to_string(),
            format!("{:.0}", res.app_tps),
            res.ops_ok.to_string(),
            res.ops_failed.to_string(),
            res.quorum_timeouts.to_string(),
            res.mode_switches.to_string(),
        ]);
        match run_kind {
            AdaptRun::Adaptive => {
                adaptive_tps = res.app_tps;
                round_trips = optikv::adapt::round_trips(&res.mode_timeline);
                print!("{}", report::mode_timeline_summary(&res));
            }
            _ => best_static = best_static.max(res.app_tps),
        }
    }
    t.print();
    println!(
        "adaptive vs best static: {:+.1}% ({} eventual→sequential→eventual round trips)",
        report::benefit_pct(adaptive_tps, best_static),
        round_trips,
    );
    if round_trips == 0 {
        eprintln!("adaptive-smoke FAILED: no mode round trip");
        std::process::exit(1);
    }
}

fn cmd_shards(args: &Args) {
    let scale = args.get_f64("scale", 0.05);
    let seed = args.get_u64("seed", 42);

    // -- merged-order engine: bit-identical to serial at every shard count --
    println!("== merged-order sharded engine vs serial (scaleout, 6 servers) ==");
    let digest = |res: &optikv::exp::runner::ExpResult| {
        (
            res.sim_stats.events,
            res.sim_stats.sent,
            res.ops_ok,
            res.violations_detected,
            res.app_tps.to_bits(),
        )
    };
    let serial = run(&scenarios::scaleout_conjunctive(6, scale, seed));
    let want = digest(&serial);
    let mut t = Table::new(&["shards", "events", "ops ok", "violations", "barriers", "identical"]);
    t.row(&[
        "serial".into(),
        serial.sim_stats.events.to_string(),
        serial.ops_ok.to_string(),
        serial.violations_detected.to_string(),
        "-".into(),
        "-".into(),
    ]);
    let mut all_ok = true;
    for shards in [1usize, 2, 4] {
        let res = run(&scenarios::scaleout_conjunctive(6, scale, seed).with_shards(shards));
        let ok = digest(&res) == want;
        all_ok &= ok;
        t.row(&[
            shards.to_string(),
            res.sim_stats.events.to_string(),
            res.ops_ok.to_string(),
            res.violations_detected.to_string(),
            res.barriers.to_string(),
            if ok { "yes".into() } else { "NO".to_string() },
        ]);
    }
    t.print();
    if !all_ok {
        eprintln!("shards-smoke FAILED: a sharded run diverged from the serial schedule");
        std::process::exit(1);
    }

    // -- threaded engine: full-stack scaling sweep --------------------------
    // the production deployment (24 servers, monitors, rollback) on worker
    // threads; digests must match serial while wall-clock drops
    println!("\n== threaded engine — full-stack scaleout, 24 servers ==");
    let mk = || {
        let mut cfg = scenarios::scaleout_conjunctive(24, scale, seed);
        cfg.n_clients = 24;
        cfg
    };
    let serial = run(&mk());
    let want = digest(&serial);
    let mut t =
        Table::new(&["shards", "events", "wall s", "events/s", "speedup", "barriers", "identical"]);
    let mut base: Option<f64> = None;
    let mut all_ok = true;
    for shards in [1usize, 2, 4, 8] {
        let t0 = std::time::Instant::now();
        let res = run(&mk().with_shards(shards).with_threaded());
        let wall = t0.elapsed().as_secs_f64();
        let ok = digest(&res) == want;
        all_ok &= ok;
        let eps = res.sim_stats.events as f64 / wall;
        let b = *base.get_or_insert(eps);
        t.row(&[
            shards.to_string(),
            res.sim_stats.events.to_string(),
            format!("{wall:.2}"),
            format!("{eps:.0}"),
            format!("{:.2}x", eps / b),
            res.barriers.to_string(),
            if ok { "yes".into() } else { "NO".to_string() },
        ]);
    }
    t.print();
    if !all_ok {
        eprintln!("shards-smoke FAILED: a threaded run diverged from the serial schedule");
        std::process::exit(1);
    }
}

fn cmd_workload(args: &Args) {
    use optikv::exp::scenarios::AdaptRun;
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);

    // -- skew sweep: the violation rate must be monotone in theta ----------
    println!("== skew sweep (kvmix, static eventual) ==");
    let mut t =
        Table::new(&["theta", "app ops/s", "ok", "viol", "viol/kop", "hot share", "keys@90%"]);
    let mut rates = Vec::new();
    for &theta in &scenarios::SKEW_THETAS {
        let res = run(&scenarios::kvmix_skew(theta, AdaptRun::StaticEventual, scale, seed));
        t.row(&[
            theta.to_string(),
            format!("{:.0}", res.app_tps),
            res.ops_ok.to_string(),
            res.violations_detected.to_string(),
            format!("{:.2}", res.violations_per_kop),
            format!("{:.2}", res.hot_key_share),
            res.keys_p90.to_string(),
        ]);
        rates.push(res.violations_per_kop);
    }
    t.print();
    // tolerant monotonicity: small dips within 5% of the heaviest rate are
    // sampling noise, but the curve must rise overall
    let slack = rates.last().copied().unwrap_or(0.0).max(1e-9) * 0.05;
    let non_decreasing = rates.windows(2).all(|w| w[1] + slack >= w[0]);
    let rises = rates[rates.len() - 1] > rates[0];
    if !(non_decreasing && rises) {
        eprintln!("workload-smoke FAILED: violation rate not monotone in zipf theta: {rates:?}");
        std::process::exit(1);
    }

    // -- flash crowd under partition: adaptive round trip ------------------
    println!("\n== flash crowd under partition (adaptive hysteresis) ==");
    let res = run(&scenarios::kvmix_flash_crowd(AdaptRun::Adaptive, true, scale, seed));
    println!("{}", report::summarize(&res));
    print!("{}", report::mode_timeline_summary(&res));
    for (label, tps) in &res.phase_tps {
        println!("phase {label}: {tps:.0} ops/s");
    }
    let round_trips = optikv::adapt::round_trips(&res.mode_timeline);
    println!(
        "mode switches {} | round trips {} | quorum timeouts {}",
        res.mode_switches, round_trips, res.quorum_timeouts
    );
    if round_trips == 0 {
        eprintln!("workload-smoke FAILED: no adaptive round trip under the flash crowd");
        std::process::exit(1);
    }

    // -- churn: leave/rejoin lowered onto the fault timeline ---------------
    println!("\n== client churn (every 4th client leaves and rejoins) ==");
    let res = run(&scenarios::kvmix_churn(AdaptRun::StaticEventual, scale, seed));
    println!("{}", report::summarize(&res));
    println!("rejoins {} | msgs cut by faults {}", res.rejoins, res.sim_stats.fault_dropped);
    if res.rejoins == 0 {
        eprintln!("workload-smoke FAILED: churned clients never rejoined");
        std::process::exit(1);
    }
}

fn cmd_recover(args: &Args) {
    use optikv::exp::scenarios::{RecoveryMode, RECOVERY_STRATEGIES};
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);

    // -- the 3x3 matrix: every cell must recover through crash churn -------
    println!("== recovery-strategy matrix (crash churn, 2 crash/restart cycles) ==");
    let mut t = Table::new(&[
        "cell",
        "app ops/s",
        "viol/kop",
        "recoveries",
        "completed",
        "aborted",
        "recover ms",
    ]);
    let mut wedged = Vec::new();
    for mode in RecoveryMode::ALL {
        for (strategy, _) in RECOVERY_STRATEGIES {
            let res = run(&scenarios::recovery_matrix_cell(mode, strategy, scale, seed));
            t.row(&[
                res.name.clone(),
                format!("{:.0}", res.app_tps),
                format!("{:.2}", res.violations_per_kop),
                res.recoveries.to_string(),
                res.completed_recoveries.to_string(),
                res.recovery_aborts.to_string(),
                format!("{:.1}", res.mean_recovery_ms),
            ]);
            if res.recoveries > 0 && res.completed_recoveries == 0 {
                wedged.push(res.name.clone());
            }
        }
    }
    t.print();
    if !wedged.is_empty() {
        eprintln!("recovery-smoke FAILED: cells started but never completed a recovery: {wedged:?}");
        std::process::exit(1);
    }

    // -- stabilize demonstration: coloring must converge with zero aborts --
    println!("\n== stabilize demonstration (self-stabilizing coloring through a crash) ==");
    let res = run(&scenarios::stabilize_coloring(scale, seed));
    let (done, aborted) = {
        let m = res.metrics.borrow();
        (m.tasks_completed, m.tasks_aborted)
    };
    println!(
        "{}: app {:.1} ops/s | violations {} | tasks done {} | tasks aborted {} | crashes {}",
        res.name, res.app_tps, res.violations_detected, done, aborted, res.crashes
    );
    if res.violations_detected == 0 {
        eprintln!("recovery-smoke FAILED: stabilize demo saw no violations — nothing demonstrated");
        std::process::exit(1);
    }
    if done == 0 || aborted > 0 {
        eprintln!(
            "recovery-smoke FAILED: self-stabilizing coloring must complete tasks without aborts \
             (done {done}, aborted {aborted})"
        );
        std::process::exit(1);
    }
}

fn cmd_trace(args: &Args) {
    use optikv::trace::chrome;
    use optikv::trace::forensics::Forensics;
    let scale = args.get_f64("scale", 0.1);
    let seed = args.get_u64("seed", 42);
    let out = args.get_or("out", "trace.json").to_string();
    let csv = args.get_or("csv", "trace-signals.csv").to_string();
    let forensics_out = args.get_or("forensics", "forensics.txt").to_string();

    println!("== flight recorder (adaptive ladder, Full mode) ==");
    let res = run(&scenarios::traced_ladder(scale, seed));
    println!("{}", report::summarize(&res));
    let hub = res.trace.as_ref().expect("traced_ladder enables the recorder");
    println!(
        "recorded {} events across {} actors ({} dropped by ring eviction)",
        hub.len(),
        hub.actors().count(),
        hub.dropped()
    );

    let json = chrome::chrome_trace_json(hub);
    std::fs::write(&out, &json).unwrap_or_else(|e| {
        eprintln!("trace-smoke FAILED: cannot write {out}: {e}");
        std::process::exit(1);
    });
    println!("wrote {out} ({} bytes) — load it at https://ui.perfetto.dev", json.len());

    let sig = chrome::signals_csv(hub);
    std::fs::write(&csv, &sig).unwrap_or_else(|e| {
        eprintln!("trace-smoke FAILED: cannot write {csv}: {e}");
        std::process::exit(1);
    });
    println!("wrote {csv} ({} adapt windows)", sig.lines().count().saturating_sub(1));

    let forensics = Forensics::walk(hub);
    let rendered = forensics.render();
    std::fs::write(&forensics_out, &rendered).unwrap_or_else(|e| {
        eprintln!("trace-smoke FAILED: cannot write {forensics_out}: {e}");
        std::process::exit(1);
    });
    print!("{rendered}");

    // acceptance: the run must actually seed violations, and every one of
    // them must walk back to at least one guilty write
    if forensics.chains.is_empty() {
        eprintln!("trace-smoke FAILED: the faulted ladder run produced no violations");
        std::process::exit(1);
    }
    let empty = forensics.empty_chains();
    if empty > 0 {
        eprintln!(
            "trace-smoke FAILED: {empty}/{} violations resolved to an empty causal chain",
            forensics.chains.len()
        );
        std::process::exit(1);
    }
    println!(
        "forensics: {} violations, every causal chain non-empty",
        forensics.chains.len()
    );
}

fn cmd_pipeline(args: &Args) {
    let scale = args.get_f64("scale", 0.05);
    let seed = args.get_u64("seed", 42);
    let clients = args.get_usize("clients", 1);
    let mut t =
        Table::new(&["depth", "app ops/s", "op p50 (ms)", "op p99 (ms)", "ok"]);
    for &d in &scenarios::PIPELINE_DEPTHS {
        let res = run(&scenarios::pipeline_coloring(d, clients, scale, seed));
        t.row(&[
            d.to_string(),
            format!("{:.0}", res.app_tps),
            format!("{:.1}", res.lat_p50_ms),
            format!("{:.1}", res.lat_p99_ms),
            res.ops_ok.to_string(),
        ]);
    }
    t.print();
}
