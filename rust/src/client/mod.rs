//! The store client, split into three layers:
//!
//! * [`quorum`] — the transport-agnostic N/R/W quorum engine: one
//!   [`quorum::QuorumCall`] per application operation, every transition a
//!   pure function (broadcast → parallel phase → serial round 2 →
//!   success/fail, `WrongServer` fast-fail, duplicate/stale dedup);
//! * [`actor`] — the thin multiplexer that runs up to `pipeline_depth`
//!   concurrent calls keyed by request id, turns engine steps into wire
//!   messages/timers, and drives the application;
//! * [`app`] — the application interface: closed-loop single ops plus
//!   [`app::AppAction::Batch`] scatter-gather waves for pipelined runs.
//!
//! [`consistency`] holds the N/R/W presets (Table II) and client timing.

pub mod actor;
pub mod app;
pub mod consistency;
pub mod quorum;
