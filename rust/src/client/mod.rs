//! The store client: N/R/W quorum engine (client-side replication, as in
//! Voldemort), consistency presets (Table II), and the app interface.

pub mod actor;
pub mod app;
pub mod consistency;
