//! The Voldemort-style client actor, rebuilt as a thin *multiplexer* over
//! the transport-agnostic quorum engine ([`crate::client::quorum`]):
//!
//! * routing — each operation resolves the key's N-server preference
//!   list on the consistent-hash ring ([`crate::store::ring`]); cluster
//!   size and N are independent, so only the key's replica set is
//!   contacted, never the whole cluster;
//! * quorum protocol — every operation is a [`QuorumCall`] (parallel
//!   phase, serial second round, GET_VERSION → PUT chaining); the actor
//!   only turns [`QuorumStep`]s into wire messages and timers;
//! * pipelining — up to `pipeline_depth` calls run concurrently, keyed
//!   by wire request id. The app hands the actor either single ops
//!   (closed loop) or [`AppAction::Batch`] waves whose operations are
//!   scattered across the open slots and gathered into one
//!   [`LastResult::Batch`]. `pipeline_depth = 1` reproduces the
//!   historical serial client event-for-event;
//! * broadcast payloads are shared: one `Rc<ServerOp>` serves all N
//!   replicas of a fan-out instead of N deep clones of the value and its
//!   vector clock.
//!
//! The client also relays HVC causality between servers by piggy-backing
//! the freshest server HVC it has seen onto every request.

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

use crate::client::app::{AppAction, AppEnv, AppLogic, AppOp, LastResult, OpOutcome};
use crate::client::consistency::{ClientTiming, ConsistencyCfg};
use crate::client::quorum::{QuorumCall, QuorumStep, Session};
use crate::clock::hvc::Hvc;
use crate::faults::state::FaultHook;
use crate::metrics::throughput::Metrics;
use crate::sim::des::{Actor, Ctx};
use crate::sim::msg::{AdaptMsg, Msg, RollbackMsg};
use crate::sim::{ProcId, Time};
use crate::store::protocol::{ServerOp, ServerReply};
use crate::store::ring::Router;
use crate::trace::{TraceEv, TraceRef};

const TAG_WAKE: u64 = 0;
/// think timers carry a generation in the low bits so timers from before
/// an abort cannot issue ops early (flag bit distinguishes them from the
/// request-timeout tags, which are small integers)
const THINK_FLAG: u64 = 1 << 63;
/// periodic adapt-report timer ([`ClientActor::with_adapt_reports`])
const REPORT_FLAG: u64 = 1 << 62;
/// latency samples carried per report (bounds the report payload the way
/// the hub bounds its own sample buffer)
const REPORT_LAT_CAP: usize = 4096;

/// One in-progress app action: the scatter-gather bookkeeping of a single
/// `Op` (a wave of one) or a `Batch` wave.
struct Wave {
    /// deliver as `LastResult::Op` rather than `Batch`
    single: bool,
    /// not-yet-issued operations, in submission order
    pending: VecDeque<(usize, AppOp)>,
    /// slot → completed (op, outcome)
    results: Vec<Option<(AppOp, OpOutcome)>>,
    /// calls currently multiplexed in `ClientActor::calls`
    inflight: usize,
}

pub struct ClientActor {
    /// index among clients (vector-clock node id, metrics row)
    pub idx: u32,
    /// every server in the cluster, indexed by server index
    servers: Vec<ProcId>,
    /// key → preference-list resolution (shared ring view)
    router: Rc<Router>,
    /// the quorum configuration for *newly opened* calls. Mutable at
    /// runtime: an [`AdaptMsg::Announce`] adopts the announced config for
    /// every call issued from that point on, while in-flight calls finish
    /// under the config/epoch they were issued with (each call owns its
    /// copy; replies to completed calls are dropped by the dedup path).
    cfg: ConsistencyCfg,
    /// current consistency epoch ([`crate::adapt`]); 0 until the adapt
    /// controller announces a switch
    epoch: u64,
    timing: ClientTiming,
    /// max concurrent quorum calls (1 = the paper's serial client)
    depth: usize,
    app: Box<dyn AppLogic>,
    /// open quorum calls, keyed by their *current* wire request id
    calls: HashMap<u64, (usize, QuorumCall)>,
    /// the app action being executed
    wave: Option<Wave>,
    /// wave waiting out the client think time
    stashed: Option<(bool, Vec<AppOp>)>,
    /// think-timer generation (stale timers are ignored)
    think_seq: u64,
    next_req: u64,
    /// freshest server HVC observed, `Rc`-shared into every outgoing
    /// request (one refcount bump per replica instead of a vector clone)
    /// and merged copy-on-write as replies arrive
    seen_hvc: Option<Rc<Hvc>>,
    /// session-guarantee state for the causal mode
    /// ([`ConsistencyCfg::causal`]): present exactly while the active
    /// config is causal. `None` everywhere else — non-causal runs never
    /// touch it, so they reproduce pre-session behavior bit-for-bit.
    session: Option<Session>,
    metrics: Metrics,
    done: bool,
    /// false while churned out (workload [`crate::workload::churn`]
    /// schedules lower to crash/restart hooks on client procs). Timers
    /// and stragglers still *arrive* while inactive — a departed client
    /// cannot intercept the network — so every handler gates on this.
    active: bool,
    /// where and how often to push [`AdaptMsg::Report`] signal digests.
    /// `None` (the default) sends nothing — a cluster without an adapt
    /// controller stays bit-identical to one that never heard of adaptation.
    adapt_report: Option<(ProcId, Time)>,
    /// signals accumulated since the last report: ok ops, quorum-round
    /// timeouts, and op latency samples
    rep_ops: u64,
    rep_timeouts: u64,
    rep_lat: Vec<Time>,
    /// flight recorder handle (`None` = recording off, zero overhead)
    trace: Option<TraceRef>,
    /// stats
    pub ops_ok: u64,
    pub ops_failed: u64,
    pub restarts: u64,
    /// churn rejoins completed (leave/rejoin cycles survived)
    pub rejoins: u64,
}

impl ClientActor {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        idx: u32,
        servers: Vec<ProcId>,
        router: Rc<Router>,
        cfg: ConsistencyCfg,
        timing: ClientTiming,
        pipeline_depth: usize,
        app: Box<dyn AppLogic>,
        metrics: Metrics,
    ) -> Self {
        assert!(pipeline_depth >= 1, "pipeline depth must be at least 1");
        assert!(
            servers.len() >= cfg.n,
            "cluster of {} servers cannot host N = {} replicas",
            servers.len(),
            cfg.n
        );
        assert_eq!(
            servers.len(),
            router.ring().n_servers(),
            "server id table must cover every ring server"
        );
        assert_eq!(
            router.ring().n_replicas(),
            cfg.n,
            "ring replication factor must match the consistency config"
        );
        Self {
            idx,
            servers,
            router,
            cfg,
            epoch: 0,
            timing,
            depth: pipeline_depth,
            app,
            calls: HashMap::new(),
            wave: None,
            stashed: None,
            think_seq: 0,
            next_req: 1,
            seen_hvc: None,
            session: cfg.causal.then(Session::new),
            metrics,
            done: false,
            active: true,
            adapt_report: None,
            rep_ops: 0,
            rep_timeouts: 0,
            rep_lat: Vec::new(),
            trace: None,
            ops_ok: 0,
            ops_failed: 0,
            restarts: 0,
            rejoins: 0,
        }
    }

    /// Push an [`AdaptMsg::Report`] of locally observed signals to `to`
    /// every `window`. Only wired up when an adapt controller is deployed:
    /// the controller cannot read the clients' metrics hub across shard
    /// boundaries, so the signals travel as messages like everything else.
    pub fn with_adapt_reports(mut self, to: ProcId, window: Time) -> Self {
        assert!(window > 0, "report window must be positive");
        self.adapt_report = Some((to, window));
        self
    }

    /// Attach the flight recorder ([`crate::trace`]).
    pub fn with_trace(mut self, trace: TraceRef) -> Self {
        self.trace = Some(trace);
        self
    }

    fn merge_seen(&mut self, h: &Rc<Hvc>) {
        match &mut self.seen_hvc {
            None => self.seen_hvc = Some(Rc::clone(h)),
            Some(s) => {
                let s = Rc::make_mut(s);
                for (a, b) in s.v.iter_mut().zip(h.v.iter()) {
                    if *b > *a {
                        *a = *b;
                    }
                }
            }
        }
    }

    /// Fan a wire op out to `targets`, sharing one payload allocation.
    fn broadcast(&mut self, ctx: &mut Ctx, targets: &[ProcId], req: u64, op: ServerOp) {
        let op = Rc::new(op);
        for &s in targets {
            ctx.send(s, Msg::Request { req, op: Rc::clone(&op), hvc: self.seen_hvc.clone() });
        }
    }

    /// Resolve the key's preference list to actor ids.
    fn resolve_targets(&self, op: &AppOp) -> Vec<ProcId> {
        self.router
            .replicas(op.key())
            .iter()
            .map(|&s| self.servers[s as usize])
            .collect()
    }

    /// Execute one engine step: send + arm the round timer, or finish.
    fn apply_step(&mut self, ctx: &mut Ctx, key: u64, step: QuorumStep) {
        match step {
            QuorumStep::Wait => {}
            QuorumStep::Send { req, to, op, round } => {
                if req != key {
                    // GET_VERSION → PUT switched to a fresh request id
                    let call = self.calls.remove(&key).expect("re-keyed call");
                    self.calls.insert(req, call);
                }
                if let Some(tr) = &self.trace {
                    tr.borrow_mut().record(
                        ctx.self_id,
                        ctx.now(),
                        ctx.event_seq(),
                        TraceEv::ClientRound { client: self.idx, req, round },
                    );
                }
                self.broadcast(ctx, &to, req, op);
                let timeout = if round == 1 {
                    self.timing.timeout_round1
                } else {
                    self.timing.timeout_round2
                };
                ctx.schedule(timeout, req);
            }
            QuorumStep::Done(outcome) => {
                let (slot, call) = self.calls.remove(&key).expect("finished call");
                if let Some(tr) = &self.trace {
                    tr.borrow_mut().record(
                        ctx.self_id,
                        ctx.now(),
                        ctx.event_seq(),
                        TraceEv::ClientComplete {
                            client: self.idx,
                            req: key,
                            ok: !matches!(outcome, OpOutcome::Failed),
                            latency: ctx.now() - call.started,
                        },
                    );
                }
                self.finish_call(ctx, slot, call, outcome);
            }
        }
    }

    /// Issue queued wave operations into free pipeline slots.
    fn pump(&mut self, ctx: &mut Ctx) {
        loop {
            let (slot, op) = {
                let Some(w) = self.wave.as_mut() else { return };
                if w.inflight >= self.depth {
                    return;
                }
                match w.pending.pop_front() {
                    Some(next) => {
                        w.inflight += 1;
                        next
                    }
                    None => return,
                }
            };
            let req = self.next_req;
            self.next_req += 1;
            let targets = self.resolve_targets(&op);
            if let Some(tr) = &self.trace {
                tr.borrow_mut().record(ctx.self_id, ctx.now(), ctx.event_seq(), TraceEv::ClientIssue {
                    client: self.idx,
                    req,
                    key: op.key().0,
                    put: matches!(op, AppOp::Put(..)),
                    epoch: self.epoch,
                });
            }
            let (call, step) =
                QuorumCall::new(self.idx, self.cfg, op, req, targets, ctx.now(), self.epoch);
            self.calls.insert(req, (slot, call));
            self.apply_step(ctx, req, step);
        }
    }

    fn finish_call(&mut self, ctx: &mut Ctx, slot: usize, call: QuorumCall, outcome: OpOutcome) {
        // causal mode: run the result through the session guarantees —
        // record committed writes, patch reads against the floor
        let outcome = match (outcome, self.session.as_mut()) {
            (OpOutcome::GetOk(sibs), Some(sess)) => {
                OpOutcome::GetOk(sess.patch_get(call.app_op.key(), sibs))
            }
            (OpOutcome::PutOk, Some(sess)) => {
                if let (AppOp::Put(k, v), Some(ver)) = (&call.app_op, call.version()) {
                    sess.on_put(*k, ver, v);
                }
                OpOutcome::PutOk
            }
            (o, _) => o,
        };
        match &outcome {
            OpOutcome::Failed => {
                self.ops_failed += 1;
                self.metrics.borrow_mut().record_app_failure(self.idx as usize);
            }
            _ => {
                self.ops_ok += 1;
                let latency = ctx.now() - call.started;
                self.metrics.borrow_mut().record_app(self.idx as usize, ctx.now(), latency);
                if self.adapt_report.is_some() {
                    self.rep_ops += 1;
                    if self.rep_lat.len() < REPORT_LAT_CAP {
                        self.rep_lat.push(latency);
                    }
                }
            }
        }
        let complete = {
            let w = self.wave.as_mut().expect("wave behind every call");
            w.inflight -= 1;
            w.results[slot] = Some((call.app_op, outcome));
            w.inflight == 0 && w.pending.is_empty()
        };
        if complete {
            let w = self.wave.take().expect("wave present");
            let mut pairs: Vec<(AppOp, OpOutcome)> =
                w.results.into_iter().map(|r| r.expect("every slot gathered")).collect();
            let last = if w.single {
                let (op, out) = pairs.pop().expect("single-op wave");
                LastResult::Op(op, out)
            } else {
                LastResult::Batch(pairs)
            };
            self.advance(ctx, Some(last));
        } else {
            self.pump(ctx);
        }
    }

    fn advance(&mut self, ctx: &mut Ctx, last: Option<LastResult>) {
        let now = ctx.now();
        let seq = ctx.event_seq();
        let idx = self.idx;
        let depth = self.depth;
        let action = {
            let mut env = AppEnv { now, seq, client_idx: idx, pipeline: depth, rng: ctx.rng() };
            self.app.next(&mut env, last)
        };
        match action {
            AppAction::Op(op) => self.schedule_wave(ctx, true, vec![op]),
            AppAction::Batch(ops) => {
                assert!(!ops.is_empty(), "apps must not emit empty batches");
                self.schedule_wave(ctx, false, ops);
            }
            AppAction::Sleep(d) => ctx.schedule(d, TAG_WAKE),
            AppAction::Done => self.done = true,
        }
    }

    fn schedule_wave(&mut self, ctx: &mut Ctx, single: bool, ops: Vec<AppOp>) {
        if self.timing.think > 0 {
            // model client-side processing between waves
            self.stashed = Some((single, ops));
            self.think_seq += 1;
            ctx.schedule(self.timing.think, THINK_FLAG | self.think_seq);
        } else {
            self.start_wave(ctx, single, ops);
        }
    }

    fn start_wave(&mut self, ctx: &mut Ctx, single: bool, ops: Vec<AppOp>) {
        let n = ops.len();
        self.wave = Some(Wave {
            single,
            pending: ops.into_iter().enumerate().collect(),
            results: (0..n).map(|_| None).collect(),
            inflight: 0,
        });
        self.pump(ctx);
    }

    fn on_reply(&mut self, ctx: &mut Ctx, from: ProcId, req: u64, reply: ServerReply) {
        let Some((_, call)) = self.calls.get_mut(&req) else {
            return; // stale reply from a completed or aborted call
        };
        let next_req = &mut self.next_req;
        let step = call.on_reply(from, req, reply, || {
            let r = *next_req;
            *next_req += 1;
            r
        });
        self.apply_step(ctx, req, step);
    }

    fn on_timeout(&mut self, ctx: &mut Ctx, req: u64) {
        let Some((_, call)) = self.calls.get_mut(&req) else {
            return; // stale timer
        };
        let step = call.on_timeout(req);
        // an expired quorum round is a live signal the adapt controller
        // watches ([`crate::adapt::signals`]): count the serial-round
        // fallback and the final timeout failure, not stale timers
        if matches!(
            step,
            QuorumStep::Send { round: 2, .. } | QuorumStep::Done(OpOutcome::Failed)
        ) {
            self.metrics.borrow_mut().quorum_timeouts += 1;
            if self.adapt_report.is_some() {
                self.rep_timeouts += 1;
            }
        }
        self.apply_step(ctx, req, step);
    }

    /// Adopt an announced consistency epoch: calls opened from now on use
    /// `cfg`; calls already in flight are untouched (each carries the
    /// config of its issue epoch). Returns whether the epoch advanced —
    /// duplicates and stale re-announces are idempotent no-ops.
    fn apply_announce(&mut self, epoch: u64, cfg: ConsistencyCfg) -> bool {
        if epoch <= self.epoch {
            return false;
        }
        assert_eq!(
            cfg.n, self.cfg.n,
            "an epoch switch cannot change the replication factor (ring is fixed)"
        );
        self.epoch = epoch;
        self.cfg = cfg;
        // the session lives exactly while the mode is causal; an
        // escalation to sequential (stronger) or a release to eventual
        // (weaker, no guarantees promised) both retire the floors
        if cfg.causal {
            if self.session.is_none() {
                self.session = Some(Session::new());
            }
        } else {
            self.session = None;
        }
        true
    }
}

impl Actor for ClientActor {
    fn on_start(&mut self, ctx: &mut Ctx) {
        if let Some((_, window)) = self.adapt_report {
            ctx.schedule(window, REPORT_FLAG);
        }
        self.advance(ctx, None);
    }

    fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg) {
        if !self.active {
            return; // stragglers delivered after the client left
        }
        match msg {
            Msg::Reply { req, reply, hvc } => {
                self.merge_seen(&hvc);
                self.on_reply(ctx, from, req, reply);
            }
            Msg::Adapt(AdaptMsg::Announce { epoch, cfg }) => {
                self.apply_announce(epoch, cfg);
                // always ack the freshest epoch this client runs under,
                // so duplicate/stale announces still converge the
                // controller's view
                ctx.send(from, Msg::Adapt(AdaptMsg::Ack { epoch: self.epoch, client: self.idx }));
            }
            Msg::Rollback(RollbackMsg::Notify { t_violate_ms, .. }) => {
                // server state may have rewound past the session floors:
                // keeping them would resurrect rolled-back writes
                if let Some(sess) = self.session.as_mut() {
                    sess.clear();
                }
                let abort = {
                    let now = ctx.now();
                    let seq = ctx.event_seq();
                    let idx = self.idx;
                    let depth = self.depth;
                    let mut env =
                        AppEnv { now, seq, client_idx: idx, pipeline: depth, rng: ctx.rng() };
                    self.app.on_violation(&mut env, t_violate_ms)
                };
                if abort && !self.done {
                    self.restarts += 1;
                    // outstanding replies/timers go stale with their calls
                    self.calls.clear();
                    self.wave = None;
                    self.stashed = None;
                    self.think_seq += 1; // pending think timers go stale too
                    self.advance(ctx, None);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, tag: u64) {
        if !self.active {
            // keep the periodic report chain armed across the outage (it
            // is the actor's own timer, not network traffic); everything
            // else went stale when the client left
            if tag == REPORT_FLAG {
                if let Some((_, window)) = self.adapt_report {
                    ctx.schedule(window, REPORT_FLAG);
                }
            }
            return;
        }
        if tag & THINK_FLAG != 0 {
            if (tag & !THINK_FLAG) == self.think_seq {
                if let Some((single, ops)) = self.stashed.take() {
                    if !self.done {
                        self.start_wave(ctx, single, ops);
                    }
                }
            }
        } else if tag == REPORT_FLAG {
            if let Some((to, window)) = self.adapt_report {
                let report = AdaptMsg::Report {
                    client: self.idx,
                    ops: std::mem::take(&mut self.rep_ops),
                    timeouts: std::mem::take(&mut self.rep_timeouts),
                    lat: std::mem::take(&mut self.rep_lat),
                };
                ctx.send(to, Msg::Adapt(report));
                ctx.schedule(window, REPORT_FLAG);
            }
        } else if tag == TAG_WAKE {
            // a wake is stale if a wave is running OR one is parked behind
            // a think timer (e.g. an abort re-planned the next wave while
            // an old Sleep(0) was still queued)
            if !self.done && self.wave.is_none() && self.stashed.is_none() {
                self.advance(ctx, None);
            }
        } else {
            self.on_timeout(ctx, tag);
        }
    }

    fn on_fault(&mut self, ctx: &mut Ctx, hook: FaultHook) {
        if let Some(tr) = &self.trace {
            let kind = match hook {
                FaultHook::Crash => "crash",
                FaultHook::Restart => "restart",
            };
            tr.borrow_mut().record(ctx.self_id, ctx.now(), ctx.event_seq(), TraceEv::Fault { kind });
        }
        match hook {
            FaultHook::Crash => {
                // the client left: in-flight calls, parked waves and
                // accumulated report signals are volatile state
                self.active = false;
                self.calls.clear();
                self.wave = None;
                self.stashed = None;
                self.think_seq += 1; // pending think timers go stale
                self.rep_ops = 0;
                self.rep_timeouts = 0;
                self.rep_lat.clear();
                // the session died with its connection
                if let Some(sess) = self.session.as_mut() {
                    sess.clear();
                }
            }
            FaultHook::Restart => {
                if !self.active {
                    self.rejoins += 1;
                    self.active = true;
                    if !self.done {
                        // resume the closed loop from a fresh app step
                        self.advance(ctx, None);
                    }
                }
            }
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ring::{Ring, Router};
    use crate::store::value::Interner;

    fn test_client(cluster: usize, cfg: ConsistencyCfg, depth: usize) -> ClientActor {
        let interner = Interner::new();
        let router = Router::new(Ring::new(cluster, cfg.n, 8, 1), interner);
        ClientActor::new(
            0,
            (0..cluster as u32).map(ProcId).collect(),
            router,
            cfg,
            ClientTiming::default(),
            depth,
            Box::new(crate::client::app::ScriptApp::new(vec![])),
            crate::metrics::throughput::MetricsHub::new(cluster, 1),
        )
    }

    #[test]
    fn targets_resolve_to_n_servers_in_a_larger_cluster() {
        let interner = Interner::new();
        let key = interner.borrow_mut().intern("x_0_0");
        let cfg = ConsistencyCfg::n3r1w1();
        let router = Router::new(Ring::new(12, cfg.n, 64, 1), interner);
        let client = ClientActor::new(
            0,
            (0..12u32).map(ProcId).collect(),
            router,
            cfg,
            ClientTiming::default(),
            1,
            Box::new(crate::client::app::ScriptApp::new(vec![])),
            crate::metrics::throughput::MetricsHub::new(12, 1),
        );
        let targets = client.resolve_targets(&AppOp::Get(key));
        assert_eq!(targets.len(), 3, "N = 3 replicas out of 12 servers");
        assert!(targets.iter().all(|p| p.0 < 12));
    }

    #[test]
    #[should_panic(expected = "pipeline depth")]
    fn zero_depth_rejected() {
        let _ = test_client(3, ConsistencyCfg::n3r1w1(), 0);
    }

    #[test]
    fn announce_switches_new_calls_but_not_inflight_ones() {
        // issue a call under the starting config (R = 2), then announce a
        // switch to R = 1: the client's config moves, but the in-flight
        // call still needs two distinct replies to complete
        let mut client = test_client(3, ConsistencyCfg::n3r2w2(), 1);
        let (call, _) = QuorumCall::new(
            0,
            client.cfg,
            AppOp::Get(crate::store::value::KeyId(1)),
            1,
            (0..3).map(ProcId).collect(),
            0,
            client.epoch,
        );
        client.calls.insert(1, (0, call));

        assert!(client.apply_announce(1, ConsistencyCfg::new(3, 1, 2)));
        assert_eq!(client.epoch, 1);
        assert_eq!(client.cfg, ConsistencyCfg::new(3, 1, 2));

        {
            // the parked call still carries its issue-epoch quorum sizes
            let (_, call) = client.calls.get_mut(&1).unwrap();
            assert_eq!(call.epoch, 0);
            assert!(matches!(
                call.on_reply(
                    ProcId(0),
                    1,
                    crate::store::protocol::ServerReply::Values(vec![]),
                    || panic!("no re-key")
                ),
                crate::client::quorum::QuorumStep::Wait
            ));
        }

        // duplicate and stale announces are no-ops
        assert!(!client.apply_announce(1, ConsistencyCfg::n3r2w2()));
        assert!(!client.apply_announce(0, ConsistencyCfg::n3r2w2()));
        assert_eq!(client.cfg, ConsistencyCfg::new(3, 1, 2));

        // a newer epoch moves the config again
        assert!(client.apply_announce(2, ConsistencyCfg::n3r2w2()));
        assert_eq!(client.epoch, 2);
    }

    #[test]
    #[should_panic(expected = "replication factor")]
    fn announce_cannot_change_n() {
        let mut client = test_client(3, ConsistencyCfg::n3r1w1(), 1);
        client.apply_announce(1, ConsistencyCfg::n5r1w1());
    }

    #[test]
    fn wave_bookkeeping_shapes() {
        // pure structure check: a batch wave gathers slots in submission
        // order regardless of completion order
        let mut w = Wave {
            single: false,
            pending: VecDeque::new(),
            results: vec![None, None],
            inflight: 2,
        };
        w.results[1] = Some((AppOp::Get(crate::store::value::KeyId(2)), OpOutcome::PutOk));
        w.inflight -= 1;
        w.results[0] = Some((AppOp::Get(crate::store::value::KeyId(1)), OpOutcome::PutOk));
        w.inflight -= 1;
        assert_eq!(w.inflight, 0);
        let keys: Vec<u32> = w
            .results
            .into_iter()
            .map(|r| r.unwrap().0.key().0)
            .collect();
        assert_eq!(keys, vec![1, 2], "gather preserves submission order");
    }
}
