//! The Voldemort-style client actor: executes application operations
//! against the replicated store with N/R/W quorum semantics (§II-B):
//!
//! * routing — each operation resolves the key's N-server preference
//!   list on the consistent-hash ring ([`crate::store::ring`]); cluster
//!   size and N are independent, so only the key's replica set is
//!   contacted, never the whole cluster;
//! * parallel phase — send to all N preference-list servers, wait for
//!   R (W) distinct acknowledgements with a timeout;
//! * serial phase — on timeout, one more round to the servers that have
//!   not responded; if the quorum is still not met, the op fails;
//! * an application PUT is GET_VERSION (quorum R) + PUT (quorum W) with
//!   the merged, incremented vector clock (§VI-A).
//!
//! The client also relays HVC causality between servers by piggy-backing
//! the freshest server HVC it has seen onto every request.

use std::rc::Rc;

use crate::clock::hvc::Hvc;
use crate::clock::vc::VectorClock;
use crate::client::app::{AppAction, AppEnv, AppLogic, AppOp, OpOutcome};
use crate::client::consistency::{ClientTiming, ConsistencyCfg};
use crate::metrics::throughput::Metrics;
use crate::sim::des::{Actor, Ctx};
use crate::sim::msg::{Msg, RollbackMsg};
use crate::sim::{ProcId, Time};
use crate::store::protocol::{ServerOp, ServerReply};
use crate::store::ring::Router;
use crate::store::value::{merge_siblings, Versioned};

const TAG_WAKE: u64 = 0;
/// think timers carry a generation in the low bits so timers from before
/// an abort cannot issue ops early (flag bit distinguishes them from the
/// request-timeout tags, which are small integers)
const THINK_FLAG: u64 = 1 << 63;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Get,
    GetVersion,
    Put,
}

struct Inflight {
    app_op: AppOp,
    phase: Phase,
    req: u64,
    /// the key's preference list (actor ids), resolved once per app op
    targets: Vec<ProcId>,
    /// servers that refused with WrongServer (deterministic: they will
    /// never ack this key, so they are excluded from the serial round)
    refused: Vec<ProcId>,
    /// distinct servers that answered (usable replies)
    replies: Vec<(ProcId, ServerReply)>,
    round: u8,
    started: Time,
    /// merged version for the PUT phase
    version: Option<VectorClock>,
}

pub struct ClientActor {
    /// index among clients (vector-clock node id, metrics row)
    pub idx: u32,
    /// every server in the cluster, indexed by server index
    servers: Vec<ProcId>,
    /// key → preference-list resolution (shared ring view)
    router: Rc<Router>,
    cfg: ConsistencyCfg,
    timing: ClientTiming,
    app: Box<dyn AppLogic>,
    inflight: Option<Inflight>,
    /// op waiting out the client think time
    stashed: Option<AppOp>,
    /// think-timer generation (stale timers are ignored)
    think_seq: u64,
    next_req: u64,
    seen_hvc: Option<Hvc>,
    metrics: Metrics,
    done: bool,
    /// stats
    pub ops_ok: u64,
    pub ops_failed: u64,
    pub restarts: u64,
}

impl ClientActor {
    pub fn new(
        idx: u32,
        servers: Vec<ProcId>,
        router: Rc<Router>,
        cfg: ConsistencyCfg,
        timing: ClientTiming,
        app: Box<dyn AppLogic>,
        metrics: Metrics,
    ) -> Self {
        assert!(
            servers.len() >= cfg.n,
            "cluster of {} servers cannot host N = {} replicas",
            servers.len(),
            cfg.n
        );
        assert_eq!(
            servers.len(),
            router.ring().n_servers(),
            "server id table must cover every ring server"
        );
        assert_eq!(
            router.ring().n_replicas(),
            cfg.n,
            "ring replication factor must match the consistency config"
        );
        Self {
            idx,
            servers,
            router,
            cfg,
            timing,
            app,
            inflight: None,
            stashed: None,
            think_seq: 0,
            next_req: 1,
            seen_hvc: None,
            metrics,
            done: false,
            ops_ok: 0,
            ops_failed: 0,
            restarts: 0,
        }
    }

    fn merge_seen(&mut self, h: &Hvc) {
        match &mut self.seen_hvc {
            None => self.seen_hvc = Some(h.clone()),
            Some(s) => {
                for (a, b) in s.v.iter_mut().zip(h.v.iter()) {
                    if *b > *a {
                        *a = *b;
                    }
                }
            }
        }
    }

    fn broadcast(&mut self, ctx: &mut Ctx, targets: &[ProcId], req: u64, op: &ServerOp) {
        for &s in targets {
            ctx.send(s, Msg::Request { req, op: op.clone(), hvc: self.seen_hvc.clone() });
        }
    }

    fn wire_op(&self, phase: Phase, inflight: &Inflight) -> ServerOp {
        match (phase, &inflight.app_op) {
            (Phase::Get, AppOp::Get(k)) => ServerOp::Get(*k),
            (Phase::GetVersion, AppOp::Put(k, _)) => ServerOp::GetVersion(*k),
            (Phase::Put, AppOp::Put(k, v)) => ServerOp::Put {
                key: *k,
                version: inflight.version.clone().expect("version merged"),
                value: v.clone(),
            },
            _ => unreachable!("phase/op mismatch"),
        }
    }

    /// Resolve the key's preference list to actor ids.
    fn resolve_targets(&self, op: &AppOp) -> Vec<ProcId> {
        self.router
            .replicas(op.key())
            .iter()
            .map(|&s| self.servers[s as usize])
            .collect()
    }

    fn start_app_op(&mut self, ctx: &mut Ctx, op: AppOp) {
        let req = self.next_req;
        self.next_req += 1;
        let phase = match op {
            AppOp::Get(_) => Phase::Get,
            AppOp::Put(..) => Phase::GetVersion,
        };
        let targets = self.resolve_targets(&op);
        let inflight = Inflight {
            app_op: op,
            phase,
            req,
            targets: targets.clone(),
            refused: Vec::new(),
            replies: Vec::new(),
            round: 1,
            started: ctx.now(),
            version: None,
        };
        let wire = self.wire_op(phase, &inflight);
        self.inflight = Some(inflight);
        self.broadcast(ctx, &targets, req, &wire);
        ctx.schedule(self.timing.timeout_round1, req);
    }

    /// Move a PUT from the version phase to the write phase (same key ⇒
    /// same preference list).
    fn start_put_phase(&mut self, ctx: &mut Ctx) {
        let req = self.next_req;
        self.next_req += 1;
        let inflight = self.inflight.as_mut().unwrap();
        inflight.phase = Phase::Put;
        inflight.req = req;
        inflight.refused.clear();
        inflight.replies.clear();
        inflight.round = 1;
        let targets = inflight.targets.clone();
        let wire = self.wire_op(Phase::Put, self.inflight.as_ref().unwrap());
        self.broadcast(ctx, &targets, req, &wire);
        ctx.schedule(self.timing.timeout_round1, req);
    }

    fn required(&self, phase: Phase) -> usize {
        match phase {
            Phase::Get | Phase::GetVersion => self.cfg.r,
            Phase::Put => self.cfg.w,
        }
    }

    fn complete(&mut self, ctx: &mut Ctx, outcome: OpOutcome) {
        let inflight = self.inflight.take().expect("inflight");
        match &outcome {
            OpOutcome::Failed => {
                self.ops_failed += 1;
                self.metrics.borrow_mut().record_app_failure(self.idx as usize);
            }
            _ => {
                self.ops_ok += 1;
                let latency = ctx.now() - inflight.started;
                self.metrics.borrow_mut().record_app(self.idx as usize, ctx.now(), latency);
            }
        }
        self.advance(ctx, Some((inflight.app_op, outcome)));
    }

    fn advance(&mut self, ctx: &mut Ctx, last: Option<(AppOp, OpOutcome)>) {
        let now = ctx.now();
        let idx = self.idx;
        let action = {
            let mut env = AppEnv { now, client_idx: idx, rng: ctx.rng() };
            self.app.next(&mut env, last)
        };
        match action {
            AppAction::Op(op) => {
                if self.timing.think > 0 {
                    // model client-side processing between operations
                    self.stashed = Some(op);
                    self.think_seq += 1;
                    ctx.schedule(self.timing.think, THINK_FLAG | self.think_seq);
                } else {
                    self.start_app_op(ctx, op);
                }
            }
            AppAction::Sleep(d) => ctx.schedule(d, TAG_WAKE),
            AppAction::Done => self.done = true,
        }
    }

    fn try_finish_phase(&mut self, ctx: &mut Ctx) {
        let inflight = self.inflight.as_ref().unwrap();
        let needed = self.required(inflight.phase);
        if inflight.replies.len() < needed {
            return;
        }
        match inflight.phase {
            Phase::Get => {
                let lists: Vec<Vec<Versioned>> = inflight
                    .replies
                    .iter()
                    .filter_map(|(_, r)| match r {
                        ServerReply::Values(v) => Some(v.clone()),
                        _ => None,
                    })
                    .collect();
                let merged = merge_siblings(lists);
                self.complete(ctx, OpOutcome::GetOk(merged));
            }
            Phase::GetVersion => {
                // merge every returned version; the write's version must
                // dominate everything the read quorum has seen
                let mut merged = VectorClock::new();
                for (_, r) in &inflight.replies {
                    if let ServerReply::Versions(vs) = r {
                        for v in vs {
                            merged = merged.merge(v);
                        }
                    }
                }
                merged.increment(self.idx);
                self.inflight.as_mut().unwrap().version = Some(merged);
                self.start_put_phase(ctx);
            }
            Phase::Put => {
                self.complete(ctx, OpOutcome::PutOk);
            }
        }
    }

    fn on_reply(&mut self, ctx: &mut Ctx, from: ProcId, req: u64, reply: ServerReply) {
        let Some(inflight) = self.inflight.as_mut() else { return };
        if inflight.req != req {
            return; // stale reply from a previous phase/op
        }
        if matches!(reply, ServerReply::Frozen) {
            return; // transient — the serial round may still succeed
        }
        if matches!(reply, ServerReply::WrongServer) {
            // deterministic refusal: fail fast once the servers still able
            // to ack cannot form the quorum
            if !inflight.refused.contains(&from) {
                inflight.refused.push(from);
            }
            let alive = inflight.targets.len() - inflight.refused.len();
            let phase = inflight.phase;
            if alive < self.required(phase) {
                self.complete(ctx, OpOutcome::Failed);
            }
            return;
        }
        if inflight.replies.iter().any(|(s, _)| *s == from) {
            return; // duplicate (second-round overlap)
        }
        inflight.replies.push((from, reply));
        self.try_finish_phase(ctx);
    }

    fn on_timeout(&mut self, ctx: &mut Ctx, req: u64) {
        let (cur_req, n_replies, phase, round) = match self.inflight.as_ref() {
            Some(i) => (i.req, i.replies.len(), i.phase, i.round),
            None => return,
        };
        if cur_req != req {
            return; // stale timer
        }
        if n_replies >= self.required(phase) {
            return; // already finished (defensive)
        }
        let inflight = self.inflight.as_mut().unwrap();
        let _ = round;
        if inflight.round == 1 {
            // serial second round: re-request from non-responders
            inflight.round = 2;
            let responded: Vec<ProcId> = inflight.replies.iter().map(|(s, _)| *s).collect();
            let refused = inflight.refused.clone();
            let targets: Vec<ProcId> = inflight
                .targets
                .iter()
                .copied()
                .filter(|s| !responded.contains(s) && !refused.contains(s))
                .collect();
            let phase = inflight.phase;
            let wire = self.wire_op(phase, self.inflight.as_ref().unwrap());
            self.broadcast(ctx, &targets, req, &wire);
            ctx.schedule(self.timing.timeout_round2, req);
        } else {
            self.complete(ctx, OpOutcome::Failed);
        }
    }
}

impl Actor for ClientActor {
    fn on_start(&mut self, ctx: &mut Ctx) {
        self.advance(ctx, None);
    }

    fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg) {
        match msg {
            Msg::Reply { req, reply, hvc } => {
                self.merge_seen(&hvc);
                self.on_reply(ctx, from, req, reply);
            }
            Msg::Rollback(RollbackMsg::Notify { t_violate_ms, .. }) => {
                let abort = {
                    let now = ctx.now();
                    let idx = self.idx;
                    let mut env = AppEnv { now, client_idx: idx, rng: ctx.rng() };
                    self.app.on_violation(&mut env, t_violate_ms)
                };
                if abort && !self.done {
                    self.restarts += 1;
                    self.inflight = None; // outstanding replies/timers go stale
                    self.stashed = None;
                    self.think_seq += 1; // pending think timers go stale too
                    self.advance(ctx, None);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, tag: u64) {
        if tag & THINK_FLAG != 0 {
            if (tag & !THINK_FLAG) == self.think_seq {
                if let Some(op) = self.stashed.take() {
                    if !self.done {
                        self.start_app_op(ctx, op);
                    }
                }
            }
        } else if tag == TAG_WAKE {
            if !self.done && self.inflight.is_none() {
                self.advance(ctx, None);
            }
        } else {
            self.on_timeout(ctx, tag);
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::ring::{Ring, Router};
    use crate::store::value::{Interner, Value};

    fn test_client(cluster: usize, cfg: ConsistencyCfg) -> ClientActor {
        let interner = Interner::new();
        let router = Router::new(Ring::new(cluster, cfg.n, 8, 1), interner);
        ClientActor::new(
            0,
            (0..cluster as u32).map(ProcId).collect(),
            router,
            cfg,
            ClientTiming::default(),
            Box::new(crate::client::app::ScriptApp::new(vec![])),
            crate::metrics::throughput::MetricsHub::new(cluster, 1),
        )
    }

    #[test]
    fn wire_op_mapping() {
        // phase/op translation is pure; exercised without a sim
        let client = test_client(3, ConsistencyCfg::n3r1w1());
        let inf = Inflight {
            app_op: AppOp::Put(crate::store::value::KeyId(4), Value::Int(9)),
            phase: Phase::GetVersion,
            req: 1,
            targets: vec![ProcId(0), ProcId(1), ProcId(2)],
            refused: vec![],
            replies: vec![],
            round: 1,
            started: 0,
            version: Some(VectorClock::new().incremented(0)),
        };
        assert!(matches!(client.wire_op(Phase::GetVersion, &inf), ServerOp::GetVersion(_)));
        assert!(matches!(client.wire_op(Phase::Put, &inf), ServerOp::Put { .. }));
    }

    #[test]
    fn required_quorums() {
        let client = test_client(3, ConsistencyCfg::n3r2w2());
        assert_eq!(client.required(Phase::Get), 2);
        assert_eq!(client.required(Phase::GetVersion), 2);
        assert_eq!(client.required(Phase::Put), 2);
    }

    #[test]
    fn targets_resolve_to_n_servers_in_a_larger_cluster() {
        let interner = Interner::new();
        let key = interner.borrow_mut().intern("x_0_0");
        let cfg = ConsistencyCfg::n3r1w1();
        let router = Router::new(Ring::new(12, cfg.n, 64, 1), interner);
        let client = ClientActor::new(
            0,
            (0..12u32).map(ProcId).collect(),
            router,
            cfg,
            ClientTiming::default(),
            Box::new(crate::client::app::ScriptApp::new(vec![])),
            crate::metrics::throughput::MetricsHub::new(12, 1),
        );
        let targets = client.resolve_targets(&AppOp::Get(key));
        assert_eq!(targets.len(), 3, "N = 3 replicas out of 12 servers");
        assert!(targets.iter().all(|p| p.0 < 12));
    }
}
