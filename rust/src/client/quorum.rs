//! The N/R/W quorum state machine, extracted from the client actor as a
//! *transport-agnostic* engine (§II-B, §VI-A):
//!
//! * **parallel phase** — broadcast to the key's whole preference list,
//!   wait for R (GET / GET_VERSION) or W (PUT) distinct acknowledgements;
//! * **serial phase** — on timeout, one more round to the servers that
//!   have not responded; if the quorum is still not met, the op fails;
//! * an application PUT is GET_VERSION (quorum R) followed by PUT
//!   (quorum W) with the merged, incremented vector clock (§VI-A);
//! * `WrongServer` refusals are deterministic, so the call *fast-fails*
//!   the moment the servers still able to ack cannot form a quorum;
//! * duplicate replies (first-round stragglers overlapping the serial
//!   round) and stale replies/timers from a previous request id are
//!   ignored.
//!
//! Every transition is a pure function from `(state, event)` to
//! `(state', QuorumStep)` — no simulator context, no message sending, no
//! timers. The client actor ([`crate::client::actor`]) is the transport:
//! it turns [`QuorumStep::Send`] into wire messages plus a timeout timer
//! and multiplexes up to `pipeline_depth` concurrent calls. This split is
//! what the transport-free unit tests below exercise.

use std::collections::HashMap;

use crate::client::app::{AppOp, OpOutcome};
use crate::client::consistency::ConsistencyCfg;
use crate::clock::vc::VectorClock;
use crate::sim::{ProcId, Time};
use crate::store::protocol::{ServerOp, ServerReply};
use crate::store::value::{merge_siblings, KeyId, Value, Versioned};

/// Which wire operation the call is currently waiting on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuorumPhase {
    Get,
    GetVersion,
    Put,
}

/// What the transport must do after feeding an event into the engine.
#[derive(Debug)]
pub enum QuorumStep {
    /// nothing to do — keep waiting for replies or the timer
    Wait,
    /// send `op` to every server in `to` under request id `req` and arm
    /// the round-`round` timeout (round 1 = parallel phase, round 2 =
    /// serial phase)
    Send { req: u64, to: Vec<ProcId>, op: ServerOp, round: u8 },
    /// the call is finished; the engine holds no further state for it
    Done(OpOutcome),
}

/// One application operation moving through the quorum protocol.
///
/// An `AppOp::Get` is a single `Get` phase; an `AppOp::Put` chains
/// `GetVersion` (quorum R) into `Put` (quorum W), consuming a fresh
/// request id for the write phase so late version replies cannot be
/// mistaken for write acks.
pub struct QuorumCall {
    /// the vector-clock node id stamped into merged write versions
    client_idx: u32,
    cfg: ConsistencyCfg,
    /// the consistency epoch this call was issued under
    /// ([`crate::adapt`]): an epoch switch announced mid-call never
    /// changes `cfg` — the call completes with the quorum sizes of its
    /// issue epoch, and only calls opened afterwards use the new config
    pub epoch: u64,
    /// the application-level operation this call executes
    pub app_op: AppOp,
    phase: QuorumPhase,
    /// current wire request id (changes at the GET_VERSION → PUT switch)
    req: u64,
    /// the key's preference list, resolved once by the transport
    targets: Vec<ProcId>,
    /// servers that refused with WrongServer (deterministic: they will
    /// never ack this key, so they are excluded from the serial round)
    refused: Vec<ProcId>,
    /// distinct servers that answered (usable replies), in arrival order
    replies: Vec<(ProcId, ServerReply)>,
    round: u8,
    /// when the transport issued the call (for latency metrics)
    pub started: Time,
    /// merged version for the PUT phase
    version: Option<VectorClock>,
}

impl QuorumCall {
    /// Begin a call: returns the engine plus the round-1 broadcast.
    pub fn new(
        client_idx: u32,
        cfg: ConsistencyCfg,
        app_op: AppOp,
        req: u64,
        targets: Vec<ProcId>,
        started: Time,
        epoch: u64,
    ) -> (Self, QuorumStep) {
        let phase = match app_op {
            AppOp::Get(_) => QuorumPhase::Get,
            AppOp::Put(..) => QuorumPhase::GetVersion,
        };
        let call = Self {
            client_idx,
            cfg,
            epoch,
            app_op,
            phase,
            req,
            targets,
            refused: Vec::new(),
            replies: Vec::new(),
            round: 1,
            started,
            version: None,
        };
        let step = QuorumStep::Send {
            req,
            to: call.targets.clone(),
            op: call.wire_op(),
            round: 1,
        };
        (call, step)
    }

    pub fn req(&self) -> u64 {
        self.req
    }

    pub fn phase(&self) -> QuorumPhase {
        self.phase
    }

    /// The merged write version (present once the GET_VERSION phase has
    /// settled) — the causal session records it as a read-your-writes
    /// floor when the PUT completes.
    pub fn version(&self) -> Option<&VectorClock> {
        self.version.as_ref()
    }

    /// Acks required to finish the current phase.
    fn required(&self) -> usize {
        match self.phase {
            QuorumPhase::Get | QuorumPhase::GetVersion => self.cfg.r,
            QuorumPhase::Put => self.cfg.w,
        }
    }

    /// The wire operation of the current phase.
    fn wire_op(&self) -> ServerOp {
        match (self.phase, &self.app_op) {
            (QuorumPhase::Get, AppOp::Get(k)) => ServerOp::Get(*k),
            (QuorumPhase::GetVersion, AppOp::Put(k, _)) => ServerOp::GetVersion(*k),
            (QuorumPhase::Put, AppOp::Put(k, v)) => ServerOp::Put {
                key: *k,
                version: self.version.clone().expect("version merged"),
                value: v.clone(),
            },
            _ => unreachable!("phase/op mismatch"),
        }
    }

    /// A reply arrived. `next_req` allocates the write-phase request id
    /// and is invoked only at the GET_VERSION → PUT transition.
    pub fn on_reply(
        &mut self,
        from: ProcId,
        req: u64,
        reply: ServerReply,
        next_req: impl FnOnce() -> u64,
    ) -> QuorumStep {
        if self.req != req {
            return QuorumStep::Wait; // stale reply from a previous phase
        }
        if matches!(reply, ServerReply::Frozen) {
            return QuorumStep::Wait; // transient — the serial round may still succeed
        }
        if matches!(reply, ServerReply::WrongServer) {
            // deterministic refusal: fail fast once the servers still able
            // to ack cannot form the quorum
            if !self.refused.contains(&from) {
                self.refused.push(from);
            }
            let alive = self.targets.len() - self.refused.len();
            if alive < self.required() {
                return QuorumStep::Done(OpOutcome::Failed);
            }
            return QuorumStep::Wait;
        }
        if self.replies.iter().any(|(s, _)| *s == from) {
            return QuorumStep::Wait; // duplicate (second-round overlap)
        }
        self.replies.push((from, reply));
        if self.replies.len() < self.required() {
            return QuorumStep::Wait;
        }
        match self.phase {
            QuorumPhase::Get => {
                let lists: Vec<Vec<Versioned>> = self
                    .replies
                    .iter()
                    .filter_map(|(_, r)| match r {
                        ServerReply::Values(v) => Some(v.clone()),
                        _ => None,
                    })
                    .collect();
                QuorumStep::Done(OpOutcome::GetOk(merge_siblings(lists)))
            }
            QuorumPhase::GetVersion => {
                // merge every returned version; the write's version must
                // dominate everything the read quorum has seen
                let mut merged = VectorClock::new();
                for (_, r) in &self.replies {
                    if let ServerReply::Versions(vs) = r {
                        for v in vs {
                            merged.merge_from(v);
                        }
                    }
                }
                merged.increment(self.client_idx);
                self.version = Some(merged);
                // write phase under a fresh request id (same key ⇒ same
                // preference list)
                self.phase = QuorumPhase::Put;
                self.req = next_req();
                self.refused.clear();
                self.replies.clear();
                self.round = 1;
                QuorumStep::Send {
                    req: self.req,
                    to: self.targets.clone(),
                    op: self.wire_op(),
                    round: 1,
                }
            }
            QuorumPhase::Put => QuorumStep::Done(OpOutcome::PutOk),
        }
    }

    /// The round timer fired.
    pub fn on_timeout(&mut self, req: u64) -> QuorumStep {
        if self.req != req {
            return QuorumStep::Wait; // stale timer
        }
        if self.replies.len() >= self.required() {
            return QuorumStep::Wait; // already finished (defensive)
        }
        if self.round == 1 {
            // serial second round: re-request from non-responders
            self.round = 2;
            let to: Vec<ProcId> = self
                .targets
                .iter()
                .copied()
                .filter(|s| {
                    !self.replies.iter().any(|(r, _)| r == s) && !self.refused.contains(s)
                })
                .collect();
            QuorumStep::Send { req: self.req, to, op: self.wire_op(), round: 2 }
        } else {
            QuorumStep::Done(OpOutcome::Failed)
        }
    }
}

/// Client-side session guarantees (Terry-style) for the causal mode
/// ([`ConsistencyCfg::causal`]): **read-your-writes** and **monotonic
/// reads** per client session, layered purely on the client — no extra
/// quorum round trips, no protocol change, no server state.
///
/// The session keeps, per key it has touched, the *floor*: the sibling
/// set the session must never observe the store regress below — its own
/// committed writes plus every version a previous GET returned. A GET
/// result is patched by vector-clock dominance against the floor
/// (genuinely concurrent siblings survive, dominated stragglers from a
/// thin R = 1 quorum are replaced), and the floor then rises to the
/// patched result. Combined with the server-side HVC piggy-backing this
/// gives each session a causal view at eventual-mode quorum cost.
///
/// Rollback interaction: a recovery that rewinds server state makes the
/// floor a lie — the floors must be dropped ([`Session::clear`]) when
/// the client handles the controller's rollback notification, otherwise
/// the session would resurrect rolled-back writes into fresh reads.
#[derive(Default)]
pub struct Session {
    floor: HashMap<KeyId, Vec<Versioned>>,
}

impl Session {
    pub fn new() -> Self {
        Self::default()
    }

    /// A committed write: the floor for `key` now includes it
    /// (read-your-writes).
    pub fn on_put(&mut self, key: KeyId, version: &VectorClock, value: &Value) {
        let entry = self.floor.entry(key).or_default();
        crate::store::value::insert_version(
            entry,
            Versioned::new(version.clone(), value.clone()),
        );
    }

    /// Patch a GET result against the floor and raise the floor to the
    /// patched result (monotonic reads).
    pub fn patch_get(&mut self, key: KeyId, sibs: Vec<Versioned>) -> Vec<Versioned> {
        let entry = self.floor.entry(key).or_default();
        let patched = merge_siblings([sibs, std::mem::take(entry)]);
        entry.clone_from(&patched);
        patched
    }

    /// Forget every floor — required when a rollback notification
    /// arrives (server state may have rewound past the floors) and when
    /// the client churns out (the session died with its connection).
    pub fn clear(&mut self) {
        self.floor.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::value::{KeyId, Value};

    fn targets(n: usize) -> Vec<ProcId> {
        (0..n as u32).map(ProcId).collect()
    }

    fn values_reply(v: i64, node: u32) -> ServerReply {
        ServerReply::Values(vec![Versioned::new(
            VectorClock::new().incremented(node),
            Value::Int(v),
        )])
    }

    fn no_req() -> u64 {
        panic!("next_req must not be called here")
    }

    #[test]
    fn get_completes_at_r_distinct_replies() {
        let cfg = ConsistencyCfg::n3r2w2();
        let (mut call, step) =
            QuorumCall::new(0, cfg, AppOp::Get(KeyId(1)), 1, targets(3), 0, 0);
        match step {
            QuorumStep::Send { req: 1, ref to, op: ServerOp::Get(_), round: 1 } => {
                assert_eq!(to.len(), 3, "parallel phase hits the whole preference list");
            }
            other => panic!("unexpected step {other:?}"),
        }
        assert!(matches!(
            call.on_reply(ProcId(0), 1, values_reply(5, 7), no_req),
            QuorumStep::Wait
        ));
        match call.on_reply(ProcId(2), 1, values_reply(5, 7), no_req) {
            QuorumStep::Done(OpOutcome::GetOk(sibs)) => assert_eq!(sibs.len(), 1),
            other => panic!("unexpected step {other:?}"),
        }
    }

    #[test]
    fn put_chains_version_then_write_under_fresh_req() {
        let cfg = ConsistencyCfg::n3r1w3();
        let (mut call, _) =
            QuorumCall::new(4, cfg, AppOp::Put(KeyId(2), Value::Int(9)), 1, targets(3), 0, 0);
        assert_eq!(call.phase(), QuorumPhase::GetVersion);
        let step = call.on_reply(
            ProcId(1),
            1,
            ServerReply::Versions(vec![VectorClock::new().incremented(0)]),
            || 2,
        );
        match step {
            QuorumStep::Send { req: 2, ref to, op: ServerOp::Put { ref version, .. }, round: 1 } => {
                assert_eq!(to.len(), 3);
                // merged version dominates the read and carries our entry
                assert_eq!(version.get(0), 1);
                assert_eq!(version.get(4), 1);
            }
            other => panic!("unexpected step {other:?}"),
        }
        assert_eq!(call.req(), 2);
        assert_eq!(call.phase(), QuorumPhase::Put);
        // late version replies under the old request id are stale
        assert!(matches!(
            call.on_reply(ProcId(0), 1, ServerReply::Versions(vec![]), no_req),
            QuorumStep::Wait
        ));
        // W = 3: two acks wait, the third finishes
        assert!(matches!(
            call.on_reply(ProcId(0), 2, ServerReply::PutAck, no_req),
            QuorumStep::Wait
        ));
        assert!(matches!(
            call.on_reply(ProcId(1), 2, ServerReply::PutAck, no_req),
            QuorumStep::Wait
        ));
        assert!(matches!(
            call.on_reply(ProcId(2), 2, ServerReply::PutAck, no_req),
            QuorumStep::Done(OpOutcome::PutOk)
        ));
    }

    #[test]
    fn serial_round_retries_only_non_responders() {
        let cfg = ConsistencyCfg::n3r1w3();
        let (mut call, _) =
            QuorumCall::new(0, cfg, AppOp::Put(KeyId(3), Value::Int(1)), 1, targets(3), 0, 0);
        let _ = call.on_reply(ProcId(0), 1, ServerReply::Versions(vec![]), || 2);
        // write phase: only server 1 acks in round 1
        let _ = call.on_reply(ProcId(1), 2, ServerReply::PutAck, no_req);
        match call.on_timeout(2) {
            QuorumStep::Send { req: 2, ref to, round: 2, .. } => {
                assert_eq!(*to, vec![ProcId(0), ProcId(2)], "responders are not re-asked");
            }
            other => panic!("unexpected step {other:?}"),
        }
        // stragglers from both rounds land; quorum completes
        let _ = call.on_reply(ProcId(0), 2, ServerReply::PutAck, no_req);
        assert!(matches!(
            call.on_reply(ProcId(2), 2, ServerReply::PutAck, no_req),
            QuorumStep::Done(OpOutcome::PutOk)
        ));
    }

    #[test]
    fn second_timeout_fails_the_call() {
        let cfg = ConsistencyCfg::n3r2w2();
        let (mut call, _) = QuorumCall::new(0, cfg, AppOp::Get(KeyId(4)), 7, targets(3), 0, 0);
        assert!(matches!(call.on_timeout(7), QuorumStep::Send { round: 2, .. }));
        assert!(matches!(
            call.on_timeout(7),
            QuorumStep::Done(OpOutcome::Failed)
        ));
    }

    #[test]
    fn wrong_server_fast_fails_once_quorum_impossible() {
        let cfg = ConsistencyCfg::n3r2w2();
        let (mut call, _) = QuorumCall::new(0, cfg, AppOp::Get(KeyId(5)), 1, targets(3), 0, 0);
        // one refusal leaves 2 ≥ R=2 alive — keep going
        assert!(matches!(
            call.on_reply(ProcId(0), 1, ServerReply::WrongServer, no_req),
            QuorumStep::Wait
        ));
        // the same server refusing again is not double-counted
        assert!(matches!(
            call.on_reply(ProcId(0), 1, ServerReply::WrongServer, no_req),
            QuorumStep::Wait
        ));
        // a second distinct refusal leaves 1 < R=2 — fail immediately,
        // without waiting out both timeout rounds
        assert!(matches!(
            call.on_reply(ProcId(1), 1, ServerReply::WrongServer, no_req),
            QuorumStep::Done(OpOutcome::Failed)
        ));
    }

    #[test]
    fn refused_servers_are_excluded_from_the_serial_round() {
        let cfg = ConsistencyCfg::n3r2w2();
        let (mut call, _) = QuorumCall::new(0, cfg, AppOp::Get(KeyId(6)), 1, targets(3), 0, 0);
        let _ = call.on_reply(ProcId(1), 1, ServerReply::WrongServer, no_req);
        match call.on_timeout(1) {
            QuorumStep::Send { ref to, round: 2, .. } => {
                assert_eq!(*to, vec![ProcId(0), ProcId(2)], "refusers are never re-asked");
            }
            other => panic!("unexpected step {other:?}"),
        }
    }

    #[test]
    fn duplicate_replies_from_round_overlap_are_deduped() {
        let cfg = ConsistencyCfg::n3r2w2();
        let (mut call, _) = QuorumCall::new(0, cfg, AppOp::Get(KeyId(7)), 1, targets(3), 0, 0);
        let _ = call.on_reply(ProcId(0), 1, values_reply(1, 0), no_req);
        // round-2 re-send overlaps a straggling first answer: same server
        // must not count twice toward R = 2
        assert!(matches!(
            call.on_reply(ProcId(0), 1, values_reply(1, 0), no_req),
            QuorumStep::Wait
        ));
        assert!(matches!(
            call.on_reply(ProcId(2), 1, values_reply(1, 0), no_req),
            QuorumStep::Done(OpOutcome::GetOk(_))
        ));
    }

    #[test]
    fn frozen_replies_do_not_count_toward_the_quorum() {
        let cfg = ConsistencyCfg::n3r1w1();
        let (mut call, _) = QuorumCall::new(0, cfg, AppOp::Get(KeyId(8)), 1, targets(3), 0, 0);
        assert!(matches!(
            call.on_reply(ProcId(0), 1, ServerReply::Frozen, no_req),
            QuorumStep::Wait
        ));
        assert!(matches!(
            call.on_reply(ProcId(0), 1, values_reply(2, 0), no_req),
            QuorumStep::Done(OpOutcome::GetOk(_))
        ));
    }

    #[test]
    fn stale_request_ids_are_ignored() {
        let cfg = ConsistencyCfg::n3r1w1();
        let (mut call, _) = QuorumCall::new(0, cfg, AppOp::Get(KeyId(9)), 5, targets(3), 0, 0);
        assert!(matches!(
            call.on_reply(ProcId(0), 4, values_reply(1, 0), no_req),
            QuorumStep::Wait
        ));
        assert!(matches!(call.on_timeout(4), QuorumStep::Wait));
        // the real reply still completes
        assert!(matches!(
            call.on_reply(ProcId(0), 5, values_reply(1, 0), no_req),
            QuorumStep::Done(OpOutcome::GetOk(_))
        ));
    }

    #[test]
    fn a_call_keeps_its_issue_epoch_quorum_sizes() {
        // epoch discipline ([`crate::adapt`]): the call was issued under
        // epoch 3 / R=2 — whatever config the client adopts afterwards,
        // THIS call still needs two distinct replies to complete
        let cfg = ConsistencyCfg::n3r2w2();
        let (mut call, _) = QuorumCall::new(0, cfg, AppOp::Get(KeyId(11)), 1, targets(3), 0, 3);
        assert_eq!(call.epoch, 3);
        assert!(matches!(
            call.on_reply(ProcId(0), 1, values_reply(1, 0), no_req),
            QuorumStep::Wait
        ));
        assert!(matches!(
            call.on_reply(ProcId(2), 1, values_reply(1, 0), no_req),
            QuorumStep::Done(OpOutcome::GetOk(_))
        ));
    }

    #[test]
    fn session_read_your_writes() {
        // the session's own committed write must show up in a later GET
        // even when a thin R = 1 quorum answers from a replica the write
        // has not reached yet (empty result)
        let mut s = Session::new();
        let k = KeyId(1);
        let wrote = VectorClock::new().incremented(4);
        s.on_put(k, &wrote, &Value::Int(9));
        let got = s.patch_get(k, vec![]);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].value, Value::Int(9));
        assert_eq!(got[0].version, wrote);
    }

    #[test]
    fn session_monotonic_reads_under_reordered_replies() {
        // first GET observes v2 (dominates v1); a later GET served by a
        // lagging replica returns only v1 — the session patches it back
        // up to v2 instead of letting the read regress
        let mut s = Session::new();
        let k = KeyId(2);
        let v1 = VectorClock::new().incremented(0);
        let mut v2 = v1.clone();
        v2.increment(0);
        let first = s.patch_get(k, vec![Versioned::new(v2.clone(), Value::Int(2))]);
        assert_eq!(first.len(), 1);
        let second = s.patch_get(k, vec![Versioned::new(v1, Value::Int(1))]);
        assert_eq!(second.len(), 1, "the stale sibling is dominated away");
        assert_eq!(second[0].value, Value::Int(2), "the read never goes backwards");
        assert_eq!(second[0].version, v2);
    }

    #[test]
    fn session_preserves_genuinely_concurrent_siblings() {
        let mut s = Session::new();
        let k = KeyId(3);
        let a = VectorClock::new().incremented(0);
        let b = VectorClock::new().incremented(1);
        s.on_put(k, &a, &Value::Int(10));
        let got = s.patch_get(k, vec![Versioned::new(b, Value::Int(11))]);
        assert_eq!(got.len(), 2, "concurrent versions both survive the patch");
    }

    #[test]
    fn session_clear_forgets_the_floors() {
        // after a rollback notification the floors may describe rewound
        // state: clearing must let the next GET accept whatever the
        // (restored) store answers
        let mut s = Session::new();
        let k = KeyId(4);
        let v2 = VectorClock::new().incremented(0).incremented(0);
        s.on_put(k, &v2, &Value::Int(2));
        s.clear();
        let old = VectorClock::new().incremented(0);
        let got = s.patch_get(k, vec![Versioned::new(old.clone(), Value::Int(1))]);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].version, old, "the rewound version is accepted as-is");
    }

    #[test]
    fn late_quorum_timer_is_a_noop() {
        let cfg = ConsistencyCfg::n3r1w1();
        let (mut call, _) = QuorumCall::new(0, cfg, AppOp::Get(KeyId(10)), 1, targets(3), 0, 0);
        let _ = call.on_reply(ProcId(1), 1, values_reply(3, 1), no_req);
        // quorum already met when the round-1 timer fires (defensive)
        assert!(matches!(call.on_timeout(1), QuorumStep::Wait));
    }
}
