//! The application interface: apps are state machines the client actor
//! drives through store operations — one at a time (closed loop, as in
//! the paper's client processes), or, when the client pipeline is enabled
//! (`pipeline_depth > 1`), a *batch* of independent operations issued as
//! one scatter-gather wave.

use crate::clock::hvc::Millis;
use crate::sim::Time;
use crate::store::value::{KeyId, Value, Versioned};
use crate::util::rng::Rng;

/// Application-level operations (the client library translates a PUT into
/// GET_VERSION + PUT wire ops).
#[derive(Debug, Clone)]
pub enum AppOp {
    Get(KeyId),
    Put(KeyId, Value),
}

impl AppOp {
    pub fn key(&self) -> KeyId {
        match self {
            AppOp::Get(k) => *k,
            AppOp::Put(k, _) => *k,
        }
    }
}

/// Outcome handed back to the app.
#[derive(Debug, Clone)]
pub enum OpOutcome {
    /// merged sibling versions from R replicas
    GetOk(Vec<Versioned>),
    PutOk,
    /// quorum not reached after both rounds
    Failed,
}

impl OpOutcome {
    pub fn ok(&self) -> bool {
        !matches!(self, OpOutcome::Failed)
    }
}

/// What the app wants next.
#[derive(Debug, Clone)]
pub enum AppAction {
    /// one operation, result delivered as [`LastResult::Op`]
    Op(AppOp),
    /// independent operations issued concurrently (scatter), with every
    /// outcome delivered together as [`LastResult::Batch`] once the last
    /// one completes (gather). Must be non-empty. With `pipeline_depth`
    /// = 1 the wave degrades gracefully to sequential issue, so apps
    /// should only emit batches when `AppEnv::pipelined()` says the
    /// client can exploit them.
    Batch(Vec<AppOp>),
    Sleep(Time),
    Done,
}

/// The completed previous action, fed back into [`AppLogic::next`].
#[derive(Debug, Clone)]
pub enum LastResult {
    /// outcome of an [`AppAction::Op`]
    Op(AppOp, OpOutcome),
    /// outcomes of an [`AppAction::Batch`], in submission order
    Batch(Vec<(AppOp, OpOutcome)>),
}

impl LastResult {
    /// All `(op, outcome)` pairs, whatever the action shape was.
    pub fn into_pairs(self) -> Vec<(AppOp, OpOutcome)> {
        match self {
            LastResult::Op(op, out) => vec![(op, out)],
            LastResult::Batch(pairs) => pairs,
        }
    }
}

/// Ambient facilities passed into app callbacks.
pub struct AppEnv<'a> {
    pub rng: &'a mut Rng,
    pub now: Time,
    /// sequence key of the event being dispatched ([`crate::sim::des::Ctx::event_seq`]).
    /// `(now, seq)` totally orders app steps across engines and shard
    /// counts; apps stamp oracle log entries with it.
    pub seq: u64,
    pub client_idx: u32,
    /// the client's `pipeline_depth`: how many quorum calls it can keep
    /// in flight. 1 = the paper's serial closed-loop client.
    pub pipeline: usize,
}

impl AppEnv<'_> {
    /// Can the client overlap independent operations? Apps use this to
    /// choose between the serial paths (which reproduce the paper's
    /// closed-loop runs exactly) and scatter-gather batches.
    pub fn pipelined(&self) -> bool {
        self.pipeline > 1
    }
}

pub trait AppLogic {
    /// Called with the result of the previous action (None on first call /
    /// after a restart) — returns the next action.
    fn next(&mut self, env: &mut AppEnv, last: Option<LastResult>) -> AppAction;

    /// A violation was reported (rollback controller broadcast). Return
    /// true to abort the in-flight action and restart via `next(None)` —
    /// the paper's task abort-and-restart recovery for graph apps.
    fn on_violation(&mut self, _env: &mut AppEnv, _t_violate_ms: Millis) -> bool {
        false
    }

    /// App label for reports.
    fn name(&self) -> &'static str {
        "app"
    }
}

/// Trivial app for tests: run a fixed script of operations.
pub struct ScriptApp {
    pub script: Vec<AppOp>,
    pub pos: usize,
    pub outcomes: Vec<OpOutcome>,
}

impl ScriptApp {
    pub fn new(script: Vec<AppOp>) -> Self {
        Self { script, pos: 0, outcomes: Vec::new() }
    }
}

impl AppLogic for ScriptApp {
    fn next(&mut self, _env: &mut AppEnv, last: Option<LastResult>) -> AppAction {
        if let Some(res) = last {
            self.outcomes.extend(res.into_pairs().into_iter().map(|(_, o)| o));
        }
        if self.pos < self.script.len() {
            let op = self.script[self.pos].clone();
            self.pos += 1;
            AppAction::Op(op)
        } else {
            AppAction::Done
        }
    }

    fn name(&self) -> &'static str {
        "script"
    }
}
