//! The application interface: apps are state machines that the client
//! actor drives one store operation at a time (closed loop, as in the
//! paper's client processes).

use crate::clock::hvc::Millis;
use crate::sim::Time;
use crate::store::value::{KeyId, Value, Versioned};
use crate::util::rng::Rng;

/// Application-level operations (the client library translates a PUT into
/// GET_VERSION + PUT wire ops).
#[derive(Debug, Clone)]
pub enum AppOp {
    Get(KeyId),
    Put(KeyId, Value),
}

impl AppOp {
    pub fn key(&self) -> KeyId {
        match self {
            AppOp::Get(k) => *k,
            AppOp::Put(k, _) => *k,
        }
    }
}

/// Outcome handed back to the app.
#[derive(Debug, Clone)]
pub enum OpOutcome {
    /// merged sibling versions from R replicas
    GetOk(Vec<Versioned>),
    PutOk,
    /// quorum not reached after both rounds
    Failed,
}

impl OpOutcome {
    pub fn ok(&self) -> bool {
        !matches!(self, OpOutcome::Failed)
    }
}

/// What the app wants next.
#[derive(Debug, Clone)]
pub enum AppAction {
    Op(AppOp),
    Sleep(Time),
    Done,
}

/// Ambient facilities passed into app callbacks.
pub struct AppEnv<'a> {
    pub rng: &'a mut Rng,
    pub now: Time,
    pub client_idx: u32,
}

pub trait AppLogic {
    /// Called with the outcome of the previous op (None on first call /
    /// after a restart) — returns the next action.
    fn next(&mut self, env: &mut AppEnv, last: Option<(AppOp, OpOutcome)>) -> AppAction;

    /// A violation was reported (rollback controller broadcast). Return
    /// true to abort the in-flight op and restart via `next(None)` — the
    /// paper's task abort-and-restart recovery for graph apps.
    fn on_violation(&mut self, _env: &mut AppEnv, _t_violate_ms: Millis) -> bool {
        false
    }

    /// App label for reports.
    fn name(&self) -> &'static str {
        "app"
    }
}

/// Trivial app for tests: run a fixed script of operations.
pub struct ScriptApp {
    pub script: Vec<AppOp>,
    pub pos: usize,
    pub outcomes: Vec<OpOutcome>,
}

impl ScriptApp {
    pub fn new(script: Vec<AppOp>) -> Self {
        Self { script, pos: 0, outcomes: Vec::new() }
    }
}

impl AppLogic for ScriptApp {
    fn next(&mut self, _env: &mut AppEnv, last: Option<(AppOp, OpOutcome)>) -> AppAction {
        if let Some((_, outcome)) = last {
            self.outcomes.push(outcome);
        }
        if self.pos < self.script.len() {
            let op = self.script[self.pos].clone();
            self.pos += 1;
            AppAction::Op(op)
        } else {
            AppAction::Done
        }
    }

    fn name(&self) -> &'static str {
        "script"
    }
}
