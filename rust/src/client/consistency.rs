//! N/R/W consistency configuration (Table II).
//!
//! Voldemort clients perform the replication themselves: a PUT (GET) is
//! successful when W (R) of the N replicas acknowledge before the timeout.
//! `R + W > N ∧ W > N/2` ⇒ sequential consistency; `R + W ≤ N` ⇒ eventual.

use crate::sim::{ms, Time};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConsistencyCfg {
    pub n: usize,
    pub r: usize,
    pub w: usize,
    /// layer client-side session guarantees (read-your-writes +
    /// monotonic reads, Terry-style) on top of the quorum config: the
    /// client patches its own committed writes and previously-seen
    /// versions into GET results, so no extra quorum round trips and no
    /// protocol change — causal consistency per session at eventual-mode
    /// cost. Meaningless (and ignored) under a sequential config, which
    /// is already stronger. `false` everywhere by default.
    pub causal: bool,
}

impl ConsistencyCfg {
    pub fn new(n: usize, r: usize, w: usize) -> Self {
        assert!(n >= 1 && r >= 1 && w >= 1 && r <= n && w <= n);
        Self { n, r, w, causal: false }
    }

    /// Enable client-side session guarantees on this quorum config.
    pub fn with_causal(mut self) -> Self {
        self.causal = true;
        self
    }

    /// Table II presets.
    pub fn n3r1w3() -> Self {
        Self::new(3, 1, 3)
    }
    pub fn n3r2w2() -> Self {
        Self::new(3, 2, 2)
    }
    pub fn n3r1w1() -> Self {
        Self::new(3, 1, 1)
    }
    pub fn n5r1w5() -> Self {
        Self::new(5, 1, 5)
    }
    pub fn n5r3w3() -> Self {
        Self::new(5, 3, 3)
    }
    pub fn n5r1w1() -> Self {
        Self::new(5, 1, 1)
    }

    /// Parse e.g. "N3R1W3" (case-insensitive). Malformed input — tokens
    /// out of order (`N3W2R2`), missing digits, empty segments — returns
    /// `None`. The explicit ordering guard keeps the digit slices
    /// well-formed by construction: without it, a reordered input would
    /// build the inverted range `s[r_pos + 1..w_pos]` (reachable from the
    /// CLI `--consistency` flag) and only a parse failure on the N
    /// segment happened to stop evaluation before the slice panicked.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.to_ascii_uppercase();
        if let Some(base) = s.strip_suffix("-CAUSAL") {
            return Self::parse(base).map(Self::with_causal);
        }
        let bytes = s.as_bytes();
        if bytes.first() != Some(&b'N') {
            return None;
        }
        let r_pos = s.find('R')?;
        let w_pos = s.find('W')?;
        if !(0 < r_pos && r_pos < w_pos) {
            return None; // reordered tokens, e.g. "N3W2R2"
        }
        let n: usize = s[1..r_pos].parse().ok()?;
        let r: usize = s[r_pos + 1..w_pos].parse().ok()?;
        let w: usize = s[w_pos + 1..].parse().ok()?;
        if n >= 1 && r >= 1 && w >= 1 && r <= n && w <= n {
            Some(Self { n, r, w, causal: false })
        } else {
            None
        }
    }

    pub fn label(&self) -> String {
        if self.causal {
            format!("N{}R{}W{}-causal", self.n, self.r, self.w)
        } else {
            format!("N{}R{}W{}", self.n, self.r, self.w)
        }
    }

    /// §II-B: sequential iff `W + R > N` and `W > N/2`.
    pub fn is_sequential(&self) -> bool {
        self.w + self.r > self.n && 2 * self.w > self.n
    }

    pub fn is_eventual(&self) -> bool {
        !self.is_sequential()
    }

    pub fn model_name(&self) -> &'static str {
        if self.is_sequential() {
            "sequential"
        } else if self.causal {
            "causal"
        } else {
            "eventual"
        }
    }
}

/// Client request timing (§VI-A: parallel phase with a 500 ms timeout,
/// then a serial second round), plus per-op client *think time* — the
/// client-side processing between store operations (JVM/client-library/
/// application compute). The paper's absolute throughputs (e.g. 15
/// clients ≈ 128 ops/s aggregated on AWS, §VI-A) imply ≈115 ms per op of
/// non-network time for the Social Media Analysis clients; the regional
/// stress workloads (§VI-B) run thin clients instead.
#[derive(Debug, Clone, Copy)]
pub struct ClientTiming {
    pub timeout_round1: Time,
    pub timeout_round2: Time,
    /// client-side processing before each operation
    pub think: Time,
}

impl Default for ClientTiming {
    fn default() -> Self {
        Self { timeout_round1: ms(500.0), timeout_round2: ms(500.0), think: 0 }
    }
}

impl ClientTiming {
    pub fn with_think(think_ms: f64) -> Self {
        Self { think: ms(think_ms), ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_classification() {
        // Table II: the paper's six configurations
        assert!(ConsistencyCfg::n3r1w3().is_sequential());
        assert!(ConsistencyCfg::n3r2w2().is_sequential());
        assert!(ConsistencyCfg::n3r1w1().is_eventual());
        assert!(ConsistencyCfg::n5r1w5().is_sequential());
        assert!(ConsistencyCfg::n5r3w3().is_sequential());
        assert!(ConsistencyCfg::n5r1w1().is_eventual());
    }

    #[test]
    fn parse_labels() {
        for c in [
            ConsistencyCfg::n3r1w3(),
            ConsistencyCfg::n3r2w2(),
            ConsistencyCfg::n3r1w1(),
            ConsistencyCfg::n5r1w5(),
            ConsistencyCfg::n5r3w3(),
            ConsistencyCfg::n5r1w1(),
        ] {
            assert_eq!(ConsistencyCfg::parse(&c.label()), Some(c));
        }
        assert_eq!(ConsistencyCfg::parse("n3r2w2"), Some(ConsistencyCfg::n3r2w2()));
        assert_eq!(ConsistencyCfg::parse("bogus"), None);
        assert_eq!(ConsistencyCfg::parse("N3R4W1"), None, "r > n rejected");
        // the causal flag round-trips through its label too
        let causal = ConsistencyCfg::n3r1w1().with_causal();
        assert_eq!(causal.label(), "N3R1W1-causal");
        assert_eq!(ConsistencyCfg::parse(&causal.label()), Some(causal));
        assert_eq!(ConsistencyCfg::parse("n3r1w1-causal"), Some(causal));
        assert_eq!(ConsistencyCfg::parse("-causal"), None);
    }

    #[test]
    fn parse_rejects_malformed_without_panicking() {
        // reordered tokens (the CLI-reachable inverted-range case)
        assert_eq!(ConsistencyCfg::parse("N3W2R2"), None);
        assert_eq!(ConsistencyCfg::parse("n3w1r1"), None);
        assert_eq!(ConsistencyCfg::parse("NW2R2"), None);
        // missing digits in each segment
        assert_eq!(ConsistencyCfg::parse("NR1W1"), None);
        assert_eq!(ConsistencyCfg::parse("N3RW1"), None);
        assert_eq!(ConsistencyCfg::parse("N3R1W"), None);
        // empty / truncated / junk segments
        assert_eq!(ConsistencyCfg::parse(""), None);
        assert_eq!(ConsistencyCfg::parse("N"), None);
        assert_eq!(ConsistencyCfg::parse("N3"), None);
        assert_eq!(ConsistencyCfg::parse("N3R1"), None);
        assert_eq!(ConsistencyCfg::parse("RW"), None);
        assert_eq!(ConsistencyCfg::parse("N3R1W1x"), None);
        assert_eq!(ConsistencyCfg::parse("N-3R1W1"), None);
        // zeros fail the >= 1 shape checks
        assert_eq!(ConsistencyCfg::parse("N0R0W0"), None);
        assert_eq!(ConsistencyCfg::parse("N3R0W1"), None);
    }

    #[test]
    fn causal_is_a_model_between_eventual_and_sequential() {
        let c = ConsistencyCfg::n3r1w1().with_causal();
        assert!(c.is_eventual(), "quorum math is untouched");
        assert_eq!(c.model_name(), "causal");
        assert_eq!(ConsistencyCfg::n3r1w1().model_name(), "eventual");
        // a sequential config subsumes the session guarantees
        assert_eq!(ConsistencyCfg::n3r2w2().with_causal().model_name(), "sequential");
    }

    #[test]
    fn borderline_quorums() {
        // R+W>N but W<=N/2 is NOT sequential (write quorums may not overlap)
        assert!(!ConsistencyCfg::new(4, 3, 2).is_sequential());
        assert!(ConsistencyCfg::new(4, 2, 3).is_sequential());
    }
}
