//! The declarative fault schedule: typed events over role-level targets
//! (server indices, topology regions) with virtual-time windows.
//!
//! A [`FaultPlan`] is plain data — the experiment configuration carries
//! one ([`crate::exp::config::ExpConfig::fault_plan`]), the runner lowers
//! it against the actor layout ([`crate::faults::state::lower`]), and the
//! CLI parses one from a compact DSL ([`FaultPlan::parse`]).

use crate::sim::{Time, SEC};

/// One scheduled fault. Times are virtual (ns); windows are `[from,
/// until)`. Servers are addressed by their cluster index, partitions by
/// *region* groups (every proc of a region — servers, their co-located
/// monitors, clients — moves together, which is what a real inter-DC cut
/// does).
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// Split the topology into isolated region groups for the window.
    /// Regions not named in any group stay mutually connected in an
    /// implicit rest-group. Messages crossing group boundaries are
    /// dropped; intra-group traffic is untouched.
    Partition { groups: Vec<Vec<u8>>, from: Time, until: Time },
    /// Crash server `server` at `at`: it loses all volatile state
    /// (table, window-log, snapshots) and neither receives nor sends.
    /// After `restart_after` (0 = never) it restarts empty and re-syncs
    /// its owned partitions from live preference-list peers before
    /// serving again.
    Crash { server: u16, at: Time, restart_after: Time },
    /// Multiply the network latency of every message to or from server
    /// `proc` by `factor` during the window (a degraded NIC / noisy
    /// neighbour; the paper's §VI-C proxy model only jitters, it never
    /// degrades a single node).
    SlowNode { proc: u16, factor: f64, from: Time, until: Time },
    /// Extra i.i.d. drop probability on the (symmetric) *machine* link
    /// between the machines of servers `link.0` and `link.1` during the
    /// window — a flaky cable rather than a full cut. Machine
    /// granularity means the burst hits every message between the two
    /// boxes: server↔server re-sync chunks and candidate traffic to the
    /// co-located monitors.
    DropBurst { link: (u16, u16), prob: f64, from: Time, until: Time },
}

/// A schedule of [`FaultEvent`]s. The default, [`FaultPlan::none()`],
/// is the empty schedule and is guaranteed inert.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: reproduces fault-free runs event-for-event.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_none(&self) -> bool {
        self.events.is_empty()
    }

    /// Builder-style push.
    pub fn with(mut self, ev: FaultEvent) -> Self {
        self.events.push(ev);
        self
    }

    /// Sanity-check the plan against a deployment shape. Returns the
    /// first problem found, if any.
    ///
    /// Besides per-event shape checks, overlapping windows on the same
    /// target are rejected: the runtime state keeps one slow factor per
    /// proc and one up/down bit per server, so `slow:2x4@10-30` plus
    /// `slow:2x2@20-40` (or two overlapping crash lifetimes of one
    /// server) would silently mis-model — fail loudly at plan time
    /// instead. Overlapping `DropBurst`s compose and are allowed.
    pub fn validate(&self, n_servers: usize, n_regions: usize) -> Result<(), String> {
        // (target, from, until) windows that must stay pairwise disjoint
        let mut slow_windows: Vec<(u16, Time, Time)> = Vec::new();
        let mut crash_windows: Vec<(u16, Time, Time)> = Vec::new();
        for ev in &self.events {
            match ev {
                FaultEvent::Partition { groups, from, until } => {
                    if from >= until {
                        return Err(format!("partition window [{from}, {until}) is empty"));
                    }
                    // NB: one listed group + the implicit rest-group is a
                    // valid two-way cut, so groups.len() == 1 is allowed.
                    let mut seen = vec![false; n_regions];
                    for g in groups {
                        if g.is_empty() {
                            return Err("partition group must not be empty".into());
                        }
                        for &r in g {
                            if r as usize >= n_regions {
                                return Err(format!(
                                    "partition names region {r} but the topology has {n_regions}"
                                ));
                            }
                            if seen[r as usize] {
                                return Err(format!("region {r} appears in two partition groups"));
                            }
                            seen[r as usize] = true;
                        }
                    }
                }
                FaultEvent::Crash { server, at, restart_after } => {
                    if *server as usize >= n_servers {
                        return Err(format!(
                            "crash names server {server} but the cluster has {n_servers}"
                        ));
                    }
                    let until = if *restart_after > 0 { *at + *restart_after } else { Time::MAX };
                    crash_windows.push((*server, *at, until));
                }
                FaultEvent::SlowNode { proc, factor, from, until } => {
                    if *proc as usize >= n_servers {
                        return Err(format!(
                            "slow-node names server {proc} but the cluster has {n_servers}"
                        ));
                    }
                    if *factor < 1.0 {
                        return Err(format!("slow-node factor {factor} must be >= 1"));
                    }
                    if from >= until {
                        return Err(format!("slow-node window [{from}, {until}) is empty"));
                    }
                    slow_windows.push((*proc, *from, *until));
                }
                FaultEvent::DropBurst { link, prob, from, until } => {
                    if link.0 as usize >= n_servers || link.1 as usize >= n_servers {
                        return Err(format!(
                            "drop-burst link {:?} outside the {n_servers}-server cluster",
                            link
                        ));
                    }
                    if !(0.0..=1.0).contains(prob) {
                        return Err(format!("drop-burst probability {prob} outside [0, 1]"));
                    }
                    if from >= until {
                        return Err(format!("drop-burst window [{from}, {until}) is empty"));
                    }
                }
            }
        }
        for (kind, windows) in [("slow-node", &slow_windows), ("crash", &crash_windows)] {
            for (i, &(t, f1, u1)) in windows.iter().enumerate() {
                for &(t2, f2, u2) in &windows[i + 1..] {
                    if t == t2 && f1 < u2 && f2 < u1 {
                        return Err(format!(
                            "overlapping {kind} windows on server {t} \
                             ([{f1}, {u1}) and [{f2}, {u2})) are not modeled"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Parse the CLI DSL: `;`-separated events, windows in (fractional)
    /// seconds of virtual time.
    ///
    /// ```text
    /// partition:0,1|2@10-40      regions {0,1} vs {2} from 10 s to 40 s
    /// crash:1@20+15              crash server 1 at 20 s, restart 15 s later
    /// crash:1@20                 crash server 1 at 20 s, never restart
    /// slow:2x4@10-30             server 2's links 4x slower from 10 s to 30 s
    /// burst:0-1:0.3@5-25         30 % loss on link 0<->1 from 5 s to 25 s
    /// ```
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut plan = FaultPlan::none();
        for item in s.split(';').map(str::trim).filter(|t| !t.is_empty()) {
            let (kind, body) =
                item.split_once(':').ok_or_else(|| format!("`{item}`: expected kind:spec"))?;
            plan.events.push(match kind {
                "partition" => parse_partition(body)?,
                "crash" => parse_crash(body)?,
                "slow" => parse_slow(body)?,
                "burst" => parse_burst(body)?,
                other => return Err(format!("unknown fault kind `{other}`")),
            });
        }
        Ok(plan)
    }
}

fn secs(s: &str) -> Result<Time, String> {
    let x: f64 = s.trim().parse().map_err(|_| format!("bad time `{s}` (seconds)"))?;
    if x < 0.0 {
        return Err(format!("negative time `{s}`"));
    }
    Ok((x * SEC as f64) as Time)
}

/// `spec@from-until` → (spec, from, until).
fn window(body: &str) -> Result<(&str, Time, Time), String> {
    let (spec, win) =
        body.split_once('@').ok_or_else(|| format!("`{body}`: expected spec@from-until"))?;
    let (a, b) =
        win.split_once('-').ok_or_else(|| format!("`{win}`: expected from-until seconds"))?;
    Ok((spec, secs(a)?, secs(b)?))
}

fn parse_partition(body: &str) -> Result<FaultEvent, String> {
    let (spec, from, until) = window(body)?;
    let mut groups = Vec::new();
    for g in spec.split('|') {
        let mut regions = Vec::new();
        for r in g.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            regions.push(r.parse::<u8>().map_err(|_| format!("bad region `{r}`"))?);
        }
        if regions.is_empty() {
            return Err(format!("`{spec}`: empty partition group"));
        }
        groups.push(regions);
    }
    Ok(FaultEvent::Partition { groups, from, until })
}

fn parse_crash(body: &str) -> Result<FaultEvent, String> {
    let (srv, when) =
        body.split_once('@').ok_or_else(|| format!("`{body}`: expected server@at[+restart]"))?;
    let server = srv.trim().parse::<u16>().map_err(|_| format!("bad server `{srv}`"))?;
    let (at, restart_after) = match when.split_once('+') {
        Some((a, r)) => (secs(a)?, secs(r)?),
        None => (secs(when)?, 0),
    };
    Ok(FaultEvent::Crash { server, at, restart_after })
}

fn parse_slow(body: &str) -> Result<FaultEvent, String> {
    let (spec, from, until) = window(body)?;
    let (p, f) = spec.split_once('x').ok_or_else(|| format!("`{spec}`: expected proc x factor"))?;
    let proc = p.trim().parse::<u16>().map_err(|_| format!("bad server `{p}`"))?;
    let factor = f.trim().parse::<f64>().map_err(|_| format!("bad factor `{f}`"))?;
    Ok(FaultEvent::SlowNode { proc, factor, from, until })
}

fn parse_burst(body: &str) -> Result<FaultEvent, String> {
    let (spec, from, until) = window(body)?;
    let (link, prob) =
        spec.rsplit_once(':').ok_or_else(|| format!("`{spec}`: expected a-b:prob"))?;
    let (a, b) = link.split_once('-').ok_or_else(|| format!("`{link}`: expected a-b link"))?;
    Ok(FaultEvent::DropBurst {
        link: (
            a.trim().parse().map_err(|_| format!("bad server `{a}`"))?,
            b.trim().parse().map_err(|_| format!("bad server `{b}`"))?,
        ),
        prob: prob.trim().parse().map_err(|_| format!("bad probability `{prob}`"))?,
        from,
        until,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty_and_inert_by_construction() {
        let p = FaultPlan::none();
        assert!(p.is_none());
        assert_eq!(p, FaultPlan::default());
        assert!(p.validate(3, 3).is_ok());
    }

    #[test]
    fn parse_full_dsl() {
        let p = FaultPlan::parse(
            "partition:0,1|2@10-40; crash:1@20+15; slow:2x4@10-30; burst:0-1:0.3@5-25",
        )
        .unwrap();
        assert_eq!(p.events.len(), 4);
        assert_eq!(
            p.events[0],
            FaultEvent::Partition {
                groups: vec![vec![0, 1], vec![2]],
                from: 10 * SEC,
                until: 40 * SEC
            }
        );
        assert_eq!(
            p.events[1],
            FaultEvent::Crash { server: 1, at: 20 * SEC, restart_after: 15 * SEC }
        );
        assert_eq!(
            p.events[2],
            FaultEvent::SlowNode { proc: 2, factor: 4.0, from: 10 * SEC, until: 30 * SEC }
        );
        assert_eq!(
            p.events[3],
            FaultEvent::DropBurst { link: (0, 1), prob: 0.3, from: 5 * SEC, until: 25 * SEC }
        );
        assert!(p.validate(3, 3).is_ok());
    }

    #[test]
    fn parse_crash_without_restart() {
        let p = FaultPlan::parse("crash:2@7.5").unwrap();
        assert_eq!(
            p.events[0],
            FaultEvent::Crash { server: 2, at: (7.5 * SEC as f64) as Time, restart_after: 0 }
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("partition:0,1@10").is_err(), "missing window end");
        assert!(FaultPlan::parse("crash:x@3").is_err(), "bad server");
        assert!(FaultPlan::parse("meteor:1@2-3").is_err(), "unknown kind");
        assert!(FaultPlan::parse("slow:1@0-1").is_err(), "missing factor");
        assert!(FaultPlan::parse("burst:0-1@5-25").is_err(), "missing probability");
        // a sub-1 factor parses but fails shape validation
        let p = FaultPlan::parse("slow:1x0.5@0-1").unwrap();
        assert!(p.validate(3, 3).is_err());
    }

    #[test]
    fn validate_catches_shape_errors() {
        let bad_region = FaultPlan::none().with(FaultEvent::Partition {
            groups: vec![vec![0], vec![7]],
            from: 0,
            until: SEC,
        });
        assert!(bad_region.validate(3, 3).is_err());

        let dup_region = FaultPlan::none().with(FaultEvent::Partition {
            groups: vec![vec![0, 1], vec![1]],
            from: 0,
            until: SEC,
        });
        assert!(dup_region.validate(3, 3).is_err());

        let bad_server =
            FaultPlan::none().with(FaultEvent::Crash { server: 5, at: 0, restart_after: 0 });
        assert!(bad_server.validate(3, 3).is_err());

        let empty_window = FaultPlan::none().with(FaultEvent::SlowNode {
            proc: 0,
            factor: 2.0,
            from: SEC,
            until: SEC,
        });
        assert!(empty_window.validate(3, 3).is_err());

        let bad_prob = FaultPlan::none().with(FaultEvent::DropBurst {
            link: (0, 1),
            prob: 1.5,
            from: 0,
            until: SEC,
        });
        assert!(bad_prob.validate(3, 3).is_err());
    }

    #[test]
    fn validate_rejects_overlapping_windows_on_one_target() {
        // one slow factor per proc: overlapping windows would mis-model
        let slow_overlap = FaultPlan::parse("slow:2x4@10-30;slow:2x2@20-40").unwrap();
        assert!(slow_overlap.validate(3, 3).is_err());
        let slow_disjoint = FaultPlan::parse("slow:2x4@10-30;slow:2x2@30-40").unwrap();
        assert!(slow_disjoint.validate(3, 3).is_ok());
        let slow_two_procs = FaultPlan::parse("slow:1x4@10-30;slow:2x2@20-40").unwrap();
        assert!(slow_two_procs.validate(3, 3).is_ok());

        // one up/down bit per server: a second crash inside the first's
        // down window (incl. a never-restarting one) is rejected
        let crash_overlap = FaultPlan::parse("crash:1@10+20;crash:1@15+5").unwrap();
        assert!(crash_overlap.validate(3, 3).is_err());
        let crash_after_dead = FaultPlan::parse("crash:1@10;crash:1@50+5").unwrap();
        assert!(crash_after_dead.validate(3, 3).is_err(), "never-restarts stays down");
        let crash_sequential = FaultPlan::parse("crash:1@10+5;crash:1@30+5").unwrap();
        assert!(crash_sequential.validate(3, 3).is_ok());
        let crash_two_servers = FaultPlan::parse("crash:1@10+20;crash:2@15+5").unwrap();
        assert!(crash_two_servers.validate(3, 3).is_ok());

        // bursts compose — overlap on the same link is fine
        let burst_overlap =
            FaultPlan::parse("burst:0-1:0.3@5-25;burst:0-1:0.2@10-30").unwrap();
        assert!(burst_overlap.validate(3, 3).is_ok());
    }
}
