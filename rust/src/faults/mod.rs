//! Fault injection: declarative, seed-deterministic schedules of network
//! partitions, crash/restart cycles, slow nodes and drop bursts, consumed
//! by the DES as a timeline of reachability transitions.
//!
//! The paper's premise is CAP — keep executing optimistically *through*
//! network partitions and replica failures, monitor the correctness
//! predicate, and roll back when it is violated (§I, §VI). An i.i.d.
//! per-message drop probability cannot express any of that: a partition
//! is a *correlated*, *time-bounded* cut of the reachability graph, and a
//! crash is a replica that loses volatile state and must re-sync from its
//! preference-list peers on rejoin (Dynamo §4.6 hinted handoff / replica
//! synchronization). This module supplies the missing vocabulary:
//!
//! * [`plan::FaultPlan`] — the *role-level* schedule an experiment
//!   declares: typed [`plan::FaultEvent`]s addressing servers by index and
//!   the topology by region, with virtual-time windows. Pure data —
//!   cloneable, comparable, parseable from a compact CLI DSL.
//! * [`state::Timeline`] + [`state::FaultState`] — the *proc-level*
//!   lowering the experiment runner derives from a plan plus the actor
//!   layout: a sorted list of [`state::Change`] transitions the simulator
//!   applies between events, and the time-varying reachability view the
//!   network consults on every send (partitioned or crashed endpoint ⇒
//!   the message is dropped, feeding the quorum timeout path in
//!   [`crate::client::quorum`]).
//!
//! Everything is deterministic: the same seed and the same plan replay
//! the identical transition schedule, and [`plan::FaultPlan::none()`]
//! (the default) leaves the simulator's behaviour untouched event-for-
//! event — the empty timeline adds no heap events and no RNG draws.

pub mod plan;
pub mod state;

pub use plan::{FaultEvent, FaultPlan};
pub use state::{lower, Change, FaultHook, FaultState, Timeline};
