//! Proc-level fault machinery: the lowered transition [`Timeline`] the
//! simulator consumes between events, and the time-varying [`FaultState`]
//! reachability view the network consults on every send.
//!
//! [`lower`] translates a role-level [`FaultPlan`] into [`Change`]s using
//! the experiment runner's actor layout (servers are procs `0..s`; every
//! proc has a region). The timeline is sorted by time with plan order
//! breaking ties, so the same plan always replays the same schedule.

use crate::faults::plan::{FaultEvent, FaultPlan};
use crate::sim::{ProcId, Time};

/// Lifecycle notification delivered to an actor when a fault transition
/// targets it directly (see [`crate::sim::des::Actor::on_fault`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultHook {
    /// The process just lost all volatile state and is down.
    Crash,
    /// The process is back up (empty) and may start recovery.
    Restart,
}

/// One lowered transition of the fault state.
#[derive(Debug, Clone, PartialEq)]
pub enum Change {
    /// Activate partition `id`: `group_of[p]` is proc `p`'s side of the cut.
    PartitionStart { id: usize, group_of: Vec<u8> },
    PartitionEnd { id: usize },
    Crash { proc: u32 },
    Restart { proc: u32 },
    SlowStart { proc: u32, factor: f64 },
    SlowEnd { proc: u32 },
    /// `a`/`b` are *machine* indices (the runner lays servers out on
    /// machines `0..s`, so a server index is its machine index)
    BurstStart { a: u32, b: u32, prob: f64 },
    BurstEnd { a: u32, b: u32 },
}

/// Time-sorted transition schedule (a cursor over lowered changes).
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// sorted ascending by time; ties keep lowering order
    changes: Vec<(Time, Change)>,
    cursor: usize,
}

impl Timeline {
    pub fn new(mut changes: Vec<(Time, Change)>) -> Self {
        changes.sort_by_key(|&(t, _)| t); // stable: ties keep plan order
        Self { changes, cursor: 0 }
    }

    pub fn empty() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.changes.is_empty()
    }

    pub fn len(&self) -> usize {
        self.changes.len()
    }

    /// Time of the next unapplied transition.
    pub fn peek_at(&self) -> Option<Time> {
        self.changes.get(self.cursor).map(|&(t, _)| t)
    }

    pub fn pop(&mut self) -> Option<(Time, Change)> {
        let item = self.changes.get(self.cursor).cloned();
        if item.is_some() {
            self.cursor += 1;
        }
        item
    }

    /// Merge additional changes into an unconsumed timeline (e.g. the
    /// workload engine's churn transitions joining a fault plan's).
    /// Appended changes sort behind existing ones at equal times — the
    /// stable re-sort keeps the original tie order — so composition
    /// never reshuffles a plan's own schedule. Panics if replay already
    /// started; composition happens at build time.
    pub fn merged_with(mut self, extra: Vec<(Time, Change)>) -> Self {
        assert_eq!(self.cursor, 0, "cannot merge into a partially-replayed timeline");
        self.changes.extend(extra);
        Self::new(self.changes)
    }
}

/// Lower a role-level plan against an actor layout. `region_of` is the
/// topology's per-proc region table; servers occupy procs `0..n_servers`
/// (the runner's layout invariant); `n_regions` is the topology's
/// *configured* region count — regions may exist without any proc in a
/// small deployment, and a plan naming one must still lower cleanly
/// (its group simply contains no procs). Panics on a plan that fails
/// [`FaultPlan::validate`] — experiment construction is the right time
/// to find out.
pub fn lower(plan: &FaultPlan, region_of: &[u8], n_servers: usize, n_regions: usize) -> Timeline {
    let n_regions =
        n_regions.max(region_of.iter().copied().max().map_or(1, |m| m as usize + 1));
    if let Err(e) = plan.validate(n_servers, n_regions) {
        panic!("invalid fault plan: {e}");
    }
    let mut changes = Vec::new();
    let mut next_partition = 0usize;
    for ev in &plan.events {
        match ev {
            FaultEvent::Partition { groups, from, until } => {
                // region → group id; unlisted regions share the rest-group
                let rest = groups.len() as u8;
                let mut group_of_region = vec![rest; n_regions];
                for (gi, g) in groups.iter().enumerate() {
                    for &r in g {
                        group_of_region[r as usize] = gi as u8;
                    }
                }
                let group_of: Vec<u8> =
                    region_of.iter().map(|&r| group_of_region[r as usize]).collect();
                let id = next_partition;
                next_partition += 1;
                changes.push((*from, Change::PartitionStart { id, group_of }));
                changes.push((*until, Change::PartitionEnd { id }));
            }
            FaultEvent::Crash { server, at, restart_after } => {
                let proc = *server as u32; // layout: server i is proc i
                changes.push((*at, Change::Crash { proc }));
                if *restart_after > 0 {
                    changes.push((*at + *restart_after, Change::Restart { proc }));
                }
            }
            FaultEvent::SlowNode { proc, factor, from, until } => {
                let proc = *proc as u32;
                changes.push((*from, Change::SlowStart { proc, factor: *factor }));
                changes.push((*until, Change::SlowEnd { proc }));
            }
            FaultEvent::DropBurst { link, prob, from, until } => {
                let (a, b) = (link.0 as u32, link.1 as u32);
                changes.push((*from, Change::BurstStart { a, b, prob: *prob }));
                changes.push((*until, Change::BurstEnd { a, b }));
            }
        }
    }
    Timeline::new(changes)
}

/// The current reachability view. Consulted on every [`crate::sim::des::
/// Ctx::send_after`]; [`FaultState::quiet`] keeps the fault-free fast
/// path allocation- and branch-cheap.
#[derive(Debug, Clone)]
pub struct FaultState {
    /// active partitions: (id, per-proc group)
    partitions: Vec<(usize, Vec<u8>)>,
    crashed: Vec<bool>,
    crashed_n: usize,
    /// per-proc latency multiplier (1.0 = nominal)
    slow: Vec<f64>,
    slow_n: usize,
    /// active link bursts: (a, b, extra drop probability)
    bursts: Vec<(u32, u32, f64)>,
}

impl FaultState {
    pub fn new(n_procs: usize) -> Self {
        Self {
            partitions: Vec::new(),
            crashed: vec![false; n_procs],
            crashed_n: 0,
            slow: vec![1.0; n_procs],
            slow_n: 0,
            bursts: Vec::new(),
        }
    }

    /// No fault currently active — sends can skip every check.
    #[inline]
    pub fn quiet(&self) -> bool {
        self.partitions.is_empty()
            && self.crashed_n == 0
            && self.slow_n == 0
            && self.bursts.is_empty()
    }

    pub fn is_crashed(&self, p: ProcId) -> bool {
        self.crashed[p.idx()]
    }

    /// Can a message travel `src → dst` right now? False when either
    /// endpoint is crashed or any active partition separates them.
    pub fn reachable(&self, src: ProcId, dst: ProcId) -> bool {
        if self.crashed[src.idx()] || self.crashed[dst.idx()] {
            return false;
        }
        self.partitions.iter().all(|(_, g)| g[src.idx()] == g[dst.idx()])
    }

    /// Latency multiplier for a message between `src` and `dst` (the
    /// slower endpoint dominates).
    pub fn latency_factor(&self, src: ProcId, dst: ProcId) -> f64 {
        self.slow[src.idx()].max(self.slow[dst.idx()])
    }

    /// Extra drop probability from active bursts on the (symmetric)
    /// link between two *machines*; overlapping bursts drop
    /// independently. Machine granularity is what makes a burst
    /// physical: the link between server machines a and b carries not
    /// just server↔server re-sync chunks but every message between
    /// their co-located processes (e.g. server a → monitor b candidate
    /// traffic).
    pub fn burst_prob(&self, src_machine: u32, dst_machine: u32) -> f64 {
        let (s, d) = (src_machine, dst_machine);
        let mut keep = 1.0;
        for &(a, b, p) in &self.bursts {
            if (a == s && b == d) || (a == d && b == s) {
                keep *= 1.0 - p;
            }
        }
        1.0 - keep
    }

    /// Apply one transition; returns the actor hook to dispatch, if the
    /// change targets a process directly.
    pub fn apply(&mut self, ch: &Change) -> Option<(u32, FaultHook)> {
        match ch {
            Change::PartitionStart { id, group_of } => {
                debug_assert_eq!(group_of.len(), self.crashed.len());
                self.partitions.push((*id, group_of.clone()));
                None
            }
            Change::PartitionEnd { id } => {
                self.partitions.retain(|(pid, _)| pid != id);
                None
            }
            Change::Crash { proc } => {
                if !self.crashed[*proc as usize] {
                    self.crashed[*proc as usize] = true;
                    self.crashed_n += 1;
                }
                Some((*proc, FaultHook::Crash))
            }
            Change::Restart { proc } => {
                if self.crashed[*proc as usize] {
                    self.crashed[*proc as usize] = false;
                    self.crashed_n -= 1;
                }
                Some((*proc, FaultHook::Restart))
            }
            Change::SlowStart { proc, factor } => {
                if self.slow[*proc as usize] == 1.0 && *factor != 1.0 {
                    self.slow_n += 1;
                }
                self.slow[*proc as usize] = *factor;
                None
            }
            Change::SlowEnd { proc } => {
                if self.slow[*proc as usize] != 1.0 {
                    self.slow_n -= 1;
                }
                self.slow[*proc as usize] = 1.0;
                None
            }
            Change::BurstStart { a, b, prob } => {
                self.bursts.push((*a, *b, *prob));
                None
            }
            Change::BurstEnd { a, b } => {
                // end the oldest matching burst; links are symmetric, so
                // match either orientation (windows are well-nested in
                // practice; plans rarely overlap the same link)
                if let Some(i) = self.bursts.iter().position(|&(x, y, _)| {
                    (x, y) == (*a, *b) || (x, y) == (*b, *a)
                }) {
                    self.bursts.remove(i);
                }
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SEC;

    fn pid(i: u32) -> ProcId {
        ProcId(i)
    }

    #[test]
    fn empty_timeline_is_quiet_forever() {
        let t = Timeline::empty();
        assert!(t.is_empty());
        assert_eq!(t.peek_at(), None);
        let s = FaultState::new(4);
        assert!(s.quiet());
        assert!(s.reachable(pid(0), pid(3)));
        assert_eq!(s.latency_factor(pid(0), pid(1)), 1.0);
        assert_eq!(s.burst_prob(0, 1), 0.0);
    }

    #[test]
    fn lower_partition_by_region() {
        // procs: servers 0,1,2 in regions 0,1,2; clients 3,4 in 0,1
        let region_of = vec![0u8, 1, 2, 0, 1];
        let plan = FaultPlan::none().with(FaultEvent::Partition {
            groups: vec![vec![0, 1], vec![2]],
            from: 10 * SEC,
            until: 20 * SEC,
        });
        let mut t = lower(&plan, &region_of, 3, 3);
        assert_eq!(t.len(), 2);
        assert_eq!(t.peek_at(), Some(10 * SEC));

        let mut s = FaultState::new(5);
        let (_, start) = t.pop().unwrap();
        assert!(s.apply(&start).is_none());
        assert!(!s.quiet());
        // within group {0,1}: servers 0,1 and clients 3,4 all connected
        assert!(s.reachable(pid(0), pid(1)));
        assert!(s.reachable(pid(0), pid(4)));
        // across the cut: region 2 isolated
        assert!(!s.reachable(pid(0), pid(2)));
        assert!(!s.reachable(pid(2), pid(3)));
        let (at, end) = t.pop().unwrap();
        assert_eq!(at, 20 * SEC);
        s.apply(&end);
        assert!(s.quiet());
        assert!(s.reachable(pid(0), pid(2)));
        assert_eq!(t.pop(), None);
    }

    #[test]
    fn lower_rest_group_connects_unlisted_regions() {
        let region_of = vec![0u8, 1, 2];
        let plan = FaultPlan::none().with(FaultEvent::Partition {
            groups: vec![vec![0]],
            from: 0,
            until: SEC,
        });
        let mut t = lower(&plan, &region_of, 3, 3);
        let mut s = FaultState::new(3);
        let (_, ch) = t.pop().unwrap();
        s.apply(&ch);
        assert!(!s.reachable(pid(0), pid(1)), "listed region cut off");
        assert!(s.reachable(pid(1), pid(2)), "unlisted regions stay connected");
    }

    #[test]
    fn crash_restart_hooks_and_reachability() {
        let region_of = vec![0u8, 0, 0];
        let plan = FaultPlan::none().with(FaultEvent::Crash {
            server: 1,
            at: 5 * SEC,
            restart_after: 3 * SEC,
        });
        let mut t = lower(&plan, &region_of, 3, 3);
        let mut s = FaultState::new(3);
        let (at, crash) = t.pop().unwrap();
        assert_eq!(at, 5 * SEC);
        assert_eq!(s.apply(&crash), Some((1, FaultHook::Crash)));
        assert!(s.is_crashed(pid(1)));
        assert!(!s.reachable(pid(0), pid(1)));
        assert!(!s.reachable(pid(1), pid(2)));
        assert!(s.reachable(pid(0), pid(2)), "others unaffected");
        let (at, restart) = t.pop().unwrap();
        assert_eq!(at, 8 * SEC);
        assert_eq!(s.apply(&restart), Some((1, FaultHook::Restart)));
        assert!(s.quiet());
        assert!(s.reachable(pid(0), pid(1)));
    }

    #[test]
    fn crash_without_restart_stays_down() {
        let plan =
            FaultPlan::none().with(FaultEvent::Crash { server: 0, at: SEC, restart_after: 0 });
        let t = lower(&plan, &[0u8, 0], 2, 1);
        assert_eq!(t.len(), 1, "no restart transition scheduled");
    }

    #[test]
    fn slow_node_scales_both_directions() {
        let mut s = FaultState::new(3);
        s.apply(&Change::SlowStart { proc: 1, factor: 4.0 });
        assert!(!s.quiet());
        assert_eq!(s.latency_factor(pid(0), pid(1)), 4.0);
        assert_eq!(s.latency_factor(pid(1), pid(2)), 4.0);
        assert_eq!(s.latency_factor(pid(0), pid(2)), 1.0);
        assert!(s.reachable(pid(0), pid(1)), "slow is not partitioned");
        s.apply(&Change::SlowEnd { proc: 1 });
        assert!(s.quiet());
    }

    #[test]
    fn bursts_are_symmetric_and_compose() {
        let mut s = FaultState::new(3);
        s.apply(&Change::BurstStart { a: 0, b: 1, prob: 0.5 });
        assert_eq!(s.burst_prob(0, 1), 0.5);
        assert_eq!(s.burst_prob(1, 0), 0.5);
        assert_eq!(s.burst_prob(0, 2), 0.0);
        // starting the reverse orientation composes independently...
        s.apply(&Change::BurstStart { a: 1, b: 0, prob: 0.5 });
        assert!((s.burst_prob(0, 1) - 0.75).abs() < 1e-12, "independent drops");
        // ...and ending twice clears both, regardless of orientation
        s.apply(&Change::BurstEnd { a: 0, b: 1 });
        s.apply(&Change::BurstEnd { a: 0, b: 1 });
        assert!(s.quiet());
    }

    #[test]
    fn overlapping_partitions_must_all_agree() {
        let mut s = FaultState::new(4);
        s.apply(&Change::PartitionStart { id: 0, group_of: vec![0, 0, 1, 1] });
        s.apply(&Change::PartitionStart { id: 1, group_of: vec![0, 1, 0, 1] });
        assert!(!s.reachable(pid(0), pid(1)), "cut by partition 1");
        assert!(!s.reachable(pid(0), pid(2)), "cut by partition 0");
        assert!(!s.reachable(pid(0), pid(3)));
        s.apply(&Change::PartitionEnd { id: 1 });
        assert!(s.reachable(pid(0), pid(1)));
        assert!(!s.reachable(pid(0), pid(2)));
    }

    #[test]
    fn timeline_sorts_stable_by_time() {
        let mut t = Timeline::new(vec![
            (2 * SEC, Change::Crash { proc: 0 }),
            (SEC, Change::SlowStart { proc: 1, factor: 2.0 }),
            (SEC, Change::SlowStart { proc: 2, factor: 3.0 }),
        ]);
        assert_eq!(t.pop().unwrap().0, SEC);
        match t.pop().unwrap() {
            (at, Change::SlowStart { proc, .. }) => {
                assert_eq!(at, SEC);
                assert_eq!(proc, 2, "equal times keep insertion order");
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(t.pop().unwrap().0, 2 * SEC);
    }

    #[test]
    fn merged_with_interleaves_and_keeps_tie_priority() {
        let base = Timeline::new(vec![
            (SEC, Change::Crash { proc: 0 }),
            (3 * SEC, Change::Restart { proc: 0 }),
        ]);
        let mut t = base.merged_with(vec![
            (2 * SEC, Change::Crash { proc: 7 }),
            (SEC, Change::Crash { proc: 8 }),
        ]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.pop().unwrap(), (SEC, Change::Crash { proc: 0 }), "base wins the tie");
        assert_eq!(t.pop().unwrap(), (SEC, Change::Crash { proc: 8 }));
        assert_eq!(t.pop().unwrap(), (2 * SEC, Change::Crash { proc: 7 }));
        assert_eq!(t.pop().unwrap().0, 3 * SEC);
        // merging nothing is the identity
        let mut same = Timeline::empty().merged_with(vec![(SEC, Change::Crash { proc: 1 })]);
        assert_eq!(same.pop().unwrap().0, SEC);
    }
}
