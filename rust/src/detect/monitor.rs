//! The monitor actor (§V "Implementation of the monitors", Algorithms 1–2).
//!
//! Each monitor owns the predicates hash-assigned to it and searches, per
//! clause of ¬P, for a set of candidates — one per conjunct, possibly from
//! different servers — that are **pairwise concurrent** under the 3-case
//! HVC interval rule. Finding one is a consistent cut on which the clause
//! (hence ¬P) holds: a violation.
//!
//! Implementation notes relative to the paper's Algorithms 1/2:
//! * the global-state advancement along *forbidden* states (linear) is
//!   realized by retiring candidates that can no longer pair with any
//!   future candidate — each server's candidate stream is HVC-monotone,
//!   so a physical-time retirement window is a sound over-approximation
//!   (it only keeps more candidates than strictly needed, never misses);
//! * *semi-forbidden* advancement (semilinear) is realized by evaluating
//!   the conjunct's literals monitor-side on every candidate (candidates
//!   arrive on every relevant PUT) and only admitting satisfied ones into
//!   the search windows;
//! * candidates are processed in small batches so interval verdicts can be
//!   dispatched to the batched backend (`runtime::accel`) — the XLA/Pallas
//!   path — instead of one comparison at a time.
//!
//! ## Indexed windows (hot path)
//!
//! Search windows are kept **sorted by the interval's physical end**
//! (`end_pt_ms`, ties by arrival). That buys two things wall-clock:
//! retirement drains a sorted prefix instead of scanning every entry,
//! and pairing a new candidate binary-searches to the overlap split —
//! everything past it is *certified* Concurrent by an exact O(1)
//! physical-overlap test ([`physically_entangled`]), so the O(d) vector
//! verdict runs only on the physically separable boundary cases.
//! Honest complexity: the per-candidate scan stays O(W) — the
//! concurrent set it must hand to the DFS is itself Θ(W) at the
//! paper's ε = ∞, so sub-linear output is impossible — but the
//! expensive part drops from O(W·d) vector comparisons to
//! O(boundary·d), which is zero at ε = ∞. Two
//! counters keep the optimization observationally pure: `pairs_checked`
//! counts verdicts actually computed (it drops, and is the perf-harness
//! metric), while `pairs_charged` counts the pairs of the *modeled*
//! linear scan and keeps driving the virtual CPU cost — so the event
//! schedule is bit-identical to the pre-index code. The DFS iterates
//! matches in arrival order for the same reason: the witness tuple
//! consumed for a violation must not depend on the index. DESIGN.md
//! §"Hot-path cost model" has the exactness argument.
//!
//! Monitors keep running after reporting (violations may recur), GC
//! predicates with no recent activity (§V "Handling a large number of
//! predicates"), and account their CPU on the machine they share with a
//! server — which is precisely the monitoring overhead the paper measures.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::clock::hvc::{IntervalOrd, Millis};
use crate::detect::candidate::{Candidate, ViolationReport};
use crate::metrics::throughput::{Metrics, ViolationRecord};
use crate::predicate::spec::{PredId, PredKind, Registry};
use crate::runtime::accel::{Accel, PairQuery};
use crate::sim::des::{Actor, Ctx};
use crate::sim::msg::Msg;
use crate::sim::{ms, ProcId, Time, SEC};
use crate::trace::{TraceEv, TraceRef, TraceWitness};

const TAG_BATCH: u64 = 1;
const TAG_GC: u64 = 2;

/// CPU cost model for monitor work (virtual time charged on the shared
/// machine). Calibrated in EXPERIMENTS.md §Perf. `per_pair` is charged
/// per *modeled* pair (`pairs_charged`), independent of how many
/// verdicts the indexed search actually computes.
#[derive(Debug, Clone)]
pub struct MonitorCost {
    /// per candidate ingested
    pub per_candidate: Time,
    /// per pair verdict computed
    pub per_pair: Time,
    /// fixed per batch (accel dispatch overhead)
    pub per_batch: Time,
}

impl Default for MonitorCost {
    fn default() -> Self {
        Self { per_candidate: 12_000, per_pair: 400, per_batch: 8_000 }
    }
}

#[derive(Debug, Clone)]
pub struct MonitorCfg {
    /// batching window before processing pending candidates
    pub batch_window: Time,
    /// retire candidates whose interval end is older than this (physical ms)
    pub retire_window_ms: Millis,
    /// GC predicates inactive for this long
    pub inactive_timeout: Time,
    /// GC sweep period
    pub gc_period: Time,
    pub cost: MonitorCost,
}

impl Default for MonitorCfg {
    fn default() -> Self {
        Self {
            batch_window: ms(1.0),
            retire_window_ms: 30_000,
            inactive_timeout: 60 * SEC,
            gc_period: 10 * SEC,
            cost: MonitorCost::default(),
        }
    }
}

/// One admitted candidate plus its arrival stamp. Windows sort by
/// `cand.end_pt_ms()` (ties by `arr`); the DFS re-sorts matches by `arr`
/// so the search visits them in the order the historical linear scan did.
#[derive(Debug)]
struct WinEntry {
    arr: u64,
    cand: Candidate,
}

/// Search state for one clause: a window of admitted candidates per
/// conjunct, sorted by interval end.
#[derive(Debug, Default)]
struct ClauseState {
    windows: Vec<Vec<WinEntry>>,
}

#[derive(Debug)]
struct PredState {
    last_activity: Time,
    clauses: Vec<ClauseState>,
}

/// Pair accounting for one search (see module docs).
#[derive(Debug, Default)]
struct PairStats {
    /// interval verdicts actually computed (accel work)
    checked: u64,
    /// pairs of the modeled linear scan — drives the CPU cost model,
    /// bit-identical to the pre-index algorithm's `pairs_checked`
    charged: u64,
}

pub struct MonitorActor {
    pub idx: u16,
    registry: Rc<RefCell<Registry>>,
    accel: Rc<RefCell<dyn Accel>>,
    controller: Option<ProcId>,
    cfg: MonitorCfg,
    metrics: Metrics,
    states: HashMap<PredId, PredState>,
    pending: Vec<Candidate>,
    batch_scheduled: bool,
    /// flight recorder handle (`None` = recording off, zero overhead)
    trace: Option<TraceRef>,
    /// monotone arrival stamp for window entries
    arr_seq: u64,
    /// stats
    pub candidates_seen: u64,
    pub violations_found: u64,
    /// interval verdicts actually computed by the indexed search
    pub pairs_checked: u64,
    /// modeled linear-scan pairs (drives the virtual CPU cost; equals
    /// the historical `pairs_checked` exactly)
    pub pairs_charged: u64,
    /// largest single search window observed
    pub window_peak: usize,
    pub gc_evicted: u64,
}

impl MonitorActor {
    pub fn new(
        idx: u16,
        registry: Rc<RefCell<Registry>>,
        accel: Rc<RefCell<dyn Accel>>,
        controller: Option<ProcId>,
        cfg: MonitorCfg,
        metrics: Metrics,
    ) -> Self {
        Self {
            idx,
            registry,
            accel,
            controller,
            cfg,
            metrics,
            states: HashMap::new(),
            pending: Vec::new(),
            batch_scheduled: false,
            trace: None,
            arr_seq: 0,
            candidates_seen: 0,
            violations_found: 0,
            pairs_checked: 0,
            pairs_charged: 0,
            window_peak: 0,
            gc_evicted: 0,
        }
    }

    /// Attach the flight recorder ([`crate::trace`]).
    pub fn with_trace(mut self, trace: TraceRef) -> Self {
        self.trace = Some(trace);
        self
    }

    fn pred_state(&mut self, pred: PredId, now: Time) -> &mut PredState {
        let registry = &self.registry;
        self.states.entry(pred).or_insert_with(|| {
            let reg = registry.borrow();
            let spec = reg.get(pred);
            PredState {
                last_activity: now,
                clauses: spec
                    .clauses
                    .iter()
                    .map(|c| ClauseState { windows: vec![Vec::new(); c.conjuncts.len()] })
                    .collect(),
            }
        })
    }

    /// Admit `cand` into the clause search; returns a violation witness set
    /// if a pairwise-concurrent tuple covering all conjuncts now exists.
    fn search(&mut self, cand: &Candidate, eps: Millis) -> Option<Vec<Candidate>> {
        let accel = self.accel.clone();
        let mut stats = PairStats::default();
        let result = {
            let st = self.states.get(&cand.pred).unwrap();
            let cs = &st.clauses[cand.clause as usize];
            search_clause(&accel, &mut stats, cs, cand, eps)
        };
        self.pairs_checked += stats.checked;
        self.pairs_charged += stats.charged;
        result
    }

    /// Process one candidate: evaluate, search, admit, retire.
    /// Returns a report if a violation was found.
    fn process(&mut self, cand: Candidate, now: Time, eps: Millis, monitor_id: ProcId) -> Option<ViolationReport> {
        self.candidates_seen += 1;
        self.metrics.borrow_mut().candidates_received += 1;

        let (kind, name, conj) = {
            let reg = self.registry.borrow();
            let spec = reg.get(cand.pred);
            (
                spec.kind,
                spec.name.clone(),
                spec.clauses[cand.clause as usize].conjuncts[cand.conjunct as usize].clone(),
            )
        };
        self.pred_state(cand.pred, now).last_activity = now;
        let peak = self.states.len();
        {
            let mut m = self.metrics.borrow_mut();
            if peak > m.active_preds_peak {
                m.active_preds_peak = peak;
            }
        }

        // truth: linear candidates are pre-filtered by the local detector;
        // semilinear candidates are always sent and evaluated here from the
        // carried values (Algorithm 2's semi-forbidden advancement)
        let truth = match kind {
            PredKind::Linear => cand.truth,
            PredKind::Semilinear => {
                cand.truth
                    || conj.satisfied_by(|k| {
                        let vals: Vec<_> = cand
                            .values
                            .iter()
                            .filter(|(vk, _)| *vk == k)
                            .map(|(_, v)| v.clone())
                            .collect();
                        if vals.is_empty() {
                            None
                        } else {
                            Some(vals)
                        }
                    })
            }
        };

        // retire stale candidates of this predicate: the windows are
        // sorted by interval end, so staleness is a prefix drain rather
        // than a full-window retain scan
        let horizon = cand.end_pt_ms() - self.cfg.retire_window_ms;
        {
            let st = self.states.get_mut(&cand.pred).unwrap();
            for cs in &mut st.clauses {
                for win in &mut cs.windows {
                    let cut = win.partition_point(|e| e.cand.end_pt_ms() < horizon);
                    if cut > 0 {
                        win.drain(..cut);
                    }
                }
            }
        }

        if !truth {
            return None;
        }

        let found = self.search(&cand, eps);
        match found {
            Some(witnesses) => {
                // consume the witnesses so one overlap reports once
                {
                    let st = self.states.get_mut(&cand.pred).unwrap();
                    let cs = &mut st.clauses[cand.clause as usize];
                    for w in &witnesses {
                        let win = &mut cs.windows[w.conjunct as usize];
                        win.retain(|e| !(e.cand.server == w.server && e.cand.seq == w.seq));
                    }
                }
                self.violations_found += 1;
                Some(ViolationReport::from_witnesses(
                    cand.pred,
                    name,
                    cand.clause,
                    witnesses,
                    now,
                    monitor_id,
                ))
            }
            None => {
                let arr = self.arr_seq;
                self.arr_seq += 1;
                let st = self.states.get_mut(&cand.pred).unwrap();
                let cs = &mut st.clauses[cand.clause as usize];
                let win = &mut cs.windows[cand.conjunct as usize];
                // sorted insert by (end_pt, arrival): partition_point on
                // `<=` lands after every equal end, so arrival stamps
                // stay ascending within a tie group
                let pos = win.partition_point(|e| e.cand.end_pt_ms() <= cand.end_pt_ms());
                win.insert(pos, WinEntry { arr, cand });
                if win.len() > self.window_peak {
                    self.window_peak = win.len();
                }
                None
            }
        }
    }

    fn flush_batch(&mut self, ctx: &mut Ctx) {
        self.batch_scheduled = false;
        if self.pending.is_empty() {
            return;
        }
        let eps = ctx.eps_ms();
        let pending = std::mem::take(&mut self.pending);
        let n = pending.len() as u64;
        let pairs_before = self.pairs_charged;
        let mut reports = Vec::new();
        for cand in pending {
            if let Some(rep) = self.process(cand, ctx.now(), eps, ctx.self_id) {
                reports.push(rep);
            }
        }
        // charge the CPU for this batch on the shared machine; results
        // leave once the computation "finishes". The charge is per
        // *modeled* pair, so the indexed search changes wall-clock cost
        // only — never the event schedule.
        let pairs = self.pairs_charged - pairs_before;
        let cost = self.cfg.cost.per_batch
            + self.cfg.cost.per_candidate * n
            + self.cfg.cost.per_pair * pairs;
        let delay = ctx.cpu_delay(cost);
        if let Some(tr) = &self.trace {
            tr.borrow_mut().record(
                ctx.self_id,
                ctx.now(),
                ctx.event_seq(),
                TraceEv::MonitorBatch {
                    monitor: self.idx,
                    candidates: n,
                    violations: reports.len() as u64,
                },
            );
        }
        for mut rep in reports {
            rep.detected_at = ctx.now() + delay;
            self.metrics.borrow_mut().record_violation(ViolationRecord {
                pred: rep.pred,
                name: rep.pred_name.clone(),
                t_violate_ms: rep.t_violate_ms,
                t_occurred_ms: rep.t_occurred_ms,
                detected_at: rep.detected_at,
                monitor: self.idx,
                at: ctx.now(),
                seq: ctx.event_seq(),
            });
            if let Some(tr) = &self.trace {
                tr.borrow_mut().record(
                    ctx.self_id,
                    ctx.now(),
                    ctx.event_seq(),
                    TraceEv::Violation {
                        pred: rep.pred,
                        name: rep.pred_name.clone(),
                        clause: rep.clause,
                        witnesses: rep
                            .witnesses
                            .iter()
                            .map(|w| TraceWitness {
                                server: w.server.0,
                                cseq: w.seq,
                                start_ms: w.start_pt_ms(),
                                end_ms: w.end_pt_ms(),
                            })
                            .collect(),
                        t_violate_ms: rep.t_violate_ms,
                        t_occurred_ms: rep.t_occurred_ms,
                    },
                );
            }
            if let Some(ctl) = self.controller {
                ctx.send_after(delay, ctl, Msg::Violation(Box::new(rep)));
            }
        }
    }

    fn gc(&mut self, now: Time) {
        let timeout = self.cfg.inactive_timeout;
        let before = self.states.len();
        self.states.retain(|_, st| st.last_activity + timeout >= now);
        self.gc_evicted += (before - self.states.len()) as u64;
    }

    /// Number of predicates currently holding monitor state.
    pub fn active_predicates(&self) -> usize {
        self.states.len()
    }
}

/// Exact O(1) "must be Concurrent" certificate: the 3-case rule can only
/// return Before/After when one interval's physical end precedes the
/// other's physical start by more than ε (rule 2's separation test is a
/// *necessary* condition for any ordering); when both orderings are
/// physically impossible the verdict is Concurrent no matter what the
/// clock vectors say. Uses the same saturating arithmetic as
/// [`crate::clock::hvc::HvcInterval::verdict`], so the two can never
/// disagree at the i64 boundaries.
#[inline]
fn physically_entangled(a: &Candidate, b: &Candidate, eps: Millis) -> bool {
    a.end_pt_ms() > b.start_pt_ms().saturating_sub(eps)
        && b.end_pt_ms() > a.start_pt_ms().saturating_sub(eps)
}

/// Clause-level tuple search (free function so candidate windows stay
/// borrowed while the accel runs; queries borrow intervals — no clock
/// clones on the hot path).
fn search_clause(
    accel: &Rc<RefCell<dyn Accel>>,
    stats: &mut PairStats,
    cs: &ClauseState,
    cand: &Candidate,
    eps: Millis,
) -> Option<Vec<Candidate>> {
    let n_conjuncts = cs.windows.len();
    if n_conjuncts == 1 {
        // single conjunct: the candidate alone is the witness
        return Some(vec![cand.clone()]);
    }

    // compatibility lists: candidates of every other conjunct that are
    // concurrent with `cand`. The window is sorted by interval end, so a
    // binary search splits off the prefix that ends early enough to
    // possibly order before `cand`; everything past the split only needs
    // the O(1) start-side half of the certificate, and full vector
    // verdicts run on the physically separable leftovers alone.
    let mut compat: Vec<Vec<&WinEntry>> = Vec::with_capacity(n_conjuncts);
    for (j, win) in cs.windows.iter().enumerate() {
        if j == cand.conjunct as usize {
            compat.push(Vec::new());
            continue;
        }
        if win.is_empty() {
            return None; // some conjunct has no active candidate
        }
        stats.charged += win.len() as u64; // the modeled scan visits all
        let sep = cand.start_pt_ms().saturating_sub(eps);
        let lo = win.partition_point(|e| e.cand.end_pt_ms() <= sep);
        debug_assert!(
            win[..lo].iter().all(|e| !physically_entangled(&e.cand, cand, eps)),
            "prefix below the split must be physically separable"
        );
        let need: Vec<usize> = (0..win.len())
            .filter(|&i| i < lo || !physically_entangled(&win[i].cand, cand, eps))
            .collect();
        let verdicts = if need.is_empty() {
            Vec::new()
        } else {
            stats.checked += need.len() as u64;
            let queries: Vec<PairQuery> = need
                .iter()
                .map(|&i| PairQuery { a: &cand.interval, b: &win[i].cand.interval })
                .collect();
            accel.borrow_mut().pair_verdicts(&queries, eps)
        };
        let mut ok: Vec<&WinEntry> = Vec::with_capacity(win.len());
        let mut vi = 0;
        for (i, e) in win.iter().enumerate() {
            let concurrent = if vi < need.len() && need[vi] == i {
                let v = verdicts[vi];
                vi += 1;
                v == IntervalOrd::Concurrent
            } else {
                true // certified by physical overlap
            };
            if concurrent {
                ok.push(e);
            }
        }
        if ok.is_empty() {
            return None;
        }
        // the DFS must try matches in arrival order — the order the
        // historical linear scan produced them — or a different witness
        // tuple could be consumed and the schedule would fork
        ok.sort_unstable_by_key(|e| e.arr);
        compat.push(ok);
    }

    // DFS over the compatibility lists for a pairwise-concurrent tuple
    let mut chosen: Vec<&Candidate> = vec![cand];
    if dfs(accel, stats, &compat, cand.conjunct as usize, 0, &mut chosen, eps) {
        Some(chosen.into_iter().cloned().collect())
    } else {
        None
    }
}

#[allow(clippy::too_many_arguments)]
fn dfs<'a>(
    accel: &Rc<RefCell<dyn Accel>>,
    stats: &mut PairStats,
    compat: &[Vec<&'a WinEntry>],
    skip: usize,
    j: usize,
    chosen: &mut Vec<&'a Candidate>,
    eps: Millis,
) -> bool {
    if j >= compat.len() {
        return true;
    }
    if j == skip {
        return dfs(accel, stats, compat, skip, j + 1, chosen, eps);
    }
    'next: for &e in &compat[j] {
        let o = &e.cand;
        // o is already concurrent with the seed; check the rest
        // (chosen[0] is the seed, skip it)
        if chosen.len() > 1 {
            stats.charged += (chosen.len() - 1) as u64;
            let need: Vec<&Candidate> = chosen[1..]
                .iter()
                .copied()
                .filter(|c| !physically_entangled(c, o, eps))
                .collect();
            if !need.is_empty() {
                stats.checked += need.len() as u64;
                let queries: Vec<PairQuery> = need
                    .iter()
                    .map(|c| PairQuery { a: &c.interval, b: &o.interval })
                    .collect();
                let verdicts = accel.borrow_mut().pair_verdicts(&queries, eps);
                for v in verdicts {
                    if v != IntervalOrd::Concurrent {
                        continue 'next;
                    }
                }
            }
        }
        chosen.push(o);
        if dfs(accel, stats, compat, skip, j + 1, chosen, eps) {
            return true;
        }
        chosen.pop();
    }
    false
}

impl Actor for MonitorActor {
    fn on_start(&mut self, ctx: &mut Ctx) {
        ctx.schedule(self.cfg.gc_period, TAG_GC);
    }

    fn on_msg(&mut self, ctx: &mut Ctx, _from: ProcId, msg: Msg) {
        match msg {
            Msg::Candidate(c) => {
                self.pending.push(*c);
                if !self.batch_scheduled {
                    self.batch_scheduled = true;
                    ctx.schedule(self.cfg.batch_window, TAG_BATCH);
                }
            }
            Msg::RegisterPred(spec) => {
                // the registry is shared per shard and usually pre-seeded at
                // layout time; `add` is idempotent by name, so registering
                // again only matters for ad-hoc worlds built without the
                // pre-seeding pass. The message still models the
                // control-plane traffic and its latency.
                self.registry.borrow_mut().add(*spec);
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, tag: u64) {
        match tag {
            TAG_BATCH => self.flush_batch(ctx),
            TAG_GC => {
                self.gc(ctx.now());
                ctx.schedule(self.cfg.gc_period, TAG_GC);
            }
            _ => {}
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::hvc::{Hvc, HvcInterval};
    use crate::metrics::throughput::MetricsHub;
    use crate::predicate::spec::{Clause, Conjunct, Literal, PredicateSpec};
    use crate::runtime::accel::NativeAccel;
    use crate::store::value::{Interner, Value};
    use crate::util::rng::Rng;

    fn me_registry() -> (Rc<RefCell<Registry>>, PredId) {
        let interner = Interner::new();
        let registry = Rc::new(RefCell::new(Registry::new()));
        let spec = crate::predicate::infer::edge_predicate(1, 2, &mut interner.borrow_mut());
        let id = registry.borrow_mut().add(spec);
        (registry, id)
    }

    fn monitor(registry: Rc<RefCell<Registry>>) -> MonitorActor {
        MonitorActor::new(
            0,
            registry,
            Rc::new(RefCell::new(NativeAccel::new())),
            None,
            MonitorCfg::default(),
            MetricsHub::new(1, 1),
        )
    }

    fn cand(pred: PredId, conjunct: u16, server: u16, seq: u64, s: i64, e: i64, truth: bool) -> Candidate {
        let dim = 2usize;
        let mk = |t: i64| {
            let mut v = vec![t - 1; dim];
            v[server as usize] = t;
            Hvc::from_vec(server, v)
        };
        Candidate {
            pred,
            clause: 0,
            conjunct,
            server: ProcId(server as u32),
            seq,
            interval: HvcInterval::new(mk(s), mk(e)),
            values: vec![],
            truth,
            emitted_at: 0,
        }
    }

    #[test]
    fn detects_concurrent_conjuncts_across_servers() {
        let (reg, id) = me_registry();
        let mut mon = monitor(reg);
        // conjunct 0 true on server 0 during [100, 200]
        let r1 = mon.process(cand(id, 0, 0, 0, 100, 200, true), 0, 5, ProcId(9));
        assert!(r1.is_none(), "no partner yet");
        // conjunct 1 true on server 1 during [150, 250] → overlap → violation
        let r2 = mon.process(cand(id, 1, 1, 0, 150, 250, true), 0, 5, ProcId(9));
        let rep = r2.expect("violation detected");
        assert_eq!(rep.witnesses.len(), 2);
        assert_eq!(rep.t_violate_ms, 100, "safe estimate = min start");
        assert_eq!(mon.violations_found, 1);
    }

    #[test]
    fn ordered_intervals_do_not_fire() {
        let (reg, id) = me_registry();
        let mut mon = monitor(reg);
        mon.process(cand(id, 0, 0, 0, 100, 110, true), 0, 2, ProcId(9));
        // far later, clearly ordered (separation ≫ eps)
        let r = mon.process(cand(id, 1, 1, 0, 500, 510, true), 0, 2, ProcId(9));
        assert!(r.is_none(), "happened-before intervals are not a violation");
    }

    #[test]
    fn uncertain_window_fires_conservatively() {
        let (reg, id) = me_registry();
        let mut mon = monitor(reg);
        mon.process(cand(id, 0, 0, 0, 100, 110, true), 0, 50, ProcId(9));
        // ends before the other starts, but within eps=50 → concurrent
        let r = mon.process(cand(id, 1, 1, 0, 120, 130, true), 0, 50, ProcId(9));
        assert!(r.is_some(), "eps-uncertain pairs must be reported");
    }

    #[test]
    fn false_semilinear_candidates_do_not_enter_windows() {
        let (reg, id) = me_registry();
        let mut mon = monitor(reg);
        let mut c = cand(id, 0, 0, 0, 100, 200, false);
        c.values = vec![]; // no values → conjunct unsatisfied
        assert!(mon.process(c, 0, 5, ProcId(9)).is_none());
        let r = mon.process(cand(id, 1, 1, 0, 150, 250, true), 0, 5, ProcId(9));
        assert!(r.is_none(), "false candidate must not act as witness");
    }

    #[test]
    fn semilinear_truth_reevaluated_from_values() {
        let interner = Interner::new();
        let registry = Rc::new(RefCell::new(Registry::new()));
        let x = interner.borrow_mut().intern("x");
        let spec = PredicateSpec {
            id: PredId(0),
            name: "sx".into(),
            kind: PredKind::Semilinear,
            clauses: vec![Clause {
                conjuncts: vec![Conjunct {
                    literals: vec![Literal { var: x, value: Value::Int(1) }],
                }],
            }],
        };
        let id = registry.borrow_mut().add(spec);
        let mut mon = monitor(registry);
        let mut c = cand(id, 0, 0, 0, 100, 200, false);
        c.values = vec![(x, Value::Int(1))];
        // single conjunct + values satisfy → immediate violation
        let r = mon.process(c, 0, 5, ProcId(9));
        assert!(r.is_some());
    }

    #[test]
    fn witnesses_consumed_no_double_report() {
        let (reg, id) = me_registry();
        let mut mon = monitor(reg);
        mon.process(cand(id, 0, 0, 0, 100, 200, true), 0, 5, ProcId(9));
        assert!(mon.process(cand(id, 1, 1, 0, 150, 250, true), 0, 5, ProcId(9)).is_some());
        // a third overlapping candidate has no partner left
        let r = mon.process(cand(id, 1, 1, 1, 160, 260, true), 0, 5, ProcId(9));
        assert!(r.is_none(), "witnesses were consumed");
    }

    #[test]
    fn retirement_bounds_window_size() {
        let (reg, id) = me_registry();
        let mut mon = monitor(reg);
        mon.cfg.retire_window_ms = 1_000;
        for k in 0..50 {
            mon.process(cand(id, 0, 0, k, 100 + k as i64, 110 + k as i64, true), 0, 2, ProcId(9));
        }
        // a much later candidate retires everything old
        mon.process(cand(id, 0, 0, 99, 100_000, 100_010, true), 0, 2, ProcId(9));
        let st = mon.states.get(&id).unwrap();
        assert!(st.clauses[0].windows[0].len() <= 2, "old candidates retired");
        assert!(mon.window_peak >= 50, "peak tracked before retirement");
    }

    #[test]
    fn windows_stay_sorted_by_interval_end() {
        let (reg, id) = me_registry();
        let mut mon = monitor(reg);
        // out-of-order ends from two interleaved servers
        for (seq, (s, e)) in [(100, 300), (120, 180), (90, 400), (200, 250)].iter().enumerate() {
            mon.process(cand(id, 0, 0, seq as u64, *s, *e, true), 0, 2, ProcId(9));
        }
        let st = mon.states.get(&id).unwrap();
        let ends: Vec<i64> =
            st.clauses[0].windows[0].iter().map(|w| w.cand.end_pt_ms()).collect();
        let mut sorted = ends.clone();
        sorted.sort_unstable();
        assert_eq!(ends, sorted, "window index invariant");
    }

    #[test]
    fn gc_evicts_inactive_predicates() {
        let (reg, id) = me_registry();
        let mut mon = monitor(reg);
        mon.process(cand(id, 0, 0, 0, 100, 200, true), 0, 5, ProcId(9));
        assert_eq!(mon.active_predicates(), 1);
        mon.gc(mon.cfg.inactive_timeout + 1);
        assert_eq!(mon.active_predicates(), 0);
        assert_eq!(mon.gc_evicted, 1);
    }

    #[test]
    fn three_way_conjunctive_tuple() {
        // conjunctive predicate with 3 conjuncts (one var each) — the
        // Conjunctive app shape; all three must be pairwise concurrent
        let interner = Interner::new();
        let registry = Rc::new(RefCell::new(Registry::new()));
        let mut lits = Vec::new();
        for i in 0..3 {
            let v = interner.borrow_mut().intern(&format!("c{i}"));
            lits.push(Conjunct { literals: vec![Literal { var: v, value: Value::Bool(true) }] });
        }
        let spec = PredicateSpec {
            id: PredId(0),
            name: "conj".into(),
            kind: PredKind::Linear,
            clauses: vec![Clause { conjuncts: lits }],
        };
        let id = registry.borrow_mut().add(spec);
        let mut mon = monitor(registry);
        assert!(mon.process(cand(id, 0, 0, 0, 100, 300, true), 0, 2, ProcId(9)).is_none());
        assert!(mon.process(cand(id, 1, 1, 0, 150, 350, true), 0, 2, ProcId(9)).is_none());
        let r = mon.process(cand(id, 2, 0, 1, 200, 280, true), 0, 2, ProcId(9));
        assert!(r.is_some(), "three pairwise-overlapping intervals");
        assert_eq!(r.unwrap().witnesses.len(), 3);
    }

    #[test]
    fn prop_certificate_never_contradicts_the_verdict() {
        // physically_entangled(a, b, ε) must imply Concurrent under the
        // full 3-case rule — the exactness of the fast path
        crate::util::prop::check_default("entangled_implies_concurrent", |rng| {
            let dim = rng.range(2, 6) as usize;
            let mk = |rng: &mut Rng, base: i64| {
                let server = rng.below(dim as u64) as u16;
                let s = base + rng.range(0, 200) as i64;
                let e = s + rng.range(0, 100) as i64;
                let mut mkh = |t: i64| {
                    let v = (0..dim).map(|_| t - rng.range(0, 30) as i64).collect::<Vec<_>>();
                    let mut h = Hvc::from_vec(server, v);
                    h.v[server as usize] = t;
                    h
                };
                Candidate {
                    pred: PredId(0),
                    clause: 0,
                    conjunct: 0,
                    server: ProcId(server as u32),
                    seq: 0,
                    interval: HvcInterval::new(mkh(s), mkh(e)),
                    values: vec![],
                    truth: true,
                    emitted_at: 0,
                }
            };
            let base_a = rng.range(0, 500) as i64;
            let a = mk(rng, base_a);
            let base_b = rng.range(0, 500) as i64;
            let b = mk(rng, base_b);
            let eps = [0, 2, 25, crate::clock::hvc::EPS_INF][rng.below(4) as usize];
            if physically_entangled(&a, &b, eps)
                && HvcInterval::verdict(&a.interval, &b.interval, eps) != IntervalOrd::Concurrent
            {
                return Err(format!(
                    "certificate contradicted the rule: a={:?} b={:?} eps={eps}",
                    a.interval, b.interval
                ));
            }
            Ok(())
        });
    }

    /// Reference implementation of the pre-index monitor: arrival-order
    /// windows, full-window retain retirement, a verdict for every pair.
    /// The indexed monitor must agree on every outcome, witness set, and
    /// `pairs_charged` (= this reference's pair count).
    struct RefMonitor {
        windows: Vec<Vec<Candidate>>,
        retire_ms: Millis,
        pairs: u64,
    }

    impl RefMonitor {
        fn new(n_conjuncts: usize, retire_ms: Millis) -> Self {
            Self { windows: vec![Vec::new(); n_conjuncts], retire_ms, pairs: 0 }
        }

        fn process(&mut self, cand: &Candidate, eps: Millis) -> Option<Vec<Candidate>> {
            let horizon = cand.end_pt_ms() - self.retire_ms;
            for win in &mut self.windows {
                win.retain(|o| o.end_pt_ms() >= horizon);
            }
            if !cand.truth {
                return None;
            }
            let mut pairs = 0u64;
            let found = self.search(cand, eps, &mut pairs);
            self.pairs += pairs;
            match found {
                Some(witnesses) => {
                    for w in &witnesses {
                        self.windows[w.conjunct as usize]
                            .retain(|o| !(o.server == w.server && o.seq == w.seq));
                    }
                    Some(witnesses)
                }
                None => {
                    self.windows[cand.conjunct as usize].push(cand.clone());
                    None
                }
            }
        }

        fn search(&self, cand: &Candidate, eps: Millis, pairs: &mut u64) -> Option<Vec<Candidate>> {
            if self.windows.len() == 1 {
                return Some(vec![cand.clone()]);
            }
            let mut compat: Vec<Vec<&Candidate>> = Vec::new();
            for (j, win) in self.windows.iter().enumerate() {
                if j == cand.conjunct as usize {
                    compat.push(Vec::new());
                    continue;
                }
                if win.is_empty() {
                    return None;
                }
                *pairs += win.len() as u64;
                let ok: Vec<&Candidate> = win
                    .iter()
                    .filter(|o| {
                        HvcInterval::verdict(&cand.interval, &o.interval, eps)
                            == IntervalOrd::Concurrent
                    })
                    .collect();
                if ok.is_empty() {
                    return None;
                }
                compat.push(ok);
            }
            let mut chosen: Vec<&Candidate> = vec![cand];
            if Self::dfs(&compat, cand.conjunct as usize, 0, &mut chosen, eps, pairs) {
                Some(chosen.into_iter().cloned().collect())
            } else {
                None
            }
        }

        fn dfs<'a>(
            compat: &[Vec<&'a Candidate>],
            skip: usize,
            j: usize,
            chosen: &mut Vec<&'a Candidate>,
            eps: Millis,
            pairs: &mut u64,
        ) -> bool {
            if j >= compat.len() {
                return true;
            }
            if j == skip {
                return Self::dfs(compat, skip, j + 1, chosen, eps, pairs);
            }
            'next: for &o in &compat[j] {
                if chosen.len() > 1 {
                    *pairs += (chosen.len() - 1) as u64;
                    for c in &chosen[1..] {
                        if HvcInterval::verdict(&c.interval, &o.interval, eps)
                            != IntervalOrd::Concurrent
                        {
                            continue 'next;
                        }
                    }
                }
                chosen.push(o);
                if Self::dfs(compat, skip, j + 1, chosen, eps, pairs) {
                    return true;
                }
                chosen.pop();
            }
            false
        }
    }

    #[test]
    fn indexed_search_matches_the_bruteforce_reference() {
        // randomized differential run: the indexed monitor and the
        // pre-index reference must report the same violations with the
        // same witnesses, and `pairs_charged` must equal the reference's
        // pair count exactly (schedule purity) while `pairs_checked`
        // does strictly less verdict work
        for (case, eps) in [0i64, 3, 40, crate::clock::hvc::EPS_INF].into_iter().enumerate() {
            let interner = Interner::new();
            let registry = Rc::new(RefCell::new(Registry::new()));
            let n_conjuncts = 3usize;
            let conjs = (0..n_conjuncts)
                .map(|i| {
                    let v = interner.borrow_mut().intern(&format!("d{i}"));
                    Conjunct { literals: vec![Literal { var: v, value: Value::Bool(true) }] }
                })
                .collect();
            let spec = PredicateSpec {
                id: PredId(0),
                name: "diff".into(),
                kind: PredKind::Linear,
                clauses: vec![Clause { conjuncts: conjs }],
            };
            let id = registry.borrow_mut().add(spec);
            let mut mon = monitor(registry);
            mon.cfg.retire_window_ms = 150;
            let mut reference = RefMonitor::new(n_conjuncts, 150);

            let mut rng = Rng::new(0xC0FFEE + case as u64);
            let mut t = 100i64;
            for seq in 0..400u64 {
                t += rng.range(0, 30) as i64;
                let conjunct = rng.below(n_conjuncts as u64) as u16;
                let server = rng.below(2) as u16;
                let len = rng.range(0, 120) as i64;
                let truth = rng.chance(0.8);
                let c = cand(id, conjunct, server, seq, t, t + len, truth);
                let got = mon.process(c.clone(), 0, eps, ProcId(9));
                let want = reference.process(&c, eps);
                match (&got, &want) {
                    (None, None) => {}
                    (Some(g), Some(w)) => {
                        let key = |c: &Candidate| (c.conjunct, c.server, c.seq);
                        let gk: Vec<_> = g.witnesses.iter().map(key).collect();
                        let wk: Vec<_> = w.iter().map(key).collect();
                        assert_eq!(gk, wk, "witness tuples diverged at seq {seq} (eps {eps})");
                    }
                    _ => panic!("outcome diverged at seq {seq} (eps {eps}): {got:?} vs {want:?}"),
                }
            }
            assert_eq!(
                mon.pairs_charged, reference.pairs,
                "charged pairs must replicate the linear scan exactly (eps {eps})"
            );
            assert!(
                mon.pairs_checked <= mon.pairs_charged,
                "the index can never do more verdict work than the scan"
            );
            if eps == crate::clock::hvc::EPS_INF {
                assert_eq!(
                    mon.pairs_checked, 0,
                    "with ε = ∞ every pair is certified — zero verdicts"
                );
            }
        }
    }
}
