//! Predicate → monitor assignment (§V): "The predicates are assigned to
//! the monitors based on the hash of the predicate names in order to
//! balance the monitors' workload." The number of monitors equals the
//! number of servers, each co-located with one server.

/// FNV-1a — stable across processes, so every server assigns identically.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Index of the monitor responsible for predicate `name` among `n` monitors.
pub fn monitor_index(name: &str, n: usize) -> usize {
    assert!(n > 0);
    (fnv1a(name.as_bytes()) % n as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_and_in_range() {
        for n in 1..8 {
            for name in ["me_1_2", "me_3_4", "conj_0", "weather_7"] {
                let i = monitor_index(name, n);
                assert!(i < n);
                assert_eq!(i, monitor_index(name, n), "deterministic");
            }
        }
    }

    #[test]
    fn balances_many_predicates() {
        let n = 5;
        let mut counts = vec![0usize; n];
        for a in 0..200 {
            for b in (a + 1)..(a + 6) {
                counts[monitor_index(&format!("me_{a}_{b}"), n)] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        let expect = total / n;
        for &c in &counts {
            assert!(
                c > expect / 2 && c < expect * 2,
                "imbalanced: {counts:?}"
            );
        }
    }
}
