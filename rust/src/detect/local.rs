//! The local predicate detector attached to each server (§V, Fig. 4/5).
//!
//! It intercepts PUT requests, maintains a cache of the variables relevant
//! to the registered predicates, and sends *candidates* (HVC intervals +
//! partial state) to the monitors:
//!
//! * **linear / conjunctive** conjuncts: a candidate is sent upon a PUT of
//!   a relevant variable iff the conjunct held during the interval since
//!   the previous relevant PUT (Fig. 5 — "it depends on whether ¬LP was
//!   true after execution of the *previous* PUT request");
//! * **semilinear** conjuncts: a candidate is *always* sent upon a PUT of
//!   a relevant variable (Fig. 5 caption), carrying the pre-state values.
//!
//! It also performs on-demand predicate inference from variable naming
//! conventions (§V "Automatic inference"), generating the mutual-exclusion
//! predicate for an edge the first time any of its lock variables is
//! touched.
//!
//! On a partitioned cluster the detector is ownership-aware: it caches
//! and registers only the conjunct variables whose partition this server
//! replicates (the ring's routing-tag convention co-locates all variables
//! of one mutual-exclusion conjunct, so every conjunct the server emits
//! candidates for is fully evaluable from owned state).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::clock::hvc::{Hvc, HvcInterval};
use crate::detect::assign::monitor_index;
use crate::detect::candidate::Candidate;
use crate::predicate::infer;
use crate::predicate::spec::{PredId, PredKind, PredicateSpec, Registry};
use crate::sim::{ProcId, Time};
use crate::store::ring::Router;
use crate::store::table::Table;
use crate::store::value::{Interner, KeyId, Value};

/// Per-(pred, clause, conjunct) tracking state.
#[derive(Debug, Clone)]
struct ConjState {
    /// HVC when the current state epoch began (start of candidate
    /// interval) — an `Rc` snapshot of the server clock at that moment;
    /// candidate emission bumps the refcount instead of cloning vectors
    since: Rc<Hvc>,
    /// truth of the conjunct during the current epoch
    truth: bool,
}

/// What one PUT interception produced; the server turns these into
/// messages and CPU charges.
#[derive(Debug, Default)]
pub struct DetectorOutput {
    /// (destination monitor, candidate)
    pub candidates: Vec<(ProcId, Candidate)>,
    /// conjunct evaluations performed (CPU cost accounting)
    pub checks: u32,
}

pub struct LocalDetector {
    server_idx: u16,
    registry: Rc<RefCell<Registry>>,
    interner: Rc<RefCell<Interner>>,
    /// partition ownership (shared ring view)
    router: Rc<Router>,
    /// monitor actor ids, indexed by monitor number
    monitors: Vec<ProcId>,
    /// cache of relevant variables: var → sibling values (pre-PUT state)
    cache: HashMap<KeyId, Vec<Value>>,
    /// conjunct tracking, keyed by (pred, clause, conjunct)
    states: HashMap<(PredId, u16, u16), ConjState>,
    /// per-server monotone candidate sequence
    seq: u64,
    /// enable naming-convention inference
    pub infer_enabled: bool,
    /// candidates emitted (stats)
    pub emitted: u64,
}

impl LocalDetector {
    pub fn new(
        server_idx: u16,
        registry: Rc<RefCell<Registry>>,
        interner: Rc<RefCell<Interner>>,
        router: Rc<Router>,
        monitors: Vec<ProcId>,
        infer_enabled: bool,
    ) -> Self {
        Self {
            server_idx,
            registry,
            interner,
            router,
            monitors,
            cache: HashMap::new(),
            states: HashMap::new(),
            seq: 0,
            infer_enabled,
            emitted: 0,
        }
    }

    pub fn monitor_of(&self, pred_name: &str) -> ProcId {
        self.monitors[monitor_index(pred_name, self.monitors.len())]
    }

    /// Seed the cache for a predicate's variables from the current table
    /// (done at registration so pre-state values are always available).
    /// Only variables whose partition this server replicates are cached —
    /// the server never sees PUTs for the rest, so caching them would
    /// only pin stale values.
    fn seed_pred_cache(&mut self, pred: PredId, table: &Table) {
        let vars = {
            let reg = self.registry.borrow();
            reg.get(pred).vars()
        };
        for var in vars {
            if !self.router.owns(self.server_idx, var) {
                continue;
            }
            self.cache
                .entry(var)
                .or_insert_with(|| table.sibling_values(var));
        }
    }

    /// Register all predicates currently in the registry (startup).
    pub fn sync_registry(&mut self, table: &Table) {
        let ids: Vec<PredId> = self.registry.borrow().iter().map(|s| s.id).collect();
        for id in ids {
            self.seed_pred_cache(id, table);
        }
    }

    /// Inference hook: any request (GET or PUT) touching `key` may reveal a
    /// lock variable whose edge predicate doesn't exist yet. The server
    /// only routes owned keys here, so registration happens exactly at the
    /// replicas of the edge's lock partition. Returns the registration
    /// messages (spec included) to forward to the owning monitors.
    pub fn on_request_key(&mut self, key: KeyId, table: &Table) -> Vec<(ProcId, PredicateSpec)> {
        if !self.infer_enabled {
            return Vec::new();
        }
        let edge = {
            let interner = self.interner.borrow();
            infer::recognize(interner.name(key))
        };
        let Some(e) = edge else { return Vec::new() };
        let name = infer::pred_name(e.a, e.b);
        if self.registry.borrow().by_name(&name).is_some() {
            return Vec::new();
        }
        let spec = infer::edge_predicate(e.a, e.b, &mut self.interner.borrow_mut());
        let id = self.registry.borrow_mut().add(spec);
        self.seed_pred_cache(id, table);
        let dst = self.monitor_of(&name);
        let spec = self.registry.borrow().get(id).clone();
        vec![(dst, spec)]
    }

    /// Intercept a PUT that has just been applied to `table`. `hvc_now` is
    /// the server's HVC after receiving the request (an `Rc` snapshot —
    /// the server mutates its clock copy-on-write, so holding it is free).
    pub fn on_put(&mut self, key: KeyId, table: &Table, hvc_now: &Rc<Hvc>, now: Time) -> DetectorOutput {
        let mut out = DetectorOutput::default();

        // fast path: variable not relevant to any predicate
        let affected: Vec<(PredId, u16, u16)> = match self.registry.borrow().affected(key) {
            None => return out,
            Some(list) => list.to_vec(),
        };

        // phase 1: emit candidates for every affected conjunct using the
        // PRE-put cache (the paper's candidates describe the state during
        // the interval that ends at this PUT)
        for &(pred, clause, conjunct) in &affected {
            out.checks += 1;
            let (kind, name, conj) = {
                let reg = self.registry.borrow();
                let spec = reg.get(pred);
                (
                    spec.kind,
                    spec.name.clone(),
                    spec.clauses[clause as usize].conjuncts[conjunct as usize].clone(),
                )
            };
            let state = self
                .states
                .entry((pred, clause, conjunct))
                .or_insert_with(|| ConjState { since: Rc::clone(hvc_now), truth: false });
            let pre_truth = state.truth;
            let since = Rc::clone(&state.since);

            // pre-state values of the conjunct's variables (from the cache)
            let pre_values: Vec<(KeyId, Value)> = conj
                .literals
                .iter()
                .flat_map(|l| {
                    self.cache
                        .get(&l.var)
                        .into_iter()
                        .flatten()
                        .map(move |v| (l.var, v.clone()))
                })
                .collect();

            // Linear/conjunctive predicates use *onset* emission instead
            // (phase 2 below): the classic weak-conjunctive algorithm sends
            // the candidate when the local predicate becomes true, which is
            // what gives the paper's millisecond-scale detection latencies
            // (Table III). Closing-PUT emission (Fig. 5) would delay
            // detection until the variable's next write.
            let emit = match kind {
                PredKind::Linear => false,
                PredKind::Semilinear => true,
            };
            if emit && since.compare(hvc_now) != crate::clock::hvc::HvcOrd::After {
                let cand = Candidate {
                    pred,
                    clause,
                    conjunct,
                    server: ProcId(u32::MAX), // filled by the server actor
                    seq: self.seq,
                    interval: HvcInterval::new(since, Rc::clone(hvc_now)),
                    values: pre_values,
                    truth: pre_truth,
                    emitted_at: now,
                };
                self.seq += 1;
                self.emitted += 1;
                let dst = self.monitor_of(&name);
                out.candidates.push((dst, cand));
            }
        }

        // phase 2: refresh the cache with the post-PUT siblings, then
        // re-evaluate the affected conjuncts for the new state epoch; for
        // linear predicates, a false→true transition emits an onset
        // candidate immediately (point interval [now, now])
        self.cache.insert(key, table.sibling_values(key));
        for &(pred, clause, conjunct) in &affected {
            let (kind, name, conj) = {
                let reg = self.registry.borrow();
                let spec = reg.get(pred);
                (
                    spec.kind,
                    spec.name.clone(),
                    spec.clauses[clause as usize].conjuncts[conjunct as usize].clone(),
                )
            };
            let cache = &self.cache;
            let post_truth = conj.satisfied_by(|k| cache.get(&k).cloned());
            let pre_truth = self.states.get(&(pred, clause, conjunct)).map(|s| s.truth).unwrap_or(false);
            if kind == PredKind::Linear && post_truth && !pre_truth {
                let post_values: Vec<(KeyId, Value)> = conj
                    .literals
                    .iter()
                    .flat_map(|l| {
                        self.cache
                            .get(&l.var)
                            .into_iter()
                            .flatten()
                            .map(move |v| (l.var, v.clone()))
                    })
                    .collect();
                let cand = Candidate {
                    pred,
                    clause,
                    conjunct,
                    server: ProcId(u32::MAX),
                    seq: self.seq,
                    // point interval: both endpoints share one snapshot
                    interval: HvcInterval::new(Rc::clone(hvc_now), Rc::clone(hvc_now)),
                    values: post_values,
                    truth: true,
                    emitted_at: now,
                };
                self.seq += 1;
                self.emitted += 1;
                let dst = self.monitor_of(&name);
                out.candidates.push((dst, cand));
            }
            let state = self.states.get_mut(&(pred, clause, conjunct)).unwrap();
            state.truth = post_truth;
            state.since = Rc::clone(hvc_now);
        }
        out
    }

    pub fn server_idx(&self) -> u16 {
        self.server_idx
    }

    pub fn registry(&self) -> &Rc<RefCell<Registry>> {
        &self.registry
    }

    /// Clear and re-seed the relevant-variable cache from `table` (used
    /// after a rollback restored older state), and recompute conjunct
    /// truths against the restored values.
    pub fn reseed(&mut self, table: &Table) {
        self.cache.clear();
        self.sync_registry(table);
        let keys: Vec<(PredId, u16, u16)> = self.states.keys().copied().collect();
        for (pred, clause, conjunct) in keys {
            let conj = {
                let reg = self.registry.borrow();
                reg.get(pred).clauses[clause as usize].conjuncts[conjunct as usize].clone()
            };
            let cache = &self.cache;
            let truth = conj.satisfied_by(|k| cache.get(&k).cloned());
            self.states.get_mut(&(pred, clause, conjunct)).unwrap().truth = truth;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::vc::VectorClock;
    use crate::predicate::spec::{Clause, Conjunct, Literal};
    use crate::store::ring::Ring;

    /// A router where `n_servers` servers each replicate every key.
    fn full_router(n_servers: usize, interner: &Rc<RefCell<Interner>>) -> Rc<Router> {
        Router::full(n_servers, interner.clone())
    }

    fn setup(kind: PredKind) -> (LocalDetector, Table, Rc<RefCell<Interner>>, PredId, KeyId, KeyId) {
        let interner = Interner::new();
        let registry = Rc::new(RefCell::new(Registry::new()));
        let (x, y) = {
            let mut i = interner.borrow_mut();
            (i.intern("x"), i.intern("y"))
        };
        let spec = PredicateSpec {
            id: PredId(0),
            name: "p".into(),
            kind,
            clauses: vec![Clause {
                conjuncts: vec![Conjunct {
                    literals: vec![
                        Literal { var: x, value: Value::Int(1) },
                        Literal { var: y, value: Value::Int(1) },
                    ],
                }],
            }],
        };
        let id = registry.borrow_mut().add(spec);
        let router = full_router(1, &interner);
        let mut det = LocalDetector::new(
            0,
            registry,
            interner.clone(),
            router,
            vec![ProcId(10), ProcId(11)],
            false,
        );
        let table = Table::new();
        det.sync_registry(&table);
        (det, table, interner, id, x, y)
    }

    fn hvc(t: i64) -> Rc<Hvc> {
        Rc::new(Hvc::from_vec(0, vec![t, 0]))
    }

    fn put(table: &mut Table, det: &mut LocalDetector, key: KeyId, val: i64, t: i64, n: u64) -> DetectorOutput {
        let mut vc = VectorClock::new();
        for _ in 0..n {
            vc.increment(9);
        }
        table.put(key, vc, Value::Int(val));
        det.on_put(key, table, &hvc(t), t as u64 * 1_000_000)
    }

    #[test]
    fn linear_emits_on_truth_onset() {
        let (mut det, mut table, _i, _id, x, y) = setup(PredKind::Linear);
        // x=1: conjunct still false (y missing) → nothing emitted
        let o1 = put(&mut table, &mut det, x, 1, 10, 1);
        assert!(o1.candidates.is_empty());
        // y=1: conjunct becomes TRUE → onset candidate at [20, 20]
        let o2 = put(&mut table, &mut det, y, 1, 20, 1);
        assert_eq!(o2.candidates.len(), 1);
        let c = &o2.candidates[0].1;
        assert!(c.truth);
        assert_eq!(c.interval.start.v[0], 20);
        assert_eq!(c.interval.end.v[0], 20);
        // x=0: conjunct turns false → no emission (onset-only for linear)
        let o3 = put(&mut table, &mut det, x, 0, 30, 2);
        assert!(o3.candidates.is_empty());
        // x=1 again: rising edge → another onset
        let o4 = put(&mut table, &mut det, x, 1, 40, 3);
        assert_eq!(o4.candidates.len(), 1);
        assert_eq!(o4.candidates[0].1.interval.start.v[0], 40);
    }

    #[test]
    fn semilinear_always_emits_on_relevant_put() {
        let (mut det, mut table, _i, _id, x, _y) = setup(PredKind::Semilinear);
        let o1 = put(&mut table, &mut det, x, 1, 10, 1);
        assert_eq!(o1.candidates.len(), 1);
        assert!(!o1.candidates[0].1.truth, "pre-state was false");
        let o2 = put(&mut table, &mut det, x, 2, 20, 2);
        assert_eq!(o2.candidates.len(), 1);
        // pre-values carried for the monitor to evaluate
        assert!(o2.candidates[0].1.values.iter().any(|(k, v)| *k == x && *v == Value::Int(1)));
    }

    #[test]
    fn irrelevant_put_is_free() {
        let (mut det, mut table, interner, _id, _x, _y) = setup(PredKind::Linear);
        let z = interner.borrow_mut().intern("z");
        let o = put(&mut table, &mut det, z, 5, 10, 1);
        assert_eq!(o.checks, 0);
        assert!(o.candidates.is_empty());
    }

    #[test]
    fn candidate_seq_monotone() {
        let (mut det, mut table, _i, _id, x, _y) = setup(PredKind::Semilinear);
        let o1 = put(&mut table, &mut det, x, 1, 10, 1);
        let o2 = put(&mut table, &mut det, x, 2, 20, 2);
        assert!(o2.candidates[0].1.seq > o1.candidates[0].1.seq);
        assert_eq!(det.emitted, 2);
    }

    #[test]
    fn inference_generates_edge_predicate_once() {
        let interner = Interner::new();
        let registry = Rc::new(RefCell::new(Registry::new()));
        let router = full_router(1, &interner);
        let mut det = LocalDetector::new(
            0,
            registry.clone(),
            interner.clone(),
            router,
            vec![ProcId(10), ProcId(11), ProcId(12)],
            true,
        );
        let table = Table::new();
        let flag = interner.borrow_mut().intern("flag_3_17_3");
        let regs = det.on_request_key(flag, &table);
        assert_eq!(regs.len(), 1);
        assert_eq!(registry.borrow().len(), 1);
        assert!(registry.borrow().by_name("me_3_17").is_some());
        // second request: already registered, no-op
        let regs2 = det.on_request_key(flag, &table);
        assert!(regs2.is_empty());
        // unrelated keys do not infer
        let other = interner.borrow_mut().intern("color_5");
        assert!(det.on_request_key(other, &table).is_empty());
        assert_eq!(registry.borrow().len(), 1);
    }

    #[test]
    fn monitor_assignment_consistent_across_servers() {
        let (det_a, ..) = setup(PredKind::Linear);
        let interner = Interner::new();
        let registry = Rc::new(RefCell::new(Registry::new()));
        let router = full_router(2, &interner);
        let det_b = LocalDetector::new(
            1,
            registry,
            interner,
            router,
            vec![ProcId(10), ProcId(11)],
            false,
        );
        assert_eq!(det_a.monitor_of("me_1_2"), det_b.monitor_of("me_1_2"));
    }

    #[test]
    fn cache_restricted_to_owned_partitions() {
        // a 4-server / N=1 ring: each key lives on exactly one server, so
        // a detector seeds (and later refreshes) only its own partition
        let interner = Interner::new();
        let registry = Rc::new(RefCell::new(Registry::new()));
        let keys: Vec<KeyId> = (0..16)
            .map(|i| interner.borrow_mut().intern(&format!("x_0_{i}")))
            .collect();
        let spec = PredicateSpec {
            id: PredId(0),
            name: "conj_0".into(),
            kind: PredKind::Linear,
            clauses: vec![Clause {
                conjuncts: keys
                    .iter()
                    .map(|&v| Conjunct {
                        literals: vec![Literal { var: v, value: Value::Int(1) }],
                    })
                    .collect(),
            }],
        };
        registry.borrow_mut().add(spec);
        let router = Router::new(Ring::new(4, 1, 16, 1), interner.clone());
        let table = Table::new();
        let mut total_cached = 0;
        for s in 0..4u16 {
            let mut det = LocalDetector::new(
                s,
                registry.clone(),
                interner.clone(),
                router.clone(),
                vec![ProcId(10)],
                false,
            );
            det.sync_registry(&table);
            let owned = keys.iter().filter(|&&k| router.owns(s, k)).count();
            assert_eq!(det.cache.len(), owned, "server {s} caches exactly its partitions");
            total_cached += det.cache.len();
        }
        assert_eq!(total_cached, keys.len(), "partitions cover the keyspace once");
    }
}
