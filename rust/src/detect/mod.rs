//! The monitoring module (§V): local predicate detectors on servers,
//! monitors running the linear/semilinear detection algorithms, candidate
//! types, and hash-based predicate→monitor assignment.

pub mod assign;
pub mod candidate;
pub mod local;
pub mod monitor;
