//! Candidates and violation reports — what flows from the local predicate
//! detectors to the monitors, and from the monitors to the rollback
//! controller.

use crate::clock::hvc::{HvcInterval, Millis};
use crate::predicate::spec::PredId;
use crate::sim::{ProcId, Time};
use crate::store::value::{KeyId, Value};

/// A candidate (§V): an HVC interval on one server during which (the local
/// part of) a conjunct held, plus the partial state that made it hold.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub pred: PredId,
    /// clause index within ¬P's DNF
    pub clause: u16,
    /// conjunct index within the clause
    pub conjunct: u16,
    /// originating server (actor id)
    pub server: ProcId,
    /// per-server monotone sequence number (dedup / ordering)
    pub seq: u64,
    pub interval: HvcInterval,
    /// values of the conjunct's variables during the interval (sibling
    /// lists flattened: a var may appear with several concurrent values)
    pub values: Vec<(KeyId, Value)>,
    /// whether the conjunct was satisfied during the interval (linear
    /// predicates pre-filter; semilinear candidates are always sent and
    /// carry the truth for the monitor to use)
    pub truth: bool,
    /// virtual time the server emitted it (latency accounting)
    pub emitted_at: Time,
}

impl Candidate {
    /// Physical start of the interval at the owning server, in ms — the
    /// paper's safe estimate basis for `T_violate`.
    pub fn start_pt_ms(&self) -> Millis {
        self.interval.start.v[self.interval.owner() as usize]
    }

    pub fn end_pt_ms(&self) -> Millis {
        self.interval.end.v[self.interval.owner() as usize]
    }
}

/// Evidence of a violation: a pairwise-concurrent set of candidates, one
/// per conjunct of some clause of ¬P.
#[derive(Debug, Clone)]
pub struct ViolationReport {
    pub pred: PredId,
    pub pred_name: String,
    pub clause: u16,
    pub witnesses: Vec<Candidate>,
    /// safe estimate of when the violation began (min physical start
    /// across witnesses), used by the rollback module as `T_violate`
    pub t_violate_ms: Millis,
    /// when the violating global state came to exist (max physical start
    /// across witnesses) — basis for detection-latency accounting
    pub t_occurred_ms: Millis,
    /// virtual time the monitor detected it
    pub detected_at: Time,
    /// monitor that found it
    pub monitor: ProcId,
}

impl ViolationReport {
    pub fn from_witnesses(
        pred: PredId,
        pred_name: String,
        clause: u16,
        witnesses: Vec<Candidate>,
        detected_at: Time,
        monitor: ProcId,
    ) -> Self {
        let t_violate_ms = witnesses.iter().map(|c| c.start_pt_ms()).min().unwrap_or(0);
        let t_occurred_ms = witnesses.iter().map(|c| c.start_pt_ms()).max().unwrap_or(0);
        Self { pred, pred_name, clause, witnesses, t_violate_ms, t_occurred_ms, detected_at, monitor }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::hvc::Hvc;

    fn interval(owner: u16, s: &[Millis], e: &[Millis]) -> HvcInterval {
        HvcInterval::new(Hvc::from_vec(owner, s.to_vec()), Hvc::from_vec(owner, e.to_vec()))
    }

    fn cand(owner: u16, s: &[Millis], e: &[Millis]) -> Candidate {
        Candidate {
            pred: PredId(0),
            clause: 0,
            conjunct: owner,
            server: ProcId(owner as u32),
            seq: 0,
            interval: interval(owner, s, e),
            values: vec![],
            truth: true,
            emitted_at: 0,
        }
    }

    #[test]
    fn start_end_pt() {
        let c = cand(1, &[5, 10], &[5, 20]);
        assert_eq!(c.start_pt_ms(), 10);
        assert_eq!(c.end_pt_ms(), 20);
    }

    #[test]
    fn t_violate_is_min_start() {
        let w1 = cand(0, &[100, 0], &[120, 0]);
        let w2 = cand(1, &[0, 90], &[0, 130]);
        let rep = ViolationReport::from_witnesses(
            PredId(3),
            "me_1_2".into(),
            0,
            vec![w1, w2],
            42,
            ProcId(7),
        );
        assert_eq!(rep.t_violate_ms, 90);
        assert_eq!(rep.t_occurred_ms, 100);
        assert_eq!(rep.pred, PredId(3));
    }
}
