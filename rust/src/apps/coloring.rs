//! *Social Media Analysis* (§VI-A): distributed greedy graph coloring on a
//! power-law social graph. Each client colors its assigned nodes in tasks
//! of `task_size` nodes; before updating a node it takes Peterson edge
//! locks for every cross-client edge (in a globally consistent order to
//! avoid deadlock), reads the neighbors' colors, picks the smallest free
//! color, *defers* the color write to the end of the task, then releases
//! the locks. On a violation report the client aborts and restarts the
//! task — no server-side rollback needed for deferred updates (§VI-B
//! "Discussion").
//!
//! High-degree nodes (degree > q, §VI-A) are pre-colored lock-free by
//! their owners in a preprocessing pass; their edges need no predicates.
//!
//! When the client pipeline is enabled (`pipeline_depth > 1`) the app
//! *scatter-gathers*: all `deg(v)` neighbor reads of a node go out as one
//! [`AppAction::Batch`] wave instead of `deg(v)` sequential round trips,
//! and the task's deferred color writes commit as one wave. Lock
//! acquisition stays strictly sequential — the globally sorted
//! acquire order is what guarantees deadlock freedom.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::apps::graph::Graph;
use crate::apps::peterson::{LockStep, MeOracleRef, PetersonLock};
use crate::client::app::{AppAction, AppEnv, AppLogic, AppOp, LastResult, OpOutcome};
use crate::clock::hvc::Millis;
use crate::metrics::throughput::Metrics;
use crate::sim::Time;
use crate::store::value::{resolve, Interner, KeyId, Value};

/// Everything the coloring clients share (single-threaded DES ⇒ `Rc`).
#[derive(Clone)]
pub struct ColoringShared {
    pub graph: Rc<Graph>,
    /// node → owning client
    pub owner: Rc<Vec<u32>>,
    pub interner: Rc<RefCell<Interner>>,
    pub oracle: MeOracleRef,
    pub metrics: Metrics,
    /// node → is high-degree (pre-colored, lock-free)
    pub hi_deg: Rc<Vec<bool>>,
    pub task_size: usize,
    /// recolor forever (throughput experiments) vs one pass
    pub loop_forever: bool,
    /// self-stabilizing variant (the `Stabilize` recovery strategy's
    /// demonstration workload): rollback notifications are ignored — no
    /// task aborts — because continuous re-coloring repairs conflicting
    /// colors on its own
    pub stabilize: bool,
}

impl ColoringShared {
    pub fn new(
        graph: Rc<Graph>,
        n_clients: usize,
        interner: Rc<RefCell<Interner>>,
        registry: &Rc<RefCell<crate::predicate::spec::Registry>>,
        oracle: MeOracleRef,
        metrics: Metrics,
        task_size: usize,
        loop_forever: bool,
        stabilize: bool,
    ) -> Self {
        let owner: Rc<Vec<u32>> =
            Rc::new(crate::apps::graph::partition_nodes(graph.n, n_clients));
        let q = graph.high_degree_threshold();
        let hi_deg: Rc<Vec<bool>> =
            Rc::new((0..graph.n as u32).map(|v| graph.degree(v) > q).collect());
        // Pre-freeze the key/predicate layout in canonical order: every
        // color key in node order, then every lockable cross-client edge
        // (both endpoints regular) in sorted order — lock variables and
        // the edge's mutual-exclusion predicate. Run-time interning and
        // inference then only ever *look up*, so KeyIds and PredIds are
        // identical on every engine and every shard.
        {
            let mut int = interner.borrow_mut();
            for v in 0..graph.n as u32 {
                color_key(&mut int, v);
            }
            let mut reg = registry.borrow_mut();
            for a in 0..graph.n as u32 {
                if hi_deg[a as usize] {
                    continue;
                }
                for &b in graph.neighbors(a) {
                    if b <= a || hi_deg[b as usize] || owner[b as usize] == owner[a as usize] {
                        continue;
                    }
                    let spec =
                        crate::predicate::infer::edge_predicate(a as u64, b as u64, &mut int);
                    reg.add(spec);
                }
            }
        }
        Self { graph, owner, interner, oracle, metrics, hi_deg, task_size, loop_forever, stabilize }
    }
}

pub fn color_key(interner: &mut Interner, v: u32) -> KeyId {
    interner.intern(&format!("color_{v}"))
}

/// Smallest non-negative color not in `used`.
fn mex(used: &[i64]) -> i64 {
    let mut c = 0i64;
    loop {
        if !used.contains(&c) {
            return c;
        }
        c += 1;
    }
}

#[derive(Debug)]
enum Phase {
    /// first `next()` call: begin the preprocessing pass
    Init,
    /// pre-coloring own high-degree nodes: reading neighbor `nj` of prep
    /// node `pi`
    PrepRead { pi: usize, nj: usize, used: Vec<i64> },
    /// pipelined variant: all neighbor reads of prep node `pi` in flight
    PrepWave { pi: usize },
    PrepWrite { pi: usize },
    TaskStart,
    /// acquiring lock `li` for node `ni` of the current task
    Lock { ni: usize, li: usize },
    /// reading neighbor `nj` of node `ni`
    ReadNbr { ni: usize, nj: usize, used: Vec<i64> },
    /// pipelined variant: all neighbor reads of node `ni` in flight
    ReadWave { ni: usize },
    /// releasing lock `li` after the color was chosen (deferred)
    Release { ni: usize, li: usize },
    /// committing deferred color `ci` of the task
    Commit { ci: usize },
    /// pipelined variant: the task's deferred writes commit as one wave
    CommitWave,
    /// releasing engaged locks after an abort, index into `locks`
    AbortRelease { li: usize },
    Done,
}

/// Colors observed by a completed scatter-gather read wave.
fn used_from_wave(wave: &[(AppOp, OpOutcome)]) -> Vec<i64> {
    wave.iter()
        .filter_map(|(_, o)| match o {
            OpOutcome::GetOk(sibs) => resolve(sibs).and_then(|v| v.value.as_int()),
            _ => None,
        })
        .collect()
}

pub struct ColoringApp {
    sh: ColoringShared,
    client: u32,
    /// my high-degree nodes (preprocessing pass)
    prep: Vec<u32>,
    /// my regular nodes, chunked into tasks
    tasks: Vec<Vec<u32>>,
    ti: usize,
    phase: Phase,
    /// locks for the node being processed
    locks: Vec<PetersonLock>,
    /// deferred (node, color) updates of the current task
    pending: Vec<(u32, i64)>,
    restart_pending: bool,
    task_started: Time,
    /// scatter-gather reads/commits (latched from `AppEnv::pipelined`)
    batch: bool,
    /// cached key ids
    color_keys: HashMap<u32, KeyId>,
    /// stats
    pub nodes_colored: u64,
    pub tasks_done: u64,
    pub tasks_aborted: u64,
}

impl ColoringApp {
    pub fn new(sh: ColoringShared, client: u32) -> Self {
        let mine: Vec<u32> = (0..sh.graph.n as u32)
            .filter(|&v| sh.owner[v as usize] == client)
            .collect();
        let prep: Vec<u32> = mine.iter().copied().filter(|&v| sh.hi_deg[v as usize]).collect();
        let regular: Vec<u32> = mine.iter().copied().filter(|&v| !sh.hi_deg[v as usize]).collect();
        let tasks: Vec<Vec<u32>> = regular.chunks(sh.task_size.max(1)).map(|c| c.to_vec()).collect();
        Self {
            sh,
            client,
            prep,
            tasks,
            ti: 0,
            phase: Phase::Init,
            locks: Vec::new(),
            pending: Vec::new(),
            restart_pending: false,
            task_started: 0,
            batch: false,
            color_keys: HashMap::new(),
            nodes_colored: 0,
            tasks_done: 0,
            tasks_aborted: 0,
        }
    }

    fn ckey(&mut self, v: u32) -> KeyId {
        let interner = &self.sh.interner;
        *self
            .color_keys
            .entry(v)
            .or_insert_with(|| color_key(&mut interner.borrow_mut(), v))
    }

    /// Locks needed for node `v`: one per cross-client edge to a non-high-
    /// degree neighbor, in globally sorted (a, b) order (deadlock freedom).
    fn locks_for(&self, v: u32) -> Vec<PetersonLock> {
        let mut edges: Vec<(u32, u32)> = self
            .sh
            .graph
            .neighbors(v)
            .iter()
            .filter(|&&u| self.sh.owner[u as usize] != self.client && !self.sh.hi_deg[u as usize])
            .map(|&u| (v.min(u), v.max(u)))
            .collect();
        edges.sort_unstable();
        let mut interner = self.sh.interner.borrow_mut();
        edges
            .into_iter()
            .map(|(a, b)| PetersonLock::new(a, b, v, &mut interner))
            .collect()
    }

    /// Start processing node `ni` of the current task.
    fn begin_node(&mut self, ni: usize, now: Time) -> AppAction {
        let v = self.tasks[self.ti][ni];
        self.locks = self.locks_for(v);
        if self.locks.is_empty() {
            self.start_reads(ni, now)
        } else {
            self.phase = Phase::Lock { ni, li: 0 };
            match self.locks[0].acquire() {
                LockStep::Do(op) => AppAction::Op(op),
                _ => unreachable!(),
            }
        }
    }

    /// Issue the neighbor reads of node `ni`: one scatter-gather wave on a
    /// pipelined client, one GET at a time otherwise.
    fn start_reads(&mut self, ni: usize, now: Time) -> AppAction {
        if self.batch {
            let v = self.tasks[self.ti][ni];
            let nbrs = self.sh.graph.neighbors(v).to_vec();
            if nbrs.is_empty() {
                return self.finish_node(ni, Vec::new(), now);
            }
            let mut ops = Vec::with_capacity(nbrs.len());
            for u in nbrs {
                let key = self.ckey(u);
                ops.push(AppOp::Get(key));
            }
            self.phase = Phase::ReadWave { ni };
            AppAction::Batch(ops)
        } else {
            self.phase = Phase::ReadNbr { ni, nj: 0, used: Vec::new() };
            self.issue_read(ni, 0)
        }
    }

    fn issue_read(&mut self, ni: usize, nj: usize) -> AppAction {
        let v = self.tasks[self.ti][ni];
        let nbrs = self.sh.graph.neighbors(v).to_vec();
        if nj < nbrs.len() {
            let key = self.ckey(nbrs[nj]);
            AppAction::Op(AppOp::Get(key))
        } else {
            unreachable!("issue_read past neighbor list")
        }
    }

    /// Node read finished: defer the color, start releasing locks (or move
    /// on when there are none).
    fn finish_node(&mut self, ni: usize, mut used: Vec<i64>, now: Time) -> AppAction {
        let v = self.tasks[self.ti][ni];
        // deferred updates of same-task neighbors are not in the store yet;
        // consult the local pending buffer so the task stays self-consistent
        for &(u, c) in &self.pending {
            if self.sh.graph.neighbors(v).contains(&u) {
                used.push(c);
            }
        }
        self.pending.push((v, mex(&used)));
        if self.locks.is_empty() {
            self.after_release(ni, now)
        } else {
            self.phase = Phase::Release { ni, li: 0 };
            match self.locks[0].release() {
                LockStep::Do(op) => AppAction::Op(op),
                _ => unreachable!(),
            }
        }
    }

    fn after_release(&mut self, ni: usize, now: Time) -> AppAction {
        let task_len = self.tasks[self.ti].len();
        if ni + 1 < task_len {
            self.begin_node(ni + 1, now)
        } else if self.batch {
            // commit every deferred update of the task as one wave — the
            // writes are independent (distinct nodes, locks released)
            let pending = self.pending.clone();
            let mut ops = Vec::with_capacity(pending.len());
            for (v, c) in pending {
                let key = self.ckey(v);
                ops.push(AppOp::Put(key, Value::Int(c)));
            }
            self.phase = Phase::CommitWave;
            AppAction::Batch(ops)
        } else {
            // task read phase done → commit deferred updates one by one
            self.phase = Phase::Commit { ci: 0 };
            let (v, _) = self.pending[0];
            let key = self.ckey(v);
            let val = self.pending[0].1;
            AppAction::Op(AppOp::Put(key, Value::Int(val)))
        }
    }

    fn finish_task(&mut self, now: Time) -> AppAction {
        self.tasks_done += 1;
        self.nodes_colored += self.pending.len() as u64;
        {
            let mut m = self.sh.metrics.borrow_mut();
            m.tasks_completed += 1;
            m.task_durations.push(now - self.task_started);
        }
        self.pending.clear();
        self.ti += 1;
        if self.ti >= self.tasks.len() {
            if self.sh.loop_forever {
                self.ti = 0;
            } else {
                self.phase = Phase::Done;
                return AppAction::Done;
            }
        }
        self.phase = Phase::TaskStart;
        self.start_task(now)
    }

    fn start_task(&mut self, now: Time) -> AppAction {
        self.task_started = now;
        self.pending.clear();
        if self.ti >= self.tasks.len() || self.tasks[self.ti].is_empty() {
            self.phase = Phase::Done;
            return AppAction::Done;
        }
        self.begin_node(0, now)
    }

    /// Begin (or continue) prep: color own high-degree nodes lock-free.
    fn start_prep(&mut self, pi: usize) -> AppAction {
        if pi >= self.prep.len() {
            self.phase = Phase::TaskStart;
            return AppAction::Sleep(0);
        }
        let v = self.prep[pi];
        if self.sh.graph.degree(v) == 0 {
            self.phase = Phase::PrepWrite { pi };
            let key = self.ckey(v);
            return AppAction::Op(AppOp::Put(key, Value::Int(0)));
        }
        if self.batch {
            let nbrs = self.sh.graph.neighbors(v).to_vec();
            let mut ops = Vec::with_capacity(nbrs.len());
            for u in nbrs {
                let key = self.ckey(u);
                ops.push(AppOp::Get(key));
            }
            self.phase = Phase::PrepWave { pi };
            return AppAction::Batch(ops);
        }
        self.phase = Phase::PrepRead { pi, nj: 0, used: Vec::new() };
        let key = self.ckey(self.sh.graph.neighbors(v)[0]);
        AppAction::Op(AppOp::Get(key))
    }

    fn handle_abort(&mut self, now: Time, seq: u64) -> AppAction {
        // release any engaged locks, then restart the current task
        self.restart_pending = false;
        self.tasks_aborted += 1;
        self.sh.metrics.borrow_mut().tasks_aborted += 1;
        self.pending.clear();
        // oracle bookkeeping: we leave every CS we were in
        for l in &self.locks {
            if l.held() {
                self.sh.oracle.borrow_mut().exit(l.edge(), self.client, now, seq);
            }
        }
        let engaged: Vec<usize> = self
            .locks
            .iter()
            .enumerate()
            .filter(|(_, l)| l.engaged())
            .map(|(i, _)| i)
            .collect();
        if let Some(&first) = engaged.first() {
            self.phase = Phase::AbortRelease { li: first };
            match self.locks[first].release() {
                LockStep::Do(op) => AppAction::Op(op),
                _ => unreachable!(),
            }
        } else {
            self.start_task(now)
        }
    }
}

impl AppLogic for ColoringApp {
    fn name(&self) -> &'static str {
        "social_media_analysis"
    }

    fn next(&mut self, env: &mut AppEnv, last: Option<LastResult>) -> AppAction {
        let now = env.now;
        let seq = env.seq;
        self.batch = env.pipelined();
        if self.restart_pending {
            return self.handle_abort(now, seq);
        }
        let (outcome, wave) = match last {
            Some(LastResult::Op(_, o)) => (Some(o), Vec::new()),
            Some(LastResult::Batch(pairs)) => (None, pairs),
            None => (None, Vec::new()),
        };

        match std::mem::replace(&mut self.phase, Phase::Done) {
            Phase::Init => {
                self.task_started = now;
                self.start_prep(0)
            }
            Phase::Done => AppAction::Done,
            Phase::PrepRead { pi, nj, mut used } => {
                if let Some(OpOutcome::GetOk(sibs)) = &outcome {
                    if let Some(c) = resolve(sibs).and_then(|v| v.value.as_int()) {
                        used.push(c);
                    }
                }
                let v = self.prep[pi];
                let nbrs_len = self.sh.graph.degree(v);
                if nj + 1 < nbrs_len {
                    let key = self.ckey(self.sh.graph.neighbors(v)[nj + 1]);
                    self.phase = Phase::PrepRead { pi, nj: nj + 1, used };
                    AppAction::Op(AppOp::Get(key))
                } else {
                    let color = mex(&used);
                    let key = self.ckey(v);
                    self.phase = Phase::PrepWrite { pi };
                    AppAction::Op(AppOp::Put(key, Value::Int(color)))
                }
            }
            Phase::PrepWave { pi } => {
                let used = used_from_wave(&wave);
                let color = mex(&used);
                let v = self.prep[pi];
                let key = self.ckey(v);
                self.phase = Phase::PrepWrite { pi };
                AppAction::Op(AppOp::Put(key, Value::Int(color)))
            }
            Phase::PrepWrite { pi } => {
                self.nodes_colored += 1;
                self.start_prep(pi + 1)
            }
            Phase::TaskStart => {
                // entered via Sleep(0) from prep, or a restart
                self.start_task(now)
            }
            Phase::Lock { ni, li } => {
                let out = outcome.expect("lock op outcome");
                match self.locks[li].on_result(&out) {
                    LockStep::Do(op) => {
                        self.phase = Phase::Lock { ni, li };
                        AppAction::Op(op)
                    }
                    LockStep::Acquired => {
                        self.sh
                            .oracle
                            .borrow_mut()
                            .enter(self.locks[li].edge(), self.client, now, seq);
                        if li + 1 < self.locks.len() {
                            self.phase = Phase::Lock { ni, li: li + 1 };
                            match self.locks[li + 1].acquire() {
                                LockStep::Do(op) => AppAction::Op(op),
                                _ => unreachable!(),
                            }
                        } else {
                            self.start_reads(ni, now)
                        }
                    }
                    LockStep::Released => unreachable!(),
                }
            }
            Phase::ReadNbr { ni, nj, mut used } => {
                if let Some(OpOutcome::GetOk(sibs)) = &outcome {
                    if let Some(c) = resolve(sibs).and_then(|v| v.value.as_int()) {
                        used.push(c);
                    }
                }
                let v = self.tasks[self.ti][ni];
                if nj + 1 < self.sh.graph.degree(v) {
                    self.phase = Phase::ReadNbr { ni, nj: nj + 1, used };
                    let key = self.ckey(self.sh.graph.neighbors(v)[nj + 1]);
                    AppAction::Op(AppOp::Get(key))
                } else {
                    self.finish_node(ni, used, now)
                }
            }
            Phase::ReadWave { ni } => {
                let used = used_from_wave(&wave);
                self.finish_node(ni, used, now)
            }
            Phase::Release { ni, li } => {
                let out = outcome.expect("release outcome");
                match self.locks[li].on_result(&out) {
                    LockStep::Do(op) => {
                        self.phase = Phase::Release { ni, li };
                        AppAction::Op(op)
                    }
                    LockStep::Released => {
                        self.sh
                            .oracle
                            .borrow_mut()
                            .exit(self.locks[li].edge(), self.client, now, seq);
                        if li + 1 < self.locks.len() {
                            self.phase = Phase::Release { ni, li: li + 1 };
                            match self.locks[li + 1].release() {
                                LockStep::Do(op) => AppAction::Op(op),
                                _ => unreachable!(),
                            }
                        } else {
                            self.after_release(ni, now)
                        }
                    }
                    LockStep::Acquired => unreachable!(),
                }
            }
            Phase::Commit { ci } => {
                if ci + 1 < self.pending.len() {
                    let (v, c) = self.pending[ci + 1];
                    let key = self.ckey(v);
                    self.phase = Phase::Commit { ci: ci + 1 };
                    AppAction::Op(AppOp::Put(key, Value::Int(c)))
                } else {
                    self.finish_task(now)
                }
            }
            Phase::CommitWave => self.finish_task(now),
            Phase::AbortRelease { li } => {
                let out = outcome.expect("abort release outcome");
                match self.locks[li].on_result(&out) {
                    LockStep::Do(op) => {
                        self.phase = Phase::AbortRelease { li };
                        AppAction::Op(op)
                    }
                    LockStep::Released | LockStep::Acquired => {
                        // find the next engaged lock
                        let next = self
                            .locks
                            .iter()
                            .enumerate()
                            .skip(li + 1)
                            .find(|(_, l)| l.engaged())
                            .map(|(i, _)| i);
                        match next {
                            Some(i) => {
                                self.phase = Phase::AbortRelease { li: i };
                                match self.locks[i].release() {
                                    LockStep::Do(op) => AppAction::Op(op),
                                    _ => unreachable!(),
                                }
                            }
                            None => self.start_task(now),
                        }
                    }
                }
            }
        }
    }

    fn on_violation(&mut self, _env: &mut AppEnv, _t_violate_ms: Millis) -> bool {
        if self.sh.stabilize {
            // self-stabilizing mode: no abort — the continuous
            // re-coloring pass repairs any conflicting colors, so the
            // rollback notification is acknowledged and ignored
            return false;
        }
        if matches!(
            self.phase,
            Phase::Done
                | Phase::Init
                | Phase::PrepRead { .. }
                | Phase::PrepWave { .. }
                | Phase::PrepWrite { .. }
        ) {
            // prep is lock-free and Done has nothing to abort
            return false;
        }
        // abort & restart the current task (deferred updates ⇒ no server
        // rollback needed)
        self.restart_pending = true;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::peterson::MeOracle;
    use crate::metrics::throughput::MetricsHub;
    use crate::util::rng::Rng;

    fn setup(n_clients: usize) -> (ColoringShared, Rc<RefCell<Interner>>) {
        let mut rng = Rng::new(11);
        let graph = Rc::new(Graph::powerlaw_cluster(60, 3, 0.3, &mut rng));
        let interner = Interner::new();
        let registry = Rc::new(RefCell::new(crate::predicate::spec::Registry::new()));
        let sh = ColoringShared::new(
            graph,
            n_clients,
            interner.clone(),
            &registry,
            MeOracle::new(),
            MetricsHub::new(1, n_clients),
            5,
            false,
            false,
        );
        (sh, interner)
    }

    /// Apply one op to an in-memory map, producing a perfect outcome.
    fn exec(op: &AppOp, store: &mut HashMap<KeyId, Value>) -> OpOutcome {
        match op {
            AppOp::Get(k) => OpOutcome::GetOk(match store.get(k) {
                Some(v) => vec![crate::store::value::Versioned::new(
                    crate::clock::vc::VectorClock::new().incremented(0),
                    v.clone(),
                )],
                None => vec![],
            }),
            AppOp::Put(k, v) => {
                store.insert(*k, v.clone());
                OpOutcome::PutOk
            }
        }
    }

    /// Pure driver: run the app against an in-memory map (no sim), feeding
    /// perfect outcomes — single ops and batch waves alike. Exercises the
    /// whole state machine at the given pipeline width.
    fn drive_to_completion(
        app: &mut ColoringApp,
        store: &mut HashMap<KeyId, Value>,
        pipeline: usize,
    ) -> usize {
        let mut rng = Rng::new(1);
        let mut env = AppEnv { now: 0, seq: 0, client_idx: app.client, pipeline, rng: &mut rng };
        let mut last: Option<LastResult> = None;
        let mut steps = 0;
        loop {
            steps += 1;
            assert!(steps < 1_000_000, "app did not terminate");
            match app.next(&mut env, last.take()) {
                AppAction::Op(op) => {
                    let outcome = exec(&op, store);
                    last = Some(LastResult::Op(op, outcome));
                }
                AppAction::Batch(ops) => {
                    assert!(!ops.is_empty(), "batches must be non-empty");
                    let pairs: Vec<(AppOp, OpOutcome)> = ops
                        .into_iter()
                        .map(|op| {
                            let o = exec(&op, store);
                            (op, o)
                        })
                        .collect();
                    last = Some(LastResult::Batch(pairs));
                }
                AppAction::Sleep(_) => {
                    last = None;
                }
                AppAction::Done => return steps,
            }
        }
    }

    #[test]
    fn single_client_colors_whole_graph_properly() {
        let (sh, interner) = setup(1);
        let graph = sh.graph.clone();
        let mut app = ColoringApp::new(sh, 0);
        let mut store: HashMap<KeyId, Value> = HashMap::new();
        drive_to_completion(&mut app, &mut store, 1);
        // every node colored, and it is a proper coloring
        let mut colors = vec![-1i64; graph.n];
        for v in 0..graph.n as u32 {
            let key = color_key(&mut interner.borrow_mut(), v);
            colors[v as usize] = store.get(&key).and_then(|x| x.as_int()).expect("colored");
        }
        for (a, b) in graph.edges() {
            assert_ne!(colors[a as usize], colors[b as usize], "edge ({a},{b}) conflict");
        }
    }

    #[test]
    fn pipelined_client_colors_whole_graph_properly() {
        // the batch paths (prep waves, read waves, commit waves) must
        // produce the same kind of proper coloring the serial paths do
        let (sh, interner) = setup(1);
        let graph = sh.graph.clone();
        let mut app = ColoringApp::new(sh, 0);
        let mut store: HashMap<KeyId, Value> = HashMap::new();
        let steps = drive_to_completion(&mut app, &mut store, 8);
        let mut colors = vec![-1i64; graph.n];
        for v in 0..graph.n as u32 {
            let key = color_key(&mut interner.borrow_mut(), v);
            colors[v as usize] = store.get(&key).and_then(|x| x.as_int()).expect("colored");
        }
        for (a, b) in graph.edges() {
            assert_ne!(colors[a as usize], colors[b as usize], "edge ({a},{b}) conflict");
        }
        // scatter-gather needs far fewer app turns than one-op-at-a-time
        let mut serial_app = ColoringApp::new(setup(1).0, 0);
        let mut serial_store: HashMap<KeyId, Value> = HashMap::new();
        let serial_steps = drive_to_completion(&mut serial_app, &mut serial_store, 1);
        assert!(
            steps * 2 < serial_steps,
            "batched run took {steps} turns vs {serial_steps} serial"
        );
    }

    #[test]
    fn two_sequential_clients_color_properly() {
        // run client 0 to completion, then client 1 (no concurrency ⇒ the
        // result must be a proper coloring)
        let (sh, interner) = setup(2);
        let graph = sh.graph.clone();
        let mut store: HashMap<KeyId, Value> = HashMap::new();
        let mut app0 = ColoringApp::new(sh.clone(), 0);
        let mut app1 = ColoringApp::new(sh, 1);
        drive_to_completion(&mut app0, &mut store, 1);
        drive_to_completion(&mut app1, &mut store, 1);
        for (a, b) in graph.edges() {
            let ka = color_key(&mut interner.borrow_mut(), a);
            let kb = color_key(&mut interner.borrow_mut(), b);
            let ca = store.get(&ka).and_then(|x| x.as_int());
            let cb = store.get(&kb).and_then(|x| x.as_int());
            assert!(ca.is_some() && cb.is_some(), "({a},{b}) uncolored");
            assert_ne!(ca, cb, "edge ({a},{b}) conflict");
        }
    }

    #[test]
    fn hi_degree_nodes_precolored_without_locks() {
        let (sh, _) = setup(1);
        let app = ColoringApp::new(sh.clone(), 0);
        // every hi-degree node is in prep, not in tasks
        for v in &app.prep {
            assert!(sh.hi_deg[*v as usize]);
        }
        for t in &app.tasks {
            for v in t {
                assert!(!sh.hi_deg[*v as usize]);
            }
        }
    }

    #[test]
    fn locks_sorted_for_deadlock_freedom() {
        let (sh, _) = setup(4);
        let app = ColoringApp::new(sh, 0);
        for task in &app.tasks {
            for &v in task {
                let locks = app.locks_for(v);
                let edges: Vec<(u32, u32)> = locks.iter().map(|l| l.edge()).collect();
                let mut sorted = edges.clone();
                sorted.sort_unstable();
                assert_eq!(edges, sorted);
            }
        }
    }

    #[test]
    fn mex_picks_smallest_free() {
        assert_eq!(mex(&[]), 0);
        assert_eq!(mex(&[0, 1, 2]), 3);
        assert_eq!(mex(&[1, 2]), 0);
        assert_eq!(mex(&[0, 2]), 1);
    }

    #[test]
    fn violation_triggers_task_restart() {
        let (sh, _) = setup(2);
        let metrics = sh.metrics.clone();
        let mut app = ColoringApp::new(sh, 0);
        let mut store: HashMap<KeyId, Value> = HashMap::new();
        let mut rng = Rng::new(1);
        // step a few ops into the first task
        let mut env = AppEnv { now: 0, seq: 0, client_idx: 0, pipeline: 1, rng: &mut rng };
        let mut last = None;
        // step until we are inside a regular (locked) task, past the
        // lock-free prep phase where violations are ignored
        while !matches!(
            app.phase,
            Phase::Lock { .. } | Phase::ReadNbr { .. } | Phase::Release { .. } | Phase::Commit { .. }
        ) {
            match app.next(&mut env, last.take()) {
                AppAction::Op(op) => {
                    let outcome = exec(&op, &mut store);
                    last = Some(LastResult::Op(op, outcome));
                }
                AppAction::Batch(ops) => {
                    let pairs: Vec<(AppOp, OpOutcome)> = ops
                        .into_iter()
                        .map(|op| {
                            let o = exec(&op, &mut store);
                            (op, o)
                        })
                        .collect();
                    last = Some(LastResult::Batch(pairs));
                }
                AppAction::Sleep(_) => last = None,
                AppAction::Done => break,
            }
        }
        assert!(app.on_violation(&mut env, 123), "mid-task violation aborts");
        // restart path: drive to completion still works
        drive_to_completion(&mut app, &mut store, 1);
        assert!(metrics.borrow().tasks_aborted >= 1);
        assert!(app.tasks_done > 0);
    }

    #[test]
    fn stabilize_mode_ignores_violations_and_still_completes() {
        let (mut sh, _) = setup(2);
        sh.stabilize = true;
        let metrics = sh.metrics.clone();
        let mut app = ColoringApp::new(sh, 0);
        let mut store: HashMap<KeyId, Value> = HashMap::new();
        let mut rng = Rng::new(1);
        let mut env = AppEnv { now: 0, seq: 0, client_idx: 0, pipeline: 1, rng: &mut rng };
        // a violation at any phase is acknowledged but aborts nothing
        assert!(!app.on_violation(&mut env, 123), "stabilize never aborts");
        drive_to_completion(&mut app, &mut store, 1);
        assert!(!app.on_violation(&mut env, 456), "still no aborts mid-run");
        assert_eq!(metrics.borrow().tasks_aborted, 0);
        assert!(app.tasks_done > 0, "the pass completes without restarts");
    }
}
