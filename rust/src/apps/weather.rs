//! *Weather Monitoring* (§VI-A): a planar grid of stations; each client
//! owns a horizontal strip and continuously updates its nodes from their
//! neighbors' states. The GET/PUT ratio is tunable (`put_pct` — Fig. 12
//! runs 25% and 50%): a node update performs `round((1-p)/p)` neighbor
//! reads followed by one write.
//!
//! Nodes on a strip boundary are updated under Peterson edge locks for
//! their cross-client edges, so the monitors watch one mutual-exclusion
//! predicate per boundary edge (inferred from the lock variable names).
//!
//! On a pipelined client (`pipeline_depth > 1`) the per-update neighbor
//! reads go out as one scatter-gather [`AppAction::Batch`] wave instead
//! of `reads_per_update` sequential round trips; lock steps stay
//! sequential (the Peterson protocol orders them).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::apps::graph::Graph;
use crate::apps::peterson::{LockStep, MeOracleRef, PetersonLock};
use crate::client::app::{AppAction, AppEnv, AppLogic, AppOp, LastResult, OpOutcome};
use crate::clock::hvc::Millis;
use crate::store::value::{Interner, KeyId, Value};

#[derive(Clone)]
pub struct WeatherShared {
    pub graph: Rc<Graph>,
    pub owner: Rc<Vec<u32>>,
    pub interner: Rc<RefCell<Interner>>,
    pub oracle: MeOracleRef,
    /// fraction of data operations that are PUTs (0 < p ≤ 1)
    pub put_pct: f64,
    /// protect boundary updates with Peterson locks (monitored predicates)
    pub use_locks: bool,
}

impl WeatherShared {
    pub fn new(
        graph: Rc<Graph>,
        n_clients: usize,
        interner: Rc<RefCell<Interner>>,
        registry: &Rc<RefCell<crate::predicate::spec::Registry>>,
        oracle: MeOracleRef,
        put_pct: f64,
        use_locks: bool,
    ) -> Self {
        assert!(put_pct > 0.0 && put_pct <= 1.0);
        let owner: Rc<Vec<u32>> =
            Rc::new(crate::apps::graph::partition_nodes(graph.n, n_clients));
        // Pre-freeze the key/predicate layout in canonical order (state
        // keys by node, then boundary-edge lock variables + predicates),
        // so run-time interning and inference only look up and the id
        // spaces match on every engine and shard.
        {
            let mut int = interner.borrow_mut();
            for v in 0..graph.n as u32 {
                state_key(&mut int, v);
            }
            if use_locks {
                let mut reg = registry.borrow_mut();
                for a in 0..graph.n as u32 {
                    for &b in graph.neighbors(a) {
                        if b <= a || owner[b as usize] == owner[a as usize] {
                            continue;
                        }
                        let spec =
                            crate::predicate::infer::edge_predicate(a as u64, b as u64, &mut int);
                        reg.add(spec);
                    }
                }
            }
        }
        Self { graph, owner, interner, oracle, put_pct, use_locks }
    }

    /// Reads per update to hit the requested PUT percentage.
    pub fn reads_per_update(&self) -> usize {
        ((1.0 - self.put_pct) / self.put_pct).round() as usize
    }
}

pub fn state_key(interner: &mut Interner, v: u32) -> KeyId {
    interner.intern(&format!("wx_{v}"))
}

#[derive(Debug)]
enum Phase {
    Init,
    /// acquiring lock `li` for the current boundary node
    Lock { li: usize },
    /// reading neighbor sample `k` of `reads` for the current node
    Read { k: usize, acc: i64 },
    /// pipelined variant: all neighbor samples of the update in flight
    ReadWave,
    Write,
    Release { li: usize },
    AbortRelease { li: usize },
}

pub struct WeatherApp {
    sh: WeatherShared,
    client: u32,
    my_nodes: Vec<u32>,
    pos: usize,
    phase: Phase,
    locks: Vec<PetersonLock>,
    state_keys: HashMap<u32, KeyId>,
    /// scatter-gather reads (latched from `AppEnv::pipelined`)
    batch: bool,
    restart_pending: bool,
    /// stop after this many node updates (0 = run forever)
    pub max_updates: u64,
    pub updates_done: u64,
}

impl WeatherApp {
    pub fn new(sh: WeatherShared, client: u32, max_updates: u64) -> Self {
        let my_nodes: Vec<u32> = (0..sh.graph.n as u32)
            .filter(|&v| sh.owner[v as usize] == client)
            .collect();
        Self {
            sh,
            client,
            my_nodes,
            pos: 0,
            phase: Phase::Init,
            locks: Vec::new(),
            state_keys: HashMap::new(),
            batch: false,
            restart_pending: false,
            max_updates,
            updates_done: 0,
        }
    }

    fn skey(&mut self, v: u32) -> KeyId {
        let interner = &self.sh.interner;
        *self
            .state_keys
            .entry(v)
            .or_insert_with(|| state_key(&mut interner.borrow_mut(), v))
    }

    fn cur_node(&self) -> u32 {
        self.my_nodes[self.pos % self.my_nodes.len()]
    }

    fn locks_for(&self, v: u32) -> Vec<PetersonLock> {
        if !self.sh.use_locks {
            return Vec::new();
        }
        let mut edges: Vec<(u32, u32)> = self
            .sh
            .graph
            .neighbors(v)
            .iter()
            .filter(|&&u| self.sh.owner[u as usize] != self.client)
            .map(|&u| (v.min(u), v.max(u)))
            .collect();
        edges.sort_unstable();
        let mut interner = self.sh.interner.borrow_mut();
        edges
            .into_iter()
            .map(|(a, b)| PetersonLock::new(a, b, v, &mut interner))
            .collect()
    }

    fn begin_node(&mut self, env: &mut AppEnv) -> AppAction {
        if self.max_updates > 0 && self.updates_done >= self.max_updates {
            return AppAction::Done;
        }
        let v = self.cur_node();
        self.locks = self.locks_for(v);
        if self.locks.is_empty() {
            self.begin_reads(env)
        } else {
            self.phase = Phase::Lock { li: 0 };
            match self.locks[0].acquire() {
                LockStep::Do(op) => AppAction::Op(op),
                _ => unreachable!(),
            }
        }
    }

    fn begin_reads(&mut self, env: &mut AppEnv) -> AppAction {
        let reads = self.sh.reads_per_update();
        if reads == 0 {
            self.phase = Phase::Write;
            return self.issue_write(env, 0);
        }
        if self.batch {
            // scatter-gather: sample every neighbor read up front and
            // issue the whole wave at once
            let mut ops = Vec::with_capacity(reads);
            for _ in 0..reads {
                let u = self.sample_neighbor(env);
                let key = self.skey(u);
                ops.push(AppOp::Get(key));
            }
            self.phase = Phase::ReadWave;
            return AppAction::Batch(ops);
        }
        self.phase = Phase::Read { k: 0, acc: 0 };
        self.issue_read(env)
    }

    /// One random neighbor of the current node (itself when isolated).
    fn sample_neighbor(&self, env: &mut AppEnv) -> u32 {
        let v = self.cur_node();
        let nbrs = self.sh.graph.neighbors(v);
        if nbrs.is_empty() {
            v
        } else {
            nbrs[env.rng.below(nbrs.len() as u64) as usize]
        }
    }

    fn issue_read(&mut self, env: &mut AppEnv) -> AppAction {
        let u = self.sample_neighbor(env);
        let key = self.skey(u);
        AppAction::Op(AppOp::Get(key))
    }

    fn issue_write(&mut self, env: &mut AppEnv, acc: i64) -> AppAction {
        let v = self.cur_node();
        let key = self.skey(v);
        // "state" = smoothed neighbor average plus noise
        let noise = env.rng.range(0, 7) as i64 - 3;
        AppAction::Op(AppOp::Put(key, Value::Int(acc + noise)))
    }

    fn finish_node(&mut self, env: &mut AppEnv) -> AppAction {
        self.updates_done += 1;
        self.pos += 1;
        if !self.locks.is_empty() {
            // release before moving on — handled by caller via Release phase
            unreachable!("finish_node with locks pending");
        }
        self.begin_node(env)
    }

    fn handle_abort(&mut self, env: &mut AppEnv) -> AppAction {
        self.restart_pending = false;
        for l in &self.locks {
            if l.held() {
                self.sh.oracle.borrow_mut().exit(l.edge(), self.client, env.now, env.seq);
            }
        }
        let engaged: Vec<usize> = self
            .locks
            .iter()
            .enumerate()
            .filter(|(_, l)| l.engaged())
            .map(|(i, _)| i)
            .collect();
        if let Some(&first) = engaged.first() {
            self.phase = Phase::AbortRelease { li: first };
            match self.locks[first].release() {
                LockStep::Do(op) => AppAction::Op(op),
                _ => unreachable!(),
            }
        } else {
            self.begin_node(env)
        }
    }
}

impl AppLogic for WeatherApp {
    fn name(&self) -> &'static str {
        "weather_monitoring"
    }

    fn next(&mut self, env: &mut AppEnv, last: Option<LastResult>) -> AppAction {
        self.batch = env.pipelined();
        if self.restart_pending {
            return self.handle_abort(env);
        }
        if self.my_nodes.is_empty() {
            return AppAction::Done;
        }
        let (outcome, wave) = match last {
            Some(LastResult::Op(_, o)) => (Some(o), Vec::new()),
            Some(LastResult::Batch(pairs)) => (None, pairs),
            None => (None, Vec::new()),
        };
        match std::mem::replace(&mut self.phase, Phase::Init) {
            Phase::Init => self.begin_node(env),
            Phase::Lock { li } => {
                let out = outcome.expect("lock outcome");
                match self.locks[li].on_result(&out) {
                    LockStep::Do(op) => {
                        self.phase = Phase::Lock { li };
                        AppAction::Op(op)
                    }
                    LockStep::Acquired => {
                        self.sh
                            .oracle
                            .borrow_mut()
                            .enter(self.locks[li].edge(), self.client, env.now, env.seq);
                        if li + 1 < self.locks.len() {
                            self.phase = Phase::Lock { li: li + 1 };
                            match self.locks[li + 1].acquire() {
                                LockStep::Do(op) => AppAction::Op(op),
                                _ => unreachable!(),
                            }
                        } else {
                            self.begin_reads(env)
                        }
                    }
                    LockStep::Released => unreachable!(),
                }
            }
            Phase::Read { k, mut acc } => {
                if let Some(OpOutcome::GetOk(sibs)) = &outcome {
                    if let Some(x) =
                        crate::store::value::resolve(sibs).and_then(|v| v.value.as_int())
                    {
                        acc = (acc + x) / 2; // running smooth
                    }
                }
                let reads = self.sh.reads_per_update();
                if k + 1 < reads {
                    self.phase = Phase::Read { k: k + 1, acc };
                    self.issue_read(env)
                } else {
                    self.phase = Phase::Write;
                    self.issue_write(env, acc)
                }
            }
            Phase::ReadWave => {
                // gather: fold the samples in submission order, exactly as
                // the sequential path smooths them
                let mut acc = 0i64;
                for (_, o) in &wave {
                    if let OpOutcome::GetOk(sibs) = o {
                        if let Some(x) =
                            crate::store::value::resolve(sibs).and_then(|v| v.value.as_int())
                        {
                            acc = (acc + x) / 2; // running smooth
                        }
                    }
                }
                self.phase = Phase::Write;
                self.issue_write(env, acc)
            }
            Phase::Write => {
                if self.locks.is_empty() {
                    self.finish_node(env)
                } else {
                    self.phase = Phase::Release { li: 0 };
                    match self.locks[0].release() {
                        LockStep::Do(op) => AppAction::Op(op),
                        _ => unreachable!(),
                    }
                }
            }
            Phase::Release { li } => {
                let out = outcome.expect("release outcome");
                match self.locks[li].on_result(&out) {
                    LockStep::Do(op) => {
                        self.phase = Phase::Release { li };
                        AppAction::Op(op)
                    }
                    LockStep::Released => {
                        self.sh
                            .oracle
                            .borrow_mut()
                            .exit(self.locks[li].edge(), self.client, env.now, env.seq);
                        if li + 1 < self.locks.len() {
                            self.phase = Phase::Release { li: li + 1 };
                            match self.locks[li + 1].release() {
                                LockStep::Do(op) => AppAction::Op(op),
                                _ => unreachable!(),
                            }
                        } else {
                            self.updates_done += 1;
                            self.pos += 1;
                            self.locks.clear();
                            self.begin_node(env)
                        }
                    }
                    LockStep::Acquired => unreachable!(),
                }
            }
            Phase::AbortRelease { li } => {
                let out = outcome.expect("abort outcome");
                match self.locks[li].on_result(&out) {
                    LockStep::Do(op) => {
                        self.phase = Phase::AbortRelease { li };
                        AppAction::Op(op)
                    }
                    _ => {
                        let next = self
                            .locks
                            .iter()
                            .enumerate()
                            .skip(li + 1)
                            .find(|(_, l)| l.engaged())
                            .map(|(i, _)| i);
                        match next {
                            Some(i) => {
                                self.phase = Phase::AbortRelease { li: i };
                                match self.locks[i].release() {
                                    LockStep::Do(op) => AppAction::Op(op),
                                    _ => unreachable!(),
                                }
                            }
                            None => {
                                self.locks.clear();
                                self.begin_node(env)
                            }
                        }
                    }
                }
            }
        }
    }

    fn on_violation(&mut self, _env: &mut AppEnv, _t_violate_ms: Millis) -> bool {
        if matches!(
            self.phase,
            Phase::Lock { .. } | Phase::Read { .. } | Phase::ReadWave | Phase::Write
        ) {
            self.restart_pending = true;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::peterson::MeOracle;
    use crate::util::rng::Rng;

    fn setup(put_pct: f64, n_clients: usize, use_locks: bool) -> WeatherShared {
        let graph = Rc::new(Graph::grid(8, 8));
        let registry = Rc::new(RefCell::new(crate::predicate::spec::Registry::new()));
        WeatherShared::new(
            graph,
            n_clients,
            Interner::new(),
            &registry,
            MeOracle::new(),
            put_pct,
            use_locks,
        )
    }

    #[test]
    fn put_pct_to_reads() {
        assert_eq!(setup(0.5, 2, false).reads_per_update(), 1);
        assert_eq!(setup(0.25, 2, false).reads_per_update(), 3);
        assert_eq!(setup(1.0, 2, false).reads_per_update(), 0);
    }

    /// Drive the app with perfect outcomes at the given pipeline width;
    /// returns (gets, puts, largest batch seen).
    fn drive(app: &mut WeatherApp, pipeline: usize, rng_seed: u64) -> (u32, u32, usize) {
        let mut rng = Rng::new(rng_seed);
        let mut gets = 0u32;
        let mut puts = 0u32;
        let mut max_wave = 0usize;
        let mut count = |op: &AppOp| match op {
            AppOp::Get(_) => {
                gets += 1;
                OpOutcome::GetOk(vec![])
            }
            AppOp::Put(..) => {
                puts += 1;
                OpOutcome::PutOk
            }
        };
        let mut last: Option<LastResult> = None;
        loop {
            let mut env = AppEnv { now: 0, seq: 0, client_idx: 0, pipeline, rng: &mut rng };
            match app.next(&mut env, last.take()) {
                AppAction::Op(op) => {
                    let out = count(&op);
                    last = Some(LastResult::Op(op, out));
                }
                AppAction::Batch(ops) => {
                    max_wave = max_wave.max(ops.len());
                    let pairs: Vec<(AppOp, OpOutcome)> = ops
                        .into_iter()
                        .map(|op| {
                            let o = count(&op);
                            (op, o)
                        })
                        .collect();
                    last = Some(LastResult::Batch(pairs));
                }
                AppAction::Sleep(_) => last = None,
                AppAction::Done => break,
            }
        }
        (gets, puts, max_wave)
    }

    #[test]
    fn interior_updates_hit_put_ratio() {
        // single client → no boundary, no locks: ops are exactly
        // reads_per_update GETs + 1 PUT per update
        let sh = setup(0.5, 1, true);
        let mut app = WeatherApp::new(sh, 0, 50);
        let (gets, puts, max_wave) = drive(&mut app, 1, 5);
        assert_eq!(puts, 50);
        assert_eq!(gets, 50, "put_pct=0.5 ⇒ 1 read per write");
        assert_eq!(app.updates_done, 50);
        assert_eq!(max_wave, 0, "serial clients never see batches");
    }

    #[test]
    fn pipelined_updates_scatter_reads_and_keep_the_mix() {
        // put_pct = 0.25 ⇒ 3 reads per write; a pipelined client issues
        // them as one wave, with the same total op mix
        let sh = setup(0.25, 1, true);
        let mut app = WeatherApp::new(sh, 0, 40);
        let (gets, puts, max_wave) = drive(&mut app, 8, 5);
        assert_eq!(puts, 40);
        assert_eq!(gets, 120, "3 reads per write, batched or not");
        assert_eq!(max_wave, 3, "all reads of an update travel in one wave");
        assert_eq!(app.updates_done, 40);
    }

    #[test]
    fn boundary_nodes_use_locks() {
        let sh = setup(0.5, 2, true);
        let app = WeatherApp::new(sh.clone(), 0, 10);
        // the last row of client 0's strip borders client 1
        let boundary_node = app
            .my_nodes
            .iter()
            .copied()
            .find(|&v| {
                sh.graph
                    .neighbors(v)
                    .iter()
                    .any(|&u| sh.owner[u as usize] != 0)
            })
            .expect("boundary exists");
        assert!(!app.locks_for(boundary_node).is_empty());
        let interior = app
            .my_nodes
            .iter()
            .copied()
            .find(|&v| {
                sh.graph
                    .neighbors(v)
                    .iter()
                    .all(|&u| sh.owner[u as usize] == 0)
            })
            .expect("interior exists");
        assert!(app.locks_for(interior).is_empty());
    }

    #[test]
    fn lock_free_mode_has_no_locks() {
        let sh = setup(0.5, 2, false);
        let app = WeatherApp::new(sh, 0, 10);
        for &v in &app.my_nodes {
            assert!(app.locks_for(v).is_empty());
        }
    }
}
