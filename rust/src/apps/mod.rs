//! The paper's three case-study applications (§VI-A) plus the graph
//! substrate and the Peterson edge-lock protocol they share, and the
//! production-traffic [`kvmix`] read/write-mix workload app.

pub mod coloring;
pub mod conjunctive;
pub mod graph;
pub mod kvmix;
pub mod peterson;
pub mod weather;
