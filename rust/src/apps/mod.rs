//! The paper's three case-study applications (§VI-A) plus the graph
//! substrate and the Peterson edge-lock protocol they share.

pub mod coloring;
pub mod conjunctive;
pub mod graph;
pub mod peterson;
pub mod weather;
