//! The *Conjunctive* application (§VI-A): distributed-debugging stress
//! test. The monitors detect `¬P ≡ P_1 ∧ P_2 ∧ … ∧ P_m` where local
//! predicate `P_i` (variable `x_k_i = 1`, owned by client `i`) becomes
//! true with probability β (the paper uses β = 1%, from MapReduce time
//! breakdowns). Because the violation rate is controllable, this workload
//! measures detection latency with statistical weight (Table III) and
//! stresses the monitors.
//!
//! `put_pct` mixes in extra GETs exactly like Weather Monitoring.
//!
//! Every op of a cycle (the flip PUT and its extra GETs) touches an
//! independent variable, so on a pipelined client (`pipeline_depth > 1`)
//! the whole cycle goes out as one [`AppAction::Batch`] wave.

use std::cell::RefCell;
use std::rc::Rc;

use crate::client::app::{AppAction, AppEnv, AppLogic, AppOp, LastResult};
use crate::predicate::spec::{Clause, Conjunct, Literal, PredId, PredKind, PredicateSpec, Registry};
use crate::store::value::{Interner, KeyId, Value};

#[derive(Clone)]
pub struct ConjunctiveShared {
    pub interner: Rc<RefCell<Interner>>,
    /// number of conjunctive predicates monitored simultaneously
    pub n_preds: usize,
    /// conjuncts per predicate (the paper's P_1 ∧ … ∧ P_10 ⇒ 10)
    pub n_conjuncts: usize,
    pub beta: f64,
    pub put_pct: f64,
    /// key ids: vars[k][i] = x_k_i
    pub vars: Rc<Vec<Vec<KeyId>>>,
    pub pred_ids: Rc<Vec<PredId>>,
}

impl ConjunctiveShared {
    /// Build the predicates `conj_k : x_k_0 = 1 ∧ … ∧ x_k_{m-1} = 1` and
    /// register them (monitors and local detectors share the registry).
    pub fn setup(
        registry: &Rc<RefCell<Registry>>,
        interner: Rc<RefCell<Interner>>,
        n_preds: usize,
        n_conjuncts: usize,
        beta: f64,
        put_pct: f64,
    ) -> Self {
        assert!(n_preds >= 1 && n_conjuncts >= 1);
        let mut vars = Vec::with_capacity(n_preds);
        let mut pred_ids = Vec::with_capacity(n_preds);
        for k in 0..n_preds {
            let kvars: Vec<KeyId> = (0..n_conjuncts)
                .map(|i| interner.borrow_mut().intern(&format!("x_{k}_{i}")))
                .collect();
            let clause = Clause {
                conjuncts: kvars
                    .iter()
                    .map(|&v| Conjunct {
                        literals: vec![Literal { var: v, value: Value::Int(1) }],
                    })
                    .collect(),
            };
            let spec = PredicateSpec {
                id: PredId(u32::MAX),
                name: format!("conj_{k}"),
                kind: PredKind::Linear,
                clauses: vec![clause],
            };
            pred_ids.push(registry.borrow_mut().add(spec));
            vars.push(kvars);
        }
        Self {
            interner,
            n_preds,
            n_conjuncts,
            beta,
            put_pct,
            vars: Rc::new(vars),
            pred_ids: Rc::new(pred_ids),
        }
    }

    pub fn extra_gets(&self) -> usize {
        ((1.0 - self.put_pct) / self.put_pct).round() as usize
    }
}

#[derive(Debug)]
enum Phase {
    Flip,
    Extra { j: usize },
}

pub struct ConjunctiveApp {
    sh: ConjunctiveShared,
    client: u32,
    /// round-robin predicate cursor
    k: usize,
    phase: Phase,
    /// stop after this many flips (0 = forever)
    pub max_flips: u64,
    pub flips: u64,
    pub trues_set: u64,
}

impl ConjunctiveApp {
    pub fn new(sh: ConjunctiveShared, client: u32, max_flips: u64) -> Self {
        Self { sh, client, k: 0, phase: Phase::Flip, max_flips, flips: 0, trues_set: 0 }
    }

    /// The conjunct variable this client drives for predicate `k`.
    fn my_var(&self, k: usize) -> KeyId {
        let i = self.client as usize % self.sh.n_conjuncts;
        self.sh.vars[k][i]
    }

    /// The flip PUT of the next cycle (None once `max_flips` is reached).
    fn flip_op(&mut self, env: &mut AppEnv) -> Option<AppOp> {
        if self.max_flips > 0 && self.flips >= self.max_flips {
            return None;
        }
        let truth = env.rng.chance(self.sh.beta);
        if truth {
            self.trues_set += 1;
        }
        self.flips += 1;
        let var = self.my_var(self.k);
        self.k = (self.k + 1) % self.sh.n_preds;
        Some(AppOp::Put(var, Value::Int(truth as i64)))
    }

    fn extra_get_op(&mut self, env: &mut AppEnv) -> AppOp {
        let k = env.rng.below(self.sh.n_preds as u64) as usize;
        let i = env.rng.below(self.sh.n_conjuncts as u64) as usize;
        AppOp::Get(self.sh.vars[k][i])
    }

    fn issue_flip(&mut self, env: &mut AppEnv) -> AppAction {
        match self.flip_op(env) {
            Some(op) => AppAction::Op(op),
            None => AppAction::Done,
        }
    }

    fn issue_extra_get(&mut self, env: &mut AppEnv) -> AppAction {
        let op = self.extra_get_op(env);
        AppAction::Op(op)
    }
}

impl AppLogic for ConjunctiveApp {
    fn name(&self) -> &'static str {
        "conjunctive"
    }

    fn next(&mut self, env: &mut AppEnv, _last: Option<LastResult>) -> AppAction {
        if env.pipelined() {
            // the flip and its extra GETs touch independent variables:
            // overlap the whole cycle as one wave
            let Some(flip) = self.flip_op(env) else { return AppAction::Done };
            let extras = self.sh.extra_gets();
            if extras == 0 {
                return AppAction::Op(flip);
            }
            let mut ops = Vec::with_capacity(1 + extras);
            ops.push(flip);
            for _ in 0..extras {
                let get = self.extra_get_op(env);
                ops.push(get);
            }
            self.phase = Phase::Flip;
            return AppAction::Batch(ops);
        }
        match self.phase {
            Phase::Flip => {
                let extras = self.sh.extra_gets();
                self.phase = if extras > 0 { Phase::Extra { j: 0 } } else { Phase::Flip };
                self.issue_flip(env)
            }
            Phase::Extra { j } => {
                let extras = self.sh.extra_gets();
                self.phase = if j + 1 < extras { Phase::Extra { j: j + 1 } } else { Phase::Flip };
                self.issue_extra_get(env)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::app::OpOutcome;
    use crate::util::rng::Rng;

    fn setup(n_preds: usize, m: usize, beta: f64, put_pct: f64) -> (ConjunctiveShared, Rc<RefCell<Registry>>) {
        let registry = Rc::new(RefCell::new(Registry::new()));
        let sh = ConjunctiveShared::setup(
            &registry,
            Interner::new(),
            n_preds,
            m,
            beta,
            put_pct,
        );
        (sh, registry)
    }

    #[test]
    fn predicates_registered_with_m_conjuncts() {
        let (sh, registry) = setup(4, 10, 0.01, 0.5);
        assert_eq!(registry.borrow().len(), 4);
        for &id in sh.pred_ids.iter() {
            let reg = registry.borrow();
            let spec = reg.get(id);
            assert_eq!(spec.kind, PredKind::Linear);
            assert_eq!(spec.clauses[0].conjuncts.len(), 10);
        }
        // variable indexing: flipping x_0_0 affects only conj_0 conjunct 0
        let reg = registry.borrow();
        let hits = reg.affected(sh.vars[0][0]).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0, sh.pred_ids[0]);
        assert_eq!(hits[0].2, 0);
    }

    #[test]
    fn op_mix_matches_put_pct() {
        let (sh, _) = setup(3, 4, 0.5, 0.25);
        let mut app = ConjunctiveApp::new(sh, 1, 40);
        let mut rng = Rng::new(3);
        let (mut gets, mut puts) = (0, 0);
        let mut last = None;
        loop {
            let mut env = AppEnv { now: 0, seq: 0, client_idx: 1, pipeline: 1, rng: &mut rng };
            match app.next(&mut env, last.take()) {
                AppAction::Op(op) => {
                    match &op {
                        AppOp::Get(_) => gets += 1,
                        AppOp::Put(..) => puts += 1,
                    }
                    last = Some(LastResult::Op(op, OpOutcome::PutOk));
                }
                AppAction::Sleep(_) => last = None,
                AppAction::Done => break,
            }
        }
        assert_eq!(puts, 40);
        assert_eq!(gets, 120, "put_pct=0.25 ⇒ 3 extra GETs per flip");
    }

    #[test]
    fn beta_controls_true_rate() {
        let (sh, _) = setup(2, 4, 0.2, 1.0);
        let mut app = ConjunctiveApp::new(sh, 0, 5_000);
        let mut rng = Rng::new(9);
        let mut last = None;
        loop {
            let mut env = AppEnv { now: 0, seq: 0, client_idx: 0, pipeline: 1, rng: &mut rng };
            match app.next(&mut env, last.take()) {
                AppAction::Op(op) => last = Some(LastResult::Op(op, OpOutcome::PutOk)),
                AppAction::Sleep(_) => last = None,
                AppAction::Done => break,
            }
        }
        let rate = app.trues_set as f64 / app.flips as f64;
        assert!((rate - 0.2).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn pipelined_cycles_batch_the_flip_with_its_extras() {
        // put_pct = 0.25 ⇒ 3 extra GETs: a pipelined client ships the
        // whole cycle as one 4-op wave, preserving the op mix
        let (sh, _) = setup(3, 4, 0.5, 0.25);
        let mut app = ConjunctiveApp::new(sh, 1, 40);
        let mut rng = Rng::new(3);
        let (mut gets, mut puts, mut waves) = (0, 0, 0);
        let mut last = None;
        loop {
            let mut env = AppEnv { now: 0, seq: 0, client_idx: 1, pipeline: 4, rng: &mut rng };
            match app.next(&mut env, last.take()) {
                AppAction::Batch(ops) => {
                    waves += 1;
                    assert_eq!(ops.len(), 4, "flip + 3 extras per wave");
                    assert!(matches!(ops[0], AppOp::Put(..)), "the flip leads the wave");
                    let pairs: Vec<(AppOp, OpOutcome)> = ops
                        .into_iter()
                        .map(|op| {
                            match &op {
                                AppOp::Get(_) => gets += 1,
                                AppOp::Put(..) => puts += 1,
                            }
                            (op, OpOutcome::PutOk)
                        })
                        .collect();
                    last = Some(LastResult::Batch(pairs));
                }
                AppAction::Op(op) => {
                    match &op {
                        AppOp::Get(_) => gets += 1,
                        AppOp::Put(..) => puts += 1,
                    }
                    last = Some(LastResult::Op(op, OpOutcome::PutOk));
                }
                AppAction::Sleep(_) => last = None,
                AppAction::Done => break,
            }
        }
        assert_eq!(puts, 40);
        assert_eq!(gets, 120);
        assert_eq!(waves, 40, "every cycle travels as one wave");
    }

    #[test]
    fn clients_round_robin_preds() {
        let (sh, _) = setup(3, 4, 1.0, 1.0);
        let mut app = ConjunctiveApp::new(sh.clone(), 2, 6);
        let mut rng = Rng::new(1);
        let mut keys = Vec::new();
        let mut last = None;
        loop {
            let mut env = AppEnv { now: 0, seq: 0, client_idx: 2, pipeline: 1, rng: &mut rng };
            match app.next(&mut env, last.take()) {
                AppAction::Op(op) => {
                    keys.push(op.key());
                    last = Some(LastResult::Op(op, OpOutcome::PutOk));
                }
                AppAction::Sleep(_) => last = None,
                AppAction::Done => break,
            }
        }
        // client 2 drives conjunct 2 of each predicate, cycling k=0,1,2
        assert_eq!(keys.len(), 6);
        assert_eq!(keys[0], sh.vars[0][2]);
        assert_eq!(keys[1], sh.vars[1][2]);
        assert_eq!(keys[2], sh.vars[2][2]);
        assert_eq!(keys[3], sh.vars[0][2]);
    }
}
