//! Peterson's 2-process mutual exclusion over the key-value store, one
//! lock per graph edge (§I, §VI-A). Correct under sequential consistency
//! [Brzezinski & Wawrzyniak]; under eventual consistency it can be
//! violated — which is exactly what the monitors watch for via the
//! inferred `me_a_b` predicates.
//!
//! The lock is a sub-state-machine the graph apps drive through the
//! one-op-at-a-time `AppLogic` interface:
//!
//! ```text
//! acquire:  PUT flag_me=true → PUT turn=peer →
//!           spin { GET flag_peer; GET turn;
//!                  enter CS iff ¬flag_peer ∨ turn == me }
//! release:  PUT flag_me=false
//! ```
//!
//! A shared [`MeOracle`] records *actual* critical-section occupancy in
//! virtual time — the ground truth against which detected violations are
//! compared (the monitors see replica-level inconsistency; the oracle sees
//! true mutual-exclusion breaches).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::client::app::{AppOp, OpOutcome};
use crate::predicate::infer;
use crate::sim::Time;
use crate::store::value::{resolve, Interner, KeyId, Value};

/// What the embedding app should do next with the lock.
#[derive(Debug, Clone)]
pub enum LockStep {
    /// issue this store op and feed the outcome back via `on_result`
    Do(AppOp),
    /// the critical section is ours
    Acquired,
    /// the release completed
    Released,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum S {
    Idle,
    PuttingFlag,
    PuttingTurn,
    GettingPeerFlag,
    GettingTurn { peer_flag: bool },
    Held,
    Releasing,
}

#[derive(Debug, Clone)]
pub struct PetersonLock {
    /// edge (a < b)
    pub a: u32,
    pub b: u32,
    /// which endpoint we are
    pub me: u32,
    pub peer: u32,
    flag_me: KeyId,
    flag_peer: KeyId,
    turn: KeyId,
    state: S,
    /// completed GET-pair spins while waiting
    pub spins: u32,
}

impl PetersonLock {
    pub fn new(a: u32, b: u32, me: u32, interner: &mut Interner) -> Self {
        assert!(a < b && (me == a || me == b));
        let peer = if me == a { b } else { a };
        Self {
            a,
            b,
            me,
            peer,
            flag_me: interner.intern(&infer::flag_name(a as u64, b as u64, me as u64)),
            flag_peer: interner.intern(&infer::flag_name(a as u64, b as u64, peer as u64)),
            turn: interner.intern(&infer::turn_name(a as u64, b as u64)),
            state: S::Idle,
            spins: 0,
        }
    }

    pub fn edge(&self) -> (u32, u32) {
        (self.a, self.b)
    }

    pub fn held(&self) -> bool {
        self.state == S::Held
    }

    /// Has the acquire protocol started (our flag may be set in the store)?
    pub fn engaged(&self) -> bool {
        !matches!(self.state, S::Idle)
    }

    /// Begin acquisition.
    pub fn acquire(&mut self) -> LockStep {
        assert_eq!(self.state, S::Idle, "acquire from Idle only");
        self.state = S::PuttingFlag;
        self.spins = 0;
        LockStep::Do(AppOp::Put(self.flag_me, Value::Bool(true)))
    }

    /// Begin release (valid when held or mid-acquire after an abort).
    pub fn release(&mut self) -> LockStep {
        self.state = S::Releasing;
        LockStep::Do(AppOp::Put(self.flag_me, Value::Bool(false)))
    }

    /// Feed back the outcome of the op we last asked for.
    pub fn on_result(&mut self, outcome: &OpOutcome) -> LockStep {
        if matches!(outcome, OpOutcome::Failed) {
            // quorum miss: retry the same protocol step
            return LockStep::Do(self.current_op());
        }
        match self.state {
            S::PuttingFlag => {
                self.state = S::PuttingTurn;
                LockStep::Do(AppOp::Put(self.turn, Value::Int(self.peer as i64)))
            }
            S::PuttingTurn => {
                self.state = S::GettingPeerFlag;
                LockStep::Do(AppOp::Get(self.flag_peer))
            }
            S::GettingPeerFlag => {
                let peer_flag = match outcome {
                    OpOutcome::GetOk(sibs) => resolve(sibs)
                        .and_then(|v| v.value.as_bool())
                        .unwrap_or(false),
                    _ => false,
                };
                self.state = S::GettingTurn { peer_flag };
                LockStep::Do(AppOp::Get(self.turn))
            }
            S::GettingTurn { peer_flag } => {
                let turn = match outcome {
                    OpOutcome::GetOk(sibs) => resolve(sibs).and_then(|v| v.value.as_int()),
                    _ => None,
                };
                // enter iff ¬flag_peer ∨ turn == me
                if !peer_flag || turn == Some(self.me as i64) {
                    self.state = S::Held;
                    LockStep::Acquired
                } else {
                    self.spins += 1;
                    self.state = S::GettingPeerFlag;
                    LockStep::Do(AppOp::Get(self.flag_peer))
                }
            }
            S::Releasing => {
                self.state = S::Idle;
                LockStep::Released
            }
            S::Idle | S::Held => unreachable!("no op outstanding in {:?}", self.state),
        }
    }

    fn current_op(&self) -> AppOp {
        match self.state {
            S::PuttingFlag => AppOp::Put(self.flag_me, Value::Bool(true)),
            S::PuttingTurn => AppOp::Put(self.turn, Value::Int(self.peer as i64)),
            S::GettingPeerFlag => AppOp::Get(self.flag_peer),
            S::GettingTurn { .. } => AppOp::Get(self.turn),
            S::Releasing => AppOp::Put(self.flag_me, Value::Bool(false)),
            S::Idle | S::Held => unreachable!(),
        }
    }
}

/// Ground-truth critical-section occupancy per edge.
#[derive(Debug, Clone)]
pub struct ActualViolation {
    pub edge: (u32, u32),
    pub clients: (u32, u32),
    pub at: Time,
}

#[derive(Debug, Default)]
pub struct MeOracle {
    /// edge → (client, since) currently inside the CS
    inside: HashMap<(u32, u32), Vec<(u32, Time)>>,
    pub actual_violations: Vec<ActualViolation>,
    pub entries: u64,
}

pub type MeOracleRef = Rc<RefCell<MeOracle>>;

impl MeOracle {
    pub fn new() -> MeOracleRef {
        Rc::new(RefCell::new(Self::default()))
    }

    pub fn enter(&mut self, edge: (u32, u32), client: u32, now: Time) {
        let occ = self.inside.entry(edge).or_default();
        if let Some(&(other, _)) = occ.iter().find(|(c, _)| *c != client) {
            self.actual_violations.push(ActualViolation { edge, clients: (other, client), at: now });
        }
        occ.push((client, now));
        self.entries += 1;
    }

    pub fn exit(&mut self, edge: (u32, u32), client: u32) {
        if let Some(occ) = self.inside.get_mut(&edge) {
            if let Some(pos) = occ.iter().position(|(c, _)| *c == client) {
                occ.remove(pos);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drive(lock: &mut PetersonLock, outcomes: &mut dyn FnMut(&AppOp) -> OpOutcome) -> u32 {
        let mut step = lock.acquire();
        let mut ops = 0;
        loop {
            match step {
                LockStep::Do(op) => {
                    ops += 1;
                    assert!(ops < 100, "livelock in test driver");
                    let out = outcomes(&op);
                    step = lock.on_result(&out);
                }
                LockStep::Acquired => return ops,
                LockStep::Released => panic!("unexpected release"),
            }
        }
    }

    fn get_ok(v: Value) -> OpOutcome {
        OpOutcome::GetOk(vec![crate::store::value::Versioned::new(
            crate::clock::vc::VectorClock::new().incremented(1),
            v,
        )])
    }

    #[test]
    fn acquires_when_peer_absent() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(1, 2, 1, &mut interner.borrow_mut());
        let ops = drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => OpOutcome::PutOk,
            AppOp::Get(_) => OpOutcome::GetOk(vec![]), // nothing stored
        });
        // flag, turn, get flag, get turn
        assert_eq!(ops, 4);
        assert!(lock.held());
    }

    #[test]
    fn spins_while_peer_holds_turn() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(1, 2, 1, &mut interner.borrow_mut());
        let mut reads = 0;
        let flag_peer = lock.flag_peer;
        let turn = lock.turn;
        let ops = drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => OpOutcome::PutOk,
            AppOp::Get(k) if *k == flag_peer => get_ok(Value::Bool(true)),
            AppOp::Get(k) if *k == turn => {
                reads += 1;
                if reads < 3 {
                    get_ok(Value::Int(2)) // turn == peer → wait
                } else {
                    get_ok(Value::Int(1)) // peer yields
                }
            }
            _ => unreachable!(),
        });
        assert!(lock.held());
        assert_eq!(lock.spins, 2);
        assert!(ops > 4);
    }

    #[test]
    fn enters_on_peer_flag_false_even_if_turn_peer() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(3, 9, 9, &mut interner.borrow_mut());
        let flag_peer = lock.flag_peer;
        drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => OpOutcome::PutOk,
            AppOp::Get(k) if *k == flag_peer => get_ok(Value::Bool(false)),
            AppOp::Get(_) => get_ok(Value::Int(3)), // turn == peer, ignored
        });
        assert!(lock.held());
    }

    #[test]
    fn release_cycle() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(1, 2, 2, &mut interner.borrow_mut());
        drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => OpOutcome::PutOk,
            AppOp::Get(_) => OpOutcome::GetOk(vec![]),
        });
        let LockStep::Do(op) = lock.release() else { panic!() };
        assert!(matches!(op, AppOp::Put(_, Value::Bool(false))));
        assert!(matches!(lock.on_result(&OpOutcome::PutOk), LockStep::Released));
        assert!(!lock.engaged());
        // reusable
        assert!(matches!(lock.acquire(), LockStep::Do(_)));
    }

    #[test]
    fn failed_ops_are_retried() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(1, 2, 1, &mut interner.borrow_mut());
        let mut failed_once = false;
        let ops = drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => {
                if !failed_once {
                    failed_once = true;
                    OpOutcome::Failed
                } else {
                    OpOutcome::PutOk
                }
            }
            AppOp::Get(_) => OpOutcome::GetOk(vec![]),
        });
        assert_eq!(ops, 5, "one retry added");
        assert!(lock.held());
    }

    #[test]
    fn oracle_detects_overlap() {
        let oracle = MeOracle::new();
        {
            let mut o = oracle.borrow_mut();
            o.enter((1, 2), 10, 100);
            o.enter((1, 2), 11, 150); // overlap!
            o.exit((1, 2), 10);
            o.exit((1, 2), 11);
            o.enter((1, 2), 10, 300); // clean re-entry
            o.exit((1, 2), 10);
            // same client re-entering is not a violation
            o.enter((3, 4), 10, 100);
            o.enter((3, 4), 10, 110);
        }
        let o = oracle.borrow();
        assert_eq!(o.actual_violations.len(), 1);
        assert_eq!(o.actual_violations[0].clients, (10, 11));
        assert_eq!(o.actual_violations[0].at, 150);
    }
}
