//! Peterson's 2-process mutual exclusion over the key-value store, one
//! lock per graph edge (§I, §VI-A). Correct under sequential consistency
//! [Brzezinski & Wawrzyniak]; under eventual consistency it can be
//! violated — which is exactly what the monitors watch for via the
//! inferred `me_a_b` predicates.
//!
//! The lock is a sub-state-machine the graph apps drive through the
//! one-op-at-a-time `AppLogic` interface:
//!
//! ```text
//! acquire:  PUT flag_me=true → PUT turn=peer →
//!           spin { GET flag_peer; GET turn;
//!                  enter CS iff ¬flag_peer ∨ turn == me }
//! release:  PUT flag_me=false
//! ```
//!
//! A shared [`MeOracle`] records *actual* critical-section occupancy in
//! virtual time — the ground truth against which detected violations are
//! compared (the monitors see replica-level inconsistency; the oracle sees
//! true mutual-exclusion breaches).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::client::app::{AppOp, OpOutcome};
use crate::predicate::infer;
use crate::sim::Time;
use crate::store::value::{resolve, Interner, KeyId, Value};

/// What the embedding app should do next with the lock.
#[derive(Debug, Clone)]
pub enum LockStep {
    /// issue this store op and feed the outcome back via `on_result`
    Do(AppOp),
    /// the critical section is ours
    Acquired,
    /// the release completed
    Released,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum S {
    Idle,
    PuttingFlag,
    PuttingTurn,
    GettingPeerFlag,
    GettingTurn { peer_flag: bool },
    Held,
    Releasing,
}

#[derive(Debug, Clone)]
pub struct PetersonLock {
    /// edge (a < b)
    pub a: u32,
    pub b: u32,
    /// which endpoint we are
    pub me: u32,
    pub peer: u32,
    flag_me: KeyId,
    flag_peer: KeyId,
    turn: KeyId,
    state: S,
    /// completed GET-pair spins while waiting
    pub spins: u32,
}

impl PetersonLock {
    pub fn new(a: u32, b: u32, me: u32, interner: &mut Interner) -> Self {
        assert!(a < b && (me == a || me == b));
        let peer = if me == a { b } else { a };
        Self {
            a,
            b,
            me,
            peer,
            flag_me: interner.intern(&infer::flag_name(a as u64, b as u64, me as u64)),
            flag_peer: interner.intern(&infer::flag_name(a as u64, b as u64, peer as u64)),
            turn: interner.intern(&infer::turn_name(a as u64, b as u64)),
            state: S::Idle,
            spins: 0,
        }
    }

    pub fn edge(&self) -> (u32, u32) {
        (self.a, self.b)
    }

    pub fn held(&self) -> bool {
        self.state == S::Held
    }

    /// Has the acquire protocol started (our flag may be set in the store)?
    pub fn engaged(&self) -> bool {
        !matches!(self.state, S::Idle)
    }

    /// Begin acquisition.
    pub fn acquire(&mut self) -> LockStep {
        assert_eq!(self.state, S::Idle, "acquire from Idle only");
        self.state = S::PuttingFlag;
        self.spins = 0;
        LockStep::Do(AppOp::Put(self.flag_me, Value::Bool(true)))
    }

    /// Begin release (valid when held or mid-acquire after an abort).
    pub fn release(&mut self) -> LockStep {
        self.state = S::Releasing;
        LockStep::Do(AppOp::Put(self.flag_me, Value::Bool(false)))
    }

    /// Feed back the outcome of the op we last asked for.
    pub fn on_result(&mut self, outcome: &OpOutcome) -> LockStep {
        if matches!(outcome, OpOutcome::Failed) {
            // quorum miss: retry the same protocol step
            return LockStep::Do(self.current_op());
        }
        match self.state {
            S::PuttingFlag => {
                self.state = S::PuttingTurn;
                LockStep::Do(AppOp::Put(self.turn, Value::Int(self.peer as i64)))
            }
            S::PuttingTurn => {
                self.state = S::GettingPeerFlag;
                LockStep::Do(AppOp::Get(self.flag_peer))
            }
            S::GettingPeerFlag => {
                let peer_flag = match outcome {
                    OpOutcome::GetOk(sibs) => resolve(sibs)
                        .and_then(|v| v.value.as_bool())
                        .unwrap_or(false),
                    _ => false,
                };
                self.state = S::GettingTurn { peer_flag };
                LockStep::Do(AppOp::Get(self.turn))
            }
            S::GettingTurn { peer_flag } => {
                let turn = match outcome {
                    OpOutcome::GetOk(sibs) => resolve(sibs).and_then(|v| v.value.as_int()),
                    _ => None,
                };
                // enter iff ¬flag_peer ∨ turn == me
                if !peer_flag || turn == Some(self.me as i64) {
                    self.state = S::Held;
                    LockStep::Acquired
                } else {
                    self.spins += 1;
                    self.state = S::GettingPeerFlag;
                    LockStep::Do(AppOp::Get(self.flag_peer))
                }
            }
            S::Releasing => {
                self.state = S::Idle;
                LockStep::Released
            }
            S::Idle | S::Held => unreachable!("no op outstanding in {:?}", self.state),
        }
    }

    fn current_op(&self) -> AppOp {
        match self.state {
            S::PuttingFlag => AppOp::Put(self.flag_me, Value::Bool(true)),
            S::PuttingTurn => AppOp::Put(self.turn, Value::Int(self.peer as i64)),
            S::GettingPeerFlag => AppOp::Get(self.flag_peer),
            S::GettingTurn { .. } => AppOp::Get(self.turn),
            S::Releasing => AppOp::Put(self.flag_me, Value::Bool(false)),
            S::Idle | S::Held => unreachable!(),
        }
    }
}

/// Ground-truth critical-section occupancy per edge.
#[derive(Debug, Clone)]
pub struct ActualViolation {
    pub edge: (u32, u32),
    pub clients: (u32, u32),
    pub at: Time,
}

/// One critical-section boundary crossing, keyed by the `(at, seq)`
/// dispatch key of the app step that crossed it
/// ([`crate::sim::des::Ctx::event_seq`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LockEv {
    pub key: (Time, u64),
    pub edge: (u32, u32),
    pub client: u32,
    pub enter: bool,
}

/// The oracle is an **append-only log** of enter/exit events replayed on
/// demand ([`MeOracle::violations`]). Logging instead of tracking live
/// occupancy is what makes the threaded engine sound: two clients of one
/// edge can live on different shards, so no single shard sees the whole
/// occupancy — but per-shard logs concatenate and stable-sort by the
/// engine-invariant dispatch key ([`MeOracle::merge`]) into exactly the
/// event order a serial run would have observed.
#[derive(Debug, Default, Clone)]
pub struct MeOracle {
    log: Vec<LockEv>,
    pub entries: u64,
}

pub type MeOracleRef = Rc<RefCell<MeOracle>>;

impl MeOracle {
    pub fn new() -> MeOracleRef {
        Rc::new(RefCell::new(Self::default()))
    }

    pub fn enter(&mut self, edge: (u32, u32), client: u32, now: Time, seq: u64) {
        self.log.push(LockEv { key: (now, seq), edge, client, enter: true });
        self.entries += 1;
    }

    pub fn exit(&mut self, edge: (u32, u32), client: u32, now: Time, seq: u64) {
        self.log.push(LockEv { key: (now, seq), edge, client, enter: false });
    }

    /// Fold another shard's log into this one, restoring global dispatch
    /// order. The sort must be stable: several exits can share one
    /// dispatch key (an abort releases every held lock in one step) and
    /// same-key events always come from a single shard, whose log
    /// already holds them in execution order.
    pub fn merge(&mut self, other: &MeOracle) {
        self.log.extend_from_slice(&other.log);
        self.entries += other.entries;
        self.log.sort_by_key(|e| e.key);
    }

    /// Replay the log: every enter that finds a *different* client
    /// already inside the edge's critical section is an actual
    /// mutual-exclusion breach.
    pub fn violations(&self) -> Vec<ActualViolation> {
        let mut inside: HashMap<(u32, u32), Vec<u32>> = HashMap::new();
        let mut out = Vec::new();
        for ev in &self.log {
            let occ = inside.entry(ev.edge).or_default();
            if ev.enter {
                if let Some(&other) = occ.iter().find(|&&c| c != ev.client) {
                    out.push(ActualViolation {
                        edge: ev.edge,
                        clients: (other, ev.client),
                        at: ev.key.0,
                    });
                }
                occ.push(ev.client);
            } else if let Some(pos) = occ.iter().position(|&c| c == ev.client) {
                occ.remove(pos);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drive(lock: &mut PetersonLock, outcomes: &mut dyn FnMut(&AppOp) -> OpOutcome) -> u32 {
        let mut step = lock.acquire();
        let mut ops = 0;
        loop {
            match step {
                LockStep::Do(op) => {
                    ops += 1;
                    assert!(ops < 100, "livelock in test driver");
                    let out = outcomes(&op);
                    step = lock.on_result(&out);
                }
                LockStep::Acquired => return ops,
                LockStep::Released => panic!("unexpected release"),
            }
        }
    }

    fn get_ok(v: Value) -> OpOutcome {
        OpOutcome::GetOk(vec![crate::store::value::Versioned::new(
            crate::clock::vc::VectorClock::new().incremented(1),
            v,
        )])
    }

    #[test]
    fn acquires_when_peer_absent() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(1, 2, 1, &mut interner.borrow_mut());
        let ops = drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => OpOutcome::PutOk,
            AppOp::Get(_) => OpOutcome::GetOk(vec![]), // nothing stored
        });
        // flag, turn, get flag, get turn
        assert_eq!(ops, 4);
        assert!(lock.held());
    }

    #[test]
    fn spins_while_peer_holds_turn() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(1, 2, 1, &mut interner.borrow_mut());
        let mut reads = 0;
        let flag_peer = lock.flag_peer;
        let turn = lock.turn;
        let ops = drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => OpOutcome::PutOk,
            AppOp::Get(k) if *k == flag_peer => get_ok(Value::Bool(true)),
            AppOp::Get(k) if *k == turn => {
                reads += 1;
                if reads < 3 {
                    get_ok(Value::Int(2)) // turn == peer → wait
                } else {
                    get_ok(Value::Int(1)) // peer yields
                }
            }
            _ => unreachable!(),
        });
        assert!(lock.held());
        assert_eq!(lock.spins, 2);
        assert!(ops > 4);
    }

    #[test]
    fn enters_on_peer_flag_false_even_if_turn_peer() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(3, 9, 9, &mut interner.borrow_mut());
        let flag_peer = lock.flag_peer;
        drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => OpOutcome::PutOk,
            AppOp::Get(k) if *k == flag_peer => get_ok(Value::Bool(false)),
            AppOp::Get(_) => get_ok(Value::Int(3)), // turn == peer, ignored
        });
        assert!(lock.held());
    }

    #[test]
    fn release_cycle() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(1, 2, 2, &mut interner.borrow_mut());
        drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => OpOutcome::PutOk,
            AppOp::Get(_) => OpOutcome::GetOk(vec![]),
        });
        let LockStep::Do(op) = lock.release() else { panic!() };
        assert!(matches!(op, AppOp::Put(_, Value::Bool(false))));
        assert!(matches!(lock.on_result(&OpOutcome::PutOk), LockStep::Released));
        assert!(!lock.engaged());
        // reusable
        assert!(matches!(lock.acquire(), LockStep::Do(_)));
    }

    #[test]
    fn failed_ops_are_retried() {
        let interner = Interner::new();
        let mut lock = PetersonLock::new(1, 2, 1, &mut interner.borrow_mut());
        let mut failed_once = false;
        let ops = drive(&mut lock, &mut |op| match op {
            AppOp::Put(..) => {
                if !failed_once {
                    failed_once = true;
                    OpOutcome::Failed
                } else {
                    OpOutcome::PutOk
                }
            }
            AppOp::Get(_) => OpOutcome::GetOk(vec![]),
        });
        assert_eq!(ops, 5, "one retry added");
        assert!(lock.held());
    }

    #[test]
    fn oracle_detects_overlap() {
        let oracle = MeOracle::new();
        {
            let mut o = oracle.borrow_mut();
            o.enter((1, 2), 10, 100, 1);
            o.enter((1, 2), 11, 150, 2); // overlap!
            o.exit((1, 2), 10, 160, 3);
            o.exit((1, 2), 11, 170, 4);
            o.enter((1, 2), 10, 300, 5); // clean re-entry
            o.exit((1, 2), 10, 310, 6);
            // same client re-entering is not a violation
            o.enter((3, 4), 10, 100, 7);
            o.enter((3, 4), 10, 110, 8);
        }
        let o = oracle.borrow();
        let v = o.violations();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].clients, (10, 11));
        assert_eq!(v[0].at, 150);
        assert_eq!(o.entries, 5);
    }

    #[test]
    fn oracle_merge_restores_global_order() {
        // Two shards each saw half of an overlapping pair; neither log
        // alone contains a violation the replay could miss, but the
        // merged log must expose the overlap in dispatch order.
        let mut a = MeOracle::default();
        a.enter((1, 2), 10, 100, 1);
        a.exit((1, 2), 10, 200, 9);
        let mut b = MeOracle::default();
        b.enter((1, 2), 11, 150, 4); // lands between a's enter and exit
        b.exit((1, 2), 11, 260, 12);
        a.merge(&b);
        let v = a.violations();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].clients, (10, 11));
        assert_eq!(v[0].at, 150);
        assert_eq!(a.entries, 2);
    }
}
