//! Graph substrate for the paper's applications.
//!
//! * **Power-law social graph** (Social Media Analysis): Holme–Kim
//!   preferential attachment with triad closure — the model behind
//!   networkx's `powerlaw_cluster_graph`, which the paper uses ("generated
//!   by the tool networkx that simulates the power-law degree distribution
//!   and the clustering characteristics of social networks"; 50 000 nodes,
//!   ~150 000 edges ⇒ m = 3).
//! * **Planar grid** (Weather Monitoring): W×H lattice, 4-neighborhood.
//! * **High-degree preprocessing** (§VI-A): the paper's threshold
//!   `q ≳ (11·|V|/3)^{1/2.5}` — nodes with degree > q are pre-colored so
//!   the distributed phase needs ≤ q extra colors and far fewer locks.
//! * **Partitioning**: contiguous chunks of nodes per client; only edges
//!   crossing clients need mutual-exclusion predicates.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Graph {
    pub n: usize,
    pub adj: Vec<Vec<u32>>,
}

impl Graph {
    pub fn empty(n: usize) -> Self {
        Self { n, adj: vec![Vec::new(); n] }
    }

    pub fn add_edge(&mut self, a: u32, b: u32) {
        if a == b || self.adj[a as usize].contains(&b) {
            return;
        }
        self.adj[a as usize].push(b);
        self.adj[b as usize].push(a);
    }

    pub fn degree(&self, v: u32) -> usize {
        self.adj[v as usize].len()
    }

    pub fn n_edges(&self) -> usize {
        self.adj.iter().map(|a| a.len()).sum::<usize>() / 2
    }

    /// Undirected edge list with a < b.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.adj.iter().enumerate().flat_map(|(a, nbrs)| {
            nbrs.iter()
                .filter(move |&&b| (a as u32) < b)
                .map(move |&b| (a as u32, b))
        })
    }

    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.adj[v as usize]
    }

    /// Holme–Kim power-law graph with clustering: `m` edges per new node,
    /// triad-closure probability `p`.
    pub fn powerlaw_cluster(n: usize, m: usize, p: f64, rng: &mut Rng) -> Self {
        assert!(n > m && m >= 1);
        let mut g = Self::empty(n);
        // repeated-nodes list: preferential attachment by degree
        let mut repeated: Vec<u32> = Vec::with_capacity(2 * n * m);
        // seed clique of m+1 nodes
        for a in 0..=(m as u32) {
            for b in (a + 1)..=(m as u32) {
                g.add_edge(a, b);
                repeated.push(a);
                repeated.push(b);
            }
        }
        for v in (m as u32 + 1)..(n as u32) {
            let mut targets: Vec<u32> = Vec::with_capacity(m);
            let mut last_target: Option<u32> = None;
            while targets.len() < m {
                let candidate = if let (Some(lt), true) = (last_target, rng.chance(p)) {
                    // triad closure: neighbor of the previous target
                    let nbrs = &g.adj[lt as usize];
                    if nbrs.is_empty() {
                        *rng.choose(&repeated)
                    } else {
                        *rng.choose(nbrs)
                    }
                } else {
                    *rng.choose(&repeated)
                };
                if candidate != v && !targets.contains(&candidate) {
                    last_target = Some(candidate);
                    targets.push(candidate);
                }
            }
            for t in targets {
                g.add_edge(v, t);
                repeated.push(v);
                repeated.push(t);
            }
        }
        g
    }

    /// W×H planar grid (weather stations), 4-neighborhood.
    pub fn grid(w: usize, h: usize) -> Self {
        let mut g = Self::empty(w * h);
        for y in 0..h {
            for x in 0..w {
                let v = (y * w + x) as u32;
                if x + 1 < w {
                    g.add_edge(v, v + 1);
                }
                if y + 1 < h {
                    g.add_edge(v, v + w as u32);
                }
            }
        }
        g
    }

    /// The paper's high-degree threshold: `q ≳ (11·|V|/3)^{1/2.5}`.
    pub fn high_degree_threshold(&self) -> usize {
        ((11.0 * self.n as f64 / 3.0).powf(1.0 / 2.5)).ceil() as usize
    }

    /// Nodes with degree > q (to be pre-colored without locks).
    pub fn high_degree_nodes(&self) -> Vec<u32> {
        let q = self.high_degree_threshold();
        (0..self.n as u32).filter(|&v| self.degree(v) > q).collect()
    }
}

/// Contiguous partition of nodes over `n_clients` clients; returns
/// `owner[v] = client index`.
pub fn partition_nodes(n: usize, n_clients: usize) -> Vec<u32> {
    assert!(n_clients >= 1);
    let base = n / n_clients;
    let extra = n % n_clients;
    let mut owner = Vec::with_capacity(n);
    for c in 0..n_clients {
        let len = base + usize::from(c < extra);
        owner.extend(std::iter::repeat(c as u32).take(len));
    }
    owner
}

/// Edges whose endpoints belong to different clients (these need the
/// Peterson mutual-exclusion predicate; same-client edges do not — §I).
pub fn cross_client_edges(g: &Graph, owner: &[u32]) -> Vec<(u32, u32)> {
    g.edges()
        .filter(|&(a, b)| owner[a as usize] != owner[b as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn powerlaw_basic_shape() {
        let mut rng = Rng::new(7);
        let g = Graph::powerlaw_cluster(2_000, 3, 0.3, &mut rng);
        assert_eq!(g.n, 2_000);
        // m edges per node beyond the seed clique → ~3n edges
        let e = g.n_edges();
        assert!(e >= 3 * (2_000 - 4) && e <= 3 * 2_000 + 10, "edges={e}");
        // heavy tail: max degree far above the mean (~6)
        let max_deg = (0..g.n as u32).map(|v| g.degree(v)).max().unwrap();
        assert!(max_deg > 40, "max degree {max_deg} not heavy-tailed");
        // no self loops or duplicates
        for v in 0..g.n as u32 {
            let mut nbrs = g.neighbors(v).to_vec();
            assert!(!nbrs.contains(&v));
            nbrs.sort_unstable();
            nbrs.dedup();
            assert_eq!(nbrs.len(), g.degree(v));
        }
    }

    #[test]
    fn paper_scale_threshold() {
        // |V| = 50 000 → q ≈ (11*50000/3)^0.4 ≈ 128; the paper reports the
        // preprocessed graph needs ≤ 2q ≈ 255 colors.
        let g = Graph::empty(50_000);
        let q = g.high_degree_threshold();
        assert!((120..140).contains(&q), "q={q}");
    }

    #[test]
    fn high_degree_nodes_are_few() {
        let mut rng = Rng::new(3);
        let g = Graph::powerlaw_cluster(5_000, 3, 0.3, &mut rng);
        let q = g.high_degree_threshold();
        let hi = g.high_degree_nodes();
        // the threshold is chosen so that |{v : deg v > q}| ≲ q
        assert!(hi.len() <= q * 2, "{} high-degree nodes vs q={q}", hi.len());
        for v in &hi {
            assert!(g.degree(*v) > q);
        }
    }

    #[test]
    fn grid_shape() {
        let g = Graph::grid(4, 3);
        assert_eq!(g.n, 12);
        assert_eq!(g.n_edges(), 17); // h*(w-1) + w*(h-1) = 9 + 8
        assert_eq!(g.degree(0), 2); // corner
        assert_eq!(g.degree(5), 4); // interior
    }

    #[test]
    fn partition_covers_all() {
        let owner = partition_nodes(10, 3);
        assert_eq!(owner.len(), 10);
        assert_eq!(owner.iter().filter(|&&c| c == 0).count(), 4);
        assert_eq!(owner.iter().filter(|&&c| c == 1).count(), 3);
        assert_eq!(owner.iter().filter(|&&c| c == 2).count(), 3);
    }

    #[test]
    fn cross_client_edges_only() {
        let g = Graph::grid(4, 1); // path 0-1-2-3
        let owner = vec![0, 0, 1, 1];
        let cross = cross_client_edges(&g, &owner);
        assert_eq!(cross, vec![(1, 2)]);
    }
}
