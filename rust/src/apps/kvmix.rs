//! *KvMix*: a YCSB-style read/write-mix workload app driven by the
//! [`crate::workload`] engine — the production-shaped counterpart to the
//! paper's three fixed-graph applications.
//!
//! Each cycle the app draws a key rank from the configured popularity
//! sampler ([`crate::workload::keyspace::KeySampler`]), flips a
//! `put_pct` coin, and issues a GET or PUT on `kv_{rank}`. Writes to the
//! first `guarded` ranks — the *hot set* — are **guarded**: the client
//! raises its per-key occupancy flag `occ_{k}_{i}`, writes the value,
//! then lowers the flag. The monitors watch one predicate per hot key,
//!
//! ```text
//! kvmix_hot_k :  ∃ ring-adjacent clients i, j :  occ_k_i = 1 ∧ occ_k_j = 1
//! ```
//!
//! so two neighbouring clients concurrently inside the same hot key's
//! write window is a detected violation — exactly the mutual-exclusion-
//! under-eventual-consistency shape of the paper's §VI apps, but with a
//! violation rate governed by key skew instead of a β coin. Clauses
//! pair only ring-adjacent clients (i, i+1 mod c), bounding monitor
//! cost at c clauses per hot key instead of c² while keeping the
//! detection probability monotone in contention.
//!
//! With a [`crate::workload::shape::LoadShape`] configured the app
//! paces itself: after each cycle it sleeps `1/rate(now)`, so flash
//! crowds and diurnal curves show up as real arrival-rate changes. No
//! shape → no sleeps → the client's own think-time pacing rules, which
//! is the inert default path.

use std::cell::RefCell;
use std::rc::Rc;

use crate::client::app::{AppAction, AppEnv, AppLogic, AppOp, LastResult};
use crate::metrics::throughput::MetricsHub;
use crate::predicate::spec::{Clause, Conjunct, Literal, PredId, PredKind, PredicateSpec, Registry};
use crate::store::value::{Interner, KeyId, Value};
use crate::workload::keyspace::KeySampler;
use crate::workload::shape::LoadShape;
use crate::workload::WorkloadCfg;

/// Keyspace, predicates and sampler shared by every kvmix client.
#[derive(Clone)]
pub struct KvMixShared {
    pub interner: Rc<RefCell<Interner>>,
    /// value keys `kv_{r}`, rank-indexed
    pub kv: Rc<Vec<KeyId>>,
    /// occupancy flags: `occ[k][i]` = client i's flag for hot key k
    pub occ: Rc<Vec<Vec<KeyId>>>,
    pub pred_ids: Rc<Vec<PredId>>,
    pub sampler: Rc<KeySampler>,
    pub shape: Option<Rc<LoadShape>>,
    pub put_pct: f64,
    pub n_clients: usize,
    /// per-rank op counts flow into the hub (merged across shards like
    /// every other counter), powering the contention stats in
    /// [`crate::exp::runner::ExpResult`]
    pub metrics: Rc<RefCell<MetricsHub>>,
}

impl KvMixShared {
    /// Intern the keyspace, build the sampler, and register one
    /// ring-adjacency predicate per guarded hot key. Deterministic: no
    /// RNG, interning order is rank-major then client-major.
    pub fn setup(
        registry: &Rc<RefCell<Registry>>,
        interner: Rc<RefCell<Interner>>,
        wl: &WorkloadCfg,
        n_clients: usize,
        metrics: Rc<RefCell<MetricsHub>>,
    ) -> Self {
        assert!(wl.n_keys > 0 && wl.guarded <= wl.n_keys);
        let kv: Vec<KeyId> =
            (0..wl.n_keys).map(|r| interner.borrow_mut().intern(&format!("kv_{r}"))).collect();
        let mut occ = Vec::with_capacity(wl.guarded);
        let mut pred_ids = Vec::with_capacity(wl.guarded);
        for k in 0..wl.guarded {
            let flags: Vec<KeyId> = (0..n_clients)
                .map(|i| interner.borrow_mut().intern(&format!("occ_{k}_{i}")))
                .collect();
            // ring-adjacent pairs; c = 2 collapses to the single pair
            let mut clauses = Vec::new();
            for i in 0..n_clients {
                let j = (i + 1) % n_clients;
                if j == i || (n_clients == 2 && i == 1) {
                    continue;
                }
                clauses.push(Clause {
                    conjuncts: [flags[i], flags[j]]
                        .iter()
                        .map(|&v| Conjunct {
                            literals: vec![Literal { var: v, value: Value::Int(1) }],
                        })
                        .collect(),
                });
            }
            if !clauses.is_empty() {
                let spec = PredicateSpec {
                    id: PredId(u32::MAX),
                    name: format!("kvmix_hot_{k}"),
                    kind: PredKind::Linear,
                    clauses,
                };
                pred_ids.push(registry.borrow_mut().add(spec));
            }
            occ.push(flags);
        }
        Self {
            interner,
            kv: Rc::new(kv),
            occ: Rc::new(occ),
            pred_ids: Rc::new(pred_ids),
            sampler: Rc::new(KeySampler::new(&wl.dist, wl.n_keys)),
            shape: wl.shape.as_ref().map(|s| Rc::new(s.clone())),
            put_pct: wl.put_pct,
            n_clients,
            metrics,
        }
    }
}

pub struct KvMixApp {
    sh: KvMixShared,
    client: u32,
    /// remaining ops of the current cycle, issued back-to-front
    pending: Vec<AppOp>,
    /// pace (sleep) before opening the next cycle
    need_pace: bool,
    /// stop after this many cycles (0 = run until the clock stops us)
    pub max_cycles: u64,
    pub cycles: u64,
    pub guarded_writes: u64,
}

impl KvMixApp {
    pub fn new(sh: KvMixShared, client: u32, max_cycles: u64) -> Self {
        Self {
            sh,
            client,
            pending: Vec::new(),
            need_pace: false,
            max_cycles,
            cycles: 0,
            guarded_writes: 0,
        }
    }

    /// Open a new cycle: exactly one sampler draw plus one mix coin, in
    /// that order — the fixed draw pattern every engine replays.
    fn open_cycle(&mut self, env: &mut AppEnv) -> AppAction {
        if self.max_cycles > 0 && self.cycles >= self.max_cycles {
            return AppAction::Done;
        }
        let r = self.sh.sampler.sample(env.rng);
        let write = env.rng.chance(self.sh.put_pct);
        self.cycles += 1;
        self.need_pace = self.sh.shape.is_some();
        self.sh.metrics.borrow_mut().bump_key(r);
        let key = self.sh.kv[r];
        if !write {
            return AppAction::Op(AppOp::Get(key));
        }
        let val = Value::Int(self.cycles as i64);
        if r < self.sh.occ.len() {
            // guarded write: occupy → write → release. The occupancy
            // window is what the hot-key predicates observe.
            self.guarded_writes += 1;
            let flag = self.sh.occ[r][self.client as usize % self.sh.n_clients];
            let occupy = AppOp::Put(flag, Value::Int(1));
            let put = AppOp::Put(key, val);
            let release = AppOp::Put(flag, Value::Int(0));
            if env.pipelined() {
                // occupy and the value write are independent keys:
                // overlap them, but the release must gather-wait so the
                // occupancy window covers the write
                self.pending.push(release);
                return AppAction::Batch(vec![occupy, put]);
            }
            self.pending.push(release);
            self.pending.push(put);
            return AppAction::Op(occupy);
        }
        AppAction::Op(AppOp::Put(key, val))
    }
}

impl AppLogic for KvMixApp {
    fn name(&self) -> &'static str {
        "kvmix"
    }

    fn next(&mut self, env: &mut AppEnv, _last: Option<LastResult>) -> AppAction {
        if let Some(op) = self.pending.pop() {
            return AppAction::Op(op);
        }
        if self.need_pace {
            self.need_pace = false;
            if let Some(shape) = &self.sh.shape {
                return AppAction::Sleep(shape.gap_at(env.now));
            }
        }
        self.open_cycle(env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::app::OpOutcome;
    use crate::sim::SEC;
    use crate::util::rng::Rng;
    use crate::workload::keyspace::KeyDist;

    fn setup(wl: &WorkloadCfg, n_clients: usize) -> (KvMixShared, Rc<RefCell<Registry>>) {
        let registry = Rc::new(RefCell::new(Registry::new()));
        let sh =
            KvMixShared::setup(&registry, Interner::new(), wl, n_clients, MetricsHub::new(1, 1));
        (sh, registry)
    }

    /// Drive the app serially, feeding PutOk/GetOk, collecting ops.
    fn drive(app: &mut KvMixApp, seed: u64, pipeline: usize) -> (Vec<AppOp>, u64) {
        let mut rng = Rng::new(seed);
        let mut ops = Vec::new();
        let mut sleeps = 0u64;
        let mut last = None;
        loop {
            let mut env = AppEnv {
                now: sleeps * SEC,
                seq: 0,
                client_idx: app.client,
                pipeline,
                rng: &mut rng,
            };
            match app.next(&mut env, last.take()) {
                AppAction::Op(op) => {
                    let out = match &op {
                        AppOp::Get(_) => OpOutcome::GetOk(vec![]),
                        AppOp::Put(..) => OpOutcome::PutOk,
                    };
                    ops.push(op.clone());
                    last = Some(LastResult::Op(op, out));
                }
                AppAction::Batch(batch) => {
                    let pairs: Vec<(AppOp, OpOutcome)> = batch
                        .into_iter()
                        .map(|op| {
                            ops.push(op.clone());
                            (op, OpOutcome::PutOk)
                        })
                        .collect();
                    last = Some(LastResult::Batch(pairs));
                }
                AppAction::Sleep(_) => {
                    sleeps += 1;
                    last = None;
                }
                AppAction::Done => break,
            }
        }
        (ops, sleeps)
    }

    #[test]
    fn hot_key_predicates_pair_ring_neighbours() {
        let wl = WorkloadCfg::uniform_default().with_keys(16, 3);
        let (sh, registry) = setup(&wl, 5);
        assert_eq!(registry.borrow().len(), 3, "one predicate per guarded key");
        let reg = registry.borrow();
        for &id in sh.pred_ids.iter() {
            let spec = reg.get(id);
            assert_eq!(spec.kind, PredKind::Linear);
            assert_eq!(spec.clauses.len(), 5, "c ring-adjacent pairs for c = 5 clients");
            for clause in &spec.clauses {
                assert_eq!(clause.conjuncts.len(), 2, "pairwise contention clauses");
            }
        }
        // flag occ_0_0 participates in predicate 0 (clauses (0,1) and (4,0))
        let hits = reg.affected(sh.occ[0][0]).unwrap();
        assert!(hits.iter().all(|h| h.0 == sh.pred_ids[0]));
        assert_eq!(hits.len(), 2);
    }

    #[test]
    fn two_clients_collapse_to_one_pair() {
        let wl = WorkloadCfg::uniform_default().with_keys(8, 1);
        let (_, registry) = setup(&wl, 2);
        let reg = registry.borrow();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get(PredId(0)).clauses.len(), 1, "ring of 2 has a single edge");
    }

    #[test]
    fn guarded_writes_bracket_the_value_with_occupancy() {
        // put_pct = 1, all keys guarded: every cycle is occupy → write → release
        let wl = WorkloadCfg::uniform_default().with_keys(2, 2).with_mix(1.0);
        let (sh, _) = setup(&wl, 3);
        let mut app = KvMixApp::new(sh.clone(), 1, 10);
        let (ops, sleeps) = drive(&mut app, 7, 1);
        assert_eq!(sleeps, 0, "no shape, no pacing");
        assert_eq!(ops.len(), 30, "3 ops per guarded cycle");
        assert_eq!(app.guarded_writes, 10);
        for cycle in ops.chunks(3) {
            let flag = cycle[0].key();
            assert!(matches!(cycle[0], AppOp::Put(_, Value::Int(1))), "occupy first");
            assert!(sh.kv.contains(&cycle[1].key()), "value write in the middle");
            assert!(matches!(cycle[2], AppOp::Put(_, Value::Int(0))), "release last");
            assert_eq!(cycle[2].key(), flag, "release lowers the same flag");
        }
    }

    #[test]
    fn pipelined_guarded_write_batches_occupy_with_value() {
        let wl = WorkloadCfg::uniform_default().with_keys(2, 2).with_mix(1.0);
        let (sh, _) = setup(&wl, 3);
        let mut app = KvMixApp::new(sh, 0, 5);
        let mut rng = Rng::new(3);
        let mut env = AppEnv { now: 0, seq: 0, client_idx: 0, pipeline: 4, rng: &mut rng };
        match app.next(&mut env, None) {
            AppAction::Batch(ops) => {
                assert_eq!(ops.len(), 2, "occupy + value overlap");
                assert!(matches!(ops[0], AppOp::Put(_, Value::Int(1))));
            }
            other => panic!("expected a batch, got {other:?}"),
        }
        // the release gathers after the wave
        let mut env = AppEnv { now: 0, seq: 0, client_idx: 0, pipeline: 4, rng: &mut rng };
        match app.next(&mut env, None) {
            AppAction::Op(AppOp::Put(_, Value::Int(0))) => {}
            other => panic!("expected the release, got {other:?}"),
        }
    }

    #[test]
    fn mix_matches_put_pct_and_unguarded_ops_are_bare() {
        let wl = WorkloadCfg::uniform_default().with_keys(64, 0).with_mix(0.25);
        let (_, _registry) = setup(&wl, 4);
        let (sh, _) = setup(&wl, 4);
        let mut app = KvMixApp::new(sh, 2, 4000);
        let (ops, _) = drive(&mut app, 11, 1);
        assert_eq!(ops.len(), 4000, "no guarded keys: one op per cycle");
        let puts = ops.iter().filter(|o| matches!(o, AppOp::Put(..))).count();
        let frac = puts as f64 / ops.len() as f64;
        assert!((frac - 0.25).abs() < 0.03, "put fraction {frac}");
    }

    #[test]
    fn shape_paces_one_sleep_per_cycle() {
        let wl = WorkloadCfg::uniform_default()
            .with_keys(8, 0)
            .with_mix(0.0)
            .with_shape(LoadShape::constant(10.0, 100 * SEC));
        let (sh, _) = setup(&wl, 2);
        let mut app = KvMixApp::new(sh, 0, 20);
        let (ops, sleeps) = drive(&mut app, 5, 1);
        assert_eq!(ops.len(), 20);
        assert_eq!(sleeps, 20, "one pacing sleep after every cycle");
    }

    #[test]
    fn skewed_sampler_concentrates_traffic_and_counts_keys() {
        let wl = WorkloadCfg::uniform_default()
            .with_keys(32, 0)
            .with_dist(KeyDist::Zipf { theta: 1.2 })
            .with_mix(0.0);
        let (sh, _) = setup(&wl, 2);
        let mut app = KvMixApp::new(sh.clone(), 0, 3000);
        let (ops, _) = drive(&mut app, 13, 1);
        let hot = ops.iter().filter(|o| o.key() == sh.kv[0]).count();
        assert!(hot > ops.len() / 5, "rank 0 dominates at theta = 1.2 ({hot})");
        let key_ops = sh.metrics.borrow().key_ops().to_vec();
        assert_eq!(key_ops.iter().sum::<u64>(), 3000, "every cycle counted");
        assert_eq!(key_ops[0], hot as u64, "counts track sampled ranks");
    }

    #[test]
    fn op_stream_is_seed_deterministic() {
        let wl = WorkloadCfg::uniform_default()
            .with_keys(16, 4)
            .with_dist(KeyDist::Zipf { theta: 0.99 });
        let (sh_a, _) = setup(&wl, 3);
        let (sh_b, _) = setup(&wl, 3);
        let mut a = KvMixApp::new(sh_a, 1, 200);
        let mut b = KvMixApp::new(sh_b, 1, 200);
        let (ops_a, _) = drive(&mut a, 21, 1);
        let (ops_b, _) = drive(&mut b, 21, 1);
        assert_eq!(ops_a.len(), ops_b.len());
        for (x, y) in ops_a.iter().zip(&ops_b) {
            assert_eq!(x.key(), y.key());
        }
    }
}
