//! Pluggable mode-selection policies. A policy sees one aggregated
//! [`WindowStats`] per signal window and returns the [`Mode`] the
//! cluster should run; the [`crate::adapt::controller::AdaptController`]
//! turns mode *changes* into epoch switches.

use crate::adapt::signals::WindowStats;

/// The operating points of the tradeoff (Table II): optimistic
/// execution under eventual consistency with detect-rollback,
/// pessimistic execution under (quorum-)sequential consistency — and,
/// between them, the causal rung: the same eventual quorum config with
/// client-side session guarantees layered on
/// ([`crate::client::quorum::Session`]). Binary controllers only ever
/// visit the outer two; the [`PolicyKind::Hysteresis3`] ladder walks
/// all three one step at a time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Eventual,
    Causal,
    Sequential,
}

impl Mode {
    pub fn label(self) -> &'static str {
        match self {
            Mode::Eventual => "eventual",
            Mode::Causal => "causal",
            Mode::Sequential => "sequential",
        }
    }

    /// Escalation-ladder rung (0 = weakest). Also indexes per-mode
    /// tables such as [`crate::adapt::AdaptCfg::recovery_by_mode`].
    pub fn rung(self) -> usize {
        match self {
            Mode::Eventual => 0,
            Mode::Causal => 1,
            Mode::Sequential => 2,
        }
    }
}

/// One decision per signal window. Policies may keep internal state
/// (streak counters); they must be deterministic functions of the
/// sample sequence so adaptive runs replay under a seed.
pub trait Policy {
    fn name(&self) -> &'static str;
    fn decide(&mut self, w: &WindowStats, current: Mode) -> Mode;
}

/// Never moves: the cluster stays in whatever mode it started in. This
/// reproduces today's static-`ConsistencyCfg` behavior — and because it
/// is the default, the experiment runner does not even deploy a
/// controller for it ([`crate::adapt::AdaptCfg::enabled`]).
#[derive(Debug, Default, Clone, Copy)]
pub struct StaticPolicy;

impl Policy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn decide(&mut self, _w: &WindowStats, current: Mode) -> Mode {
        current
    }
}

/// Hysteresis thresholds. Each signal is an independent (hi, lo) pair:
/// the policy escalates to sequential when *any* armed signal exceeds
/// its `hi`, and de-escalates only after [`Self::hold_windows`]
/// consecutive windows with *every* signal below its `lo`. The gap
/// between `hi` and `lo` plus the hold is what prevents flapping on a
/// signal that hovers near the threshold.
///
/// A pair is disarmed by setting both bounds to `f64::INFINITY` (it then
/// never escalates and never blocks de-escalation).
#[derive(Debug, Clone, PartialEq)]
pub struct HysteresisCfg {
    pub viol_per_kop_hi: f64,
    pub viol_per_kop_lo: f64,
    pub timeouts_per_sec_hi: f64,
    pub timeouts_per_sec_lo: f64,
    pub stall_frac_hi: f64,
    pub stall_frac_lo: f64,
    pub lat_p99_ms_hi: f64,
    pub lat_p99_ms_lo: f64,
    /// mean violation detection latency (ms) over the window — a slow
    /// detector widens the rollback window, which is the other half of
    /// the "rollbacks cheap" premise
    pub detect_ms_hi: f64,
    pub detect_ms_lo: f64,
    /// consecutive calm windows required before returning to eventual
    pub hold_windows: usize,
}

impl Default for HysteresisCfg {
    fn default() -> Self {
        Self {
            // "violations are rare": a handful per kop is the premise
            // breaking down
            viol_per_kop_hi: 5.0,
            viol_per_kop_lo: 1.0,
            // expired quorum rounds signal an unhealthy network
            timeouts_per_sec_hi: 0.5,
            timeouts_per_sec_lo: 0.05,
            // a quarter of wall-time frozen for rollback erases the
            // optimistic win
            stall_frac_hi: 0.25,
            stall_frac_lo: 0.02,
            // latency pairs ship disarmed: absolute op-latency and
            // detection-latency bounds are deployment-specific (regional
            // detection is ~ms, global ~s — §VI), scenarios arm them
            // explicitly
            lat_p99_ms_hi: f64::INFINITY,
            lat_p99_ms_lo: f64::INFINITY,
            detect_ms_hi: f64::INFINITY,
            detect_ms_lo: f64::INFINITY,
            hold_windows: 5,
        }
    }
}

impl HysteresisCfg {
    /// A copy with every pair disarmed — callers arm just the signals
    /// their scenario reasons about.
    pub fn disarmed() -> Self {
        Self {
            viol_per_kop_hi: f64::INFINITY,
            viol_per_kop_lo: f64::INFINITY,
            timeouts_per_sec_hi: f64::INFINITY,
            timeouts_per_sec_lo: f64::INFINITY,
            stall_frac_hi: f64::INFINITY,
            stall_frac_lo: f64::INFINITY,
            lat_p99_ms_hi: f64::INFINITY,
            lat_p99_ms_lo: f64::INFINITY,
            detect_ms_hi: f64::INFINITY,
            detect_ms_lo: f64::INFINITY,
            ..Self::default()
        }
    }
}

/// Threshold hysteresis over the sliding-window signals. Binary by
/// default (eventual ↔ sequential, today's behavior); with
/// [`Self::three_level`] it becomes the escalation ladder
/// eventual ↔ causal ↔ sequential, moving **one rung per window**: a
/// hot window climbs one step, `hold_windows` consecutive calm windows
/// descend one step (the streak restarts on each rung, so a full
/// descent takes `2 × hold_windows` calm windows).
#[derive(Debug)]
pub struct HysteresisPolicy {
    cfg: HysteresisCfg,
    calm_streak: usize,
    /// three-level ladder (causal rung exists) vs binary
    ladder: bool,
}

impl HysteresisPolicy {
    pub fn new(cfg: HysteresisCfg) -> Self {
        Self { cfg, calm_streak: 0, ladder: false }
    }

    pub fn three_level(cfg: HysteresisCfg) -> Self {
        Self { cfg, calm_streak: 0, ladder: true }
    }

    fn hot(&self, w: &WindowStats) -> bool {
        w.viol_per_kop() > self.cfg.viol_per_kop_hi
            || w.timeouts_per_sec() > self.cfg.timeouts_per_sec_hi
            || w.stall_frac() > self.cfg.stall_frac_hi
            || w.lat_p99_ms > self.cfg.lat_p99_ms_hi
            || w.detect_mean_ms() > self.cfg.detect_ms_hi
    }

    fn calm(&self, w: &WindowStats) -> bool {
        w.viol_per_kop() < self.cfg.viol_per_kop_lo
            && w.timeouts_per_sec() < self.cfg.timeouts_per_sec_lo
            && w.stall_frac() < self.cfg.stall_frac_lo
            && w.lat_p99_ms < self.cfg.lat_p99_ms_lo
            && w.detect_mean_ms() < self.cfg.detect_ms_lo
    }
}

impl Policy for HysteresisPolicy {
    fn name(&self) -> &'static str {
        if self.ladder {
            "hysteresis3"
        } else {
            "hysteresis"
        }
    }

    fn decide(&mut self, w: &WindowStats, current: Mode) -> Mode {
        match current {
            Mode::Eventual => {
                if self.hot(w) {
                    self.calm_streak = 0;
                    if self.ladder {
                        Mode::Causal
                    } else {
                        Mode::Sequential
                    }
                } else {
                    Mode::Eventual
                }
            }
            Mode::Causal => {
                // the middle rung escalates like the floor and releases
                // like the ceiling; hot wins when a window is both
                // (impossible with coherent lo <= hi thresholds)
                if self.hot(w) {
                    self.calm_streak = 0;
                    return Mode::Sequential;
                }
                if self.calm(w) {
                    self.calm_streak += 1;
                    if self.calm_streak >= self.cfg.hold_windows {
                        self.calm_streak = 0;
                        return Mode::Eventual;
                    }
                } else {
                    self.calm_streak = 0;
                }
                Mode::Causal
            }
            Mode::Sequential => {
                if self.calm(w) {
                    self.calm_streak += 1;
                    if self.calm_streak >= self.cfg.hold_windows {
                        self.calm_streak = 0;
                        return if self.ladder { Mode::Causal } else { Mode::Eventual };
                    }
                } else {
                    self.calm_streak = 0;
                }
                Mode::Sequential
            }
        }
    }
}

/// Cloneable policy selector carried by experiment configs; built into a
/// live [`Policy`] by the runner.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicyKind {
    /// today's behavior — no controller is deployed at all
    Static,
    Hysteresis(HysteresisCfg),
    /// the same thresholds driving the three-level escalation ladder
    /// (requires [`crate::adapt::AdaptCfg::causal`] to name the middle
    /// rung's quorum config)
    Hysteresis3(HysteresisCfg),
}

impl PolicyKind {
    pub fn build(&self) -> Box<dyn Policy> {
        match self {
            PolicyKind::Static => Box::new(StaticPolicy),
            PolicyKind::Hysteresis(h) => Box::new(HysteresisPolicy::new(h.clone())),
            PolicyKind::Hysteresis3(h) => Box::new(HysteresisPolicy::three_level(h.clone())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(ops: u64, violations: u64, timeouts: u64, stall_ms: f64) -> WindowStats {
        WindowStats { ops, violations, timeouts, stall_ms, span_ms: 1_000.0, ..Default::default() }
    }

    #[test]
    fn static_policy_never_moves() {
        let mut p = StaticPolicy;
        let storm = stats(10, 500, 500, 900.0);
        assert_eq!(p.decide(&storm, Mode::Eventual), Mode::Eventual);
        assert_eq!(p.decide(&storm, Mode::Sequential), Mode::Sequential);
    }

    #[test]
    fn hysteresis_escalates_on_any_hot_signal() {
        let cfg = HysteresisCfg::default();
        // violations: > 5 per kop
        let mut p = HysteresisPolicy::new(cfg.clone());
        assert_eq!(p.decide(&stats(1_000, 6, 0, 0.0), Mode::Eventual), Mode::Sequential);
        // timeouts: > 0.5 per s
        let mut p = HysteresisPolicy::new(cfg.clone());
        assert_eq!(p.decide(&stats(1_000, 0, 1, 0.0), Mode::Eventual), Mode::Sequential);
        // stall: > 25 % of the window
        let mut p = HysteresisPolicy::new(cfg.clone());
        assert_eq!(p.decide(&stats(1_000, 0, 0, 300.0), Mode::Eventual), Mode::Sequential);
        // all below hi: stays
        let mut p = HysteresisPolicy::new(cfg);
        assert_eq!(p.decide(&stats(1_000, 4, 0, 100.0), Mode::Eventual), Mode::Eventual);
    }

    #[test]
    fn hysteresis_holds_before_deescalating() {
        let cfg = HysteresisCfg { hold_windows: 3, ..HysteresisCfg::default() };
        let mut p = HysteresisPolicy::new(cfg);
        let calm = stats(1_000, 0, 0, 0.0);
        assert_eq!(p.decide(&calm, Mode::Sequential), Mode::Sequential, "calm 1");
        assert_eq!(p.decide(&calm, Mode::Sequential), Mode::Sequential, "calm 2");
        assert_eq!(p.decide(&calm, Mode::Sequential), Mode::Eventual, "calm 3 releases");
    }

    #[test]
    fn a_noisy_window_resets_the_calm_streak() {
        let cfg = HysteresisCfg { hold_windows: 2, ..HysteresisCfg::default() };
        let mut p = HysteresisPolicy::new(cfg);
        let calm = stats(1_000, 0, 0, 0.0);
        // 3 violations/kop is below hi (5) but above lo (1): not calm
        let murky = stats(1_000, 3, 0, 0.0);
        assert_eq!(p.decide(&calm, Mode::Sequential), Mode::Sequential);
        assert_eq!(p.decide(&murky, Mode::Sequential), Mode::Sequential, "streak reset");
        assert_eq!(p.decide(&calm, Mode::Sequential), Mode::Sequential);
        assert_eq!(p.decide(&calm, Mode::Sequential), Mode::Eventual);
    }

    #[test]
    fn band_between_lo_and_hi_is_sticky_both_ways() {
        // the anti-flap property: a signal hovering between lo and hi
        // neither escalates nor de-escalates
        let cfg = HysteresisCfg { hold_windows: 1, ..HysteresisCfg::default() };
        let murky = stats(1_000, 3, 0, 0.0);
        let mut p = HysteresisPolicy::new(cfg.clone());
        assert_eq!(p.decide(&murky, Mode::Eventual), Mode::Eventual);
        let mut p = HysteresisPolicy::new(cfg);
        assert_eq!(p.decide(&murky, Mode::Sequential), Mode::Sequential);
    }

    #[test]
    fn disarmed_pairs_never_fire_or_block() {
        let mut armed_only_timeouts = HysteresisCfg::disarmed();
        armed_only_timeouts.timeouts_per_sec_hi = 0.5;
        armed_only_timeouts.timeouts_per_sec_lo = 0.05;
        armed_only_timeouts.hold_windows = 1;
        let mut p = HysteresisPolicy::new(armed_only_timeouts);
        // a violation storm does not escalate (pair disarmed) ...
        assert_eq!(p.decide(&stats(10, 500, 0, 0.0), Mode::Eventual), Mode::Eventual);
        // ... timeouts do ...
        assert_eq!(p.decide(&stats(10, 500, 5, 0.0), Mode::Eventual), Mode::Sequential);
        // ... and the storm does not block the release once timeouts stop
        assert_eq!(p.decide(&stats(10, 500, 0, 0.0), Mode::Sequential), Mode::Eventual);
    }

    #[test]
    fn armed_detection_latency_pair_escalates_and_releases() {
        let mut cfg = HysteresisCfg::disarmed();
        cfg.detect_ms_hi = 100.0;
        cfg.detect_ms_lo = 20.0;
        cfg.hold_windows = 1;
        let mut p = HysteresisPolicy::new(cfg);
        let slow_detect = WindowStats {
            ops: 1_000,
            violations: 4,
            detect_ms_sum: 800.0,
            detect_n: 4,
            span_ms: 1_000.0,
            ..Default::default()
        };
        assert_eq!(slow_detect.detect_mean_ms(), 200.0);
        assert_eq!(p.decide(&slow_detect, Mode::Eventual), Mode::Sequential);
        // a violation-free window has nothing slow to detect: calm
        let quiet = WindowStats { ops: 1_000, span_ms: 1_000.0, ..Default::default() };
        assert_eq!(p.decide(&quiet, Mode::Sequential), Mode::Eventual);
    }

    #[test]
    fn policy_kind_builds_the_right_impl() {
        assert_eq!(PolicyKind::Static.build().name(), "static");
        assert_eq!(
            PolicyKind::Hysteresis(HysteresisCfg::default()).build().name(),
            "hysteresis"
        );
        assert_eq!(
            PolicyKind::Hysteresis3(HysteresisCfg::default()).build().name(),
            "hysteresis3"
        );
    }

    #[test]
    fn ladder_climbs_one_rung_per_hot_window() {
        let mut p = HysteresisPolicy::three_level(HysteresisCfg::default());
        let storm = stats(1_000, 50, 0, 0.0);
        assert_eq!(p.decide(&storm, Mode::Eventual), Mode::Causal, "never skips causal");
        assert_eq!(p.decide(&storm, Mode::Causal), Mode::Sequential);
        assert_eq!(p.decide(&storm, Mode::Sequential), Mode::Sequential, "already at the top");
    }

    #[test]
    fn ladder_descends_one_rung_per_held_calm_streak() {
        let cfg = HysteresisCfg { hold_windows: 2, ..HysteresisCfg::default() };
        let mut p = HysteresisPolicy::three_level(cfg);
        let calm = stats(1_000, 0, 0, 0.0);
        assert_eq!(p.decide(&calm, Mode::Sequential), Mode::Sequential, "calm 1");
        assert_eq!(p.decide(&calm, Mode::Sequential), Mode::Causal, "calm 2 releases a rung");
        // the streak restarts on the causal rung: a full descent costs
        // another hold_windows calm windows
        assert_eq!(p.decide(&calm, Mode::Causal), Mode::Causal, "calm 1 again");
        assert_eq!(p.decide(&calm, Mode::Causal), Mode::Eventual, "calm 2 again");
    }

    #[test]
    fn ladder_middle_rung_is_sticky_in_the_murky_band() {
        let cfg = HysteresisCfg { hold_windows: 1, ..HysteresisCfg::default() };
        let mut p = HysteresisPolicy::three_level(cfg);
        // 3 violations/kop: below hi (5), above lo (1) — neither way
        let murky = stats(1_000, 3, 0, 0.0);
        assert_eq!(p.decide(&murky, Mode::Causal), Mode::Causal);
        // and a murky window resets a started calm streak
        let cfg = HysteresisCfg { hold_windows: 2, ..HysteresisCfg::default() };
        let mut p = HysteresisPolicy::three_level(cfg);
        let calm = stats(1_000, 0, 0, 0.0);
        assert_eq!(p.decide(&calm, Mode::Causal), Mode::Causal);
        assert_eq!(p.decide(&murky, Mode::Causal), Mode::Causal, "streak reset");
        assert_eq!(p.decide(&calm, Mode::Causal), Mode::Causal);
        assert_eq!(p.decide(&calm, Mode::Causal), Mode::Eventual);
    }

    #[test]
    fn binary_hysteresis_never_emits_causal() {
        // the pre-ladder behavior is untouched: hot goes straight to
        // sequential and release goes straight back
        let cfg = HysteresisCfg { hold_windows: 1, ..HysteresisCfg::default() };
        let mut p = HysteresisPolicy::new(cfg);
        assert_eq!(p.decide(&stats(1_000, 50, 0, 0.0), Mode::Eventual), Mode::Sequential);
        assert_eq!(p.decide(&stats(1_000, 0, 0, 0.0), Mode::Sequential), Mode::Eventual);
    }

    #[test]
    fn mode_rungs_are_ordered() {
        assert_eq!(Mode::Eventual.rung(), 0);
        assert_eq!(Mode::Causal.rung(), 1);
        assert_eq!(Mode::Sequential.rung(), 2);
        assert_eq!(Mode::Causal.label(), "causal");
    }
}
