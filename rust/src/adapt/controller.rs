//! The `AdaptController` actor: one per deployment (co-located with the
//! control plane in region 0), ticking once per signal window.
//!
//! Per tick it (1) closes a [`WinSample`] — folding the op / timeout /
//! latency digests the clients push as [`AdaptMsg::Report`]s into the
//! violation & stall samples pushed by the rollback controller since the
//! last tick — (2) asks the [`Policy`] for the target [`Mode`], and (3)
//! on a change runs the epoch protocol: bump the consistency epoch,
//! record it on the mode timeline, and announce the new quorum config to
//! every client. Clients ack the epoch they run under; the controller
//! re-announces to un-acked clients each tick, so an announce lost to a
//! partition converges after heal instead of wedging the protocol.
//!
//! Every signal arrives as a message; the controller reads no shared
//! state. That is what lets it live on any shard of the threaded engine
//! while the clients it governs live on others (client reports lag one
//! report window behind the hub they also feed, which only shifts policy
//! decisions by a bounded, deterministic delay).

use crate::adapt::policy::{Mode, Policy};
use crate::adapt::signals::{SignalWindow, WinSample};
use crate::adapt::AdaptCfg;
use crate::client::consistency::ConsistencyCfg;
use crate::rollback::recovery::RecoveryPolicy;
use crate::sim::des::{Actor, Ctx};
use crate::sim::msg::{AdaptMsg, Msg};
use crate::sim::{ProcId, Time, MS};
use crate::trace::{TraceEv, TraceRef};
use crate::util::stats::Cdf;

const TAG_TICK: u64 = 1;

/// One entry of the mode timeline: from `from` onwards the cluster was
/// asked to run `cfg` under consistency epoch `epoch`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModeSpan {
    pub from: Time,
    pub epoch: u64,
    pub cfg: ConsistencyCfg,
}

impl ModeSpan {
    pub fn label(&self) -> &'static str {
        self.cfg.model_name()
    }
}

/// Count completed eventual → sequential → eventual excursions on a
/// timeline (the acceptance artifact of the fault-phased scenarios).
pub fn round_trips(timeline: &[ModeSpan]) -> usize {
    let mut n = 0;
    let mut armed = false; // saw eventual → sequential
    for w in timeline.windows(2) {
        let (a, b) = (w[0].cfg.is_sequential(), w[1].cfg.is_sequential());
        match (a, b) {
            (false, true) => armed = true,
            (true, false) if armed => {
                n += 1;
                armed = false;
            }
            _ => {}
        }
    }
    n
}

pub struct AdaptController {
    clients: Vec<ProcId>,
    policy: Box<dyn Policy>,
    eventual: ConsistencyCfg,
    /// the middle rung's quorum config — present iff the policy is the
    /// three-level ladder (validated)
    causal: Option<ConsistencyCfg>,
    sequential: ConsistencyCfg,
    /// the rollback controller, when one is deployed — the target of
    /// [`AdaptMsg::SetRecovery`] pushes
    rollback: Option<ProcId>,
    /// per-mode recovery strategies (indexed by [`Mode::rung`]); `None`
    /// pushes nothing and leaves the rollback controller's static policy
    recovery_by_mode: Option<[RecoveryPolicy; 3]>,
    window: Time,
    win: SignalWindow,
    mode: Mode,
    /// highest epoch each client has acked (index = client idx)
    acked: Vec<u64>,
    /// last computed op-latency p99 — carried forward through windows
    /// whose reports held ops but no latency samples (clients cap their
    /// report payloads), so an armed latency pair does not decay to a
    /// permanently "calm" 0
    last_lat_p99: f64,
    // push accumulators for the currently-open window
    cur_ops: u64,
    cur_timeouts: u64,
    cur_lat: Vec<Time>,
    cur_violations: u64,
    cur_detect_ms_sum: f64,
    cur_detect_n: u64,
    cur_stall_ms: f64,
    /// flight recorder handle (`None` = recording off, zero overhead)
    trace: Option<TraceRef>,
    /// current consistency epoch (0 = the starting config)
    pub epoch: u64,
    /// announce times and configs, starting with the initial mode
    pub timeline: Vec<ModeSpan>,
    /// completed mode changes announced
    pub switches: u64,
    /// announce messages sent (incl. re-announces to un-acked clients)
    pub announces_sent: u64,
}

impl AdaptController {
    pub fn new(clients: Vec<ProcId>, cfg: &AdaptCfg, starting: ConsistencyCfg) -> Self {
        cfg.validate(starting).expect("adapt config must validate against the experiment");
        assert!(cfg.enabled(), "a static adapt config deploys no controller");
        let mode = if starting == cfg.sequential {
            Mode::Sequential
        } else if cfg.causal == Some(starting) {
            Mode::Causal
        } else {
            Mode::Eventual
        };
        let n_clients = clients.len();
        Self {
            clients,
            policy: cfg.policy.build(),
            eventual: cfg.eventual,
            causal: cfg.causal,
            sequential: cfg.sequential,
            rollback: None,
            recovery_by_mode: cfg.recovery_by_mode,
            window: cfg.window,
            win: SignalWindow::new(cfg.windows_kept),
            mode,
            acked: vec![0; n_clients],
            last_lat_p99: 0.0,
            cur_ops: 0,
            cur_timeouts: 0,
            cur_lat: Vec::new(),
            cur_violations: 0,
            cur_detect_ms_sum: 0.0,
            cur_detect_n: 0,
            cur_stall_ms: 0.0,
            trace: None,
            epoch: 0,
            timeline: Vec::new(),
            switches: 0,
            announces_sent: 0,
        }
    }

    /// Wire the rollback controller so mode switches can re-target the
    /// recovery strategy. A no-op without a recovery matrix.
    pub fn with_rollback(mut self, rollback: Option<ProcId>) -> Self {
        self.rollback = rollback;
        self
    }

    /// Attach the flight recorder ([`crate::trace`]).
    pub fn with_trace(mut self, trace: TraceRef) -> Self {
        self.trace = Some(trace);
        self
    }

    fn mode_cfg(&self, mode: Mode) -> ConsistencyCfg {
        match mode {
            Mode::Eventual => self.eventual,
            Mode::Causal => self.causal.expect("a causal mode requires a causal rung config"),
            Mode::Sequential => self.sequential,
        }
    }

    /// Push the current mode's recovery strategy to the rollback
    /// controller (which applies it between recoveries, never
    /// mid-phase). Sends nothing unless a matrix is configured.
    fn push_recovery_policy(&mut self, ctx: &mut Ctx) {
        if let (Some(rb), Some(map)) = (self.rollback, self.recovery_by_mode) {
            ctx.send(rb, Msg::Adapt(AdaptMsg::SetRecovery { policy: map[self.mode.rung()] }));
        }
    }

    /// Close the open window over the samples pushed since the last tick.
    fn close_window(&mut self) -> WinSample {
        let lat = std::mem::take(&mut self.cur_lat);
        let lat_p99_ms = if !lat.is_empty() {
            let p = Cdf::new(lat.iter().map(|&l| l as f64 / MS as f64).collect()).quantile(0.99);
            self.last_lat_p99 = p;
            p
        } else if self.cur_ops > 0 {
            // ops completed but their samples were capped away: keep the
            // estimate rather than decay to a falsely calm 0
            self.last_lat_p99
        } else {
            0.0 // genuinely idle window
        };
        let sample = WinSample {
            ops: std::mem::take(&mut self.cur_ops),
            timeouts: std::mem::take(&mut self.cur_timeouts),
            violations: self.cur_violations,
            stall_ms: self.cur_stall_ms,
            lat_p99_ms,
            detect_ms_sum: self.cur_detect_ms_sum,
            detect_n: self.cur_detect_n,
            span_ms: self.window as f64 / MS as f64,
        };
        self.cur_violations = 0;
        self.cur_detect_ms_sum = 0.0;
        self.cur_detect_n = 0;
        self.cur_stall_ms = 0.0;
        sample
    }

    /// Announce the current epoch to every client that has not acked it.
    /// Converged clusters send nothing — this doubles as the retransmit
    /// path for announces lost to partitions or crashes.
    fn announce_unacked(&mut self, ctx: &mut Ctx) {
        if self.epoch == 0 {
            return; // epoch 0 is the starting config — nothing to announce
        }
        let cfg = self.mode_cfg(self.mode);
        let epoch = self.epoch;
        for (i, &c) in self.clients.iter().enumerate() {
            if self.acked[i] < epoch {
                ctx.send(c, Msg::Adapt(AdaptMsg::Announce { epoch, cfg }));
                self.announces_sent += 1;
            }
        }
    }
}

impl Actor for AdaptController {
    fn on_start(&mut self, ctx: &mut Ctx) {
        self.timeline.push(ModeSpan { from: 0, epoch: 0, cfg: self.mode_cfg(self.mode) });
        // make the matrix authoritative from t = 0: the starting mode's
        // strategy may differ from the experiment's static recovery
        self.push_recovery_policy(ctx);
        ctx.schedule(self.window, TAG_TICK);
    }

    fn on_msg(&mut self, _ctx: &mut Ctx, _from: ProcId, msg: Msg) {
        match msg {
            Msg::Adapt(AdaptMsg::Ack { epoch, client }) => {
                if let Some(a) = self.acked.get_mut(client as usize) {
                    *a = (*a).max(epoch);
                }
            }
            Msg::Adapt(AdaptMsg::Report { ops, timeouts, mut lat, .. }) => {
                self.cur_ops += ops;
                self.cur_timeouts += timeouts;
                self.cur_lat.append(&mut lat);
            }
            Msg::Adapt(AdaptMsg::ViolationSeen { detection_ms }) => {
                self.cur_violations += 1;
                self.cur_detect_ms_sum += detection_ms.max(0.0);
                self.cur_detect_n += 1;
            }
            Msg::Adapt(AdaptMsg::RecoveryDone { stall_ms }) => {
                self.cur_stall_ms += stall_ms.max(0.0);
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, tag: u64) {
        if tag != TAG_TICK {
            return;
        }
        let sample = self.close_window();
        if let Some(tr) = &self.trace {
            tr.borrow_mut().record(
                ctx.self_id,
                ctx.now(),
                ctx.event_seq(),
                TraceEv::AdaptWindow {
                    ops: sample.ops,
                    timeouts: sample.timeouts,
                    violations: sample.violations,
                    stall_ms: sample.stall_ms as u64,
                    lat_p99_ms: sample.lat_p99_ms,
                    detect_ms_sum: sample.detect_ms_sum,
                    detect_n: sample.detect_n,
                    span_ms: sample.span_ms as u64,
                },
            );
        }
        self.win.push(sample);
        let stats = self.win.stats();
        let decision = self.policy.decide(&stats, self.mode);
        if decision != self.mode {
            let from = self.mode_cfg(self.mode).model_name();
            let to = self.mode_cfg(decision).model_name();
            self.mode = decision;
            self.epoch += 1;
            self.switches += 1;
            self.timeline.push(ModeSpan {
                from: ctx.now(),
                epoch: self.epoch,
                cfg: self.mode_cfg(decision),
            });
            if let Some(tr) = &self.trace {
                tr.borrow_mut().record(
                    ctx.self_id,
                    ctx.now(),
                    ctx.event_seq(),
                    TraceEv::ModeSwitch { epoch: self.epoch, from, to },
                );
            }
            self.push_recovery_policy(ctx);
        }
        self.announce_unacked(ctx);
        ctx.schedule(self.window, TAG_TICK);
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(from: Time, epoch: u64, seq: bool) -> ModeSpan {
        ModeSpan {
            from,
            epoch,
            cfg: if seq { ConsistencyCfg::n3r2w2() } else { ConsistencyCfg::n3r1w1() },
        }
    }

    #[test]
    fn round_trip_counting() {
        assert_eq!(round_trips(&[]), 0);
        assert_eq!(round_trips(&[span(0, 0, false)]), 0);
        assert_eq!(round_trips(&[span(0, 0, false), span(1, 1, true)]), 0, "no return yet");
        assert_eq!(
            round_trips(&[span(0, 0, false), span(1, 1, true), span(2, 2, false)]),
            1
        );
        assert_eq!(
            round_trips(&[
                span(0, 0, false),
                span(1, 1, true),
                span(2, 2, false),
                span(3, 3, true),
                span(4, 4, false),
            ]),
            2
        );
        // starting sequential: the first drop to eventual is not a round trip
        assert_eq!(round_trips(&[span(0, 0, true), span(1, 1, false)]), 0);
    }

    #[test]
    fn mode_span_labels() {
        assert_eq!(span(0, 0, true).label(), "sequential");
        assert_eq!(span(0, 0, false).label(), "eventual");
    }
}
