//! Adaptive consistency: a runtime controller that moves the whole
//! cluster between *eventual* and *sequential* quorum configurations —
//! optionally through a middle *causal* rung (the eventual quorum with
//! client-side session guarantees, [`AdaptCfg::hysteresis3`]), with a
//! per-mode recovery-strategy matrix pushed to the rollback controller
//! on every switch ([`AdaptCfg::with_recovery_matrix`]).
//!
//! The paper's benefit claim — optimistic execution beats sequential
//! consistency by 50–80% — holds **when violations are rare and
//! rollbacks cheap**. The journal version (Nguyen et al., 1909.01980)
//! observes that under contention or bad networks the rollback cost can
//! erase the benefit, and PCAP (Rahman et al., 1509.02464) shows the
//! consistency/latency knob can be turned adaptively at runtime. This
//! module closes that loop:
//!
//! * [`signals`] — sliding windows over live signals the system already
//!   produces: violation notifications and rollback stall time (pushed by
//!   the rollback controller), client op-latency percentiles and
//!   quorum-timeout counts (polled from the shared metrics hub).
//! * [`policy`] — a pluggable [`policy::Policy`] deciding the target
//!   [`policy::Mode`] per window. [`policy::HysteresisPolicy`] trips to
//!   sequential when any armed signal crosses its high threshold and
//!   returns to eventual only after `hold_windows` consecutive calm
//!   windows below the low thresholds; [`policy::StaticPolicy`] never
//!   moves (and, being the default, is not even deployed — see below).
//! * [`controller`] — the [`controller::AdaptController`] actor driving
//!   the **epoch-based reconfiguration protocol**: on a mode change it
//!   bumps the consistency epoch and announces the new quorum config to
//!   every client ([`crate::sim::msg::AdaptMsg`]); clients finish
//!   in-flight [`crate::client::quorum::QuorumCall`]s under their issue
//!   epoch and open new calls under the announced one. Announces are
//!   re-sent each window until acked, so clients cut off by a partition
//!   converge after heal. N is pinned across modes — only R/W move — so
//!   the placement ring never changes.
//!
//! **Inertness discipline** (same as `pipeline_depth = 1` and
//! `FaultPlan::none()`): with [`AdaptCfg::static_default`] — the
//! [`crate::exp::config::ExpConfig`] default — the runner deploys *no*
//! adapt actor, no signal messages flow, and every run is bit-identical
//! to the pre-adapt code path (regression-pinned in
//! `rust/tests/adaptive_e2e.rs`).

pub mod controller;
pub mod policy;
pub mod signals;

use crate::client::consistency::ConsistencyCfg;
use crate::rollback::recovery::RecoveryPolicy;
use crate::sim::{Time, SEC};

pub use controller::{round_trips, AdaptController, ModeSpan};
pub use policy::{HysteresisCfg, Mode, Policy, PolicyKind};
pub use signals::{SignalWindow, WinSample, WindowStats};

/// Experiment-level adaptive-consistency configuration, carried by
/// [`crate::exp::config::ExpConfig::adapt`].
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptCfg {
    pub policy: PolicyKind,
    /// the quorum config of [`Mode::Eventual`]
    pub eventual: ConsistencyCfg,
    /// the quorum config of [`Mode::Causal`] — the middle rung of the
    /// three-level ladder: an eventual-shaped quorum with the
    /// session-guarantee flag set. `None` (the default) keeps the
    /// controller binary, exactly the pre-ladder behavior.
    pub causal: Option<ConsistencyCfg>,
    /// the quorum config of [`Mode::Sequential`]
    pub sequential: ConsistencyCfg,
    /// per-mode recovery strategies, indexed by [`Mode::rung`]: on every
    /// mode switch the adapt controller pushes the new mode's policy to
    /// the rollback controller ([`crate::sim::msg::AdaptMsg::SetRecovery`],
    /// applied between recoveries, never mid-phase). `None` (the
    /// default) sends nothing — the rollback controller keeps the
    /// experiment's static [`crate::exp::config::ExpConfig::recovery`].
    pub recovery_by_mode: Option<[RecoveryPolicy; 3]>,
    /// signal-window length (virtual time)
    pub window: Time,
    /// sliding windows aggregated per decision
    pub windows_kept: usize,
}

impl AdaptCfg {
    /// The inert default: a static policy, so the runner deploys no
    /// controller at all and existing runs reproduce bit-identically.
    pub fn static_default() -> Self {
        Self {
            policy: PolicyKind::Static,
            eventual: ConsistencyCfg::n3r1w1(),
            causal: None,
            sequential: ConsistencyCfg::n3r2w2(),
            recovery_by_mode: None,
            window: SEC,
            windows_kept: 3,
        }
    }

    /// An active hysteresis controller between the two given configs.
    pub fn hysteresis(
        h: HysteresisCfg,
        eventual: ConsistencyCfg,
        sequential: ConsistencyCfg,
    ) -> Self {
        Self {
            policy: PolicyKind::Hysteresis(h),
            eventual,
            causal: None,
            sequential,
            recovery_by_mode: None,
            window: SEC,
            windows_kept: 3,
        }
    }

    /// The three-level escalation ladder: eventual ↔ causal ↔
    /// sequential, one rung per decision.
    pub fn hysteresis3(
        h: HysteresisCfg,
        eventual: ConsistencyCfg,
        causal: ConsistencyCfg,
        sequential: ConsistencyCfg,
    ) -> Self {
        Self {
            policy: PolicyKind::Hysteresis3(h),
            eventual,
            causal: Some(causal),
            sequential,
            recovery_by_mode: None,
            window: SEC,
            windows_kept: 3,
        }
    }

    /// Attach a per-mode recovery-strategy matrix (indexed by
    /// [`Mode::rung`]: eventual, causal, sequential).
    pub fn with_recovery_matrix(mut self, by_mode: [RecoveryPolicy; 3]) -> Self {
        self.recovery_by_mode = Some(by_mode);
        self
    }

    /// Does this config deploy a live controller?
    pub fn enabled(&self) -> bool {
        !matches!(self.policy, PolicyKind::Static)
    }

    /// Shape-check against the experiment's starting consistency. Only
    /// meaningful when [`Self::enabled`]; a static config is always fine.
    pub fn validate(&self, starting: ConsistencyCfg) -> Result<(), String> {
        if !self.enabled() {
            return Ok(());
        }
        if !self.eventual.is_eventual() {
            return Err(format!("{} is not an eventual config", self.eventual.label()));
        }
        if !self.sequential.is_sequential() {
            return Err(format!("{} is not a sequential config", self.sequential.label()));
        }
        if self.eventual.n != self.sequential.n {
            return Err(format!(
                "modes must share N (ring is fixed): {} vs {}",
                self.eventual.label(),
                self.sequential.label()
            ));
        }
        if matches!(self.policy, PolicyKind::Hysteresis3(_)) != self.causal.is_some() {
            return Err("the causal mode config and the Hysteresis3 policy go together".into());
        }
        if let Some(c) = self.causal {
            // the middle rung is the eventual quorum math with session
            // guarantees layered on — anything stronger would invert the
            // ladder's cost ordering
            if c.model_name() != "causal" {
                return Err(format!(
                    "{} is not a causal config (eventual quorum + session guarantees)",
                    c.label()
                ));
            }
            if c.n != self.eventual.n {
                return Err(format!(
                    "modes must share N (ring is fixed): {} vs {}",
                    c.label(),
                    self.eventual.label()
                ));
            }
        }
        if starting != self.eventual
            && starting != self.sequential
            && Some(starting) != self.causal
        {
            return Err(format!(
                "starting consistency {} is not one of the modes ({} / {} / {})",
                starting.label(),
                self.eventual.label(),
                self.causal.map(|c| c.label()).unwrap_or_else(|| "-".into()),
                self.sequential.label()
            ));
        }
        if self.window == 0 || self.windows_kept == 0 {
            return Err("signal window and windows_kept must be positive".into());
        }
        if let PolicyKind::Hysteresis(h) | PolicyKind::Hysteresis3(h) = &self.policy {
            // every pair must satisfy lo <= hi or hysteresis inverts into
            // an oscillator: a signal sitting between the bounds would be
            // simultaneously "hot" (escalate) and "calm" (release) and
            // the controller would flap every hold_windows + 1 ticks.
            // This also catches the half-armed trap of setting only a hi
            // bound on a disarmed (inf, inf) pair.
            for (name, hi, lo) in [
                ("viol_per_kop", h.viol_per_kop_hi, h.viol_per_kop_lo),
                ("timeouts_per_sec", h.timeouts_per_sec_hi, h.timeouts_per_sec_lo),
                ("stall_frac", h.stall_frac_hi, h.stall_frac_lo),
                ("lat_p99_ms", h.lat_p99_ms_hi, h.lat_p99_ms_lo),
                ("detect_ms", h.detect_ms_hi, h.detect_ms_lo),
            ] {
                if lo > hi || lo.is_nan() || hi.is_nan() {
                    return Err(format!(
                        "{name} thresholds must satisfy lo <= hi (got lo {lo}, hi {hi})"
                    ));
                }
            }
            if h.hold_windows == 0 {
                return Err("hold_windows must be at least 1".into());
            }
        }
        Ok(())
    }
}

impl Default for AdaptCfg {
    fn default() -> Self {
        Self::static_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_default_is_inert() {
        let a = AdaptCfg::static_default();
        assert!(!a.enabled());
        assert_eq!(a, AdaptCfg::default());
        // static configs validate against anything
        assert!(a.validate(ConsistencyCfg::n5r1w5()).is_ok());
    }

    #[test]
    fn hysteresis_validates_mode_shapes() {
        let ok = AdaptCfg::hysteresis(
            HysteresisCfg::default(),
            ConsistencyCfg::new(3, 1, 2),
            ConsistencyCfg::n3r2w2(),
        );
        assert!(ok.enabled());
        assert!(ok.validate(ConsistencyCfg::new(3, 1, 2)).is_ok());
        assert!(ok.validate(ConsistencyCfg::n3r2w2()).is_ok(), "may start sequential");
        assert!(
            ok.validate(ConsistencyCfg::n3r1w1()).is_err(),
            "starting config must be one of the two modes"
        );

        let swapped = AdaptCfg::hysteresis(
            HysteresisCfg::default(),
            ConsistencyCfg::n3r2w2(),
            ConsistencyCfg::n3r1w1(),
        );
        assert!(swapped.validate(ConsistencyCfg::n3r2w2()).is_err());

        let n_mismatch = AdaptCfg::hysteresis(
            HysteresisCfg::default(),
            ConsistencyCfg::n3r1w1(),
            ConsistencyCfg::n5r3w3(),
        );
        assert!(n_mismatch.validate(ConsistencyCfg::n3r1w1()).is_err());
    }

    #[test]
    fn hysteresis_validates_threshold_coherence() {
        let start = ConsistencyCfg::n3r1w1();
        let modes = (ConsistencyCfg::n3r1w1(), ConsistencyCfg::n3r2w2());

        // inverted pair: lo above hi would make the policy oscillate
        let inverted = HysteresisCfg {
            timeouts_per_sec_hi: 0.5,
            timeouts_per_sec_lo: 2.0,
            ..HysteresisCfg::default()
        };
        let cfg = AdaptCfg::hysteresis(inverted, modes.0, modes.1);
        assert!(cfg.validate(start).is_err());

        // half-armed trap: hi set on a disarmed (inf, inf) pair leaves
        // lo = inf > hi
        let half = HysteresisCfg { stall_frac_hi: 0.2, ..HysteresisCfg::disarmed() };
        let cfg = AdaptCfg::hysteresis(half, modes.0, modes.1);
        assert!(cfg.validate(start).is_err());

        // a zero hold would release on the first calm window
        let zero_hold = HysteresisCfg { hold_windows: 0, ..HysteresisCfg::default() };
        let cfg = AdaptCfg::hysteresis(zero_hold, modes.0, modes.1);
        assert!(cfg.validate(start).is_err());

        // fully-armed and fully-disarmed defaults both pass
        let cfg = AdaptCfg::hysteresis(HysteresisCfg::default(), modes.0, modes.1);
        assert!(cfg.validate(start).is_ok());
        let cfg = AdaptCfg::hysteresis(HysteresisCfg::disarmed(), modes.0, modes.1);
        assert!(cfg.validate(start).is_ok());
    }

    #[test]
    fn hysteresis3_validates_the_causal_rung() {
        let eventual = ConsistencyCfg::n3r1w1();
        let causal = eventual.with_causal();
        let sequential = ConsistencyCfg::n3r2w2();

        let ok = AdaptCfg::hysteresis3(HysteresisCfg::default(), eventual, causal, sequential);
        assert!(ok.enabled());
        assert!(ok.validate(eventual).is_ok());
        assert!(ok.validate(causal).is_ok(), "may start on the middle rung");
        assert!(ok.validate(sequential).is_ok());
        assert!(ok.validate(ConsistencyCfg::new(3, 1, 2)).is_err(), "not a mode");

        // the middle rung must actually be causal: a bare eventual
        // config or a sequential one both fail the shape check
        let bare =
            AdaptCfg::hysteresis3(HysteresisCfg::default(), eventual, eventual, sequential);
        assert!(bare.validate(eventual).is_err());
        let strong = AdaptCfg::hysteresis3(
            HysteresisCfg::default(),
            eventual,
            sequential.with_causal(),
            sequential,
        );
        assert!(strong.validate(eventual).is_err());

        // N is pinned across all three rungs
        let n_mismatch = AdaptCfg::hysteresis3(
            HysteresisCfg::default(),
            eventual,
            ConsistencyCfg::n5r1w1().with_causal(),
            sequential,
        );
        assert!(n_mismatch.validate(eventual).is_err());

        // a binary policy carrying a causal config (or a ladder missing
        // one) is incoherent
        let mut orphan = AdaptCfg::hysteresis(HysteresisCfg::default(), eventual, sequential);
        orphan.causal = Some(causal);
        assert!(orphan.validate(eventual).is_err());
        let mut missing =
            AdaptCfg::hysteresis3(HysteresisCfg::default(), eventual, causal, sequential);
        missing.causal = None;
        assert!(missing.validate(eventual).is_err());
    }

    #[test]
    fn recovery_matrix_rides_along_and_compares() {
        use crate::rollback::recovery::RecoveryPolicy;
        let cfg = AdaptCfg::hysteresis3(
            HysteresisCfg::default(),
            ConsistencyCfg::n3r1w1(),
            ConsistencyCfg::n3r1w1().with_causal(),
            ConsistencyCfg::n3r2w2(),
        )
        .with_recovery_matrix([
            RecoveryPolicy::FullRestore,
            RecoveryPolicy::ResetToClean,
            RecoveryPolicy::Stabilize,
        ]);
        assert!(cfg.validate(ConsistencyCfg::n3r1w1()).is_ok());
        assert_eq!(
            cfg.recovery_by_mode.unwrap()[Mode::Causal.rung()],
            RecoveryPolicy::ResetToClean
        );
        assert_ne!(cfg, cfg.clone().with_recovery_matrix([RecoveryPolicy::None; 3]));
    }
}
