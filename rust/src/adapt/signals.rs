//! Sliding-window aggregation of the live signals the adapt controller
//! ingests. Nothing here is new instrumentation: violations and rollback
//! stalls are pushed by the rollback controller
//! ([`crate::sim::msg::AdaptMsg`]), op counts / quorum timeouts / op
//! latencies already live in the shared
//! [`crate::metrics::throughput::MetricsHub`] and are polled as deltas
//! once per window tick.

use std::collections::VecDeque;

/// One closed signal window.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WinSample {
    /// successful app ops completed in the window
    pub ops: u64,
    /// quorum rounds that expired client-side in the window
    pub timeouts: u64,
    /// violation reports forwarded by the rollback controller
    pub violations: u64,
    /// total server-freeze time of recoveries that *finished* in the
    /// window (ms)
    pub stall_ms: f64,
    /// p99 of the op-latency samples recorded in the window (ms; 0 when
    /// no sample landed)
    pub lat_p99_ms: f64,
    /// sum / count of detection-latency samples (ms)
    pub detect_ms_sum: f64,
    pub detect_n: u64,
    /// window length (ms of virtual time)
    pub span_ms: f64,
}

/// The last `keep` windows, aggregated for the policy.
#[derive(Debug)]
pub struct SignalWindow {
    keep: usize,
    samples: VecDeque<WinSample>,
}

impl SignalWindow {
    pub fn new(keep: usize) -> Self {
        assert!(keep >= 1, "must keep at least one window");
        Self { keep, samples: VecDeque::with_capacity(keep + 1) }
    }

    pub fn push(&mut self, s: WinSample) {
        self.samples.push_back(s);
        while self.samples.len() > self.keep {
            self.samples.pop_front();
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Aggregate stats over the kept windows.
    pub fn stats(&self) -> WindowStats {
        let mut w = WindowStats::default();
        for s in &self.samples {
            w.ops += s.ops;
            w.timeouts += s.timeouts;
            w.violations += s.violations;
            w.stall_ms += s.stall_ms;
            w.detect_ms_sum += s.detect_ms_sum;
            w.detect_n += s.detect_n;
            w.span_ms += s.span_ms;
            // the freshest non-empty latency estimate wins: an idle tail
            // window must not erase a hot percentile mid-decision
            if s.lat_p99_ms > 0.0 {
                w.lat_p99_ms = s.lat_p99_ms;
            }
        }
        w
    }
}

/// What a [`crate::adapt::policy::Policy`] decides on.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WindowStats {
    pub ops: u64,
    pub timeouts: u64,
    pub violations: u64,
    pub stall_ms: f64,
    pub lat_p99_ms: f64,
    pub detect_ms_sum: f64,
    pub detect_n: u64,
    pub span_ms: f64,
}

impl WindowStats {
    /// Violations per 1000 successful ops (the paper's "violations are
    /// rare" premise, normalized by offered load).
    pub fn viol_per_kop(&self) -> f64 {
        self.violations as f64 * 1_000.0 / self.ops.max(1) as f64
    }

    /// Expired quorum rounds per second of virtual time.
    pub fn timeouts_per_sec(&self) -> f64 {
        if self.span_ms <= 0.0 {
            return 0.0;
        }
        self.timeouts as f64 * 1_000.0 / self.span_ms
    }

    /// Fraction of the window the cluster sat frozen for rollback.
    pub fn stall_frac(&self) -> f64 {
        if self.span_ms <= 0.0 {
            return 0.0;
        }
        (self.stall_ms / self.span_ms).min(1.0)
    }

    /// Mean detection latency of the window's violation samples (ms).
    pub fn detect_mean_ms(&self) -> f64 {
        if self.detect_n == 0 {
            return 0.0;
        }
        self.detect_ms_sum / self.detect_n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(ops: u64, timeouts: u64, violations: u64, stall_ms: f64) -> WinSample {
        WinSample { ops, timeouts, violations, stall_ms, span_ms: 1_000.0, ..WinSample::default() }
    }

    #[test]
    fn window_evicts_oldest() {
        let mut w = SignalWindow::new(3);
        for i in 0..5u64 {
            w.push(sample(i, 0, 0, 0.0));
        }
        assert_eq!(w.len(), 3);
        // kept windows are the last three: ops 2 + 3 + 4
        assert_eq!(w.stats().ops, 9);
        assert_eq!(w.stats().span_ms, 3_000.0);
    }

    #[test]
    fn rates_normalize_by_span_and_load() {
        let mut w = SignalWindow::new(4);
        w.push(sample(500, 3, 1, 100.0));
        w.push(sample(500, 1, 1, 150.0));
        let s = w.stats();
        assert_eq!(s.viol_per_kop(), 2.0, "2 violations per 1000 ops");
        assert_eq!(s.timeouts_per_sec(), 2.0, "4 timeouts over 2 s");
        assert!((s.stall_frac() - 0.125).abs() < 1e-12, "250 ms frozen of 2 s");
    }

    #[test]
    fn empty_and_zero_guards() {
        let w = SignalWindow::new(2);
        assert!(w.is_empty());
        let s = w.stats();
        assert_eq!(s.viol_per_kop(), 0.0);
        assert_eq!(s.timeouts_per_sec(), 0.0);
        assert_eq!(s.stall_frac(), 0.0);
        assert_eq!(s.detect_mean_ms(), 0.0);
    }

    #[test]
    fn latest_nonzero_latency_wins() {
        let mut w = SignalWindow::new(3);
        w.push(WinSample { lat_p99_ms: 40.0, span_ms: 1_000.0, ..WinSample::default() });
        w.push(WinSample { lat_p99_ms: 90.0, span_ms: 1_000.0, ..WinSample::default() });
        w.push(WinSample { lat_p99_ms: 0.0, span_ms: 1_000.0, ..WinSample::default() });
        assert_eq!(w.stats().lat_p99_ms, 90.0, "idle window must not erase the estimate");
    }

    #[test]
    fn detection_mean() {
        let mut w = SignalWindow::new(2);
        w.push(WinSample {
            detect_ms_sum: 30.0,
            detect_n: 2,
            span_ms: 1_000.0,
            ..WinSample::default()
        });
        w.push(WinSample {
            detect_ms_sum: 10.0,
            detect_n: 2,
            span_ms: 1_000.0,
            ..WinSample::default()
        });
        assert_eq!(w.stats().detect_mean_ms(), 10.0);
    }
}
