//! Values, interned keys, and versioned (vector-clock stamped) entries —
//! the data model of the Dynamo/Voldemort-style store: a key maps to a
//! *list* of `<version, value>` pairs; concurrent PUTs leave sibling
//! versions which clients resolve.

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;

use crate::clock::vc::{Causality, VectorClock};

/// Interned key id. Variable names like `flagA_B_A` are interned once per
/// simulation; the hot path moves u32s, not strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KeyId(pub u32);

/// String interner shared by all actors of one simulation (single-threaded
/// DES ⇒ `Rc<RefCell<…>>`).
#[derive(Debug, Default)]
pub struct Interner {
    map: HashMap<Box<str>, u32>,
    names: Vec<Box<str>>,
}

impl Interner {
    pub fn new() -> Rc<RefCell<Interner>> {
        Rc::new(RefCell::new(Interner::default()))
    }

    pub fn intern(&mut self, name: &str) -> KeyId {
        if let Some(&id) = self.map.get(name) {
            return KeyId(id);
        }
        let id = self.names.len() as u32;
        let boxed: Box<str> = name.into();
        self.names.push(boxed.clone());
        self.map.insert(boxed, id);
        KeyId(id)
    }

    pub fn lookup(&self, name: &str) -> Option<KeyId> {
        self.map.get(name).map(|&id| KeyId(id))
    }

    pub fn name(&self, key: KeyId) -> &str {
        &self.names[key.0 as usize]
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

/// Stored values. Small enum — the paper's applications store flags,
/// turn-owners, colors and sensor scalars.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Str(Box<str>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            Value::Int(i) => Some(*i != 0),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Bool(b) => Some(*b as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Parse from predicate-spec text: `true`/`false`, integer, else string.
    pub fn parse(text: &str) -> Value {
        match text {
            "true" => Value::Bool(true),
            "false" => Value::Bool(false),
            "null" => Value::Null,
            _ => text
                .parse::<i64>()
                .map(Value::Int)
                .unwrap_or_else(|_| Value::Str(text.into())),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Str(s) => write!(f, "{s}"),
        }
    }
}

/// A `<version, value>` pair as stored and as returned by GET.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Versioned {
    pub version: VectorClock,
    pub value: Value,
}

impl Versioned {
    pub fn new(version: VectorClock, value: Value) -> Self {
        Self { version, value }
    }
}

/// Insert a new version into a sibling list with Dynamo semantics:
/// versions dominated by the newcomer are dropped; if the newcomer is
/// dominated it is ignored; otherwise it joins as a concurrent sibling.
/// Returns true if the list changed.
pub fn insert_version(siblings: &mut Vec<Versioned>, new: Versioned) -> bool {
    for s in siblings.iter() {
        match s.version.compare(&new.version) {
            Causality::After | Causality::Equal => return false, // dominated / duplicate
            _ => {}
        }
    }
    siblings.retain(|s| s.version.compare(&new.version) != Causality::Before);
    siblings.push(new);
    true
}

/// Merge sibling lists coming from several replicas (a client-side GET
/// combining R responses): union with domination pruning.
pub fn merge_siblings(lists: impl IntoIterator<Item = Vec<Versioned>>) -> Vec<Versioned> {
    let mut out: Vec<Versioned> = Vec::new();
    for list in lists {
        for v in list {
            insert_version(&mut out, v);
        }
    }
    out
}

/// Default client-side resolver (Voldemort offers a library resolver):
/// pick the sibling with the causally greatest version; among concurrent
/// siblings, break ties deterministically by the version's entry list (so
/// every client resolves identically). Returns None on empty input.
pub fn resolve(siblings: &[Versioned]) -> Option<&Versioned> {
    siblings.iter().reduce(|best, v| match v.version.compare(&best.version) {
        Causality::After => v,
        Causality::Concurrent => {
            // deterministic tiebreak: lexicographically larger entry vector
            if v.version.entries() > best.version.entries() {
                v
            } else {
                best
            }
        }
        _ => best,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn vc(pairs: &[(u32, u64)]) -> VectorClock {
        let mut v = VectorClock::new();
        for &(n, c) in pairs {
            for _ in 0..c {
                v.increment(n);
            }
        }
        v
    }

    #[test]
    fn interner_roundtrip() {
        let i = Interner::new();
        let a = i.borrow_mut().intern("flagA_B_A");
        let b = i.borrow_mut().intern("turnA_B");
        let a2 = i.borrow_mut().intern("flagA_B_A");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(i.borrow().name(a), "flagA_B_A");
        assert_eq!(i.borrow().lookup("turnA_B"), Some(b));
        assert_eq!(i.borrow().lookup("nope"), None);
    }

    #[test]
    fn value_parse() {
        assert_eq!(Value::parse("true"), Value::Bool(true));
        assert_eq!(Value::parse("42"), Value::Int(42));
        assert_eq!(Value::parse("A"), Value::Str("A".into()));
        assert_eq!(Value::parse("null"), Value::Null);
        assert_eq!(Value::Int(1).as_bool(), Some(true));
    }

    #[test]
    fn insert_dominating_version_replaces() {
        let mut sibs = vec![Versioned::new(vc(&[(1, 1)]), Value::Int(1))];
        let newer = Versioned::new(vc(&[(1, 2)]), Value::Int(2));
        assert!(insert_version(&mut sibs, newer));
        assert_eq!(sibs.len(), 1);
        assert_eq!(sibs[0].value, Value::Int(2));
    }

    #[test]
    fn insert_dominated_version_ignored() {
        let mut sibs = vec![Versioned::new(vc(&[(1, 2)]), Value::Int(2))];
        let older = Versioned::new(vc(&[(1, 1)]), Value::Int(1));
        assert!(!insert_version(&mut sibs, older));
        assert_eq!(sibs.len(), 1);
    }

    #[test]
    fn concurrent_versions_coexist() {
        let mut sibs = vec![Versioned::new(vc(&[(1, 1)]), Value::Str("A".into()))];
        let other = Versioned::new(vc(&[(2, 1)]), Value::Str("B".into()));
        assert!(insert_version(&mut sibs, other));
        assert_eq!(sibs.len(), 2, "concurrent writes must create siblings");
    }

    #[test]
    fn merge_from_replicas() {
        let l1 = vec![Versioned::new(vc(&[(1, 1)]), Value::Int(1))];
        let l2 = vec![
            Versioned::new(vc(&[(1, 2)]), Value::Int(2)),
            Versioned::new(vc(&[(2, 1)]), Value::Int(9)),
        ];
        let merged = merge_siblings([l1, l2]);
        assert_eq!(merged.len(), 2);
        assert!(merged.iter().any(|v| v.value == Value::Int(2)));
        assert!(merged.iter().any(|v| v.value == Value::Int(9)));
    }

    #[test]
    fn resolver_picks_dominant_then_tiebreaks() {
        let a = Versioned::new(vc(&[(1, 2)]), Value::Int(10));
        let b = Versioned::new(vc(&[(1, 1)]), Value::Int(5));
        assert_eq!(resolve(&[b.clone(), a.clone()]).unwrap().value, Value::Int(10));
        // concurrent: deterministic, order-independent
        let c = Versioned::new(vc(&[(2, 1)]), Value::Int(7));
        let r1 = resolve(&[a.clone(), c.clone()]).unwrap().value.clone();
        let r2 = resolve(&[c, a]).unwrap().value.clone();
        assert_eq!(r1, r2);
        assert_eq!(resolve(&[]), None);
    }

    #[test]
    fn prop_sibling_list_is_antichain() {
        prop::check_default("siblings_antichain", |rng| {
            let mut sibs: Vec<Versioned> = Vec::new();
            for i in 0..rng.range(1, 20) {
                let mut v = VectorClock::new();
                for _ in 0..rng.range(0, 4) {
                    v.increment(rng.below(4) as u32);
                }
                insert_version(&mut sibs, Versioned::new(v, Value::Int(i as i64)));
            }
            for (i, a) in sibs.iter().enumerate() {
                for b in sibs.iter().skip(i + 1) {
                    if a.version.compare(&b.version) != Causality::Concurrent {
                        return Err(format!("non-concurrent siblings: {a:?} {b:?}"));
                    }
                }
            }
            Ok(())
        });
    }
}
