//! A server's storage engine: `key → sibling versions`, with Dynamo
//! insert semantics. Kept separate from the server actor so snapshots and
//! the window-log can manipulate it directly.

use std::collections::HashMap;

use crate::clock::vc::VectorClock;
use crate::store::value::{insert_version, KeyId, Value, Versioned};

#[derive(Debug, Clone, Default)]
pub struct Table {
    map: HashMap<KeyId, Vec<Versioned>>,
    puts_applied: u64,
}

impl Table {
    pub fn new() -> Self {
        Self::default()
    }

    /// All sibling versions of a key (empty slice if absent).
    pub fn get(&self, key: KeyId) -> &[Versioned] {
        self.map.get(&key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Version clocks only (GET_VERSION).
    pub fn versions(&self, key: KeyId) -> Vec<VectorClock> {
        self.get(key).iter().map(|v| v.version.clone()).collect()
    }

    /// Resolved single value (server-side convenience for detectors): all
    /// sibling values.
    pub fn sibling_values(&self, key: KeyId) -> Vec<Value> {
        self.get(key).iter().map(|v| v.value.clone()).collect()
    }

    /// Apply a PUT. Returns the previous sibling list (for the window log)
    /// and whether the table changed.
    pub fn put(&mut self, key: KeyId, version: VectorClock, value: Value) -> (Vec<Versioned>, bool) {
        let entry = self.map.entry(key).or_default();
        let prev = entry.clone();
        let changed = insert_version(entry, Versioned::new(version, value));
        if changed {
            self.puts_applied += 1;
        }
        (prev, changed)
    }

    /// Overwrite a key's entire sibling list (window-log rollback).
    pub fn restore_key(&mut self, key: KeyId, siblings: Vec<Versioned>) {
        if siblings.is_empty() {
            self.map.remove(&key);
        } else {
            self.map.insert(key, siblings);
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn puts_applied(&self) -> u64 {
        self.puts_applied
    }

    /// Deep snapshot of the whole table (periodic checkpoints).
    pub fn snapshot(&self) -> HashMap<KeyId, Vec<Versioned>> {
        self.map.clone()
    }

    /// Replace contents from a snapshot.
    pub fn restore_snapshot(&mut self, snap: HashMap<KeyId, Vec<Versioned>>) {
        self.map = snap;
    }

    pub fn iter(&self) -> impl Iterator<Item = (&KeyId, &Vec<Versioned>)> {
        self.map.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vc(node: u32, n: u64) -> VectorClock {
        let mut v = VectorClock::new();
        for _ in 0..n {
            v.increment(node);
        }
        v
    }

    #[test]
    fn put_get_roundtrip() {
        let mut t = Table::new();
        let k = KeyId(1);
        let (prev, changed) = t.put(k, vc(1, 1), Value::Int(7));
        assert!(prev.is_empty());
        assert!(changed);
        assert_eq!(t.get(k).len(), 1);
        assert_eq!(t.get(k)[0].value, Value::Int(7));
        assert_eq!(t.versions(k), vec![vc(1, 1)]);
    }

    #[test]
    fn concurrent_puts_create_siblings() {
        let mut t = Table::new();
        let k = KeyId(1);
        t.put(k, vc(1, 1), Value::Str("A".into()));
        let (prev, changed) = t.put(k, vc(2, 1), Value::Str("B".into()));
        assert!(changed);
        assert_eq!(prev.len(), 1);
        assert_eq!(t.get(k).len(), 2);
        assert_eq!(t.sibling_values(k).len(), 2);
    }

    #[test]
    fn stale_put_ignored() {
        let mut t = Table::new();
        let k = KeyId(1);
        t.put(k, vc(1, 2), Value::Int(2));
        let (_, changed) = t.put(k, vc(1, 1), Value::Int(1));
        assert!(!changed);
        assert_eq!(t.puts_applied(), 1);
    }

    #[test]
    fn snapshot_restore() {
        let mut t = Table::new();
        t.put(KeyId(1), vc(1, 1), Value::Int(1));
        let snap = t.snapshot();
        t.put(KeyId(1), vc(1, 2), Value::Int(2));
        t.put(KeyId(2), vc(1, 1), Value::Int(9));
        assert_eq!(t.len(), 2);
        t.restore_snapshot(snap);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(KeyId(1))[0].value, Value::Int(1));
    }

    #[test]
    fn restore_key_to_empty_removes() {
        let mut t = Table::new();
        t.put(KeyId(1), vc(1, 1), Value::Int(1));
        t.restore_key(KeyId(1), vec![]);
        assert!(t.is_empty());
    }
}
