//! The client↔server wire operations of the Voldemort-style store.

use crate::clock::vc::VectorClock;
use crate::store::value::{KeyId, Value, Versioned};

/// Operations a client sends to a server. An application-level PUT is
/// translated by the client library into GET_VERSION followed by PUT with
/// an incremented version (§VI-A "Performance Metric and Measurement").
#[derive(Debug, Clone)]
pub enum ServerOp {
    Get(KeyId),
    GetVersion(KeyId),
    Put { key: KeyId, version: VectorClock, value: Value },
}

impl ServerOp {
    pub fn key(&self) -> KeyId {
        match self {
            ServerOp::Get(k) | ServerOp::GetVersion(k) => *k,
            ServerOp::Put { key, .. } => *key,
        }
    }

    pub fn is_put(&self) -> bool {
        matches!(self, ServerOp::Put { .. })
    }
}

/// Server replies.
#[derive(Debug, Clone)]
pub enum ServerReply {
    /// all sibling versions of the key (GET)
    Values(Vec<Versioned>),
    /// just the version clocks (GET_VERSION)
    Versions(Vec<VectorClock>),
    /// write applied (PUT)
    PutAck,
    /// server is frozen for recovery — client treats as a miss
    Frozen,
    /// the key's partition is not replicated on this server — the client
    /// mis-routed (stale ring view); does not count toward any quorum
    WrongServer,
}
