//! The Voldemort-style server actor: serves GET / GET_VERSION / PUT with
//! vector-clock sibling semantics, maintains its HVC, hosts the local
//! predicate detector (PUT interception per Fig. 4/5), the window-log and
//! periodic snapshots for rollback, and honors freeze/restore/resume from
//! the recovery controller.
//!
//! The server is *partition-aware*: it consults the cluster ring
//! ([`crate::store::ring`]) and serves, stores, window-logs and snapshots
//! only the keys whose preference list it belongs to. A mis-routed
//! request is refused with [`ServerReply::WrongServer`] instead of
//! silently widening the key's replica set.

use std::rc::Rc;

use crate::clock::hvc::Hvc;
use crate::detect::local::LocalDetector;
use crate::metrics::throughput::Metrics;
use crate::rollback::snapshot::SnapshotStore;
use crate::rollback::windowlog::WindowLog;
use crate::sim::des::{Actor, Ctx};
use crate::sim::msg::{Msg, RollbackMsg};
use crate::sim::{ProcId, Time, SEC};
use crate::store::protocol::{ServerOp, ServerReply};
use crate::store::ring::Router;
use crate::store::table::Table;

const TAG_SNAPSHOT: u64 = 1;

/// Server cost/behaviour knobs (virtual CPU times; calibrated so the
/// simulated service times sit in the paper's "a few ms per request"
/// envelope and monitoring overhead lands in the reported 1–8% band).
#[derive(Debug, Clone)]
pub struct ServerCfg {
    pub svc_get: Time,
    pub svc_get_version: Time,
    pub svc_put: Time,
    /// detector cost per conjunct check on a relevant PUT
    pub det_check: Time,
    /// detector cost per emitted candidate
    pub det_emit: Time,
    /// periodic snapshot period (0 = disabled)
    pub snapshot_period: Time,
    /// window-log retention (ms of server physical time)
    pub windowlog_ms: i64,
    pub windowlog_max: usize,
    pub snapshots_keep: usize,
}

impl Default for ServerCfg {
    fn default() -> Self {
        Self {
            svc_get: 100 * 1_000,         // 0.10 ms
            svc_get_version: 80 * 1_000,  // 0.08 ms
            svc_put: 150 * 1_000,         // 0.15 ms
            det_check: 4_000,             // 4 µs per conjunct evaluation
            det_emit: 3_000,              // 3 µs per candidate
            snapshot_period: 30 * SEC,
            windowlog_ms: 600_000, // Retroscope's ~10 minutes
            windowlog_max: 2_000_000,
            snapshots_keep: 8,
        }
    }
}

pub struct ServerActor {
    pub idx: u16,
    hvc: Hvc,
    table: Table,
    /// partition ownership (shared ring view)
    router: Rc<Router>,
    detector: Option<LocalDetector>,
    windowlog: WindowLog,
    snapshots: SnapshotStore,
    frozen: Option<u64>,
    cfg: ServerCfg,
    metrics: Metrics,
    controller: Option<ProcId>,
    /// stats
    pub reqs_served: u64,
    pub reqs_refused: u64,
    pub puts_intercepted: u64,
}

impl ServerActor {
    pub fn new(
        idx: u16,
        router: Rc<Router>,
        detector: Option<LocalDetector>,
        cfg: ServerCfg,
        metrics: Metrics,
        controller: Option<ProcId>,
    ) -> Self {
        // the HVC dimension is the cluster size — one entry per server
        let n_servers = router.ring().n_servers();
        assert!((idx as usize) < n_servers, "server index outside the ring");
        Self {
            idx,
            hvc: Hvc::new(idx, n_servers, 0, 0),
            table: Table::new(),
            router,
            detector,
            windowlog: WindowLog::new(cfg.windowlog_ms, cfg.windowlog_max),
            snapshots: SnapshotStore::new(cfg.snapshots_keep),
            frozen: None,
            cfg,
            metrics,
            controller,
            reqs_served: 0,
            reqs_refused: 0,
            puts_intercepted: 0,
        }
    }

    pub fn table(&self) -> &Table {
        &self.table
    }

    fn handle_request(&mut self, ctx: &mut Ctx, from: ProcId, req: u64, op: Rc<ServerOp>, piggy: Option<Hvc>) {
        let pt = ctx.pt_ms();
        let eps = ctx.eps_ms();
        match &piggy {
            Some(h) => self.hvc.recv(h, pt, eps),
            None => self.hvc.tick(pt, eps),
        }

        if self.frozen.is_some() {
            // frozen for recovery: refuse (client treats as a miss)
            ctx.send_after(50 * 1_000, from, Msg::Reply {
                req,
                reply: ServerReply::Frozen,
                hvc: self.hvc.clone(),
            });
            return;
        }

        if !self.router.owns(self.idx, op.key()) {
            // not a replica of this key's partition: refuse so the store
            // never grows beyond the preference list
            self.reqs_refused += 1;
            ctx.send_after(50 * 1_000, from, Msg::Reply {
                req,
                reply: ServerReply::WrongServer,
                hvc: self.hvc.clone(),
            });
            return;
        }

        // inference hook fires on ANY request touching a lock variable
        // this server owns (non-owners never see the key)
        let mut regs = Vec::new();
        if let Some(det) = self.detector.as_mut() {
            regs = det.on_request_key(op.key(), &self.table);
        }

        let mut svc;
        let reply;
        let mut cands = Vec::new();
        match &*op {
            ServerOp::Get(key) => {
                svc = self.cfg.svc_get;
                reply = ServerReply::Values(self.table.get(*key).to_vec());
            }
            ServerOp::GetVersion(key) => {
                svc = self.cfg.svc_get_version;
                reply = ServerReply::Versions(self.table.versions(*key));
            }
            ServerOp::Put { key, version, value } => {
                // the broadcast shares one payload across replicas; clone
                // only here, where this replica applies the write
                let key = *key;
                svc = self.cfg.svc_put;
                let (prev, changed) = self.table.put(key, version.clone(), value.clone());
                if changed {
                    self.windowlog.append(pt, key, prev);
                    if let Some(det) = self.detector.as_mut() {
                        self.puts_intercepted += 1;
                        let out = det.on_put(key, &self.table, &self.hvc, ctx.now());
                        svc += self.cfg.det_check * out.checks as u64
                            + self.cfg.det_emit * out.candidates.len() as u64;
                        cands = out.candidates;
                    }
                }
                reply = ServerReply::PutAck;
            }
        }

        let delay = ctx.cpu_delay(svc);
        self.reqs_served += 1;
        self.metrics.borrow_mut().record_server(self.idx as usize, ctx.now());

        ctx.send_after(delay, from, Msg::Reply { req, reply, hvc: self.hvc.clone() });
        let me = ctx.self_id;
        for (dst, mut c) in cands {
            c.server = me;
            c.emitted_at = ctx.now() + delay;
            ctx.send_after(delay, dst, Msg::Candidate(Box::new(c)));
        }
        for (dst, spec) in regs {
            ctx.send_after(delay, dst, Msg::RegisterPred(Box::new(spec)));
        }
    }

    fn handle_rollback(&mut self, ctx: &mut Ctx, from: ProcId, msg: RollbackMsg) {
        match msg {
            RollbackMsg::Freeze { epoch } => {
                self.frozen = Some(epoch);
                ctx.send(from, Msg::Rollback(RollbackMsg::FrozenAck { epoch }));
            }
            RollbackMsg::Restore { epoch, to_ms } => {
                let from_window_log = if self.windowlog.covers(to_ms) {
                    self.windowlog.rollback(&mut self.table, to_ms);
                    true
                } else {
                    self.snapshots.restore_before(&mut self.table, to_ms);
                    false
                };
                // the detector's cache must reflect rolled-back state
                if let Some(det) = self.detector.as_mut() {
                    det.reseed(&self.table);
                }
                ctx.send(from, Msg::Rollback(RollbackMsg::RestoredAck { epoch, from_window_log }));
            }
            RollbackMsg::Resume { .. } => {
                self.frozen = None;
            }
            _ => {}
        }
    }
}

impl Actor for ServerActor {
    fn on_start(&mut self, ctx: &mut Ctx) {
        if let Some(det) = self.detector.as_mut() {
            det.sync_registry(&self.table);
        }
        if self.cfg.snapshot_period > 0 {
            ctx.schedule(self.cfg.snapshot_period, TAG_SNAPSHOT);
        }
        let _ = self.controller;
    }

    fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg) {
        match msg {
            Msg::Request { req, op, hvc } => self.handle_request(ctx, from, req, op, hvc),
            Msg::Rollback(rb) => self.handle_rollback(ctx, from, rb),
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, tag: u64) {
        if tag == TAG_SNAPSHOT {
            self.snapshots.take(ctx.pt_ms(), &self.table);
            // snapshotting costs CPU proportional to table size
            let cost = 50 * 1_000 + (self.table.len() as u64) * 150;
            ctx.cpu(cost);
            ctx.schedule(self.cfg.snapshot_period, TAG_SNAPSHOT);
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

// unit tests for the server live in rust/tests/store_integration.rs where a
// full Sim can be assembled; the pure pieces (Table, WindowLog, Snapshots,
// LocalDetector) are tested in their own modules.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    #[test]
    fn default_costs_sane() {
        let c = ServerCfg::default();
        assert!(c.svc_get < 5 * MS && c.svc_put < 5 * MS);
        assert!(c.det_check < c.svc_put / 10, "intercept must be cheap vs service");
    }
}
