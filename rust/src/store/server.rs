//! The Voldemort-style server actor: serves GET / GET_VERSION / PUT with
//! vector-clock sibling semantics, maintains its HVC, hosts the local
//! predicate detector (PUT interception per Fig. 4/5), the window-log and
//! periodic snapshots for rollback, and honors freeze/restore/resume from
//! the recovery controller.
//!
//! The server is *partition-aware*: it consults the cluster ring
//! ([`crate::store::ring`]) and serves, stores, window-logs and snapshots
//! only the keys whose preference list it belongs to. A mis-routed
//! request is refused with [`ServerReply::WrongServer`] instead of
//! silently widening the key's replica set.
//!
//! Crash/restart lifecycle ([`crate::faults`]): a [`FaultHook::Crash`]
//! wipes all volatile state (table, window-log, snapshots, HVC) and the
//! server goes dark — in-flight messages and timers are still delivered
//! but ignored. On [`FaultHook::Restart`] it comes back empty, asks every
//! peer for its copies of the keys it owns ([`SyncMsg::Request`]), merges
//! the returned sibling lists under normal vector-clock semantics, and
//! only then serves again (requests during catch-up get the transient
//! [`ServerReply::Frozen`]). A peer that never answers is covered by the
//! `resync_timeout`, after which the server serves with what it has —
//! availability over completeness, the Dynamo trade.

use std::rc::Rc;

use crate::clock::hvc::{Hvc, EPS_INF};
use crate::detect::local::LocalDetector;
use crate::faults::state::FaultHook;
use crate::metrics::throughput::Metrics;
use crate::rollback::snapshot::SnapshotStore;
use crate::rollback::windowlog::WindowLog;
use crate::sim::des::{Actor, Ctx};
use crate::sim::msg::{Msg, RollbackMsg, SyncMsg};
use crate::sim::{ProcId, Time, SEC};
use crate::store::protocol::{ServerOp, ServerReply};
use crate::store::ring::Router;
use crate::store::table::Table;
use crate::store::value::{KeyId, Versioned};
use crate::trace::{TraceEv, TraceRef};

const TAG_SNAPSHOT: u64 = 1;
/// re-sync timeout timers carry the sync epoch in the low bits so a
/// leftover timer from an earlier recovery cannot cut a later one short
/// (mirrors the client's think-timer generation scheme)
const RESYNC_FLAG: u64 = 1 << 62;

/// Server cost/behaviour knobs (virtual CPU times; calibrated so the
/// simulated service times sit in the paper's "a few ms per request"
/// envelope and monitoring overhead lands in the reported 1–8% band).
#[derive(Debug, Clone)]
pub struct ServerCfg {
    pub svc_get: Time,
    pub svc_get_version: Time,
    pub svc_put: Time,
    /// detector cost per conjunct check on a relevant PUT
    pub det_check: Time,
    /// detector cost per emitted candidate
    pub det_emit: Time,
    /// periodic snapshot period (0 = disabled)
    pub snapshot_period: Time,
    /// window-log retention (ms of server physical time)
    pub windowlog_ms: i64,
    pub windowlog_max: usize,
    pub snapshots_keep: usize,
    /// how long a restarting server waits for peer re-sync chunks before
    /// serving with whatever it has recovered
    pub resync_timeout: Time,
}

impl Default for ServerCfg {
    fn default() -> Self {
        Self {
            svc_get: 100 * 1_000,         // 0.10 ms
            svc_get_version: 80 * 1_000,  // 0.08 ms
            svc_put: 150 * 1_000,         // 0.15 ms
            det_check: 4_000,             // 4 µs per conjunct evaluation
            det_emit: 3_000,              // 3 µs per candidate
            snapshot_period: 30 * SEC,
            windowlog_ms: 600_000, // Retroscope's ~10 minutes
            windowlog_max: 2_000_000,
            snapshots_keep: 8,
            resync_timeout: 2 * SEC,
        }
    }
}

pub struct ServerActor {
    pub idx: u16,
    /// the server's clock, shared into replies and candidate intervals
    /// by reference count and mutated copy-on-write (`Rc::make_mut`):
    /// a tick only copies the vector while an in-flight message still
    /// holds the previous snapshot
    hvc: Rc<Hvc>,
    table: Table,
    /// partition ownership (shared ring view)
    router: Rc<Router>,
    detector: Option<LocalDetector>,
    windowlog: WindowLog,
    snapshots: SnapshotStore,
    frozen: Option<u64>,
    cfg: ServerCfg,
    metrics: Metrics,
    controller: Option<ProcId>,
    /// flight recorder handle (`None` = recording off, zero overhead)
    trace: Option<TraceRef>,
    /// actor ids of every server in the cluster (incl. self), for
    /// crash-recovery re-sync
    peers: Vec<ProcId>,
    /// crash/restart lifecycle ([`crate::faults`])
    crashed: bool,
    /// restarted but still catching up from peers
    recovering: bool,
    sync_epoch: u64,
    sync_pending: usize,
    /// a `RollbackMsg::Reset` is being served: ack the controller with
    /// this epoch once the peer re-derivation completes
    pending_reset: Option<u64>,
    /// stats
    pub reqs_served: u64,
    pub reqs_refused: u64,
    pub puts_intercepted: u64,
    pub crashes: u64,
    pub resyncs: u64,
    /// sibling versions merged back during re-syncs
    pub resync_keys: u64,
    /// checkpoint-free resets served ([`RollbackMsg::Reset`])
    pub resets: u64,
}

impl ServerActor {
    pub fn new(
        idx: u16,
        router: Rc<Router>,
        detector: Option<LocalDetector>,
        cfg: ServerCfg,
        metrics: Metrics,
        controller: Option<ProcId>,
        peers: Vec<ProcId>,
    ) -> Self {
        // the HVC dimension is the cluster size — one entry per server
        let n_servers = router.ring().n_servers();
        assert!((idx as usize) < n_servers, "server index outside the ring");
        assert!(
            peers.is_empty() || peers.len() == n_servers,
            "peer table must name every ring server (or be empty to opt out of re-sync)"
        );
        Self {
            idx,
            hvc: Rc::new(Hvc::new(idx, n_servers, 0, 0)),
            table: Table::new(),
            router,
            detector,
            windowlog: WindowLog::new(cfg.windowlog_ms, cfg.windowlog_max),
            snapshots: SnapshotStore::new(cfg.snapshots_keep),
            frozen: None,
            cfg,
            metrics,
            controller,
            trace: None,
            peers,
            crashed: false,
            recovering: false,
            sync_epoch: 0,
            sync_pending: 0,
            pending_reset: None,
            reqs_served: 0,
            reqs_refused: 0,
            puts_intercepted: 0,
            crashes: 0,
            resyncs: 0,
            resync_keys: 0,
            resets: 0,
        }
    }

    /// Attach the flight recorder ([`crate::trace`]).
    pub fn with_trace(mut self, trace: TraceRef) -> Self {
        self.trace = Some(trace);
        self
    }

    pub fn table(&self) -> &Table {
        &self.table
    }

    fn handle_request(&mut self, ctx: &mut Ctx, from: ProcId, req: u64, op: Rc<ServerOp>, piggy: Option<Rc<Hvc>>) {
        let pt = ctx.pt_ms();
        let eps = ctx.eps_ms();
        // copy-on-tick: make_mut clones the clock only if a reply or a
        // candidate interval still references the previous snapshot
        match &piggy {
            Some(h) => Rc::make_mut(&mut self.hvc).recv(h, pt, eps),
            None => Rc::make_mut(&mut self.hvc).tick(pt, eps),
        }

        if self.frozen.is_some() || self.recovering {
            // frozen for rollback, or catching up after a restart:
            // refuse transiently (client treats as a miss)
            ctx.send_after(50 * 1_000, from, Msg::Reply {
                req,
                reply: ServerReply::Frozen,
                hvc: Rc::clone(&self.hvc),
            });
            return;
        }

        if !self.router.owns(self.idx, op.key()) {
            // not a replica of this key's partition: refuse so the store
            // never grows beyond the preference list
            self.reqs_refused += 1;
            ctx.send_after(50 * 1_000, from, Msg::Reply {
                req,
                reply: ServerReply::WrongServer,
                hvc: Rc::clone(&self.hvc),
            });
            return;
        }

        // inference hook fires on ANY request touching a lock variable
        // this server owns (non-owners never see the key)
        let mut regs = Vec::new();
        if let Some(det) = self.detector.as_mut() {
            regs = det.on_request_key(op.key(), &self.table);
        }

        let mut svc;
        let reply;
        let mut cands = Vec::new();
        match &*op {
            ServerOp::Get(key) => {
                svc = self.cfg.svc_get;
                reply = ServerReply::Values(self.table.get(*key).to_vec());
            }
            ServerOp::GetVersion(key) => {
                svc = self.cfg.svc_get_version;
                reply = ServerReply::Versions(self.table.versions(*key));
            }
            ServerOp::Put { key, version, value } => {
                // the broadcast shares one payload across replicas; clone
                // only here, where this replica applies the write
                let key = *key;
                svc = self.cfg.svc_put;
                let (prev, changed) = self.table.put(key, version.clone(), value.clone());
                if changed {
                    self.windowlog.append(pt, key, prev);
                    if let Some(det) = self.detector.as_mut() {
                        self.puts_intercepted += 1;
                        let out = det.on_put(key, &self.table, &self.hvc, ctx.now());
                        svc += self.cfg.det_check * out.checks as u64
                            + self.cfg.det_emit * out.candidates.len() as u64;
                        cands = out.candidates;
                    }
                    if let Some(tr) = &self.trace {
                        let mut tr = tr.borrow_mut();
                        let hvc = if tr.full_payloads() {
                            self.hvc.v.as_slice().to_vec()
                        } else {
                            Vec::new()
                        };
                        tr.record(ctx.self_id, ctx.now(), ctx.event_seq(), TraceEv::ServerApply {
                            server: self.idx,
                            key: key.0,
                            req,
                            client: from.0,
                            pt_ms: pt,
                            hvc,
                        });
                    }
                }
                reply = ServerReply::PutAck;
            }
        }

        let delay = ctx.cpu_delay(svc);
        self.reqs_served += 1;
        self.metrics.borrow_mut().record_server(self.idx as usize, ctx.now());

        ctx.send_after(delay, from, Msg::Reply { req, reply, hvc: Rc::clone(&self.hvc) });
        let me = ctx.self_id;
        for (dst, mut c) in cands {
            c.server = me;
            c.emitted_at = ctx.now() + delay;
            if let Some(tr) = &self.trace {
                let mut tr = tr.borrow_mut();
                let keys = if tr.full_payloads() {
                    c.values.iter().map(|(k, _)| k.0).collect()
                } else {
                    Vec::new()
                };
                tr.record(ctx.self_id, ctx.now(), ctx.event_seq(), TraceEv::CandidateEmit {
                    server: self.idx,
                    pred: c.pred,
                    clause: c.clause,
                    conjunct: c.conjunct,
                    cseq: c.seq,
                    start_ms: c.start_pt_ms(),
                    end_ms: c.end_pt_ms(),
                    keys,
                });
            }
            ctx.send_after(delay, dst, Msg::Candidate(Box::new(c)));
        }
        for (dst, spec) in regs {
            ctx.send_after(delay, dst, Msg::RegisterPred(Box::new(spec)));
        }
        // last replica to process the broadcast returns the payload
        // allocation to the engine's pool for the next ingest
        ctx.recycle_op(op);
    }

    /// Begin catch-up after a restart: ask every peer for its copies of
    /// the keys this server owns, then serve once all chunks arrived (or
    /// the re-sync timeout expired).
    fn begin_resync(&mut self, ctx: &mut Ctx) {
        self.sync_epoch += 1;
        self.recovering = true;
        let me = ctx.self_id;
        let targets: Vec<ProcId> = self.peers.iter().copied().filter(|&p| p != me).collect();
        self.sync_pending = targets.len();
        if targets.is_empty() {
            self.finish_resync(ctx);
            return;
        }
        let epoch = self.sync_epoch;
        let server = self.idx;
        for &p in &targets {
            ctx.send(p, Msg::Sync(Box::new(SyncMsg::Request { epoch, server })));
        }
        ctx.schedule(self.cfg.resync_timeout, RESYNC_FLAG | epoch);
    }

    fn finish_resync(&mut self, ctx: &mut Ctx) {
        self.recovering = false;
        self.resyncs += 1;
        // the detector's cache (and, via reseed, the inferred registry)
        // must reflect the recovered state, exactly as after a rollback
        if let Some(det) = self.detector.as_mut() {
            det.reseed(&self.table);
        }
        // a controller-driven reset acks only once the re-derivation is
        // complete — the ResetToClean strategy's per-server handshake
        if let Some(epoch) = self.pending_reset.take() {
            if let Some(c) = self.controller {
                ctx.send(c, Msg::Rollback(RollbackMsg::ResetAck { epoch }));
            }
        }
    }

    fn handle_sync(&mut self, ctx: &mut Ctx, from: ProcId, msg: SyncMsg) {
        match msg {
            SyncMsg::Request { epoch, server } => {
                if self.recovering {
                    return; // mid-catch-up ourselves: cannot help
                }
                // every key we hold that the restarting server owns,
                // sorted so the merge order is deterministic
                let mut data: Vec<(KeyId, Vec<Versioned>)> = self
                    .table
                    .iter()
                    .filter(|(k, _)| self.router.owns(server, **k))
                    .map(|(k, v)| (*k, v.clone()))
                    .collect();
                data.sort_unstable_by_key(|(k, _)| k.0);
                // reading + serializing the chunk costs CPU like a snapshot
                let cost = 50 * 1_000 + data.len() as u64 * 200;
                let delay = ctx.cpu_delay(cost);
                ctx.send_after(delay, from, Msg::Sync(Box::new(SyncMsg::Chunk { epoch, data })));
            }
            SyncMsg::Chunk { epoch, data } => {
                if epoch != self.sync_epoch {
                    return; // stale chunk from an earlier recovery
                }
                let pt = ctx.pt_ms();
                let mut merged_any = false;
                for (key, siblings) in data {
                    for v in siblings {
                        let (prev, changed) = self.table.put(key, v.version, v.value);
                        if changed {
                            merged_any = true;
                            self.resync_keys += 1;
                            self.windowlog.append(pt, key, prev);
                        }
                    }
                }
                if self.recovering {
                    self.sync_pending = self.sync_pending.saturating_sub(1);
                    if self.sync_pending == 0 {
                        self.finish_resync(ctx); // reseeds the detector
                    }
                } else if merged_any {
                    // straggler chunk after a timeout-based finish: the
                    // merge above bypassed the PUT interception path, so
                    // the detector's value cache must be refreshed or it
                    // would evaluate conjuncts against stale state
                    if let Some(det) = self.detector.as_mut() {
                        det.reseed(&self.table);
                    }
                }
                // late chunks still merge (vector clocks make the merge
                // idempotent) — the hinted-handoff flavour of repair
            }
        }
    }

    fn handle_rollback(&mut self, ctx: &mut Ctx, from: ProcId, msg: RollbackMsg) {
        if self.recovering {
            // mid-catch-up (fresh after a restart or serving a reset):
            // this replica has no coherent state to freeze, restore or
            // reset, so it stays silent — the controller's per-phase
            // deadline covers the missing ack
            return;
        }
        match msg {
            RollbackMsg::Freeze { epoch } => {
                self.frozen = Some(epoch);
                ctx.send(from, Msg::Rollback(RollbackMsg::FrozenAck { epoch }));
            }
            RollbackMsg::Restore { epoch, to_ms } => {
                let from_window_log = if self.windowlog.covers(to_ms) {
                    self.windowlog.rollback(&mut self.table, to_ms);
                    true
                } else {
                    self.snapshots.restore_before(&mut self.table, to_ms);
                    false
                };
                // the detector's cache must reflect rolled-back state
                if let Some(det) = self.detector.as_mut() {
                    det.reseed(&self.table);
                }
                ctx.send(from, Msg::Rollback(RollbackMsg::RestoredAck { epoch, from_window_log }));
            }
            RollbackMsg::Resume { .. } => {
                self.frozen = None;
            }
            RollbackMsg::Reset { epoch } => {
                // checkpoint-free repair (ResetToClean): drop the owned
                // partition state wholesale and re-derive it from the
                // preference-list peers over the crash-recovery Sync
                // path; the ack goes out when the re-derivation settles
                self.resets += 1;
                self.frozen = None;
                self.table = Table::new();
                self.windowlog = WindowLog::new(self.cfg.windowlog_ms, self.cfg.windowlog_max);
                self.snapshots = SnapshotStore::new(self.cfg.snapshots_keep);
                self.pending_reset = Some(epoch);
                self.begin_resync(ctx);
            }
            _ => {}
        }
    }
}

impl Actor for ServerActor {
    fn on_start(&mut self, ctx: &mut Ctx) {
        if let Some(det) = self.detector.as_mut() {
            det.sync_registry(&self.table);
        }
        if self.cfg.snapshot_period > 0 {
            ctx.schedule(self.cfg.snapshot_period, TAG_SNAPSHOT);
        }
        let _ = self.controller;
    }

    fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg) {
        if self.crashed {
            return; // a dead process sees nothing
        }
        match msg {
            Msg::Request { req, op, hvc } => self.handle_request(ctx, from, req, op, hvc),
            Msg::Rollback(rb) => self.handle_rollback(ctx, from, rb),
            Msg::Sync(s) => self.handle_sync(ctx, from, *s),
            _ => {}
        }
    }

    fn on_timer(&mut self, ctx: &mut Ctx, tag: u64) {
        if tag == TAG_SNAPSHOT {
            // keep the snapshot beat through a crash so it resumes after
            // the restart; just skip the work while down or catching up
            if !self.crashed && !self.recovering {
                self.snapshots.take(ctx.pt_ms(), &self.table);
                // snapshotting costs CPU proportional to table size
                let cost = 50 * 1_000 + (self.table.len() as u64) * 150;
                ctx.cpu(cost);
            }
            ctx.schedule(self.cfg.snapshot_period, TAG_SNAPSHOT);
        } else if tag & RESYNC_FLAG != 0 {
            let stale = (tag & !RESYNC_FLAG) != self.sync_epoch;
            if !stale && !self.crashed && self.recovering {
                // some peer never answered (crashed or partitioned away):
                // serve with what we have — availability over completeness
                self.finish_resync(ctx);
            }
        }
    }

    fn on_fault(&mut self, ctx: &mut Ctx, hook: FaultHook) {
        if let Some(tr) = &self.trace {
            let kind = match hook {
                FaultHook::Crash => "crash",
                FaultHook::Restart => "restart",
            };
            tr.borrow_mut().record(ctx.self_id, ctx.now(), ctx.event_seq(), TraceEv::Fault { kind });
        }
        match hook {
            FaultHook::Crash => {
                self.crashed = true;
                self.recovering = false;
                self.frozen = None;
                self.pending_reset = None;
                self.crashes += 1;
                // all volatile state is gone
                self.table = Table::new();
                self.windowlog = WindowLog::new(self.cfg.windowlog_ms, self.cfg.windowlog_max);
                self.snapshots = SnapshotStore::new(self.cfg.snapshots_keep);
                let n_servers = self.router.ring().n_servers();
                self.hvc = Rc::new(Hvc::new(self.idx, n_servers, 0, 0));
            }
            FaultHook::Restart => {
                self.crashed = false;
                // a fresh HVC that claims nothing about remote processes
                // (entries floored far in the past, as at cold start)
                let n_servers = self.router.ring().n_servers();
                self.hvc = Rc::new(Hvc::new(self.idx, n_servers, ctx.pt_ms(), EPS_INF));
                // with an empty peer table (unit-test rigs) this is an
                // immediate no-op re-sync and the server serves right away
                self.begin_resync(ctx);
            }
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

// unit tests for the server live in rust/tests/store_integration.rs where a
// full Sim can be assembled; the pure pieces (Table, WindowLog, Snapshots,
// LocalDetector) are tested in their own modules.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    #[test]
    fn default_costs_sane() {
        let c = ServerCfg::default();
        assert!(c.svc_get < 5 * MS && c.svc_put < 5 * MS);
        assert!(c.det_check < c.svc_put / 10, "intercept must be cheap vs service");
    }
}
