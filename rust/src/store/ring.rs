//! The cluster partitioning layer: a consistent-hash ring with virtual
//! nodes, per-key preference lists, and partition-ownership queries.
//!
//! Voldemort (and Dynamo before it) partitions the keyspace over a ring:
//! every server owns many small arcs (virtual nodes), and a key's
//! *preference list* is the first N distinct servers met walking clockwise
//! from the key's hash position (§II of the paper; DeCandia et al. §4.2).
//! Clients replicate to the preference list only, so cluster size and the
//! replication factor N are independent — a 24-server cluster still
//! writes each key to just N = 3 replicas, which is what makes the store
//! scale horizontally.
//!
//! Two pieces live here:
//!
//! * [`Ring`] — the pure hash geometry: tokens, clockwise walks,
//!   ownership. Deterministic in `(n_servers, n_replicas, vnodes, seed)`,
//!   so every client and server derives the identical mapping without
//!   coordination (the paper's deployments distribute the cluster.xml the
//!   same way).
//! * [`Router`] — the name-aware layer on top: it resolves `KeyId →
//!   preference list` through the interner and applies the *routing-tag*
//!   convention: the Peterson lock variables of one edge
//!   (`flag_a_b_a`, `flag_a_b_b`, `turn_a_b`) all route by the edge tag,
//!   so the variables of one mutual-exclusion conjunct always share a
//!   replica set and the per-server local detectors keep seeing every
//!   variable they must evaluate (the hash-tag idiom of Dynamo-family
//!   stores). Resolved lists are memoized — the hot path is one HashMap
//!   probe, not a ring walk.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::detect::assign::fnv1a;
use crate::predicate::infer;
use crate::store::value::{Interner, KeyId};

/// Default number of virtual nodes per server. 64 keeps the per-server
/// load within ~15% of uniform for the cluster sizes the scale-out
/// scenarios use (imbalance of a vnode ring shrinks like 1/sqrt(vnodes)).
pub const DEFAULT_VNODES: usize = 64;

/// Default token-placement seed. Chosen (once, offline) so the shipped
/// scale-out cluster sizes {3, 6, 12, 24} all balance within ~15% at
/// [`DEFAULT_VNODES`]; any seed works correctness-wise.
pub const DEFAULT_RING_SEED: u64 = 139;

/// SplitMix64 finalizer — the ring's one hash primitive. Stable across
/// processes and reconstructions (no RNG state involved).
#[inline]
pub fn mix64(z: u64) -> u64 {
    let mut z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Position of a key (or routing tag) on the ring, from its name.
/// Lock variables of one Peterson edge collapse to the edge tag so the
/// whole lock co-locates; every other name hashes individually.
pub fn route_hash(name: &str) -> u64 {
    match infer::recognize(name) {
        Some(e) => mix64(0xED6E_7A67 ^ mix64(e.a).wrapping_add(mix64(e.b ^ 0x5EED))),
        None => mix64(fnv1a(name.as_bytes())),
    }
}

/// Consistent-hash ring: `n_servers × vnodes` tokens on the u64 circle.
#[derive(Debug, Clone)]
pub struct Ring {
    n_servers: usize,
    n_replicas: usize,
    vnodes: usize,
    seed: u64,
    /// sorted (position, server)
    tokens: Vec<(u64, u16)>,
}

impl Ring {
    pub fn new(n_servers: usize, n_replicas: usize, vnodes: usize, seed: u64) -> Self {
        assert!(n_servers >= 1, "ring needs at least one server");
        assert!(
            (1..=n_servers).contains(&n_replicas),
            "replication factor {n_replicas} must be in 1..={n_servers}"
        );
        assert!(vnodes >= 1, "ring needs at least one vnode per server");
        let mut tokens = Vec::with_capacity(n_servers * vnodes);
        for s in 0..n_servers as u64 {
            for v in 0..vnodes as u64 {
                tokens.push((mix64(seed ^ mix64((s << 20) | v)), s as u16));
            }
        }
        tokens.sort_unstable();
        Self { n_servers, n_replicas, vnodes, seed, tokens }
    }

    pub fn n_servers(&self) -> usize {
        self.n_servers
    }

    pub fn n_replicas(&self) -> usize {
        self.n_replicas
    }

    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The preference list for a ring position: the first `n_replicas`
    /// distinct servers walking clockwise from `hash`, primary first.
    pub fn preference_list(&self, hash: u64) -> Vec<u16> {
        let start = self.tokens.partition_point(|&(p, _)| p < hash);
        let mut out = Vec::with_capacity(self.n_replicas);
        for i in 0..self.tokens.len() {
            let (_, s) = self.tokens[(start + i) % self.tokens.len()];
            if !out.contains(&s) {
                out.push(s);
                if out.len() == self.n_replicas {
                    break;
                }
            }
        }
        out
    }

    /// The server coordinating a position (head of the preference list).
    pub fn primary(&self, hash: u64) -> u16 {
        self.tokens[self.tokens.partition_point(|&(p, _)| p < hash) % self.tokens.len()].1
    }

    /// Partition-ownership query: does `server` replicate position `hash`?
    pub fn owns(&self, server: u16, hash: u64) -> bool {
        self.preference_list(hash).contains(&server)
    }
}

/// Key-level router shared by the clients, servers and local detectors of
/// one simulated cluster.
pub struct Router {
    ring: Ring,
    interner: Rc<RefCell<Interner>>,
    /// memoized `key → replica set` (ring and key names are immutable for
    /// the lifetime of a run)
    cache: RefCell<HashMap<KeyId, Rc<Vec<u16>>>>,
}

impl Router {
    pub fn new(ring: Ring, interner: Rc<RefCell<Interner>>) -> Rc<Self> {
        Rc::new(Self { ring, interner, cache: RefCell::new(HashMap::new()) })
    }

    /// Full replication over `n_servers` (the paper's original deployment
    /// shape, and the degenerate ring the tests use).
    pub fn full(n_servers: usize, interner: Rc<RefCell<Interner>>) -> Rc<Self> {
        Self::new(Ring::new(n_servers, n_servers, 1, DEFAULT_RING_SEED), interner)
    }

    pub fn ring(&self) -> &Ring {
        &self.ring
    }

    /// The key's replica set, ascending by server index.
    ///
    /// The walk order (who is "primary") does not matter to the quorum
    /// client — it contacts the whole list in parallel — so the list is
    /// normalized to ascending order. This keeps the event schedule of a
    /// `cluster_servers == N` run identical to the historical
    /// full-replication code path, which broadcast to servers 0..N in
    /// index order.
    pub fn replicas(&self, key: KeyId) -> Rc<Vec<u16>> {
        if let Some(r) = self.cache.borrow().get(&key) {
            return Rc::clone(r);
        }
        let hash = {
            let interner = self.interner.borrow();
            route_hash(interner.name(key))
        };
        let mut list = self.ring.preference_list(hash);
        list.sort_unstable();
        let rc = Rc::new(list);
        self.cache.borrow_mut().insert(key, Rc::clone(&rc));
        rc
    }

    /// Partition-ownership query at key granularity.
    pub fn owns(&self, server: u16, key: KeyId) -> bool {
        self.replicas(key).contains(&server)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preference_list_n_distinct_in_range() {
        let ring = Ring::new(8, 3, 16, 7);
        for i in 0..500u64 {
            let l = ring.preference_list(mix64(i));
            assert_eq!(l.len(), 3);
            let mut d = l.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 3, "replicas must be distinct: {l:?}");
            assert!(l.iter().all(|&s| (s as usize) < 8));
        }
    }

    #[test]
    fn full_replication_when_n_equals_cluster() {
        let ring = Ring::new(3, 3, 64, DEFAULT_RING_SEED);
        for i in 0..100u64 {
            let mut l = ring.preference_list(mix64(i ^ 0xC0FFEE));
            l.sort_unstable();
            assert_eq!(l, vec![0, 1, 2], "N == S puts every key everywhere");
        }
    }

    #[test]
    fn ownership_matches_preference_list() {
        let ring = Ring::new(6, 3, 32, 1);
        for i in 0..200u64 {
            let h = mix64(i);
            let l = ring.preference_list(h);
            for s in 0..6u16 {
                assert_eq!(ring.owns(s, h), l.contains(&s));
            }
            assert_eq!(ring.primary(h), l[0]);
        }
    }

    #[test]
    fn lock_variables_of_an_edge_colocate() {
        let interner = Interner::new();
        let (fa, fb, t, other) = {
            let mut i = interner.borrow_mut();
            (
                i.intern("flag_3_17_3"),
                i.intern("flag_3_17_17"),
                i.intern("turn_3_17"),
                i.intern("color_3"),
            )
        };
        let router = Router::new(Ring::new(12, 3, 64, DEFAULT_RING_SEED), interner);
        let ra = router.replicas(fa);
        assert_eq!(*ra, *router.replicas(fb), "both flags share the replica set");
        assert_eq!(*ra, *router.replicas(t), "turn co-locates with the flags");
        // an unrelated key routes independently of the edge tag
        assert_eq!(route_hash("color_3"), mix64(fnv1a(b"color_3")));
        let _ = router.replicas(other);
    }

    #[test]
    fn router_memoizes_and_sorts() {
        let interner = Interner::new();
        let k = interner.borrow_mut().intern("x_0_0");
        let router = Router::new(Ring::new(9, 3, 64, 2), interner);
        let a = router.replicas(k);
        let b = router.replicas(k);
        assert!(Rc::ptr_eq(&a, &b), "second lookup hits the memo");
        let mut sorted = (*a).clone();
        sorted.sort_unstable();
        assert_eq!(*a, sorted, "replica sets are normalized ascending");
    }
}
