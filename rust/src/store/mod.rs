//! The Dynamo/Voldemort-style key-value store substrate: versioned
//! values, the consistent-hash partitioning ring, server storage engine,
//! wire protocol, and the server actor.

pub mod protocol;
pub mod ring;
pub mod server;
pub mod table;
pub mod value;
