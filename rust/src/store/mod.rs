//! The Dynamo/Voldemort-style key-value store substrate: versioned
//! values, server storage engine, wire protocol, and the server actor.

pub mod protocol;
pub mod server;
pub mod table;
pub mod value;
