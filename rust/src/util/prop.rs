//! Minimal property-testing driver (no `proptest` offline): run a closure
//! over N seeded random cases; on failure, report the failing seed so the
//! case can be replayed deterministically with `PROP_SEED=<seed>`.

use crate::util::rng::Rng;

/// Number of cases per property; override with env `PROP_CASES`.
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(256)
}

/// Run `f` over `cases` seeded RNGs. `f` returns Err(description) on a
/// counterexample. Panics with the failing seed for replay.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // replay mode: a single pinned seed
    if let Ok(s) = std::env::var("PROP_SEED") {
        let seed: u64 = s.parse().expect("PROP_SEED must be u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for case in 0..cases {
        // distinct, stable seeds per case and per property name
        let seed = fnv1a(name.as_bytes()) ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at case {case} (replay with PROP_SEED={seed}): {msg}");
        }
    }
}

/// Convenience: run with the default number of cases.
pub fn check_default<F>(name: &str, f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, default_cases(), f)
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 32, |rng| {
            n += 1;
            let a = rng.below(100);
            if a < 100 {
                Ok(())
            } else {
                Err(format!("{a} out of range"))
            }
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property `failing`")]
    fn failing_property_panics_with_seed() {
        check("failing", 8, |rng| {
            let v = rng.below(4);
            if v == 3 {
                Err("hit 3".into())
            } else {
                Ok(())
            }
        });
    }
}
