//! Tiny XML-subset parser, sufficient for the paper's predicate
//! specification format (Fig. 3): nested elements, text content, no
//! attributes/namespaces/CDATA. Entities `&lt; &gt; &amp; &quot; &apos;`
//! are decoded in text nodes.

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Element {
    pub name: String,
    pub children: Vec<Element>,
    /// concatenated text directly under this element (trimmed)
    pub text: String,
}

impl Element {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), children: Vec::new(), text: String::new() }
    }

    /// First child with the given tag name.
    pub fn child(&self, name: &str) -> Option<&Element> {
        self.children.iter().find(|c| c.name == name)
    }

    /// All children with the given tag name.
    pub fn children_named<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a Element> {
        self.children.iter().filter(move |c| c.name == name)
    }

    /// Text of the first child with the given tag name.
    pub fn child_text(&self, name: &str) -> Option<&str> {
        self.child(name).map(|c| c.text.as_str())
    }

    /// Serialize back to XML (used in round-trip tests).
    pub fn to_xml(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, depth: usize) {
        let pad = "  ".repeat(depth);
        if self.children.is_empty() {
            out.push_str(&format!("{pad}<{0}>{1}</{0}>\n", self.name, escape(&self.text)));
        } else {
            out.push_str(&format!("{pad}<{}>\n", self.name));
            for c in &self.children {
                c.write(out, depth + 1);
            }
            out.push_str(&format!("{pad}</{}>\n", self.name));
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xml parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { msg: msg.into(), pos: self.pos })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.src.len() && self.src[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn skip_prolog_and_comments(&mut self) {
        loop {
            self.skip_ws();
            if self.src[self.pos..].starts_with(b"<?") {
                if let Some(end) = find(self.src, self.pos, b"?>") {
                    self.pos = end + 2;
                    continue;
                }
            }
            if self.src[self.pos..].starts_with(b"<!--") {
                if let Some(end) = find(self.src, self.pos, b"-->") {
                    self.pos = end + 3;
                    continue;
                }
            }
            break;
        }
    }

    fn parse_element(&mut self) -> Result<Element, ParseError> {
        self.skip_prolog_and_comments();
        if self.pos >= self.src.len() || self.src[self.pos] != b'<' {
            return self.err("expected '<'");
        }
        self.pos += 1;
        let name = self.read_name()?;
        self.skip_ws();
        // no attributes supported; allow self-closing
        if self.src[self.pos..].starts_with(b"/>") {
            self.pos += 2;
            return Ok(Element::new(name));
        }
        if self.pos >= self.src.len() || self.src[self.pos] != b'>' {
            return self.err(format!("expected '>' after <{name}"));
        }
        self.pos += 1;
        let mut el = Element::new(name.clone());
        let mut text = String::new();
        loop {
            if self.pos >= self.src.len() {
                return self.err(format!("unexpected EOF inside <{name}>"));
            }
            if self.src[self.pos] == b'<' {
                if self.src[self.pos..].starts_with(b"<!--") {
                    match find(self.src, self.pos, b"-->") {
                        Some(end) => {
                            self.pos = end + 3;
                            continue;
                        }
                        None => return self.err("unterminated comment"),
                    }
                }
                if self.src[self.pos + 1..].first() == Some(&b'/') {
                    // closing tag
                    self.pos += 2;
                    let close = self.read_name()?;
                    if close != name {
                        return self.err(format!("mismatched </{close}>, expected </{name}>"));
                    }
                    self.skip_ws();
                    if self.pos >= self.src.len() || self.src[self.pos] != b'>' {
                        return self.err("expected '>' in closing tag");
                    }
                    self.pos += 1;
                    el.text = unescape(text.trim());
                    return Ok(el);
                }
                el.children.push(self.parse_element()?);
            } else {
                text.push(self.src[self.pos] as char);
                self.pos += 1;
            }
        }
    }

    fn read_name(&mut self) -> Result<String, ParseError> {
        let start = self.pos;
        while self.pos < self.src.len() {
            let c = self.src[self.pos];
            if c.is_ascii_alphanumeric() || c == b'_' || c == b'-' || c == b'.' || c == b':' {
                self.pos += 1;
            } else {
                break;
            }
        }
        if self.pos == start {
            return self.err("empty tag name");
        }
        Ok(String::from_utf8_lossy(&self.src[start..self.pos]).into_owned())
    }
}

fn find(hay: &[u8], from: usize, needle: &[u8]) -> Option<usize> {
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|i| i + from)
}

fn escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

fn unescape(s: &str) -> String {
    s.replace("&lt;", "<")
        .replace("&gt;", ">")
        .replace("&quot;", "\"")
        .replace("&apos;", "'")
        .replace("&amp;", "&")
}

/// Parse a document into its root element.
pub fn parse(src: &str) -> Result<Element, ParseError> {
    let mut p = Parser { src: src.as_bytes(), pos: 0 };
    let el = p.parse_element()?;
    p.skip_ws();
    Ok(el)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_fig3_predicate_spec() {
        // The paper's Fig. 3 XML (semilinear predicate).
        let src = r#"
<predicate>
 <type>semilinear</type>
 <conjClause>
  <id>0</id>
  <var> <name>x2</name> <value>1</value> </var>
  <var> <name>y2</name> <value>1</value> </var>
 </conjClause>
 <conjClause>
  <id>1</id>
  <var> <name>z2</name> <value>1</value> </var>
 </conjClause>
</predicate>"#;
        let root = parse(src).unwrap();
        assert_eq!(root.name, "predicate");
        assert_eq!(root.child_text("type"), Some("semilinear"));
        let clauses: Vec<_> = root.children_named("conjClause").collect();
        assert_eq!(clauses.len(), 2);
        let vars: Vec<_> = clauses[0].children_named("var").collect();
        assert_eq!(vars.len(), 2);
        assert_eq!(vars[0].child_text("name"), Some("x2"));
        assert_eq!(vars[0].child_text("value"), Some("1"));
        assert_eq!(clauses[1].children_named("var").count(), 1);
    }

    #[test]
    fn round_trip() {
        let src = "<a><b>hi</b><c><d>1</d></c></a>";
        let el = parse(src).unwrap();
        let re = parse(&el.to_xml()).unwrap();
        assert_eq!(el, re);
    }

    #[test]
    fn entities_and_comments() {
        let src = "<x><!-- note --><t>a &amp; b &lt; c</t></x>";
        let el = parse(src).unwrap();
        assert_eq!(el.child_text("t"), Some("a & b < c"));
    }

    #[test]
    fn self_closing() {
        let el = parse("<a><b/><c>t</c></a>").unwrap();
        assert_eq!(el.children.len(), 2);
        assert_eq!(el.children[0].name, "b");
    }

    #[test]
    fn errors_on_mismatch() {
        assert!(parse("<a><b></a></b>").is_err());
        assert!(parse("<a>").is_err());
        assert!(parse("plain").is_err());
    }

    #[test]
    fn prolog_skipped() {
        let el = parse("<?xml version=\"1.0\"?>\n<a><b>1</b></a>").unwrap();
        assert_eq!(el.name, "a");
    }
}
