//! Deterministic PRNG and distributions.
//!
//! The repo builds offline (no `rand` crate), so we carry a small,
//! well-known generator: SplitMix64 for streams/seeding and xoshiro256++
//! for the main generator, plus the distributions the simulator needs:
//! uniform, Bernoulli, exponential, normal (Box–Muller) and Gamma
//! (Marsaglia–Tsang, with the alpha<1 boost) — the paper's latency model
//! (§VI-C) uses Gamma with shape 0.8.

/// SplitMix64: used to expand a seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal sample from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = sm.next_u64();
        }
        // avoid the all-zero state (astronomically unlikely, but cheap to guard)
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s, spare_normal: None }
    }

    /// Derive an independent stream keyed by `tag` (e.g. per-actor RNGs).
    pub fn stream(seed: u64, tag: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ tag.wrapping_mul(0xA24BAED4963EE407));
        Self::new(sm.next_u64())
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] (never zero; safe for ln()).
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64_open().ln() / lambda
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Gamma(shape, scale=1) via Marsaglia–Tsang; handles shape < 1 with the
    /// standard `Gamma(a+1) * U^(1/a)` boost.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            let g = self.gamma(shape + 1.0);
            return g * self.f64_open().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let mut x;
            let mut v;
            loop {
                x = self.normal();
                v = 1.0 + c * x;
                if v > 0.0 {
                    break;
                }
            }
            v = v * v * v;
            let u = self.f64_open();
            if u < 1.0 - 0.0331 * (x * x) * (x * x) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::stream(42, 1);
        let mut d = Rng::stream(42, 2);
        assert_ne!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            let n = r.below(17);
            assert!(n < 17);
            let m = r.range(5, 9);
            assert!((5..9).contains(&m));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.below(8) as usize] += 1;
        }
        for &c in &counts {
            let expected = n / 8;
            assert!(
                (c as i64 - expected as i64).unsigned_abs() < (expected / 10) as u64,
                "bucket count {c} too far from {expected}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_moments_shape_08() {
        // The paper's latency model: Gamma with shape 0.8 (mean=0.8, var=0.8).
        let mut r = Rng::new(13);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let g = r.gamma(0.8);
            assert!(g >= 0.0);
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.8).abs() < 0.02, "mean={mean}");
        assert!((var - 0.8).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_moments_shape_3() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.gamma(3.0);
        }
        let mean = sum / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
