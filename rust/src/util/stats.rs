//! Small statistics helpers used by the metrics module and the benches:
//! mean/std/percentiles, histograms with the paper's latency buckets
//! (Table III), and a fixed-width table printer for bench output.

/// Arithmetic mean. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1). Returns 0.0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Coefficient of variation (std/mean); 0 when mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy. One
/// implementation shared with [`Cdf::quantile`] so the two can never
/// disagree about rank conventions.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    Cdf::new(xs.to_vec()).quantile(p / 100.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Histogram over explicit bucket upper bounds (last bucket is overflow).
/// Used to regenerate Table III's response-time distribution.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// upper bounds, exclusive, ascending
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(bounds: Vec<f64>) -> Self {
        let n = bounds.len();
        Self { bounds, counts: vec![0; n + 1], total: 0 }
    }

    /// The paper's Table III buckets, in milliseconds.
    pub fn table3_buckets() -> Self {
        Self::new(vec![50.0, 1_000.0, 10_000.0, 17_000.0])
    }

    pub fn add(&mut self, x: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| x < b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn fraction(&self, idx: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.counts[idx] as f64 / self.total as f64
        }
    }

    /// Rows of (label, count, percentage).
    pub fn rows(&self) -> Vec<(String, u64, f64)> {
        let mut out = Vec::new();
        let mut lo = 0.0;
        for (i, &b) in self.bounds.iter().enumerate() {
            out.push((format!("{} - {}", fmt_num(lo), fmt_num(b)), self.counts[i], self.fraction(i) * 100.0));
            lo = b;
        }
        out.push((format!(">= {}", fmt_num(lo)), self.counts[self.bounds.len()], self.fraction(self.bounds.len()) * 100.0));
        out
    }
}

/// Empirical CDF over a sample set: a sorted copy supporting quantile
/// and tail-fraction queries. This is how the violation detection-
/// latency distributions of §VI become a queryable artifact
/// ([`crate::exp::runner::ExpResult::detection_cdf`]) rather than a
/// printed histogram.
#[derive(Debug, Clone, Default)]
pub struct Cdf {
    /// ascending
    xs: Vec<f64>,
}

impl Cdf {
    pub fn new(mut xs: Vec<f64>) -> Self {
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { xs }
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Quantile by nearest rank, `q` in `[0, 1]` (e.g. 0.999 for p99.9).
    /// 0.0 on an empty sample set.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0)) * (self.xs.len() as f64 - 1.0)).round() as usize;
        self.xs[rank.min(self.xs.len() - 1)]
    }

    /// Empirical `P[X <= x]`; 0.0 on an empty sample set.
    pub fn fraction_le(&self, x: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let n_le = self.xs.partition_point(|&v| v <= x);
        n_le as f64 / self.xs.len() as f64
    }

    /// The (x, F(x)) step points, one per sample.
    pub fn points(&self) -> impl Iterator<Item = (f64, f64)> + '_ {
        let n = self.xs.len();
        self.xs.iter().enumerate().map(move |(i, &x)| (x, (i + 1) as f64 / n as f64))
    }

    pub fn max(&self) -> f64 {
        self.xs.last().copied().unwrap_or(0.0)
    }

    /// The crate's one quantile ladder (p50/p90/p99/p99.9/max), computed
    /// in a single call. Every report that prints a latency ladder goes
    /// through this instead of repeating ad-hoc `quantile` call sites.
    pub fn summary(&self) -> CdfSummary {
        CdfSummary {
            n: self.len(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
            max: self.max(),
        }
    }
}

/// One row of quantiles from [`Cdf::summary`]. All 0.0 on an empty
/// sample set (the `Cdf` convention).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CdfSummary {
    pub n: usize,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub p999: f64,
    pub max: f64,
}

impl CdfSummary {
    /// One-line rendering with a unit suffix, shared by the experiment
    /// reports and the forensics dump.
    pub fn render(&self, unit: &str) -> String {
        format!(
            "n={} p50={:.2}{u} p90={:.2}{u} p99={:.2}{u} p99.9={:.2}{u} max={:.2}{u}",
            self.n,
            self.p50,
            self.p90,
            self.p99,
            self.p999,
            self.max,
            u = unit
        )
    }
}

fn fmt_num(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        let i = x as i64;
        // thousands separators for readability in printed tables
        let s = i.abs().to_string();
        let mut out = String::new();
        for (k, c) in s.chars().enumerate() {
            if k > 0 && (s.len() - k) % 3 == 0 {
                out.push(',');
            }
            out.push(c);
        }
        if i < 0 {
            format!("-{out}")
        } else {
            out
        }
    } else {
        format!("{x:.1}")
    }
}

/// Fixed-width table printer for bench output (we have no external
/// table crates). Column widths auto-size to content.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944487).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[5.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p50 = percentile(&xs, 50.0);
        assert!((49.0..=51.0).contains(&p50));
    }

    #[test]
    fn histogram_table3_shape() {
        let mut h = Histogram::table3_buckets();
        h.add(3.0); // < 50
        h.add(49.9);
        h.add(200.0); // 50 - 1000
        h.add(5_000.0); // 1000 - 10000
        h.add(12_000.0); // 10000 - 17000
        h.add(30_000.0); // overflow
        assert_eq!(h.counts, vec![2, 1, 1, 1, 1]);
        assert_eq!(h.total, 6);
        let rows = h.rows();
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0].1, 2);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| a | bb |"));
        assert!(s.contains("| 1 | 2  |"));
    }

    #[test]
    fn cv_zero_mean() {
        assert_eq!(cv(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn cdf_quantiles_and_fractions() {
        let c = Cdf::new((1..=1000).map(|i| i as f64).rev().collect());
        assert_eq!(c.len(), 1000);
        assert_eq!(c.quantile(0.0), 1.0);
        assert_eq!(c.quantile(1.0), 1000.0);
        assert_eq!(c.max(), 1000.0);
        let p999 = c.quantile(0.999);
        assert!((999.0..=1000.0).contains(&p999), "p99.9={p999}");
        assert!((c.fraction_le(500.0) - 0.5).abs() < 1e-9);
        assert_eq!(c.fraction_le(0.5), 0.0);
        assert_eq!(c.fraction_le(2000.0), 1.0);
        let pts: Vec<_> = c.points().take(2).collect();
        assert_eq!(pts[0], (1.0, 0.001));
    }

    #[test]
    fn cdf_summary_matches_quantiles() {
        let c = Cdf::new((1..=1000).map(|i| i as f64).collect());
        let s = c.summary();
        assert_eq!(s.n, 1000);
        assert_eq!(s.p50, c.quantile(0.50));
        assert_eq!(s.p90, c.quantile(0.90));
        assert_eq!(s.p99, c.quantile(0.99));
        assert_eq!(s.p999, c.quantile(0.999));
        assert_eq!(s.max, 1000.0);
        let line = s.render("ms");
        assert!(line.contains("n=1000") && line.contains("p99.9="), "{line}");
        let empty = Cdf::default().summary();
        assert_eq!(empty, CdfSummary { n: 0, p50: 0.0, p90: 0.0, p99: 0.0, p999: 0.0, max: 0.0 });
    }

    #[test]
    fn cdf_empty_is_zero() {
        let c = Cdf::default();
        assert!(c.is_empty());
        assert_eq!(c.quantile(0.5), 0.0);
        assert_eq!(c.fraction_le(1.0), 0.0);
        assert_eq!(c.max(), 0.0);
    }
}
