//! Minimal CLI argument parsing (no `clap` offline): `--key value`,
//! `--key=value`, and boolean `--flag` forms, plus positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args()` tail.
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(body.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(body.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some("true") | Some("1") | Some("yes") | Some("on") => true,
            Some("false") | Some("0") | Some("no") | Some("off") => false,
            Some(_) => default,
            None => default,
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("run --seed 42 --clients=15 --verbose --model eventual");
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get_usize("clients", 0), 15);
        assert!(a.get_bool("verbose", false));
        assert_eq!(a.get("model"), Some("eventual"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("--x 1 --flag");
        assert!(a.get_bool("flag", false));
        assert_eq!(a.get_u64("x", 0), 1);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_f64("nope", 2.5), 2.5);
        assert!(!a.has("nope"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v");
        assert!(a.get_bool("a", false));
        assert_eq!(a.get("b"), Some("v"));
    }
}
