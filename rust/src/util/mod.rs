//! Self-contained utilities (the build is offline: no external crates
//! beyond `xla`): PRNG + distributions, statistics, a mini property-test
//! driver, CLI parsing, and an XML-subset parser.

pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod xmlmini;
