//! Network topology and latency model.
//!
//! Latency between two processes follows the paper's §VI-C proxy model:
//! `D_{A,B} = D^d_{A,B} × (1 + Γ(shape=0.8) × 0.2)` where `D^d` is the
//! deterministic one-way delay between the *regions* of A and B. Same-
//! machine traffic (a server and its co-located monitor) uses a loopback
//! constant. Optional i.i.d. message loss models the timeouts/second
//! rounds of the Voldemort client.

use crate::sim::{ms, ProcId, Time};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Topology {
    /// region index of each process
    pub region_of: Vec<u8>,
    /// machine index of each process (co-location ⇒ loopback + shared CPU)
    pub machine_of: Vec<u32>,
    /// one-way deterministic delay (ms) between regions, `base_ms[a][b]`
    pub base_ms: Vec<Vec<f64>>,
    /// Gamma shape for the stochastic component (paper: 0.8)
    pub gamma_shape: f64,
    /// multiplier fraction (paper: 0.2)
    pub jitter_frac: f64,
    /// same-machine delay (ms)
    pub loopback_ms: f64,
    /// i.i.d. message drop probability
    pub drop_prob: f64,
}

impl Topology {
    /// All processes in one region / machine-per-process. Useful in tests.
    pub fn flat(n_procs: usize, base_one_way_ms: f64) -> Self {
        Self {
            region_of: vec![0; n_procs],
            machine_of: (0..n_procs as u32).collect(),
            base_ms: vec![vec![base_one_way_ms]],
            gamma_shape: 0.8,
            jitter_frac: 0.2,
            loopback_ms: 0.05,
            drop_prob: 0.0,
        }
    }

    /// The paper's AWS global setup: Ohio / Oregon / Frankfurt with
    /// pairwise RTTs 76 / 103 / 163 ms (§VI-A) → one-way halves. Intra-
    /// region delay ~1 ms.
    pub fn aws_global() -> Vec<Vec<f64>> {
        vec![
            // Ohio     Oregon   Frankfurt
            vec![1.0, 38.0, 51.5],
            vec![38.0, 1.0, 81.5],
            vec![51.5, 81.5, 1.0],
        ]
    }

    /// The paper's regional setup: one region, 5 availability zones,
    /// inter-AZ latency < 2 ms (§VI-B "Impact of workload characteristics").
    pub fn aws_regional(n_zones: usize) -> Vec<Vec<f64>> {
        let mut m = vec![vec![0.75; n_zones]; n_zones];
        for (z, row) in m.iter_mut().enumerate() {
            row[z] = 0.25;
        }
        m
    }

    /// The paper's local-lab proxy setup (Fig. 8): three regions, 1 ms
    /// one-way intra-region, `inter_ms` (50 or 100) one-way inter-region.
    pub fn local_lab(inter_ms: f64) -> Vec<Vec<f64>> {
        vec![
            vec![1.0, inter_ms, inter_ms],
            vec![inter_ms, 1.0, inter_ms],
            vec![inter_ms, inter_ms, 1.0],
        ]
    }

    /// Sample the one-way latency for a message `src → dst`.
    pub fn latency(&self, src: ProcId, dst: ProcId, rng: &mut Rng) -> Time {
        if src == dst || self.machine_of[src.idx()] == self.machine_of[dst.idx()] {
            return ms(self.loopback_ms);
        }
        let base = self.base_ms[self.region_of[src.idx()] as usize][self.region_of[dst.idx()] as usize];
        let sample = rng.gamma(self.gamma_shape);
        ms(base * (1.0 + sample * self.jitter_frac))
    }

    /// Should this message be dropped by the i.i.d. loss model?
    ///
    /// Same-machine (loopback) traffic — a server and its co-located
    /// monitor — is exempt: loopback loss is physically implausible, and
    /// dropping candidate messages on the server→monitor hop would
    /// silently skew the monitoring-overhead numbers. Loopback also
    /// consumes no RNG draw, so the loss stream over real links is
    /// unaffected by how much loopback chatter a run generates.
    pub fn drops(&self, src: ProcId, dst: ProcId, rng: &mut Rng) -> bool {
        if self.drop_prob <= 0.0 {
            return false;
        }
        if src == dst || self.machine_of[src.idx()] == self.machine_of[dst.idx()] {
            return false;
        }
        rng.chance(self.drop_prob)
    }

    pub fn n_procs(&self) -> usize {
        self.region_of.len()
    }

    /// Minimum deterministic one-way latency (ns) over every process
    /// pair that a partition `split` separates — the conservative
    /// lookahead `W` of a sharded run ([`crate::sim::shard`]): the
    /// Gamma jitter is *additive-only* (`latency ≥ ms(base)`, see
    /// [`Topology::latency`]), so no message between different shards
    /// can be delivered earlier than `send time + W`. Same-machine
    /// pairs are excluded — they use the loopback constant, and the
    /// shard planner rejects splits that separate co-located processes.
    /// `None` when no cross-shard pair exists (a single shard).
    pub fn min_cross_latency(&self, split: &[u32]) -> Option<Time> {
        assert_eq!(split.len(), self.n_procs());
        let mut best: Option<f64> = None;
        for i in 0..self.n_procs() {
            for j in 0..self.n_procs() {
                if i == j || split[i] == split[j] || self.machine_of[i] == self.machine_of[j] {
                    continue;
                }
                let base = self.base_ms[self.region_of[i] as usize][self.region_of[j] as usize];
                best = Some(best.map_or(base, |b: f64| b.min(base)));
            }
        }
        best.map(ms)
    }
}

/// Builder used by the experiment runner: lay out servers, co-located
/// monitors, clients and a controller across regions/machines.
#[derive(Debug, Clone, Default)]
pub struct TopologyBuilder {
    region_of: Vec<u8>,
    machine_of: Vec<u32>,
    thread_counts: Vec<usize>,
    next_machine: u32,
}

impl TopologyBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a process on a brand-new machine with `threads` CPU threads.
    /// Returns (proc index, machine index).
    pub fn add_machine_proc(&mut self, region: u8, threads: usize) -> (u32, u32) {
        let m = self.next_machine;
        self.next_machine += 1;
        self.thread_counts.push(threads);
        let p = self.region_of.len() as u32;
        self.region_of.push(region);
        self.machine_of.push(m);
        (p, m)
    }

    /// Add a process co-located on an existing machine.
    pub fn add_colocated_proc(&mut self, machine: u32) -> u32 {
        let p = self.region_of.len() as u32;
        let region = self
            .machine_of
            .iter()
            .position(|&m| m == machine)
            .map(|i| self.region_of[i])
            .expect("machine exists");
        self.region_of.push(region);
        self.machine_of.push(machine);
        p
    }

    pub fn build(self, base_ms: Vec<Vec<f64>>, drop_prob: f64) -> (Topology, Vec<usize>) {
        let topo = Topology {
            region_of: self.region_of,
            machine_of: self.machine_of,
            base_ms,
            gamma_shape: 0.8,
            jitter_frac: 0.2,
            loopback_ms: 0.05,
            drop_prob,
        };
        (topo, self.thread_counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MS;

    #[test]
    fn loopback_for_colocated() {
        let mut b = TopologyBuilder::new();
        let (_s0, m0) = b.add_machine_proc(0, 2);
        let mon = b.add_colocated_proc(m0);
        let (s1, _) = b.add_machine_proc(1, 2);
        let (topo, threads) = b.build(Topology::local_lab(50.0), 0.0);
        assert_eq!(threads, vec![2, 2]);
        let mut rng = Rng::new(1);
        let l = topo.latency(ProcId(0), ProcId(mon), &mut rng);
        assert!(l < MS, "loopback should be sub-millisecond, got {l}");
        let l2 = topo.latency(ProcId(0), ProcId(s1), &mut rng);
        assert!(l2 >= ms(50.0), "inter-region should be >= 50 ms, got {l2}");
    }

    #[test]
    fn gamma_jitter_matches_paper_model() {
        // D = D^d * (1 + gamma(0.8) * 0.2)  →  mean = D^d * 1.16
        let topo = Topology::flat(2, 100.0);
        let mut rng = Rng::new(5);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let l = topo.latency(ProcId(0), ProcId(1), &mut rng);
            assert!(l >= ms(100.0), "jitter is additive-only");
            sum += l as f64 / MS as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 116.0).abs() < 1.5, "mean={mean}, expected ~116");
    }

    #[test]
    fn aws_matrices_shape() {
        let g = Topology::aws_global();
        assert_eq!(g.len(), 3);
        assert_eq!(g[0][1], 38.0);
        let r = Topology::aws_regional(5);
        assert_eq!(r.len(), 5);
        assert!(r[0][1] < 2.0);
        let l = Topology::local_lab(100.0);
        assert_eq!(l[0][2], 100.0);
    }

    #[test]
    fn min_cross_latency_is_the_smallest_separated_base() {
        // 4 machine-per-process procs in regions 0,0,1,1 of local_lab(50):
        // splitting by region leaves only 50 ms links across the cut;
        // splitting within region 0 exposes the 1 ms intra-region link
        let mut b = TopologyBuilder::new();
        for r in [0u8, 0, 1, 1] {
            b.add_machine_proc(r, 2);
        }
        let (topo, _) = b.build(Topology::local_lab(50.0), 0.0);
        assert_eq!(topo.min_cross_latency(&[0, 0, 1, 1]), Some(ms(50.0)));
        assert_eq!(topo.min_cross_latency(&[0, 1, 1, 1]), Some(ms(1.0)));
        assert_eq!(topo.min_cross_latency(&[0, 0, 0, 0]), None, "single shard: no cross pair");
    }

    #[test]
    fn min_cross_latency_skips_colocated_pairs() {
        // a co-located pair split across shards must not contribute the
        // loopback constant (the planner rejects such splits anyway)
        let mut b = TopologyBuilder::new();
        let (_s0, m0) = b.add_machine_proc(0, 2);
        let mon = b.add_colocated_proc(m0);
        let _ = b.add_machine_proc(0, 2);
        let (topo, _) = b.build(Topology::aws_regional(1), 0.0);
        let split = vec![0, 1, 1];
        let w = topo.min_cross_latency(&split).unwrap();
        assert_eq!(w, ms(0.25), "real-link base, not loopback (mon={mon})");
    }

    #[test]
    fn drop_probability() {
        let mut topo = Topology::flat(2, 1.0);
        topo.drop_prob = 0.5;
        let mut rng = Rng::new(9);
        let drops = (0..10_000)
            .filter(|_| topo.drops(ProcId(0), ProcId(1), &mut rng))
            .count();
        assert!((4_500..5_500).contains(&drops));
    }

    #[test]
    fn loopback_never_drops() {
        let mut b = TopologyBuilder::new();
        let (_s0, m0) = b.add_machine_proc(0, 2);
        let mon = b.add_colocated_proc(m0);
        let (s1, _) = b.add_machine_proc(0, 2);
        let (mut topo, _) = b.build(Topology::aws_regional(1), 0.0);
        topo.drop_prob = 1.0; // certain loss on real links
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            assert!(
                !topo.drops(ProcId(0), ProcId(mon), &mut rng),
                "co-located traffic is exempt from i.i.d. loss"
            );
        }
        assert!(topo.drops(ProcId(0), ProcId(s1), &mut rng), "real links still drop");
    }
}
