//! Parallel sharded simulation: the conservative time-window engine
//! that runs shards of the event loop on worker threads.
//!
//! # Shard ownership
//!
//! A [`ShardPlan`] assigns every process to exactly one shard, under one
//! hard rule: **co-located processes (same machine) share a shard**.
//! Loopback traffic has no latency floor (a constant far below any
//! cross-shard lookahead) and same-machine CPU claims share a FIFO
//! queue, so a machine is indivisible. The experiment runner derives
//! placements from contiguous ring blocks — a server, its co-located
//! monitor, and the keys it serves land together — and the plan
//! validator rejects anything that splits a machine.
//!
//! # Window protocol
//!
//! With lookahead `W` = the minimum deterministic one-way latency
//! between any two processes on *different* shards
//! ([`Topology::min_cross_latency`]), the coordinator repeats:
//!
//! 1. **anchor**: `t` = the minimum pending timestamp across all shards
//!    (queued events, staged envelopes, fault transitions);
//! 2. **window**: every worker processes its local events in
//!    `[t, t + W)` freely — no communication;
//! 3. **barrier**: workers hand their outboxes (cross-shard sends as
//!    owned [`WireEv`] envelopes) to the coordinator, which routes them
//!    for ingestion at the next window.
//!
//! This is safe because the Gamma jitter of the latency model is
//! *additive-only*: a message sent at `s ∈ [t, t+W)` to another shard is
//! delivered at `s + latency ≥ s + W ≥ t + W` — never inside the window
//! that produced it, so no shard can miss an incoming event it should
//! have processed before one it already did. Slow-node fault factors
//! only stretch latencies (factor ≥ 1), and crash/partition/burst
//! faults *drop* messages rather than accelerate them, so the bound
//! survives fault injection.
//!
//! # Determinism
//!
//! Two mechanisms make same-seed runs bit-identical at any shard count
//! and under any thread schedule, with no coordination:
//!
//! * **per-origin sequence numbers** — an event's tiebreak key is
//!   `(origin << ORIGIN_SEQ_SHIFT) | per-origin counter`, assigned by
//!   whichever shard hosts the origin. The (at, seq) total order is a
//!   function of the workload, not of the schedule.
//! * **per-sender network RNG streams** — every latency/drop draw for
//!   messages sent by process `p` comes from `Rng::stream(seed,
//!   0xBEEF_0000 + p)`, owned by `p`'s shard. Actor streams and clock
//!   skews are seeded exactly as in the serial engine.
//!
//! The merged-order engine ([`crate::sim::des::Sim::new_sharded`]) runs
//! this same window/barrier/outbox protocol *single-threaded in global
//! merged order* with the serial engine's single RNG stream and global
//! counter — which is why `shards = k` there is bit-identical to the
//! pre-sharding serial runner for every `k`, the regression pin the
//! determinism suite enforces.
//!
//! The threaded engine requires `Send` actors (built inside their worker
//! thread); the full OptiKV stack shares state through `Rc` side
//! channels and runs under the merged-order engine, while this module's
//! [`run_demo`] workload — an open KV request/reply mill with the
//! scale-out experiment's communication shape — exercises the threaded
//! path and carries the perf rows.

use std::sync::mpsc;

use crate::clock::hvc::{Hvc, Millis};
use crate::faults::state::Timeline;
use crate::sim::des::{Actor, Ctx, SchedKind, Sim, SimStats};
use crate::sim::machine::Machines;
use crate::sim::msg::{Msg, WireMsg};
use crate::sim::net::{Topology, TopologyBuilder};
use crate::sim::{ProcId, Time, US};
use crate::store::protocol::{ServerOp, ServerReply};
use crate::store::value::KeyId;
use std::rc::Rc;

/// A cross-shard event envelope: the `(at, seq)` dispatch key assigned
/// by the sender's shard plus an owned [`WireMsg`] payload.
#[derive(Debug)]
pub struct WireEv {
    pub at: Time,
    pub seq: u64,
    pub dst: ProcId,
    pub from: ProcId,
    pub msg: WireMsg,
}

/// Process → shard assignment plus the conservative lookahead derived
/// from the topology.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub shard_of: Vec<u32>,
    pub n_shards: usize,
    /// window width `W` (ns); `Time::MAX` when nothing can ever cross
    /// shards (single shard ⇒ one unbounded window)
    pub lookahead: Time,
}

impl ShardPlan {
    /// Validate `shard_of` against `topo` and derive the lookahead.
    /// Rejects: length mismatch, shard ids with no process (an idle
    /// worker means a mis-built plan), splits that separate co-located
    /// processes, and topologies whose minimum cross-shard base latency
    /// is zero (no lookahead ⇒ no window to run).
    pub fn build(topo: &Topology, shard_of: Vec<u32>) -> Result<Self, String> {
        if shard_of.len() != topo.n_procs() {
            return Err(format!(
                "plan covers {} processes, topology has {}",
                shard_of.len(),
                topo.n_procs()
            ));
        }
        let n_shards = match shard_of.iter().max() {
            Some(&m) => m as usize + 1,
            None => return Err("empty plan".into()),
        };
        let mut seen = vec![false; n_shards];
        for &s in &shard_of {
            seen[s as usize] = true;
        }
        if let Some(hole) = seen.iter().position(|&b| !b) {
            return Err(format!("shard {hole} owns no process"));
        }
        for i in 0..shard_of.len() {
            for j in (i + 1)..shard_of.len() {
                if topo.machine_of[i] == topo.machine_of[j] && shard_of[i] != shard_of[j] {
                    return Err(format!(
                        "processes {i} and {j} share machine {} but land on shards {} and {}",
                        topo.machine_of[i], shard_of[i], shard_of[j]
                    ));
                }
            }
        }
        let lookahead = if n_shards == 1 {
            Time::MAX
        } else {
            match topo.min_cross_latency(&shard_of) {
                Some(0) => return Err("zero cross-shard base latency leaves no lookahead".into()),
                Some(w) => w,
                // partitioned but no link can carry a message between
                // shards (disconnected base matrix): windows never close
                None => Time::MAX,
            }
        };
        Ok(Self { shard_of, n_shards, lookahead })
    }

    /// Everything on one shard (the trivial plan).
    pub fn single(topo: &Topology) -> Self {
        Self { shard_of: vec![0; topo.n_procs()], n_shards: 1, lookahead: Time::MAX }
    }
}

/// Per-worker construction parameters (everything a worker thread needs
/// to build its [`Sim`] locally — actors are `!Send`, so each worker
/// builds its own).
pub struct ThreadCfg {
    pub topo: Topology,
    pub threads: Vec<usize>,
    pub seed: u64,
    pub skew_ms: f64,
    pub eps_ms: Millis,
    pub sched: SchedKind,
    pub timeline: Timeline,
}

enum ToWorker {
    Prime,
    Window { horizon: Time, until: Time, inbound: Vec<WireEv> },
    Finish { until: Time },
}

struct Reply {
    next_at: Option<Time>,
    outbound: Vec<WireEv>,
}

struct Done<R> {
    stats: SimStats,
    machines: Machines,
    result: R,
}

/// Result of a threaded run: merged stats plus the per-shard extraction
/// results, in shard order (deterministic).
pub struct ThreadedRun<R> {
    pub results: Vec<R>,
    pub stats: SimStats,
    pub machines: Machines,
    pub per_shard_events: Vec<u64>,
    pub barriers: u64,
    pub lookahead: Time,
}

/// Run `plan.n_shards` worker threads to `until` under the conservative
/// window protocol. `build` runs once inside each worker thread to
/// register that shard's actors (via [`Sim::add_actor_at`]); `extract`
/// runs in-thread after the run to pull results out of them.
pub fn run_threaded<R, B, X>(
    cfg: &ThreadCfg,
    plan: &ShardPlan,
    until: Time,
    build: &B,
    extract: &X,
) -> ThreadedRun<R>
where
    R: Send,
    B: Fn(u32, &mut Sim) + Sync,
    X: Fn(u32, &mut Sim) -> R + Sync,
{
    let k = plan.n_shards;
    std::thread::scope(|scope| {
        let mut to_tx = Vec::with_capacity(k);
        let mut reply_rx = Vec::with_capacity(k);
        let mut done_rx = Vec::with_capacity(k);
        for shard in 0..k as u32 {
            let (ttx, trx) = mpsc::channel::<ToWorker>();
            let (rtx, rrx) = mpsc::channel::<Reply>();
            let (dtx, drx) = mpsc::channel::<Done<R>>();
            to_tx.push(ttx);
            reply_rx.push(rrx);
            done_rx.push(drx);
            scope.spawn(move || {
                let mut sim = Sim::new_worker(
                    cfg.topo.clone(),
                    &cfg.threads,
                    cfg.seed,
                    cfg.skew_ms,
                    cfg.eps_ms,
                    plan,
                    shard,
                    cfg.sched,
                );
                sim.install_faults(cfg.timeline.clone());
                build(shard, &mut sim);
                while let Ok(cmd) = trx.recv() {
                    match cmd {
                        ToWorker::Prime => sim.prime(),
                        ToWorker::Window { horizon, until, inbound } => {
                            for ev in inbound {
                                sim.ingest(ev);
                            }
                            sim.run_window(horizon, until);
                        }
                        ToWorker::Finish { until } => {
                            sim.finish(until);
                            let stats = sim.stats().clone();
                            let machines = sim.machines().clone();
                            let result = extract(shard, &mut sim);
                            let _ = dtx.send(Done { stats, machines, result });
                            return;
                        }
                    }
                    let _ = rtx.send(Reply {
                        next_at: sim.next_pending_at(),
                        outbound: sim.drain_outbox(),
                    });
                }
            });
        }

        // coordinator: anchor → window → barrier, until quiet or `until`
        let route = |pending: &mut Vec<Vec<WireEv>>, out: Vec<WireEv>| {
            for ev in out {
                pending[plan.shard_of[ev.dst.idx()] as usize].push(ev);
            }
        };
        let mut pending: Vec<Vec<WireEv>> = (0..k).map(|_| Vec::new()).collect();
        let mut next_at: Vec<Option<Time>> = vec![None; k];
        let mut barriers = 0u64;
        for tx in &to_tx {
            tx.send(ToWorker::Prime).expect("worker alive");
        }
        for i in 0..k {
            let r = reply_rx[i].recv().expect("worker alive");
            next_at[i] = r.next_at;
            route(&mut pending, r.outbound);
        }
        loop {
            let mut t: Option<Time> = None;
            for &na in &next_at {
                t = match (t, na) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
            }
            for p in &pending {
                for ev in p {
                    t = Some(t.map_or(ev.at, |a| a.min(ev.at)));
                }
            }
            let Some(t) = t else { break };
            if t > until {
                break;
            }
            barriers += 1;
            let horizon = t.saturating_add(plan.lookahead);
            for (i, tx) in to_tx.iter().enumerate() {
                tx.send(ToWorker::Window { horizon, until, inbound: std::mem::take(&mut pending[i]) })
                    .expect("worker alive");
            }
            for i in 0..k {
                let r = reply_rx[i].recv().expect("worker alive");
                next_at[i] = r.next_at;
                route(&mut pending, r.outbound);
            }
        }
        for tx in &to_tx {
            tx.send(ToWorker::Finish { until }).expect("worker alive");
        }

        let mut results = Vec::with_capacity(k);
        let mut stats = SimStats::default();
        let mut machines: Option<Machines> = None;
        let mut per_shard_events = Vec::with_capacity(k);
        for drx in &done_rx {
            let d = drx.recv().expect("worker finished");
            per_shard_events.push(d.stats.events);
            stats.merge(&d.stats);
            match &mut machines {
                Some(m) => m.merge(&d.machines),
                None => machines = Some(d.machines),
            }
            results.push(d.result);
        }
        ThreadedRun {
            results,
            stats,
            machines: machines.expect("k >= 1"),
            per_shard_events,
            barriers,
            lookahead: plan.lookahead,
        }
    })
}

// ---------------------------------------------------------------------------
// demo workload: a Send-actor KV mill with the scale-out comm shape
// ---------------------------------------------------------------------------

/// Request/reply server for the threaded perf rows: charges a CPU
/// service time per request and answers with a fresh HVC snapshot
/// (plain data only, so it is constructible inside any worker thread).
pub struct EchoServer {
    pub id: u16,
    pub dim: usize,
    pub svc: Time,
    pub served: u64,
}

impl Actor for EchoServer {
    fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg) {
        if let Msg::Request { req, .. } = msg {
            self.served += 1;
            let d = ctx.cpu_delay(self.svc);
            let hvc = Rc::new(Hvc::new(self.id, self.dim, ctx.pt_ms(), 0));
            ctx.send_after(d, from, Msg::Reply { req, reply: ServerReply::PutAck, hvc });
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

/// Closed-loop client: keeps `depth` requests in flight against
/// uniformly random servers (drawn from its own actor RNG stream, so the
/// request schedule is shard-count-invariant).
pub struct LoadClient {
    pub n_servers: u64,
    pub n_keys: u64,
    pub depth: u32,
    pub next_req: u64,
    pub ops_done: u64,
}

impl LoadClient {
    fn fire(&mut self, ctx: &mut Ctx) {
        let srv = ProcId(ctx.rng().below(self.n_servers) as u32);
        let key = KeyId(ctx.rng().below(self.n_keys) as u32);
        self.next_req += 1;
        ctx.send(srv, Msg::Request { req: self.next_req, op: Rc::new(ServerOp::Get(key)), hvc: None });
    }
}

impl Actor for LoadClient {
    fn on_start(&mut self, ctx: &mut Ctx) {
        for _ in 0..self.depth {
            self.fire(ctx);
        }
    }

    fn on_msg(&mut self, ctx: &mut Ctx, _from: ProcId, msg: Msg) {
        if let Msg::Reply { .. } = msg {
            self.ops_done += 1;
            self.fire(ctx);
        }
    }

    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

/// Shape of a demo run. `s24()` mirrors the `scaleout-s24` perf row's
/// communication profile: 24 servers, 120 closed-loop clients, 3 zones
/// of the regional latency matrix.
#[derive(Debug, Clone)]
pub struct DemoSpec {
    pub servers: usize,
    pub clients: usize,
    pub zones: usize,
    pub depth: u32,
    pub svc_us: u64,
    pub seed: u64,
}

impl DemoSpec {
    pub fn s24(seed: u64) -> Self {
        Self { servers: 24, clients: 120, zones: 3, depth: 4, svc_us: 20, seed }
    }
}

pub struct DemoResult {
    pub stats: SimStats,
    pub ops: u64,
    pub per_shard_events: Vec<u64>,
    pub barriers: u64,
    pub lookahead: Time,
}

/// Every process on its own machine (2 threads), zone-striped — so any
/// contiguous-block plan satisfies the co-location rule trivially.
fn demo_layout(spec: &DemoSpec) -> (Topology, Vec<usize>) {
    let mut tb = TopologyBuilder::new();
    for i in 0..spec.servers {
        tb.add_machine_proc((i % spec.zones) as u8, 2);
    }
    for j in 0..spec.clients {
        tb.add_machine_proc((j % spec.zones) as u8, 2);
    }
    tb.build(Topology::aws_regional(spec.zones), 0.0)
}

/// Contiguous-block placement: servers into `k` ring blocks, clients
/// into matching blocks.
pub fn demo_plan(spec: &DemoSpec, topo: &Topology, shards: usize) -> ShardPlan {
    let k = shards.clamp(1, spec.servers);
    let mut shard_of = vec![0u32; spec.servers + spec.clients];
    for (i, s) in shard_of.iter_mut().take(spec.servers).enumerate() {
        *s = (i * k / spec.servers) as u32;
    }
    for j in 0..spec.clients {
        shard_of[spec.servers + j] = (j * k / spec.clients) as u32;
    }
    ShardPlan::build(topo, shard_of).expect("machine-per-process layout always splits cleanly")
}

/// Run the demo mill on the threaded engine with `shards` workers.
pub fn run_demo(spec: &DemoSpec, shards: usize, until: Time, sched: SchedKind) -> DemoResult {
    let (topo, threads) = demo_layout(spec);
    let plan = demo_plan(spec, &topo, shards);
    let cfg = ThreadCfg {
        topo,
        threads,
        seed: spec.seed,
        skew_ms: 0.5,
        eps_ms: 1,
        sched,
        timeline: Timeline::empty(),
    };
    let s_n = spec.servers;
    let run = run_threaded(
        &cfg,
        &plan,
        until,
        &|shard, sim: &mut Sim| {
            for i in 0..s_n {
                if plan.shard_of[i] == shard {
                    sim.add_actor_at(
                        ProcId(i as u32),
                        Box::new(EchoServer {
                            id: i as u16,
                            dim: s_n,
                            svc: spec.svc_us * US,
                            served: 0,
                        }),
                    );
                }
            }
            for j in 0..spec.clients {
                if plan.shard_of[s_n + j] == shard {
                    sim.add_actor_at(
                        ProcId((s_n + j) as u32),
                        Box::new(LoadClient {
                            n_servers: s_n as u64,
                            n_keys: 4_096,
                            depth: spec.depth,
                            next_req: 0,
                            ops_done: 0,
                        }),
                    );
                }
            }
        },
        &|shard, sim: &mut Sim| {
            let mut ops = 0u64;
            for j in 0..spec.clients {
                if plan.shard_of[s_n + j] == shard {
                    let any = sim
                        .actor_mut(ProcId((s_n + j) as u32))
                        .as_any()
                        .expect("LoadClient downcasts");
                    ops += any.downcast_mut::<LoadClient>().expect("is LoadClient").ops_done;
                }
            }
            ops
        },
    );
    DemoResult {
        ops: run.results.iter().sum(),
        stats: run.stats,
        per_shard_events: run.per_shard_events,
        barriers: run.barriers,
        lookahead: run.lookahead,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{ms, MS, SEC};

    #[test]
    fn plan_rejects_bad_shapes() {
        let topo = Topology::flat(4, 10.0);
        assert!(ShardPlan::build(&topo, vec![0, 1]).is_err(), "length mismatch");
        assert!(ShardPlan::build(&topo, vec![0, 0, 2, 2]).is_err(), "shard 1 owns nothing");
        assert!(ShardPlan::build(&topo, vec![0, 0, 1, 1]).is_ok());
    }

    #[test]
    fn plan_rejects_split_machines() {
        let mut tb = TopologyBuilder::new();
        let (_s, m) = tb.add_machine_proc(0, 2);
        tb.add_colocated_proc(m);
        tb.add_machine_proc(0, 2);
        let (topo, _) = tb.build(Topology::aws_regional(1), 0.0);
        let err = ShardPlan::build(&topo, vec![0, 1, 1]).unwrap_err();
        assert!(err.contains("share machine"), "{err}");
        assert!(ShardPlan::build(&topo, vec![0, 0, 1]).is_ok());
    }

    #[test]
    fn plan_lookahead_is_min_cross_base() {
        let topo = Topology::flat(4, 10.0);
        let plan = ShardPlan::build(&topo, vec![0, 0, 1, 1]).unwrap();
        assert_eq!(plan.lookahead, ms(10.0));
        assert_eq!(plan.n_shards, 2);
        let single = ShardPlan::single(&topo);
        assert_eq!(single.lookahead, Time::MAX, "one unbounded window");
    }

    #[test]
    fn wire_types_are_send() {
        fn ok<T: Send>() {}
        ok::<WireEv>();
        ok::<ThreadCfg>();
        ok::<SimStats>();
    }

    fn tiny() -> DemoSpec {
        DemoSpec { servers: 4, clients: 8, zones: 2, depth: 2, svc_us: 20, seed: 7 }
    }

    #[test]
    fn demo_makes_progress_and_reports_telemetry() {
        let spec = tiny();
        let r = run_demo(&spec, 2, SEC, SchedKind::Heap);
        assert!(r.ops > 100, "the mill turned: {} ops", r.ops);
        assert!(r.stats.events > 2 * r.ops, "request+reply per op");
        assert!(r.barriers > 0);
        assert_eq!(r.per_shard_events.len(), 2);
        assert!(r.per_shard_events.iter().all(|&e| e > 0), "both shards worked");
        assert_eq!(r.lookahead, ms(0.25), "same-zone cross-shard pairs exist");
    }

    #[test]
    fn demo_same_seed_reproduces() {
        let spec = tiny();
        let a = run_demo(&spec, 2, SEC, SchedKind::Heap);
        let b = run_demo(&spec, 2, SEC, SchedKind::Heap);
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.stats.events, b.stats.events);
        assert_eq!(a.stats.sent, b.stats.sent);
        assert_eq!(a.per_shard_events, b.per_shard_events);
        assert_eq!(a.barriers, b.barriers);
    }

    #[test]
    fn demo_is_invariant_under_shard_count() {
        // the headline determinism property of the threaded engine: the
        // simulated outcome is a function of (spec, seed) only — shard
        // count changes wall-clock, not results
        let spec = tiny();
        let runs: Vec<DemoResult> =
            [1usize, 2, 4].iter().map(|&k| run_demo(&spec, k, SEC, SchedKind::Heap)).collect();
        for r in &runs[1..] {
            assert_eq!(r.ops, runs[0].ops);
            assert_eq!(r.stats.events, runs[0].stats.events);
            assert_eq!(r.stats.sent, runs[0].stats.sent);
            assert_eq!(r.stats.dropped, runs[0].stats.dropped);
        }
        assert_eq!(runs[1].per_shard_events.iter().sum::<u64>(), runs[0].stats.events);
    }

    #[test]
    fn demo_calendar_sched_matches_heap() {
        let spec = tiny();
        let h = run_demo(&spec, 2, SEC, SchedKind::Heap);
        let c = run_demo(&spec, 2, SEC, SchedKind::Calendar);
        assert_eq!(h.ops, c.ops);
        assert_eq!(h.stats.events, c.stats.events);
        assert_eq!(h.stats.sent, c.stats.sent);
        assert_eq!(h.per_shard_events, c.per_shard_events);
    }

    #[test]
    fn single_shard_demo_has_one_window() {
        let spec = tiny();
        let r = run_demo(&spec, 1, 500 * MS, SchedKind::Heap);
        assert!(r.ops > 0);
        assert_eq!(r.barriers, 1, "W = MAX ⇒ the whole run is one window");
    }
}
