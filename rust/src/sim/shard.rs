//! Parallel sharded simulation: the conservative time-window engine
//! that runs shards of the event loop on worker threads.
//!
//! # Shard ownership
//!
//! A [`ShardPlan`] assigns every process to exactly one shard, under one
//! hard rule: **co-located processes (same machine) share a shard**.
//! Loopback traffic has no latency floor (a constant far below any
//! cross-shard lookahead) and same-machine CPU claims share a FIFO
//! queue, so a machine is indivisible. The experiment runner derives
//! placements from contiguous ring blocks — a server, its co-located
//! monitor, and the keys it serves land together — and the plan
//! validator rejects anything that splits a machine.
//!
//! # Window protocol
//!
//! With lookahead `W` = the minimum deterministic one-way latency
//! between any two processes on *different* shards
//! ([`Topology::min_cross_latency`]), the coordinator repeats:
//!
//! 1. **anchor**: `t` = the minimum pending timestamp across all shards
//!    (queued events, staged envelopes, fault transitions);
//! 2. **window**: every worker processes its local events in
//!    `[t, t + W)` freely — no communication;
//! 3. **barrier**: workers hand their outboxes (cross-shard sends as
//!    owned [`WireEv`] envelopes) to the coordinator, which routes them
//!    for ingestion at the next window.
//!
//! This is safe because the Gamma jitter of the latency model is
//! *additive-only*: a message sent at `s ∈ [t, t+W)` to another shard is
//! delivered at `s + latency ≥ s + W ≥ t + W` — never inside the window
//! that produced it, so no shard can miss an incoming event it should
//! have processed before one it already did. Slow-node fault factors
//! only stretch latencies (factor ≥ 1), and crash/partition/burst
//! faults *drop* messages rather than accelerate them, so the bound
//! survives fault injection.
//!
//! # Determinism
//!
//! Two mechanisms make same-seed runs bit-identical at any shard count
//! and under any thread schedule, with no coordination:
//!
//! * **per-origin sequence numbers** — an event's tiebreak key is
//!   `(origin << ORIGIN_SEQ_SHIFT) | per-origin counter`, assigned by
//!   whichever shard hosts the origin. The (at, seq) total order is a
//!   function of the workload, not of the schedule.
//! * **per-sender network RNG streams** — every latency/drop draw for
//!   messages sent by process `p` comes from `Rng::stream(seed,
//!   0xBEEF_0000 + p)`, owned by `p`'s shard. Actor streams and clock
//!   skews are seeded exactly as in the serial engine.
//!
//! Both mechanisms are shared by *all three* engines (see
//! [`crate::sim::des`]): the merged-order engine runs this same
//! window/barrier/outbox protocol single-threaded in global merged
//! order with the identical sequence/RNG contract — which is why
//! `shards = k` there is bit-identical to the serial runner for every
//! `k`, and why a threaded run is bit-identical to both.
//!
//! # Running the full production stack threaded
//!
//! Actors need not be `Send`: the `build` closure handed to
//! [`run_threaded`] executes *inside* each worker thread, so every
//! worker deterministically rebuilds its own copy of the world from the
//! experiment config and registers only its shard's actors. Shared
//! `Rc<RefCell<…>>` side channels (interner, router, predicate
//! registry, metrics hub, mutual-exclusion oracle) become **per-shard
//! copies merged at barrier time**:
//!
//! * the key [`crate::store::value::Interner`] and the predicate
//!   [`crate::predicate::spec::Registry`] are *pre-frozen at layout
//!   time* — every key and inferred predicate is known from the config
//!   and workload graph, so all shards carry identical id assignments
//!   and nothing needs merging;
//! * the [`crate::metrics::throughput::MetricsHub`] merges
//!   element-wise (each per-proc series is written by exactly one
//!   shard, so the merge is bit-exact);
//! * the [`crate::apps::peterson::MeOracle`] is an append-only log of
//!   lock enter/exit entries keyed by the engine-invariant `(at, seq)`
//!   dispatch key ([`crate::sim::des::Ctx::event_seq`]); per-shard logs
//!   concatenate and stable-sort back into the exact global dispatch
//!   order before replay;
//! * adaptive-consistency signals flow as ordinary messages
//!   ([`crate::sim::msg::AdaptMsg::Report`]) instead of hub polling, so
//!   the controller works unchanged across shard boundaries.
//!
//! Cross-shard envelope buffers are recycled through a free list
//! ([`crate::sim::des::Sim::supply_outbox`]): the coordinator returns
//! each drained inbound vector to the worker it came from, so
//! steady-state barriers allocate no envelope vectors.

use std::sync::mpsc;

use crate::clock::hvc::Millis;
use crate::faults::state::Timeline;
use crate::sim::des::{SchedKind, Sim, SimStats};
use crate::sim::machine::Machines;
use crate::sim::msg::WireMsg;
use crate::sim::net::Topology;
use crate::sim::{ProcId, Time};

/// A cross-shard event envelope: the `(at, seq)` dispatch key assigned
/// by the sender's shard plus an owned [`WireMsg`] payload.
#[derive(Debug)]
pub struct WireEv {
    pub at: Time,
    pub seq: u64,
    pub dst: ProcId,
    pub from: ProcId,
    pub msg: WireMsg,
}

/// Process → shard assignment plus the conservative lookahead derived
/// from the topology.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub shard_of: Vec<u32>,
    pub n_shards: usize,
    /// window width `W` (ns); `Time::MAX` when nothing can ever cross
    /// shards (single shard ⇒ one unbounded window)
    pub lookahead: Time,
}

impl ShardPlan {
    /// Validate `shard_of` against `topo` and derive the lookahead.
    /// Rejects: length mismatch, shard ids with no process (an idle
    /// worker means a mis-built plan), splits that separate co-located
    /// processes, and topologies whose minimum cross-shard base latency
    /// is zero (no lookahead ⇒ no window to run).
    pub fn build(topo: &Topology, shard_of: Vec<u32>) -> Result<Self, String> {
        if shard_of.len() != topo.n_procs() {
            return Err(format!(
                "plan covers {} processes, topology has {}",
                shard_of.len(),
                topo.n_procs()
            ));
        }
        let n_shards = match shard_of.iter().max() {
            Some(&m) => m as usize + 1,
            None => return Err("empty plan".into()),
        };
        let mut seen = vec![false; n_shards];
        for &s in &shard_of {
            seen[s as usize] = true;
        }
        if let Some(hole) = seen.iter().position(|&b| !b) {
            return Err(format!("shard {hole} owns no process"));
        }
        for i in 0..shard_of.len() {
            for j in (i + 1)..shard_of.len() {
                if topo.machine_of[i] == topo.machine_of[j] && shard_of[i] != shard_of[j] {
                    return Err(format!(
                        "processes {i} and {j} share machine {} but land on shards {} and {}",
                        topo.machine_of[i], shard_of[i], shard_of[j]
                    ));
                }
            }
        }
        let lookahead = if n_shards == 1 {
            Time::MAX
        } else {
            match topo.min_cross_latency(&shard_of) {
                Some(0) => return Err("zero cross-shard base latency leaves no lookahead".into()),
                Some(w) => w,
                // partitioned but no link can carry a message between
                // shards (disconnected base matrix): windows never close
                None => Time::MAX,
            }
        };
        Ok(Self { shard_of, n_shards, lookahead })
    }

    /// Everything on one shard (the trivial plan).
    pub fn single(topo: &Topology) -> Self {
        Self { shard_of: vec![0; topo.n_procs()], n_shards: 1, lookahead: Time::MAX }
    }
}

/// Per-worker construction parameters (everything a worker thread needs
/// to build its [`Sim`] locally — actors are `!Send`, so each worker
/// builds its own).
pub struct ThreadCfg {
    pub topo: Topology,
    pub threads: Vec<usize>,
    pub seed: u64,
    pub skew_ms: f64,
    pub eps_ms: Millis,
    pub sched: SchedKind,
    pub timeline: Timeline,
}

enum ToWorker {
    Prime,
    Window { horizon: Time, until: Time, inbound: Vec<WireEv> },
    Finish { until: Time },
}

struct Reply {
    next_at: Option<Time>,
    outbound: Vec<WireEv>,
}

struct Done<R> {
    stats: SimStats,
    machines: Machines,
    result: R,
}

/// Result of a threaded run: merged stats plus the per-shard extraction
/// results, in shard order (deterministic).
pub struct ThreadedRun<R> {
    pub results: Vec<R>,
    pub stats: SimStats,
    pub machines: Machines,
    pub per_shard_events: Vec<u64>,
    pub barriers: u64,
    pub lookahead: Time,
}

/// Run `plan.n_shards` worker threads to `until` under the conservative
/// window protocol. `build` runs once inside each worker thread to
/// register that shard's actors (via [`Sim::add_actor_at`]); `extract`
/// runs in-thread after the run to pull results out of them.
pub fn run_threaded<R, B, X>(
    cfg: &ThreadCfg,
    plan: &ShardPlan,
    until: Time,
    build: &B,
    extract: &X,
) -> ThreadedRun<R>
where
    R: Send,
    B: Fn(u32, &mut Sim) + Sync,
    X: Fn(u32, &mut Sim) -> R + Sync,
{
    let k = plan.n_shards;
    std::thread::scope(|scope| {
        let mut to_tx = Vec::with_capacity(k);
        let mut reply_rx = Vec::with_capacity(k);
        let mut done_rx = Vec::with_capacity(k);
        for shard in 0..k as u32 {
            let (ttx, trx) = mpsc::channel::<ToWorker>();
            let (rtx, rrx) = mpsc::channel::<Reply>();
            let (dtx, drx) = mpsc::channel::<Done<R>>();
            to_tx.push(ttx);
            reply_rx.push(rrx);
            done_rx.push(drx);
            scope.spawn(move || {
                let mut sim = Sim::new_worker(
                    cfg.topo.clone(),
                    &cfg.threads,
                    cfg.seed,
                    cfg.skew_ms,
                    cfg.eps_ms,
                    plan,
                    shard,
                    cfg.sched,
                );
                sim.install_faults(cfg.timeline.clone());
                build(shard, &mut sim);
                while let Ok(cmd) = trx.recv() {
                    match cmd {
                        ToWorker::Prime => sim.prime(),
                        ToWorker::Window { horizon, until, mut inbound } => {
                            for ev in inbound.drain(..) {
                                sim.ingest(ev);
                            }
                            // the emptied inbound vector becomes the next
                            // outbox (envelope free list)
                            sim.supply_outbox(inbound);
                            sim.run_window(horizon, until);
                        }
                        ToWorker::Finish { until } => {
                            sim.finish(until);
                            let stats = sim.stats().clone();
                            let machines = sim.machines().clone();
                            let result = extract(shard, &mut sim);
                            let _ = dtx.send(Done { stats, machines, result });
                            return;
                        }
                    }
                    let _ = rtx.send(Reply {
                        next_at: sim.next_pending_at(),
                        outbound: sim.drain_outbox(),
                    });
                }
            });
        }

        // coordinator: anchor → window → barrier, until quiet or `until`.
        // Drained outbound vectors go on a free list and come back as the
        // next barrier's inbound buffers, closing the envelope-recycling
        // loop with the workers' `supply_outbox` half.
        let route = |pending: &mut Vec<Vec<WireEv>>,
                     free: &mut Vec<Vec<WireEv>>,
                     mut out: Vec<WireEv>| {
            for ev in out.drain(..) {
                pending[plan.shard_of[ev.dst.idx()] as usize].push(ev);
            }
            free.push(out);
        };
        let mut pending: Vec<Vec<WireEv>> = (0..k).map(|_| Vec::new()).collect();
        let mut free: Vec<Vec<WireEv>> = Vec::new();
        let mut next_at: Vec<Option<Time>> = vec![None; k];
        let mut barriers = 0u64;
        for tx in &to_tx {
            tx.send(ToWorker::Prime).expect("worker alive");
        }
        for i in 0..k {
            let r = reply_rx[i].recv().expect("worker alive");
            next_at[i] = r.next_at;
            route(&mut pending, &mut free, r.outbound);
        }
        loop {
            let mut t: Option<Time> = None;
            for &na in &next_at {
                t = match (t, na) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
            }
            for p in &pending {
                for ev in p {
                    t = Some(t.map_or(ev.at, |a| a.min(ev.at)));
                }
            }
            let Some(t) = t else { break };
            if t > until {
                break;
            }
            barriers += 1;
            let horizon = t.saturating_add(plan.lookahead);
            for (i, tx) in to_tx.iter().enumerate() {
                let inbound =
                    std::mem::replace(&mut pending[i], free.pop().unwrap_or_default());
                tx.send(ToWorker::Window { horizon, until, inbound }).expect("worker alive");
            }
            for i in 0..k {
                let r = reply_rx[i].recv().expect("worker alive");
                next_at[i] = r.next_at;
                route(&mut pending, &mut free, r.outbound);
            }
        }
        for tx in &to_tx {
            tx.send(ToWorker::Finish { until }).expect("worker alive");
        }

        let mut results = Vec::with_capacity(k);
        let mut stats = SimStats::default();
        let mut machines: Option<Machines> = None;
        let mut per_shard_events = Vec::with_capacity(k);
        for drx in &done_rx {
            let d = drx.recv().expect("worker finished");
            per_shard_events.push(d.stats.events);
            stats.merge(&d.stats);
            match &mut machines {
                Some(m) => m.merge(&d.machines),
                None => machines = Some(d.machines),
            }
            results.push(d.result);
        }
        ThreadedRun {
            results,
            stats,
            machines: machines.expect("k >= 1"),
            per_shard_events,
            barriers,
            lookahead: plan.lookahead,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ms;
    use crate::sim::net::TopologyBuilder;

    #[test]
    fn plan_rejects_bad_shapes() {
        let topo = Topology::flat(4, 10.0);
        assert!(ShardPlan::build(&topo, vec![0, 1]).is_err(), "length mismatch");
        assert!(ShardPlan::build(&topo, vec![0, 0, 2, 2]).is_err(), "shard 1 owns nothing");
        assert!(ShardPlan::build(&topo, vec![0, 0, 1, 1]).is_ok());
    }

    #[test]
    fn plan_rejects_split_machines() {
        let mut tb = TopologyBuilder::new();
        let (_s, m) = tb.add_machine_proc(0, 2);
        tb.add_colocated_proc(m);
        tb.add_machine_proc(0, 2);
        let (topo, _) = tb.build(Topology::aws_regional(1), 0.0);
        let err = ShardPlan::build(&topo, vec![0, 1, 1]).unwrap_err();
        assert!(err.contains("share machine"), "{err}");
        assert!(ShardPlan::build(&topo, vec![0, 0, 1]).is_ok());
    }

    #[test]
    fn plan_lookahead_is_min_cross_base() {
        let topo = Topology::flat(4, 10.0);
        let plan = ShardPlan::build(&topo, vec![0, 0, 1, 1]).unwrap();
        assert_eq!(plan.lookahead, ms(10.0));
        assert_eq!(plan.n_shards, 2);
        let single = ShardPlan::single(&topo);
        assert_eq!(single.lookahead, Time::MAX, "one unbounded window");
    }

    #[test]
    fn wire_types_are_send() {
        fn ok<T: Send>() {}
        ok::<WireEv>();
        ok::<ThreadCfg>();
        ok::<SimStats>();
    }

}
