//! Calendar queue: the classic O(1)-amortized DES priority queue
//! (Brown 1988). Pending items hash into time buckets of a fixed
//! `width`; each bucket stays sorted, and the dequeue cursor walks the
//! calendar "year" bucket by bucket. Under the DES *hold model* —
//! pop the minimum, handle it, push a few items a bounded delay into
//! the future — both operations touch O(1) buckets on average, where a
//! binary heap pays O(log n) per push/pop. The queue resizes (and
//! re-estimates its width from the pending-time spread) when the item
//! count drifts out of the bucket count's operating range, so it adapts
//! to any event density without tuning.
//!
//! The simulator plugs this in behind
//! [`SchedKind::Calendar`](crate::sim::des::SchedKind); keys are the
//! DES dispatch key `(at, seq)`, unique per item, so ordering is exact
//! — same dispatch schedule as the heap, bit for bit.

use crate::sim::Time;

/// Key extraction for calendar entries: `(at, seq)` must be unique per
/// queued item and totally ordered (the simulator's event key).
pub trait Keyed {
    fn key(&self) -> (Time, u64);
}

const INITIAL_BUCKETS: usize = 64;
const MIN_BUCKETS: usize = 16;
/// initial width: 100 µs of virtual time per bucket (resize re-estimates)
const INITIAL_WIDTH: Time = 100_000;

pub struct CalendarQueue<T: Keyed> {
    /// each bucket sorted *descending* by key, so the bucket minimum
    /// pops off the back in O(1)
    buckets: Vec<Vec<T>>,
    /// virtual-time width of one bucket (ns)
    width: Time,
    len: usize,
    /// cached global minimum key; kept exact on every push/pop, so
    /// `peek_key` is O(1)
    min_key: Option<(Time, u64)>,
}

impl<T: Keyed> CalendarQueue<T> {
    pub fn new() -> Self {
        Self::with_shape(INITIAL_WIDTH, INITIAL_BUCKETS)
    }

    pub fn with_shape(width: Time, n_buckets: usize) -> Self {
        assert!(width > 0 && n_buckets > 0);
        Self {
            buckets: (0..n_buckets).map(|_| Vec::new()).collect(),
            width,
            len: 0,
            min_key: None,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn peek_key(&self) -> Option<(Time, u64)> {
        self.min_key
    }

    #[inline]
    fn bucket_of(&self, at: Time) -> usize {
        ((at / self.width) % self.buckets.len() as Time) as usize
    }

    pub fn push(&mut self, item: T) {
        self.push_inner(item);
        self.maybe_resize();
    }

    fn push_inner(&mut self, item: T) {
        let key = item.key();
        let idx = self.bucket_of(key.0);
        let b = &mut self.buckets[idx];
        // descending order: everything greater stays in front
        let pos = b.partition_point(|e| e.key() > key);
        b.insert(pos, item);
        self.len += 1;
        if self.min_key.is_none_or(|mk| key < mk) {
            self.min_key = Some(key);
        }
    }

    pub fn pop(&mut self) -> Option<T> {
        let mk = self.min_key?;
        let idx = self.bucket_of(mk.0);
        let item = self.buckets[idx].pop().expect("min bucket non-empty");
        debug_assert_eq!(item.key(), mk, "cached minimum is the bucket's back");
        self.len -= 1;
        self.recompute_min(mk.0);
        self.maybe_resize();
        Some(item)
    }

    /// Re-derive `min_key` after a pop. `floor` is the popped timestamp:
    /// in a DES no remaining item is earlier (monotone dispatch), so the
    /// cursor walk starts at its calendar slot and visits at most one
    /// full year of buckets; if the year is empty (a long quiet gap) a
    /// direct scan of the per-bucket minima finds the next item — the
    /// standard calendar-queue fallback.
    fn recompute_min(&mut self, floor: Time) {
        if self.len == 0 {
            self.min_key = None;
            return;
        }
        let nb = self.buckets.len() as Time;
        let slot_start = (floor / self.width) * self.width;
        for k in 0..nb {
            let win_hi = slot_start.saturating_add((k + 1).saturating_mul(self.width));
            let idx = (((floor / self.width) + k) % nb) as usize;
            if let Some(e) = self.buckets[idx].last() {
                let key = e.key();
                // entries a whole year (or more) ahead share the bucket
                // but fall outside this lap's window — skip them
                if key.0 < win_hi {
                    self.min_key = Some(key);
                    return;
                }
            }
        }
        let best = self
            .buckets
            .iter()
            .filter_map(|b| b.last().map(|e| e.key()))
            .min()
            .expect("len > 0 ⇒ some bucket non-empty");
        self.min_key = Some(best);
    }

    /// Keep the item count within the bucket count's operating range
    /// (the calendar's O(1) average needs a few items per bucket).
    fn maybe_resize(&mut self) {
        let nb = self.buckets.len();
        if self.len > nb * 4 {
            self.rebuild(nb * 2);
        } else if self.len < nb / 4 && nb > MIN_BUCKETS {
            self.rebuild((nb / 2).max(MIN_BUCKETS));
        }
    }

    /// Re-bucket everything with `new_nb` buckets and a width estimated
    /// from the current pending-time spread (≈3× the mean gap between
    /// adjacent distinct timestamps — Brown's rule keeps a handful of
    /// items per bucket-year).
    fn rebuild(&mut self, new_nb: usize) {
        let items: Vec<T> = self.buckets.iter_mut().flat_map(|b| b.drain(..)).collect();
        let mut ats: Vec<Time> = items.iter().map(|e| e.key().0).collect();
        ats.sort_unstable();
        let mut gap_sum: Time = 0;
        let mut gaps = 0u64;
        for w in ats.windows(2) {
            if w[1] > w[0] {
                gap_sum += w[1] - w[0];
                gaps += 1;
            }
        }
        if gaps > 0 {
            self.width = ((gap_sum / gaps) * 3).max(1);
        }
        self.buckets = (0..new_nb).map(|_| Vec::new()).collect();
        self.len = 0;
        self.min_key = None;
        for it in items {
            self.push_inner(it);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    struct Item {
        at: Time,
        seq: u64,
    }
    impl Keyed for Item {
        fn key(&self) -> (Time, u64) {
            (self.at, self.seq)
        }
    }

    /// Drain both structures and compare the full pop sequence.
    fn assert_same_order(items: Vec<Item>) {
        let mut cal = CalendarQueue::new();
        let mut heap: BinaryHeap<Reverse<(Time, u64)>> = BinaryHeap::new();
        for it in items {
            cal.push(it);
            heap.push(Reverse(it.key()));
        }
        while let Some(Reverse(want)) = heap.pop() {
            assert_eq!(cal.peek_key(), Some(want));
            assert_eq!(cal.pop().unwrap().key(), want);
        }
        assert!(cal.is_empty());
        assert_eq!(cal.peek_key(), None);
    }

    #[test]
    fn matches_heap_on_random_batch() {
        let mut rng = Rng::new(1);
        let items: Vec<Item> = (0..5_000)
            .map(|seq| Item { at: rng.below(1_000_000_000), seq })
            .collect();
        assert_same_order(items);
    }

    #[test]
    fn matches_heap_with_timestamp_ties() {
        // many items on few distinct timestamps: seq must break ties FIFO
        let mut rng = Rng::new(2);
        let items: Vec<Item> = (0..2_000)
            .map(|seq| Item { at: rng.below(50) * 1_000_000, seq })
            .collect();
        assert_same_order(items);
    }

    #[test]
    fn hold_model_interleaving_matches_heap() {
        // the DES steady state: pop the minimum, push a few successors a
        // bounded delay ahead — exercised against the heap step by step
        let mut rng = Rng::new(7);
        let mut cal = CalendarQueue::new();
        let mut heap: BinaryHeap<Reverse<(Time, u64)>> = BinaryHeap::new();
        let mut seq = 0u64;
        for _ in 0..256 {
            let at = rng.below(1_000_000);
            cal.push(Item { at, seq });
            heap.push(Reverse((at, seq)));
            seq += 1;
        }
        for _ in 0..20_000 {
            let Reverse(want) = heap.pop().unwrap();
            let got = cal.pop().unwrap().key();
            assert_eq!(got, want);
            // a couple of successors a bounded delay ahead, occasionally
            // none (long quiet stretches force the fallback scan)
            for _ in 0..rng.below(3) {
                let at = want.0 + rng.below(2_000_000) + 1;
                cal.push(Item { at, seq });
                heap.push(Reverse((at, seq)));
                seq += 1;
            }
            assert_eq!(cal.len(), heap.len());
        }
        while let Some(Reverse(want)) = heap.pop() {
            assert_eq!(cal.pop().unwrap().key(), want);
        }
        assert!(cal.is_empty());
    }

    #[test]
    fn resize_grow_and_shrink_preserve_order() {
        // push far past the grow threshold, then drain past the shrink
        // threshold; order must hold throughout the rebuilds
        let mut rng = Rng::new(11);
        let items: Vec<Item> = (0..20_000)
            .map(|seq| Item { at: rng.below(10_000_000_000), seq })
            .collect();
        assert_same_order(items);
    }

    #[test]
    fn bimodal_gaps_survive_width_estimation() {
        // clusters of dense activity separated by long silences: the
        // width estimate is dominated by the dense gaps, so the silent
        // spans cross whole years and take the fallback path
        let mut items = Vec::new();
        let mut seq = 0u64;
        let mut t: Time = 0;
        let mut rng = Rng::new(13);
        for _ in 0..40 {
            for _ in 0..100 {
                t += rng.below(10_000) + 1;
                items.push(Item { at: t, seq });
                seq += 1;
            }
            t += 50_000_000; // 50 ms of silence
        }
        assert_same_order(items);
    }

    #[test]
    fn zero_timestamp_and_single_item() {
        let mut cal = CalendarQueue::new();
        cal.push(Item { at: 0, seq: 0 });
        assert_eq!(cal.peek_key(), Some((0, 0)));
        assert_eq!(cal.pop().unwrap(), Item { at: 0, seq: 0 });
        assert_eq!(cal.pop().map(|i| i.key()), None);
    }
}
