//! Per-process physical clocks with bounded skew.
//!
//! HVCs assume clocks are synchronized within ε. The model gives every
//! process a constant offset drawn uniformly from [-skew_max, +skew_max]
//! plus a slow sinusoidal wander (NTP-style discipline residue), so the
//! instantaneous inter-process error is bounded by `2·skew_max`.

use crate::clock::hvc::Millis;
use crate::sim::{Time, MS};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct ClockModel {
    /// constant per-process offset, ns (may be negative)
    offsets_ns: Vec<i64>,
    /// per-process wander amplitude, ns
    wander_amp_ns: Vec<i64>,
    /// per-process wander period, ns
    wander_period_ns: Vec<u64>,
}

impl ClockModel {
    /// `skew_max_ms` bounds |offset| + wander amplitude.
    pub fn new(n_procs: usize, skew_max_ms: f64, rng: &mut Rng) -> Self {
        let max_ns = (skew_max_ms * MS as f64) as i64;
        let mut offsets_ns = Vec::with_capacity(n_procs);
        let mut wander_amp_ns = Vec::with_capacity(n_procs);
        let mut wander_period_ns = Vec::with_capacity(n_procs);
        for _ in 0..n_procs {
            // reserve 20% of the budget for wander
            let off_budget = (max_ns as f64 * 0.8) as i64;
            let off = if off_budget > 0 {
                rng.range(0, (2 * off_budget + 1) as u64) as i64 - off_budget
            } else {
                0
            };
            offsets_ns.push(off);
            wander_amp_ns.push((max_ns as f64 * 0.2) as i64);
            // 30–120 s wander period
            wander_period_ns.push(rng.range(30, 120) * 1_000_000_000);
        }
        Self { offsets_ns, wander_amp_ns, wander_period_ns }
    }

    /// Perfectly synchronized clocks (skew 0) — for tests.
    pub fn perfect(n_procs: usize) -> Self {
        Self {
            offsets_ns: vec![0; n_procs],
            wander_amp_ns: vec![0; n_procs],
            wander_period_ns: vec![60_000_000_000; n_procs],
        }
    }

    /// Physical time (ns) of process `p` at virtual time `now`.
    #[inline]
    pub fn pt_ns(&self, p: usize, now: Time) -> i64 {
        let base = now as i64 + self.offsets_ns[p];
        let amp = self.wander_amp_ns[p];
        if amp == 0 {
            return base.max(0);
        }
        let period = self.wander_period_ns[p] as f64;
        let phase = (now as f64 / period) * std::f64::consts::TAU;
        (base + (phase.sin() * amp as f64) as i64).max(0)
    }

    /// Physical time in ms (the HVC granularity).
    #[inline]
    pub fn pt_ms(&self, p: usize, now: Time) -> Millis {
        self.pt_ns(p, now) / MS as i64
    }

    pub fn n_procs(&self) -> usize {
        self.offsets_ns.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn perfect_clocks_agree() {
        let cm = ClockModel::perfect(4);
        for t in [0u64, 1_000_000, 5_000_000_000] {
            for p in 0..4 {
                assert_eq!(cm.pt_ns(p, t), t as i64);
            }
        }
    }

    #[test]
    fn skew_is_bounded() {
        prop::check_default("clock_skew_bounded", |rng| {
            let skew_ms = rng.range(1, 20) as f64;
            let cm = ClockModel::new(6, skew_ms, rng);
            let bound = (skew_ms * MS as f64) as i64 + 1;
            for _ in 0..50 {
                let t = rng.range(0, 600_000_000_000);
                for p in 0..6 {
                    let err = cm.pt_ns(p, t) - t as i64;
                    if err.abs() > bound && t as i64 > bound {
                        return Err(format!("skew {err} exceeds bound {bound} at t={t}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn clocks_monotone() {
        prop::check_default("clock_monotone", |rng| {
            let cm = ClockModel::new(3, 5.0, rng);
            let mut prev = [i64::MIN; 3];
            for k in 0..200u64 {
                let t = k * 50_000_000; // 50 ms steps ≫ wander slope
                for p in 0..3 {
                    let pt = cm.pt_ns(p, t);
                    if pt < prev[p] {
                        return Err(format!("clock {p} went backwards at t={t}"));
                    }
                    prev[p] = pt;
                }
            }
            Ok(())
        });
    }
}
