//! Machine CPU model: each machine has `k` worker threads shared by every
//! actor co-located on it (a Voldemort server and its monitor, in the
//! paper's deployment). Work is scheduled FIFO-greedy: a job arriving at
//! `t` starts on the earliest-free thread, no preemption.
//!
//! This is how monitoring *overhead* becomes visible exactly as in the
//! paper (§VI-B: "each M5.large server has only two Voldemort server
//! threads; when one of them is running the predicate detection module,
//! the aggregated throughput would be clearly affected").

use crate::sim::Time;

#[derive(Debug, Clone)]
pub struct Machines {
    /// per machine: next-free virtual time of each thread
    threads: Vec<Vec<Time>>,
    /// per machine: accumulated busy ns (for utilization reports)
    busy: Vec<u64>,
}

impl Machines {
    pub fn new(thread_counts: &[usize]) -> Self {
        Self {
            threads: thread_counts.iter().map(|&k| vec![0; k.max(1)]).collect(),
            busy: vec![0; thread_counts.len()],
        }
    }

    pub fn n_machines(&self) -> usize {
        self.threads.len()
    }

    /// Claim `svc` ns of CPU on `machine` for a job arriving at `now`.
    /// Returns the completion time.
    pub fn claim(&mut self, machine: usize, now: Time, svc: Time) -> Time {
        let threads = &mut self.threads[machine];
        // earliest-free thread
        let (idx, &free) = threads
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .expect("machine has at least one thread");
        let start = now.max(free);
        let done = start + svc;
        threads[idx] = done;
        self.busy[machine] += svc;
        done
    }

    /// Earliest time a new job could start on `machine` if submitted at `now`.
    pub fn earliest_start(&self, machine: usize, now: Time) -> Time {
        let free = *self.threads[machine].iter().min().unwrap();
        now.max(free)
    }

    /// Accumulated busy time (ns) of a machine.
    pub fn busy_ns(&self, machine: usize) -> u64 {
        self.busy[machine]
    }

    /// Merge the per-shard worker copies of the threaded engine
    /// ([`crate::sim::shard`]). The shard co-location rule guarantees
    /// each machine's CPU is claimed by exactly one shard, so for every
    /// machine one copy holds all the work and the others are untouched
    /// zeros — take the busier copy wholesale, per machine.
    pub fn merge(&mut self, other: &Machines) {
        assert_eq!(self.threads.len(), other.threads.len(), "same topology");
        for m in 0..self.threads.len() {
            if other.busy[m] > self.busy[m] {
                self.busy[m] = other.busy[m];
                self.threads[m].clone_from(&other.threads[m]);
            }
        }
    }

    /// Utilization of a machine over `[0, horizon]`.
    pub fn utilization(&self, machine: usize, horizon: Time) -> f64 {
        if horizon == 0 {
            return 0.0;
        }
        let cap = horizon as f64 * self.threads[machine].len() as f64;
        self.busy[machine] as f64 / cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_thread_serializes() {
        let mut m = Machines::new(&[1]);
        let d1 = m.claim(0, 100, 50);
        assert_eq!(d1, 150);
        // second job arrives while first still running → queues behind it
        let d2 = m.claim(0, 120, 50);
        assert_eq!(d2, 200);
        // job arriving after idle starts immediately
        let d3 = m.claim(0, 300, 10);
        assert_eq!(d3, 310);
    }

    #[test]
    fn two_threads_run_in_parallel() {
        let mut m = Machines::new(&[2]);
        let d1 = m.claim(0, 0, 100);
        let d2 = m.claim(0, 0, 100);
        assert_eq!(d1, 100);
        assert_eq!(d2, 100, "second thread takes the second job");
        let d3 = m.claim(0, 0, 100);
        assert_eq!(d3, 200, "third job queues");
    }

    #[test]
    fn contention_from_colocated_work_delays_requests() {
        // the monitoring-overhead mechanism: monitor work occupies a thread,
        // server requests queue behind it
        let mut m = Machines::new(&[2]);
        m.claim(0, 0, 1_000); // monitor batch on thread A
        m.claim(0, 0, 1_000); // monitor batch on thread B
        let d = m.claim(0, 10, 100); // server request must wait
        assert_eq!(d, 1_100);
    }

    #[test]
    fn merge_takes_each_machine_from_its_owning_shard() {
        // two machines, each worked by a different shard's copy
        let mut a = Machines::new(&[2, 2]);
        let mut b = Machines::new(&[2, 2]);
        a.claim(0, 0, 500);
        b.claim(1, 0, 300);
        b.claim(1, 0, 200);
        a.merge(&b);
        assert_eq!(a.busy_ns(0), 500);
        assert_eq!(a.busy_ns(1), 500);
        assert_eq!(a.earliest_start(1, 0), 200, "thread state follows the busy copy");
    }

    #[test]
    fn utilization_accounting() {
        let mut m = Machines::new(&[2]);
        m.claim(0, 0, 500);
        m.claim(0, 0, 500);
        assert_eq!(m.busy_ns(0), 1000);
        assert!((m.utilization(0, 1000) - 0.5).abs() < 1e-9);
    }
}
