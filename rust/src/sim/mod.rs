//! Deterministic discrete-event simulator (DES).
//!
//! A virtual-time executor over a set of actors (servers, clients,
//! monitors, the rollback controller). Substitutes for the paper's AWS
//! EC2 / local-lab deployments: network latencies follow the paper's own
//! Gamma proxy model (§VI-C), per-process physical clocks have bounded
//! skew (the HVC ε story), and each machine has a bounded number of CPU
//! threads shared by a server and its co-located monitor (which is
//! exactly how the paper accounts monitoring overhead).
//!
//! The event loop comes in serial and sharded flavors ([`des`]): the
//! merged-order sharded engine partitions the event set but keeps the
//! serial dispatch order (bit-identical results at any shard count),
//! and the threaded engine ([`shard`]) runs the same conservative
//! window/barrier protocol for real on worker threads. [`calendar`]
//! provides the O(1)-amortized alternative to the binary-heap scheduler.

pub mod calendar;
pub mod clockmodel;
pub mod des;
pub mod machine;
pub mod msg;
pub mod net;
pub mod shard;

/// Virtual time in nanoseconds.
pub type Time = u64;

pub const US: Time = 1_000;
pub const MS: Time = 1_000_000;
pub const SEC: Time = 1_000_000_000;

/// Convert a millisecond count (possibly fractional) to virtual ns.
#[inline]
pub fn ms(x: f64) -> Time {
    (x * MS as f64) as Time
}

/// Virtual ns → whole milliseconds (the HVC granularity).
#[inline]
pub fn to_ms(t: Time) -> i64 {
    (t / MS) as i64
}

/// Actor (process) identifier: an index into the simulation's actor table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProcId(pub u32);

impl ProcId {
    #[inline]
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl std::fmt::Display for ProcId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "p{}", self.0)
    }
}
