//! The discrete-event executor: timestamped events dispatched to actors
//! one at a time, deterministic under a seed.
//!
//! Three engines share this file, all driving the same actors through
//! the same [`Ctx`]:
//!
//! * **single-queue** ([`Sim::new`]) — the historical serial loop: one
//!   priority queue draining in `(at, seq)` order.
//! * **merged-order sharded** ([`Sim::new_sharded`]) — the event set is
//!   partitioned into per-shard queues with cross-shard sends staged in
//!   outboxes and exchanged at conservative window barriers
//!   (`W` = minimum cross-shard latency, see
//!   [`crate::sim::shard::ShardPlan`]). The shards are *driven in
//!   globally-merged `(at, seq)` order*, so every run is bit-identical
//!   to the single-queue engine at every shard count — this engine
//!   exists to execute (and regression-pin) the exact window/barrier/
//!   outbox protocol the threaded engine runs concurrently.
//! * **worker shard** ([`Sim::new_worker`]) — one shard of the threaded
//!   engine ([`crate::sim::shard::run_threaded`]): hosts only the actors
//!   its plan assigns to it, runs windows on command
//!   ([`Sim::run_window`]), and trades cross-shard sends as owned wire
//!   envelopes ([`crate::sim::shard::WireEv`]).
//!
//! All three engines share **one determinism contract**: every event
//! carries a sequence key composed from its origin process and that
//! origin's private counter (`(origin << ORIGIN_SEQ_SHIFT) | counter`),
//! and every network-latency draw comes from the *sender's* private RNG
//! stream. Both are keyed by process id alone, so the composite
//! `(at, seq)` schedule is a function of (topology, seed) — invariant
//! under the engine choice, the shard count, and the thread schedule.
//! This is what lets the full production stack run threaded and still
//! produce bit-identical digests against the merged-order engine.
//!
//! Either queue flavor ([`SchedKind`]) can back any engine: the binary
//! heap or the calendar queue ([`crate::sim::calendar`]).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::clock::hvc::Millis;
use crate::faults::state::{FaultHook, FaultState, Timeline};
use crate::sim::calendar::{CalendarQueue, Keyed};
use crate::sim::clockmodel::ClockModel;
use crate::sim::machine::Machines;
use crate::sim::msg::{Msg, MsgClass, WireMsg, N_MSG_CLASSES};
use crate::sim::net::Topology;
use crate::sim::shard::{ShardPlan, WireEv};
use crate::sim::{ProcId, Time};
use crate::util::rng::Rng;

/// A simulated process.
pub trait Actor {
    /// Called once before the event loop starts.
    fn on_start(&mut self, _ctx: &mut Ctx) {}
    /// A message arrived from `from`.
    fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg);
    /// A self-scheduled timer fired.
    fn on_timer(&mut self, _ctx: &mut Ctx, _tag: u64) {}
    /// A fault transition targeted this process directly (crash /
    /// restart from the installed [`Timeline`]). Timers and in-flight
    /// messages keep being *delivered* to a crashed actor — a real
    /// process cannot intercept the network — so actors that can crash
    /// must gate their handlers on the lifecycle state this hook sets.
    fn on_fault(&mut self, _ctx: &mut Ctx, _hook: FaultHook) {}
    /// Downcast hook so the experiment runner can pull stats after a run.
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

#[derive(Debug)]
enum EvKind {
    Msg { from: ProcId, msg: Msg },
    Timer { tag: u64 },
}

#[derive(Debug)]
struct Ev {
    at: Time,
    seq: u64,
    dst: ProcId,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // ties broken by insertion order → deterministic FIFO
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

impl Keyed for Ev {
    fn key(&self) -> (Time, u64) {
        (self.at, self.seq)
    }
}

/// Which scheduler structure backs an event queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedKind {
    /// `BinaryHeap` — O(log n) push/pop, the historical default.
    #[default]
    Heap,
    /// Calendar queue ([`crate::sim::calendar`]) — O(1) amortized under
    /// the DES hold model.
    Calendar,
}

/// Message-traffic counters.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub sent: [u64; N_MSG_CLASSES],
    pub dropped: [u64; N_MSG_CLASSES],
    pub events: u64,
    /// messages dropped by the fault model (partition cut, crashed
    /// endpoint, drop burst) — a subset of `dropped`
    pub fault_dropped: u64,
    /// fault-state transitions applied from the installed timeline
    pub fault_transitions: u64,
}

/// Nominal serialized size per message class (bytes). This is an
/// *allocation/traffic proxy* for the perf harness (`exp/perfjson`), not
/// a wire protocol: requests/replies count their headers plus a typical
/// single-version payload, candidates and violations their clock
/// intervals and witness sets, sync chunks a small key batch. The values
/// only need to be stable across runs so `sent_bytes_proxy` trends are
/// comparable PR-over-PR.
pub const MSG_CLASS_BYTES: [u64; N_MSG_CLASSES] = [
    96,    // Request: op + key + version clock + piggy-backed HVC ref
    120,   // Reply: status + sibling list (typical single version) + HVC
    256,   // Candidate: HVC interval + partial state values
    512,   // Violation: witness set (several candidates)
    32,    // Rollback control
    192,   // RegisterPred: predicate spec
    1_024, // Sync: re-sync chunk (key batch)
    40,    // Adapt: epoch announce/ack or a signal sample
];

impl SimStats {
    pub fn sent_total(&self) -> u64 {
        self.sent.iter().sum()
    }
    pub fn sent_class(&self, c: MsgClass) -> u64 {
        self.sent[c as usize]
    }
    /// Total nominal bytes sent ([`MSG_CLASS_BYTES`] per class) — the
    /// perf harness's allocation proxy.
    pub fn sent_bytes_proxy(&self) -> u64 {
        self.sent
            .iter()
            .zip(MSG_CLASS_BYTES.iter())
            .map(|(n, b)| n * b)
            .sum()
    }

    /// Deterministic merge of per-shard worker stats (the threaded
    /// engine): traffic and event counters sum — a message is sent (and
    /// possibly dropped) on exactly one shard, the sender's, and
    /// dispatched on exactly one, the receiver's. `fault_transitions`
    /// takes the max instead: every worker applies the *whole* timeline
    /// to keep its reachability view current, so summing would count
    /// each transition once per shard.
    pub fn merge(&mut self, other: &SimStats) {
        for c in 0..N_MSG_CLASSES {
            self.sent[c] += other.sent[c];
            self.dropped[c] += other.dropped[c];
        }
        self.events += other.events;
        self.fault_dropped += other.fault_dropped;
        self.fault_transitions = self.fault_transitions.max(other.fault_transitions);
    }
}

/// One event queue, behind either scheduler ([`SchedKind`]).
enum EventQueue {
    Heap(BinaryHeap<Reverse<Ev>>),
    Calendar(CalendarQueue<Ev>),
}

impl EventQueue {
    fn new(kind: SchedKind) -> Self {
        match kind {
            SchedKind::Heap => EventQueue::Heap(BinaryHeap::new()),
            SchedKind::Calendar => EventQueue::Calendar(CalendarQueue::new()),
        }
    }

    #[inline]
    fn push(&mut self, ev: Ev) {
        match self {
            EventQueue::Heap(h) => h.push(Reverse(ev)),
            EventQueue::Calendar(c) => c.push(ev),
        }
    }

    #[inline]
    fn peek_key(&self) -> Option<(Time, u64)> {
        match self {
            EventQueue::Heap(h) => h.peek().map(|Reverse(ev)| (ev.at, ev.seq)),
            EventQueue::Calendar(c) => c.peek_key(),
        }
    }

    #[inline]
    fn pop(&mut self) -> Option<Ev> {
        match self {
            EventQueue::Heap(h) => h.pop().map(|Reverse(ev)| ev),
            EventQueue::Calendar(c) => c.pop(),
        }
    }
}

/// The event queues of the merged-order sharded engine: one intra-shard
/// queue per shard plus the cross-shard outboxes exchanged at window
/// barriers.
struct ShardQueues {
    shard_of: Vec<u32>,
    queues: Vec<EventQueue>,
    /// staged cross-shard sends, delivered into the destination queue at
    /// the next barrier — their delivery times are `>= horizon` (the
    /// lookahead argument), so staging them cannot change the merged
    /// dispatch order
    outboxes: Vec<Vec<Ev>>,
    /// conservative lookahead `W` = minimum cross-shard one-way latency
    lookahead: Time,
    /// end (exclusive) of the window being processed; 0 between windows
    horizon: Time,
    barriers: u64,
    shard_events: Vec<u64>,
}

impl ShardQueues {
    fn flush_outboxes(&mut self) {
        for k in 0..self.outboxes.len() {
            for ev in std::mem::take(&mut self.outboxes[k]) {
                self.queues[k].push(ev);
            }
        }
    }

    fn peek_key(&self) -> Option<(Time, u64)> {
        self.queues.iter().filter_map(|q| q.peek_key()).min()
    }

    /// Pop the globally-minimal queued event, with its shard index.
    fn pop_min(&mut self) -> Option<(usize, Ev)> {
        let k = (0..self.queues.len())
            .filter_map(|k| self.queues[k].peek_key().map(|key| (key, k)))
            .min()?
            .1;
        Some((k, self.queues[k].pop().expect("peeked queue non-empty")))
    }
}

enum Queues {
    Single(EventQueue),
    Sharded(ShardQueues),
}

impl Queues {
    #[inline]
    fn push(&mut self, ev: Ev, src: ProcId) {
        match self {
            Queues::Single(q) => q.push(ev),
            Queues::Sharded(sq) => {
                let sk = sq.shard_of[src.idx()] as usize;
                let dk = sq.shard_of[ev.dst.idx()] as usize;
                if sk != dk {
                    debug_assert!(
                        ev.at >= sq.horizon,
                        "cross-shard event inside the window: lookahead violated"
                    );
                    sq.outboxes[dk].push(ev);
                } else {
                    sq.queues[dk].push(ev);
                }
            }
        }
    }

    /// Timestamp of the earliest *queued* event (staged outbox events are
    /// excluded: they are `>= horizon`, outside any window in progress,
    /// and every barrier flushes the outboxes first).
    #[inline]
    fn peek_at(&self) -> Option<Time> {
        match self {
            Queues::Single(q) => q.peek_key().map(|(at, _)| at),
            Queues::Sharded(sq) => sq.peek_key().map(|(at, _)| at),
        }
    }

    #[inline]
    fn pop_min(&mut self) -> Option<Ev> {
        match self {
            Queues::Single(q) => q.pop(),
            Queues::Sharded(sq) => {
                let (k, ev) = sq.pop_min()?;
                sq.shard_events[k] += 1;
                Some(ev)
            }
        }
    }

    /// Barrier half of the window protocol: move staged cross-shard
    /// sends into their destination queues. No-op for the single queue.
    fn flush(&mut self) {
        if let Queues::Sharded(sq) = self {
            sq.flush_outboxes();
        }
    }
}

/// Origin-tagged sequence layout (all engines): the high bits carry the
/// origin process, the low bits its private counter, so `(at, seq)` is a
/// total order that no engine choice, thread schedule or shard count can
/// perturb. 2^40 events per origin and 2^24 processes are both far
/// beyond any run this simulator does.
pub const ORIGIN_SEQ_SHIFT: u32 = 40;

/// Cap on the pooled `Rc<ServerOp>` payloads recycled through
/// [`Ctx::recycle_op`] / [`Sim::ingest`] — bounds the slab so an
/// ingest-heavy run cannot hoard memory.
const OP_POOL_CAP: usize = 256;

/// Worker-side state of the threaded engine: which processes this shard
/// hosts and the outbox of wire envelopes bound for other shards at the
/// next barrier (plus a spare buffer so the coordinator can recycle
/// envelope vectors instead of allocating one per window).
struct ShardExec {
    shard_of: Vec<u32>,
    my_shard: u32,
    outbox: Vec<WireEv>,
    /// recycled envelope buffer handed back by the coordinator
    /// ([`Sim::supply_outbox`]); swapped in at the next drain
    outbox_spare: Vec<WireEv>,
    /// end (exclusive) of the window being processed
    horizon: Time,
}

/// Everything the actors share; split from the actor table so an actor can
/// hold `&mut Ctx` while being itself borrowed.
pub struct SimCore {
    now: Time,
    /// `(at, seq)` key of the event being dispatched — globally unique
    /// and engine-invariant, exposed via [`Ctx::event_seq`] so shards
    /// can stamp their side-channel logs for barrier-time merging
    cur_seq: u64,
    /// per-origin private counters composed into sequence keys
    origin_seq: Vec<u64>,
    queues: Queues,
    pub topo: Topology,
    pub clocks: ClockModel,
    pub machines: Machines,
    /// per-*sender* network RNG streams (`Rng::stream(seed, 0xBEEF_0000
    /// + sender)`): each draw sequence is owned by exactly one shard —
    /// whichever hosts the sender — on every engine
    rng_net: Vec<Rng>,
    rng_actors: Vec<Rng>,
    /// recycled `Rc<ServerOp>` payloads ([`Ctx::recycle_op`]); refilled
    /// by the cross-shard ingest path so the envelope hot path reuses
    /// allocations instead of hitting the global allocator per message
    op_pool: Vec<std::rc::Rc<crate::store::protocol::ServerOp>>,
    pub stats: SimStats,
    /// HVC ε (ms) — global config, read by servers/monitors via ctx
    pub eps_ms: Millis,
    /// time-varying reachability view ([`crate::faults`]); quiet unless
    /// a fault timeline is installed and a window is active
    pub faults: FaultState,
    /// present only on worker shards of the threaded engine
    exec: Option<Box<ShardExec>>,
}

/// Per-dispatch context handed to actors.
pub struct Ctx<'a> {
    core: &'a mut SimCore,
    pub self_id: ProcId,
}

impl<'a> Ctx<'a> {
    #[inline]
    pub fn now(&self) -> Time {
        self.core.now
    }

    /// This process's physical clock, in ms (HVC granularity).
    #[inline]
    pub fn pt_ms(&self) -> Millis {
        self.core.clocks.pt_ms(self.self_id.idx(), self.core.now)
    }

    #[inline]
    pub fn eps_ms(&self) -> Millis {
        self.core.eps_ms
    }

    /// This actor's private RNG stream.
    #[inline]
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.core.rng_actors[self.self_id.idx()]
    }

    /// The `(at, seq)` sequence component of the event being dispatched:
    /// globally unique together with [`Ctx::now`], and identical across
    /// engines and shard counts. Side-channel logs (the mutual-exclusion
    /// oracle, violation records) key their entries on it so per-shard
    /// logs merge back into the exact global dispatch order.
    #[inline]
    pub fn event_seq(&self) -> u64 {
        self.core.cur_seq
    }

    /// Return a request payload to the per-shard slab once the server is
    /// done with it. Only sole-owner `Rc`s are pooled (a broadcast's
    /// payload is still shared by the client's in-flight call) and the
    /// pool is bounded, so this is always safe to call.
    #[inline]
    pub fn recycle_op(&mut self, op: std::rc::Rc<crate::store::protocol::ServerOp>) {
        if std::rc::Rc::strong_count(&op) == 1 && self.core.op_pool.len() < OP_POOL_CAP {
            self.core.op_pool.push(op);
        }
    }

    /// Send a message: delivery at `now + net latency` (or never, if the
    /// loss model drops it).
    pub fn send(&mut self, dst: ProcId, msg: Msg) {
        self.send_after(0, dst, msg);
    }

    /// Send after holding the message locally for `delay` ns (e.g. a reply
    /// leaving only once the CPU finished the request).
    ///
    /// The fault view is consulted first: a partitioned or crashed
    /// endpoint silently loses the message (feeding the quorum timeout
    /// path in the client), an active drop burst loses it with extra
    /// probability, and a slow node stretches its delivery latency. With
    /// no active fault none of these checks consumes an RNG draw, so a
    /// run under `FaultPlan::none()` is bit-identical to the pre-fault
    /// code path.
    ///
    /// Every latency/loss draw comes from the *per-sender* network RNG
    /// stream of `self_id` — the same stream handle on every engine, so
    /// the draw sequence is schedule- and shard-count-invariant.
    pub fn send_after(&mut self, delay: Time, dst: ProcId, msg: Msg) {
        let core = &mut *self.core;
        let src = self.self_id;
        let class = msg.class() as usize;
        core.stats.sent[class] += 1;
        let rng = &mut core.rng_net[src.idx()];
        if !core.faults.quiet() {
            if !core.faults.reachable(src, dst) {
                core.stats.dropped[class] += 1;
                core.stats.fault_dropped += 1;
                return;
            }
            // bursts are per machine-pair: the link between two server
            // machines carries candidate traffic to their co-located
            // monitors, not just server↔server re-sync chunks
            let burst = core
                .faults
                .burst_prob(core.topo.machine_of[src.idx()], core.topo.machine_of[dst.idx()]);
            if burst > 0.0 && rng.chance(burst) {
                core.stats.dropped[class] += 1;
                core.stats.fault_dropped += 1;
                return;
            }
        }
        if core.topo.drops(src, dst, rng) {
            core.stats.dropped[class] += 1;
            return;
        }
        let mut lat = core.topo.latency(src, dst, rng);
        if !core.faults.quiet() {
            // a degraded NIC slows the node's *network* links only —
            // same-machine loopback is exempt, mirroring the loss model
            let same_machine = core.topo.machine_of[src.idx()] == core.topo.machine_of[dst.idx()];
            let factor = core.faults.latency_factor(src, dst);
            if factor != 1.0 && !same_machine {
                lat = (lat as f64 * factor) as Time;
            }
        }
        let at = core.now + delay + lat;
        core.push_from(at, src, dst, EvKind::Msg { from: src, msg });
    }

    /// Schedule a timer for this actor.
    pub fn schedule(&mut self, delay: Time, tag: u64) {
        let at = self.core.now + delay;
        let dst = self.self_id;
        self.core.push_from(at, dst, dst, EvKind::Timer { tag });
    }

    /// Claim `svc` ns of CPU on this actor's machine (FIFO across all
    /// co-located actors). Returns the completion time; callers typically
    /// `send_after(done - now, …)`.
    pub fn cpu(&mut self, svc: Time) -> Time {
        let m = self.core.topo.machine_of[self.self_id.idx()] as usize;
        self.core.machines.claim(m, self.core.now, svc)
    }

    /// Completion delay (ns from now) for `svc` ns of CPU work.
    pub fn cpu_delay(&mut self, svc: Time) -> Time {
        self.cpu(svc) - self.core.now
    }

    pub fn topo(&self) -> &Topology {
        &self.core.topo
    }

    pub fn stats(&self) -> &SimStats {
        &self.core.stats
    }
}

impl SimCore {
    /// Next event sequence key for an event originated by `origin`: the
    /// origin's private counter tagged with its process id — the same
    /// composition on every engine, so the total `(at, seq)` order is
    /// identical no matter which engine (or shard) hosts `origin`.
    fn next_seq(&mut self, origin: ProcId) -> u64 {
        let c = &mut self.origin_seq[origin.idx()];
        let seq = ((origin.0 as u64) << ORIGIN_SEQ_SHIFT) | *c;
        *c += 1;
        seq
    }

    /// Enqueue an event originated by `src` for `dst`. On a threaded
    /// worker, a cross-shard destination diverts the event into the wire
    /// outbox instead (timers never cross: their dst is their origin).
    fn push_from(&mut self, at: Time, src: ProcId, dst: ProcId, kind: EvKind) {
        let seq = self.next_seq(src);
        if let Some(ex) = &mut self.exec {
            if ex.shard_of[dst.idx()] != ex.my_shard {
                debug_assert!(
                    at >= ex.horizon,
                    "cross-shard event inside the window: lookahead violated"
                );
                let EvKind::Msg { from, msg } = kind else {
                    unreachable!("timers never cross shards")
                };
                ex.outbox.push(WireEv { at, seq, dst, from, msg: WireMsg::from_msg(msg) });
                return;
            }
        }
        self.queues.push(Ev { at, seq, dst, kind }, src);
    }
}

/// The simulation: topology + machines + actor table + event loop.
pub struct Sim {
    core: SimCore,
    actors: Vec<Option<Box<dyn Actor>>>,
    started: bool,
    /// lowered fault schedule; empty unless installed
    timeline: Timeline,
    /// scratch slot for the threaded engine: the build closure runs and
    /// finishes before the extract closure is called, yet both need the
    /// same (non-`Send`) world handles — build stashes them here, extract
    /// takes them back out. Never crosses a thread boundary.
    blackboard: Option<Box<dyn std::any::Any>>,
}

impl Sim {
    pub fn new(topo: Topology, thread_counts: &[usize], seed: u64, skew_max_ms: f64, eps_ms: Millis) -> Self {
        let n = topo.n_procs();
        let mut seeder = Rng::new(seed);
        let clocks = if skew_max_ms > 0.0 {
            ClockModel::new(n, skew_max_ms, &mut seeder)
        } else {
            ClockModel::perfect(n)
        };
        let rng_actors = (0..n).map(|i| Rng::stream(seed, 0x1000 + i as u64)).collect();
        let rng_net = (0..n).map(|i| Rng::stream(seed, 0xBEEF_0000 + i as u64)).collect();
        Self {
            core: SimCore {
                now: 0,
                cur_seq: 0,
                origin_seq: vec![0; n],
                queues: Queues::Single(EventQueue::new(SchedKind::Heap)),
                topo,
                clocks,
                machines: Machines::new(thread_counts),
                rng_net,
                rng_actors,
                op_pool: Vec::new(),
                stats: SimStats::default(),
                eps_ms,
                faults: FaultState::new(n),
                exec: None,
            },
            actors: Vec::new(),
            started: false,
            timeline: Timeline::empty(),
            blackboard: None,
        }
    }

    /// Stash a value for a later phase of the same run (see the
    /// `blackboard` field). Panics if a value is already stashed.
    pub fn set_blackboard(&mut self, v: Box<dyn std::any::Any>) {
        assert!(self.blackboard.is_none(), "blackboard already occupied");
        self.blackboard = Some(v);
    }

    /// Take back the value stashed by [`Sim::set_blackboard`], if any.
    pub fn take_blackboard(&mut self) -> Option<Box<dyn std::any::Any>> {
        self.blackboard.take()
    }

    /// The merged-order sharded engine: identical seeding, RNG streams
    /// and global `(at, seq)` dispatch order as [`Sim::new`] — results
    /// are bit-identical at every shard count by construction — but the
    /// run executes the full window/barrier/outbox protocol of the
    /// conservative parallel engine and reports its telemetry
    /// ([`Sim::barriers`], [`Sim::shard_events`]).
    pub fn new_sharded(
        topo: Topology,
        thread_counts: &[usize],
        seed: u64,
        skew_max_ms: f64,
        eps_ms: Millis,
        plan: &ShardPlan,
        sched: SchedKind,
    ) -> Self {
        let mut sim = Self::new(topo, thread_counts, seed, skew_max_ms, eps_ms);
        assert_eq!(plan.shard_of.len(), sim.core.topo.n_procs(), "plan must cover every process");
        sim.core.queues = Queues::Sharded(ShardQueues {
            shard_of: plan.shard_of.clone(),
            queues: (0..plan.n_shards).map(|_| EventQueue::new(sched)).collect(),
            outboxes: vec![Vec::new(); plan.n_shards],
            lookahead: plan.lookahead,
            horizon: 0,
            barriers: 0,
            shard_events: vec![0; plan.n_shards],
        });
        sim
    }

    /// One worker shard of the threaded engine
    /// ([`crate::sim::shard::run_threaded`]). The worker sees the whole
    /// topology (latencies and reachability need every process) but
    /// hosts only the actors registered via [`Sim::add_actor_at`].
    /// Seeding matches [`Sim::new`] exactly — per-origin sequence
    /// counters and per-sender network streams are the contract of every
    /// engine — so a worker's hosted slice of the schedule is the same
    /// slice the merged-order engine computes.
    pub fn new_worker(
        topo: Topology,
        thread_counts: &[usize],
        seed: u64,
        skew_max_ms: f64,
        eps_ms: Millis,
        plan: &ShardPlan,
        my_shard: u32,
        sched: SchedKind,
    ) -> Self {
        let n = topo.n_procs();
        assert_eq!(plan.shard_of.len(), n, "plan must cover every process");
        let mut sim = Self::new(topo, thread_counts, seed, skew_max_ms, eps_ms);
        sim.core.queues = Queues::Single(EventQueue::new(sched));
        sim.core.exec = Some(Box::new(ShardExec {
            shard_of: plan.shard_of.clone(),
            my_shard,
            outbox: Vec::new(),
            outbox_spare: Vec::new(),
            horizon: 0,
        }));
        sim
    }

    /// Install a lowered fault schedule ([`crate::faults::lower`]). The
    /// empty timeline (the default) leaves every run untouched.
    pub fn install_faults(&mut self, timeline: Timeline) {
        self.timeline = timeline;
    }

    /// Register the next actor; ids must line up with the topology's
    /// process order (the experiment runner guarantees this).
    pub fn add_actor(&mut self, actor: Box<dyn Actor>) -> ProcId {
        let id = ProcId(self.actors.len() as u32);
        assert!(
            self.actors.len() < self.core.topo.n_procs(),
            "more actors than topology processes"
        );
        self.actors.push(Some(actor));
        id
    }

    /// Register an actor at an explicit process id (worker shards host a
    /// sparse subset of the topology's processes).
    pub fn add_actor_at(&mut self, id: ProcId, actor: Box<dyn Actor>) {
        assert!(id.idx() < self.core.topo.n_procs(), "id within topology");
        if self.actors.len() <= id.idx() {
            self.actors.resize_with(self.core.topo.n_procs(), || None);
        }
        assert!(self.actors[id.idx()].is_none(), "actor {id:?} registered twice");
        self.actors[id.idx()] = Some(actor);
    }

    pub fn now(&self) -> Time {
        self.core.now
    }

    pub fn stats(&self) -> &SimStats {
        &self.core.stats
    }

    pub fn machines(&self) -> &Machines {
        &self.core.machines
    }

    /// Window barriers executed by the merged-order sharded engine
    /// (0 on the single-queue engine; the threaded engine counts
    /// barriers in its coordinator).
    pub fn barriers(&self) -> u64 {
        match &self.core.queues {
            Queues::Sharded(sq) => sq.barriers,
            Queues::Single(_) => 0,
        }
    }

    /// Events dispatched per shard by the merged-order sharded engine
    /// (empty on the single-queue engine) — the imbalance telemetry.
    pub fn shard_events(&self) -> Vec<u64> {
        match &self.core.queues {
            Queues::Sharded(sq) => sq.shard_events.clone(),
            Queues::Single(_) => Vec::new(),
        }
    }

    fn dispatch(&mut self, ev: Ev) {
        let idx = ev.dst.idx();
        let mut actor = self.actors[idx].take().unwrap_or_else(|| panic!("actor {idx} missing"));
        self.core.cur_seq = ev.seq;
        let mut ctx = Ctx { core: &mut self.core, self_id: ev.dst };
        match ev.kind {
            EvKind::Msg { from, msg } => actor.on_msg(&mut ctx, from, msg),
            EvKind::Timer { tag } => actor.on_timer(&mut ctx, tag),
        }
        self.actors[idx] = Some(actor);
    }

    /// Apply the next due fault transition and, for crash/restart,
    /// deliver the lifecycle hook to the targeted actor (the restart
    /// hook is where a server launches its peer re-sync). On a worker
    /// shard the targeted actor may live elsewhere: the reachability
    /// state still updates (every worker tracks the whole fault view),
    /// only the hook delivery is skipped — the owning shard delivers it.
    fn apply_next_fault(&mut self) {
        let (_, change) = self.timeline.pop().expect("fault transition due");
        self.core.stats.fault_transitions += 1;
        if let Some((proc, hook)) = self.core.faults.apply(&change) {
            let idx = proc as usize;
            let foreign = self
                .core
                .exec
                .as_ref()
                .is_some_and(|ex| ex.shard_of[idx] != ex.my_shard);
            if foreign {
                return;
            }
            let mut actor =
                self.actors[idx].take().unwrap_or_else(|| panic!("actor {idx} missing"));
            let mut ctx = Ctx { core: &mut self.core, self_id: ProcId(proc) };
            actor.on_fault(&mut ctx, hook);
            self.actors[idx] = Some(actor);
        }
    }

    /// Is the next thing to happen a fault transition (rather than a
    /// queued event)? Transitions win ties so a cut at time T affects
    /// messages sent at T.
    fn fault_due(&self) -> Option<Time> {
        let next_fault = self.timeline.peek_at()?;
        match self.core.queues.peek_at() {
            Some(at) if at < next_fault => None,
            _ => Some(next_fault),
        }
    }

    fn start_all(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        if self.core.exec.is_some() {
            // worker shards host a sparse actor subset
            self.actors.resize_with(self.core.topo.n_procs(), || None);
        } else {
            assert_eq!(
                self.actors.len(),
                self.core.topo.n_procs(),
                "actor count must match topology"
            );
        }
        for i in 0..self.actors.len() {
            let Some(mut actor) = self.actors[i].take() else { continue };
            let mut ctx = Ctx { core: &mut self.core, self_id: ProcId(i as u32) };
            actor.on_start(&mut ctx);
            self.actors[i] = Some(actor);
        }
    }

    /// Process every pending event and fault transition with
    /// `at < horizon` and `at <= until`, in merged `(at, seq)` order,
    /// fault transitions winning timestamp ties. With
    /// `horizon = Time::MAX` this *is* the historical serial loop.
    fn drain_window(&mut self, horizon: Time, until: Time) {
        loop {
            if let Some(at) = self.fault_due() {
                if at >= horizon || at > until {
                    break;
                }
                self.core.now = at;
                self.apply_next_fault();
                continue;
            }
            let next_at = match self.core.queues.peek_at() {
                Some(at) => at,
                None => break,
            };
            if next_at >= horizon || next_at > until {
                break;
            }
            let ev = self.core.queues.pop_min().expect("peeked queue non-empty");
            self.core.now = ev.at;
            self.core.stats.events += 1;
            self.dispatch(ev);
        }
    }

    /// The conservative window loop of the merged-order sharded engine:
    /// each barrier flushes the cross-shard outboxes, anchors the next
    /// window at the globally-minimal pending timestamp `t`, and drains
    /// `[t, t + W)`. Every window processes at least the anchoring item
    /// (`W > 0`), so the loop terminates.
    fn run_windows(&mut self, until: Time) {
        loop {
            self.core.queues.flush();
            let next = match (self.core.queues.peek_at(), self.timeline.peek_at()) {
                (Some(e), Some(f)) => e.min(f),
                (Some(e), None) => e,
                (None, Some(f)) => f,
                (None, None) => break,
            };
            if next > until {
                break;
            }
            let Queues::Sharded(sq) = &mut self.core.queues else {
                unreachable!("run_windows drives the sharded queues")
            };
            let horizon = next.saturating_add(sq.lookahead);
            sq.barriers += 1;
            sq.horizon = horizon;
            self.drain_window(horizon, until);
            let Queues::Sharded(sq) = &mut self.core.queues else { unreachable!() };
            sq.horizon = 0;
        }
    }

    /// Run until virtual time `until` (events at t > until stay queued).
    pub fn run_until(&mut self, until: Time) {
        self.start_all();
        match &self.core.queues {
            Queues::Single(_) => self.drain_window(Time::MAX, until),
            Queues::Sharded(_) => self.run_windows(until),
        }
        self.core.now = until;
    }

    /// Drain every queued event (until the system goes quiet).
    pub fn run_to_quiescence(&mut self, hard_cap: Time) {
        self.start_all();
        loop {
            self.core.queues.flush();
            if let Some(at) = self.fault_due() {
                if at > hard_cap {
                    break;
                }
                self.core.now = at;
                self.apply_next_fault();
                continue;
            }
            let Some(ev) = self.core.queues.pop_min() else { break };
            if ev.at > hard_cap {
                break;
            }
            self.core.now = ev.at;
            self.core.stats.events += 1;
            self.dispatch(ev);
        }
    }

    // --- worker-shard protocol (driven by `crate::sim::shard`) ---

    /// Threaded-engine face of start-up: deliver `on_start` to the
    /// hosted actors. Cross-shard sends made during start-up land in the
    /// outbox like any others.
    pub fn prime(&mut self) {
        self.start_all();
    }

    /// Run one conservative window: process every local event and fault
    /// transition with `at < horizon` (clamped to `until`), staging
    /// cross-shard sends for the next barrier. [`Sim::prime`] first.
    pub fn run_window(&mut self, horizon: Time, until: Time) {
        debug_assert!(self.started, "prime() before run_window()");
        if let Some(ex) = &mut self.core.exec {
            ex.horizon = horizon;
        }
        self.drain_window(horizon, until);
    }

    /// Accept a cross-shard wire envelope; the sender's shard already
    /// assigned its `(at, seq)` key. The hot `Request` path re-wraps its
    /// payload into a pooled `Rc` ([`Ctx::recycle_op`]) instead of
    /// allocating a fresh one per ingested message.
    pub fn ingest(&mut self, ev: WireEv) {
        use std::rc::Rc;
        let WireEv { at, seq, dst, from, msg } = ev;
        debug_assert!(
            self.core
                .exec
                .as_ref()
                .is_some_and(|ex| ex.shard_of[dst.idx()] == ex.my_shard),
            "envelope routed to the wrong shard"
        );
        let msg = match msg {
            WireMsg::Request { req, op, hvc } => {
                let op = match self.core.op_pool.pop() {
                    Some(mut rc) => {
                        *Rc::get_mut(&mut rc).expect("pooled Rc is sole-owned") = op;
                        rc
                    }
                    None => Rc::new(op),
                };
                Msg::Request { req, op, hvc: hvc.map(Rc::new) }
            }
            other => other.into_msg(),
        };
        self.core.queues.push(Ev { at, seq, dst, kind: EvKind::Msg { from, msg } }, dst);
    }

    /// Take the staged cross-shard envelopes (the barrier exchange). The
    /// spare buffer recycled via [`Sim::supply_outbox`] becomes the new
    /// outbox, so steady-state windows allocate no envelope vectors.
    pub fn drain_outbox(&mut self) -> Vec<WireEv> {
        match &mut self.core.exec {
            Some(ex) => std::mem::replace(&mut ex.outbox, std::mem::take(&mut ex.outbox_spare)),
            None => Vec::new(),
        }
    }

    /// Hand a drained envelope buffer back for reuse (the coordinator's
    /// half of the envelope free-list).
    pub fn supply_outbox(&mut self, mut buf: Vec<WireEv>) {
        buf.clear();
        if let Some(ex) = &mut self.core.exec {
            ex.outbox_spare = buf;
        }
    }

    /// Earliest pending local work (queued event or fault transition) —
    /// the coordinator anchors the next window at the minimum across
    /// shards.
    pub fn next_pending_at(&self) -> Option<Time> {
        match (self.core.queues.peek_at(), self.timeline.peek_at()) {
            (Some(e), Some(f)) => Some(e.min(f)),
            (a, b) => a.or(b),
        }
    }

    /// Threaded-engine epilogue: pin virtual time to the run end
    /// (mirrors the tail of [`Sim::run_until`]) before extraction.
    pub fn finish(&mut self, until: Time) {
        self.core.now = until;
    }

    /// Direct (test-only) access to an actor.
    pub fn actor_mut(&mut self, id: ProcId) -> &mut Box<dyn Actor> {
        self.actors[id.idx()].as_mut().expect("actor present")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::net::Topology;
    use crate::sim::{MS, SEC};
    use crate::store::protocol::{ServerOp, ServerReply};
    use std::cell::RefCell;
    use std::rc::Rc;

    /// Ping-pong actor pair used to exercise the loop.
    struct Pinger {
        peer: ProcId,
        remaining: u32,
        log: Rc<RefCell<Vec<(Time, u64)>>>,
    }

    impl Actor for Pinger {
        fn on_start(&mut self, ctx: &mut Ctx) {
            if self.remaining > 0 {
                ctx.send(
                    self.peer,
                    Msg::Request { req: self.remaining as u64, op: Rc::new(ServerOp::Get(crate::store::value::KeyId(0))), hvc: None },
                );
            }
        }
        fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg) {
            match msg {
                Msg::Request { req, .. } => {
                    ctx.send(from, Msg::Reply { req, reply: ServerReply::PutAck, hvc: Rc::new(crate::clock::hvc::Hvc::new(0, 1, ctx.pt_ms(), 0)) });
                }
                Msg::Reply { req, .. } => {
                    self.log.borrow_mut().push((ctx.now(), req));
                    self.remaining -= 1;
                    if self.remaining > 0 {
                        ctx.send(
                            self.peer,
                            Msg::Request { req: self.remaining as u64, op: Rc::new(ServerOp::Get(crate::store::value::KeyId(0))), hvc: None },
                        );
                    }
                }
                _ => {}
            }
        }
    }

    fn two_proc_sim(seed: u64) -> (Sim, Rc<RefCell<Vec<(Time, u64)>>>) {
        let topo = Topology::flat(2, 10.0);
        let mut sim = Sim::new(topo, &[1, 1], seed, 0.0, 0);
        let log = Rc::new(RefCell::new(Vec::new()));
        sim.add_actor(Box::new(Pinger { peer: ProcId(1), remaining: 5, log: log.clone() }));
        sim.add_actor(Box::new(Pinger { peer: ProcId(0), remaining: 0, log: log.clone() }));
        (sim, log)
    }

    /// The same pair under the merged-order sharded engine, one pinger
    /// per shard.
    fn two_proc_sharded(seed: u64, sched: SchedKind) -> (Sim, Rc<RefCell<Vec<(Time, u64)>>>) {
        let topo = Topology::flat(2, 10.0);
        let plan = ShardPlan::build(&topo, vec![0, 1]).unwrap();
        let mut sim = Sim::new_sharded(topo, &[1, 1], seed, 0.0, 0, &plan, sched);
        let log = Rc::new(RefCell::new(Vec::new()));
        sim.add_actor(Box::new(Pinger { peer: ProcId(1), remaining: 5, log: log.clone() }));
        sim.add_actor(Box::new(Pinger { peer: ProcId(0), remaining: 0, log: log.clone() }));
        (sim, log)
    }

    #[test]
    fn ping_pong_round_trips() {
        let (mut sim, log) = two_proc_sim(1);
        sim.run_until(10 * SEC);
        let log = log.borrow();
        assert_eq!(log.len(), 5);
        // each round trip is >= 20 ms (2 x 10 ms one-way)
        assert!(log[0].0 >= 20 * MS);
        for w in log.windows(2) {
            assert!(w[1].0 > w[0].0, "times must advance");
        }
    }

    #[test]
    fn determinism_same_seed() {
        let (mut a, la) = two_proc_sim(42);
        let (mut b, lb) = two_proc_sim(42);
        a.run_until(SEC);
        b.run_until(SEC);
        assert_eq!(*la.borrow(), *lb.borrow());
    }

    #[test]
    fn different_seed_different_latencies() {
        let (mut a, la) = two_proc_sim(1);
        let (mut b, lb) = two_proc_sim(2);
        a.run_until(SEC);
        b.run_until(SEC);
        assert_ne!(*la.borrow(), *lb.borrow());
    }

    #[test]
    fn timer_delivery() {
        struct T {
            fired: Rc<RefCell<Vec<u64>>>,
        }
        impl Actor for T {
            fn on_start(&mut self, ctx: &mut Ctx) {
                ctx.schedule(5 * MS, 7);
                ctx.schedule(MS, 3);
            }
            fn on_msg(&mut self, _: &mut Ctx, _: ProcId, _: Msg) {}
            fn on_timer(&mut self, _ctx: &mut Ctx, tag: u64) {
                self.fired.borrow_mut().push(tag);
            }
        }
        let topo = Topology::flat(1, 1.0);
        let mut sim = Sim::new(topo, &[1], 0, 0.0, 0);
        let fired = Rc::new(RefCell::new(Vec::new()));
        sim.add_actor(Box::new(T { fired: fired.clone() }));
        sim.run_until(SEC);
        assert_eq!(*fired.borrow(), vec![3, 7], "timers fire in time order");
    }

    #[test]
    fn stats_count_messages() {
        let (mut sim, _) = two_proc_sim(3);
        sim.run_until(10 * SEC);
        assert_eq!(sim.stats().sent_class(MsgClass::Request), 5);
        assert_eq!(sim.stats().sent_class(MsgClass::Reply), 5);
        assert!(sim.stats().events >= 10);
    }

    #[test]
    fn installed_partition_cuts_the_ping_pong() {
        use crate::faults::state::Change;
        // cut the two procs apart just after the first round trip; the
        // pinger has no retransmit, so the chain stalls at the cut
        let (mut sim, log) = two_proc_sim(1);
        sim.install_faults(Timeline::new(vec![(
            25 * MS,
            Change::PartitionStart { id: 0, group_of: vec![0, 1] },
        )]));
        sim.run_until(10 * SEC);
        let n = log.borrow().len();
        assert!(n < 5, "the cut must stall the exchange (got {n} round trips)");
        assert!(sim.stats().fault_dropped > 0, "a message crossed the cut");
        assert_eq!(sim.stats().fault_transitions, 1);
    }

    #[test]
    fn empty_timeline_is_bit_identical_to_no_timeline() {
        let (mut a, la) = two_proc_sim(11);
        let (mut b, lb) = two_proc_sim(11);
        b.install_faults(Timeline::empty());
        a.run_until(SEC);
        b.run_until(SEC);
        assert_eq!(*la.borrow(), *lb.borrow());
        assert_eq!(a.stats().events, b.stats().events);
        assert_eq!(b.stats().fault_dropped, 0);
    }

    #[test]
    fn sharded_matches_serial_bit_for_bit() {
        for sched in [SchedKind::Heap, SchedKind::Calendar] {
            let (mut a, la) = two_proc_sim(42);
            let (mut b, lb) = two_proc_sharded(42, sched);
            a.run_until(SEC);
            b.run_until(SEC);
            assert_eq!(*la.borrow(), *lb.borrow(), "sched {sched:?}");
            assert_eq!(a.stats().events, b.stats().events);
            assert_eq!(a.stats().sent, b.stats().sent);
            assert!(b.barriers() > 0, "windows actually ran");
            assert_eq!(b.shard_events().iter().sum::<u64>(), b.stats().events);
        }
    }

    #[test]
    fn sharded_faulted_matches_serial() {
        use crate::faults::state::Change;
        let cut = || {
            Timeline::new(vec![(
                25 * MS,
                Change::PartitionStart { id: 0, group_of: vec![0, 1] },
            )])
        };
        let (mut a, la) = two_proc_sim(7);
        a.install_faults(cut());
        let (mut b, lb) = two_proc_sharded(7, SchedKind::Heap);
        b.install_faults(cut());
        a.run_until(10 * SEC);
        b.run_until(10 * SEC);
        assert_eq!(*la.borrow(), *lb.borrow());
        assert_eq!(a.stats().fault_dropped, b.stats().fault_dropped);
        assert_eq!(a.stats().fault_transitions, b.stats().fault_transitions);
    }

    #[test]
    fn worker_pair_reproduces_the_exchange() {
        // Drive the two-shard worker protocol by hand: each worker hosts
        // one pinger; the coordinator loop below is the minimal version
        // of `shard::run_threaded` (in-thread, no channels).
        let mk_worker = |shard: u32, log: &Rc<RefCell<Vec<(Time, u64)>>>| {
            let topo = Topology::flat(2, 10.0);
            let plan = ShardPlan::build(&topo, vec![0, 1]).unwrap();
            let mut sim =
                Sim::new_worker(topo, &[1, 1], 9, 0.0, 0, &plan, shard, SchedKind::Heap);
            let id = ProcId(shard);
            let peer = ProcId(1 - shard);
            let remaining = if shard == 0 { 5 } else { 0 };
            sim.add_actor_at(id, Box::new(Pinger { peer, remaining, log: log.clone() }));
            sim
        };
        let log = Rc::new(RefCell::new(Vec::new()));
        let mut w: Vec<Sim> = (0..2).map(|s| mk_worker(s, &log)).collect();
        let plan = ShardPlan::build(&Topology::flat(2, 10.0), vec![0, 1]).unwrap();
        let until = SEC;
        for s in &mut w {
            s.prime();
        }
        let mut pending: Vec<Vec<WireEv>> = vec![Vec::new(), Vec::new()];
        let mut route = |pending: &mut Vec<Vec<WireEv>>, evs: Vec<WireEv>| {
            for ev in evs {
                pending[plan.shard_of[ev.dst.idx()] as usize].push(ev);
            }
        };
        for s in &mut w {
            let out = s.drain_outbox();
            route(&mut pending, out);
        }
        loop {
            let mut t: Option<Time> = None;
            for s in &w {
                t = match (t, s.next_pending_at()) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
            }
            for p in &pending {
                for ev in p {
                    t = Some(t.map_or(ev.at, |a| a.min(ev.at)));
                }
            }
            let Some(t) = t else { break };
            if t > until {
                break;
            }
            let horizon = t.saturating_add(plan.lookahead);
            for (k, s) in w.iter_mut().enumerate() {
                for ev in std::mem::take(&mut pending[k]) {
                    s.ingest(ev);
                }
                s.run_window(horizon, until);
            }
            for s in &mut w {
                let out = s.drain_outbox();
                route(&mut pending, out);
            }
        }
        for s in &mut w {
            s.finish(until);
        }
        // the exchange completed with the serial round-trip structure
        let log = log.borrow();
        assert_eq!(log.len(), 5);
        assert!(log[0].0 >= 20 * MS);
        for pair in log.windows(2) {
            assert!(pair[1].0 > pair[0].0);
        }
        let events: u64 = w.iter().map(|s| s.stats().events).sum();
        assert_eq!(events, 10, "5 requests + 5 replies dispatched across the two shards");
    }

    #[test]
    fn stats_merge_is_componentwise() {
        let mut a = SimStats::default();
        a.sent[0] = 3;
        a.dropped[1] = 1;
        a.events = 10;
        a.fault_dropped = 1;
        a.fault_transitions = 4;
        let mut b = SimStats::default();
        b.sent[0] = 2;
        b.events = 7;
        b.fault_transitions = 4;
        a.merge(&b);
        assert_eq!(a.sent[0], 5);
        assert_eq!(a.dropped[1], 1);
        assert_eq!(a.events, 17);
        assert_eq!(a.fault_dropped, 1);
        assert_eq!(a.fault_transitions, 4, "max, not sum: both applied the same timeline");
    }
}
