//! The discrete-event executor: a binary heap of timestamped events,
//! actors dispatched one event at a time, deterministic under a seed.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::clock::hvc::Millis;
use crate::faults::state::{FaultHook, FaultState, Timeline};
use crate::sim::clockmodel::ClockModel;
use crate::sim::machine::Machines;
use crate::sim::msg::{Msg, MsgClass, N_MSG_CLASSES};
use crate::sim::net::Topology;
use crate::sim::{ProcId, Time};
use crate::util::rng::Rng;

/// A simulated process.
pub trait Actor {
    /// Called once before the event loop starts.
    fn on_start(&mut self, _ctx: &mut Ctx) {}
    /// A message arrived from `from`.
    fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg);
    /// A self-scheduled timer fired.
    fn on_timer(&mut self, _ctx: &mut Ctx, _tag: u64) {}
    /// A fault transition targeted this process directly (crash /
    /// restart from the installed [`Timeline`]). Timers and in-flight
    /// messages keep being *delivered* to a crashed actor — a real
    /// process cannot intercept the network — so actors that can crash
    /// must gate their handlers on the lifecycle state this hook sets.
    fn on_fault(&mut self, _ctx: &mut Ctx, _hook: FaultHook) {}
    /// Downcast hook so the experiment runner can pull stats after a run.
    fn as_any(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

#[derive(Debug)]
enum EvKind {
    Msg { from: ProcId, msg: Msg },
    Timer { tag: u64 },
}

#[derive(Debug)]
struct Ev {
    at: Time,
    seq: u64,
    dst: ProcId,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // ties broken by insertion order → deterministic FIFO
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Message-traffic counters.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub sent: [u64; N_MSG_CLASSES],
    pub dropped: [u64; N_MSG_CLASSES],
    pub events: u64,
    /// messages dropped by the fault model (partition cut, crashed
    /// endpoint, drop burst) — a subset of `dropped`
    pub fault_dropped: u64,
    /// fault-state transitions applied from the installed timeline
    pub fault_transitions: u64,
}

/// Nominal serialized size per message class (bytes). This is an
/// *allocation/traffic proxy* for the perf harness (`exp/perfjson`), not
/// a wire protocol: requests/replies count their headers plus a typical
/// single-version payload, candidates and violations their clock
/// intervals and witness sets, sync chunks a small key batch. The values
/// only need to be stable across runs so `sent_bytes_proxy` trends are
/// comparable PR-over-PR.
pub const MSG_CLASS_BYTES: [u64; N_MSG_CLASSES] = [
    96,    // Request: op + key + version clock + piggy-backed HVC ref
    120,   // Reply: status + sibling list (typical single version) + HVC
    256,   // Candidate: HVC interval + partial state values
    512,   // Violation: witness set (several candidates)
    32,    // Rollback control
    192,   // RegisterPred: predicate spec
    1_024, // Sync: re-sync chunk (key batch)
    40,    // Adapt: epoch announce/ack or a signal sample
];

impl SimStats {
    pub fn sent_total(&self) -> u64 {
        self.sent.iter().sum()
    }
    pub fn sent_class(&self, c: MsgClass) -> u64 {
        self.sent[c as usize]
    }
    /// Total nominal bytes sent ([`MSG_CLASS_BYTES`] per class) — the
    /// perf harness's allocation proxy.
    pub fn sent_bytes_proxy(&self) -> u64 {
        self.sent
            .iter()
            .zip(MSG_CLASS_BYTES.iter())
            .map(|(n, b)| n * b)
            .sum()
    }
}

/// Everything the actors share; split from the actor table so an actor can
/// hold `&mut Ctx` while being itself borrowed.
pub struct SimCore {
    now: Time,
    seq: u64,
    heap: BinaryHeap<Reverse<Ev>>,
    pub topo: Topology,
    pub clocks: ClockModel,
    pub machines: Machines,
    rng_net: Rng,
    rng_actors: Vec<Rng>,
    pub stats: SimStats,
    /// HVC ε (ms) — global config, read by servers/monitors via ctx
    pub eps_ms: Millis,
    /// time-varying reachability view ([`crate::faults`]); quiet unless
    /// a fault timeline is installed and a window is active
    pub faults: FaultState,
}

/// Per-dispatch context handed to actors.
pub struct Ctx<'a> {
    core: &'a mut SimCore,
    pub self_id: ProcId,
}

impl<'a> Ctx<'a> {
    #[inline]
    pub fn now(&self) -> Time {
        self.core.now
    }

    /// This process's physical clock, in ms (HVC granularity).
    #[inline]
    pub fn pt_ms(&self) -> Millis {
        self.core.clocks.pt_ms(self.self_id.idx(), self.core.now)
    }

    #[inline]
    pub fn eps_ms(&self) -> Millis {
        self.core.eps_ms
    }

    /// This actor's private RNG stream.
    #[inline]
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.core.rng_actors[self.self_id.idx()]
    }

    /// Send a message: delivery at `now + net latency` (or never, if the
    /// loss model drops it).
    pub fn send(&mut self, dst: ProcId, msg: Msg) {
        self.send_after(0, dst, msg);
    }

    /// Send after holding the message locally for `delay` ns (e.g. a reply
    /// leaving only once the CPU finished the request).
    ///
    /// The fault view is consulted first: a partitioned or crashed
    /// endpoint silently loses the message (feeding the quorum timeout
    /// path in the client), an active drop burst loses it with extra
    /// probability, and a slow node stretches its delivery latency. With
    /// no active fault none of these checks consumes an RNG draw, so a
    /// run under `FaultPlan::none()` is bit-identical to the pre-fault
    /// code path.
    pub fn send_after(&mut self, delay: Time, dst: ProcId, msg: Msg) {
        let class = msg.class() as usize;
        self.core.stats.sent[class] += 1;
        if !self.core.faults.quiet() {
            if !self.core.faults.reachable(self.self_id, dst) {
                self.core.stats.dropped[class] += 1;
                self.core.stats.fault_dropped += 1;
                return;
            }
            // bursts are per machine-pair: the link between two server
            // machines carries candidate traffic to their co-located
            // monitors, not just server↔server re-sync chunks
            let burst = self.core.faults.burst_prob(
                self.core.topo.machine_of[self.self_id.idx()],
                self.core.topo.machine_of[dst.idx()],
            );
            if burst > 0.0 && self.core.rng_net.chance(burst) {
                self.core.stats.dropped[class] += 1;
                self.core.stats.fault_dropped += 1;
                return;
            }
        }
        if self.core.topo.drops(self.self_id, dst, &mut self.core.rng_net) {
            self.core.stats.dropped[class] += 1;
            return;
        }
        let mut lat = self.core.topo.latency(self.self_id, dst, &mut self.core.rng_net);
        if !self.core.faults.quiet() {
            // a degraded NIC slows the node's *network* links only —
            // same-machine loopback is exempt, mirroring the loss model
            let same_machine = self.core.topo.machine_of[self.self_id.idx()]
                == self.core.topo.machine_of[dst.idx()];
            let factor = self.core.faults.latency_factor(self.self_id, dst);
            if factor != 1.0 && !same_machine {
                lat = (lat as f64 * factor) as Time;
            }
        }
        let at = self.core.now + delay + lat;
        self.core.push(at, dst, EvKind::Msg { from: self.self_id, msg });
    }

    /// Schedule a timer for this actor.
    pub fn schedule(&mut self, delay: Time, tag: u64) {
        let at = self.core.now + delay;
        let dst = self.self_id;
        self.core.push(at, dst, EvKind::Timer { tag });
    }

    /// Claim `svc` ns of CPU on this actor's machine (FIFO across all
    /// co-located actors). Returns the completion time; callers typically
    /// `send_after(done - now, …)`.
    pub fn cpu(&mut self, svc: Time) -> Time {
        let m = self.core.topo.machine_of[self.self_id.idx()] as usize;
        self.core.machines.claim(m, self.core.now, svc)
    }

    /// Completion delay (ns from now) for `svc` ns of CPU work.
    pub fn cpu_delay(&mut self, svc: Time) -> Time {
        self.cpu(svc) - self.core.now
    }

    pub fn topo(&self) -> &Topology {
        &self.core.topo
    }

    pub fn stats(&self) -> &SimStats {
        &self.core.stats
    }
}

impl SimCore {
    fn push(&mut self, at: Time, dst: ProcId, kind: EvKind) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Ev { at, seq, dst, kind }));
    }
}

/// The simulation: topology + machines + actor table + event loop.
pub struct Sim {
    core: SimCore,
    actors: Vec<Option<Box<dyn Actor>>>,
    started: bool,
    /// lowered fault schedule; empty unless installed
    timeline: Timeline,
}

impl Sim {
    pub fn new(topo: Topology, thread_counts: &[usize], seed: u64, skew_max_ms: f64, eps_ms: Millis) -> Self {
        let n = topo.n_procs();
        let mut seeder = Rng::new(seed);
        let clocks = if skew_max_ms > 0.0 {
            ClockModel::new(n, skew_max_ms, &mut seeder)
        } else {
            ClockModel::perfect(n)
        };
        let rng_actors = (0..n).map(|i| Rng::stream(seed, 0x1000 + i as u64)).collect();
        Self {
            core: SimCore {
                now: 0,
                seq: 0,
                heap: BinaryHeap::new(),
                topo,
                clocks,
                machines: Machines::new(thread_counts),
                rng_net: Rng::stream(seed, 0xFACE),
                rng_actors,
                stats: SimStats::default(),
                eps_ms,
                faults: FaultState::new(n),
            },
            actors: Vec::new(),
            started: false,
            timeline: Timeline::empty(),
        }
    }

    /// Install a lowered fault schedule ([`crate::faults::lower`]). The
    /// empty timeline (the default) leaves every run untouched.
    pub fn install_faults(&mut self, timeline: Timeline) {
        self.timeline = timeline;
    }

    /// Register the next actor; ids must line up with the topology's
    /// process order (the experiment runner guarantees this).
    pub fn add_actor(&mut self, actor: Box<dyn Actor>) -> ProcId {
        let id = ProcId(self.actors.len() as u32);
        assert!(
            self.actors.len() < self.core.topo.n_procs(),
            "more actors than topology processes"
        );
        self.actors.push(Some(actor));
        id
    }

    pub fn now(&self) -> Time {
        self.core.now
    }

    pub fn stats(&self) -> &SimStats {
        &self.core.stats
    }

    pub fn machines(&self) -> &Machines {
        &self.core.machines
    }

    fn dispatch(&mut self, ev: Ev) {
        let idx = ev.dst.idx();
        let mut actor = self.actors[idx].take().unwrap_or_else(|| panic!("actor {idx} missing"));
        let mut ctx = Ctx { core: &mut self.core, self_id: ev.dst };
        match ev.kind {
            EvKind::Msg { from, msg } => actor.on_msg(&mut ctx, from, msg),
            EvKind::Timer { tag } => actor.on_timer(&mut ctx, tag),
        }
        self.actors[idx] = Some(actor);
    }

    /// Apply the next due fault transition and, for crash/restart,
    /// deliver the lifecycle hook to the targeted actor (the restart
    /// hook is where a server launches its peer re-sync).
    fn apply_next_fault(&mut self) {
        let (_, change) = self.timeline.pop().expect("fault transition due");
        self.core.stats.fault_transitions += 1;
        if let Some((proc, hook)) = self.core.faults.apply(&change) {
            let idx = proc as usize;
            let mut actor =
                self.actors[idx].take().unwrap_or_else(|| panic!("actor {idx} missing"));
            let mut ctx = Ctx { core: &mut self.core, self_id: ProcId(proc) };
            actor.on_fault(&mut ctx, hook);
            self.actors[idx] = Some(actor);
        }
    }

    /// Is the next thing to happen a fault transition (rather than a
    /// heap event)? Transitions win ties so a cut at time T affects
    /// messages sent at T.
    fn fault_due(&self) -> Option<Time> {
        let next_fault = self.timeline.peek_at()?;
        match self.core.heap.peek() {
            Some(Reverse(ev)) if ev.at < next_fault => None,
            _ => Some(next_fault),
        }
    }

    fn start_all(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        assert_eq!(
            self.actors.len(),
            self.core.topo.n_procs(),
            "actor count must match topology"
        );
        for i in 0..self.actors.len() {
            let mut actor = self.actors[i].take().unwrap();
            let mut ctx = Ctx { core: &mut self.core, self_id: ProcId(i as u32) };
            actor.on_start(&mut ctx);
            self.actors[i] = Some(actor);
        }
    }

    /// Run until virtual time `until` (events at t > until stay queued).
    pub fn run_until(&mut self, until: Time) {
        self.start_all();
        loop {
            if let Some(at) = self.fault_due() {
                if at > until {
                    break;
                }
                self.core.now = at;
                self.apply_next_fault();
                continue;
            }
            let next_at = match self.core.heap.peek() {
                Some(Reverse(ev)) => ev.at,
                None => break,
            };
            if next_at > until {
                break;
            }
            let Reverse(ev) = self.core.heap.pop().unwrap();
            self.core.now = ev.at;
            self.core.stats.events += 1;
            self.dispatch(ev);
        }
        self.core.now = until;
    }

    /// Drain every queued event (until the system goes quiet).
    pub fn run_to_quiescence(&mut self, hard_cap: Time) {
        self.start_all();
        loop {
            if let Some(at) = self.fault_due() {
                if at > hard_cap {
                    break;
                }
                self.core.now = at;
                self.apply_next_fault();
                continue;
            }
            let Some(Reverse(ev)) = self.core.heap.pop() else { break };
            if ev.at > hard_cap {
                break;
            }
            self.core.now = ev.at;
            self.core.stats.events += 1;
            self.dispatch(ev);
        }
    }

    /// Direct (test-only) access to an actor.
    pub fn actor_mut(&mut self, id: ProcId) -> &mut Box<dyn Actor> {
        self.actors[id.idx()].as_mut().expect("actor present")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::net::Topology;
    use crate::sim::{MS, SEC};
    use crate::store::protocol::{ServerOp, ServerReply};
    use std::cell::RefCell;
    use std::rc::Rc;

    /// Ping-pong actor pair used to exercise the loop.
    struct Pinger {
        peer: ProcId,
        remaining: u32,
        log: Rc<RefCell<Vec<(Time, u64)>>>,
    }

    impl Actor for Pinger {
        fn on_start(&mut self, ctx: &mut Ctx) {
            if self.remaining > 0 {
                ctx.send(
                    self.peer,
                    Msg::Request { req: self.remaining as u64, op: Rc::new(ServerOp::Get(crate::store::value::KeyId(0))), hvc: None },
                );
            }
        }
        fn on_msg(&mut self, ctx: &mut Ctx, from: ProcId, msg: Msg) {
            match msg {
                Msg::Request { req, .. } => {
                    ctx.send(from, Msg::Reply { req, reply: ServerReply::PutAck, hvc: Rc::new(crate::clock::hvc::Hvc::new(0, 1, ctx.pt_ms(), 0)) });
                }
                Msg::Reply { req, .. } => {
                    self.log.borrow_mut().push((ctx.now(), req));
                    self.remaining -= 1;
                    if self.remaining > 0 {
                        ctx.send(
                            self.peer,
                            Msg::Request { req: self.remaining as u64, op: Rc::new(ServerOp::Get(crate::store::value::KeyId(0))), hvc: None },
                        );
                    }
                }
                _ => {}
            }
        }
    }

    fn two_proc_sim(seed: u64) -> (Sim, Rc<RefCell<Vec<(Time, u64)>>>) {
        let topo = Topology::flat(2, 10.0);
        let mut sim = Sim::new(topo, &[1, 1], seed, 0.0, 0);
        let log = Rc::new(RefCell::new(Vec::new()));
        sim.add_actor(Box::new(Pinger { peer: ProcId(1), remaining: 5, log: log.clone() }));
        sim.add_actor(Box::new(Pinger { peer: ProcId(0), remaining: 0, log: log.clone() }));
        (sim, log)
    }

    #[test]
    fn ping_pong_round_trips() {
        let (mut sim, log) = two_proc_sim(1);
        sim.run_until(10 * SEC);
        let log = log.borrow();
        assert_eq!(log.len(), 5);
        // each round trip is >= 20 ms (2 x 10 ms one-way)
        assert!(log[0].0 >= 20 * MS);
        for w in log.windows(2) {
            assert!(w[1].0 > w[0].0, "times must advance");
        }
    }

    #[test]
    fn determinism_same_seed() {
        let (mut a, la) = two_proc_sim(42);
        let (mut b, lb) = two_proc_sim(42);
        a.run_until(SEC);
        b.run_until(SEC);
        assert_eq!(*la.borrow(), *lb.borrow());
    }

    #[test]
    fn different_seed_different_latencies() {
        let (mut a, la) = two_proc_sim(1);
        let (mut b, lb) = two_proc_sim(2);
        a.run_until(SEC);
        b.run_until(SEC);
        assert_ne!(*la.borrow(), *lb.borrow());
    }

    #[test]
    fn timer_delivery() {
        struct T {
            fired: Rc<RefCell<Vec<u64>>>,
        }
        impl Actor for T {
            fn on_start(&mut self, ctx: &mut Ctx) {
                ctx.schedule(5 * MS, 7);
                ctx.schedule(MS, 3);
            }
            fn on_msg(&mut self, _: &mut Ctx, _: ProcId, _: Msg) {}
            fn on_timer(&mut self, _ctx: &mut Ctx, tag: u64) {
                self.fired.borrow_mut().push(tag);
            }
        }
        let topo = Topology::flat(1, 1.0);
        let mut sim = Sim::new(topo, &[1], 0, 0.0, 0);
        let fired = Rc::new(RefCell::new(Vec::new()));
        sim.add_actor(Box::new(T { fired: fired.clone() }));
        sim.run_until(SEC);
        assert_eq!(*fired.borrow(), vec![3, 7], "timers fire in time order");
    }

    #[test]
    fn stats_count_messages() {
        let (mut sim, _) = two_proc_sim(3);
        sim.run_until(10 * SEC);
        assert_eq!(sim.stats().sent_class(MsgClass::Request), 5);
        assert_eq!(sim.stats().sent_class(MsgClass::Reply), 5);
        assert!(sim.stats().events >= 10);
    }

    #[test]
    fn installed_partition_cuts_the_ping_pong() {
        use crate::faults::state::Change;
        // cut the two procs apart just after the first round trip; the
        // pinger has no retransmit, so the chain stalls at the cut
        let (mut sim, log) = two_proc_sim(1);
        sim.install_faults(Timeline::new(vec![(
            25 * MS,
            Change::PartitionStart { id: 0, group_of: vec![0, 1] },
        )]));
        sim.run_until(10 * SEC);
        let n = log.borrow().len();
        assert!(n < 5, "the cut must stall the exchange (got {n} round trips)");
        assert!(sim.stats().fault_dropped > 0, "a message crossed the cut");
        assert_eq!(sim.stats().fault_transitions, 1);
    }

    #[test]
    fn empty_timeline_is_bit_identical_to_no_timeline() {
        let (mut a, la) = two_proc_sim(11);
        let (mut b, lb) = two_proc_sim(11);
        b.install_faults(Timeline::empty());
        a.run_until(SEC);
        b.run_until(SEC);
        assert_eq!(*la.borrow(), *lb.borrow());
        assert_eq!(a.stats().events, b.stats().events);
        assert_eq!(b.stats().fault_dropped, 0);
    }
}
