//! The message vocabulary of the simulated distributed system: the
//! Voldemort-style client↔server protocol, monitor candidates/violations,
//! rollback control, and predicate registration (for predicates inferred
//! at runtime from variable names).

use std::rc::Rc;

use crate::client::consistency::ConsistencyCfg;
use crate::clock::hvc::{Hvc, Millis};
use crate::detect::candidate::{Candidate, ViolationReport};
use crate::predicate::spec::PredicateSpec;
use crate::store::protocol::{ServerOp, ServerReply};
use crate::store::value::{KeyId, Versioned};

/// Rollback / recovery control messages (controller ↔ servers/clients).
#[derive(Debug, Clone)]
pub enum RollbackMsg {
    /// controller → clients: predicate `pred` was violated at ~`t_violate_ms`;
    /// abort the current task / roll back. `epoch` identifies the recovery.
    Notify { epoch: u64, t_violate_ms: Millis },
    /// controller → servers: stop serving while a restore is in progress.
    Freeze { epoch: u64 },
    /// server → controller
    FrozenAck { epoch: u64 },
    /// controller → servers: restore state to the latest snapshot/cut
    /// before `to_ms` (window-log or periodic snapshot, server-side).
    Restore { epoch: u64, to_ms: Millis },
    /// server → controller (false ⇒ the window-log did not reach back far
    /// enough and a full snapshot restore was used instead)
    RestoredAck { epoch: u64, from_window_log: bool },
    /// controller → servers and clients: resume computation.
    Resume { epoch: u64 },
}

/// Crash-recovery re-sync (restarting server ↔ live preference-list
/// peers, [`crate::faults`]): a replica that restarts after a crash has
/// lost all volatile state and catches up on the partitions it owns
/// before serving again (Dynamo-style replica synchronization).
#[derive(Debug, Clone)]
pub enum SyncMsg {
    /// restarting server `server` → every peer: send me your copies of
    /// the keys I own. `epoch` guards against stale chunks from an
    /// earlier recovery.
    Request { epoch: u64, server: u16 },
    /// peer → restarting server: sibling lists of the shared keys,
    /// sorted by key id so the merge order is deterministic.
    Chunk { epoch: u64, data: Vec<(KeyId, Vec<Versioned>)> },
}

/// Adaptive-consistency control plane ([`crate::adapt`]): the epoch
/// protocol that moves the whole cluster between quorum configurations
/// at runtime, plus the signal feed from the rollback controller.
#[derive(Debug, Clone)]
pub enum AdaptMsg {
    /// adapt controller → every client: consistency epoch `epoch` begins —
    /// open new quorum calls under `cfg`. In-flight calls finish under the
    /// epoch they were issued in (each [`crate::client::quorum::QuorumCall`]
    /// carries its own config), and the announce is re-sent each signal
    /// window until acked so clients cut off by a partition converge
    /// after heal.
    Announce { epoch: u64, cfg: ConsistencyCfg },
    /// client → adapt controller: `client` now issues under `epoch` (a
    /// client that already runs a newer epoch re-acks that newer one, so
    /// duplicate announces are idempotent).
    Ack { epoch: u64, client: u32 },
    /// rollback controller → adapt controller: one violation report was
    /// received; `detection_ms` is its detection latency sample.
    ViolationSeen { detection_ms: f64 },
    /// rollback controller → adapt controller: a recovery finished;
    /// servers sat frozen for `stall_ms` (0 for notify-only recovery).
    RecoveryDone { stall_ms: f64 },
}

/// Everything that travels between actors.
#[derive(Debug, Clone)]
pub enum Msg {
    /// client → server. The client piggy-backs the freshest HVC it has
    /// observed (clients relay causality between servers; the HVC dimension
    /// stays = #servers). Both the payload and the clock are `Rc`-shared:
    /// a quorum broadcast fans one allocation out to all N replicas
    /// instead of deep-cloning the value, its vector clock, and the
    /// piggy-backed HVC per target.
    Request { req: u64, op: Rc<ServerOp>, hvc: Option<Rc<Hvc>> },
    /// server → client. The HVC is an `Rc` snapshot of the server's
    /// clock; the server mutates its clock copy-on-write
    /// (`Rc::make_mut`), so a reply no longer deep-clones the vector.
    Reply { req: u64, reply: ServerReply, hvc: Rc<Hvc> },
    /// local predicate detector (on a server) → monitor.
    Candidate(Box<Candidate>),
    /// monitor → rollback controller (and anyone subscribed).
    Violation(Box<ViolationReport>),
    /// rollback control plane.
    Rollback(RollbackMsg),
    /// server → monitor: a predicate inferred at runtime from variable
    /// naming conventions (§V "Automatic inference").
    RegisterPred(Box<PredicateSpec>),
    /// crash-recovery re-sync between servers.
    Sync(Box<SyncMsg>),
    /// adaptive-consistency control plane (epoch switches and signals).
    Adapt(AdaptMsg),
}

impl Msg {
    /// Coarse class label for statistics.
    pub fn class(&self) -> MsgClass {
        match self {
            Msg::Request { .. } => MsgClass::Request,
            Msg::Reply { .. } => MsgClass::Reply,
            Msg::Candidate(_) => MsgClass::Candidate,
            Msg::Violation(_) => MsgClass::Violation,
            Msg::Rollback(_) => MsgClass::Rollback,
            Msg::RegisterPred(_) => MsgClass::Register,
            Msg::Sync(_) => MsgClass::Sync,
            Msg::Adapt(_) => MsgClass::Adapt,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgClass {
    Request = 0,
    Reply = 1,
    Candidate = 2,
    Violation = 3,
    Rollback = 4,
    Register = 5,
    Sync = 6,
    Adapt = 7,
}

pub const N_MSG_CLASSES: usize = 8;
