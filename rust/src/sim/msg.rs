//! The message vocabulary of the simulated distributed system: the
//! Voldemort-style client↔server protocol, monitor candidates/violations,
//! rollback control, and predicate registration (for predicates inferred
//! at runtime from variable names).

use std::rc::Rc;

use crate::client::consistency::ConsistencyCfg;
use crate::clock::hvc::{Hvc, HvcInterval, Millis};
use crate::detect::candidate::{Candidate, ViolationReport};
use crate::predicate::spec::{PredId, PredicateSpec};
use crate::rollback::recovery::RecoveryPolicy;
use crate::sim::{ProcId, Time};
use crate::store::protocol::{ServerOp, ServerReply};
use crate::store::value::{KeyId, Value, Versioned};

/// Rollback / recovery control messages (controller ↔ servers/clients).
#[derive(Debug, Clone)]
pub enum RollbackMsg {
    /// controller → clients: predicate `pred` was violated at ~`t_violate_ms`;
    /// abort the current task / roll back. `epoch` identifies the recovery.
    Notify { epoch: u64, t_violate_ms: Millis },
    /// controller → servers: stop serving while a restore is in progress.
    Freeze { epoch: u64 },
    /// server → controller
    FrozenAck { epoch: u64 },
    /// controller → servers: restore state to the latest snapshot/cut
    /// before `to_ms` (window-log or periodic snapshot, server-side).
    Restore { epoch: u64, to_ms: Millis },
    /// server → controller (false ⇒ the window-log did not reach back far
    /// enough and a full snapshot restore was used instead)
    RestoredAck { epoch: u64, from_window_log: bool },
    /// controller → servers and clients: resume computation.
    Resume { epoch: u64 },
    /// controller → one server (ResetToClean): drop the owned partition
    /// state wholesale and re-derive it from preference-list peers over
    /// the [`SyncMsg`] path — the checkpoint-free repair.
    Reset { epoch: u64 },
    /// server → controller: the reset's peer re-derivation settled.
    ResetAck { epoch: u64 },
}

/// Crash-recovery re-sync (restarting server ↔ live preference-list
/// peers, [`crate::faults`]): a replica that restarts after a crash has
/// lost all volatile state and catches up on the partitions it owns
/// before serving again (Dynamo-style replica synchronization).
#[derive(Debug, Clone)]
pub enum SyncMsg {
    /// restarting server `server` → every peer: send me your copies of
    /// the keys I own. `epoch` guards against stale chunks from an
    /// earlier recovery.
    Request { epoch: u64, server: u16 },
    /// peer → restarting server: sibling lists of the shared keys,
    /// sorted by key id so the merge order is deterministic.
    Chunk { epoch: u64, data: Vec<(KeyId, Vec<Versioned>)> },
}

/// Adaptive-consistency control plane ([`crate::adapt`]): the epoch
/// protocol that moves the whole cluster between quorum configurations
/// at runtime, plus the signal feed from the rollback controller.
#[derive(Debug, Clone)]
pub enum AdaptMsg {
    /// adapt controller → every client: consistency epoch `epoch` begins —
    /// open new quorum calls under `cfg`. In-flight calls finish under the
    /// epoch they were issued in (each [`crate::client::quorum::QuorumCall`]
    /// carries its own config), and the announce is re-sent each signal
    /// window until acked so clients cut off by a partition converge
    /// after heal.
    Announce { epoch: u64, cfg: ConsistencyCfg },
    /// client → adapt controller: `client` now issues under `epoch` (a
    /// client that already runs a newer epoch re-acks that newer one, so
    /// duplicate announces are idempotent).
    Ack { epoch: u64, client: u32 },
    /// rollback controller → adapt controller: one violation report was
    /// received; `detection_ms` is its detection latency sample.
    ViolationSeen { detection_ms: f64 },
    /// rollback controller → adapt controller: a recovery finished;
    /// servers sat frozen for `stall_ms` (0 for notify-only recovery).
    RecoveryDone { stall_ms: f64 },
    /// adapt controller → rollback controller: the escalation ladder
    /// moved to a mode whose configured recovery strategy is `policy`.
    /// Applied immediately when idle; mid-recovery the switch is
    /// deferred until the in-flight attempt settles, so a swap can
    /// never orphan an ack phase.
    SetRecovery { policy: RecoveryPolicy },
    /// client → adapt controller, once per signal window: the client's
    /// op / quorum-timeout counts and raw op-latency samples since its
    /// last report. The controller aggregates these instead of polling a
    /// shared metrics hub, so the signal path works unchanged when
    /// clients and controller live on different shards of the threaded
    /// engine. Sent only when an adapt controller is deployed.
    Report { client: u32, ops: u64, timeouts: u64, lat: Vec<Time> },
}

/// Everything that travels between actors.
#[derive(Debug, Clone)]
pub enum Msg {
    /// client → server. The client piggy-backs the freshest HVC it has
    /// observed (clients relay causality between servers; the HVC dimension
    /// stays = #servers). Both the payload and the clock are `Rc`-shared:
    /// a quorum broadcast fans one allocation out to all N replicas
    /// instead of deep-cloning the value, its vector clock, and the
    /// piggy-backed HVC per target.
    Request { req: u64, op: Rc<ServerOp>, hvc: Option<Rc<Hvc>> },
    /// server → client. The HVC is an `Rc` snapshot of the server's
    /// clock; the server mutates its clock copy-on-write
    /// (`Rc::make_mut`), so a reply no longer deep-clones the vector.
    Reply { req: u64, reply: ServerReply, hvc: Rc<Hvc> },
    /// local predicate detector (on a server) → monitor.
    Candidate(Box<Candidate>),
    /// monitor → rollback controller (and anyone subscribed).
    Violation(Box<ViolationReport>),
    /// rollback control plane.
    Rollback(RollbackMsg),
    /// server → monitor: a predicate inferred at runtime from variable
    /// naming conventions (§V "Automatic inference").
    RegisterPred(Box<PredicateSpec>),
    /// crash-recovery re-sync between servers.
    Sync(Box<SyncMsg>),
    /// adaptive-consistency control plane (epoch switches and signals).
    Adapt(AdaptMsg),
}

impl Msg {
    /// Coarse class label for statistics.
    pub fn class(&self) -> MsgClass {
        match self {
            Msg::Request { .. } => MsgClass::Request,
            Msg::Reply { .. } => MsgClass::Reply,
            Msg::Candidate(_) => MsgClass::Candidate,
            Msg::Violation(_) => MsgClass::Violation,
            Msg::Rollback(_) => MsgClass::Rollback,
            Msg::RegisterPred(_) => MsgClass::Register,
            Msg::Sync(_) => MsgClass::Sync,
            Msg::Adapt(_) => MsgClass::Adapt,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgClass {
    Request = 0,
    Reply = 1,
    Candidate = 2,
    Violation = 3,
    Rollback = 4,
    Register = 5,
    Sync = 6,
    Adapt = 7,
}

pub const N_MSG_CLASSES: usize = 8;

// ---------------------------------------------------------------------------
// wire envelopes: the `Send` mirror of `Msg` for cross-shard exchange
// ---------------------------------------------------------------------------

/// Take a payload out of its `Rc` without cloning when this was the last
/// handle (the common case for a message already popped off the event
/// queue).
fn unwrap_rc<T: Clone>(rc: Rc<T>) -> T {
    Rc::try_unwrap(rc).unwrap_or_else(|rc| (*rc).clone())
}

/// [`Candidate`] with the interval endpoints owned instead of
/// `Rc`-shared, so it can cross a thread boundary.
#[derive(Debug, Clone)]
pub struct WireCandidate {
    pub pred: PredId,
    pub clause: u16,
    pub conjunct: u16,
    pub server: ProcId,
    pub seq: u64,
    pub start: Hvc,
    pub end: Hvc,
    pub values: Vec<(KeyId, Value)>,
    pub truth: bool,
    pub emitted_at: Time,
}

impl From<Candidate> for WireCandidate {
    fn from(c: Candidate) -> Self {
        Self {
            pred: c.pred,
            clause: c.clause,
            conjunct: c.conjunct,
            server: c.server,
            seq: c.seq,
            start: unwrap_rc(c.interval.start),
            end: unwrap_rc(c.interval.end),
            values: c.values,
            truth: c.truth,
            emitted_at: c.emitted_at,
        }
    }
}

impl From<WireCandidate> for Candidate {
    fn from(w: WireCandidate) -> Self {
        Self {
            pred: w.pred,
            clause: w.clause,
            conjunct: w.conjunct,
            server: w.server,
            seq: w.seq,
            interval: HvcInterval::new(w.start, w.end),
            values: w.values,
            truth: w.truth,
            emitted_at: w.emitted_at,
        }
    }
}

/// [`ViolationReport`] with owned witnesses.
#[derive(Debug, Clone)]
pub struct WireViolation {
    pub pred: PredId,
    pub pred_name: String,
    pub clause: u16,
    pub witnesses: Vec<WireCandidate>,
    pub t_violate_ms: Millis,
    pub t_occurred_ms: Millis,
    pub detected_at: Time,
    pub monitor: ProcId,
}

impl From<ViolationReport> for WireViolation {
    fn from(v: ViolationReport) -> Self {
        Self {
            pred: v.pred,
            pred_name: v.pred_name,
            clause: v.clause,
            witnesses: v.witnesses.into_iter().map(WireCandidate::from).collect(),
            t_violate_ms: v.t_violate_ms,
            t_occurred_ms: v.t_occurred_ms,
            detected_at: v.detected_at,
            monitor: v.monitor,
        }
    }
}

impl From<WireViolation> for ViolationReport {
    fn from(w: WireViolation) -> Self {
        Self {
            pred: w.pred,
            pred_name: w.pred_name,
            clause: w.clause,
            witnesses: w.witnesses.into_iter().map(Candidate::from).collect(),
            t_violate_ms: w.t_violate_ms,
            t_occurred_ms: w.t_occurred_ms,
            detected_at: w.detected_at,
            monitor: w.monitor,
        }
    }
}

/// Owned, `Send` mirror of [`Msg`] — the payload of a cross-shard wire
/// envelope in the threaded engine ([`crate::sim::shard::WireEv`]).
/// Only the `Rc`-shared payloads change representation (request ops,
/// clock snapshots, candidate intervals get deep-copied out of their
/// `Rc`); everything else crosses as-is. The receiving shard re-wraps
/// with [`WireMsg::into_msg`], so actors see ordinary [`Msg`] values and
/// cannot tell a cross-shard delivery from a local one. The `Rc` fan-out
/// sharing a quorum broadcast enjoys *within* a shard is unaffected —
/// only envelopes that actually cross shards pay the deep copy.
#[derive(Debug, Clone)]
pub enum WireMsg {
    Request { req: u64, op: ServerOp, hvc: Option<Hvc> },
    Reply { req: u64, reply: ServerReply, hvc: Hvc },
    Candidate(Box<WireCandidate>),
    Violation(Box<WireViolation>),
    Rollback(RollbackMsg),
    RegisterPred(Box<PredicateSpec>),
    Sync(Box<SyncMsg>),
    Adapt(AdaptMsg),
}

impl WireMsg {
    pub fn from_msg(msg: Msg) -> Self {
        match msg {
            Msg::Request { req, op, hvc } => {
                WireMsg::Request { req, op: unwrap_rc(op), hvc: hvc.map(unwrap_rc) }
            }
            Msg::Reply { req, reply, hvc } => WireMsg::Reply { req, reply, hvc: unwrap_rc(hvc) },
            Msg::Candidate(c) => WireMsg::Candidate(Box::new(WireCandidate::from(*c))),
            Msg::Violation(v) => WireMsg::Violation(Box::new(WireViolation::from(*v))),
            Msg::Rollback(m) => WireMsg::Rollback(m),
            Msg::RegisterPred(p) => WireMsg::RegisterPred(p),
            Msg::Sync(s) => WireMsg::Sync(s),
            Msg::Adapt(a) => WireMsg::Adapt(a),
        }
    }

    pub fn into_msg(self) -> Msg {
        match self {
            WireMsg::Request { req, op, hvc } => {
                Msg::Request { req, op: Rc::new(op), hvc: hvc.map(Rc::new) }
            }
            WireMsg::Reply { req, reply, hvc } => Msg::Reply { req, reply, hvc: Rc::new(hvc) },
            WireMsg::Candidate(c) => Msg::Candidate(Box::new(Candidate::from(*c))),
            WireMsg::Violation(v) => Msg::Violation(Box::new(ViolationReport::from(*v))),
            WireMsg::Rollback(m) => Msg::Rollback(m),
            WireMsg::RegisterPred(p) => Msg::RegisterPred(p),
            WireMsg::Sync(s) => Msg::Sync(s),
            WireMsg::Adapt(a) => Msg::Adapt(a),
        }
    }

    /// Same coarse class labels as [`Msg::class`].
    pub fn class(&self) -> MsgClass {
        match self {
            WireMsg::Request { .. } => MsgClass::Request,
            WireMsg::Reply { .. } => MsgClass::Reply,
            WireMsg::Candidate(_) => MsgClass::Candidate,
            WireMsg::Violation(_) => MsgClass::Violation,
            WireMsg::Rollback(_) => MsgClass::Rollback,
            WireMsg::RegisterPred(_) => MsgClass::Register,
            WireMsg::Sync(_) => MsgClass::Sync,
            WireMsg::Adapt(_) => MsgClass::Adapt,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::hvc::Hvc;

    /// The whole point of the mirror: it must be `Send` (compile-time).
    #[test]
    fn wire_msg_is_send() {
        fn ok<T: Send + 'static>() {}
        ok::<WireMsg>();
        ok::<WireCandidate>();
        ok::<WireViolation>();
    }

    #[test]
    fn request_reply_round_trip() {
        let hvc = Hvc::new(1, 3, 100, 5);
        let msg = Msg::Request {
            req: 42,
            op: Rc::new(ServerOp::Get(KeyId(7))),
            hvc: Some(Rc::new(hvc.clone())),
        };
        let class = msg.class();
        let back = WireMsg::from_msg(msg).into_msg();
        assert_eq!(back.class(), class);
        match back {
            Msg::Request { req, op, hvc: Some(h) } => {
                assert_eq!(req, 42);
                assert!(matches!(*op, ServerOp::Get(KeyId(7))));
                assert_eq!(h.v, hvc.v);
                assert_eq!(h.owner, hvc.owner);
            }
            other => panic!("wrong shape: {other:?}"),
        }
    }

    #[test]
    fn candidate_round_trip_preserves_interval() {
        let start = Hvc::from_vec(2, vec![10, 20, 30]);
        let end = Hvc::from_vec(2, vec![15, 25, 35]);
        let cand = Candidate {
            pred: PredId(3),
            clause: 1,
            conjunct: 2,
            server: ProcId(4),
            seq: 99,
            interval: HvcInterval::new(start, end),
            values: vec![(KeyId(1), Value::Int(5))],
            truth: true,
            emitted_at: 1_000,
        };
        let (s_ms, e_ms) = (cand.start_pt_ms(), cand.end_pt_ms());
        let msg = Msg::Candidate(Box::new(cand));
        let back = WireMsg::from_msg(msg).into_msg();
        match back {
            Msg::Candidate(c) => {
                assert_eq!(c.pred, PredId(3));
                assert_eq!(c.server, ProcId(4));
                assert_eq!(c.start_pt_ms(), s_ms);
                assert_eq!(c.end_pt_ms(), e_ms);
                assert_eq!(c.values, vec![(KeyId(1), Value::Int(5))]);
            }
            other => panic!("wrong shape: {other:?}"),
        }
    }
}

