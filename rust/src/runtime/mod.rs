//! The L3↔L2/L1 boundary: the `Accel` verdict interface, the native Rust
//! reference backend, and the PJRT-backed XLA backend that executes the
//! AOT-compiled Pallas/JAX kernels from `artifacts/`.
//!
//! The XLA path is gated behind the `accel` cargo feature: it needs the
//! `xla` + `anyhow` crates and a PJRT CPU plugin, none of which exist in
//! offline CI. Without the feature a stub with the same entry point
//! compiles in its place and fails loudly if actually selected at
//! runtime.

pub mod accel;

#[cfg(feature = "accel")]
pub mod pjrt;

#[cfg(not(feature = "accel"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
