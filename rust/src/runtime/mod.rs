//! The L3↔L2/L1 boundary: the `Accel` verdict interface, the native Rust
//! reference backend, and the PJRT-backed XLA backend that executes the
//! AOT-compiled Pallas/JAX kernels from `artifacts/`.

pub mod accel;
pub mod pjrt;
