//! Stub compiled in place of `runtime::pjrt` when the `accel` cargo
//! feature is off. It keeps the crate (and everything selecting
//! [`crate::exp::config::AccelKind::Native`]) building without the
//! xla/anyhow crates or a PJRT toolchain; selecting the XLA backend at
//! runtime fails with a rebuild hint instead of a link error.

use std::cell::RefCell;
use std::rc::Rc;

use crate::runtime::accel::Accel;

/// Always panics: the binary was built without the `accel` feature.
pub fn shared_xla_accel() -> Rc<RefCell<dyn Accel>> {
    panic!(
        "the XLA/PJRT verdict backend was not compiled in; rebuild with \
         `cargo build --features accel` (requires the vendored xla + anyhow \
         crates and `make artifacts`, see DESIGN.md)"
    );
}
