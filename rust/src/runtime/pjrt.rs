//! PJRT-backed verdict backend: loads the HLO *text* produced by
//! `python/compile/aot.py` (JAX + Pallas, lowered once at build time),
//! compiles it on the PJRT CPU client, and serves batched HVC-interval
//! verdicts from the monitor hot path. Python never runs at request time.
//!
//! Interchange is HLO text, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! ## Clock encoding
//!
//! Kernels use i32 millisecond clocks. HVC entries are either "normal"
//! (small non-negative ms) or "ε = ∞ floor" values near `-EPS_INF`. The
//! encoding shifts floor values into `[-2^30 + pt]` so that every
//! comparison the 3-case rule performs has the same outcome in i32 as in
//! i64 (floors stay below all normal values and keep their relative
//! order). ε itself is clamped to 2^30 (⇒ "never physically separated",
//! exactly the ε = ∞ semantics).

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, Context, Result};

use crate::clock::hvc::{Hvc, IntervalOrd, Millis};
use crate::runtime::accel::{Accel, PairQuery};

/// Clamp/encode an i64 ms clock entry into kernel i32 space.
pub fn encode_ms(x: Millis) -> i32 {
    const FLOOR_CUT: i64 = -(1 << 40);
    const SHIFT: i64 = 1 << 30;
    if x < FLOOR_CUT {
        // ε=∞ floor: pt - EPS_INF → pt - 2^30
        let pt = x + crate::clock::hvc::EPS_INF;
        (pt - SHIFT).clamp(i32::MIN as i64 + 1, i32::MAX as i64) as i32
    } else {
        x.clamp(-(1 << 30), i32::MAX as i64) as i32
    }
}

/// Encode ε for the kernel (∞ ⇒ 2^30: the separation test never passes).
pub fn encode_eps(eps: Millis) -> i32 {
    eps.clamp(0, 1 << 30) as i32
}

/// Fixed-shape AOT executable for pair verdicts.
pub struct XlaAccel {
    exe: xla::PjRtLoadedExecutable,
    /// compiled batch size
    pub b: usize,
    /// compiled (padded) HVC dimension
    pub d: usize,
    pub calls: u64,
    pub pairs: u64,
}

impl XlaAccel {
    /// Artifacts directory: `$OPTIKV_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("OPTIKV_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Load and compile `pair_verdict.hlo.txt` (+ its `.meta` shape file).
    pub fn load(dir: &Path) -> Result<Self> {
        let meta_path = dir.join("pair_verdict.meta");
        let meta = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} (run `make artifacts`)"))?;
        let mut it = meta.split_whitespace();
        let b: usize = it.next().ok_or_else(|| anyhow!("meta missing B"))?.parse()?;
        let d: usize = it.next().ok_or_else(|| anyhow!("meta missing D"))?.parse()?;
        let hlo_path = dir.join("pair_verdict.hlo.txt");
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {hlo_path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| anyhow!("compiling: {e:?}"))?;
        Ok(Self { exe, b, d, calls: 0, pairs: 0 })
    }

    fn pack_clock(&self, out: &mut Vec<i32>, h: &Hvc) {
        for j in 0..self.d {
            out.push(h.v.get(j).map(|&x| encode_ms(x)).unwrap_or(0));
        }
    }

    /// Execute one padded batch of up to `self.b` pairs.
    fn run_batch(&mut self, pairs: &[PairQuery<'_>], eps: Millis) -> Result<Vec<IntervalOrd>> {
        assert!(pairs.len() <= self.b);
        let (b, d) = (self.b, self.d);
        let mut a_start = Vec::with_capacity(b * d);
        let mut a_end = Vec::with_capacity(b * d);
        let mut b_start = Vec::with_capacity(b * d);
        let mut b_end = Vec::with_capacity(b * d);
        let mut a_start_own = Vec::with_capacity(b);
        let mut a_end_own = Vec::with_capacity(b);
        let mut b_start_own = Vec::with_capacity(b);
        let mut b_end_own = Vec::with_capacity(b);
        for p in pairs {
            self.pack_clock(&mut a_start, &p.a.start);
            self.pack_clock(&mut a_end, &p.a.end);
            self.pack_clock(&mut b_start, &p.b.start);
            self.pack_clock(&mut b_end, &p.b.end);
            // (owner components below)
            let oa = p.a.owner() as usize;
            let ob = p.b.owner() as usize;
            a_start_own.push(encode_ms(p.a.start.v[oa]));
            a_end_own.push(encode_ms(p.a.end.v[oa]));
            b_start_own.push(encode_ms(p.b.start.v[ob]));
            b_end_own.push(encode_ms(p.b.end.v[ob]));
        }
        // pad with identical dummy intervals (verdict ignored)
        for _ in pairs.len()..b {
            for v in [&mut a_start, &mut a_end, &mut b_start, &mut b_end] {
                v.extend(std::iter::repeat(0).take(d));
            }
            a_start_own.push(0);
            a_end_own.push(0);
            b_start_own.push(0);
            b_end_own.push(0);
        }
        let shape = [b as i64, d as i64];
        let lit = |v: &[i32], sh: &[i64]| -> Result<xla::Literal> {
            xla::Literal::vec1(v)
                .reshape(sh)
                .map_err(|e| anyhow!("literal reshape: {e:?}"))
        };
        let args = [
            lit(&a_start, &shape)?,
            lit(&a_end, &shape)?,
            lit(&b_start, &shape)?,
            lit(&b_end, &shape)?,
            lit(&a_start_own, &[b as i64])?,
            lit(&a_end_own, &[b as i64])?,
            lit(&b_start_own, &[b as i64])?,
            lit(&b_end_own, &[b as i64])?,
            xla::Literal::vec1(&[encode_eps(eps)]),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
        let verdicts: Vec<i32> = out.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(verdicts[..pairs.len()]
            .iter()
            .map(|&v| match v {
                1 => IntervalOrd::Before,
                2 => IntervalOrd::After,
                _ => IntervalOrd::Concurrent,
            })
            .collect())
    }
}

impl Accel for XlaAccel {
    fn pair_verdicts(&mut self, pairs: &[PairQuery<'_>], eps: Millis) -> Vec<IntervalOrd> {
        self.pairs += pairs.len() as u64;
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(self.b) {
            self.calls += 1;
            match self.run_batch(chunk, eps) {
                Ok(v) => out.extend(v),
                Err(e) => panic!("XlaAccel execution failed: {e:#}"),
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Shared XLA backend for the experiment runner; panics with a helpful
/// message when artifacts are missing.
pub fn shared_xla_accel() -> Rc<RefCell<dyn Accel>> {
    let dir = XlaAccel::default_dir();
    match XlaAccel::load(&dir) {
        Ok(a) => Rc::new(RefCell::new(a)),
        Err(e) => panic!(
            "failed to load XLA artifacts from {dir:?}: {e:#}. Build them with `make artifacts`."
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::hvc::EPS_INF;

    #[test]
    fn encode_preserves_order() {
        // normal values
        assert!(encode_ms(10) < encode_ms(20));
        // floors keep their relative order and stay below normals
        let f1 = 100 - EPS_INF;
        let f2 = 200 - EPS_INF;
        assert!(encode_ms(f1) < encode_ms(f2));
        assert!(encode_ms(f2) < encode_ms(0));
        // eps clamp
        assert_eq!(encode_eps(EPS_INF), 1 << 30);
        assert_eq!(encode_eps(5), 5);
    }

    // execution tests against the real artifacts live in
    // rust/tests/xla_accel.rs (they are skipped when artifacts/ is absent)
}
