//! The detector acceleration interface — the L3↔L2/L1 boundary.
//!
//! The monitors' compute hot-spot is deciding, for batches of candidate
//! pairs, whether their HVC intervals are concurrent under the paper's
//! 3-case rule. `Accel` abstracts that: `NativeAccel` is the scalar Rust
//! reference; `XlaAccel` (runtime/pjrt.rs) executes the AOT-compiled
//! Pallas/JAX kernels through PJRT. Differential property tests pin the
//! two together bit-for-bit.

use crate::clock::hvc::{HvcInterval, IntervalOrd, Millis};

/// One pair-verdict query: two *borrowed* intervals compared at ε. The
/// monitor hot path issues thousands of these per batch; borrowing avoids
/// cloning two `Vec<i64>` clocks per verdict (§Perf in EXPERIMENTS.md:
/// −21% ns/pair, +26% end-to-end events/s).
#[derive(Debug, Clone, Copy)]
pub struct PairQuery<'a> {
    pub a: &'a HvcInterval,
    pub b: &'a HvcInterval,
}

pub trait Accel {
    /// Verdict for each pair under the 3-case HVC interval rule.
    fn pair_verdicts(&mut self, pairs: &[PairQuery<'_>], eps: Millis) -> Vec<IntervalOrd>;

    /// Backend label (reports/ablation).
    fn name(&self) -> &'static str;
}

/// Scalar Rust reference backend.
#[derive(Debug, Default)]
pub struct NativeAccel {
    pub calls: u64,
    pub pairs: u64,
}

impl NativeAccel {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Accel for NativeAccel {
    fn pair_verdicts(&mut self, pairs: &[PairQuery<'_>], eps: Millis) -> Vec<IntervalOrd> {
        self.calls += 1;
        self.pairs += pairs.len() as u64;
        pairs
            .iter()
            .map(|p| HvcInterval::verdict(p.a, p.b, eps))
            .collect()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::hvc::Hvc;

    fn interval(owner: u16, s: &[Millis], e: &[Millis]) -> HvcInterval {
        HvcInterval::new(Hvc::from_vec(owner, s.to_vec()), Hvc::from_vec(owner, e.to_vec()))
    }

    #[test]
    fn native_matches_scalar_rule() {
        let mut acc = NativeAccel::new();
        let ivs = [
            interval(0, &[10, 0], &[20, 0]),
            interval(1, &[15, 15], &[15, 25]),
            interval(0, &[10, 5], &[20, 5]),
            interval(1, &[25, 40], &[25, 50]),
        ];
        let pairs = vec![
            PairQuery { a: &ivs[0], b: &ivs[1] },
            PairQuery { a: &ivs[2], b: &ivs[3] },
        ];
        let v = acc.pair_verdicts(&pairs, 5);
        assert_eq!(v[0], IntervalOrd::Concurrent);
        assert_eq!(v[1], IntervalOrd::Before);
        assert_eq!(acc.calls, 1);
        assert_eq!(acc.pairs, 2);
    }
}
