//! Deterministic flight recorder (DESIGN.md §14).
//!
//! A per-actor bounded ring buffer of typed [`TraceEv`]s, each stamped
//! with the engine-invariant `(at, seq)` dispatch key
//! ([`crate::sim::des::Ctx::event_seq`]). Because every event is recorded
//! on the ring of the actor whose dispatch produced it, and an actor's
//! dispatch stream is identical across the serial, merged-order sharded
//! and threaded engines, the merged trace is **bit-identical across all
//! three engines** at every shard count: per-shard hubs are harvested as
//! plain data and their rings unioned (each actor lives on exactly one
//! shard), and [`TraceHub::entries`] stable-sorts the union by
//! `(at, seq)` — the exact order a serial run records them in.
//!
//! [`TraceCfg::off`] is the inert default, following the
//! `WorkloadCfg::uniform_default` / `AdaptCfg::static_default` pattern:
//! actors hold `Option<TraceRef>` = `None`, so a disabled recorder does
//! zero allocations, draws zero RNG values, sends zero messages and is
//! digest-pinned identical to pre-trace builds (enforced by
//! `rust/tests/trace_determinism.rs`).
//!
//! The recorder never adds messages or timers — it is a pure side
//! channel like [`crate::metrics::throughput::MetricsHub`], so
//! `N_MSG_CLASSES` and every event schedule stay untouched even when
//! recording is on.
//!
//! Submodules: [`forensics`] walks a recorded violation back through HVC
//! causality to the guilty writes; [`chrome`] exports the merged trace
//! as Chrome trace-event JSON (Perfetto-loadable) and the per-window
//! adapt-signal time series as CSV.

pub mod chrome;
pub mod forensics;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::clock::hvc::Millis;
use crate::predicate::spec::PredId;
use crate::sim::{ProcId, Time};

/// How much the recorder captures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceMode {
    /// inert: no hub is built, actors hold no handle
    Off,
    /// bounded rings of identity-only events (no HVC snapshots, no key
    /// lists) — the low-overhead always-on flavour
    Ring,
    /// forensics-grade payloads: server applies carry their HVC
    /// snapshot, candidates carry their variable keys — what the
    /// causal-chain walk needs
    Full,
}

/// Recorder configuration. [`TraceCfg::off`] must stay the inert
/// default of [`crate::exp::config::ExpConfig`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCfg {
    pub mode: TraceMode,
    /// per-actor ring capacity in events (oldest events overwrite first)
    pub ring_cap: usize,
}

impl TraceCfg {
    /// The inert default: no recorder, bit-identical to pre-trace runs.
    pub fn off() -> Self {
        Self { mode: TraceMode::Off, ring_cap: 0 }
    }

    /// Identity-only events in rings of `cap` per actor.
    pub fn ring(cap: usize) -> Self {
        Self { mode: TraceMode::Ring, ring_cap: cap }
    }

    /// Forensics-grade payloads in rings of `cap` per actor.
    pub fn full(cap: usize) -> Self {
        Self { mode: TraceMode::Full, ring_cap: cap }
    }

    pub fn enabled(&self) -> bool {
        self.mode != TraceMode::Off
    }

    pub fn full_payloads(&self) -> bool {
        self.mode == TraceMode::Full
    }

    pub fn validate(&self) {
        if self.enabled() {
            assert!(self.ring_cap > 0, "bad trace config: ring capacity must be positive");
        }
    }
}

/// What kind of actor a ring belongs to (set at world build; drives the
/// export's track naming).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActorKind {
    Server,
    Monitor,
    Client,
    Controller,
    Adapt,
}

impl ActorKind {
    pub fn label(&self) -> &'static str {
        match self {
            ActorKind::Server => "server",
            ActorKind::Monitor => "monitor",
            ActorKind::Client => "client",
            ActorKind::Controller => "controller",
            ActorKind::Adapt => "adapt",
        }
    }
}

/// Witness identity inside a recorded violation: enough to find the
/// matching [`TraceEv::CandidateEmit`] on the owning server's ring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceWitness {
    /// actor id of the emitting server (the ring key)
    pub server: u32,
    /// the candidate's per-server monotone sequence number
    pub cseq: u64,
    /// physical interval of the candidate at the owning server (ms)
    pub start_ms: Millis,
    pub end_ms: Millis,
}

/// One typed recorder event. Identity fields are always present; the
/// payload fields marked *(full)* are empty under [`TraceMode::Ring`].
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEv {
    /// a client opened a quorum call
    ClientIssue {
        client: u32,
        req: u64,
        key: u32,
        /// true for PUT-shaped ops (PUT / GET_VERSION→PUT chains)
        put: bool,
        /// consistency epoch the call was issued under
        epoch: u64,
    },
    /// a quorum round was (re)sent — round 2 is the serial fallback
    ClientRound {
        client: u32,
        req: u64,
        round: u8,
    },
    /// a quorum call finished
    ClientComplete {
        client: u32,
        req: u64,
        ok: bool,
        latency: Time,
    },
    /// a server applied a PUT that changed its table
    ServerApply {
        server: u16,
        key: u32,
        /// wire request id of the write (links back to the client call)
        req: u64,
        /// actor id of the writing client
        client: u32,
        /// server physical time of the apply (ms)
        pt_ms: Millis,
        /// *(full)* the server's HVC snapshot after the apply
        hvc: Vec<Millis>,
    },
    /// the local detector emitted a candidate interval
    CandidateEmit {
        server: u16,
        pred: PredId,
        clause: u16,
        conjunct: u16,
        cseq: u64,
        start_ms: Millis,
        end_ms: Millis,
        /// *(full)* the conjunct's variable keys carried by the candidate
        keys: Vec<u32>,
    },
    /// a monitor flushed one candidate batch (its verdict summary)
    MonitorBatch {
        monitor: u16,
        candidates: u64,
        violations: u64,
    },
    /// a monitor certified a pairwise-concurrent witness tuple
    Violation {
        pred: PredId,
        name: String,
        clause: u16,
        witnesses: Vec<TraceWitness>,
        t_violate_ms: Millis,
        t_occurred_ms: Millis,
    },
    /// the rollback controller moved through a recovery phase
    RecoveryPhase {
        /// recovery epoch (0 for the inline notify-only path)
        epoch: u64,
        /// phase name: "begin", "freeze", "restore", "resume", "reset",
        /// "notify", "done", "abort"
        phase: &'static str,
    },
    /// the adapt controller switched the cluster's consistency mode
    ModeSwitch {
        epoch: u64,
        from: &'static str,
        to: &'static str,
    },
    /// one closed adapt signal window — the exact inputs the controller's
    /// policy consumed (PCAP-style inspectability)
    AdaptWindow {
        ops: u64,
        timeouts: u64,
        violations: u64,
        stall_ms: u64,
        lat_p99_ms: f64,
        detect_ms_sum: f64,
        detect_n: u64,
        span_ms: u64,
    },
    /// a fault-timeline transition hit this actor
    Fault {
        /// "crash" or "restart"
        kind: &'static str,
    },
}

/// One recorded entry: the dispatch key plus the recording actor.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    pub at: Time,
    pub seq: u64,
    pub actor: u32,
    pub ev: TraceEv,
}

/// Bounded per-actor ring. Events are appended in the actor's dispatch
/// order (engine-invariant); once full, the oldest event is overwritten.
/// Because each ring belongs to exactly one actor, drops are themselves
/// deterministic and engine-invariant.
#[derive(Debug, Clone, PartialEq)]
struct Ring {
    cap: usize,
    buf: Vec<TraceEntry>,
    /// index of the oldest entry once the ring wrapped
    head: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Self { cap, buf: Vec::new(), head: 0, dropped: 0 }
    }

    fn push(&mut self, e: TraceEntry) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    fn len(&self) -> usize {
        self.buf.len()
    }

    /// Entries oldest → newest.
    fn iter_ordered(&self) -> impl Iterator<Item = &TraceEntry> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }
}

/// The flight recorder: per-actor rings plus the actor registry. Plain
/// data (`Clone + Send`) so the threaded engine harvests per-shard hubs
/// exactly like [`crate::metrics::throughput::MetricsHub`].
#[derive(Debug, Clone)]
pub struct TraceHub {
    cfg: TraceCfg,
    /// actor id → ring (BTreeMap: deterministic iteration)
    rings: BTreeMap<u32, Ring>,
    /// actor id → (kind, index within kind)
    actors: BTreeMap<u32, (ActorKind, u32)>,
    /// events recorded (before ring eviction)
    pub recorded: u64,
}

/// Shared recorder handle, cloned into every actor at world build —
/// the shared-hub pattern of [`crate::metrics::throughput::Metrics`].
pub type TraceRef = Rc<RefCell<TraceHub>>;

impl TraceHub {
    pub fn new(cfg: TraceCfg) -> TraceRef {
        cfg.validate();
        assert!(cfg.enabled(), "an Off recorder must not be built — pass None instead");
        Rc::new(RefCell::new(Self {
            cfg,
            rings: BTreeMap::new(),
            actors: BTreeMap::new(),
            recorded: 0,
        }))
    }

    pub fn cfg(&self) -> TraceCfg {
        self.cfg
    }

    /// Does this hub capture forensics-grade payloads?
    pub fn full_payloads(&self) -> bool {
        self.cfg.full_payloads()
    }

    /// Declare an actor (called at world build for hosted actors only,
    /// so per-shard registries stay disjoint and merge cleanly).
    pub fn register(&mut self, id: ProcId, kind: ActorKind, idx: u32) {
        self.actors.insert(id.0, (kind, idx));
    }

    pub fn actor_kind(&self, id: u32) -> Option<(ActorKind, u32)> {
        self.actors.get(&id).copied()
    }

    pub fn actors(&self) -> impl Iterator<Item = (u32, ActorKind, u32)> + '_ {
        self.actors.iter().map(|(&id, &(k, i))| (id, k, i))
    }

    /// Record one event on `actor`'s ring, stamped with its dispatch key.
    pub fn record(&mut self, actor: ProcId, at: Time, seq: u64, ev: TraceEv) {
        self.recorded += 1;
        let cap = self.cfg.ring_cap;
        self.rings
            .entry(actor.0)
            .or_insert_with(|| Ring::new(cap))
            .push(TraceEntry { at, seq, actor: actor.0, ev });
    }

    /// Events evicted by ring wraps, across all rings.
    pub fn dropped(&self) -> u64 {
        self.rings.values().map(|r| r.dropped).sum()
    }

    /// Retained events across all rings.
    pub fn len(&self) -> usize {
        self.rings.values().map(|r| r.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Union a per-shard hub into this one (threaded engine, shards in
    /// shard order). Each actor is hosted by exactly one shard, so rings
    /// and registry entries are key-disjoint; ragged overlap would mean
    /// a layout bug and trips the assert.
    pub fn merge(&mut self, other: &TraceHub) {
        assert_eq!(self.cfg, other.cfg, "hubs must share a trace config");
        for (&id, ring) in &other.rings {
            let prev = self.rings.insert(id, ring.clone());
            assert!(prev.is_none(), "actor {id} recorded on two shards");
        }
        for (&id, &meta) in &other.actors {
            self.actors.insert(id, meta);
        }
        self.recorded += other.recorded;
    }

    /// The merged trace: all rings flattened and stable-sorted by the
    /// `(at, seq)` dispatch key — the global recording order, identical
    /// across engines. Ties (several events from one dispatch) keep
    /// their within-ring order; a dispatch key is globally unique, so
    /// ties never span rings.
    pub fn entries(&self) -> Vec<TraceEntry> {
        let mut all: Vec<TraceEntry> =
            self.rings.values().flat_map(|r| r.iter_ordered().cloned()).collect();
        all.sort_by_key(|e| (e.at, e.seq));
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(n: u64) -> TraceEv {
        TraceEv::ClientRound { client: 0, req: n, round: 1 }
    }

    #[test]
    fn off_is_inert_and_validates() {
        let c = TraceCfg::off();
        assert!(!c.enabled());
        c.validate();
        assert_eq!(c, TraceCfg::off());
    }

    #[test]
    #[should_panic(expected = "ring capacity")]
    fn enabled_needs_capacity() {
        TraceCfg { mode: TraceMode::Ring, ring_cap: 0 }.validate();
    }

    #[test]
    fn ring_drops_oldest_deterministically() {
        let hub = TraceHub::new(TraceCfg::ring(3));
        let mut h = hub.borrow_mut();
        for i in 0..5u64 {
            h.record(ProcId(7), i * 10, i, ev(i));
        }
        assert_eq!(h.recorded, 5);
        assert_eq!(h.dropped(), 2);
        let reqs: Vec<u64> = h
            .entries()
            .iter()
            .map(|e| match &e.ev {
                TraceEv::ClientRound { req, .. } => *req,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(reqs, vec![2, 3, 4], "oldest evicted first");
    }

    #[test]
    fn entries_merge_rings_by_dispatch_key() {
        let hub = TraceHub::new(TraceCfg::ring(8));
        let mut h = hub.borrow_mut();
        // actor 9 records late, actor 3 early — entries() re-interleaves
        h.record(ProcId(9), 200, 5, ev(1));
        h.record(ProcId(3), 100, 2, ev(0));
        h.record(ProcId(9), 300, 9, ev(2));
        let order: Vec<u32> = h.entries().iter().map(|e| e.actor).collect();
        assert_eq!(order, vec![3, 9, 9]);
    }

    #[test]
    fn shard_merge_unions_disjoint_rings() {
        let a = TraceHub::new(TraceCfg::full(8));
        let b = TraceHub::new(TraceCfg::full(8));
        a.borrow_mut().register(ProcId(0), ActorKind::Server, 0);
        b.borrow_mut().register(ProcId(1), ActorKind::Server, 1);
        a.borrow_mut().record(ProcId(0), 50, 1, ev(0));
        b.borrow_mut().record(ProcId(1), 25, 0, ev(1));
        let mut m = a.borrow().clone();
        m.merge(&b.borrow());
        assert_eq!(m.len(), 2);
        assert_eq!(m.recorded, 2);
        let order: Vec<u32> = m.entries().iter().map(|e| e.actor).collect();
        assert_eq!(order, vec![1, 0], "dispatch-key order, not shard order");
        assert_eq!(m.actor_kind(1), Some((ActorKind::Server, 1)));
    }

    #[test]
    #[should_panic(expected = "two shards")]
    fn shard_merge_rejects_overlapping_rings() {
        let a = TraceHub::new(TraceCfg::ring(4));
        let b = TraceHub::new(TraceCfg::ring(4));
        a.borrow_mut().record(ProcId(0), 1, 1, ev(0));
        b.borrow_mut().record(ProcId(0), 2, 2, ev(1));
        a.borrow_mut().merge(&b.borrow());
    }
}
