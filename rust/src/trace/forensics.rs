//! Violation forensics: walk the flight recorder backward from each
//! recorded violation to the minimal causal chain that produced it
//! (DESIGN.md §14).
//!
//! The walk follows the identity links the recorder stamps on every
//! event:
//!
//! 1. a [`TraceEv::Violation`] names its witness candidates by
//!    `(server actor, cseq)`;
//! 2. each witness's [`TraceEv::CandidateEmit`] (on the owning server's
//!    ring) carries the conjunct's variable keys;
//! 3. for each key, the **guilty write** is the latest
//!    [`TraceEv::ServerApply`] on that server at or before the
//!    candidate's dispatch key — the PUT whose post-state made the
//!    conjunct hold during the certified interval;
//! 4. the apply's `(client, req)` link names the client call that issued
//!    the write.
//!
//! The monitor's certificate itself is reproduced as the physical
//! interval overlap `[max start, min end]` across the witnesses — the
//! pairwise-concurrency evidence the detection was based on.
//!
//! Guilty-write resolution needs forensics-grade payloads
//! ([`crate::trace::TraceMode::Full`]): under `Ring` the candidate
//! events carry no key lists and every chain is empty (identity-only
//! flight recording is for overhead runs, not debugging).

use std::collections::HashMap;

use crate::clock::hvc::Millis;
use crate::sim::Time;
use crate::trace::{TraceEntry, TraceEv, TraceHub};
use crate::util::stats::Cdf;

/// A write the walk holds responsible for one witness interval.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GuiltyWrite {
    pub server: u16,
    pub key: u32,
    /// wire request id of the write
    pub req: u64,
    /// actor id of the writing client
    pub client: u32,
    /// dispatch time of the apply
    pub at: Time,
    /// server physical time of the apply (ms)
    pub pt_ms: Millis,
}

/// One witness candidate resolved back to its writes.
#[derive(Debug, Clone, PartialEq)]
pub struct WitnessChain {
    /// actor id of the emitting server
    pub server_actor: u32,
    pub server: u16,
    pub cseq: u64,
    /// physical candidate interval at the owning server (ms)
    pub interval: (Millis, Millis),
    /// the conjunct's variable keys (empty under identity-only tracing)
    pub keys: Vec<u32>,
    pub writes: Vec<GuiltyWrite>,
}

/// The reconstructed causal chain of one violation.
#[derive(Debug, Clone, PartialEq)]
pub struct CausalChain {
    /// dispatch key of the monitor flush that certified it
    pub at: Time,
    pub seq: u64,
    pub pred_name: String,
    pub clause: u16,
    pub t_violate_ms: Millis,
    pub t_occurred_ms: Millis,
    /// the certified physical interval overlap `[max start, min end]`
    /// across witnesses — the monitor's concurrency evidence
    pub overlap: (Millis, Millis),
    pub witnesses: Vec<WitnessChain>,
}

impl CausalChain {
    /// Total guilty writes named across witnesses.
    pub fn n_writes(&self) -> usize {
        self.witnesses.iter().map(|w| w.writes.len()).sum()
    }

    /// A chain is empty when the walk could not tie a single write to
    /// the violation — the `optikv trace` failure condition.
    pub fn is_empty(&self) -> bool {
        self.n_writes() == 0
    }

    /// ms from the earliest guilty write to the certifying flush.
    pub fn depth_ms(&self) -> f64 {
        let first = self
            .witnesses
            .iter()
            .flat_map(|w| w.writes.iter().map(|g| g.at))
            .min();
        match first {
            Some(t) => (self.at.saturating_sub(t)) as f64 / crate::sim::MS as f64,
            None => 0.0,
        }
    }
}

/// The forensics report over one recorded run.
#[derive(Debug, Clone, Default)]
pub struct Forensics {
    pub chains: Vec<CausalChain>,
}

impl Forensics {
    /// Reconstruct every recorded violation's causal chain from the
    /// merged trace.
    pub fn walk(hub: &TraceHub) -> Self {
        let entries = hub.entries();
        // (server actor, cseq) → candidate entry index
        let mut cand_ix: HashMap<(u32, u64), usize> = HashMap::new();
        // (server actor, key) → apply entry indices, in dispatch order
        let mut applies: HashMap<(u32, u32), Vec<usize>> = HashMap::new();
        for (i, e) in entries.iter().enumerate() {
            match &e.ev {
                TraceEv::CandidateEmit { cseq, .. } => {
                    cand_ix.insert((e.actor, *cseq), i);
                }
                TraceEv::ServerApply { key, .. } => {
                    applies.entry((e.actor, *key)).or_default().push(i);
                }
                _ => {}
            }
        }

        let mut chains = Vec::new();
        for e in &entries {
            let TraceEv::Violation { name, clause, witnesses, t_violate_ms, t_occurred_ms, .. } =
                &e.ev
            else {
                continue;
            };
            let overlap = (
                witnesses.iter().map(|w| w.start_ms).max().unwrap_or(0),
                witnesses.iter().map(|w| w.end_ms).min().unwrap_or(0),
            );
            let mut wchains = Vec::with_capacity(witnesses.len());
            for w in witnesses {
                let mut chain = WitnessChain {
                    server_actor: w.server,
                    server: 0,
                    cseq: w.cseq,
                    interval: (w.start_ms, w.end_ms),
                    keys: Vec::new(),
                    writes: Vec::new(),
                };
                if let Some(&ci) = cand_ix.get(&(w.server, w.cseq)) {
                    let cand = &entries[ci];
                    if let TraceEv::CandidateEmit { server, keys, .. } = &cand.ev {
                        chain.server = *server;
                        chain.keys = keys.clone();
                        for &key in keys {
                            if let Some(g) =
                                latest_apply_before(&entries, &applies, w.server, key, cand)
                            {
                                if !chain.writes.contains(&g) {
                                    chain.writes.push(g);
                                }
                            }
                        }
                    }
                }
                wchains.push(chain);
            }
            chains.push(CausalChain {
                at: e.at,
                seq: e.seq,
                pred_name: name.clone(),
                clause: *clause,
                t_violate_ms: *t_violate_ms,
                t_occurred_ms: *t_occurred_ms,
                overlap,
                witnesses: wchains,
            });
        }
        Self { chains }
    }

    pub fn empty_chains(&self) -> usize {
        self.chains.iter().filter(|c| c.is_empty()).count()
    }

    /// Human-readable report: one block per violation plus the
    /// write-to-certification depth ladder
    /// ([`crate::util::stats::Cdf::summary`]).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "forensics: {} violation(s), {} with an empty causal chain\n",
            self.chains.len(),
            self.empty_chains()
        ));
        for (i, c) in self.chains.iter().enumerate() {
            out.push_str(&format!(
                "[{i}] {} clause {} at t={:.3}ms (seq {}) overlap=[{}, {}]ms \
                 t_violate={}ms t_occurred={}ms\n",
                c.pred_name,
                c.clause,
                c.at as f64 / crate::sim::MS as f64,
                c.seq,
                c.overlap.0,
                c.overlap.1,
                c.t_violate_ms,
                c.t_occurred_ms
            ));
            for w in &c.witnesses {
                out.push_str(&format!(
                    "    witness server {} (actor {}) cseq {} interval [{}, {}]ms\n",
                    w.server, w.server_actor, w.cseq, w.interval.0, w.interval.1
                ));
                for g in &w.writes {
                    out.push_str(&format!(
                        "        guilty write: key {} req {} by client actor {} \
                         applied at {:.3}ms (pt {}ms)\n",
                        g.key,
                        g.req,
                        g.client,
                        g.at as f64 / crate::sim::MS as f64,
                        g.pt_ms
                    ));
                }
                if w.writes.is_empty() {
                    out.push_str("        (no write resolved — chain incomplete)\n");
                }
            }
        }
        let depths = Cdf::new(
            self.chains.iter().filter(|c| !c.is_empty()).map(|c| c.depth_ms()).collect(),
        );
        out.push_str(&format!("write-to-certification depth: {}\n", depths.summary().render("ms")));
        out
    }
}

/// The latest `ServerApply` of `key` on `server_actor` whose dispatch
/// key is at or before the candidate's — the write the interval's
/// post-state came from.
fn latest_apply_before(
    entries: &[TraceEntry],
    applies: &HashMap<(u32, u32), Vec<usize>>,
    server_actor: u32,
    key: u32,
    cand: &TraceEntry,
) -> Option<GuiltyWrite> {
    let ix = applies.get(&(server_actor, key))?;
    // entries are (at, seq)-sorted, so the per-key index lists are too
    let pos = ix.partition_point(|&i| (entries[i].at, entries[i].seq) <= (cand.at, cand.seq));
    if pos == 0 {
        return None;
    }
    let e = &entries[ix[pos - 1]];
    let TraceEv::ServerApply { server, key, req, client, pt_ms, .. } = &e.ev else {
        return None;
    };
    Some(GuiltyWrite {
        server: *server,
        key: *key,
        req: *req,
        client: *client,
        at: e.at,
        pt_ms: *pt_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predicate::spec::PredId;
    use crate::sim::{ProcId, MS};
    use crate::trace::{TraceCfg, TraceWitness};

    /// Hand-seeded hub: client 20 writes key 5 to server 0 (actor 0) and
    /// client 21 writes key 6 to server 1 (actor 1); both applies spawn
    /// candidates whose overlap the monitor (actor 10) certifies.
    fn seeded_hub() -> TraceHub {
        let hub = crate::trace::TraceHub::new(TraceCfg::full(64));
        let mut h = hub.borrow_mut();
        h.record(
            ProcId(0),
            100 * MS,
            1,
            TraceEv::ServerApply { server: 0, key: 5, req: 40, client: 20, pt_ms: 100, hvc: vec![] },
        );
        h.record(
            ProcId(0),
            100 * MS,
            1,
            TraceEv::CandidateEmit {
                server: 0,
                pred: PredId(0),
                clause: 0,
                conjunct: 0,
                cseq: 0,
                start_ms: 100,
                end_ms: 100,
                keys: vec![5],
            },
        );
        // an even older apply of key 5 must NOT be blamed
        h.record(
            ProcId(0),
            50 * MS,
            0,
            TraceEv::ServerApply { server: 0, key: 5, req: 39, client: 20, pt_ms: 50, hvc: vec![] },
        );
        h.record(
            ProcId(1),
            105 * MS,
            2,
            TraceEv::ServerApply { server: 1, key: 6, req: 41, client: 21, pt_ms: 105, hvc: vec![] },
        );
        h.record(
            ProcId(1),
            105 * MS,
            2,
            TraceEv::CandidateEmit {
                server: 1,
                pred: PredId(0),
                clause: 0,
                conjunct: 1,
                cseq: 0,
                start_ms: 105,
                end_ms: 110,
                keys: vec![6],
            },
        );
        h.record(
            ProcId(10),
            120 * MS,
            3,
            TraceEv::Violation {
                pred: PredId(0),
                name: "me_1_2".into(),
                clause: 0,
                witnesses: vec![
                    TraceWitness { server: 0, cseq: 0, start_ms: 100, end_ms: 100 },
                    TraceWitness { server: 1, cseq: 0, start_ms: 105, end_ms: 110 },
                ],
                t_violate_ms: 100,
                t_occurred_ms: 105,
            },
        );
        drop(h);
        Rc::try_unwrap(hub).unwrap().into_inner()
    }

    use std::rc::Rc;

    #[test]
    fn walk_names_the_true_guilty_writes() {
        let f = Forensics::walk(&seeded_hub());
        assert_eq!(f.chains.len(), 1);
        assert_eq!(f.empty_chains(), 0);
        let c = &f.chains[0];
        assert_eq!(c.pred_name, "me_1_2");
        assert_eq!(c.overlap, (105, 100), "max start / min end");
        assert_eq!(c.n_writes(), 2);
        let w0 = &c.witnesses[0].writes[0];
        assert_eq!((w0.key, w0.req, w0.client), (5, 40, 20), "latest apply, not the older one");
        let w1 = &c.witnesses[1].writes[0];
        assert_eq!((w1.key, w1.req, w1.client), (6, 41, 21));
        assert!((c.depth_ms() - 20.0).abs() < 1e-9, "violation at 120ms, first write at 100ms");
        let txt = f.render();
        assert!(txt.contains("guilty write: key 5 req 40 by client actor 20"), "{txt}");
        assert!(txt.contains("0 with an empty causal chain"), "{txt}");
    }

    #[test]
    fn identity_only_trace_yields_empty_chains() {
        // same shape but Ring mode: candidates carry no keys
        let hub = crate::trace::TraceHub::new(TraceCfg::ring(64));
        let mut h = hub.borrow_mut();
        h.record(
            ProcId(0),
            100 * MS,
            1,
            TraceEv::CandidateEmit {
                server: 0,
                pred: PredId(0),
                clause: 0,
                conjunct: 0,
                cseq: 0,
                start_ms: 100,
                end_ms: 100,
                keys: vec![],
            },
        );
        h.record(
            ProcId(10),
            120 * MS,
            2,
            TraceEv::Violation {
                pred: PredId(0),
                name: "me_1_2".into(),
                clause: 0,
                witnesses: vec![TraceWitness { server: 0, cseq: 0, start_ms: 100, end_ms: 100 }],
                t_violate_ms: 100,
                t_occurred_ms: 100,
            },
        );
        drop(h);
        let hub = Rc::try_unwrap(hub).unwrap().into_inner();
        let f = Forensics::walk(&hub);
        assert_eq!(f.chains.len(), 1);
        assert_eq!(f.empty_chains(), 1);
    }
}
