//! Chrome trace-event JSON export (Perfetto-loadable) plus the
//! per-window adapt-signal CSV dump.
//!
//! Hand-rolled writer in the `util/xmlmini.rs` tradition — the crate is
//! dependency-free, so no serde. The output follows the Trace Event
//! Format: a `traceEvents` array of `"M"` metadata (one named track per
//! registered actor under pid 1), `"X"` duration spans (quorum calls,
//! recovery epochs, consistency-mode epochs), `"i"` instants
//! (applies, candidates, violations, faults) and `"C"` counters (the
//! adapt signal windows). Timestamps are integer microseconds of
//! virtual time, so the writer is exactly reproducible — the golden
//! test pins the bytes.

use std::collections::HashMap;

use crate::sim::Time;
use crate::trace::{TraceEv, TraceHub};

/// Escape a string into a JSON literal (no surrounding quotes).
fn esc(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// One trace event object, fields in fixed order.
struct EvJson {
    name: String,
    ph: &'static str,
    tid: u32,
    /// microseconds
    ts: u64,
    /// microseconds; only emitted for `ph == "X"`
    dur: Option<u64>,
    /// instant scope (`"g"` for global); only for `ph == "i"`
    scope: Option<&'static str>,
    /// pre-rendered JSON object body, e.g. `"key":5,"ok":true`
    args: String,
}

impl EvJson {
    fn render(&self, out: &mut String) {
        out.push_str("{\"name\":\"");
        esc(&self.name, out);
        out.push_str(&format!("\",\"ph\":\"{}\",\"pid\":1,\"tid\":{},\"ts\":{}", self.ph, self.tid, self.ts));
        if let Some(d) = self.dur {
            out.push_str(&format!(",\"dur\":{d}"));
        }
        if let Some(s) = self.scope {
            out.push_str(&format!(",\"s\":\"{s}\""));
        }
        if !self.args.is_empty() {
            out.push_str(&format!(",\"args\":{{{}}}", self.args));
        }
        out.push('}');
    }
}

fn us(t: Time) -> u64 {
    t / 1_000
}

/// Render the merged trace as Chrome trace-event JSON.
pub fn chrome_trace_json(hub: &TraceHub) -> String {
    let entries = hub.entries();
    let t_max = entries.last().map(|e| e.at).unwrap_or(0);
    let mut evs: Vec<EvJson> = Vec::new();

    // track metadata: one named thread per registered actor
    evs.push(EvJson {
        name: "process_name".into(),
        ph: "M",
        tid: 0,
        ts: 0,
        dur: None,
        scope: None,
        args: "\"name\":\"optikv\"".into(),
    });
    for (id, kind, idx) in hub.actors() {
        evs.push(EvJson {
            name: "thread_name".into(),
            ph: "M",
            tid: id,
            ts: 0,
            dur: None,
            scope: None,
            args: format!("\"name\":\"{} {}\"", kind.label(), idx),
        });
    }

    // (client actor, req) → issue (at, key, put, epoch)
    let mut issues: HashMap<(u32, u64), (Time, u32, bool, u64)> = HashMap::new();
    // recovery epoch → begin time (on the controller's track)
    let mut rec_begin: HashMap<u64, Time> = HashMap::new();
    // the open consistency-mode epoch: (start, label, actor)
    let mut mode_open: Option<(Time, String, u32)> = None;

    for e in &entries {
        match &e.ev {
            TraceEv::ClientIssue { req, key, put, epoch, .. } => {
                issues.insert((e.actor, *req), (e.at, *key, *put, *epoch));
            }
            TraceEv::ClientComplete { req, ok, latency, .. } => {
                match issues.remove(&(e.actor, *req)) {
                    Some((t0, key, put, epoch)) => evs.push(EvJson {
                        name: format!("{} k{}", if put { "put" } else { "get" }, key),
                        ph: "X",
                        tid: e.actor,
                        ts: us(t0),
                        dur: Some(us(e.at.saturating_sub(t0))),
                        scope: None,
                        args: format!("\"req\":{req},\"epoch\":{epoch},\"ok\":{ok}"),
                    }),
                    None => evs.push(EvJson {
                        name: format!("complete req {req}"),
                        ph: "i",
                        tid: e.actor,
                        ts: us(e.at),
                        dur: None,
                        scope: Some("t"),
                        args: format!("\"ok\":{ok},\"latency_us\":{}", us(*latency)),
                    }),
                }
            }
            TraceEv::ClientRound { req, round, .. } => evs.push(EvJson {
                name: format!("round{round}"),
                ph: "i",
                tid: e.actor,
                ts: us(e.at),
                dur: None,
                scope: Some("t"),
                args: format!("\"req\":{req}"),
            }),
            TraceEv::ServerApply { key, req, client, pt_ms, .. } => evs.push(EvJson {
                name: format!("apply k{key}"),
                ph: "i",
                tid: e.actor,
                ts: us(e.at),
                dur: None,
                scope: Some("t"),
                args: format!("\"req\":{req},\"client\":{client},\"pt_ms\":{pt_ms}"),
            }),
            TraceEv::CandidateEmit { pred, conjunct, cseq, start_ms, end_ms, .. } => {
                evs.push(EvJson {
                    name: format!("cand p{}c{conjunct}", pred.0),
                    ph: "i",
                    tid: e.actor,
                    ts: us(e.at),
                    dur: None,
                    scope: Some("t"),
                    args: format!("\"cseq\":{cseq},\"start_ms\":{start_ms},\"end_ms\":{end_ms}"),
                })
            }
            TraceEv::MonitorBatch { candidates, violations, .. } => evs.push(EvJson {
                name: "batch".into(),
                ph: "i",
                tid: e.actor,
                ts: us(e.at),
                dur: None,
                scope: Some("t"),
                args: format!("\"candidates\":{candidates},\"violations\":{violations}"),
            }),
            TraceEv::Violation { name, witnesses, t_violate_ms, t_occurred_ms, .. } => {
                evs.push(EvJson {
                    name: format!("violation {name}"),
                    ph: "i",
                    tid: e.actor,
                    ts: us(e.at),
                    dur: None,
                    scope: Some("g"),
                    args: format!(
                        "\"witnesses\":{},\"t_violate_ms\":{t_violate_ms},\"t_occurred_ms\":{t_occurred_ms}",
                        witnesses.len()
                    ),
                })
            }
            TraceEv::RecoveryPhase { epoch, phase } => {
                evs.push(EvJson {
                    name: format!("recovery:{phase}"),
                    ph: "i",
                    tid: e.actor,
                    ts: us(e.at),
                    dur: None,
                    scope: Some("t"),
                    args: format!("\"epoch\":{epoch}"),
                });
                match *phase {
                    "begin" => {
                        rec_begin.insert(*epoch, e.at);
                    }
                    "done" | "abort" => {
                        if let Some(t0) = rec_begin.remove(epoch) {
                            evs.push(EvJson {
                                name: format!("recovery e{epoch}"),
                                ph: "X",
                                tid: e.actor,
                                ts: us(t0),
                                dur: Some(us(e.at.saturating_sub(t0))),
                                scope: None,
                                args: format!("\"outcome\":\"{phase}\""),
                            });
                        }
                    }
                    _ => {}
                }
            }
            TraceEv::ModeSwitch { epoch, from, to } => {
                if let Some((t0, label, tid)) = mode_open.take() {
                    evs.push(EvJson {
                        name: format!("mode {label}"),
                        ph: "X",
                        tid,
                        ts: us(t0),
                        dur: Some(us(e.at.saturating_sub(t0))),
                        scope: None,
                        args: String::new(),
                    });
                }
                evs.push(EvJson {
                    name: format!("switch {from}->{to}"),
                    ph: "i",
                    tid: e.actor,
                    ts: us(e.at),
                    dur: None,
                    scope: Some("t"),
                    args: format!("\"epoch\":{epoch}"),
                });
                mode_open = Some((e.at, (*to).to_string(), e.actor));
            }
            TraceEv::AdaptWindow { ops, timeouts, violations, stall_ms, .. } => {
                evs.push(EvJson {
                    name: "adapt-signals".into(),
                    ph: "C",
                    tid: e.actor,
                    ts: us(e.at),
                    dur: None,
                    scope: None,
                    args: format!(
                        "\"ops\":{ops},\"timeouts\":{timeouts},\"violations\":{violations},\"stall_ms\":{stall_ms}"
                    ),
                })
            }
            TraceEv::Fault { kind } => evs.push(EvJson {
                name: (*kind).into(),
                ph: "i",
                tid: e.actor,
                ts: us(e.at),
                dur: None,
                scope: Some("g"),
                args: String::new(),
            }),
        }
    }
    // close the final consistency-mode epoch at the trace horizon
    if let Some((t0, label, tid)) = mode_open {
        evs.push(EvJson {
            name: format!("mode {label}"),
            ph: "X",
            tid,
            ts: us(t0),
            dur: Some(us(t_max.saturating_sub(t0))),
            scope: None,
            args: String::new(),
        });
    }

    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, ev) in evs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('\n');
        ev.render(&mut out);
    }
    out.push_str("\n]}\n");
    out
}

/// The per-window adapt-signal time series as CSV — every input the
/// controller's policy consumed, one row per closed window.
pub fn signals_csv(hub: &TraceHub) -> String {
    let mut out = String::from(
        "at_ms,ops,timeouts,violations,stall_ms,lat_p99_ms,detect_ms_sum,detect_n,span_ms\n",
    );
    for e in hub.entries() {
        let TraceEv::AdaptWindow {
            ops,
            timeouts,
            violations,
            stall_ms,
            lat_p99_ms,
            detect_ms_sum,
            detect_n,
            span_ms,
        } = e.ev
        else {
            continue;
        };
        out.push_str(&format!(
            "{},{ops},{timeouts},{violations},{stall_ms},{lat_p99_ms:.3},{detect_ms_sum:.3},{detect_n},{span_ms}\n",
            e.at / crate::sim::MS
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predicate::spec::PredId;
    use crate::sim::{ProcId, MS};
    use crate::trace::{ActorKind, TraceCfg, TraceWitness};

    /// The golden hub: one quorum call, one apply + candidate, one
    /// violation, one adapt window — every writer branch that renders
    /// instants, spans and counters.
    fn golden_hub() -> TraceHub {
        let hub = crate::trace::TraceHub::new(TraceCfg::full(64));
        {
            let mut h = hub.borrow_mut();
            h.register(ProcId(0), ActorKind::Server, 0);
            h.register(ProcId(2), ActorKind::Monitor, 0);
            h.register(ProcId(4), ActorKind::Client, 0);
            h.register(ProcId(6), ActorKind::Adapt, 0);
            h.record(
                ProcId(4),
                10 * MS,
                1,
                TraceEv::ClientIssue { client: 0, req: 7, key: 5, put: true, epoch: 0 },
            );
            h.record(
                ProcId(0),
                12 * MS,
                2,
                TraceEv::ServerApply {
                    server: 0,
                    key: 5,
                    req: 7,
                    client: 4,
                    pt_ms: 12,
                    hvc: vec![12, 0],
                },
            );
            h.record(
                ProcId(0),
                12 * MS,
                2,
                TraceEv::CandidateEmit {
                    server: 0,
                    pred: PredId(0),
                    clause: 0,
                    conjunct: 0,
                    cseq: 0,
                    start_ms: 12,
                    end_ms: 12,
                    keys: vec![5],
                },
            );
            h.record(
                ProcId(4),
                15 * MS,
                3,
                TraceEv::ClientComplete { client: 0, req: 7, ok: true, latency: 5 * MS },
            );
            h.record(
                ProcId(2),
                20 * MS,
                4,
                TraceEv::Violation {
                    pred: PredId(0),
                    name: "me_1_2".into(),
                    clause: 0,
                    witnesses: vec![TraceWitness { server: 0, cseq: 0, start_ms: 12, end_ms: 12 }],
                    t_violate_ms: 12,
                    t_occurred_ms: 12,
                },
            );
            h.record(
                ProcId(6),
                30 * MS,
                5,
                TraceEv::AdaptWindow {
                    ops: 9,
                    timeouts: 1,
                    violations: 1,
                    stall_ms: 0,
                    lat_p99_ms: 4.5,
                    detect_ms_sum: 8.0,
                    detect_n: 1,
                    span_ms: 1000,
                },
            );
        }
        std::rc::Rc::try_unwrap(hub).unwrap().into_inner()
    }

    /// Byte-exact golden for the writer — the seeded hub is built by
    /// hand, so this pins the format itself, not a simulation.
    #[test]
    fn golden_chrome_json() {
        let expected = concat!(
            "{\"displayTimeUnit\":\"ms\",\"traceEvents\":[\n",
            "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\"ts\":0,\"args\":{\"name\":\"optikv\"}},\n",
            "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\"ts\":0,\"args\":{\"name\":\"server 0\"}},\n",
            "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":2,\"ts\":0,\"args\":{\"name\":\"monitor 0\"}},\n",
            "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":4,\"ts\":0,\"args\":{\"name\":\"client 0\"}},\n",
            "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":6,\"ts\":0,\"args\":{\"name\":\"adapt 0\"}},\n",
            "{\"name\":\"apply k5\",\"ph\":\"i\",\"pid\":1,\"tid\":0,\"ts\":12000,\"s\":\"t\",\"args\":{\"req\":7,\"client\":4,\"pt_ms\":12}},\n",
            "{\"name\":\"cand p0c0\",\"ph\":\"i\",\"pid\":1,\"tid\":0,\"ts\":12000,\"s\":\"t\",\"args\":{\"cseq\":0,\"start_ms\":12,\"end_ms\":12}},\n",
            "{\"name\":\"put k5\",\"ph\":\"X\",\"pid\":1,\"tid\":4,\"ts\":10000,\"dur\":5000,\"args\":{\"req\":7,\"epoch\":0,\"ok\":true}},\n",
            "{\"name\":\"violation me_1_2\",\"ph\":\"i\",\"pid\":1,\"tid\":2,\"ts\":20000,\"s\":\"g\",\"args\":{\"witnesses\":1,\"t_violate_ms\":12,\"t_occurred_ms\":12}},\n",
            "{\"name\":\"adapt-signals\",\"ph\":\"C\",\"pid\":1,\"tid\":6,\"ts\":30000,\"args\":{\"ops\":9,\"timeouts\":1,\"violations\":1,\"stall_ms\":0}}\n",
            "]}\n"
        );
        assert_eq!(chrome_trace_json(&golden_hub()), expected);
    }

    #[test]
    fn signals_csv_rows() {
        let csv = signals_csv(&golden_hub());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("at_ms,ops,"));
        assert_eq!(lines[1], "30,9,1,1,0,4.500,8.000,1,1000");
    }

    #[test]
    fn mode_and_recovery_spans_pair_up() {
        let hub = crate::trace::TraceHub::new(TraceCfg::ring(64));
        {
            let mut h = hub.borrow_mut();
            h.record(
                ProcId(6),
                5 * MS,
                1,
                TraceEv::ModeSwitch { epoch: 1, from: "eventual", to: "sequential" },
            );
            h.record(ProcId(5), 10 * MS, 2, TraceEv::RecoveryPhase { epoch: 1, phase: "begin" });
            h.record(ProcId(5), 11 * MS, 3, TraceEv::RecoveryPhase { epoch: 1, phase: "freeze" });
            h.record(ProcId(5), 18 * MS, 4, TraceEv::RecoveryPhase { epoch: 1, phase: "done" });
            h.record(
                ProcId(6),
                25 * MS,
                5,
                TraceEv::ModeSwitch { epoch: 2, from: "sequential", to: "eventual" },
            );
            h.record(ProcId(0), 40 * MS, 6, TraceEv::Fault { kind: "crash" });
        }
        let hub = std::rc::Rc::try_unwrap(hub).unwrap().into_inner();
        let json = chrome_trace_json(&hub);
        assert!(json.contains("\"name\":\"mode sequential\",\"ph\":\"X\",\"pid\":1,\"tid\":6,\"ts\":5000,\"dur\":20000"), "{json}");
        assert!(json.contains("\"name\":\"recovery e1\",\"ph\":\"X\",\"pid\":1,\"tid\":5,\"ts\":10000,\"dur\":8000"), "{json}");
        // the trailing mode epoch closes at the trace horizon (40ms)
        assert!(json.contains("\"name\":\"mode eventual\",\"ph\":\"X\",\"pid\":1,\"tid\":6,\"ts\":25000,\"dur\":15000"), "{json}");
        assert!(json.contains("\"name\":\"crash\",\"ph\":\"i\""));
    }
}
