//! Logical/physical clocks: vector clocks for value versions (Voldemort
//! role) and hybrid vector clocks for the monitoring module.

pub mod hvc;
pub mod vc;
