//! Hybrid Vector Clocks (HVC, Demirbas & Kulkarni) and the paper's
//! HVC-*interval* causality rule used by the monitors (§V, Fig. 6).
//!
//! An HVC at process `i` is a vector of the most recent *physical* times
//! process `i` knows about every process, with `hvc[i] = PT_i`. Entries are
//! floored at `PT_i - ε` (ε = clock synchronization error bound), which is
//! what allows compression when ε is finite; with ε = ∞ an HVC behaves as a
//! plain vector clock over physical timestamps (the setting the paper uses
//! in its experiments).
//!
//! Clock values are milliseconds (`i64`); the monitors and the AOT kernels
//! operate at this granularity. Coarsening only errs toward "concurrent",
//! the paper's safe direction (no missed violations).

use std::cmp::Ordering;

/// Physical time in milliseconds.
pub type Millis = i64;

/// Sentinel for "ε = ∞" (pure vector-clock behaviour).
pub const EPS_INF: Millis = i64::MAX / 4;

/// Comparison result for HVC vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HvcOrd {
    Equal,
    Before,
    After,
    Concurrent,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Hvc {
    /// owning process index (a server id in this system)
    pub owner: u16,
    /// dense vector, one entry per process, in ms
    pub v: Vec<Millis>,
}

impl Hvc {
    /// A fresh clock for process `owner` among `n` processes at time `pt`,
    /// with all remote entries at the `pt - eps` floor.
    pub fn new(owner: u16, n: usize, pt: Millis, eps: Millis) -> Self {
        let floor = pt.saturating_sub(eps);
        let mut v = vec![floor; n];
        v[owner as usize] = pt;
        Self { owner, v }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.v.len()
    }

    /// Advance on a local event / message *send* at physical time `pt`:
    /// `v[i] = pt`, `v[j] = max(v[j], pt - eps)`.
    pub fn tick(&mut self, pt: Millis, eps: Millis) {
        let floor = pt.saturating_sub(eps);
        for x in &mut self.v {
            if *x < floor {
                *x = floor;
            }
        }
        let i = self.owner as usize;
        if self.v[i] < pt {
            self.v[i] = pt;
        } else {
            // physical clock must appear monotone at its own index even if
            // the OS clock stalls: bump by one ms-step equivalent (0 keeps
            // the old value, which is still monotone)
            self.v[i] = self.v[i].max(pt);
        }
    }

    /// Merge a piggy-backed clock on message *receive* at physical time
    /// `pt`: `v[i] = pt`, `v[j] = max(msg[j], v[j], pt - eps)`.
    pub fn recv(&mut self, msg: &Hvc, pt: Millis, eps: Millis) {
        debug_assert_eq!(self.dim(), msg.dim());
        let floor = pt.saturating_sub(eps);
        for (x, &m) in self.v.iter_mut().zip(msg.v.iter()) {
            *x = (*x).max(m).max(floor);
        }
        let i = self.owner as usize;
        self.v[i] = self.v[i].max(pt);
    }

    /// Standard vector comparison.
    pub fn compare(&self, other: &Hvc) -> HvcOrd {
        debug_assert_eq!(self.dim(), other.dim());
        let mut less = false;
        let mut greater = false;
        for (a, b) in self.v.iter().zip(other.v.iter()) {
            match a.cmp(b) {
                Ordering::Less => less = true,
                Ordering::Greater => greater = true,
                Ordering::Equal => {}
            }
            if less && greater {
                return HvcOrd::Concurrent;
            }
        }
        match (less, greater) {
            (false, false) => HvcOrd::Equal,
            (true, false) => HvcOrd::Before,
            (false, true) => HvcOrd::After,
            (true, true) => HvcOrd::Concurrent,
        }
    }

    #[inline]
    pub fn strictly_before(&self, other: &Hvc) -> bool {
        self.compare(other) == HvcOrd::Before
    }

    /// Number of entries that differ from the `pt - eps` floor — the
    /// compressed representation size the paper describes (a bitmap of n
    /// bits plus this many explicit integers).
    pub fn compressed_len(&self, eps: Millis) -> usize {
        let pt = self.v[self.owner as usize];
        let floor = pt.saturating_sub(eps);
        self.v.iter().filter(|&&x| x != floor).count()
    }

    /// Compress to (bitmap, explicit values); inverse of [`Hvc::decompress`].
    pub fn compress(&self, eps: Millis) -> (Vec<bool>, Vec<Millis>) {
        let pt = self.v[self.owner as usize];
        let floor = pt.saturating_sub(eps);
        let bitmap: Vec<bool> = self.v.iter().map(|&x| x != floor).collect();
        let vals: Vec<Millis> = self.v.iter().copied().filter(|&x| x != floor).collect();
        (bitmap, vals)
    }

    pub fn decompress(owner: u16, bitmap: &[bool], vals: &[Millis], pt: Millis, eps: Millis) -> Self {
        let floor = pt.saturating_sub(eps);
        let mut vi = vals.iter();
        let v = bitmap
            .iter()
            .map(|&set| if set { *vi.next().expect("bitmap/vals mismatch") } else { floor })
            .collect();
        Self { owner, v }
    }
}

/// An HVC interval `[start, end]` on a server — the time span attached to a
/// candidate sent to a monitor (the local predicate held throughout it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HvcInterval {
    pub start: Hvc,
    pub end: Hvc,
}

/// Verdict of the paper's 3-case interval causality rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntervalOrd {
    /// overlapping or within the ε-uncertainty window → treated concurrent
    Concurrent,
    /// first interval happened before the second
    Before,
    /// second interval happened before the first
    After,
}

impl HvcInterval {
    pub fn new(start: Hvc, end: Hvc) -> Self {
        debug_assert_eq!(start.owner, end.owner);
        Self { start, end }
    }

    pub fn owner(&self) -> u16 {
        self.start.owner
    }

    /// The paper's rule (§V "Implementation of the monitors", Fig. 6),
    /// applied after orienting so that ¬(start_a > start_b):
    ///
    /// 1. if ¬(end_a < start_b)          → Concurrent (common segment);
    /// 2. if end_a < start_b and
    ///    end_a[Sa] ≤ start_b[Sb] − ε    → `a` Before `b`;
    /// 3. if end_a < start_b but the physical separation is within ε
    ///                                   → Concurrent (uncertain, safe).
    pub fn verdict(a: &HvcInterval, b: &HvcInterval, eps: Millis) -> IntervalOrd {
        // orient: ensure ¬(start_a > start_b)
        if a.start.compare(&b.start) == HvcOrd::After {
            return match Self::verdict(b, a, eps) {
                IntervalOrd::Before => IntervalOrd::After,
                IntervalOrd::After => IntervalOrd::Before,
                IntervalOrd::Concurrent => IntervalOrd::Concurrent,
            };
        }
        if a.end.strictly_before(&b.start) {
            let pa = a.end.v[a.owner() as usize];
            let pb = b.start.v[b.owner() as usize];
            if pa <= pb.saturating_sub(eps) {
                IntervalOrd::Before
            } else {
                IntervalOrd::Concurrent
            }
        } else {
            // overlap (including vector-concurrent endpoints): common segment
            IntervalOrd::Concurrent
        }
    }

    /// Convenience: are the two intervals to be treated as concurrent?
    pub fn concurrent(a: &HvcInterval, b: &HvcInterval, eps: Millis) -> bool {
        Self::verdict(a, b, eps) == IntervalOrd::Concurrent
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn hvc(owner: u16, v: &[Millis]) -> Hvc {
        Hvc { owner, v: v.to_vec() }
    }

    #[test]
    fn paper_compression_example() {
        // n=10, eps=20, HVC_0 = [100,80,80,95,80,80,100,80,80,80]
        // → 3 explicit integers (100, 95, 100)
        let h = hvc(0, &[100, 80, 80, 95, 80, 80, 100, 80, 80, 80]);
        assert_eq!(h.compressed_len(20), 3);
        let (bitmap, vals) = h.compress(20);
        assert_eq!(vals, vec![100, 95, 100]);
        let back = Hvc::decompress(0, &bitmap, &vals, 100, 20);
        assert_eq!(back, h);
    }

    #[test]
    fn tick_and_recv_monotone() {
        let eps = 10;
        let mut a = Hvc::new(0, 3, 100, eps);
        a.tick(105, eps);
        assert_eq!(a.v[0], 105);
        assert_eq!(a.v[1], 95);
        let b = Hvc::new(1, 3, 104, eps);
        let before = a.clone();
        a.recv(&b, 106, eps);
        assert_eq!(a.v[0], 106);
        assert_eq!(a.v[1], 104); // learned from b
        assert!(matches!(before.compare(&a), HvcOrd::Before));
    }

    #[test]
    fn compare_cases() {
        let a = hvc(0, &[5, 5]);
        let b = hvc(0, &[6, 6]);
        let c = hvc(1, &[6, 4]);
        assert_eq!(a.compare(&b), HvcOrd::Before);
        assert_eq!(b.compare(&a), HvcOrd::After);
        assert_eq!(a.compare(&a), HvcOrd::Equal);
        assert_eq!(a.compare(&c), HvcOrd::Concurrent);
    }

    #[test]
    fn interval_rule_overlap() {
        // intervals share a segment → concurrent regardless of eps
        let i1 = HvcInterval::new(hvc(0, &[10, 0]), hvc(0, &[20, 0]));
        let i2 = HvcInterval::new(hvc(1, &[15, 15]), hvc(1, &[15, 25]));
        assert_eq!(HvcInterval::verdict(&i1, &i2, 0), IntervalOrd::Concurrent);
    }

    #[test]
    fn interval_rule_clear_precedence() {
        // end1 < start2 vector-wise AND physically separated by > eps
        let i1 = HvcInterval::new(hvc(0, &[10, 5]), hvc(0, &[20, 5]));
        let i2 = HvcInterval::new(hvc(1, &[25, 40]), hvc(1, &[25, 50]));
        assert_eq!(HvcInterval::verdict(&i1, &i2, 5), IntervalOrd::Before);
        assert_eq!(HvcInterval::verdict(&i2, &i1, 5), IntervalOrd::After);
    }

    #[test]
    fn interval_rule_uncertain_window() {
        // end1 < start2 vector-wise, but physical separation within eps →
        // uncertain → concurrent (the "avoid missing possible bugs" case)
        let i1 = HvcInterval::new(hvc(0, &[10, 5]), hvc(0, &[20, 5]));
        let i2 = HvcInterval::new(hvc(1, &[25, 22]), hvc(1, &[25, 50]));
        // separation = start2[1] - end1[0] = 22 - 20 = 2 < eps=5
        assert_eq!(HvcInterval::verdict(&i1, &i2, 5), IntervalOrd::Concurrent);
        // with eps=1 it's a clear precedence (20 <= 22 - 1)
        assert_eq!(HvcInterval::verdict(&i1, &i2, 1), IntervalOrd::Before);
    }

    fn random_hvc(rng: &mut Rng, owner: u16, n: usize) -> Hvc {
        let base = rng.range(0, 1000) as i64;
        let v = (0..n).map(|_| base + rng.range(0, 50) as i64).collect();
        Hvc { owner, v }
    }

    fn random_interval(rng: &mut Rng, n: usize) -> HvcInterval {
        let owner = rng.below(n as u64) as u16;
        let s = random_hvc(rng, owner, n);
        let mut e = s.clone();
        for x in &mut e.v {
            *x += rng.range(0, 40) as i64;
        }
        e.v[owner as usize] += 1; // end strictly after start at owner
        HvcInterval::new(s, e)
    }

    #[test]
    fn prop_hvc_compare_antisymmetric() {
        prop::check_default("hvc_antisymmetric", |rng| {
            let n = rng.range(2, 6) as usize;
            let a = random_hvc(rng, 0, n);
            let b = random_hvc(rng, 1, n);
            let ok = matches!(
                (a.compare(&b), b.compare(&a)),
                (HvcOrd::Equal, HvcOrd::Equal)
                    | (HvcOrd::Before, HvcOrd::After)
                    | (HvcOrd::After, HvcOrd::Before)
                    | (HvcOrd::Concurrent, HvcOrd::Concurrent)
            );
            if ok {
                Ok(())
            } else {
                Err(format!("a={a:?} b={b:?}"))
            }
        });
    }

    #[test]
    fn prop_interval_verdict_antisymmetric() {
        prop::check_default("interval_antisymmetric", |rng| {
            let n = rng.range(2, 6) as usize;
            let a = random_interval(rng, n);
            let b = random_interval(rng, n);
            let eps = rng.range(0, 30) as i64;
            let ok = matches!(
                (HvcInterval::verdict(&a, &b, eps), HvcInterval::verdict(&b, &a, eps)),
                (IntervalOrd::Concurrent, IntervalOrd::Concurrent)
                    | (IntervalOrd::Before, IntervalOrd::After)
                    | (IntervalOrd::After, IntervalOrd::Before)
            );
            if ok {
                Ok(())
            } else {
                Err(format!("a={a:?} b={b:?} eps={eps}"))
            }
        });
    }

    #[test]
    fn prop_larger_eps_never_unconcurrents() {
        // Growing ε only moves verdicts toward Concurrent (safety): if two
        // intervals are concurrent at ε they stay concurrent at ε' > ε.
        prop::check_default("eps_monotone_safety", |rng| {
            let n = rng.range(2, 5) as usize;
            let a = random_interval(rng, n);
            let b = random_interval(rng, n);
            let e1 = rng.range(0, 20) as i64;
            let e2 = e1 + rng.range(1, 20) as i64;
            let v1 = HvcInterval::verdict(&a, &b, e1);
            let v2 = HvcInterval::verdict(&a, &b, e2);
            if v1 == IntervalOrd::Concurrent && v2 != IntervalOrd::Concurrent {
                return Err(format!("eps {e1}->{e2} un-concurrented: {a:?} {b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_compress_roundtrip() {
        prop::check_default("hvc_compress_roundtrip", |rng| {
            let n = rng.range(2, 12) as usize;
            let owner = rng.below(n as u64) as u16;
            let eps = rng.range(1, 50) as i64;
            let pt = rng.range(100, 10_000) as i64;
            let mut h = Hvc::new(owner, n, pt, eps);
            // randomly raise some entries above the floor
            for j in 0..n {
                if rng.chance(0.4) {
                    h.v[j] = pt - rng.range(0, eps as u64) as i64;
                }
            }
            h.v[owner as usize] = pt;
            let (bm, vals) = h.compress(eps);
            let back = Hvc::decompress(owner, &bm, &vals, pt, eps);
            if back != h {
                return Err(format!("roundtrip mismatch {h:?} -> {back:?}"));
            }
            Ok(())
        });
    }
}
